"""L2 correctness: the scanned chain vs the oracle, and chain stationarity.

The strongest test here is `test_chain_matches_exact_marginals`: the
primal-dual sampler targets p(x, theta) whose x-marginal is the MRF p(x);
on a tiny model we compare empirical single-site marginals against exact
enumeration — this validates the *entire* L1+L2 stack as a Markov kernel,
not just bitwise plumbing.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import dualize, model
from compile.kernels import ref


def _tiny_model(n_pad=8, f_pad=8, seed=0, n=3):
    """3-variable chain MRF with random positive tables + unary fields."""
    rng = np.random.default_rng(seed)
    edges = [(0, 1), (1, 2)]
    tables = [np.exp(rng.normal(size=(2, 2))) for _ in edges]
    unary = rng.normal(size=n).astype(np.float32) * 0.5
    ops = dualize.dense_operands(n, edges, tables, unary, n_pad, f_pad)
    return edges, tables, unary, ops


def _exact_marginals(n, edges, tables, unary):
    probs = np.zeros(2**n)
    for idx in range(2**n):
        x = [(idx >> v) & 1 for v in range(n)]
        logp = sum(unary[v] * x[v] for v in range(n))
        logp += sum(
            np.log(tables[i][x[e1], x[e2]]) for i, (e1, e2) in enumerate(edges)
        )
        probs[idx] = np.exp(logp)
    probs /= probs.sum()
    marg = np.zeros(n)
    for idx in range(2**n):
        for v in range(n):
            if (idx >> v) & 1:
                marg[v] += probs[idx]
    return marg


def _run_chain(ops, *, n, chains, sweeps, seed, use_pallas=True, bn=8, bk=8):
    j, a, q, b1, b2, v1, v2 = ops
    n_pad = j.shape[1]
    f_pad = j.shape[0]
    x0 = jnp.zeros((chains, n_pad), jnp.float32)
    th0 = jnp.zeros((chains, f_pad), jnp.float32)
    key = jax.random.key_data(jax.random.PRNGKey(seed)).astype(jnp.uint32)
    fn = jax.jit(
        lambda *args: model.pd_chain(
            *args, n=n, sweeps=sweeps, bn=bn, bk=bk, use_pallas=use_pallas
        )
    )
    return fn(
        x0, th0, jnp.array(j), jnp.array(a), jnp.array(q), jnp.array(b1),
        jnp.array(b2), jnp.array(v1), jnp.array(v2), key,
    )


def test_chain_pallas_equals_ref_path():
    """Same key => the pallas-kernel chain and the pure-jnp chain agree exactly."""
    _, _, _, ops = _tiny_model()
    out_p = _run_chain(ops, n=3, chains=4, sweeps=20, seed=1, use_pallas=True)
    out_r = _run_chain(ops, n=3, chains=4, sweeps=20, seed=1, use_pallas=False)
    for a_, b_ in zip(out_p, out_r):
        np.testing.assert_array_equal(np.asarray(a_), np.asarray(b_))


def test_chain_matches_pd_chain_ref():
    """pd_chain (scan) == pd_chain_ref (python loop) bit-for-bit."""
    _, _, _, ops = _tiny_model(seed=3)
    j, a, q, b1, b2, v1, v2 = ops
    chains, sweeps = 2, 7
    x0 = jnp.zeros((chains, j.shape[1]), jnp.float32)
    th0 = jnp.zeros((chains, j.shape[0]), jnp.float32)
    key = jax.random.PRNGKey(11)
    x_r, th_r = ref.pd_chain_ref(
        x0, th0, jnp.array(j), jnp.array(a), jnp.array(q), jnp.array(b1),
        jnp.array(b2), jnp.array(v1), jnp.array(v2), key, sweeps
    )
    out = _run_chain(ops, n=3, chains=chains, sweeps=sweeps, seed=11,
                     use_pallas=False)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(x_r))
    np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(th_r))


def test_sum_and_mag_outputs_consistent():
    _, _, _, ops = _tiny_model(seed=5)
    x, th, sum_x, mag = _run_chain(ops, n=3, chains=2, sweeps=16, seed=2)
    assert mag.shape == (16, 2)
    # The final sweep's magnetization must match the returned x.
    np.testing.assert_allclose(
        np.asarray(mag)[-1], np.asarray(x)[:, :3].mean(axis=1), rtol=1e-6
    )
    assert np.all(np.asarray(sum_x) >= 0)
    assert np.all(np.asarray(sum_x) <= 16)
    # Padded columns are inert (a = -40).
    assert np.all(np.asarray(x)[:, 3:] == 0)
    assert np.all(np.asarray(sum_x)[:, 3:] == 0)


def test_chain_matches_exact_marginals():
    """Empirical marginals from long PD chains track exact enumeration."""
    edges, tables, unary, ops = _tiny_model(seed=9)
    exact = _exact_marginals(3, edges, tables, unary)
    burn, keep, chains = 200, 3000, 8
    _, _, _, _ = _run_chain(ops, n=3, chains=chains, sweeps=burn, seed=0)
    # continue from burn-in state: rerun a long chain and use sum_x
    x, th, sum_x, mag = _run_chain(ops, n=3, chains=chains, sweeps=burn + keep,
                                   seed=4)
    # sum over all sweeps; subtract nothing (burn-in bias is tiny at 3k
    # samples x 8 chains for a 3-variable model, tolerance accounts for it)
    est = np.asarray(sum_x)[:, :3].sum(axis=0) / (chains * (burn + keep))
    np.testing.assert_allclose(est, exact, atol=0.03)


def test_pad_dims():
    assert model.pad_dims(2500, 4900, 256, 256) == (2560, 5120)
    assert model.pad_dims(256, 480, 256, 256) == (256, 512)
    assert model.pad_dims(100, 4950, 128, 256) == (104, 5120)
    n_pad, f_pad = model.pad_dims(3, 2, 256, 256)
    assert n_pad >= 3 and f_pad >= 2


def test_make_chain_fn_specs():
    fn, specs = model.make_chain_fn(n=100, f=4950, chains=10, sweeps=4,
                                    bn=128, bk=256)
    assert specs[0].shape == (10, 104)       # x padded to a multiple of 8
    assert specs[2].shape == (5120, 104)     # J (f_pad, n_pad)
    assert specs[9].dtype == jnp.uint32
    out = jax.eval_shape(fn, *specs)
    assert out[3].shape == (4, 10)           # mag (sweeps, chains)
