"""Section 4.1 math: positive factorization and Theorem-2 dual parameters."""

import hypothesis
import hypothesis.strategies as st
import numpy as np
import pytest

from compile import dualize

log_entry = st.floats(-6.0, 6.0, allow_nan=False, allow_infinity=False)


def tables(draw):
    vals = [draw(log_entry) for _ in range(4)]
    return np.exp(np.array(vals)).reshape(2, 2)


@st.composite
def positive_tables(draw):
    return tables(draw)


@hypothesis.settings(max_examples=300, deadline=None)
@hypothesis.given(positive_tables())
def test_factorization_positive_and_exact(p):
    """Lemmas 2-4: P = B C^T with strictly positive B, C."""
    b, c = dualize.factorize_positive(p)
    assert np.all(b > 0), b
    assert np.all(c > 0), c
    np.testing.assert_allclose(b @ c.T, p, rtol=1e-8, atol=1e-12)


@hypothesis.settings(max_examples=300, deadline=None)
@hypothesis.given(positive_tables())
def test_theorem2_reconstructs_table(p):
    """Summing theta out of the dual model recovers P up to one global scale."""
    d = dualize.dualize_table(p)
    t = d.table()
    ratio = t / p
    np.testing.assert_allclose(ratio, ratio[0, 0], rtol=1e-7)


def test_symmetric_psd_table_identity_path():
    """Symmetric det>=0 tables hit Lemma 2 directly: B == C."""
    p = np.array([[2.0, 1.0], [1.0, 2.0]])
    b, c = dualize.factorize_positive(p)
    np.testing.assert_allclose(b @ c.T, p, rtol=1e-10)


def test_negative_det_swap_path():
    """Anti-ferromagnetic (det < 0) tables require the Lemma-4 swap."""
    p = np.array([[0.5, 2.0], [2.0, 0.5]])
    assert np.linalg.det(p) < 0
    b, c = dualize.factorize_positive(p)
    assert np.all(b > 0) and np.all(c > 0)
    np.testing.assert_allclose(b @ c.T, p, rtol=1e-8)


def test_near_singular_table():
    p = np.array([[1.0, 1.0], [1.0, 1.0 + 1e-12]])
    b, c = dualize.factorize_positive(p)
    np.testing.assert_allclose(b @ c.T, p, rtol=1e-6)


def test_rejects_nonpositive():
    with pytest.raises(ValueError):
        dualize.factorize_positive(np.array([[1.0, 0.0], [1.0, 1.0]]))
    with pytest.raises(ValueError):
        dualize.factorize_positive(np.array([[1.0, -1.0], [1.0, 1.0]]))


@hypothesis.settings(max_examples=100, deadline=None)
@hypothesis.given(st.floats(0.01, 3.0))
def test_ising_table_duality(beta):
    d = dualize.dualize_table(dualize.ising_table(beta))
    t = d.table()
    ratio = t / dualize.ising_table(beta)
    np.testing.assert_allclose(ratio, ratio[0, 0], rtol=1e-7)


def test_dense_operands_tiny_chain():
    """Exact marginal check: 2-variable chain, brute force over (x, theta)."""
    beta = 0.7
    p = dualize.ising_table(beta)
    j, a, q, b1, b2, v1, v2 = dualize.dense_operands(2, [(0, 1)], [p])
    assert j.shape == (1, 2)
    # enumerate p(x1, x2) = sum_theta exp(a.x + q th + th (b1 x1 + b2 x2))
    table = np.zeros((2, 2))
    for x1 in (0, 1):
        for x2 in (0, 1):
            for th in (0, 1):
                e = a[0, 0] * x1 + a[0, 1] * x2 + q[0] * th
                e += th * (b1[0] * x1 + b2[0] * x2)
                table[x1, x2] += np.exp(e)
    ratio = table / p
    np.testing.assert_allclose(ratio, ratio[0, 0], rtol=1e-5)


def test_dense_operands_padding_inert():
    """Padded rows/cols must not perturb the model (a_pad=-40, q_pad=-40)."""
    p = dualize.ising_table(0.5)
    j, a, q, b1, b2, v1, v2 = dualize.dense_operands(
        2, [(0, 1)], [p], n_pad=8, f_pad=4
    )
    assert j.shape == (4, 8)
    assert np.all(a[0, 2:] == -40.0)
    assert np.all(q[1:] == -40.0)
    assert np.all(j[1:, :] == 0) and np.all(j[:, 2:] == 0)


def test_unary_logodds_folded():
    p = dualize.ising_table(0.2)
    unary = np.array([0.3, -0.4], dtype=np.float32)
    j, a, *_ = dualize.dense_operands(2, [(0, 1)], [p], unary_logodds=unary)
    d = dualize.dualize_table(p)
    np.testing.assert_allclose(
        a[0], [0.3 + d.alpha1, -0.4 + d.alpha2], rtol=1e-5
    )
