"""L1 correctness: the Pallas kernel vs the pure-jnp oracle.

The kernel must agree bit-for-bit with ref.field_sample_ref given identical
uniforms (both compute an f32 field then compare), across shapes, tilings
and input regimes. hypothesis sweeps the shape/tile space.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import pd_sweep, ref


def _random_inputs(rng, c, f, n, coupling=0.5):
    theta = (rng.random((c, f)) < 0.5).astype(np.float32)
    j = (rng.normal(size=(f, n)) * coupling).astype(np.float32)
    a = rng.normal(size=(1, n)).astype(np.float32)
    u = rng.random((c, n)).astype(np.float32)
    return jnp.array(theta), jnp.array(j), jnp.array(a), jnp.array(u)


def _assert_kernel_matches(c, f, n, bn, bk, seed=0, coupling=0.5):
    rng = np.random.default_rng(seed)
    theta, j, a, u = _random_inputs(rng, c, f, n, coupling)
    got = pd_sweep.field_sample(theta, j, a, u, bn=bn, bk=bk)
    want = ref.field_sample_ref(theta, j, a, u)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.dtype == jnp.float32
    vals = np.unique(np.asarray(got))
    assert set(vals.tolist()) <= {0.0, 1.0}


def test_kernel_basic():
    _assert_kernel_matches(c=4, f=512, n=512, bn=256, bk=256)


def test_kernel_single_tile():
    _assert_kernel_matches(c=2, f=128, n=128, bn=128, bk=128)


def test_kernel_many_k_tiles():
    _assert_kernel_matches(c=3, f=1024, n=128, bn=128, bk=64)


def test_kernel_many_n_tiles():
    _assert_kernel_matches(c=3, f=64, n=1024, bn=128, bk=64)


def test_kernel_single_chain():
    _assert_kernel_matches(c=1, f=256, n=256, bn=128, bk=128)


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(
    c=st.integers(1, 8),
    nn=st.integers(1, 4),
    nk=st.integers(1, 4),
    bn=st.sampled_from([64, 128, 256]),
    bk=st.sampled_from([64, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_shape_sweep(c, nn, nk, bn, bk, seed):
    """Property: kernel == oracle for every divisible (C, F, N, BN, BK)."""
    _assert_kernel_matches(c=c, f=nk * bk, n=nn * bn, bn=bn, bk=bk, seed=seed)


@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(
    coupling=st.floats(0.0, 8.0, allow_nan=False),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_extreme_fields(coupling, seed):
    """Strong couplings saturate sigmoid; kernel must still match exactly."""
    _assert_kernel_matches(c=4, f=256, n=256, bn=128, bk=128, seed=seed,
                           coupling=coupling)


def test_kernel_zero_theta_reduces_to_unary():
    """With theta = 0 the sample depends only on the unary field a."""
    rng = np.random.default_rng(7)
    c, f, n = 4, 128, 128
    theta = jnp.zeros((c, f), jnp.float32)
    j = jnp.array(rng.normal(size=(f, n)), jnp.float32)
    a = jnp.array(rng.normal(size=(1, n)), jnp.float32)
    u = jnp.array(rng.random((c, n)), jnp.float32)
    got = pd_sweep.field_sample(theta, j, a, u, bn=128, bk=128)
    want = (np.asarray(u) < jax.nn.sigmoid(np.asarray(a))).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_kernel_rejects_bad_shapes():
    with pytest.raises(AssertionError):
        _assert_kernel_matches(c=2, f=100, n=128, bn=128, bk=64)  # f % bk != 0


def test_kernel_marginal_statistics():
    """Sampling frequencies track sigmoid(field) (statistical sanity)."""
    rng = np.random.default_rng(3)
    c, f, n, reps = 1, 128, 256, 400
    theta = jnp.array((rng.random((c, f)) < 0.5), jnp.float32)
    j = jnp.array(rng.normal(size=(f, n)) * 0.1, jnp.float32)
    a = jnp.array(rng.normal(size=(1, n)), jnp.float32)
    field = np.asarray(theta) @ np.asarray(j) + np.asarray(a)
    p = 1.0 / (1.0 + np.exp(-field))
    acc = np.zeros((c, n))
    for r in range(reps):
        u = jnp.array(rng.random((c, n)), jnp.float32)
        acc += np.asarray(pd_sweep.field_sample(theta, j, a, u, bn=128, bk=128))
    freq = acc / reps
    # 400 Bernoulli reps: generous 5-sigma band.
    sigma = np.sqrt(p * (1 - p) / reps)
    assert np.all(np.abs(freq - p) < 5 * sigma + 1e-6)
