"""AOT path: lowering produces parseable HLO text + a consistent manifest."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def grid16_artifact(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    text, meta = aot.lower_config("grid16", aot.ARTIFACT_CONFIGS["grid16"])
    path = os.path.join(out, meta["file"])
    with open(path, "w") as fh:
        fh.write(text)
    return text, meta, path


def test_hlo_text_shape(grid16_artifact):
    text, meta, _ = grid16_artifact
    assert text.startswith("HloModule")
    assert "entry_computation_layout" in text
    # while loop from lax.scan must be present (no unrolled 8x body)
    assert "while" in text


def test_manifest_operands(grid16_artifact):
    _, meta, _ = grid16_artifact
    names = [o["name"] for o in meta["operands"]]
    assert names == list(aot.OPERAND_NAMES)
    shapes = {o["name"]: tuple(o["shape"]) for o in meta["operands"]}
    assert shapes["x"] == (meta["chains"], meta["n_pad"])
    assert shapes["j"] == (meta["f_pad"], meta["n_pad"])
    assert shapes["key"] == (2,)
    outs = {o["name"]: tuple(o["shape"]) for o in meta["outputs"]}
    assert outs["mag"] == (meta["sweeps"], meta["chains"])


def test_all_configs_have_consistent_padding():
    for name, cfg in aot.ARTIFACT_CONFIGS.items():
        n_pad, f_pad = model.pad_dims(cfg["n"], cfg["f"], cfg["bn"], cfg["bk"])
        assert n_pad >= cfg["n"] and f_pad >= cfg["f"], name
        assert n_pad % min(cfg["bn"], n_pad) == 0, name


def test_lowered_module_executes_in_jax(grid16_artifact):
    """Sanity: the exact computation we ship also runs under jax.jit here."""
    cfg = aot.ARTIFACT_CONFIGS["grid16"]
    fn, specs = model.make_chain_fn(
        n=cfg["n"], f=cfg["f"], chains=cfg["chains"], sweeps=cfg["sweeps"],
        bn=cfg["bn"], bk=cfg["bk"],
    )
    args = []
    rng = np.random.default_rng(0)
    for s in specs:
        if s.dtype == jnp.uint32:
            args.append(jnp.array([1, 2], jnp.uint32))
        elif s.dtype == jnp.int32:
            args.append(jnp.zeros(s.shape, jnp.int32))
        else:
            args.append(jnp.array(rng.random(s.shape) * 0.1, jnp.float32))
    x, th, sum_x, mag = jax.jit(fn)(*args)
    assert x.shape == (cfg["chains"], 256)
    assert mag.shape == (cfg["sweeps"], cfg["chains"])
    assert np.all(np.isfinite(np.asarray(mag)))
