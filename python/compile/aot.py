"""AOT entrypoint: lower the L2 chain to HLO text artifacts.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the rust `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Writes one `pd_chain_<name>.hlo.txt` per config plus `manifest.json`
describing every operand shape so the Rust runtime can marshal literals
without re-deriving padding rules.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model

# name -> (variables, factors, chains, sweeps per call, bn, bk)
# Matches DESIGN.md section 8. grid50 is Fig 2a / the denoise example,
# fc100 is Fig 2b, rand1000_k2 the random-graph bench, grid16 tests.
#
# Tile-size policy (EXPERIMENTS.md §Perf): on a real TPU the kernel would
# use (BN=256, BK=256) VMEM tiles; under interpret=True every grid step
# round-trips block copies through the emulator, costing ~70x on large
# models (measured 76.6s -> 0.12s per grid50 chunk). grid16 keeps the
# TPU tiling so the multi-step grid semantics stay covered end-to-end;
# the large artifacts use whole-array tiles (grid = (1, 1)) on CPU.
ARTIFACT_CONFIGS = {
    "grid16": dict(n=256, f=480, chains=4, sweeps=8, bn=256, bk=256),
    "grid50": dict(n=2500, f=4900, chains=10, sweeps=16, bn=4096, bk=8192,
                   use_pallas=False),
    "fc100": dict(n=100, f=4950, chains=10, sweeps=32, bn=128, bk=8192,
                  use_pallas=False),
    "rand1000_k2": dict(n=1000, f=2000, chains=10, sweeps=16, bn=1024, bk=2048,
                        use_pallas=False),
}

OPERAND_NAMES = ("x", "theta", "j", "a", "q", "b1", "b2", "v1", "v2", "key")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_config(name: str, cfg: dict) -> tuple[str, dict]:
    fn, specs = model.make_chain_fn(
        n=cfg["n"], f=cfg["f"], chains=cfg["chains"], sweeps=cfg["sweeps"],
        bn=cfg["bn"], bk=cfg["bk"], use_pallas=cfg.get("use_pallas", True),
    )
    lowered = jax.jit(fn).lower(*specs)
    n_pad, f_pad = model.pad_dims(cfg["n"], cfg["f"], cfg["bn"], cfg["bk"])
    meta = {
        "name": name,
        "file": f"pd_chain_{name}.hlo.txt",
        "n": cfg["n"],
        "f": cfg["f"],
        "chains": cfg["chains"],
        "sweeps": cfg["sweeps"],
        "n_pad": n_pad,
        "f_pad": f_pad,
        "operands": [
            {"name": nm, "shape": list(s.shape), "dtype": s.dtype.name}
            for nm, s in zip(OPERAND_NAMES, specs)
        ],
        "outputs": [
            {"name": "x", "shape": [cfg["chains"], n_pad], "dtype": "float32"},
            {"name": "theta", "shape": [cfg["chains"], f_pad], "dtype": "float32"},
            {"name": "sum_x", "shape": [cfg["chains"], n_pad], "dtype": "float32"},
            {"name": "mag", "shape": [cfg["sweeps"], cfg["chains"]], "dtype": "float32"},
        ],
    }
    return to_hlo_text(lowered), meta


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--only", nargs="*", default=None,
        help="subset of config names to lower (default: all)",
    )
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for name, cfg in ARTIFACT_CONFIGS.items():
        if args.only and name not in args.only:
            continue
        text, meta = lower_config(name, cfg)
        path = os.path.join(args.out_dir, meta["file"])
        with open(path, "w") as fh:
            fh.write(text)
        manifest.append(meta)
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as fh:
        json.dump({"artifacts": manifest}, fh, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
