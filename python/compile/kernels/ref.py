"""Pure-jnp oracles for the Pallas kernel and the full primal-dual sweep.

Everything here is the *specification*: tests assert the Pallas kernel and
the scanned model reproduce these functions bit-for-bit (same uniforms, f32).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def field_sample_ref(theta, j, a, u):
    """Reference for kernels.pd_sweep.field_sample (same signature)."""
    field = jnp.dot(theta, j, preferred_element_type=jnp.float32) + a
    return (u < jax.nn.sigmoid(field)).astype(jnp.float32)


def theta_update_ref(x, q, b1, b2, v1, v2, u):
    """Dual update: theta_i ~ Bernoulli(sigmoid(q_i + b1_i x_{v1} + b2_i x_{v2})).

    Args:
      x:  (C, Np) f32 primal states (padded; v1/v2 index real columns only).
      q, b1, b2: (Fp,) f32 dual factor parameters.
      v1, v2: (Fp,) i32 endpoint indices.
      u:  (C, Fp) f32 uniforms.
    Returns (C, Fp) f32 in {0., 1.}.
    """
    x1 = jnp.take(x, v1, axis=1)
    x2 = jnp.take(x, v2, axis=1)
    t = q + b1 * x1 + b2 * x2
    return (u < jax.nn.sigmoid(t)).astype(jnp.float32)


def pd_sweep_ref(x, theta, j, a, q, b1, b2, v1, v2, ux, ut):
    """One full primal-dual sweep, given explicit uniforms: x|theta then theta|x."""
    x = field_sample_ref(theta, j, a, ux)
    theta = theta_update_ref(x, q, b1, b2, v1, v2, ut)
    return x, theta


def pd_chain_ref(x, theta, j, a, q, b1, b2, v1, v2, key, sweeps: int):
    """Multi-sweep chain with the same PRNG discipline as model.pd_chain."""
    c, n = x.shape
    f = theta.shape[1]
    for k in jax.random.split(key, sweeps):
        kx, kt = jax.random.split(k)
        ux = jax.random.uniform(kx, (c, n), dtype=jnp.float32)
        ut = jax.random.uniform(kt, (c, f), dtype=jnp.float32)
        x, theta = pd_sweep_ref(x, theta, j, a, q, b1, b2, v1, v2, ux, ut)
    return x, theta
