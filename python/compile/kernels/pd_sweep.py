"""Layer-1 Pallas kernel: the primal-dual x-update hot spot.

The x-update of the primal-dual Gibbs sweep is, for every chain c and
variable v in parallel,

    field[c, v] = a[v] + sum_i theta[c, i] * J[i, v]
    x[c, v]     = 1{ u[c, v] < sigmoid(field[c, v]) }

i.e. a (C x F) @ (F x N) matmul followed by a cheap elementwise epilogue.
On a real TPU the matmul runs on the MXU and the epilogue on the VPU; the
kernel tiles the output into (C, BN) blocks and loops over F in BK chunks,
staging HBM -> VMEM via BlockSpec. This is the TPU re-think of the paper's
"one GPU thread per variable" formulation (see DESIGN.md
section Hardware-Adaptation).

The kernel MUST be lowered with interpret=True in this environment: the CPU
PJRT plugin cannot execute Mosaic custom-calls. Numerics are validated
against the pure-jnp oracle in ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. BN is the output-column tile (lane dimension on TPU,
# multiple of 128); BK the contraction tile. The chain dimension C is small
# (4-16 in every artifact config) and is kept whole in each block: it plays
# the role of the sublane dimension.
DEFAULT_BN = 256
DEFAULT_BK = 256


def _field_sample_kernel(theta_ref, j_ref, a_ref, u_ref, x_ref, *, nk: int):
    """One (n, k) grid step of the tiled matmul + Bernoulli epilogue.

    Grid is (N/BN, F/BK) with k innermost, so for a fixed output block we
    visit k = 0..nk-1 consecutively and may use x_ref as the accumulator
    (output revisiting).
    """
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        x_ref[...] = jnp.zeros_like(x_ref)

    # MXU work: (C, BK) @ (BK, BN) accumulated in f32.
    x_ref[...] += jnp.dot(
        theta_ref[...], j_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        field = x_ref[...] + a_ref[...]  # a broadcasts over chains
        x_ref[...] = (u_ref[...] < jax.nn.sigmoid(field)).astype(jnp.float32)


def field_sample(
    theta: jax.Array,
    j: jax.Array,
    a: jax.Array,
    u: jax.Array,
    *,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    """Sample x ~ prod_v Bernoulli(sigmoid(a_v + (theta @ J)_v)) elementwise.

    Args:
      theta: (C, F) f32 — dual states, one column per factor.
      j:     (F, N) f32 — dual incidence, J[i, v] = beta contribution of
             factor i to variable v (zero where factor i does not touch v).
      a:     (1, N) f32 — per-variable unary field (alphas + unary log-odds).
      u:     (C, N) f32 — iid U[0,1) variates.

    Returns:
      (C, N) f32 in {0., 1.}.

    F and N must be divisible by bk and bn respectively (model.py pads).
    """
    c, f = theta.shape
    f2, n = j.shape
    assert f == f2, (theta.shape, j.shape)
    assert a.shape == (1, n), a.shape
    assert u.shape == (c, n), u.shape
    bn = min(bn, n)
    bk = min(bk, f)
    assert n % bn == 0 and f % bk == 0, (n, bn, f, bk)
    nn, nk = n // bn, f // bk

    kernel = functools.partial(_field_sample_kernel, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(nn, nk),
        in_specs=[
            pl.BlockSpec((c, bk), lambda n_, k_: (0, k_)),   # theta
            pl.BlockSpec((bk, bn), lambda n_, k_: (k_, n_)),  # J
            pl.BlockSpec((1, bn), lambda n_, k_: (0, n_)),   # a
            pl.BlockSpec((c, bn), lambda n_, k_: (0, n_)),   # u
        ],
        out_specs=pl.BlockSpec((c, bn), lambda n_, k_: (0, n_)),
        out_shape=jax.ShapeDtypeStruct((c, n), jnp.float32),
        interpret=interpret,
    )(theta, j, a, u)
