"""Layer-2 JAX model: the multi-sweep primal-dual Gibbs chain.

One artifact = `pd_chain` specialized to a static (chains, N, F, sweeps)
configuration (see aot.py). The scan body is one full blocked-Gibbs sweep:

    x     ~ p(x | theta)    -- the Pallas kernel (all variables parallel)
    theta ~ p(theta | x)    -- vectorized gathers  (all factors parallel)

Outputs are the final chain state plus the sufficient statistics the Rust
coordinator accumulates across chunked calls (per-variable sample sums and
a per-sweep magnetization trace); no (S, C, N) trace is ever materialized.

Python/JAX runs only at build time: `make artifacts` lowers this module to
HLO text and the Rust runtime replays it via PJRT.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.kernels import pd_sweep
from compile.kernels import ref


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pad_dims(n: int, f: int, bn: int, bk: int) -> tuple[int, int]:
    """Padded (N, F) so the kernel tiles divide evenly."""
    return _round_up(n, min(bn, _round_up(n, 8))), _round_up(f, min(bk, _round_up(f, 8)))


def pd_chain(
    x,
    theta,
    j,
    a,
    q,
    b1,
    b2,
    v1,
    v2,
    key_data,
    *,
    n: int,
    sweeps: int,
    bn: int = pd_sweep.DEFAULT_BN,
    bk: int = pd_sweep.DEFAULT_BK,
    use_pallas: bool = True,
):
    """Run `sweeps` full primal-dual sweeps over C chains.

    Args:
      x:        (C, Np) f32 in {0,1} — primal state (padded cols are inert).
      theta:    (C, Fp) f32 in {0,1} — dual state.
      j:        (Fp, Np) f32 — dual incidence matrix.
      a:        (1, Np) f32 — unary fields (pads = -40).
      q,b1,b2:  (Fp,) f32 — per-factor dual params (pad q = -40).
      v1,v2:    (Fp,) i32 — factor endpoints (pads point at column 0).
      key_data: (2,) u32 — raw threefry key supplied by the Rust caller.
      n:        true (unpadded) variable count, static.
      sweeps:   sweeps per call, static.

    Returns:
      x', theta', sum_x (C, Np) — sum of x over the `sweeps` samples,
      mag (sweeps, C) — per-sweep mean of x over the first n columns.
    """
    c, n_pad = x.shape
    f_pad = theta.shape[1]
    key = jax.random.wrap_key_data(key_data, impl="threefry2x32")

    x_update = (
        functools.partial(pd_sweep.field_sample, bn=bn, bk=bk)
        if use_pallas
        else ref.field_sample_ref
    )

    def body(carry, k):
        x, theta, sum_x = carry
        kx, kt = jax.random.split(k)
        ux = jax.random.uniform(kx, (c, n_pad), dtype=jnp.float32)
        ut = jax.random.uniform(kt, (c, f_pad), dtype=jnp.float32)
        x = x_update(theta, j, a, ux)
        theta = ref.theta_update_ref(x, q, b1, b2, v1, v2, ut)
        mag = jnp.mean(x[:, :n], axis=1)
        return (x, theta, sum_x + x), mag

    keys = jax.random.split(key, sweeps)
    (x, theta, sum_x), mag = jax.lax.scan(
        body, (x, theta, jnp.zeros_like(x)), keys
    )
    return x, theta, sum_x, mag


def make_chain_fn(
    *,
    n: int,
    f: int,
    chains: int,
    sweeps: int,
    bn: int = pd_sweep.DEFAULT_BN,
    bk: int = pd_sweep.DEFAULT_BK,
    use_pallas: bool = True,
):
    """Bind the static configuration; returns (fn, example_arg_specs)."""
    n_pad, f_pad = pad_dims(n, f, bn, bk)

    def fn(x, theta, j, a, q, b1, b2, v1, v2, key_data):
        return pd_chain(
            x, theta, j, a, q, b1, b2, v1, v2, key_data,
            n=n, sweeps=sweeps, bn=min(bn, n_pad), bk=min(bk, f_pad),
            use_pallas=use_pallas,
        )

    spec = jax.ShapeDtypeStruct
    specs = (
        spec((chains, n_pad), jnp.float32),   # x
        spec((chains, f_pad), jnp.float32),   # theta
        spec((f_pad, n_pad), jnp.float32),    # J
        spec((1, n_pad), jnp.float32),        # a
        spec((f_pad,), jnp.float32),          # q
        spec((f_pad,), jnp.float32),          # b1
        spec((f_pad,), jnp.float32),          # b2
        spec((f_pad,), jnp.int32),            # v1
        spec((f_pad,), jnp.int32),            # v2
        spec((2,), jnp.uint32),               # key
    )
    return fn, specs
