"""Python mirror of the paper's 2x2 dualization (Section 4.1).

The Rust crate (rust/src/duality/) is the production implementation; this
module exists so the build-time tests can (a) validate the math against
brute-force enumeration and (b) build dense operands (J, a, q, beta) for
the L2 model without round-tripping through Rust.

Given a strictly positive 2x2 table P (proportional to p(x1, x2)):

  Lemma 3: D = diag(1/p12, 1/p21); D P is symmetric.
  Lemma 4: if det(D P) < 0, pre-multiply by the swap matrix S; S D P has
           det >= 0 (and stays symmetric for the rescaled table -- see
           `factorize_positive` for the exact order of operations used).
  Lemma 2: a symmetric PSD positive table M factors as M = B B^T with
           B = [[sqrt(m11) cos phi, sqrt(m11) sin phi],
                [sqrt(m22) sin phi, sqrt(m22) cos phi]],
           phi = pi/4 - arccos(m12 / sqrt(m11 m22)) / 2.
  Theorem 2: from P = B C^T read off
           alpha1 = log B21/B11          alpha2 = log C21/C11
           q      = log (B12 C12)/(B11 C11)
           beta1  = log (B22 B11)/(B12 B21)
           beta2  = log (C22 C11)/(C12 C21)
  so that p(x1,x2) ∝ sum_theta exp(alpha1 x1 + alpha2 x2 + q theta
                                   + theta (beta1 x1 + beta2 x2)).
"""

from __future__ import annotations

import dataclasses

import numpy as np

SWAP = np.array([[0.0, 1.0], [1.0, 0.0]])


@dataclasses.dataclass(frozen=True)
class DualFactor:
    """Theorem-2 dual parameters of one pairwise factor."""

    alpha1: float
    alpha2: float
    q: float
    beta1: float
    beta2: float

    def table(self) -> np.ndarray:
        """Reconstruct the (unnormalized) 2x2 table by summing out theta."""
        p = np.zeros((2, 2))
        for x1 in (0, 1):
            for x2 in (0, 1):
                for th in (0, 1):
                    p[x1, x2] += np.exp(
                        self.alpha1 * x1
                        + self.alpha2 * x2
                        + self.q * th
                        + th * (self.beta1 * x1 + self.beta2 * x2)
                    )
        return p


def _symmetric_sqrt_factor(m: np.ndarray) -> np.ndarray:
    """Lemma 2: B with B B^T = m, for symmetric m with det >= 0, all entries > 0."""
    m11, m22, m12 = m[0, 0], m[1, 1], m[0, 1]
    ratio = np.clip(m12 / np.sqrt(m11 * m22), -1.0, 1.0)
    # Remark 1: stable evaluation of cos/sin of phi = pi/4 - arccos(ratio)/2.
    cos_phi = 0.5 * (np.sqrt(1.0 + ratio) + np.sqrt(1.0 - ratio))
    sin_phi = 0.5 * (np.sqrt(1.0 + ratio) - np.sqrt(1.0 - ratio))
    return np.array(
        [
            [np.sqrt(m11) * cos_phi, np.sqrt(m11) * sin_phi],
            [np.sqrt(m22) * sin_phi, np.sqrt(m22) * cos_phi],
        ]
    )


def factorize_positive(p: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Factor a strictly positive 2x2 table as P = B C^T, B, C > 0.

    Follows Lemmas 2-4: rescale rows to make the table symmetric, swap rows
    first if the determinant is negative, take the trigonometric square
    root, then push the rescaling/permutation into B.
    """
    p = np.asarray(p, dtype=np.float64)
    if p.shape != (2, 2) or np.any(p <= 0):
        raise ValueError(f"need strictly positive 2x2 table, got {p!r}")

    swapped = np.linalg.det(p) < 0
    ps = SWAP @ p if swapped else p

    # Lemma 3: D = diag(1/ps12, 1/ps21) makes D @ ps symmetric...
    d = np.array([1.0 / ps[0, 1], 1.0 / ps[1, 0]])
    m = ps * d[:, None]
    # ...up to float noise; enforce exactly for the sqrt step.
    m[1, 0] = m[0, 1]
    if np.linalg.det(m) < 0:
        # det(D P) has the sign of det(P) >= 0 post-swap; tiny negative
        # values can only arise from roundoff on near-singular tables.
        m[0, 1] = m[1, 0] = np.sqrt(m[0, 0] * m[1, 1]) * (1.0 - 1e-12)

    bsym = _symmetric_sqrt_factor(m)  # m = bsym bsym^T
    b = bsym / d[:, None]  # ps = b bsym^T
    if swapped:
        b = SWAP @ b  # p = (S b) bsym^T
    return b, bsym


def dualize_table(p: np.ndarray) -> DualFactor:
    """Theorem 2: dual parameters of a strictly positive 2x2 table."""
    b, c = factorize_positive(p)
    return DualFactor(
        alpha1=float(np.log(b[1, 0] / b[0, 0])),
        alpha2=float(np.log(c[1, 0] / c[0, 0])),
        q=float(np.log(b[0, 1] * c[0, 1] / (b[0, 0] * c[0, 0]))),
        beta1=float(np.log(b[1, 1] * b[0, 0] / (b[0, 1] * b[1, 0]))),
        beta2=float(np.log(c[1, 1] * c[0, 0] / (c[0, 1] * c[1, 0]))),
    )


def ising_table(beta: float) -> np.ndarray:
    """exp(beta) on agreement, exp(-beta) on disagreement."""
    return np.array(
        [[np.exp(beta), np.exp(-beta)], [np.exp(-beta), np.exp(beta)]]
    )


def dense_operands(
    n: int,
    edges: list[tuple[int, int]],
    tables: list[np.ndarray],
    unary_logodds: np.ndarray | None = None,
    n_pad: int | None = None,
    f_pad: int | None = None,
):
    """Build the dense L2/L1 operands (J, a, q, b1, b2, v1, v2) from factors.

    Mirrors rust/src/duality/model.rs::DualModel::dense_operands. Padded
    columns get a = -40 (so sigmoid ~ 0 and padded variables stay 0), padded
    factors get q = -40 / beta = 0 / endpoints 0 (inert).
    """
    f = len(edges)
    n_pad = n_pad or n
    f_pad = f_pad or f
    assert n_pad >= n and f_pad >= f

    j = np.zeros((f_pad, n_pad), dtype=np.float32)
    a = np.full((1, n_pad), -40.0, dtype=np.float32)
    a[0, :n] = 0.0 if unary_logodds is None else unary_logodds
    q = np.full((f_pad,), -40.0, dtype=np.float32)
    b1 = np.zeros((f_pad,), dtype=np.float32)
    b2 = np.zeros((f_pad,), dtype=np.float32)
    v1 = np.zeros((f_pad,), dtype=np.int32)
    v2 = np.zeros((f_pad,), dtype=np.int32)

    for i, ((e1, e2), table) in enumerate(zip(edges, tables)):
        dual = dualize_table(table)
        a[0, e1] += dual.alpha1
        a[0, e2] += dual.alpha2
        q[i] = dual.q
        b1[i], b2[i] = dual.beta1, dual.beta2
        v1[i], v2[i] = e1, e2
        j[i, e1] += dual.beta1
        j[i, e2] += dual.beta2
    return j, a, q, b1, b2, v1, v2
