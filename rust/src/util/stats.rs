//! Shared descriptive statistics: Welford online moments, quantiles.
//!
//! Used by [`crate::diagnostics`] (per-chain, per-variable moment tracking
//! for PSRF) and by [`crate::bench`] (latency summaries).

/// Online mean/variance accumulator (Welford). Numerically stable for the
/// long chains the PSRF monitor feeds it (millions of updates).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    /// Fold in one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    /// Number of observations so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean (0 when empty).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Unbiased sample variance (0 when fewer than 2 observations).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Unbiased sample standard deviation.
    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Merge another accumulator (parallel reduction; Chan et al.).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        self.mean += delta * other.n as f64 / n as f64;
        self.m2 += other.m2
            + delta * delta * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
    }
}

/// Summary of a sample: mean/std/min/max/percentiles.
#[derive(Clone, Debug)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Unbiased sample standard deviation.
    pub std_dev: f64,
    /// Smallest observation.
    pub min: f64,
    /// Median (nearest-rank).
    pub p50: f64,
    /// 95th percentile (nearest-rank).
    pub p95: f64,
    /// Largest observation.
    pub max: f64,
}

impl Summary {
    /// Summarize (sorts a copy).
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "summary of empty sample");
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut w = Welford::new();
        for &x in xs {
            w.push(x);
        }
        Summary {
            n: xs.len(),
            mean: w.mean(),
            std_dev: w.std_dev(),
            min: sorted[0],
            p50: quantile(&sorted, 0.5),
            p95: quantile(&sorted, 0.95),
            max: *sorted.last().unwrap(),
        }
    }
}

/// Mean of a slice, with the empty slice mapped to 0 instead of NaN.
///
/// Marginal summaries for zero-variable models (a freshly `create`d
/// tenant before any `apply`) hit the empty case on every serving path —
/// CLI `sample`, CLI `serve`, and the wire protocol's `subscribe` events
/// — so they all share this one guard rather than re-deriving it.
pub fn mean_or_zero(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Linear-interpolated quantile of a pre-sorted slice.
pub fn quantile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_two_pass() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64) * 0.37 - 100.0).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-9);
        assert!((w.variance() - var).abs() < 1e-6);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let xs: Vec<f64> = (0..500).map(|i| ((i * 2654435761u64) % 997) as f64).collect();
        let mut whole = Welford::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..123] {
            a.push(x);
        }
        for &x in &xs[123..] {
            b.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.variance() - whole.variance()).abs() < 1e-6);
    }

    #[test]
    fn merge_with_empty() {
        let mut a = Welford::new();
        a.push(1.0);
        a.push(3.0);
        let before = a.clone();
        a.merge(&Welford::new());
        assert_eq!(a.mean(), before.mean());
        let mut e = Welford::new();
        e.merge(&a);
        assert_eq!(e.mean(), a.mean());
    }

    #[test]
    fn quantiles() {
        let sorted: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert_eq!(quantile(&sorted, 0.0), 0.0);
        assert_eq!(quantile(&sorted, 0.5), 50.0);
        assert_eq!(quantile(&sorted, 1.0), 100.0);
        assert!((quantile(&sorted, 0.95) - 95.0).abs() < 1e-9);
    }

    #[test]
    fn mean_or_zero_guards_the_empty_slice() {
        assert_eq!(mean_or_zero(&[]), 0.0, "empty models must not report NaN");
        assert!((mean_or_zero(&[0.25, 0.75]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }
}
