//! Fixed-size worker pool with scoped parallel-for (tokio/rayon-free).
//!
//! The native primal–dual sampler resamples all variables (then all
//! factors) in parallel each sweep; this pool provides two scoped
//! primitives for it: `scope_chunks` (split an index range into uniform
//! contiguous chunks) and `scope_ranges` (run caller-chosen contiguous
//! ranges — the lane engine feeds it degree-aware boundaries from
//! [`balanced_ranges`] so dense/skewed graphs load-balance). Both run a
//! closure per chunk on the workers and join; closures borrow from the
//! caller's stack via `std::thread::scope`-style lifetimes.
//!
//! One pool may be *lent* to several owner threads at once (the
//! multi-tenant coordinator shares a single pool across all of its
//! shards instead of spawning per-shard pools): scopes submitted from
//! different threads interleave in the shared job queue, each scope
//! blocks only on its own completion counter, and no worker ever waits
//! on another scope — so concurrent scoped calls are safe and
//! deadlock-free by construction ([`ThreadPool::shared`] +
//! `scopes_are_safe_concurrently_across_owner_threads` below).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

struct Shared {
    queue: Mutex<std::collections::VecDeque<Message>>,
    available: Condvar,
}

/// Hard cap on pool size, enforced by [`ThreadPool::new`].
///
/// The primal–dual sampler derives one RNG stream per chunk per half-step
/// from the domain `sweep·8192 + {0, 4096} + chunk` (see
/// `samplers/primal_dual.rs`); 4096 is the largest chunk count that keeps
/// the x- and θ-domains disjoint. Clamping here means the split scheme
/// cannot silently collide however large a pool is requested — and
/// `scope_chunks` never produces more chunks than workers.
pub const MAX_POOL_SIZE: usize = 4096;

/// A fixed pool of worker threads executing submitted closures.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn `size` workers, clamped to `1..=`[`MAX_POOL_SIZE`].
    pub fn new(size: usize) -> Self {
        let size = Self::clamped_size(size);
        let shared = Arc::new(Shared {
            queue: Mutex::new(std::collections::VecDeque::new()),
            available: Condvar::new(),
        });
        let workers = (0..size)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || loop {
                    let msg = {
                        let mut q = shared.queue.lock().unwrap();
                        loop {
                            if let Some(m) = q.pop_front() {
                                break m;
                            }
                            q = shared.available.wait(q).unwrap();
                        }
                    };
                    match msg {
                        Message::Run(job) => job(),
                        Message::Shutdown => return,
                    }
                })
            })
            .collect();
        Self {
            shared,
            workers,
            size,
        }
    }

    /// The worker count `new(size)` will actually spawn: at least 1, at
    /// most [`MAX_POOL_SIZE`] (the RNG stream-domain bound).
    pub fn clamped_size(size: usize) -> usize {
        size.clamp(1, MAX_POOL_SIZE)
    }

    /// A pool behind an `Arc`, ready to lend to several owner threads
    /// (e.g. every shard of a coordinator). Scoped calls from different
    /// owners interleave safely — see the module docs.
    pub fn shared(size: usize) -> Arc<Self> {
        Arc::new(Self::new(size))
    }

    /// Pool sized to the machine (logical cores, capped at 16).
    pub fn default_size() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get().min(16))
            .unwrap_or(4)
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    fn submit(&self, job: Job) {
        self.shared
            .queue
            .lock()
            .unwrap()
            .push_back(Message::Run(job));
        self.shared.available.notify_one();
    }

    /// Run `f(chunk_index, start, end)` over `[0, len)` split into
    /// `self.size()` uniform contiguous chunks, blocking until all
    /// complete.
    ///
    /// `f` may borrow non-`'static` data: internally the borrow is erased
    /// and re-guarded by joining before return (the closure cannot outlive
    /// this call).
    pub fn scope_chunks<F>(&self, len: usize, f: F)
    where
        F: Fn(usize, usize, usize) + Sync,
    {
        if len == 0 {
            return;
        }
        let chunks = self.size.min(len);
        let chunk_len = len.div_ceil(chunks);
        let mut bounds = Vec::with_capacity(chunks + 1);
        for c in 0..=chunks {
            bounds.push((c * chunk_len).min(len));
        }
        self.scope_ranges(&bounds, f);
    }

    /// Run `f(chunk_index, bounds[c], bounds[c + 1])` for each consecutive
    /// pair of `bounds` (which must be non-decreasing), blocking until all
    /// complete. Empty ranges still invoke `f` (with `start == end`) so
    /// chunk-indexed callers see a stable chunk count.
    ///
    /// This is the degree-aware counterpart of [`ThreadPool::scope_chunks`]:
    /// pair it with [`balanced_ranges`] to split work by per-site cost
    /// instead of site count.
    pub fn scope_ranges<F>(&self, bounds: &[usize], f: F)
    where
        F: Fn(usize, usize, usize) + Sync,
    {
        if bounds.len() < 2 {
            return;
        }
        debug_assert!(bounds.windows(2).all(|w| w[0] <= w[1]), "bounds must be non-decreasing");
        let chunks = bounds.len() - 1;
        let pending = Arc::new((Mutex::new(chunks), Condvar::new()));

        // SAFETY: we block on `pending` until every submitted job has run,
        // so the erased borrow of `f` never outlives this stack frame.
        let f_ptr: &(dyn Fn(usize, usize, usize) + Sync) = &f;
        let f_static: &'static (dyn Fn(usize, usize, usize) + Sync) =
            unsafe { std::mem::transmute(f_ptr) };

        for c in 0..chunks {
            let (start, end) = (bounds[c], bounds[c + 1]);
            let pending = Arc::clone(&pending);
            self.submit(Box::new(move || {
                f_static(c, start, end);
                let (lock, cv) = &*pending;
                let mut left = lock.lock().unwrap();
                *left -= 1;
                if *left == 0 {
                    cv.notify_all();
                }
            }));
        }
        let (lock, cv) = &*pending;
        let mut left = lock.lock().unwrap();
        while *left > 0 {
            left = cv.wait(left).unwrap();
        }
    }

    /// Map `f` over `0..n` in parallel, collecting results in order.
    pub fn par_map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + Default + Clone,
        F: Fn(usize) -> T + Sync,
    {
        let mut out = vec![T::default(); n];
        {
            let out_ptr = SendPtr(out.as_mut_ptr());
            self.scope_chunks(n, |_, start, end| {
                let out_ptr = &out_ptr;
                for i in start..end {
                    // SAFETY: chunks are disjoint index ranges.
                    unsafe { *out_ptr.0.add(i) = f(i) };
                }
            });
        }
        out
    }
}

struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

/// Split `[0, n)` into at most `chunks` contiguous ranges of roughly equal
/// *weight*, where `prefix` is the weight prefix sum (`prefix[0] = 0`,
/// `prefix[i]` = total weight of sites `0..i`, so `n = prefix.len() - 1`).
///
/// Returns chunk bounds suitable for [`ThreadPool::scope_ranges`]:
/// non-decreasing, starting at 0, ending at `n`. Each bound is placed
/// where the running weight reaches an equal share of the weight *still
/// remaining* (not of the original total), so a single very heavy site
/// (dense/skewed incidence) takes one chunk while the rest of the sites
/// still spread evenly over the remaining chunks.
pub fn balanced_ranges(prefix: &[u64], chunks: usize) -> Vec<usize> {
    balanced_ranges_aligned(prefix, chunks, 1)
}

/// [`balanced_ranges`] with every *interior* chunk bound rounded to the
/// nearest multiple of `align` (the final bound always stays `n`).
///
/// The lane engine passes the number of sites whose packed state rows
/// span a whole number of 64-byte cache lines: aligned bounds put every
/// chunk seam on a line multiple relative to the state base, minimizing
/// false sharing between pool workers (eliminated outright when the
/// allocation is line-aligned), at the cost of at most `align / 2` sites
/// of imbalance per bound (nearest-multiple rounding; down-only rounding
/// would cascade the whole deficit into the last chunk on small inputs).
/// `align = 1` is plain weighted chunking. Rounding and end-clamping can
/// still make an interior chunk empty (bounds are kept non-decreasing,
/// never reordered) — [`ThreadPool::scope_ranges`] handles empty chunks
/// by design. When nearest rounding would pull a *progressing* bound back
/// to its predecessor, the bound rounds up instead: with a hub site
/// holding most of the mass at the front of the range, a stalled bound
/// keeps every later equal-share target below the hub weight and all
/// interior bounds collapse to 0 (one worker owning the whole model).
/// Rounding up can land an interior bound on `n` itself (off-grid); that
/// seam coincides with the final bound, so no false sharing results.
pub fn balanced_ranges_aligned(prefix: &[u64], chunks: usize, align: usize) -> Vec<usize> {
    let align = align.max(1);
    let n = prefix.len().saturating_sub(1);
    let chunks = chunks.clamp(1, MAX_POOL_SIZE).min(n.max(1));
    let total = prefix.last().copied().unwrap_or(0);
    let mut bounds = Vec::with_capacity(chunks + 1);
    bounds.push(0usize);
    let mut prev = 0usize;
    for c in 0..chunks.saturating_sub(1) {
        let remaining = total - prefix[prev];
        let target = prefix[prev] + remaining / (chunks - c) as u64;
        // first index whose cumulative weight reaches the target, rounded
        // to the nearest grid point (monotonicity via the clamp below)
        let idx = prefix.partition_point(|&p| p < target).clamp(prev, n);
        let mut aligned = ((idx + align / 2) / align * align).clamp(prev, n);
        if aligned == prev && idx > prev {
            // nearest rounding stalled a bound that had found progress —
            // round up so a heavy hub can't absorb every later chunk
            aligned = (idx.div_ceil(align) * align).clamp(prev, n);
        }
        bounds.push(aligned);
        prev = aligned;
    }
    bounds.push(n);
    bounds
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            for _ in 0..self.workers.len() {
                q.push_back(Message::Shutdown);
            }
        }
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Global counter handy for tests asserting work distribution.
pub static TASKS_EXECUTED: AtomicUsize = AtomicUsize::new(0);

#[allow(dead_code)]
pub(crate) fn bump_task_counter() {
    TASKS_EXECUTED.fetch_add(1, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_size_is_capped_for_rng_stream_safety() {
        // regression for the primal–dual stream-domain assumption: a pool
        // larger than MAX_POOL_SIZE would alias x- and θ-chunk streams.
        // (Tested via the size computation — spawning 4096 threads in a
        // unit test would be wasteful; `new` feeds `clamped_size` directly.)
        assert_eq!(ThreadPool::clamped_size(0), 1);
        assert_eq!(ThreadPool::clamped_size(16), 16);
        assert_eq!(ThreadPool::clamped_size(MAX_POOL_SIZE), MAX_POOL_SIZE);
        assert_eq!(ThreadPool::clamped_size(MAX_POOL_SIZE + 1), MAX_POOL_SIZE);
        assert_eq!(ThreadPool::clamped_size(usize::MAX), MAX_POOL_SIZE);
    }

    #[test]
    fn chunks_cover_range_exactly_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        pool.scope_chunks(1000, |_, s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn par_map_ordering() {
        let pool = ThreadPool::new(3);
        let out = pool.par_map(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_range_is_noop() {
        let pool = ThreadPool::new(2);
        pool.scope_chunks(0, |_, _, _| panic!("must not run"));
    }

    #[test]
    fn single_item() {
        let pool = ThreadPool::new(8);
        let out = pool.par_map(1, |i| i + 41);
        assert_eq!(out, vec![41]);
    }

    #[test]
    fn reuse_across_many_scopes() {
        let pool = ThreadPool::new(4);
        let total = AtomicU64::new(0);
        for _ in 0..50 {
            pool.scope_chunks(64, |_, s, e| {
                total.fetch_add((e - s) as u64, Ordering::SeqCst);
            });
        }
        assert_eq!(total.load(Ordering::SeqCst), 50 * 64);
    }

    #[test]
    fn scope_ranges_covers_custom_bounds_once() {
        let pool = ThreadPool::new(3);
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        // skewed bounds, including an empty chunk
        pool.scope_ranges(&[0, 90, 90, 95, 100], |_, s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn balanced_ranges_equalizes_weight() {
        // one very heavy site at the front: uniform chunking would put it
        // with a quarter of everything else; weighted chunking isolates it
        let mut weights = vec![1u64; 100];
        weights[0] = 1000;
        let mut prefix = vec![0u64];
        for &w in &weights {
            prefix.push(prefix.last().unwrap() + w);
        }
        let bounds = balanced_ranges(&prefix, 4);
        assert!(bounds.windows(2).all(|w| w[0] <= w[1]));
        // the heavy site is isolated AND the light tail still spreads
        // evenly over the remaining chunks
        assert_eq!(bounds, vec![0, 1, 34, 67, 100], "got {bounds:?}");
    }

    #[test]
    fn balanced_ranges_heavy_tail_property() {
        // satellite property test: one hub site holding > 90 % of the
        // incidence mass (a power-law tenant's top hub). The hub's chunk is
        // unsplittable — it owns whatever the hub weighs — but every OTHER
        // chunk must stay within 2x the mean of the weight that is
        // actually splittable (total minus the hub), for a spread of
        // sizes, chunk counts, and alignments.
        for &(n, hub_weight, chunks, align) in &[
            (100usize, 10_000u64, 4usize, 1usize),
            (100, 10_000, 8, 1),
            (1000, 100_000, 8, 8),
            (1000, 50_000, 16, 8),
            (513, 30_000, 7, 64),
            (64, 5_000, 4, 8),
        ] {
            // hub at index 0, unit-weight tail
            let mut prefix = Vec::with_capacity(n + 1);
            prefix.push(0u64);
            for i in 0..n {
                let w = if i == 0 { hub_weight } else { 1 };
                prefix.push(prefix.last().unwrap() + w);
            }
            let total = *prefix.last().unwrap();
            assert!(hub_weight as f64 > 0.9 * total as f64, "not hub-heavy");
            let bounds = balanced_ranges_aligned(&prefix, chunks, align);
            // well-formed
            assert_eq!(bounds[0], 0);
            assert_eq!(*bounds.last().unwrap(), n);
            assert!(bounds.windows(2).all(|w| w[0] <= w[1]), "{bounds:?}");
            // the hub is isolated: the chunk containing site 0 carries no
            // more than the hub plus one alignment step of tail sites
            let hub_end = bounds[1..].iter().copied().find(|&b| b > 0).unwrap_or(n);
            let hub_chunk_weight = prefix[hub_end] - prefix[0];
            assert!(
                hub_chunk_weight <= hub_weight + align as u64,
                "hub chunk dragged {hub_chunk_weight} > hub {hub_weight} + align \
                 (n={n} chunks={chunks} align={align}: {bounds:?})"
            );
            // every splittable (non-hub) chunk stays <= 2x the mean of the
            // splittable mass; alignment may add up to align/2 sites of
            // unit weight per seam
            let splittable = (total - hub_chunk_weight) as f64;
            let tail_chunks = (bounds.len() - 1).saturating_sub(1).max(1);
            let limit = 2.0 * splittable / tail_chunks as f64 + (align as f64) / 2.0;
            for w in bounds.windows(2) {
                let (s, e) = (w[0], w[1]);
                if s == 0 && e == hub_end {
                    continue; // the hub chunk, exempt where unsplittable
                }
                let weight = (prefix[e] - prefix[s]) as f64;
                assert!(
                    weight <= limit,
                    "chunk {s}..{e} carries {weight} > limit {limit:.1} \
                     (n={n} chunks={chunks} align={align}: {bounds:?})"
                );
            }
        }
    }

    #[test]
    fn balanced_ranges_uniform_weights_match_even_split() {
        let prefix: Vec<u64> = (0..=100).collect();
        let bounds = balanced_ranges(&prefix, 4);
        assert_eq!(bounds, vec![0, 25, 50, 75, 100]);
    }

    #[test]
    fn balanced_ranges_degenerate_inputs() {
        assert_eq!(balanced_ranges(&[0], 4), vec![0, 0]);
        assert_eq!(balanced_ranges(&[0, 0, 0], 2), vec![0, 0, 2]);
        assert_eq!(balanced_ranges(&[0, 5], 8), vec![0, 1]);
    }

    #[test]
    fn aligned_ranges_round_interior_bounds_only() {
        let prefix: Vec<u64> = (0..=100).collect();
        // uniform weights, align 8: 25/50/75 round down to the grid
        assert_eq!(
            balanced_ranges_aligned(&prefix, 4, 8),
            vec![0, 24, 48, 72, 100]
        );
        // align 1 is exactly the unaligned split
        assert_eq!(
            balanced_ranges_aligned(&prefix, 4, 1),
            balanced_ranges(&prefix, 4)
        );
        // the final bound is never rounded; an interior bound may round up
        // onto n itself (a seam shared with the final bound is harmless)
        let b = balanced_ranges_aligned(&prefix, 3, 64);
        assert_eq!(*b.last().unwrap(), 100);
        assert!(b.windows(2).all(|w| w[0] <= w[1]), "got {b:?}");
        assert!(
            b[1..b.len() - 1].iter().all(|&x| x % 64 == 0 || x == 100),
            "interior bounds off-grid: {b:?}"
        );
    }

    #[test]
    fn aligned_ranges_do_not_cascade_on_small_inputs() {
        // regression: down-only rounding turned n=20 / 4 chunks / align 8
        // into [0, 0, 8, 8, 20] (two empty chunks, one worker owning 12
        // of 20 sites); nearest rounding spreads the grid points out, and
        // the stall-avoidance round-up puts the spare seam at the end
        let prefix: Vec<u64> = (0..=20).collect();
        assert_eq!(
            balanced_ranges_aligned(&prefix, 4, 8),
            vec![0, 8, 16, 20, 20]
        );
        // a model smaller than one grid step degenerates to a single
        // chunk — acceptable (7 sites don't amortize 4 workers), but the
        // bounds must stay well-formed
        let prefix: Vec<u64> = (0..=7).collect();
        let b = balanced_ranges_aligned(&prefix, 4, 8);
        assert_eq!(*b.last().unwrap(), 7);
        assert!(b.windows(2).all(|w| w[0] <= w[1]), "got {b:?}");
    }

    #[test]
    fn aligned_ranges_cover_exactly_once_under_scope() {
        let pool = ThreadPool::new(4);
        let prefix: Vec<u64> = (0..=37).collect();
        let bounds = balanced_ranges_aligned(&prefix, 4, 8);
        let hits: Vec<AtomicU64> = (0..37).map(|_| AtomicU64::new(0)).collect();
        pool.scope_ranges(&bounds, |_, s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn scopes_are_safe_concurrently_across_owner_threads() {
        // the multi-tenant coordinator lends ONE pool to all shards: many
        // owner threads issue scoped calls concurrently. Each scope must
        // see exactly its own chunks, complete, and never deadlock.
        let pool = ThreadPool::shared(3);
        let owners: Vec<_> = (0..4)
            .map(|o| {
                let pool = Arc::clone(&pool);
                std::thread::spawn(move || {
                    let mut total = 0u64;
                    for round in 0..30 {
                        let len = 64 + o * 17 + round;
                        let sum = AtomicU64::new(0);
                        pool.scope_chunks(len, |_, s, e| {
                            sum.fetch_add((e - s) as u64, Ordering::SeqCst);
                        });
                        assert_eq!(sum.load(Ordering::SeqCst), len as u64);
                        total += len as u64;
                    }
                    total
                })
            })
            .collect();
        for h in owners {
            assert!(h.join().unwrap() > 0);
        }
    }

    #[test]
    fn borrows_stack_data() {
        let pool = ThreadPool::new(4);
        let data: Vec<u64> = (0..256).collect();
        let sum = AtomicU64::new(0);
        pool.scope_chunks(data.len(), |_, s, e| {
            let local: u64 = data[s..e].iter().sum();
            sum.fetch_add(local, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), (0..256).sum::<u64>());
    }
}
