//! Union-find with path halving + union by size.
//!
//! Substrate for Swendsen–Wang cluster extraction and spanning-forest
//! construction in the blocking planner.

/// Disjoint-set forest over `0..n`.
#[derive(Clone, Debug)]
pub struct UnionFind {
    parent: Vec<u32>,
    size: Vec<u32>,
    components: usize,
}

impl UnionFind {
    /// `n` singleton components.
    pub fn new(n: usize) -> Self {
        Self {
            parent: (0..n as u32).collect(),
            size: vec![1; n],
            components: n,
        }
    }

    /// Number of elements (not components).
    pub fn len(&self) -> usize {
        self.parent.len()
    }

    /// Whether the structure holds no elements.
    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }

    /// Number of disjoint components.
    pub fn components(&self) -> usize {
        self.components
    }

    /// Representative of `x`'s component (path halving).
    pub fn find(&mut self, mut x: usize) -> usize {
        loop {
            let p = self.parent[x] as usize;
            if p == x {
                return x;
            }
            let gp = self.parent[p];
            self.parent[x] = gp;
            x = gp as usize;
        }
    }

    /// Merge the components of `a` and `b`; returns true if they were
    /// previously disjoint.
    pub fn union(&mut self, a: usize, b: usize) -> bool {
        let (mut ra, mut rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        if self.size[ra] < self.size[rb] {
            std::mem::swap(&mut ra, &mut rb);
        }
        self.parent[rb] = ra as u32;
        self.size[ra] += self.size[rb];
        self.components -= 1;
        true
    }

    /// Whether `a` and `b` share a component.
    pub fn connected(&mut self, a: usize, b: usize) -> bool {
        self.find(a) == self.find(b)
    }

    /// Size of the component containing `x`.
    pub fn component_size(&mut self, x: usize) -> usize {
        let r = self.find(x);
        self.size[r] as usize
    }

    /// Group element indices by component root (ordering deterministic).
    pub fn groups(&mut self) -> Vec<Vec<usize>> {
        let n = self.len();
        let mut by_root: std::collections::BTreeMap<usize, Vec<usize>> =
            std::collections::BTreeMap::new();
        for x in 0..n {
            let r = self.find(x);
            by_root.entry(r).or_default().push(x);
        }
        by_root.into_values().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_union_find() {
        let mut uf = UnionFind::new(5);
        assert_eq!(uf.components(), 5);
        assert!(uf.union(0, 1));
        assert!(uf.union(2, 3));
        assert!(!uf.union(1, 0));
        assert_eq!(uf.components(), 3);
        assert!(uf.connected(0, 1));
        assert!(!uf.connected(0, 2));
        uf.union(1, 2);
        assert!(uf.connected(0, 3));
        assert_eq!(uf.component_size(3), 4);
    }

    #[test]
    fn groups_partition() {
        let mut uf = UnionFind::new(6);
        uf.union(0, 2);
        uf.union(2, 4);
        uf.union(1, 5);
        let groups = uf.groups();
        assert_eq!(groups.len(), 3);
        let sizes: Vec<usize> = groups.iter().map(Vec::len).collect();
        let mut sorted = sizes.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![1, 2, 3]);
        let all: usize = sizes.iter().sum();
        assert_eq!(all, 6);
    }

    #[test]
    fn chain_collapse() {
        let mut uf = UnionFind::new(1000);
        for i in 0..999 {
            uf.union(i, i + 1);
        }
        assert_eq!(uf.components(), 1);
        assert_eq!(uf.component_size(0), 1000);
        assert!(uf.connected(0, 999));
    }
}
