//! Spanned, labeled diagnostics for the crate's hand-rolled parsers.
//!
//! Both the wire-protocol parser ([`crate::coordinator::protocol`]) and
//! the CLI list accessors ([`crate::util::cli`]) report malformed input
//! the same way: a byte-offset [`Span`] into the offending source plus an
//! *expected-token label* and what was actually found — never a bare
//! "parse error". This follows the rust-sitter error-reporting idiom
//! (span + label per failure) so a client, a log line, or a terminal can
//! all render the failure precisely, including a caret underline of the
//! offending bytes ([`Diagnostic::underline`]).

use std::fmt;

/// Half-open byte range `start..end` into the source being parsed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Span {
    /// First byte of the offending region.
    pub start: usize,
    /// One past the last byte of the offending region.
    pub end: usize,
}

impl Span {
    /// Span covering `start..end`.
    pub fn new(start: usize, end: usize) -> Self {
        Self { start, end }
    }

    /// Empty span at `at` (used for "expected more input here").
    pub fn point(at: usize) -> Self {
        Self { start: at, end: at }
    }
}

impl fmt::Display for Span {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}..{}", self.start, self.end)
    }
}

/// One spanned, labeled parse failure: where, what was expected, what was
/// found instead.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Diagnostic {
    /// Byte range of the offending input.
    pub span: Span,
    /// Label of the token/production the parser expected.
    pub expected: String,
    /// Description of what was actually found (token text, "end of
    /// line", byte counts, …).
    pub found: String,
}

impl Diagnostic {
    /// Build a diagnostic from a span and the expected/found labels.
    pub fn new(span: Span, expected: impl Into<String>, found: impl Into<String>) -> Self {
        Self {
            span,
            expected: expected.into(),
            found: found.into(),
        }
    }

    /// Render the source with a caret underline of the span, for
    /// terminal-facing reporters:
    ///
    /// ```text
    /// sweep nine 10
    ///       ^^^^ expected tenant id (u64), found "nine"
    /// ```
    ///
    /// Offsets are byte-based; the caret column falls back to the byte
    /// count if the span does not land on a character boundary.
    pub fn underline(&self, src: &str) -> String {
        let col = src
            .get(..self.span.start)
            .map_or(self.span.start, |s| s.chars().count());
        let width = src
            .get(self.span.start..self.span.end)
            .map_or(self.span.end.saturating_sub(self.span.start), |s| {
                s.chars().count()
            })
            .max(1);
        format!(
            "{src}\n{:indent$}{:^<width$} expected {}, found {}",
            "", "", self.expected, self.found,
            indent = col,
            width = width,
        )
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "at {}: expected {}, found {}",
            self.span, self.expected, self.found
        )
    }
}

impl std::error::Error for Diagnostic {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_carries_span_and_labels() {
        let d = Diagnostic::new(Span::new(6, 10), "tenant id (u64)", "\"nine\"");
        let s = d.to_string();
        assert!(s.contains("6..10"), "{s}");
        assert!(s.contains("expected tenant id (u64)"), "{s}");
        assert!(s.contains("found \"nine\""), "{s}");
    }

    #[test]
    fn underline_points_at_the_offending_token() {
        let src = "sweep nine 10";
        let d = Diagnostic::new(Span::new(6, 10), "tenant id (u64)", "\"nine\"");
        let rendered = d.underline(src);
        let lines: Vec<&str> = rendered.lines().collect();
        assert_eq!(lines[0], src);
        assert!(lines[1].starts_with("      ^^^^"), "{rendered}");
        assert!(lines[1].contains("expected tenant id (u64)"));
    }

    #[test]
    fn point_span_still_renders_one_caret() {
        let d = Diagnostic::new(Span::point(5), "a value", "end of line");
        let rendered = d.underline("--abc");
        assert!(rendered.lines().nth(1).unwrap().contains('^'), "{rendered}");
    }

    #[test]
    fn underline_survives_non_boundary_offsets() {
        // multibyte input with a span that does not land on a char
        // boundary must not panic
        let d = Diagnostic::new(Span::new(1, 3), "x", "y");
        let _ = d.underline("é é");
    }
}
