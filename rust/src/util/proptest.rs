//! Mini property-testing harness (proptest is unavailable offline).
//!
//! A property is a closure from a seeded [`Gen`] to `Result<(), String>`;
//! the harness runs it for `cases` random seeds and reports the first
//! failing seed so the case can be replayed deterministically:
//!
//! ```no_run
//! # // no_run: doctest binaries lack the xla rpath in this offline image
//! use pdgibbs::util::proptest::{check, Gen};
//! check("addition commutes", 100, |g: &mut Gen| {
//!     let (a, b) = (g.i64_in(-10..=10), g.i64_in(-10..=10));
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```

use crate::rng::{Pcg64, RngCore};
use std::ops::RangeInclusive;

/// Random-input generator handed to each property case.
pub struct Gen {
    rng: Pcg64,
    /// Seed of this case, for failure reports.
    pub seed: u64,
}

impl Gen {
    /// Generator for one case seed.
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Pcg64::seed(seed),
            seed,
        }
    }

    /// Next raw 64-bit draw.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform integer in the inclusive range.
    pub fn usize_in(&mut self, range: RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*range.start(), *range.end());
        lo + self.rng.next_below((hi - lo + 1) as u64) as usize
    }

    /// Uniform signed integer in the inclusive range.
    pub fn i64_in(&mut self, range: RangeInclusive<i64>) -> i64 {
        let (lo, hi) = (*range.start(), *range.end());
        lo + self.rng.next_below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    /// Fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0..=xs.len() - 1)]
    }

    /// A strictly positive 2×2 table with log-entries in ±`scale`.
    pub fn positive_table(&mut self, scale: f64) -> [[f64; 2]; 2] {
        let mut t = [[0.0; 2]; 2];
        for row in &mut t {
            for v in row.iter_mut() {
                *v = self.f64_in(-scale, scale).exp();
            }
        }
        t
    }

    /// Access the underlying RNG (e.g. to seed a sampler).
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Run `cases` random instances of `prop`; panic with the failing seed.
pub fn check<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    // Derive per-case seeds from the property name so adding properties
    // elsewhere does not shift this one's cases.
    let base = name
        .bytes()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x1000_0000_01b3)
        });
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9e37_79b9_7f4a_7c15));
        let mut gen = Gen::new(seed);
        if let Err(msg) = prop(&mut gen) {
            panic!(
                "property '{name}' failed on case {case} (replay seed {seed}):\n{msg}"
            );
        }
    }
}

/// Replay a single failing seed (used while debugging).
pub fn replay<F>(seed: u64, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let mut gen = Gen::new(seed);
    if let Err(msg) = prop(&mut gen) {
        panic!("replay of seed {seed} failed:\n{msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let count = AtomicU64::new(0);
        check("counting", 50, |_g| {
            count.fetch_add(1, Ordering::SeqCst);
            Ok(())
        });
        assert_eq!(count.load(Ordering::SeqCst), 50);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check("always fails", 10, |g: &mut Gen| {
            Err(format!("value {}", g.u64()))
        });
    }

    #[test]
    fn gen_ranges_respected() {
        check("ranges", 200, |g: &mut Gen| {
            let x = g.usize_in(3..=9);
            if !(3..=9).contains(&x) {
                return Err(format!("usize {x}"));
            }
            let y = g.i64_in(-5..=5);
            if !(-5..=5).contains(&y) {
                return Err(format!("i64 {y}"));
            }
            let z = g.f64_in(1.0, 2.0);
            if !(1.0..2.0).contains(&z) {
                return Err(format!("f64 {z}"));
            }
            Ok(())
        });
    }

    #[test]
    fn positive_tables_are_positive() {
        check("tables", 100, |g: &mut Gen| {
            let t = g.positive_table(4.0);
            if t.iter().flatten().all(|&v| v > 0.0) {
                Ok(())
            } else {
                Err(format!("{t:?}"))
            }
        });
    }

    #[test]
    fn deterministic_given_name() {
        use std::sync::Mutex;
        let first: Mutex<Vec<u64>> = Mutex::new(Vec::new());
        check("det", 5, |g: &mut Gen| {
            first.lock().unwrap().push(g.seed);
            Ok(())
        });
        let second: Mutex<Vec<u64>> = Mutex::new(Vec::new());
        check("det", 5, |g: &mut Gen| {
            second.lock().unwrap().push(g.seed);
            Ok(())
        });
        assert_eq!(*first.lock().unwrap(), *second.lock().unwrap());
    }
}
