//! Declarative command-line parsing (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! typed accessors with defaults, and an auto-generated `--help`.

use std::collections::BTreeMap;
use std::fmt;

use super::span::{Diagnostic, Span};

/// Specification of one option/flag.
#[derive(Clone, Debug)]
struct OptSpec {
    name: &'static str,
    help: &'static str,
    takes_value: bool,
    default: Option<String>,
}

/// Command-line parser and parsed-value store.
pub struct Cli {
    program: String,
    about: &'static str,
    specs: Vec<OptSpec>,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positional: Vec<String>,
}

/// Error with a rendered usage string.
#[derive(Debug)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

impl Cli {
    /// Start a parser for `program` with a one-line description.
    pub fn new(program: &str, about: &'static str) -> Self {
        Self {
            program: program.to_string(),
            about,
            specs: Vec::new(),
            values: BTreeMap::new(),
            flags: BTreeMap::new(),
            positional: Vec::new(),
        }
    }

    /// Register `--name <value>` with an optional default.
    pub fn opt(mut self, name: &'static str, default: Option<&str>, help: &'static str) -> Self {
        self.specs.push(OptSpec {
            name,
            help,
            takes_value: true,
            default: default.map(str::to_string),
        });
        self
    }

    /// Register a boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec {
            name,
            help,
            takes_value: false,
            default: None,
        });
        self
    }

    /// Parse an argument list (without argv[0]).
    pub fn parse(mut self, args: &[String]) -> Result<Cli, CliError> {
        for spec in &self.specs {
            if let Some(d) = &spec.default {
                self.values.insert(spec.name.to_string(), d.clone());
            }
            if !spec.takes_value {
                self.flags.insert(spec.name.to_string(), false);
            }
        }
        let mut i = 0;
        while i < args.len() {
            let arg = &args[i];
            if arg == "--help" || arg == "-h" {
                return Err(CliError(self.usage()));
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| CliError(format!("unknown option --{name}\n{}", self.usage())))?
                    .clone();
                if spec.takes_value {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| CliError(format!("--{name} needs a value")))?
                        }
                    };
                    self.values.insert(name.to_string(), value);
                } else {
                    if inline.is_some() {
                        return Err(CliError(format!("--{name} takes no value")));
                    }
                    self.flags.insert(name.to_string(), true);
                }
            } else {
                self.positional.push(arg.clone());
            }
            i += 1;
        }
        Ok(self)
    }

    /// Parse from the process environment, printing usage and exiting on error.
    pub fn parse_env(self) -> Cli {
        let args: Vec<String> = std::env::args().skip(1).collect();
        match self.parse(&args) {
            Ok(cli) => cli,
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        }
    }

    /// Render the auto-generated usage/help text.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for spec in &self.specs {
            let head = if spec.takes_value {
                format!("  --{} <v>", spec.name)
            } else {
                format!("  --{}", spec.name)
            };
            let default = spec
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("{head:<24}{}{default}\n", spec.help));
        }
        s
    }

    // -- accessors --------------------------------------------------------

    /// Raw value of `--name`, if present (or defaulted).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    /// Whether the boolean `--name` flag was passed.
    pub fn get_flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    /// Typed accessor; exits with a message on parse failure.
    pub fn get_usize(&self, name: &str) -> usize {
        self.parse_typed(name)
    }

    /// Typed accessor; exits with a message on parse failure.
    pub fn get_u64(&self, name: &str) -> u64 {
        self.parse_typed(name)
    }

    /// Typed accessor; exits with a message on parse failure.
    pub fn get_f64(&self, name: &str) -> f64 {
        self.parse_typed(name)
    }

    /// Positional (non-flag) arguments in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    fn parse_typed<T: std::str::FromStr>(&self, name: &str) -> T {
        let raw = self
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} missing and has no default"));
        raw.parse().unwrap_or_else(|_| {
            eprintln!("invalid value for --{name}: {raw}");
            std::process::exit(2);
        })
    }

    /// Parse a comma-separated list of f64 (e.g. `--betas 0.1,0.2,0.3`).
    /// Exits with a spanned diagnostic on a malformed element; library
    /// callers should prefer [`Cli::try_f64_list`].
    pub fn get_f64_list(&self, name: &str) -> Vec<f64> {
        self.try_f64_list(name).unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        })
    }

    /// Fallible form of [`Cli::get_f64_list`]: a malformed element is
    /// reported the same way the wire-protocol parser reports malformed
    /// requests — a byte-spanned, labeled [`Diagnostic`] with a caret
    /// underline of the offending characters — never a panic:
    ///
    /// ```text
    /// invalid value for --betas:
    /// 0.1,x,0.3
    ///     ^ expected finite f64 list element, found "x"
    /// ```
    pub fn try_f64_list(&self, name: &str) -> Result<Vec<f64>, CliError> {
        let Some(raw) = self.get(name) else {
            return Ok(Vec::new());
        };
        let mut out = Vec::new();
        let mut start = 0;
        for piece in raw.split_inclusive(',') {
            let elem = piece.strip_suffix(',').unwrap_or(piece);
            let trimmed = elem.trim();
            if !trimmed.is_empty() {
                let parsed = trimmed.parse::<f64>().ok().filter(|v| v.is_finite());
                match parsed {
                    Some(v) => out.push(v),
                    None => {
                        let lead = elem.len() - elem.trim_start().len();
                        let span = Span::new(start + lead, start + lead + trimmed.len());
                        let d = Diagnostic::new(
                            span,
                            "finite f64 list element",
                            format!("\"{trimmed}\""),
                        );
                        return Err(CliError(format!(
                            "invalid value for --{name}:\n{}",
                            d.underline(raw)
                        )));
                    }
                }
            }
            start += piece.len();
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    fn demo() -> Cli {
        Cli::new("demo", "test tool")
            .opt("size", Some("10"), "problem size")
            .opt("beta", None, "coupling")
            .flag("verbose", "chatty")
    }

    #[test]
    fn defaults_and_overrides() {
        let c = demo().parse(&args(&["--beta", "0.5"])).unwrap();
        assert_eq!(c.get_usize("size"), 10);
        assert_eq!(c.get_f64("beta"), 0.5);
        assert!(!c.get_flag("verbose"));
    }

    #[test]
    fn equals_syntax_and_flags() {
        let c = demo()
            .parse(&args(&["--size=42", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(c.get_usize("size"), 42);
        assert!(c.get_flag("verbose"));
        assert_eq!(c.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(demo().parse(&args(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(demo().parse(&args(&["--beta"])).is_err());
    }

    #[test]
    fn float_lists() {
        let c = Cli::new("x", "y")
            .opt("betas", Some("0.1,0.2"), "list")
            .parse(&args(&[]))
            .unwrap();
        assert_eq!(c.get_f64_list("betas"), vec![0.1, 0.2]);
        // empty segments and surrounding whitespace are tolerated
        let c = Cli::new("x", "y")
            .opt("betas", Some(" 0.5 ,, -1.0, "), "list")
            .parse(&args(&[]))
            .unwrap();
        assert_eq!(c.try_f64_list("betas").unwrap(), vec![0.5, -1.0]);
        // an unset option is an empty list, not an error
        let c = Cli::new("x", "y").opt("betas", None, "list").parse(&args(&[])).unwrap();
        assert_eq!(c.try_f64_list("betas").unwrap(), Vec::<f64>::new());
    }

    #[test]
    fn malformed_float_list_is_a_spanned_diagnostic_not_a_panic() {
        let c = Cli::new("x", "y")
            .opt("betas", Some("0.1,x,0.3"), "list")
            .parse(&args(&[]))
            .unwrap();
        let e = c.try_f64_list("betas").unwrap_err();
        assert!(e.0.contains("--betas"), "{e}");
        assert!(e.0.contains("0.1,x,0.3"), "source line missing: {e}");
        assert!(
            e.0.contains("expected finite f64 list element, found \"x\""),
            "label missing: {e}"
        );
        // the caret lands under the offending element (byte offset 4)
        let caret_line = e.0.lines().last().unwrap();
        assert!(caret_line.starts_with("    ^"), "caret misplaced: {e}");
        // non-finite elements are rejected too
        let c = Cli::new("x", "y")
            .opt("betas", Some("1.0,inf"), "list")
            .parse(&args(&[]))
            .unwrap();
        let e = c.try_f64_list("betas").unwrap_err();
        assert!(e.0.contains("found \"inf\""), "{e}");
    }

    #[test]
    fn help_is_error_with_usage() {
        let Err(e) = demo().parse(&args(&["--help"])) else {
            panic!("--help must short-circuit");
        };
        assert!(e.0.contains("--size"));
        assert!(e.0.contains("problem size"));
    }
}
