//! Infrastructure substrates built from scratch for the offline environment.
//!
//! The vendored crate set has no serde/clap/tokio/rayon/proptest, so the
//! crate ships minimal, well-tested replacements:
//!
//! * [`json`] — recursive-descent JSON parser + serializer (artifact
//!   manifests, coordinator requests, bench reports).
//! * [`cli`] — declarative flag/option parser for `main.rs` and the bench
//!   binaries.
//! * [`error`] — anyhow-style error context chaining ([`error::Result`],
//!   [`error::Context`], the `err!`/`ensure!` macros).
//! * [`threadpool`] — fixed-size scoped worker pool with uniform,
//!   weighted, and alignment-aware parallel-for primitives
//!   ([`balanced_ranges`], [`threadpool::balanced_ranges_aligned`]);
//!   powers the native parallel samplers and the coordinator.
//! * [`aligned`] — cache-line-aligned `f64` storage underneath the
//!   SIMD-tiled kernel buffers and the tile-aligned conditional-table
//!   arena.
//! * [`proptest`] — mini property-testing harness (random case generation,
//!   failure reporting with the reproducing seed).
//! * [`union_find`] — path-halving union-find (Swendsen–Wang clusters,
//!   spanning forests).
//! * [`stats`] — Welford moments and simple descriptive statistics shared
//!   by diagnostics and the bench harness.
//! * [`span`] — spanned, labeled parse diagnostics (byte-offset span +
//!   expected-token label) shared by the wire-protocol parser and the
//!   CLI list accessors.

pub mod aligned;
pub mod cli;
pub mod error;
pub mod json;
pub mod proptest;
pub mod span;
pub mod stats;
pub mod threadpool;
pub mod union_find;

pub use aligned::AlignedF64s;
pub use json::Json;
pub use span::{Diagnostic, Span};
pub use threadpool::{balanced_ranges, ThreadPool};
pub use union_find::UnionFind;
