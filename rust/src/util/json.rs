//! Minimal JSON: recursive-descent parser + serializer.
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) with precise error positions. Used for the
//! artifact manifest written by `python/compile/aot.py`, coordinator
//! request payloads, and machine-readable bench reports.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` for deterministic ordering.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (stored as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array of values.
    Arr(Vec<Json>),
    /// An object (deterministically ordered).
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset and message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    /// Byte offset of the error in the input.
    pub pos: usize,
    /// Human-readable description.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: src.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    /// The number value, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The value as a non-negative integer, if exactly representable.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    /// The string value, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean value, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The element slice, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// A field of an `Obj`, or `None` for non-objects/missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style path access for tests and manifest reading.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for seg in path {
            cur = match seg.parse::<usize>() {
                Ok(i) => cur.as_arr()?.get(i)?,
                Err(_) => cur.get(seg)?,
            };
        }
        Some(cur)
    }

    /// Serialize compactly (no whitespace).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Build an object from pairs (test/report convenience).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only; surrogate pairs are not needed for
                            // our manifests but reject them cleanly.
                            let ch = char::from_u32(code)
                                .ok_or_else(|| self.err("surrogate \\u escape"))?;
                            out.push(ch);
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse("\"a\\nb\\u0041\"").unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parses_nested() {
        let doc = r#"{"a": [1, 2, {"b": false}], "c": "x"}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.at(&["a", "2", "b"]), Some(&Json::Bool(false)));
        assert_eq!(v.at(&["c"]).and_then(Json::as_str), Some("x"));
        assert_eq!(v.at(&["a", "0"]).and_then(Json::as_usize), Some(1));
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"arr":[1,2.5,null,true,"s\"x"],"n":-3}"#;
        let v = Json::parse(doc).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn error_positions() {
        let e = Json::parse("[1, x]").unwrap_err();
        assert_eq!(e.pos, 4);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ☃"));
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }
}
