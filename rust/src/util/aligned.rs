//! Cache-line-aligned `f64` storage for the SIMD-tiled kernels.
//!
//! Stable Rust cannot put an alignment attribute on a `Vec`'s heap
//! buffer directly, so [`AlignedF64s`] stores its elements inside a
//! `Vec` of 64-byte-aligned cache-line blocks and exposes them as plain
//! `&[f64]` slices. Consumers get two guarantees the tiled kernels
//! depend on:
//!
//! * the base pointer is 64-byte aligned (one full cache line, and wide
//!   enough for any aligned load up to AVX-512), and
//! * any offset that is a multiple of [`F64S_PER_CACHE_LINE`] is also
//!   64-byte aligned — which is why the x-table arena
//!   ([`crate::duality::CsrIncidence`]'s sibling `XTableArena`) pads
//!   every table to a multiple of that width.
//!
//! The container is append/overwrite-only (`push` / `clear` / mutable
//! slices); it never exposes uninitialized memory because whole blocks
//! are zero-filled on allocation.

/// Number of `f64` lanes in one 64-byte cache line — the unit all
/// tile-aligned layouts pad to (and the widest tile the kernels use).
pub const F64S_PER_CACHE_LINE: usize = 8;

/// One 64-byte-aligned block of eight `f64`s.
#[repr(C, align(64))]
#[derive(Clone, Copy, Debug, Default)]
struct CacheLine([f64; F64S_PER_CACHE_LINE]);

/// Growable `f64` buffer whose heap storage is 64-byte aligned (see
/// module docs).
#[derive(Clone, Debug, Default)]
pub struct AlignedF64s {
    lines: Vec<CacheLine>,
    len: usize,
}

impl AlignedF64s {
    /// Empty buffer (no allocation until the first push).
    pub const fn new() -> Self {
        Self {
            lines: Vec::new(),
            len: 0,
        }
    }

    /// Number of live elements.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the buffer holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Drop all elements, keeping the allocation.
    pub fn clear(&mut self) {
        self.len = 0;
    }

    /// The live elements as one contiguous, 64-byte-aligned slice.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        // SAFETY: `CacheLine` is `#[repr(C, align(64))]` around
        // `[f64; 8]` (size 64, no padding), so `lines` is a contiguous
        // run of `lines.len() * 8` initialized f64s and `len` never
        // exceeds that (invariant kept by `push`).
        unsafe { std::slice::from_raw_parts(self.lines.as_ptr() as *const f64, self.len) }
    }

    /// The live elements as one mutable contiguous slice.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        // SAFETY: as in `as_slice`; `&mut self` gives exclusive access.
        unsafe { std::slice::from_raw_parts_mut(self.lines.as_mut_ptr() as *mut f64, self.len) }
    }

    /// Append one element (amortized O(1); new blocks are zero-filled).
    pub fn push(&mut self, x: f64) {
        if self.len == self.lines.len() * F64S_PER_CACHE_LINE {
            self.lines.push(CacheLine::default());
        }
        let i = self.len;
        self.len += 1;
        self.as_mut_slice()[i] = x;
    }

    /// Append every element of `xs` (one capacity reservation + one bulk
    /// copy — the x-table arena funnels every table rebuild and every
    /// compaction pass through here).
    pub fn extend_from_slice(&mut self, xs: &[f64]) {
        let start = self.len;
        let new_len = start + xs.len();
        let lines = new_len.div_ceil(F64S_PER_CACHE_LINE);
        if lines > self.lines.len() {
            self.lines.resize(lines, CacheLine::default());
        }
        self.len = new_len;
        self.as_mut_slice()[start..].copy_from_slice(xs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_pointer_is_cache_line_aligned() {
        let mut b = AlignedF64s::new();
        for i in 0..100 {
            b.push(i as f64);
        }
        assert_eq!(b.as_slice().as_ptr() as usize % 64, 0);
        assert_eq!(b.len(), 100);
        assert_eq!(b.as_slice()[17], 17.0);
    }

    #[test]
    fn aligned_offsets_stay_aligned() {
        let mut b = AlignedF64s::new();
        b.extend_from_slice(&vec![1.5; 64]);
        let p = b.as_slice();
        for off in (0..64).step_by(F64S_PER_CACHE_LINE) {
            assert_eq!(p[off..].as_ptr() as usize % 64, 0, "offset {off}");
        }
    }

    #[test]
    fn clear_keeps_allocation_and_roundtrips() {
        let mut b = AlignedF64s::new();
        b.extend_from_slice(&[1.0, 2.0, 3.0]);
        b.clear();
        assert!(b.is_empty());
        b.extend_from_slice(&[4.0, 5.0]);
        assert_eq!(b.as_slice(), &[4.0, 5.0]);
        b.as_mut_slice()[0] = 9.0;
        assert_eq!(b.as_slice(), &[9.0, 5.0]);
    }
}
