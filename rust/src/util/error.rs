//! Minimal error type with context chaining (anyhow is unavailable in the
//! offline build image).
//!
//! Mirrors the slice of `anyhow` the crate actually uses: a string-backed
//! error with layered context, a [`Result`] alias whose error defaults to
//! [`Error`], a [`Context`] extension trait for `Result`/`Option`, and the
//! [`err!`](crate::err)/[`ensure!`](crate::ensure) macros. `Display` shows
//! the outermost message; alternate formatting (`{:#}`) shows the whole
//! chain, outermost first, colon-separated — matching how `main.rs`
//! reports failures.

use std::fmt;

/// Error carrying an ordered chain of context messages (outermost first).
#[derive(Debug)]
pub struct Error {
    frames: Vec<String>,
}

impl Error {
    /// Build from a single message.
    pub fn msg(msg: impl fmt::Display) -> Self {
        Self {
            frames: vec![msg.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn wrap(mut self, msg: impl fmt::Display) -> Self {
        self.frames.insert(0, msg.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> &[String] {
        &self.frames
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.frames.join(": "))
        } else {
            f.write_str(&self.frames[0])
        }
    }
}

impl std::error::Error for Error {}

/// Crate-wide result alias; the error type defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension adding `.context(..)` / `.with_context(..)` to `Result` and
/// `Option`, converting into [`Error`] with the message as outer frame.
pub trait Context<T> {
    /// Wrap the error/`None` with a fixed outer message.
    fn context<D: fmt::Display>(self, msg: D) -> Result<T>;
    /// Like `context`, but the message is built lazily.
    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<D: fmt::Display>(self, msg: D) -> Result<T> {
        // `{:#}` keeps the full chain when E is already `Error`.
        self.map_err(|e| Error::msg(format!("{e:#}")).wrap(msg))
    }

    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{e:#}")).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<D: fmt::Display>(self, msg: D) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string, `anyhow!`-style.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`err!`](crate::err) when the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::err!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_plain_vs_alternate() {
        let e = Error::msg("inner").wrap("middle").wrap("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: middle: inner");
        assert_eq!(e.chain(), &["outer", "middle", "inner"]);
    }

    #[test]
    fn context_on_result_keeps_chain() {
        let base: Result<()> = Err(Error::msg("root"));
        let wrapped = base.context("loading");
        let e = wrapped.unwrap_err();
        assert_eq!(format!("{e:#}"), "loading: root");
    }

    #[test]
    fn context_on_foreign_error() {
        let io: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "missing file",
        ));
        let e = io.with_context(|| "reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert!(format!("{e:#}").contains("missing file"));
    }

    #[test]
    fn context_on_option() {
        let none: Option<u32> = None;
        assert_eq!(format!("{}", none.context("absent").unwrap_err()), "absent");
        assert_eq!(Some(7u32).context("absent").unwrap(), 7);
    }

    #[test]
    fn macros_build_errors() {
        fn check(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            Ok(1)
        }
        assert_eq!(check(true).unwrap(), 1);
        assert_eq!(format!("{}", check(false).unwrap_err()), "flag was false");
        let e = err!("code {}", 42);
        assert_eq!(format!("{e}"), "code 42");
    }
}
