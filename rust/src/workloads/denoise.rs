//! Image denoising MRFs — the end-to-end example workloads.
//!
//! Two flavors:
//!
//! * **Binary** (classic Geman–Geman): a binary image corrupted by iid
//!   flip noise; the posterior over the clean image is an Ising grid
//!   whose unary fields are the per-pixel noise likelihood ratios.
//! * **K-state segmentation**: a label image corrupted by a symmetric
//!   channel. K-state graphs carry no unary fields, so the observation
//!   enters as *evidence*: each pixel gets a companion observation site
//!   tied to it by a channel Potts factor, and the observation sites are
//!   clamped to the noisy labels. The posterior over the pixel sites is
//!   then the clamped conditional law — the same composition
//!   (cardinality × evidence × any sweep policy) the engine serves.
//!
//! Both exercise the full stack (dualization → PD sampling → marginals →
//! argmax) on a real small task.

use crate::graph::{FactorGraph, PairFactor};
use crate::rng::{Pcg64, RngCore};

use super::ising_grid;

/// Parameters of the denoising posterior.
#[derive(Clone, Copy, Debug)]
pub struct DenoiseConfig {
    /// Image height in pixels.
    pub rows: usize,
    /// Image width in pixels.
    pub cols: usize,
    /// Ising smoothness coupling β.
    pub coupling: f64,
    /// Flip probability of the observation noise.
    pub flip_prob: f64,
}

impl Default for DenoiseConfig {
    fn default() -> Self {
        Self {
            rows: 50,
            cols: 50,
            coupling: 0.35,
            flip_prob: 0.12,
        }
    }
}

/// A deterministic binary test image: filled disk + bar (so the result is
/// visually checkable in the terminal).
pub fn synthetic_image(rows: usize, cols: usize) -> Vec<bool> {
    let (cr, cc) = (rows as f64 / 2.0, cols as f64 / 2.5);
    let radius = rows.min(cols) as f64 / 4.0;
    let mut img = vec![false; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            let dr = r as f64 - cr;
            let dc = c as f64 - cc;
            let in_disk = (dr * dr + dc * dc).sqrt() <= radius;
            let in_bar = c >= cols * 3 / 4 && c < cols * 7 / 8 && r >= rows / 6 && r < rows * 5 / 6;
            img[r * cols + c] = in_disk || in_bar;
        }
    }
    img
}

/// Corrupt an image with iid pixel flips.
pub fn noisy_image(clean: &[bool], flip_prob: f64, seed: u64) -> Vec<bool> {
    let mut rng = Pcg64::seed(seed);
    clean
        .iter()
        .map(|&b| if rng.bernoulli(flip_prob) { !b } else { b })
        .collect()
}

/// Posterior MRF `p(x | y) ∝ ∏_v p(y_v | x_v) · Ising(x)`.
///
/// The likelihood contributes unary log-odds
/// `log p(y|x=1)/p(y|x=0) = ±log((1−ρ)/ρ)` with the sign set by `y_v`.
pub fn denoise_mrf(cfg: &DenoiseConfig, observed: &[bool]) -> FactorGraph {
    assert_eq!(observed.len(), cfg.rows * cfg.cols);
    assert!(cfg.flip_prob > 0.0 && cfg.flip_prob < 0.5);
    let mut g = ising_grid(cfg.rows, cfg.cols, cfg.coupling, 0.0);
    let llr = ((1.0 - cfg.flip_prob) / cfg.flip_prob).ln();
    for (v, &y) in observed.iter().enumerate() {
        g.set_unary(v, if y { llr } else { -llr });
    }
    g
}

/// Pixel accuracy between two binary images.
pub fn accuracy(a: &[bool], b: &[bool]) -> f64 {
    assert_eq!(a.len(), b.len());
    let same = a.iter().zip(b).filter(|(x, y)| x == y).count();
    same as f64 / a.len() as f64
}

/// Render a binary image as unicode rows (visual spot-check in examples).
pub fn render(img: &[bool], rows: usize, cols: usize) -> String {
    let mut s = String::with_capacity(rows * (cols + 1));
    for r in 0..rows {
        for c in 0..cols {
            s.push(if img[r * cols + c] { '█' } else { '·' });
        }
        s.push('\n');
    }
    s
}

/// A deterministic K-label test image: nested disks over a striped
/// background, cycling through all `k` labels (so every state appears
/// and region boundaries run both with and against the grid axes).
pub fn synthetic_labels(rows: usize, cols: usize, k: usize) -> Vec<u8> {
    assert!(k >= 2);
    let (cr, cc) = (rows as f64 / 2.0, cols as f64 / 2.0);
    let radius = rows.min(cols) as f64 / 3.0;
    let mut img = vec![0u8; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            let dr = r as f64 - cr;
            let dc = c as f64 - cc;
            let d = (dr * dr + dc * dc).sqrt();
            img[r * cols + c] = if d <= radius {
                // concentric rings cycle through the non-zero labels
                (1 + (d * (k - 1) as f64 / (radius + 1e-9)) as usize % (k - 1)) as u8
            } else {
                // diagonal background stripes cycle through ALL labels
                ((r / 3 + c / 3) % k) as u8
            };
        }
    }
    img
}

/// Corrupt a label image with a symmetric channel: each pixel keeps its
/// label with probability `1 − rho`, otherwise becomes one of the `k − 1`
/// other labels uniformly.
pub fn noisy_labels(clean: &[u8], k: usize, rho: f64, seed: u64) -> Vec<u8> {
    assert!(k >= 2 && rho > 0.0 && rho < (k - 1) as f64 / k as f64);
    let mut rng = Pcg64::seed(seed);
    clean
        .iter()
        .map(|&lbl| {
            if rng.bernoulli(rho) {
                let other = (rng.next_u64() % (k as u64 - 1)) as u8;
                if other < lbl { other } else { other + 1 }
            } else {
                lbl
            }
        })
        .collect()
}

/// Segmentation posterior `p(x | y)` as a clamped K-state MRF.
///
/// Sites `0..n` are the pixels (Potts smoothness `coupling` on grid
/// edges); sites `n..2n` are per-pixel observation sites, each tied to
/// its pixel by a channel factor with agreement bonus
/// `β_obs = ln((1−ρ)(k−1)/ρ)` — exactly the symmetric-channel likelihood
/// ratio, since `p(y=x)/p(y≠x) = (1−ρ)/(ρ/(k−1))`. Returns the graph and
/// the evidence list clamping each observation site to its noisy label;
/// push the evidence through any engine's clamp API and the pixel-site
/// marginals are the segmentation posterior.
pub fn segmentation_mrf(
    rows: usize,
    cols: usize,
    k: usize,
    coupling: f64,
    rho: f64,
    observed: &[u8],
) -> (FactorGraph, Vec<(usize, u8)>) {
    let n = rows * cols;
    assert_eq!(observed.len(), n);
    assert!(k >= 2 && rho > 0.0 && rho < (k - 1) as f64 / k as f64);
    assert!(observed.iter().all(|&y| (y as usize) < k));
    let mut g = FactorGraph::new_k(2 * n, k);
    for r in 0..rows {
        for c in 0..cols {
            let v = r * cols + c;
            if c + 1 < cols {
                g.add_factor(PairFactor::potts(v, v + 1, coupling));
            }
            if r + 1 < rows {
                g.add_factor(PairFactor::potts(v, v + cols, coupling));
            }
        }
    }
    let beta_obs = ((1.0 - rho) * (k - 1) as f64 / rho).ln();
    let mut evidence = Vec::with_capacity(n);
    for (v, &y) in observed.iter().enumerate() {
        g.add_factor(PairFactor::potts(v, n + v, beta_obs));
        evidence.push((n + v, y));
    }
    (g, evidence)
}

/// Pixel accuracy between two label images.
pub fn label_accuracy(a: &[u8], b: &[u8]) -> f64 {
    assert_eq!(a.len(), b.len());
    let same = a.iter().zip(b).filter(|(x, y)| x == y).count();
    same as f64 / a.len() as f64
}

/// Render a label image as unicode rows, one glyph per label (visual
/// spot-check in examples; supports the full `k ≤ 8` range).
pub fn render_labels(img: &[u8], rows: usize, cols: usize) -> String {
    const GLYPHS: [char; 8] = ['·', '█', '▒', '░', '▓', '○', '●', '◆'];
    let mut s = String::with_capacity(rows * (cols + 1));
    for r in 0..rows {
        for c in 0..cols {
            s.push(GLYPHS[img[r * cols + c] as usize % GLYPHS.len()]);
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_shapes() {
        let img = synthetic_image(20, 30);
        assert_eq!(img.len(), 600);
        let on = img.iter().filter(|&&b| b).count();
        assert!(on > 30 && on < 400, "on={on}");
    }

    #[test]
    fn noise_flips_expected_fraction() {
        let clean = synthetic_image(40, 40);
        let noisy = noisy_image(&clean, 0.1, 5);
        let acc = accuracy(&clean, &noisy);
        assert!((acc - 0.9).abs() < 0.03, "acc={acc}");
    }

    #[test]
    fn posterior_unaries_match_likelihood() {
        let cfg = DenoiseConfig {
            rows: 4,
            cols: 4,
            coupling: 0.3,
            flip_prob: 0.2,
        };
        let obs = vec![true; 16];
        let g = denoise_mrf(&cfg, &obs);
        let llr = (0.8f64 / 0.2).ln();
        for v in 0..16 {
            assert!((g.unary(v) - llr).abs() < 1e-12);
        }
        assert_eq!(g.num_factors(), 2 * 4 * 3);
    }

    #[test]
    fn render_dimensions() {
        let img = synthetic_image(5, 7);
        let s = render(&img, 5, 7);
        assert_eq!(s.lines().count(), 5);
        assert!(s.lines().all(|l| l.chars().count() == 7));
    }

    #[test]
    fn label_images_cover_every_state_and_channel_noise_hits_its_rate() {
        for k in [3usize, 5, 8] {
            let clean = synthetic_labels(24, 24, k);
            assert_eq!(clean.len(), 576);
            for s in 0..k as u8 {
                assert!(clean.contains(&s), "k={k}: label {s} unused");
            }
            let noisy = noisy_labels(&clean, k, 0.15, 7);
            assert!(noisy.iter().all(|&y| (y as usize) < k));
            let acc = label_accuracy(&clean, &noisy);
            assert!((acc - 0.85).abs() < 0.04, "k={k}: acc={acc}");
        }
    }

    #[test]
    fn segmentation_mrf_shape_and_channel_strength() {
        let (rows, cols, k, rho) = (4usize, 5usize, 3usize, 0.2);
        let y = noisy_labels(&synthetic_labels(rows, cols, k), k, rho, 3);
        let (g, evidence) = segmentation_mrf(rows, cols, k, 0.4, rho, &y);
        let n = rows * cols;
        assert_eq!(g.num_vars(), 2 * n);
        assert_eq!(g.k(), k);
        // grid smoothness edges + one channel factor per pixel
        assert_eq!(g.num_factors(), (rows * (cols - 1) + (rows - 1) * cols) + n);
        // evidence clamps exactly the observation sites, to the noisy labels
        assert_eq!(evidence.len(), n);
        for (i, &(site, lbl)) in evidence.iter().enumerate() {
            assert_eq!((site, lbl), (n + i, y[i]));
        }
        // the channel factor carries the symmetric-channel likelihood ratio
        let beta_obs = ((1.0 - rho) * (k - 1) as f64 / rho).ln();
        let channel = g
            .factors()
            .find(|(_, f)| (f.v1, f.v2) == (0, n))
            .expect("pixel 0 channel factor")
            .1;
        assert!((channel.potts_beta() - beta_obs).abs() < 1e-12);
    }

    #[test]
    fn render_labels_dimensions() {
        let img = synthetic_labels(5, 7, 4);
        let s = render_labels(&img, 5, 7);
        assert_eq!(s.lines().count(), 5);
        assert!(s.lines().all(|l| l.chars().count() == 7));
    }
}
