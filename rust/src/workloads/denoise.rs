//! Binary image denoising MRF — the end-to-end example workload.
//!
//! Classic Geman–Geman setup: a binary image corrupted by iid flip noise;
//! the posterior over the clean image is an Ising grid whose unary fields
//! are the per-pixel noise likelihood ratios. This is exactly the vision
//! workload the paper's introduction motivates, and it exercises the full
//! stack (dualization → PD sampling via the XLA runtime → marginals →
//! thresholding) on a real small task.

use crate::graph::FactorGraph;
use crate::rng::{Pcg64, RngCore};

use super::ising_grid;

/// Parameters of the denoising posterior.
#[derive(Clone, Copy, Debug)]
pub struct DenoiseConfig {
    /// Image height in pixels.
    pub rows: usize,
    /// Image width in pixels.
    pub cols: usize,
    /// Ising smoothness coupling β.
    pub coupling: f64,
    /// Flip probability of the observation noise.
    pub flip_prob: f64,
}

impl Default for DenoiseConfig {
    fn default() -> Self {
        Self {
            rows: 50,
            cols: 50,
            coupling: 0.35,
            flip_prob: 0.12,
        }
    }
}

/// A deterministic binary test image: filled disk + bar (so the result is
/// visually checkable in the terminal).
pub fn synthetic_image(rows: usize, cols: usize) -> Vec<bool> {
    let (cr, cc) = (rows as f64 / 2.0, cols as f64 / 2.5);
    let radius = rows.min(cols) as f64 / 4.0;
    let mut img = vec![false; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            let dr = r as f64 - cr;
            let dc = c as f64 - cc;
            let in_disk = (dr * dr + dc * dc).sqrt() <= radius;
            let in_bar = c >= cols * 3 / 4 && c < cols * 7 / 8 && r >= rows / 6 && r < rows * 5 / 6;
            img[r * cols + c] = in_disk || in_bar;
        }
    }
    img
}

/// Corrupt an image with iid pixel flips.
pub fn noisy_image(clean: &[bool], flip_prob: f64, seed: u64) -> Vec<bool> {
    let mut rng = Pcg64::seed(seed);
    clean
        .iter()
        .map(|&b| if rng.bernoulli(flip_prob) { !b } else { b })
        .collect()
}

/// Posterior MRF `p(x | y) ∝ ∏_v p(y_v | x_v) · Ising(x)`.
///
/// The likelihood contributes unary log-odds
/// `log p(y|x=1)/p(y|x=0) = ±log((1−ρ)/ρ)` with the sign set by `y_v`.
pub fn denoise_mrf(cfg: &DenoiseConfig, observed: &[bool]) -> FactorGraph {
    assert_eq!(observed.len(), cfg.rows * cfg.cols);
    assert!(cfg.flip_prob > 0.0 && cfg.flip_prob < 0.5);
    let mut g = ising_grid(cfg.rows, cfg.cols, cfg.coupling, 0.0);
    let llr = ((1.0 - cfg.flip_prob) / cfg.flip_prob).ln();
    for (v, &y) in observed.iter().enumerate() {
        g.set_unary(v, if y { llr } else { -llr });
    }
    g
}

/// Pixel accuracy between two binary images.
pub fn accuracy(a: &[bool], b: &[bool]) -> f64 {
    assert_eq!(a.len(), b.len());
    let same = a.iter().zip(b).filter(|(x, y)| x == y).count();
    same as f64 / a.len() as f64
}

/// Render a binary image as unicode rows (visual spot-check in examples).
pub fn render(img: &[bool], rows: usize, cols: usize) -> String {
    let mut s = String::with_capacity(rows * (cols + 1));
    for r in 0..rows {
        for c in 0..cols {
            s.push(if img[r * cols + c] { '█' } else { '·' });
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_shapes() {
        let img = synthetic_image(20, 30);
        assert_eq!(img.len(), 600);
        let on = img.iter().filter(|&&b| b).count();
        assert!(on > 30 && on < 400, "on={on}");
    }

    #[test]
    fn noise_flips_expected_fraction() {
        let clean = synthetic_image(40, 40);
        let noisy = noisy_image(&clean, 0.1, 5);
        let acc = accuracy(&clean, &noisy);
        assert!((acc - 0.9).abs() < 0.03, "acc={acc}");
    }

    #[test]
    fn posterior_unaries_match_likelihood() {
        let cfg = DenoiseConfig {
            rows: 4,
            cols: 4,
            coupling: 0.3,
            flip_prob: 0.2,
        };
        let obs = vec![true; 16];
        let g = denoise_mrf(&cfg, &obs);
        let llr = (0.8f64 / 0.2).ln();
        for v in 0..16 {
            assert!((g.unary(v) - llr).abs() < 1e-12);
        }
        assert_eq!(g.num_factors(), 2 * 4 * 3);
    }

    #[test]
    fn render_dimensions() {
        let img = synthetic_image(5, 7);
        let s = render(&img, 5, 7);
        assert_eq!(s.lines().count(), 5);
        assert!(s.lines().all(|l| l.chars().count() == 7));
    }
}
