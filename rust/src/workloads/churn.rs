//! Dynamic-topology churn traces.
//!
//! The paper motivates the primal–dual sampler with "large dynamic
//! networks, where factors are added and removed on a continuous basis".
//! No public trace of such a workload exists, so we synthesize one (see
//! DESIGN.md §Substitutions): a seeded sequence of add/remove operations
//! interleaved with sampling, with a configurable target factor count so
//! the graph stays near a steady-state density.

use crate::graph::{FactorGraph, FactorId, PairFactor};
use crate::rng::{Pcg64, RngCore};

/// One topology mutation.
#[derive(Clone, Debug, PartialEq)]
pub enum ChurnOp {
    /// Insert an Ising factor with the given coupling.
    Add { v1: usize, v2: usize, beta: f64 },
    /// Remove the i-th *currently live* churned factor (index into the
    /// trace player's live list, so traces replay deterministically).
    RemoveLive { index: usize },
}

/// A replayable churn trace over a fixed variable set.
#[derive(Clone, Debug)]
pub struct ChurnTrace {
    /// Fixed variable count of the churned graph.
    pub num_vars: usize,
    /// Operations in replay order.
    pub ops: Vec<ChurnOp>,
}

impl ChurnTrace {
    /// Generate `steps` operations keeping roughly `target_factors` live.
    ///
    /// Each step adds with probability `p_add(live)` (1 when empty,
    /// decreasing past the target) and removes a uniform live factor
    /// otherwise. Couplings are uniform in `[0, beta_max]`.
    pub fn generate(
        num_vars: usize,
        target_factors: usize,
        steps: usize,
        beta_max: f64,
        seed: u64,
    ) -> ChurnTrace {
        assert!(num_vars >= 2);
        let mut rng = Pcg64::seed(seed);
        let mut live = 0usize;
        let mut ops = Vec::with_capacity(steps);
        for _ in 0..steps {
            let p_add = if live == 0 {
                1.0
            } else {
                (1.0 - live as f64 / (2.0 * target_factors as f64)).clamp(0.05, 0.95)
            };
            if rng.bernoulli(p_add) {
                let v1 = rng.next_below(num_vars as u64) as usize;
                let v2 = loop {
                    let v = rng.next_below(num_vars as u64) as usize;
                    if v != v1 {
                        break v;
                    }
                };
                ops.push(ChurnOp::Add {
                    v1,
                    v2,
                    beta: beta_max * rng.next_f64(),
                });
                live += 1;
            } else {
                ops.push(ChurnOp::RemoveLive {
                    index: rng.next_below(live as u64) as usize,
                });
                live -= 1;
            }
        }
        ChurnTrace { num_vars, ops }
    }

    /// Apply the whole trace to a fresh graph, returning it plus the ids of
    /// factors still live (useful for tests; the coordinator replays ops
    /// one at a time instead).
    pub fn materialize(&self) -> (FactorGraph, Vec<FactorId>) {
        let mut g = FactorGraph::new(self.num_vars);
        let mut live: Vec<FactorId> = Vec::new();
        for op in &self.ops {
            Self::apply(&mut g, &mut live, op);
        }
        (g, live)
    }

    /// Apply one op to `(graph, live-list)`. Returns the id of the factor
    /// added or removed, so samplers mirroring the graph (the validation
    /// path adapters) can apply the same mutation without re-implementing
    /// the live-list convention.
    pub fn apply(g: &mut FactorGraph, live: &mut Vec<FactorId>, op: &ChurnOp) -> FactorId {
        match *op {
            ChurnOp::Add { v1, v2, beta } => {
                let id = g.add_factor(PairFactor::ising(v1, v2, beta));
                live.push(id);
                id
            }
            ChurnOp::RemoveLive { index } => {
                let id = live.swap_remove(index);
                g.remove_factor(id).expect("trace removes only live factors");
                id
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic() {
        let a = ChurnTrace::generate(20, 30, 200, 0.5, 42);
        let b = ChurnTrace::generate(20, 30, 200, 0.5, 42);
        assert_eq!(a.ops, b.ops);
    }

    #[test]
    fn removals_reference_live_factors() {
        let t = ChurnTrace::generate(10, 15, 500, 0.5, 7);
        let (g, live) = t.materialize(); // panics internally if invalid
        assert_eq!(g.num_factors(), live.len());
    }

    #[test]
    fn hovers_near_target() {
        let t = ChurnTrace::generate(50, 100, 4000, 0.5, 3);
        let (g, _) = t.materialize();
        let live = g.num_factors() as f64;
        assert!(live > 30.0 && live < 250.0, "live={live}");
    }

    #[test]
    fn couplings_in_band() {
        let t = ChurnTrace::generate(10, 10, 100, 0.25, 5);
        for op in &t.ops {
            if let ChurnOp::Add { beta, .. } = op {
                assert!((0.0..=0.25).contains(beta));
            }
        }
    }
}
