//! Multi-tenant traffic traces: seeded arrival/departure of tenants with
//! per-tenant churn.
//!
//! The sharded coordinator's workload is not one big model but a churning
//! *population* of small ones — per-user/per-session MRFs arriving,
//! mutating, being queried and departing. No public trace of such a
//! workload exists (same situation as the single-model churn traces, see
//! DESIGN.md §Substitutions), so we synthesize one: a seeded event
//! sequence that the soak tests and the `--mode server` bench replay
//! against a [`crate::coordinator::Coordinator`]. All randomness comes
//! from one [`Pcg64`] stream, so a `(config, seed)` pair always produces
//! the identical trace.
//!
//! The network serving edge adds two socket-speaking drivers: events
//! render as wire-protocol lines ([`TenantEvent::to_wire`]) so a trace
//! can replay through a real TCP connection
//! ([`replay_trace_over_socket`] — the CI soak), and [`run_net_load`] is
//! a closed-loop load generator (tens of thousands of logical clients
//! over a bounded socket pool, seeded bursty arrivals) behind the
//! `--mode server-net` saturation bench.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Instant;

use crate::coordinator::protocol::{classify_reply, ReplyKind};
use crate::rng::{Pcg64, RngCore};
use crate::util::error::{Context, Result};

use super::ChurnOp;

/// Arrival probability per step while below `max_tenants`.
const P_ARRIVE: f64 = 0.12;
/// Departure probability per step while more than one tenant is live.
const P_DEPART: f64 = 0.04;

/// One event of a multi-tenant trace. Tenant ids are unique per trace
/// (never reused after a `Drop`), and every `Apply`/`Sweep`/`Drop`
/// references a tenant created earlier and not yet dropped.
#[derive(Clone, Debug, PartialEq)]
pub enum TenantEvent {
    /// A tenant arrives: host a fresh `vars`-variable model under `seed`.
    Create { tenant: u64, vars: usize, seed: u64 },
    /// Topology churn on one tenant (valid against its live-factor list).
    Apply { tenant: u64, ops: Vec<ChurnOp> },
    /// Foreground sweeps on one tenant.
    Sweep { tenant: u64, n: usize },
    /// The tenant departs.
    Drop { tenant: u64 },
}

impl TenantEvent {
    /// Render as one wire-protocol request line (see `docs/PROTOCOL.md`);
    /// `Create` events pin 4 chains. Couplings use `f64`'s shortest
    /// round-tripping decimal form, so replaying the line reproduces the
    /// event bit-exactly (the tests parse it back and compare).
    pub fn to_wire(&self) -> String {
        match self {
            TenantEvent::Create { tenant, vars, seed } => {
                format!("create {tenant} {vars} 4 {seed}")
            }
            TenantEvent::Apply { tenant, ops } => {
                let mut s = format!("apply {tenant}");
                for op in ops {
                    match op {
                        ChurnOp::Add { v1, v2, beta } => {
                            s.push_str(&format!(" add {v1} {v2} {beta}"));
                        }
                        ChurnOp::RemoveLive { index } => {
                            s.push_str(&format!(" del {index}"));
                        }
                    }
                }
                s
            }
            TenantEvent::Sweep { tenant, n } => format!("sweep {tenant} {n}"),
            TenantEvent::Drop { tenant } => format!("drop {tenant}"),
        }
    }
}

/// Generation parameters for [`TenantTrace::generate`].
#[derive(Clone, Debug)]
pub struct TenantTraceConfig {
    /// Population cap; arrivals pause while at the cap.
    pub max_tenants: usize,
    /// Number of trace steps (each emits one or two events).
    pub steps: usize,
    /// Inclusive range of per-tenant variable counts.
    pub vars: (usize, usize),
    /// Per-tenant steady-state live factor target (same control law as
    /// [`super::ChurnTrace::generate`]).
    pub target_factors: usize,
    /// Churn ops per `Apply` event.
    pub ops_per_apply: usize,
    /// Sweeps per `Sweep` event.
    pub sweeps_per_step: usize,
    /// Couplings are uniform in `[0, beta_max]`.
    pub beta_max: f64,
}

impl Default for TenantTraceConfig {
    fn default() -> Self {
        Self {
            max_tenants: 16,
            steps: 400,
            vars: (4, 12),
            target_factors: 12,
            ops_per_apply: 4,
            sweeps_per_step: 8,
            beta_max: 0.5,
        }
    }
}

/// A replayable multi-tenant traffic trace (see module docs).
#[derive(Clone, Debug)]
pub struct TenantTrace {
    /// Arrival/departure/churn events in replay order.
    pub events: Vec<TenantEvent>,
}

struct LiveTenant {
    id: u64,
    vars: usize,
    live_factors: usize,
}

impl TenantTrace {
    /// Generate a trace: each step is an arrival (probability
    /// [`P_ARRIVE`], forced while the population is empty), a departure
    /// ([`P_DEPART`], only while ≥ 2 tenants are live — the trace always
    /// leaves survivors), or a churn burst plus sweeps on one uniformly
    /// chosen tenant.
    pub fn generate(config: TenantTraceConfig, seed: u64) -> TenantTrace {
        assert!(config.vars.0 >= 2 && config.vars.0 <= config.vars.1);
        assert!(config.max_tenants >= 1);
        let mut rng = Pcg64::seed(seed);
        let mut events = Vec::with_capacity(2 * config.steps);
        let mut live: Vec<LiveTenant> = Vec::new();
        let mut next_id = 1u64;
        for _ in 0..config.steps {
            let roll = rng.next_f64();
            if live.is_empty() || (roll < P_ARRIVE && live.len() < config.max_tenants) {
                let span = (config.vars.1 - config.vars.0 + 1) as u64;
                let vars = config.vars.0 + rng.next_below(span) as usize;
                events.push(TenantEvent::Create {
                    tenant: next_id,
                    vars,
                    seed: seed ^ next_id.wrapping_mul(0x9E37_79B9_7F4A_7C15),
                });
                live.push(LiveTenant {
                    id: next_id,
                    vars,
                    live_factors: 0,
                });
                next_id += 1;
            } else if roll > 1.0 - P_DEPART && live.len() > 1 {
                let i = rng.next_below(live.len() as u64) as usize;
                events.push(TenantEvent::Drop {
                    tenant: live.swap_remove(i).id,
                });
            } else {
                let i = rng.next_below(live.len() as u64) as usize;
                let t = &mut live[i];
                let mut ops = Vec::with_capacity(config.ops_per_apply);
                for _ in 0..config.ops_per_apply {
                    let p_add = if t.live_factors == 0 {
                        1.0
                    } else {
                        (1.0 - t.live_factors as f64 / (2.0 * config.target_factors as f64))
                            .clamp(0.05, 0.95)
                    };
                    if rng.bernoulli(p_add) {
                        let v1 = rng.next_below(t.vars as u64) as usize;
                        let v2 = loop {
                            let v = rng.next_below(t.vars as u64) as usize;
                            if v != v1 {
                                break v;
                            }
                        };
                        ops.push(ChurnOp::Add {
                            v1,
                            v2,
                            beta: config.beta_max * rng.next_f64(),
                        });
                        t.live_factors += 1;
                    } else {
                        ops.push(ChurnOp::RemoveLive {
                            index: rng.next_below(t.live_factors as u64) as usize,
                        });
                        t.live_factors -= 1;
                    }
                }
                let id = t.id;
                events.push(TenantEvent::Apply { tenant: id, ops });
                events.push(TenantEvent::Sweep {
                    tenant: id,
                    n: config.sweeps_per_step,
                });
            }
        }
        TenantTrace { events }
    }

    /// Tenants still live at the end of the trace.
    pub fn survivors(&self) -> Vec<u64> {
        let mut live = Vec::new();
        for e in &self.events {
            match e {
                TenantEvent::Create { tenant, .. } => live.push(*tenant),
                TenantEvent::Drop { tenant } => live.retain(|t| t != tenant),
                _ => {}
            }
        }
        live
    }
}

/// Replay a [`TenantTrace`] through a real socket speaking the wire
/// protocol, one request per event, reading each reply before sending
/// the next. Returns the number of non-`ok` replies (0 = clean soak).
/// Empty `Apply` events are skipped (an op-less `apply` is a parse
/// error by design).
pub fn replay_trace_over_socket(addr: &str, trace: &TenantTrace) -> Result<u64> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting soak client to {addr}"))?;
    let mut reader = BufReader::new(stream.try_clone().context("cloning soak socket")?);
    let mut failures = 0u64;
    for event in &trace.events {
        if matches!(event, TenantEvent::Apply { ops, .. } if ops.is_empty()) {
            continue;
        }
        let line = event.to_wire();
        stream
            .write_all(line.as_bytes())
            .and_then(|()| stream.write_all(b"\n"))
            .with_context(|| format!("sending {line:?}"))?;
        let mut reply = String::new();
        let n = reader
            .read_line(&mut reply)
            .with_context(|| format!("awaiting reply to {line:?}"))?;
        crate::ensure!(n > 0, "server closed the soak connection after {line:?}");
        if classify_reply(reply.trim_end()) != ReplyKind::Ok {
            failures += 1;
        }
    }
    Ok(failures)
}

/// Parameters for [`run_net_load`]: a closed-loop network load with
/// seeded bursty arrivals.
///
/// `logical_clients` simulated clients (each with at most one
/// outstanding request — closed loop) are multiplexed over
/// `connections` real sockets, because tens of thousands of fds would
/// blow typical `ulimit -n` budgets while tens of thousands of *logical*
/// request streams are exactly the serving story the edge must absorb.
#[derive(Clone, Debug)]
pub struct NetLoadConfig {
    /// Server address (e.g. from `NetServer::addr().to_string()`).
    pub addr: String,
    /// Simulated concurrent clients.
    pub logical_clients: usize,
    /// Real sockets (one OS thread each) the clients multiplex over.
    pub connections: usize,
    /// Requests each logical client issues before retiring.
    pub requests_per_client: usize,
    /// Tenants (ids `1..=tenants`) created before the load starts;
    /// client `i` traffics tenant `1 + i % tenants`.
    pub tenants: u64,
    /// Variables per tenant model.
    pub vars: usize,
    /// Sweeps per `sweep` request.
    pub sweep_n: usize,
    /// Coupling magnitude cap for generated `apply` ops.
    pub beta_max: f64,
    /// Burst cap: each wakeup pipelines `1..=max_burst` requests from
    /// distinct clients before draining replies (bursty arrivals).
    pub max_burst: usize,
    /// Root seed for the request mix and burst sizes.
    pub seed: u64,
}

impl Default for NetLoadConfig {
    fn default() -> Self {
        Self {
            addr: String::new(),
            logical_clients: 20_000,
            connections: 16,
            requests_per_client: 4,
            tenants: 64,
            vars: 12,
            sweep_n: 4,
            beta_max: 0.5,
            max_burst: 32,
            seed: 0,
        }
    }
}

/// Aggregate outcome of one [`run_net_load`] run. Latencies are
/// round-trip seconds measured from each burst's send to each reply in
/// it (the closed-loop client-perceived latency, queueing included).
#[derive(Clone, Debug, Default)]
pub struct NetLoadReport {
    /// Requests sent (and answered — the loop is closed).
    pub sent: u64,
    /// `ok`/`event` replies.
    pub ok: u64,
    /// `err overloaded` admission rejections.
    pub overloaded: u64,
    /// `err parse` replies (0 for a well-formed generator).
    pub parse_errors: u64,
    /// `err exec` and protocol-violation replies.
    pub exec_errors: u64,
    /// Per-request round-trip latencies, seconds (unordered).
    pub latencies_s: Vec<f64>,
    /// Wall-clock seconds for the whole load (excluding tenant setup).
    pub elapsed_s: f64,
}

/// Drive a closed-loop load against a wire-protocol server (see
/// [`NetLoadConfig`]). Creates the tenants, runs every logical client to
/// completion, and returns the merged report.
pub fn run_net_load(config: &NetLoadConfig) -> Result<NetLoadReport> {
    crate::ensure!(config.connections >= 1, "need at least one connection");
    crate::ensure!(config.logical_clients >= 1, "need at least one client");
    crate::ensure!(config.tenants >= 1 && config.vars >= 2, "need tenants with >= 2 vars");
    // setup: create every tenant over a dedicated connection
    {
        let mut stream = TcpStream::connect(&config.addr)
            .with_context(|| format!("connecting load setup to {}", config.addr))?;
        let mut reader = BufReader::new(stream.try_clone().context("cloning setup socket")?);
        let mut lines = String::new();
        for t in 1..=config.tenants {
            lines.push_str(&format!("create {t} {} 4 {}\n", config.vars, config.seed ^ t));
        }
        stream.write_all(lines.as_bytes()).context("sending creates")?;
        for t in 1..=config.tenants {
            let mut reply = String::new();
            reader.read_line(&mut reply).context("awaiting create reply")?;
            crate::ensure!(
                classify_reply(reply.trim_end()) == ReplyKind::Ok,
                "create tenant {t} failed: {}",
                reply.trim_end()
            );
        }
    }
    let t0 = Instant::now();
    let reports: Vec<Result<NetLoadReport>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..config.connections)
            .map(|conn| s.spawn(move || drive_connection(config, conn)))
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(crate::err!("load connection thread panicked")))
            })
            .collect()
    });
    let mut agg = NetLoadReport {
        elapsed_s: t0.elapsed().as_secs_f64(),
        ..Default::default()
    };
    for r in reports {
        let r = r?;
        agg.sent += r.sent;
        agg.ok += r.ok;
        agg.overloaded += r.overloaded;
        agg.parse_errors += r.parse_errors;
        agg.exec_errors += r.exec_errors;
        agg.latencies_s.extend(r.latencies_s);
    }
    Ok(agg)
}

/// One socket's worth of the closed loop: round-robin over this
/// connection's share of the logical clients, pipelining seeded bursts
/// and draining every reply before the next burst.
fn drive_connection(config: &NetLoadConfig, conn: usize) -> Result<NetLoadReport> {
    let mut stream = TcpStream::connect(&config.addr)
        .with_context(|| format!("connecting load socket {conn}"))?;
    let _ = stream.set_nodelay(true);
    let mut reader = BufReader::new(stream.try_clone().context("cloning load socket")?);
    let mut rng = Pcg64::seed(config.seed ^ (0xC0FFEE + conn as u64));
    let client_ids: Vec<usize> = (0..config.logical_clients)
        .filter(|i| i % config.connections == conn)
        .collect();
    let mut remaining: Vec<usize> = vec![config.requests_per_client; client_ids.len()];
    let mut pending: usize = remaining.iter().sum();
    let mut report = NetLoadReport::default();
    let mut cursor = 0usize;
    while pending > 0 {
        let burst = 1 + rng.next_below(config.max_burst.max(1) as u64) as usize;
        let mut lines = String::new();
        let mut picked = 0usize;
        let mut scanned = 0usize;
        while picked < burst && scanned < remaining.len() {
            let idx = cursor % remaining.len();
            cursor += 1;
            scanned += 1;
            if remaining[idx] == 0 {
                continue;
            }
            remaining[idx] -= 1;
            pending -= 1;
            picked += 1;
            let tenant = 1 + (client_ids[idx] as u64 % config.tenants);
            let roll = rng.next_f64();
            let line = if roll < 0.60 {
                format!("sweep {tenant} {}", config.sweep_n.max(1))
            } else if roll < 0.80 {
                let v1 = rng.next_below(config.vars as u64) as usize;
                let mut v2 = rng.next_below(config.vars as u64) as usize;
                if v2 == v1 {
                    v2 = (v1 + 1) % config.vars;
                }
                format!("apply {tenant} add {v1} {v2} {}", config.beta_max * rng.next_f64())
            } else if roll < 0.95 {
                format!("marginals {tenant}")
            } else {
                format!("stats {tenant}")
            };
            lines.push_str(&line);
            lines.push('\n');
        }
        if picked == 0 {
            break;
        }
        let send_t = Instant::now();
        stream.write_all(lines.as_bytes()).context("writing load burst")?;
        for _ in 0..picked {
            let mut reply = String::new();
            let n = reader.read_line(&mut reply).context("reading load reply")?;
            crate::ensure!(n > 0, "server closed connection {conn} mid-burst");
            report.sent += 1;
            report.latencies_s.push(send_t.elapsed().as_secs_f64());
            match classify_reply(reply.trim_end()) {
                ReplyKind::Ok | ReplyKind::Event => report.ok += 1,
                ReplyKind::Overloaded => report.overloaded += 1,
                ReplyKind::ParseError => report.parse_errors += 1,
                ReplyKind::ExecError | ReplyKind::Unknown => report.exec_errors += 1,
            }
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{FactorGraph, FactorId};
    use crate::workloads::ChurnTrace;
    use std::collections::HashMap;

    #[test]
    fn trace_is_deterministic() {
        let a = TenantTrace::generate(TenantTraceConfig::default(), 42);
        let b = TenantTrace::generate(TenantTraceConfig::default(), 42);
        assert_eq!(a.events, b.events);
        let c = TenantTrace::generate(TenantTraceConfig::default(), 43);
        assert_ne!(a.events, c.events);
    }

    #[test]
    fn events_replay_validly_and_respect_the_population_cap() {
        let cfg = TenantTraceConfig {
            max_tenants: 6,
            steps: 500,
            ..Default::default()
        };
        let trace = TenantTrace::generate(cfg, 7);
        // replay every event against local per-tenant graphs; panics on
        // any invalid op (unknown tenant, bad RemoveLive index)
        let mut graphs: HashMap<u64, (FactorGraph, Vec<FactorId>)> = HashMap::new();
        let mut peak = 0usize;
        for e in &trace.events {
            match e {
                TenantEvent::Create { tenant, vars, .. } => {
                    assert!(*vars >= 2);
                    let prev = graphs.insert(*tenant, (FactorGraph::new(*vars), Vec::new()));
                    assert!(prev.is_none(), "tenant id reused");
                    peak = peak.max(graphs.len());
                }
                TenantEvent::Apply { tenant, ops } => {
                    let (g, live) = graphs.get_mut(tenant).expect("apply to live tenant");
                    for op in ops {
                        ChurnTrace::apply(g, live, op);
                    }
                }
                TenantEvent::Sweep { tenant, n } => {
                    assert!(graphs.contains_key(tenant), "sweep of live tenant");
                    assert!(*n > 0);
                }
                TenantEvent::Drop { tenant } => {
                    assert!(graphs.remove(tenant).is_some(), "drop of live tenant");
                }
            }
        }
        assert!(peak <= 6, "population cap violated: {peak}");
        assert!(!graphs.is_empty(), "trace must leave survivors");
        let mut survivors: Vec<u64> = graphs.keys().copied().collect();
        survivors.sort_unstable();
        let mut want = trace.survivors();
        want.sort_unstable();
        assert_eq!(survivors, want);
    }

    #[test]
    fn wire_rendering_round_trips_through_the_protocol_parser() {
        use crate::coordinator::protocol::{parse_request, Request};
        let trace = TenantTrace::generate(TenantTraceConfig::default(), 9);
        assert!(trace.events.len() > 100);
        for e in &trace.events {
            let line = e.to_wire();
            let req = parse_request(&line).unwrap_or_else(|d| panic!("{line:?}: {d}"));
            match (e, req) {
                (
                    TenantEvent::Create { tenant, vars, seed },
                    Request::Create {
                        tenant: t,
                        vars: v,
                        chains,
                        seed: s,
                        k,
                        sweep,
                    },
                ) => {
                    assert_eq!((*tenant, *vars, 4, *seed), (t, v, chains, s));
                    assert_eq!(k, 2, "traces carry no cardinality");
                    assert_eq!(sweep, Default::default(), "traces carry no policy");
                }
                (
                    TenantEvent::Apply { tenant, ops },
                    Request::Apply { tenant: t, ops: o },
                ) => {
                    assert_eq!(*tenant, t);
                    // couplings survive the decimal round trip bit-exactly
                    assert_eq!(*ops, o);
                }
                (TenantEvent::Sweep { tenant, n }, Request::Sweep { tenant: t, n: m }) => {
                    assert_eq!((*tenant, *n), (t, m));
                }
                (TenantEvent::Drop { tenant }, Request::Drop { tenant: t }) => {
                    assert_eq!(*tenant, t);
                }
                (e, r) => panic!("event/request kind mismatch: {e:?} vs {r:?}"),
            }
        }
    }

    #[test]
    fn per_tenant_seeds_differ() {
        let trace = TenantTrace::generate(TenantTraceConfig::default(), 3);
        let mut seeds = Vec::new();
        for e in &trace.events {
            if let TenantEvent::Create { seed, .. } = e {
                seeds.push(*seed);
            }
        }
        assert!(seeds.len() > 1, "expected several arrivals");
        let n = seeds.len();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), n, "per-tenant seeds must be distinct");
    }
}
