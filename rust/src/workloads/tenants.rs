//! Multi-tenant traffic traces: seeded arrival/departure of tenants with
//! per-tenant churn.
//!
//! The sharded coordinator's workload is not one big model but a churning
//! *population* of small ones — per-user/per-session MRFs arriving,
//! mutating, being queried and departing. No public trace of such a
//! workload exists (same situation as the single-model churn traces, see
//! DESIGN.md §Substitutions), so we synthesize one: a seeded event
//! sequence that the soak tests and the `--mode server` bench replay
//! against a [`crate::coordinator::Coordinator`]. All randomness comes
//! from one [`Pcg64`] stream, so a `(config, seed)` pair always produces
//! the identical trace.

use crate::rng::{Pcg64, RngCore};

use super::ChurnOp;

/// Arrival probability per step while below `max_tenants`.
const P_ARRIVE: f64 = 0.12;
/// Departure probability per step while more than one tenant is live.
const P_DEPART: f64 = 0.04;

/// One event of a multi-tenant trace. Tenant ids are unique per trace
/// (never reused after a `Drop`), and every `Apply`/`Sweep`/`Drop`
/// references a tenant created earlier and not yet dropped.
#[derive(Clone, Debug, PartialEq)]
pub enum TenantEvent {
    /// A tenant arrives: host a fresh `vars`-variable model under `seed`.
    Create { tenant: u64, vars: usize, seed: u64 },
    /// Topology churn on one tenant (valid against its live-factor list).
    Apply { tenant: u64, ops: Vec<ChurnOp> },
    /// Foreground sweeps on one tenant.
    Sweep { tenant: u64, n: usize },
    /// The tenant departs.
    Drop { tenant: u64 },
}

/// Generation parameters for [`TenantTrace::generate`].
#[derive(Clone, Debug)]
pub struct TenantTraceConfig {
    /// Population cap; arrivals pause while at the cap.
    pub max_tenants: usize,
    /// Number of trace steps (each emits one or two events).
    pub steps: usize,
    /// Inclusive range of per-tenant variable counts.
    pub vars: (usize, usize),
    /// Per-tenant steady-state live factor target (same control law as
    /// [`super::ChurnTrace::generate`]).
    pub target_factors: usize,
    /// Churn ops per `Apply` event.
    pub ops_per_apply: usize,
    /// Sweeps per `Sweep` event.
    pub sweeps_per_step: usize,
    /// Couplings are uniform in `[0, beta_max]`.
    pub beta_max: f64,
}

impl Default for TenantTraceConfig {
    fn default() -> Self {
        Self {
            max_tenants: 16,
            steps: 400,
            vars: (4, 12),
            target_factors: 12,
            ops_per_apply: 4,
            sweeps_per_step: 8,
            beta_max: 0.5,
        }
    }
}

/// A replayable multi-tenant traffic trace (see module docs).
#[derive(Clone, Debug)]
pub struct TenantTrace {
    /// Arrival/departure/churn events in replay order.
    pub events: Vec<TenantEvent>,
}

struct LiveTenant {
    id: u64,
    vars: usize,
    live_factors: usize,
}

impl TenantTrace {
    /// Generate a trace: each step is an arrival (probability
    /// [`P_ARRIVE`], forced while the population is empty), a departure
    /// ([`P_DEPART`], only while ≥ 2 tenants are live — the trace always
    /// leaves survivors), or a churn burst plus sweeps on one uniformly
    /// chosen tenant.
    pub fn generate(config: TenantTraceConfig, seed: u64) -> TenantTrace {
        assert!(config.vars.0 >= 2 && config.vars.0 <= config.vars.1);
        assert!(config.max_tenants >= 1);
        let mut rng = Pcg64::seed(seed);
        let mut events = Vec::with_capacity(2 * config.steps);
        let mut live: Vec<LiveTenant> = Vec::new();
        let mut next_id = 1u64;
        for _ in 0..config.steps {
            let roll = rng.next_f64();
            if live.is_empty() || (roll < P_ARRIVE && live.len() < config.max_tenants) {
                let span = (config.vars.1 - config.vars.0 + 1) as u64;
                let vars = config.vars.0 + rng.next_below(span) as usize;
                events.push(TenantEvent::Create {
                    tenant: next_id,
                    vars,
                    seed: seed ^ next_id.wrapping_mul(0x9E37_79B9_7F4A_7C15),
                });
                live.push(LiveTenant {
                    id: next_id,
                    vars,
                    live_factors: 0,
                });
                next_id += 1;
            } else if roll > 1.0 - P_DEPART && live.len() > 1 {
                let i = rng.next_below(live.len() as u64) as usize;
                events.push(TenantEvent::Drop {
                    tenant: live.swap_remove(i).id,
                });
            } else {
                let i = rng.next_below(live.len() as u64) as usize;
                let t = &mut live[i];
                let mut ops = Vec::with_capacity(config.ops_per_apply);
                for _ in 0..config.ops_per_apply {
                    let p_add = if t.live_factors == 0 {
                        1.0
                    } else {
                        (1.0 - t.live_factors as f64 / (2.0 * config.target_factors as f64))
                            .clamp(0.05, 0.95)
                    };
                    if rng.bernoulli(p_add) {
                        let v1 = rng.next_below(t.vars as u64) as usize;
                        let v2 = loop {
                            let v = rng.next_below(t.vars as u64) as usize;
                            if v != v1 {
                                break v;
                            }
                        };
                        ops.push(ChurnOp::Add {
                            v1,
                            v2,
                            beta: config.beta_max * rng.next_f64(),
                        });
                        t.live_factors += 1;
                    } else {
                        ops.push(ChurnOp::RemoveLive {
                            index: rng.next_below(t.live_factors as u64) as usize,
                        });
                        t.live_factors -= 1;
                    }
                }
                let id = t.id;
                events.push(TenantEvent::Apply { tenant: id, ops });
                events.push(TenantEvent::Sweep {
                    tenant: id,
                    n: config.sweeps_per_step,
                });
            }
        }
        TenantTrace { events }
    }

    /// Tenants still live at the end of the trace.
    pub fn survivors(&self) -> Vec<u64> {
        let mut live = Vec::new();
        for e in &self.events {
            match e {
                TenantEvent::Create { tenant, .. } => live.push(*tenant),
                TenantEvent::Drop { tenant } => live.retain(|t| t != tenant),
                _ => {}
            }
        }
        live
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{FactorGraph, FactorId};
    use crate::workloads::ChurnTrace;
    use std::collections::HashMap;

    #[test]
    fn trace_is_deterministic() {
        let a = TenantTrace::generate(TenantTraceConfig::default(), 42);
        let b = TenantTrace::generate(TenantTraceConfig::default(), 42);
        assert_eq!(a.events, b.events);
        let c = TenantTrace::generate(TenantTraceConfig::default(), 43);
        assert_ne!(a.events, c.events);
    }

    #[test]
    fn events_replay_validly_and_respect_the_population_cap() {
        let cfg = TenantTraceConfig {
            max_tenants: 6,
            steps: 500,
            ..Default::default()
        };
        let trace = TenantTrace::generate(cfg, 7);
        // replay every event against local per-tenant graphs; panics on
        // any invalid op (unknown tenant, bad RemoveLive index)
        let mut graphs: HashMap<u64, (FactorGraph, Vec<FactorId>)> = HashMap::new();
        let mut peak = 0usize;
        for e in &trace.events {
            match e {
                TenantEvent::Create { tenant, vars, .. } => {
                    assert!(*vars >= 2);
                    let prev = graphs.insert(*tenant, (FactorGraph::new(*vars), Vec::new()));
                    assert!(prev.is_none(), "tenant id reused");
                    peak = peak.max(graphs.len());
                }
                TenantEvent::Apply { tenant, ops } => {
                    let (g, live) = graphs.get_mut(tenant).expect("apply to live tenant");
                    for op in ops {
                        ChurnTrace::apply(g, live, op);
                    }
                }
                TenantEvent::Sweep { tenant, n } => {
                    assert!(graphs.contains_key(tenant), "sweep of live tenant");
                    assert!(*n > 0);
                }
                TenantEvent::Drop { tenant } => {
                    assert!(graphs.remove(tenant).is_some(), "drop of live tenant");
                }
            }
        }
        assert!(peak <= 6, "population cap violated: {peak}");
        assert!(!graphs.is_empty(), "trace must leave survivors");
        let mut survivors: Vec<u64> = graphs.keys().copied().collect();
        survivors.sort_unstable();
        let mut want = trace.survivors();
        want.sort_unstable();
        assert_eq!(survivors, want);
    }

    #[test]
    fn per_tenant_seeds_differ() {
        let trace = TenantTrace::generate(TenantTraceConfig::default(), 3);
        let mut seeds = Vec::new();
        for e in &trace.events {
            if let TenantEvent::Create { seed, .. } = e {
                seeds.push(*seed);
            }
        }
        assert!(seeds.len() > 1, "expected several arrivals");
        let n = seeds.len();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), n, "per-tenant seeds must be distinct");
    }
}
