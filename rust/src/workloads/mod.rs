//! Workload generators: the paper's three synthetic model families (§6),
//! the dynamic-churn traces motivating the method (§1), the multi-tenant
//! traffic traces driving the sharded coordinator, the statistical
//! validation scenario zoo ([`scenarios`]), and the image-denoising MRF
//! used by the end-to-end example.

mod churn;
mod denoise;
pub mod scenarios;
mod tenants;

pub use churn::{ChurnOp, ChurnTrace};
pub use denoise::{
    accuracy, denoise_mrf, label_accuracy, noisy_image, noisy_labels, render, render_labels,
    segmentation_mrf, synthetic_image, synthetic_labels, DenoiseConfig,
};
pub use scenarios::{Regime, Scenario};
pub use tenants::{
    replay_trace_over_socket, run_net_load, NetLoadConfig, NetLoadReport, TenantEvent,
    TenantTrace, TenantTraceConfig,
};

use crate::graph::{FactorGraph, PairFactor};
use crate::rng::{Pcg64, RngCore};

/// §6 model 1: `rows × cols` Ising grid with uniform coupling `beta` and
/// uniform unary field `h` (log-odds).
pub fn ising_grid(rows: usize, cols: usize, beta: f64, h: f64) -> FactorGraph {
    let mut g = FactorGraph::new(rows * cols);
    for v in 0..rows * cols {
        g.set_unary(v, h);
    }
    let idx = |r: usize, c: usize| r * cols + c;
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                g.add_factor(PairFactor::ising(idx(r, c), idx(r, c + 1), beta));
            }
            if r + 1 < rows {
                g.add_factor(PairFactor::ising(idx(r, c), idx(r + 1, c), beta));
            }
        }
    }
    g
}

/// K-state Potts grid: `rows × cols` variables of cardinality `k` with
/// uniform Potts coupling `beta` (agreement bonus `e^β` on the diagonal)
/// on the 4-neighbor lattice. K-state models carry no unary terms — the
/// indicator dual keeps the base field zero
/// ([`crate::duality::DualModel`] docs).
pub fn potts_grid(rows: usize, cols: usize, k: usize, beta: f64) -> FactorGraph {
    let mut g = FactorGraph::new_k(rows * cols, k);
    let idx = |r: usize, c: usize| r * cols + c;
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                g.add_factor(PairFactor::potts(idx(r, c), idx(r, c + 1), beta));
            }
            if r + 1 < rows {
                g.add_factor(PairFactor::potts(idx(r, c), idx(r + 1, c), beta));
            }
        }
    }
    g
}

/// Seeded evidence set: `count` distinct sites of an `n`-variable,
/// `k`-state model, each clamped to a uniformly drawn state. The serving
/// scenario in miniature — every user request conditions a shared tenant
/// model on a different evidence set.
pub fn evidence_set(n: usize, k: usize, count: usize, seed: u64) -> Vec<(usize, u8)> {
    assert!(count <= n, "cannot clamp {count} of {n} sites");
    let mut rng = Pcg64::seed(seed);
    let mut taken = vec![false; n];
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let v = loop {
            let v = rng.next_below(n as u64) as usize;
            if !taken[v] {
                break v;
            }
        };
        taken[v] = true;
        out.push((v, rng.next_below(k as u64) as u8));
    }
    out
}

/// §6 model 2: random graph with `n` variables and `k·n` factors; unary and
/// pairwise log-potentials drawn `N(0, σ²)` with `σ = 1` in the paper.
///
/// Each factor's 2×2 table is `exp` of iid normal log-potentials; endpoints
/// are a uniform random (distinct) pair. Matches "both the unitary and
/// pairwise log-potentials were sampled from a normal distribution with
/// mean 0 and standard deviation of 1".
pub fn random_graph(n: usize, k: usize, sigma: f64, seed: u64) -> FactorGraph {
    let mut rng = Pcg64::seed(seed);
    let mut g = FactorGraph::new(n);
    for v in 0..n {
        g.set_unary(v, sigma * rng.normal());
    }
    for _ in 0..k * n {
        let v1 = rng.next_below(n as u64) as usize;
        let v2 = loop {
            let v = rng.next_below(n as u64) as usize;
            if v != v1 {
                break v;
            }
        };
        let t = [
            [(sigma * rng.normal()).exp(), (sigma * rng.normal()).exp()],
            [(sigma * rng.normal()).exp(), (sigma * rng.normal()).exp()],
        ];
        g.add_factor(PairFactor::new(v1, v2, t));
    }
    g
}

/// §6 model 3: fully connected Ising over `n` variables. `beta(i, j)` gives
/// the coupling of each pair; the paper uses uniform β ∈ [0.01, 0.015] and
/// notes that *varying* couplings break the poly-time special case of
/// Flach (2013), so the bench also exercises a jittered variant.
pub fn fully_connected_ising(n: usize, beta: impl Fn(usize, usize) -> f64) -> FactorGraph {
    let mut g = FactorGraph::new(n);
    for i in 0..n {
        for j in i + 1..n {
            g.add_factor(PairFactor::ising(i, j, beta(i, j)));
        }
    }
    g
}

/// Fully connected Ising with couplings jittered uniformly in
/// `[beta·(1−jitter), beta·(1+jitter)]` (seeded).
pub fn fully_connected_jittered(n: usize, beta: f64, jitter: f64, seed: u64) -> FactorGraph {
    let mut rng = Pcg64::seed(seed);
    let mut couplings = Vec::with_capacity(n * (n - 1) / 2);
    for _ in 0..n * (n - 1) / 2 {
        couplings.push(beta * (1.0 + jitter * (2.0 * rng.next_f64() - 1.0)));
    }
    let mut it = couplings.into_iter();
    let mut g = FactorGraph::new(n);
    for i in 0..n {
        for j in i + 1..n {
            g.add_factor(PairFactor::ising(i, j, it.next().unwrap()));
        }
    }
    g
}

/// Heavy-tailed power-law MRF: `edges` pair factors whose endpoints are
/// drawn from a zipf(`gamma`) rank distribution (variable 0 is the most
/// probable endpoint, so it becomes a massive hub), self-loops rejected
/// by resampling. Couplings are degree-scaled in a second pass:
/// `β_e = ±beta0 / max(deg(u), deg(v))` with a random sign, so every
/// variable's total coupling strength `Σ_e |β_e|` stays bounded by
/// `beta0` regardless of its degree. That bound is exactly the regime
/// minibatched sweeps are built for: the per-site subsampling rate
/// `λ + L` stays O(1) while hub degrees grow without limit — see
/// [`crate::engine::SweepPolicy::Minibatch`] and
/// `benches/throughput.rs --mode minibatch`.
pub fn power_law_graph(n: usize, edges: usize, gamma: f64, beta0: f64, seed: u64) -> FactorGraph {
    power_law_graph_k(n, edges, gamma, beta0, 2, seed)
}

/// K-state sibling of [`power_law_graph`]: the same zipf endpoint draw
/// and degree-scaled mixed-sign couplings over `k`-state variables, with
/// Potts factors for `k > 2` and the original Ising tables at `k = 2`
/// (identical RNG consumption either way, so the edge set is
/// seed-stable across cardinalities). This is the tenant the `--k` flag
/// of `benches/throughput.rs --mode minibatch` sweeps.
pub fn power_law_graph_k(
    n: usize,
    edges: usize,
    gamma: f64,
    beta0: f64,
    k: usize,
    seed: u64,
) -> FactorGraph {
    assert!(n >= 2, "need two variables for a pair factor");
    let mut rng = Pcg64::seed(seed);
    // cumulative zipf(γ) mass over ranks (variable i has rank i)
    let mut cum = Vec::with_capacity(n);
    let mut total = 0.0f64;
    for i in 0..n {
        total += ((i + 1) as f64).powf(-gamma);
        cum.push(total);
    }
    let pick = |rng: &mut Pcg64| -> usize {
        let u = rng.next_f64() * total;
        cum.partition_point(|&c| c <= u).min(n - 1)
    };
    // pass 1: endpoints first, so pass 2 can see final degrees
    let mut ends = Vec::with_capacity(edges);
    let mut deg = vec![0u32; n];
    for _ in 0..edges {
        let v1 = pick(&mut rng);
        let v2 = loop {
            let v = pick(&mut rng);
            if v != v1 {
                break v;
            }
        };
        deg[v1] += 1;
        deg[v2] += 1;
        ends.push((v1, v2));
    }
    // pass 2: degree-scaled mixed-sign couplings bound Σ|β| per site
    let mut g = FactorGraph::new_k(n, k);
    for (v1, v2) in ends {
        let scale = deg[v1].max(deg[v2]) as f64;
        let sign = if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
        let beta = sign * beta0 / scale;
        if k == 2 {
            g.add_factor(PairFactor::ising(v1, v2, beta));
        } else {
            g.add_factor(PairFactor::potts(v1, v2, beta));
        }
    }
    g
}

/// A random chain/tree-structured MRF (exactly solvable; used to validate
/// samplers and BP against enumeration on larger `n`).
pub fn random_tree(n: usize, sigma: f64, seed: u64) -> FactorGraph {
    let mut rng = Pcg64::seed(seed);
    let mut g = FactorGraph::new(n);
    for v in 0..n {
        g.set_unary(v, sigma * rng.normal());
    }
    for v in 1..n {
        let parent = rng.next_below(v as u64) as usize;
        let t = [
            [(sigma * rng.normal()).exp(), (sigma * rng.normal()).exp()],
            [(sigma * rng.normal()).exp(), (sigma * rng.normal()).exp()],
        ];
        g.add_factor(PairFactor::new(parent, v, t));
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_counts() {
        let g = ising_grid(50, 50, 0.3, 0.0);
        assert_eq!(g.num_vars(), 2500);
        assert_eq!(g.num_factors(), 2 * 50 * 49); // 4900
        assert_eq!(g.max_degree(), 4);
    }

    #[test]
    fn potts_grid_counts_and_cardinality() {
        let g = potts_grid(3, 3, 3, 0.8);
        assert_eq!(g.num_vars(), 9);
        assert_eq!(g.k(), 3);
        assert_eq!(g.num_factors(), 12);
        assert_eq!(g.max_degree(), 4);
        for (_, f) in g.factors() {
            assert!((f.potts_beta() - 0.8).abs() < 1e-12);
        }
    }

    #[test]
    fn evidence_set_is_distinct_in_range_and_seeded() {
        let ev = evidence_set(9, 3, 4, 17);
        assert_eq!(ev.len(), 4);
        let mut sites: Vec<_> = ev.iter().map(|&(v, _)| v).collect();
        sites.sort_unstable();
        sites.dedup();
        assert_eq!(sites.len(), 4, "sites must be distinct");
        assert!(ev.iter().all(|&(v, s)| v < 9 && s < 3));
        assert_eq!(ev, evidence_set(9, 3, 4, 17), "seeded determinism");
        assert_ne!(ev, evidence_set(9, 3, 4, 18));
    }

    #[test]
    fn random_graph_counts() {
        let g = random_graph(1000, 2, 1.0, 7);
        assert_eq!(g.num_vars(), 1000);
        assert_eq!(g.num_factors(), 2000);
    }

    #[test]
    fn random_graph_deterministic_by_seed() {
        let a = random_graph(50, 3, 1.0, 9);
        let b = random_graph(50, 3, 1.0, 9);
        for ((_, fa), (_, fb)) in a.factors().zip(b.factors()) {
            assert_eq!(fa, fb);
        }
        assert_ne!(
            random_graph(50, 3, 1.0, 9).factors().next().map(|(_, f)| f.table),
            random_graph(50, 3, 1.0, 10).factors().next().map(|(_, f)| f.table)
        );
    }

    #[test]
    fn fully_connected_counts() {
        let g = fully_connected_ising(100, |_, _| 0.012);
        assert_eq!(g.num_factors(), 100 * 99 / 2);
        assert_eq!(g.max_degree(), 99);
    }

    #[test]
    fn jittered_in_band() {
        let g = fully_connected_jittered(20, 0.012, 0.2, 3);
        for (_, f) in g.factors() {
            let beta = f.table[0][0].ln();
            assert!(beta >= 0.012 * 0.8 - 1e-12 && beta <= 0.012 * 1.2 + 1e-12);
        }
    }

    #[test]
    fn power_law_graph_is_heavy_tailed_with_bounded_coupling() {
        let g = power_law_graph(2000, 8000, 1.8, 0.8, 5);
        assert_eq!(g.num_vars(), 2000);
        assert_eq!(g.num_factors(), 8000);
        let mut deg = vec![0usize; 2000];
        let mut l1 = vec![0.0f64; 2000];
        let (mut pos, mut neg) = (0usize, 0usize);
        for (_, f) in g.factors() {
            assert_ne!(f.v1, f.v2, "self-loops must be rejected");
            let beta = f.table[0][0].ln();
            if beta > 0.0 {
                pos += 1;
            } else {
                neg += 1;
            }
            deg[f.v1] += 1;
            deg[f.v2] += 1;
            l1[f.v1] += beta.abs();
            l1[f.v2] += beta.abs();
        }
        // zipf head: variable 0 is a hub far beyond the rank-1000 tail
        assert!(deg[0] > 1000, "hub degree {} not heavy-tailed", deg[0]);
        assert!(deg[0] > 50 * deg[1000].max(1), "{} vs {}", deg[0], deg[1000]);
        // degree scaling keeps every site's total coupling below beta0
        for (v, &l) in l1.iter().enumerate() {
            assert!(l <= 0.8 + 1e-9, "site {v}: Σ|β| = {l} exceeds β0");
        }
        assert!(pos > 0 && neg > 0, "signs must mix: {pos}+/{neg}-");
        // deterministic by seed
        let h = power_law_graph(2000, 8000, 1.8, 0.8, 5);
        for ((_, fa), (_, fb)) in g.factors().zip(h.factors()) {
            assert_eq!(fa, fb);
        }
    }

    #[test]
    fn power_law_graph_k_shares_the_edge_set_across_cardinalities() {
        let g2 = power_law_graph(300, 900, 1.8, 0.8, 5);
        for k in [3usize, 8] {
            let gk = power_law_graph_k(300, 900, 1.8, 0.8, k, 5);
            assert_eq!(gk.k(), k);
            assert_eq!(gk.num_factors(), 900);
            let mut l1 = vec![0.0f64; 300];
            for ((_, f2), (_, fk)) in g2.factors().zip(gk.factors()) {
                assert_eq!((f2.v1, f2.v2), (fk.v1, fk.v2), "edge set drifted");
                // same signed coupling magnitude, Potts-encoded
                assert!((fk.potts_beta() - f2.table[0][0].ln()).abs() < 1e-12);
                l1[fk.v1] += fk.potts_beta().abs();
                l1[fk.v2] += fk.potts_beta().abs();
            }
            for (v, &l) in l1.iter().enumerate() {
                assert!(l <= 0.8 + 1e-9, "site {v}: Σ|β| = {l} exceeds β0");
            }
        }
    }

    #[test]
    fn tree_is_acyclic() {
        let g = random_tree(40, 1.0, 11);
        assert_eq!(g.num_factors(), 39);
        // acyclic <=> union-find never joins an already-connected pair
        let mut uf = crate::util::UnionFind::new(40);
        for (_, f) in g.factors() {
            assert!(uf.union(f.v1, f.v2), "cycle at {:?}", (f.v1, f.v2));
        }
    }
}
