//! The statistical-validation scenario zoo: small exactly-solvable models
//! spanning every regime the paper argues about, each with a documented
//! autocorrelation-time bound so the exactness gates
//! ([`crate::validation`]) can thin correctly.
//!
//! Coverage axes:
//!
//! * **Coupling strength** — the paper's method is pitched at *weakly
//!   coupled* models; every topology appears at β below, at, and/or
//!   above the 2D-Ising critical coupling `β_c = ln(1+√2)/2 ≈ 0.44`
//!   (the natural "weak coupling boundary" for these Ising-table
//!   workloads). Above it the PD chain still targets the exact
//!   stationary distribution — it just mixes slower, which the gates
//!   absorb through larger [`Scenario::tau`] bounds.
//! * **Topology** — chains (sparse, 2-colorable), a 3×3 grid (the
//!   paper's §6 grid family in miniature), a triangle (smallest odd
//!   cycle), and dense `K_n` models where the chromatic number equals
//!   `n` — the no-small-coloring motivation (Fig 2b) where chromatic
//!   Gibbs degenerates to sequential.
//! * **Churn** — op sequences crossing the engine's degree-6
//!   x-table-cache cap in both directions, so the gates also certify
//!   the post-churn distribution (a stale cached conditional is exactly
//!   the bug class bit-identity tests cannot see).
//! * **Cardinality and evidence** — K-state Potts grids below and above
//!   the 3-state critical coupling `ln(1+√3) ≈ 1.005` exercise the
//!   indicator dual, and a clamped-endpoints chain gates every path
//!   against the *conditional* law (the serving scenario: each request
//!   is an evidence set on a shared tenant).
//!
//! `tau` bounds were precomputed by measuring the PD sampler's
//! integrated autocorrelation time of magnetization (the slowest
//! monitored statistic) on each model and doubling it; the PD sampler is
//! the slowest-mixing path the zoo drives (the paper's "inferior mixing"
//! trade-off), so its bound covers every other path. The derivation is
//! documented in `docs/TESTING.md`.

use crate::graph::{FactorGraph, PairFactor};
use crate::workloads::{ChurnOp, ChurnTrace};

/// 2D-Ising critical coupling `ln(1+√2)/2` — the zoo's "weak coupling
/// boundary" reference point.
pub const BETA_CRITICAL: f64 = 0.44068679350977147;

/// Where a scenario's coupling sits relative to [`BETA_CRITICAL`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Regime {
    /// Comfortably weak coupling (fast mixing; the paper's home turf).
    Below,
    /// At the critical boundary.
    At,
    /// Strong coupling (slow but still exact mixing).
    Above,
}

/// One validation scenario: a base model, optional churn, and the gate
/// parameters precomputed for it.
pub struct Scenario {
    /// Stable identifier used in reports and test names.
    pub name: &'static str,
    /// Coupling regime relative to [`BETA_CRITICAL`].
    pub regime: Regime,
    /// The base model every path starts from.
    pub graph: FactorGraph,
    /// Churn applied mid-run (empty = static scenario). Ops follow the
    /// tenant live-list convention: the list indexed by
    /// [`ChurnOp::RemoveLive`] starts as the base graph's factors in
    /// iteration order.
    pub churn: Vec<ChurnOp>,
    /// Precomputed integrated-autocorrelation-time bound (in sweeps) of
    /// the slowest path on the *final* model — the harness's thinning
    /// stride.
    pub tau: usize,
    /// States per variable (2 = binary Ising; matches `graph.k()`).
    pub k: usize,
    /// Evidence `(site, state)` pairs every path clamps before the gates
    /// run (empty = unconditioned scenario). Conditioned scenarios are
    /// gated against the clamped conditional law via
    /// [`crate::validation::validate_conditioned`].
    pub evidence: Vec<(usize, u8)>,
}

impl Scenario {
    /// The model the paths sample *after* churn — what the gates compare
    /// against. Identical to the base graph for static scenarios.
    pub fn final_graph(&self) -> FactorGraph {
        let mut g = self.graph.clone();
        let mut live: Vec<usize> = g.factors().map(|(id, _)| id).collect();
        for op in &self.churn {
            ChurnTrace::apply(&mut g, &mut live, op);
        }
        g
    }

    /// Whether every factor (of the final graph) is ferromagnetic
    /// *binary* Ising — the applicability condition of Swendsen–Wang.
    /// K-state Potts tables share the agreement-bonus shape but not the
    /// binary state space, so `k > 2` scenarios are excluded.
    pub fn is_ferromagnetic(&self) -> bool {
        self.k == 2
            && self
                .final_graph()
                .factors()
                .all(|(_, f)| crate::duality::sw::ising_w_from_table(&f.table).is_some())
    }
}

/// An `n`-variable Ising chain (path graph) with uniform coupling and
/// field — the sparsest zoo topology. A named view of the degenerate
/// 1-row [`crate::workloads::ising_grid`] (same variables, same factor
/// ids, same couplings).
pub fn ising_chain(n: usize, beta: f64, h: f64) -> FactorGraph {
    crate::workloads::ising_grid(1, n, beta, h)
}

/// The 3-variable triangle — the smallest odd cycle (3-chromatic, the
/// smallest model a 2-coloring cannot serve).
pub fn triangle(beta: f64, h: f64) -> FactorGraph {
    let mut g = FactorGraph::new(3);
    for v in 0..3 {
        g.set_unary(v, h);
    }
    g.add_factor(PairFactor::ising(0, 1, beta));
    g.add_factor(PairFactor::ising(1, 2, beta));
    g.add_factor(PairFactor::ising(0, 2, beta));
    g
}

/// The hub-edge additions shared by both churn scenarios: six factors on
/// variable 0 of an 8-variable chain, driving its degree from 1 to 7 —
/// across the engine's degree-6 x-table-cache cap.
fn hub_adds() -> Vec<ChurnOp> {
    vec![
        ChurnOp::Add { v1: 0, v2: 2, beta: 0.20 },
        ChurnOp::Add { v1: 0, v2: 3, beta: 0.18 },
        ChurnOp::Add { v1: 0, v2: 4, beta: 0.15 },
        ChurnOp::Add { v1: 0, v2: 5, beta: 0.12 },
        ChurnOp::Add { v1: 0, v2: 6, beta: 0.10 },
        ChurnOp::Add { v1: 0, v2: 7, beta: 0.08 },
    ]
}

/// The full scenario zoo, in a stable order.
pub fn zoo() -> Vec<Scenario> {
    let mut scenarios = vec![
        Scenario {
            name: "chain8-below",
            regime: Regime::Below,
            graph: ising_chain(8, 0.2, 0.1),
            churn: Vec::new(),
            k: 2,
            evidence: Vec::new(),
            tau: 8,
        },
        Scenario {
            name: "chain8-at",
            regime: Regime::At,
            graph: ising_chain(8, BETA_CRITICAL, 0.05),
            churn: Vec::new(),
            k: 2,
            evidence: Vec::new(),
            tau: 20,
        },
        Scenario {
            name: "chain8-above",
            regime: Regime::Above,
            graph: ising_chain(8, 0.7, 0.05),
            churn: Vec::new(),
            k: 2,
            evidence: Vec::new(),
            tau: 48,
        },
        Scenario {
            name: "grid3x3-below",
            regime: Regime::Below,
            graph: crate::workloads::ising_grid(3, 3, 0.25, 0.1),
            churn: Vec::new(),
            k: 2,
            evidence: Vec::new(),
            tau: 16,
        },
        Scenario {
            name: "grid3x3-at",
            regime: Regime::At,
            graph: crate::workloads::ising_grid(3, 3, BETA_CRITICAL, 0.05),
            churn: Vec::new(),
            k: 2,
            evidence: Vec::new(),
            tau: 64,
        },
        // the adaptive-blocking home turf: above-critical grid where the
        // flat PD chain's lanes lock step and mix slowly — the blocked
        // lane paths register against this one (and the ESS/s bench's
        // ≥ 1.5× target is pinned on its larger sibling)
        Scenario {
            name: "grid3x3-above",
            regime: Regime::Above,
            graph: crate::workloads::ising_grid(3, 3, 0.6, 0.05),
            churn: Vec::new(),
            k: 2,
            evidence: Vec::new(),
            tau: 160,
        },
        Scenario {
            name: "triangle-above",
            regime: Regime::Above,
            graph: triangle(1.0, 0.2),
            churn: Vec::new(),
            k: 2,
            evidence: Vec::new(),
            tau: 200,
        },
        // K₁₀ with jittered couplings: chromatic number 10 (no small
        // coloring — the paper's Fig-2b motivation) and, per Flach
        // (2013), *varying* couplings also break the dense poly-time
        // special case. Per-site coupling mass ≈ 9·0.08 keeps it weak.
        Scenario {
            name: "kn10-dense",
            regime: Regime::Below,
            graph: crate::workloads::fully_connected_jittered(10, 0.08, 0.3, 41),
            churn: Vec::new(),
            k: 2,
            evidence: Vec::new(),
            tau: 20,
        },
        // K₁₂ in the paper's §6 uniform band β ∈ [0.01, 0.015].
        Scenario {
            name: "kn12-paper",
            regime: Regime::Below,
            graph: crate::workloads::fully_connected_ising(12, |_, _| 0.0125),
            churn: Vec::new(),
            k: 2,
            evidence: Vec::new(),
            tau: 4,
        },
    ];
    // churn: cross the degree-6 cap upward (hub ends at degree 7, on the
    // accumulate fallback) and also drop a mid-chain base factor (live
    // index 3 = edge 3–4) so removal invalidation is exercised too
    let mut up = hub_adds();
    up.insert(0, ChurnOp::RemoveLive { index: 3 });
    scenarios.push(Scenario {
        name: "churn-cross-up",
        regime: Regime::Below,
        graph: ising_chain(8, 0.3, 0.1),
        churn: up,
        k: 2,
        evidence: Vec::new(),
        tau: 16,
    });
    // churn: cross the cap upward then back down (hub ends at degree 3,
    // back on the cached-table path after having been above the cap).
    // After the six adds the live list is [7 base edges, 6 hub edges];
    // removing tail indices 12, 11, 10, 9 drops the 0–7, 0–6, 0–5, 0–4
    // hub edges, leaving 0–2 and 0–3.
    let mut down = hub_adds();
    down.extend([
        ChurnOp::RemoveLive { index: 12 },
        ChurnOp::RemoveLive { index: 11 },
        ChurnOp::RemoveLive { index: 10 },
        ChurnOp::RemoveLive { index: 9 },
    ]);
    scenarios.push(Scenario {
        name: "churn-cross-down",
        regime: Regime::Below,
        graph: ising_chain(8, 0.3, 0.1),
        churn: down,
        k: 2,
        evidence: Vec::new(),
        tau: 16,
    });
    // hub-heavy star (the power-law tenant in miniature): one degree-11
    // hub with mixed-sign couplings plus a rim cycle edge. This is the
    // scenario the minibatch lane paths register against — the hub sits
    // far above any reasonable minibatch degree threshold, and the churn
    // (remove a hub edge, re-add it with flipped sign, add a leaf-leaf
    // edge) exercises plan invalidation under the same gates.
    scenarios.push(Scenario {
        name: "hub12-minibatch",
        regime: Regime::Below,
        graph: hub_star(),
        churn: vec![
            ChurnOp::RemoveLive { index: 0 },
            ChurnOp::Add { v1: 0, v2: 1, beta: -0.18 },
            ChurnOp::Add { v1: 1, v2: 3, beta: 0.10 },
        ],
        k: 2,
        evidence: Vec::new(),
        tau: 16,
    });
    // K-state Potts: the §6 grid family at k = 3, below and above the
    // 3-state Potts critical coupling β_c = ln(1+√3) ≈ 1.005 — the
    // indicator-dual generalization under the same gates (3⁹ ≈ 20k
    // joint codes, inside the tabulation cap).
    scenarios.push(Scenario {
        name: "potts3-grid3x3-below",
        regime: Regime::Below,
        graph: crate::workloads::potts_grid(3, 3, 3, 0.5),
        churn: Vec::new(),
        k: 3,
        evidence: Vec::new(),
        tau: 16,
    });
    scenarios.push(Scenario {
        name: "potts3-grid3x3-above",
        regime: Regime::Above,
        graph: crate::workloads::potts_grid(3, 3, 3, 1.3),
        churn: Vec::new(),
        k: 3,
        evidence: Vec::new(),
        tau: 120,
    });
    // evidence clamping: the weak chain conditioned on both endpoints —
    // every path clamps x₀ = 1 and x₇ = 0 and is gated against the
    // conditional law over the six free sites. Conditioning shortens
    // correlations (the clamped ends act as boundary fields), so the
    // unconditioned chain8 tau bound is already conservative.
    scenarios.push(Scenario {
        name: "chain8-evidence",
        regime: Regime::Below,
        graph: ising_chain(8, 0.3, 0.1),
        churn: Vec::new(),
        k: 2,
        evidence: vec![(0, 1), (7, 0)],
        tau: 8,
    });
    // K-state × policy coverage: the minibatch lane paths register
    // against these hub-heavy Potts stars (one per bit-plane count
    // b ∈ {2, 3}), and the blocked lane paths against the above-critical
    // Potts models further down. Stars stay weakly coupled (hub Σ|β|
    // well under 1), so the chain8/hub12 tau scale carries over.
    // potts3-hub9: 8 mixed-sign hub edges + rim edge; churn mirrors
    // hub12-minibatch (drop a hub edge, re-add flipped, add leaf-leaf)
    // so K-state plan invalidation runs under the same gates.
    scenarios.push(Scenario {
        name: "potts3-hub9-minibatch",
        regime: Regime::Below,
        graph: potts_star(9, 3),
        churn: vec![
            ChurnOp::RemoveLive { index: 0 },
            ChurnOp::Add { v1: 0, v2: 1, beta: -0.14 },
            ChurnOp::Add { v1: 1, v2: 3, beta: 0.10 },
        ],
        k: 3,
        evidence: Vec::new(),
        tau: 16,
    });
    // potts5-hub6 holds evidence on a leaf: the *conditioned* minibatch
    // gate — corrected per-state fields must target the clamped
    // conditional law, not the free one.
    scenarios.push(Scenario {
        name: "potts5-hub6-minibatch",
        regime: Regime::Below,
        graph: potts_star(6, 5),
        churn: Vec::new(),
        k: 5,
        evidence: vec![(3, 4)],
        tau: 16,
    });
    // potts8-hub5: the full 3-bit-plane budget (8 = 2³) on the smallest
    // star whose hub (degree 4) still clears a threshold-3 plan;
    // 8⁵ = 32768 sits exactly at the joint-tabulation cap.
    scenarios.push(Scenario {
        name: "potts8-hub5-minibatch",
        regime: Regime::Below,
        graph: potts_star(5, 8),
        churn: Vec::new(),
        k: 8,
        evidence: Vec::new(),
        tau: 16,
    });
    // above-critical K-state models for the blocked paths: k = 5 and
    // k = 8 Potts critical couplings are ln(1+√5) ≈ 1.18 and
    // ln(1+√8) ≈ 1.34; these sit above, where joint tree draws matter.
    scenarios.push(Scenario {
        name: "potts5-grid2x3-above",
        regime: Regime::Above,
        graph: crate::workloads::potts_grid(2, 3, 5, 1.3),
        churn: Vec::new(),
        k: 5,
        evidence: Vec::new(),
        tau: 120,
    });
    // the conditioned blocked gate: a strongly-coupled 8-state chain
    // clamped at one end — FFBS tree draws must respect evidence both
    // as a dropped planner candidate and as a frozen boundary site.
    scenarios.push(Scenario {
        name: "potts8-chain5-above",
        regime: Regime::Above,
        graph: crate::workloads::potts_grid(1, 5, 8, 1.5),
        churn: Vec::new(),
        k: 8,
        evidence: vec![(0, 5)],
        tau: 96,
    });
    scenarios
}

/// An `n`-variable K-state Potts star: hub 0 with mixed-sign,
/// varied-magnitude couplings to every leaf, plus one rim edge closing
/// an odd cycle through the hub (so the topology is not a tree). Hub
/// Σ|β| < 1 keeps every cardinality in the weak regime; K-state graphs
/// carry no unary fields.
pub fn potts_star(n: usize, k: usize) -> FactorGraph {
    let mut g = FactorGraph::new_k(n, k);
    for leaf in 1..n {
        let mag = 0.10 + 0.02 * (leaf % 4) as f64;
        let beta = if leaf % 2 == 0 { -mag } else { mag };
        g.add_factor(PairFactor::potts(0, leaf, beta));
    }
    g.add_factor(PairFactor::potts(1, 2, 0.15));
    g
}

/// The `hub12-minibatch` base model: an 11-leaf star with mixed-sign,
/// varied-magnitude couplings (hub Σ|β| ≈ 1.6 — weak regime) and one rim
/// edge closing an odd cycle through the hub.
fn hub_star() -> FactorGraph {
    let mut g = FactorGraph::new(12);
    g.set_unary(0, 0.2);
    for leaf in 1..12 {
        let mag = 0.12 + 0.02 * (leaf % 4) as f64;
        let beta = if leaf % 2 == 0 { -mag } else { mag };
        g.add_factor(PairFactor::ising(0, leaf, beta));
    }
    g.add_factor(PairFactor::ising(1, 2, 0.15));
    g
}

/// Look up one zoo scenario by name (panics on unknown names — the zoo
/// is a fixed, code-reviewed set).
pub fn by_name(name: &str) -> Scenario {
    zoo()
        .into_iter()
        .find(|s| s.name == name)
        .unwrap_or_else(|| panic!("no scenario named '{name}'"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::coloring;

    #[test]
    fn zoo_is_gate_compatible() {
        // every scenario must fit the joint-tabulation cap and have sane
        // gate parameters
        let zoo = zoo();
        assert!(zoo.len() >= 10, "zoo shrank to {}", zoo.len());
        for s in &zoo {
            let g = s.final_graph();
            assert!(g.num_vars() >= 3 && g.num_vars() <= 14, "{}", s.name);
            assert!(s.tau >= 1, "{}", s.name);
            assert!(g.num_factors() > 0, "{}", s.name);
        }
        // names are unique
        let mut names: Vec<_> = zoo.iter().map(|s| s.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), zoo.len());
    }

    #[test]
    fn zoo_covers_all_regimes() {
        let zoo = zoo();
        for regime in [Regime::Below, Regime::At, Regime::Above] {
            assert!(
                zoo.iter().any(|s| s.regime == regime),
                "no scenario {regime:?} the weak-coupling boundary"
            );
        }
    }

    #[test]
    fn dense_scenarios_admit_no_small_coloring() {
        // the paper's motivation: K_n needs n colors, so chromatic
        // parallelism degenerates while PD still updates all sites at once
        let kn = by_name("kn10-dense");
        assert_eq!(coloring::greedy(&kn.graph).num_colors, 10);
        let kn = by_name("kn12-paper");
        assert_eq!(coloring::greedy(&kn.graph).num_colors, 12);
    }

    #[test]
    fn churn_scenarios_cross_the_table_cache_cap() {
        use crate::duality::DualModel;
        // up: hub degree ends at 7 (> 6: no cached x-table);
        // down: ends at 3 (≤ 6: cached again)
        let up = by_name("churn-cross-up");
        let g = up.final_graph();
        assert_eq!(g.degree(0), 7);
        assert!(DualModel::from_graph(&g).x_table(0).is_none());
        let down = by_name("churn-cross-down");
        let g = down.final_graph();
        assert_eq!(g.degree(0), 3);
        assert!(DualModel::from_graph(&g).x_table(0).is_some());
        // the mid-chain removal in cross-up landed on edge 3–4
        assert_eq!(up.final_graph().num_factors(), 6 + 6);
    }

    #[test]
    fn hub_scenario_is_hub_heavy_before_and_after_churn() {
        let s = by_name("hub12-minibatch");
        assert_eq!(s.graph.degree(0), 11, "base hub degree");
        let g = s.final_graph();
        assert_eq!(g.degree(0), 11, "churn re-adds the removed hub edge");
        // base: 11 star edges + 1 rim; churn: −1 removal, +2 additions
        assert_eq!(g.num_factors(), 13);
        // mixed signs on the hub (the minibatch alias table must carry
        // signed entries, not just magnitudes)
        let (mut pos, mut neg) = (0, 0);
        for (_, f) in g.factors() {
            if f.v1 == 0 || f.v2 == 0 {
                if f.table[0][0].ln() > 0.0 {
                    pos += 1;
                } else {
                    neg += 1;
                }
            }
        }
        assert!(pos > 0 && neg > 0, "{pos}+/{neg}-");
    }

    #[test]
    fn ferromagnetic_filter_matches_sw_applicability() {
        assert!(by_name("chain8-below").is_ferromagnetic());
        assert!(by_name("kn10-dense").is_ferromagnetic());
        assert!(by_name("churn-cross-up").is_ferromagnetic());
        // Potts tables have the agreement-bonus shape, but SW is binary
        assert!(!by_name("potts3-grid3x3-below").is_ferromagnetic());
    }

    #[test]
    fn kstate_and_evidence_scenarios_are_consistent() {
        for s in &zoo() {
            assert_eq!(s.k, s.graph.k(), "{}: k field drifted", s.name);
            assert_eq!(s.k, s.final_graph().k(), "{}: churn changed k", s.name);
            let states = (s.k as f64).powi(s.graph.num_vars() as i32);
            assert!(states <= 32768.0, "{} exceeds the joint cap", s.name);
            let mut seen = vec![false; s.graph.num_vars()];
            for &(v, st) in &s.evidence {
                assert!(
                    v < s.graph.num_vars() && (st as usize) < s.k,
                    "{}: evidence ({v}, {st}) out of range",
                    s.name
                );
                assert!(!seen[v], "{} clamps site {v} twice", s.name);
                seen[v] = true;
            }
            assert!(
                s.evidence.len() < s.graph.num_vars(),
                "{}: no free site left",
                s.name
            );
        }
        let p = by_name("potts3-grid3x3-above");
        assert_eq!(p.k, 3);
        assert_eq!(p.graph.num_factors(), 12);
        let e = by_name("chain8-evidence");
        assert_eq!(e.evidence, vec![(0, 1), (7, 0)]);
    }

    #[test]
    fn builders_shape() {
        let c = ising_chain(5, 0.3, -0.1);
        assert_eq!(c.num_vars(), 5);
        assert_eq!(c.num_factors(), 4);
        assert_eq!(c.max_degree(), 2);
        let t = triangle(0.5, 0.0);
        assert_eq!(t.num_vars(), 3);
        assert_eq!(t.num_factors(), 3);
        assert_eq!(coloring::greedy(&t).num_colors, 3);
    }

    #[test]
    #[should_panic(expected = "no scenario named")]
    fn unknown_scenario_panics() {
        by_name("does-not-exist");
    }
}
