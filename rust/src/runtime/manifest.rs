//! Artifact manifest parsing (`artifacts/manifest.json`).
//!
//! Written by `python/compile/aot.py`; describes every artifact's static
//! configuration so the runtime can marshal literals without re-deriving
//! the python-side padding rules.

use std::path::Path;

use crate::err;
use crate::util::error::{Context, Result};
use crate::util::json::Json;

/// Static configuration of one AOT artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    /// Workload name (manifest key, e.g. `grid50`).
    pub name: String,
    /// Artifact file name relative to the artifact directory.
    pub file: String,
    /// Real (unpadded) variable count.
    pub n: usize,
    /// Real (unpadded) factor capacity.
    pub f: usize,
    /// Chains advanced per executable call.
    pub chains: usize,
    /// Sweeps executed per call.
    pub sweeps: usize,
    /// Padded variable count (XLA static shape).
    pub n_pad: usize,
    /// Padded factor count (XLA static shape).
    pub f_pad: usize,
}

/// All artifacts produced by one `make artifacts` run.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// Artifact entries in manifest order.
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Read and parse a `manifest.json` file.
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let doc = Json::parse(text).map_err(|e| err!("manifest: {e}"))?;
        let arr = doc
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| err!("manifest missing 'artifacts' array"))?;
        let mut artifacts = Vec::with_capacity(arr.len());
        for item in arr {
            artifacts.push(ArtifactMeta {
                name: field_str(item, "name")?,
                file: field_str(item, "file")?,
                n: field_usize(item, "n")?,
                f: field_usize(item, "f")?,
                chains: field_usize(item, "chains")?,
                sweeps: field_usize(item, "sweeps")?,
                n_pad: field_usize(item, "n_pad")?,
                f_pad: field_usize(item, "f_pad")?,
            });
        }
        Ok(Manifest { artifacts })
    }

    /// Look up an artifact by workload name.
    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// All workload names, in manifest order.
    pub fn names(&self) -> Vec<&str> {
        self.artifacts.iter().map(|a| a.name.as_str()).collect()
    }

    /// Smallest artifact that fits a model with `n` vars and `f` factors.
    pub fn best_fit(&self, n: usize, f: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| a.n_pad >= n && a.f_pad >= f)
            .min_by_key(|a| a.n_pad * a.f_pad)
    }
}

fn field_str(j: &Json, key: &str) -> Result<String> {
    j.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| err!("manifest entry missing string '{key}'"))
}

fn field_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| err!("manifest entry missing integer '{key}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": [
        {"name": "grid16", "file": "pd_chain_grid16.hlo.txt",
         "n": 256, "f": 480, "chains": 4, "sweeps": 8,
         "n_pad": 256, "f_pad": 512,
         "operands": [], "outputs": []},
        {"name": "fc100", "file": "pd_chain_fc100.hlo.txt",
         "n": 100, "f": 4950, "chains": 10, "sweeps": 32,
         "n_pad": 104, "f_pad": 5120,
         "operands": [], "outputs": []}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let g = m.get("grid16").unwrap();
        assert_eq!(g.n, 256);
        assert_eq!(g.f_pad, 512);
        assert_eq!(m.names(), vec!["grid16", "fc100"]);
    }

    #[test]
    fn best_fit_picks_smallest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.best_fit(100, 400).unwrap().name, "grid16");
        assert_eq!(m.best_fit(100, 4000).unwrap().name, "fc100");
        assert!(m.best_fit(10_000, 1).is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"artifacts": [{"name": 3}]}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
    }
}
