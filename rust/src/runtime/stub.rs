//! Offline stand-in for the PJRT runtime (default build; see [`super`]).
//!
//! Presents the same API as the real `pjrt.rs` so every caller — CLI,
//! benches, the dispatch policy — compiles unchanged, and fails only at
//! [`Runtime::load`] with an actionable message. Nothing here can execute
//! an artifact; the coordinator's native sparse path is the fallback.

use std::path::Path;

use crate::duality::model::DenseOperands;
use crate::err;
use crate::util::error::Result;

use super::{ArtifactMeta, ChainState, ChunkOutput, Manifest};

const UNAVAILABLE: &str = "pdgibbs was built without the `xla` feature; the PJRT \
     artifact runtime is unavailable (rebuild with `--features xla` in an \
     environment that provides the vendored `xla` crate)";

/// Stub registry: construction always fails, so the remaining methods are
/// unreachable in practice but keep call sites type-checking.
pub struct Runtime {
    manifest: Manifest,
}

impl Runtime {
    /// Always errors in the default (offline) build.
    pub fn load(_dir: impl AsRef<Path>) -> Result<Self> {
        Err(err!("{UNAVAILABLE}"))
    }

    /// Empty manifest (the stub never loads artifacts).
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Placeholder platform string for reports.
    pub fn platform(&self) -> String {
        "unavailable (built without `xla`)".to_string()
    }

    /// Mirrors the real runtime's compile entry point; always errors.
    pub fn executable(&self, name: &str) -> Result<()> {
        Err(err!("cannot compile artifact '{name}': {UNAVAILABLE}"))
    }

    /// Mirrors the real runtime's bind entry point; always errors.
    pub fn chain_exec(&self, name: &str, _ops: &DenseOperands) -> Result<PdChainExec> {
        Err(err!("cannot bind artifact '{name}': {UNAVAILABLE}"))
    }
}

/// Stub executor (never constructed: [`Runtime::chain_exec`] always errors).
pub struct PdChainExec {
    meta: ArtifactMeta,
}

impl PdChainExec {
    /// The bound artifact's static configuration.
    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// Fresh all-zeros chain state (same layout contract as the real path).
    pub fn zero_state(&self) -> ChainState {
        ChainState {
            x: vec![0.0; self.meta.chains * self.meta.n_pad],
            theta: vec![0.0; self.meta.chains * self.meta.f_pad],
        }
    }

    /// Mirrors the real executor's run entry point; always errors.
    pub fn run(&self, _state: &ChainState, _key: [u32; 2]) -> Result<ChunkOutput> {
        Err(err!("{UNAVAILABLE}"))
    }

    /// Mean of x over real (unpadded) variables for one chain row.
    pub fn magnetization(&self, x: &[f32], chain: usize) -> f32 {
        let m = &self.meta;
        let row = &x[chain * m.n_pad..chain * m.n_pad + m.n];
        row.iter().sum::<f32>() / m.n as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_missing_feature() {
        let e = Runtime::load("artifacts").unwrap_err();
        assert!(format!("{e}").contains("xla"), "unhelpful error: {e}");
    }
}
