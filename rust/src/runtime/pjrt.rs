//! The real PJRT-backed runtime (`--features xla` only; see module docs in
//! [`super`]). Compiles HLO-text artifacts on a CPU PJRT client and runs
//! multi-sweep chain chunks.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::duality::model::DenseOperands;
use crate::util::error::{Context, Result};
use crate::{ensure, err};

use super::{ArtifactMeta, ChainState, ChunkOutput, Manifest};

/// Lazily-compiled registry of artifacts on one PJRT client.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// CPU-backed runtime over an artifact directory produced by
    /// `python -m compile.aot`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| err!("pjrt cpu client: {e:?}"))?;
        Ok(Self {
            client,
            dir,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// The artifact manifest this runtime was loaded with.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (e.g. `cpu`, `tpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) the executable for an artifact.
    pub fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(Arc::clone(exe));
        }
        let meta = self
            .manifest
            .get(name)
            .ok_or_else(|| err!("unknown artifact '{name}'"))?;
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .map_err(|e| err!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .map_err(|e| err!("compile {name}: {e:?}"))?,
        );
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), Arc::clone(&exe));
        Ok(exe)
    }

    /// Bind an artifact + dense operands into a runnable chain executor.
    pub fn chain_exec(&self, name: &str, ops: &DenseOperands) -> Result<PdChainExec> {
        let meta = self
            .manifest
            .get(name)
            .ok_or_else(|| err!("unknown artifact '{name}'"))?
            .clone();
        ensure!(
            ops.n_pad == meta.n_pad && ops.f_pad == meta.f_pad,
            "operand padding ({}, {}) does not match artifact '{name}' ({}, {})",
            ops.n_pad,
            ops.f_pad,
            meta.n_pad,
            meta.f_pad
        );
        let exe = self.executable(name)?;
        Ok(PdChainExec {
            exe,
            meta,
            j: lit2(&ops.j, ops.f_pad, ops.n_pad)?,
            a: lit2(&ops.a, 1, ops.n_pad)?,
            q: lit1(&ops.q),
            b1: lit1(&ops.b1),
            b2: lit1(&ops.b2),
            v1: lit1(&ops.v1),
            v2: lit1(&ops.v2),
        })
    }
}

fn lit1<T: xla::NativeType>(v: &[T]) -> xla::Literal {
    xla::Literal::vec1(v)
}

fn lit2<T: xla::NativeType>(v: &[T], rows: usize, cols: usize) -> Result<xla::Literal> {
    ensure!(v.len() == rows * cols, "shape mismatch");
    xla::Literal::vec1(v)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| err!("reshape: {e:?}"))
}

/// One artifact bound to one model's operands: runs multi-sweep chunks.
pub struct PdChainExec {
    exe: Arc<xla::PjRtLoadedExecutable>,
    meta: ArtifactMeta,
    j: xla::Literal,
    a: xla::Literal,
    q: xla::Literal,
    b1: xla::Literal,
    b2: xla::Literal,
    v1: xla::Literal,
    v2: xla::Literal,
}

impl PdChainExec {
    /// The bound artifact's static configuration.
    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// Fresh all-zeros chain state.
    pub fn zero_state(&self) -> ChainState {
        ChainState {
            x: vec![0.0; self.meta.chains * self.meta.n_pad],
            theta: vec![0.0; self.meta.chains * self.meta.f_pad],
        }
    }

    /// Execute one chunk of `meta.sweeps` sweeps for all chains.
    ///
    /// `key` seeds the artifact's internal threefry stream — pass a fresh
    /// pair per call (the coordinator derives them from its PCG).
    pub fn run(&self, state: &ChainState, key: [u32; 2]) -> Result<ChunkOutput> {
        let m = &self.meta;
        ensure!(state.x.len() == m.chains * m.n_pad, "bad x len");
        ensure!(state.theta.len() == m.chains * m.f_pad, "bad theta len");
        let x = lit2(&state.x, m.chains, m.n_pad)?;
        let theta = lit2(&state.theta, m.chains, m.f_pad)?;
        let key_lit = lit1(&key[..]);
        // execute takes Borrow<Literal>: pass references so the static
        // operands (J is ~50 MB at grid50 scale) are never re-cloned on
        // the hot path (§Perf L3 iteration 2).
        let args: [&xla::Literal; 10] = [
            &x, &theta, &self.j, &self.a, &self.q, &self.b1, &self.b2, &self.v1, &self.v2,
            &key_lit,
        ];
        let result = self
            .exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| err!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| err!("fetch: {e:?}"))?;
        // jax lowered with return_tuple=True: a 4-tuple
        let parts = result.to_tuple().map_err(|e| err!("untuple: {e:?}"))?;
        ensure!(parts.len() == 4, "expected 4 outputs, got {}", parts.len());
        let get = |lit: &xla::Literal| -> Result<Vec<f32>> {
            lit.to_vec::<f32>().map_err(|e| err!("to_vec: {e:?}"))
        };
        Ok(ChunkOutput {
            state: ChainState {
                x: get(&parts[0])?,
                theta: get(&parts[1])?,
            },
            sum_x: get(&parts[2])?,
            mag: get(&parts[3])?,
        })
    }

    /// Mean of x over real (unpadded) variables for one chain row.
    pub fn magnetization(&self, x: &[f32], chain: usize) -> f32 {
        let m = &self.meta;
        let row = &x[chain * m.n_pad..chain * m.n_pad + m.n];
        row.iter().sum::<f32>() / m.n as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need artifacts live in rust/tests/runtime_e2e.rs
    // (they require `make artifacts` to have run). Here: manifest-free units.

    #[test]
    fn lit2_rejects_bad_shape() {
        assert!(lit2(&[1.0f32, 2.0, 3.0], 2, 2).is_err());
        assert!(lit2(&[1.0f32, 2.0, 3.0, 4.0], 2, 2).is_ok());
    }
}
