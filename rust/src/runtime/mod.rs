//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! `make artifacts` (build-time Python) lowers the L2 model — whose
//! x-update is the L1 Pallas kernel — to HLO *text* plus a JSON manifest.
//! This module is the only place the `xla` crate is touched:
//!
//!   [`Runtime::load`] → parse manifest → [`Runtime::executable`] compiles
//!   (and caches) one PJRT executable per artifact → [`PdChainExec::run`]
//!   marshals literals, executes a multi-sweep chunk, and unmarshals the
//!   chain state + sufficient statistics.
//!
//! Python never runs here; the rust binary is self-contained once
//! `artifacts/` exists.
//!
//! ## Feature gating
//!
//! The `xla` crate is vendored, not on crates.io, and absent from the
//! offline build image — so the real implementation (`pjrt.rs`) only
//! compiles under `--features xla`. The default build substitutes
//! `stub.rs`: the same API surface, with [`Runtime::load`] returning an
//! explanatory error so every caller degrades gracefully (the coordinator
//! and benches fall back to the native sparse samplers).

mod manifest;

pub use manifest::{ArtifactMeta, Manifest};

/// Chain state carried between chunked executions.
#[derive(Clone, Debug)]
pub struct ChainState {
    /// `(chains, n_pad)` row-major, values in {0., 1.}.
    pub x: Vec<f32>,
    /// `(chains, f_pad)` row-major.
    pub theta: Vec<f32>,
}

/// Outputs of one multi-sweep chunk.
#[derive(Clone, Debug)]
pub struct ChunkOutput {
    /// Final packed chain state after the chunk.
    pub state: ChainState,
    /// `(chains, n_pad)`: Σ over the chunk's sweeps of x.
    pub sum_x: Vec<f32>,
    /// `(sweeps, chains)`: per-sweep magnetization (mean over real vars).
    pub mag: Vec<f32>,
}

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::{PdChainExec, Runtime};

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::{PdChainExec, Runtime};
