//! [`CsrIncidence`]: the flat incidence arena behind the sampler hot path.
//!
//! [`super::DualModel`] keeps its nested `Vec<Vec<(slot, β)>>` incidence as
//! the *reference* structure — easy to mutate, easy to reason about — and
//! mirrors it here as contiguous arrays (`off` / `slot` / `beta`, classic
//! CSR) so a sweep walks one cache-friendly arena instead of
//! pointer-chasing one heap allocation per variable.
//!
//! Dynamic churn must stay O(degree) amortized (the paper's "almost no
//! preprocessing" claim), so mutations never rewrite the arena globally:
//!
//! * **insert** appends to a small per-variable *delta overlay* (base
//!   segments cannot grow in place);
//! * **remove** swap-compacts *within* the variable's base segment — the
//!   removed entry swaps with the segment's last live entry and the
//!   per-variable live length shrinks, exactly the `swap_remove` the
//!   nested reference performs — or drops the entry from the overlay.
//!   Views therefore never contain dead entries; freed cells are only
//!   *slack* (unused tail capacity) awaiting compaction;
//! * once slack + overlay outgrow a fraction of the arena, the owner
//!   triggers a **compaction**: one O(E) rebuild from the reference
//!   incidence, bumping [`CsrIncidence::epoch`]. Between compactions every
//!   read is the live base slice plus the (usually empty) overlay slice.
//!
//! Churned variables are tracked with a per-variable dirty flag (deduped,
//! so the bookkeeping stays O(vars) however long a steady churn run gets);
//! compaction reorders exactly those views, and owners refresh derived
//! caches for them alone.

/// Flat CSR incidence with a delta overlay (see module docs).
#[derive(Clone, Debug, Default)]
pub struct CsrIncidence {
    /// `off[v]` is the start of base variable `v`'s segment; the segment's
    /// *capacity* runs to `off[v + 1]`, its live prefix to
    /// `off[v] + base_live[v]`. Variables added after the last rebuild
    /// have no base segment.
    off: Vec<u32>,
    /// Live prefix length of each base segment (shrinks on remove).
    base_live: Vec<u32>,
    slot: Vec<u32>,
    beta: Vec<f64>,
    /// Per-variable entries inserted since the last rebuild.
    overlay: Vec<Vec<(u32, f64)>>,
    /// Per-variable churn flag since the last rebuild (dedups
    /// `dirty_vars`).
    dirty: Vec<bool>,
    /// Variables touched by insert/remove since the last rebuild, each at
    /// most once — compaction reorders exactly these views, so owners
    /// only need to refresh derived caches for them.
    dirty_vars: Vec<u32>,
    /// Dead base cells (swap-compacted out of every view) awaiting
    /// compaction.
    slack: usize,
    overlay_len: usize,
    epoch: u64,
}

impl CsrIncidence {
    /// Empty arena over `n` variables.
    pub fn new(n: usize) -> Self {
        Self {
            off: vec![0; n + 1],
            base_live: vec![0; n],
            overlay: vec![Vec::new(); n],
            dirty: vec![false; n],
            ..Self::default()
        }
    }

    pub fn num_vars(&self) -> usize {
        self.overlay.len()
    }

    /// Rebuild generation — bumped by every [`CsrIncidence::rebuild`].
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Dead base cells awaiting compaction.
    pub fn slack(&self) -> usize {
        self.slack
    }

    /// Entries living in the overlay (inserted since the last rebuild).
    pub fn overlay_len(&self) -> usize {
        self.overlay_len
    }

    /// Register a variable appended after the last rebuild (no base
    /// segment until then — reads come from its overlay only).
    pub fn add_var(&mut self) {
        self.overlay.push(Vec::new());
        self.dirty.push(false);
    }

    #[inline]
    fn base_range(&self, v: usize) -> (usize, usize) {
        if v < self.base_live.len() {
            let s = self.off[v] as usize;
            (s, s + self.base_live[v] as usize)
        } else {
            (0, 0)
        }
    }

    /// Hot-path view of variable `v`: `(base slots, base βs, overlay)`.
    ///
    /// The two base slices are parallel, contiguous, and contain only
    /// *live* entries (removal swap-compacts within the segment). The
    /// overlay holds entries inserted since the last compaction, iterated
    /// after the base entries.
    #[inline]
    pub fn view(&self, v: usize) -> (&[u32], &[f64], &[(u32, f64)]) {
        let (s, e) = self.base_range(v);
        (&self.slot[s..e], &self.beta[s..e], &self.overlay[v])
    }

    /// Total live entry count of the view — the width of the per-lane
    /// gather for variable `v`, always equal to its live degree.
    #[inline]
    pub fn view_len(&self, v: usize) -> usize {
        let (s, e) = self.base_range(v);
        (e - s) + self.overlay[v].len()
    }

    /// The live incidence of `v` as one list (base then overlay) — the
    /// logical content the nested reference incidence must equal, up to
    /// order.
    pub fn logical(&self, v: usize) -> Vec<(u32, f64)> {
        let (s, e) = self.base_range(v);
        let mut out: Vec<(u32, f64)> = (s..e).map(|i| (self.slot[i], self.beta[i])).collect();
        out.extend_from_slice(&self.overlay[v]);
        out
    }

    /// Variables whose view changed since the last rebuild, each listed
    /// once: the set compaction will reorder.
    pub fn dirty_vars(&self) -> &[u32] {
        &self.dirty_vars
    }

    fn mark_dirty(&mut self, v: usize) {
        if !self.dirty[v] {
            self.dirty[v] = true;
            self.dirty_vars.push(v as u32);
        }
    }

    /// O(1): append `(slot, β)` to `v`'s overlay.
    pub fn insert(&mut self, v: usize, slot: u32, beta: f64) {
        self.overlay[v].push((slot, beta));
        self.overlay_len += 1;
        self.mark_dirty(v);
    }

    /// O(degree): drop `slot` from `v` — from the overlay if it was
    /// inserted since the last rebuild, else by swap-compacting it out of
    /// the base segment's live prefix. Returns whether the entry was
    /// found.
    pub fn remove(&mut self, v: usize, slot: u32) -> bool {
        if let Some(pos) = self.overlay[v].iter().position(|&(s, _)| s == slot) {
            self.overlay[v].swap_remove(pos);
            self.overlay_len -= 1;
            self.mark_dirty(v);
            return true;
        }
        let (s, e) = self.base_range(v);
        for i in s..e {
            if self.slot[i] == slot {
                self.slot.swap(i, e - 1);
                self.beta.swap(i, e - 1);
                self.base_live[v] -= 1;
                self.slack += 1;
                self.mark_dirty(v);
                return true;
            }
        }
        false
    }

    /// Whether enough churn has accumulated that the owner should rebuild:
    /// slack wastes arena memory, overlays cost a second (non-contiguous)
    /// loop per site. The threshold (a quarter of arena + variable count,
    /// floor 16) keeps rebuild cost amortized O(1) per mutation and avoids
    /// rebuild storms during bulk construction.
    pub fn needs_compaction(&self) -> bool {
        let dirty = self.slack + self.overlay_len;
        dirty > 16 && dirty * 4 > self.slot.len() + self.num_vars()
    }

    /// O(E) rebuild from the nested reference incidence; bumps the epoch,
    /// clears slack, overlays, and dirty flags.
    pub fn rebuild(&mut self, incidence: &[Vec<(u32, f64)>]) {
        let n = incidence.len();
        let total: usize = incidence.iter().map(Vec::len).sum();
        assert!(total < u32::MAX as usize, "incidence arena overflows u32");
        self.off.clear();
        self.off.reserve(n + 1);
        self.base_live.clear();
        self.base_live.reserve(n);
        self.slot.clear();
        self.slot.reserve(total);
        self.beta.clear();
        self.beta.reserve(total);
        self.off.push(0);
        for list in incidence {
            for &(slot, beta) in list {
                self.slot.push(slot);
                self.beta.push(beta);
            }
            self.off.push(self.slot.len() as u32);
            self.base_live.push(list.len() as u32);
        }
        self.overlay.clear();
        self.overlay.resize(n, Vec::new());
        self.dirty.clear();
        self.dirty.resize(n, false);
        self.dirty_vars.clear();
        self.slack = 0;
        self.overlay_len = 0;
        self.epoch += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sorted(mut xs: Vec<(u32, f64)>) -> Vec<(u32, f64)> {
        xs.sort_by_key(|e| e.0);
        xs
    }

    #[test]
    fn rebuild_mirrors_nested_lists() {
        let nested = vec![vec![(0u32, 0.5), (2, -0.25)], vec![], vec![(1u32, 1.5)]];
        let mut csr = CsrIncidence::new(3);
        csr.rebuild(&nested);
        assert_eq!(csr.epoch(), 1);
        for v in 0..3 {
            assert_eq!(csr.logical(v), nested[v]);
            let (slots, betas, overlay) = csr.view(v);
            assert_eq!(slots.len(), nested[v].len());
            assert_eq!(betas.len(), nested[v].len());
            assert!(overlay.is_empty());
        }
    }

    #[test]
    fn overlay_and_slack_track_churn() {
        let nested = vec![vec![(0u32, 0.5), (1, 0.75), (2, -1.0)], vec![(1u32, -0.5)]];
        let mut csr = CsrIncidence::new(2);
        csr.rebuild(&nested);
        // removing a base entry swap-compacts it out of the live view
        assert!(csr.remove(0, 1));
        assert_eq!(csr.slack(), 1);
        let (slots, betas, _) = csr.view(0);
        assert_eq!(slots, &[0, 2], "last live entry swapped into the hole");
        assert_eq!(betas, &[0.5, -1.0]);
        assert_eq!(sorted(csr.logical(0)), vec![(0, 0.5), (2, -1.0)]);
        // overlay insert, then overlay remove round-trips without touching
        // the base arena
        csr.insert(0, 7, 2.0);
        assert_eq!(csr.overlay_len(), 1);
        assert_eq!(sorted(csr.logical(0)), vec![(0, 0.5), (2, -1.0), (7, 2.0)]);
        assert!(csr.remove(0, 7));
        assert_eq!(csr.overlay_len(), 0);
        // removing something absent reports false
        assert!(!csr.remove(0, 9));
        assert!(!csr.remove(0, 1)); // already removed
        assert_eq!(csr.view_len(0), 2);
    }

    #[test]
    fn dirty_vars_stay_deduped_under_steady_churn() {
        // regression: a long remove→insert cycle through one variable must
        // not grow the dirty bookkeeping beyond one entry per variable
        let mut csr = CsrIncidence::new(2);
        csr.rebuild(&[vec![(0u32, 1.0)], vec![]]);
        for round in 0..200u32 {
            assert!(csr.remove(0, round));
            csr.insert(0, round + 1, 1.0);
        }
        assert_eq!(csr.dirty_vars(), &[0], "dirty list must stay deduped");
        assert_eq!(csr.view_len(0), 1);
    }

    #[test]
    fn vars_added_after_rebuild_live_in_overlay() {
        let mut csr = CsrIncidence::new(1);
        csr.rebuild(&[vec![(0u32, 1.0)]]);
        csr.add_var();
        assert_eq!(csr.num_vars(), 2);
        assert_eq!(csr.view_len(1), 0);
        csr.insert(1, 3, -1.0);
        assert_eq!(csr.logical(1), vec![(3, -1.0)]);
        let (slots, _, overlay) = csr.view(1);
        assert!(slots.is_empty());
        assert_eq!(overlay, &[(3, -1.0)]);
    }

    #[test]
    fn compaction_threshold_scales_with_arena() {
        let mut csr = CsrIncidence::new(2);
        csr.rebuild(&[vec![(0u32, 1.0)], vec![(0u32, 1.0)]]);
        for i in 0..16 {
            csr.insert(0, 10 + i, 0.1);
        }
        assert!(!csr.needs_compaction(), "16 dirty entries: below threshold");
        csr.insert(0, 99, 0.1);
        assert!(csr.needs_compaction(), "17 dirty on a 2-entry base");
    }
}
