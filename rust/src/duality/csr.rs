//! [`CsrIncidence`]: the flat incidence arena behind the sampler hot path.
//!
//! [`super::DualModel`] keeps its nested `Vec<Vec<(slot, β)>>` incidence as
//! the *reference* structure — easy to mutate, easy to reason about — and
//! mirrors it here as contiguous arrays (`off` / `slot` / `beta`, classic
//! CSR) so a sweep walks one cache-friendly arena instead of
//! pointer-chasing one heap allocation per variable.
//!
//! Dynamic churn must stay O(degree) amortized (the paper's "almost no
//! preprocessing" claim), so mutations never rewrite the arena globally:
//!
//! * **insert** appends to a small per-variable *delta overlay* (base
//!   segments cannot grow in place);
//! * **remove** swap-compacts *within* the variable's base segment — the
//!   removed entry swaps with the segment's last live entry and the
//!   per-variable live length shrinks, exactly the `swap_remove` the
//!   nested reference performs — or drops the entry from the overlay.
//!   Views therefore never contain dead entries; freed cells are only
//!   *slack* (unused tail capacity) awaiting compaction;
//! * once slack + overlay outgrow a fraction of the arena, the owner
//!   triggers a **compaction**: one O(E) rebuild from the reference
//!   incidence, bumping [`CsrIncidence::epoch`]. Between compactions every
//!   read is the live base slice plus the (usually empty) overlay slice.
//!
//! Churned variables are tracked with a per-variable dirty flag (deduped,
//! so the bookkeeping stays O(vars) however long a steady churn run gets);
//! compaction reorders exactly those views, and owners refresh derived
//! caches for them alone.
//!
//! The same arena discipline backs the cached x-conditional tables: see
//! [`XTableArena`] for the tile-aligned structure-of-arrays layout the
//! SIMD-tiled lane kernels gather from.

use crate::util::aligned::{AlignedF64s, F64S_PER_CACHE_LINE};

/// Tile-aligned arena of per-variable cached x-conditional tables.
///
/// [`super::DualModel`] caches, for every variable of degree ≤ 6, the
/// Bernoulli acceptance parts `(mult, thresh)` of its conditional for
/// every θ-bit pattern — `2^deg` entries. This arena stores those tables
/// the way the SIMD-tiled kernels want to read them:
///
/// * **structure-of-arrays**: one flat `mult` array and one flat
///   `thresh` array (not `Vec<(f64, f64)>` per variable), so the
///   per-lane gather walks two homogeneous streams;
/// * **tile-aligned**: storage is 64-byte-aligned ([`AlignedF64s`]) and
///   every table starts at a multiple of [`F64S_PER_CACHE_LINE`]
///   entries, so a table never straddles a cache line it doesn't own;
/// * **churn-friendly**: a table that shrinks (or keeps its size) under
///   churn is rewritten in place; one that grows abandons its block for
///   a fresh one at the arena end, and once abandoned *slack* outgrows a
///   quarter of the arena the whole thing is compacted in one O(total)
///   pass — the same epoch-compaction idiom as [`CsrIncidence`].
#[derive(Clone, Debug, Default)]
pub struct XTableArena {
    /// Per-variable block start (in entries); `u32::MAX` = no block.
    off: Vec<u32>,
    /// Per-variable live entries (`2^deg`); 0 = no cached table.
    len: Vec<u32>,
    /// Per-variable block capacity, a multiple of the tile width.
    cap: Vec<u32>,
    mult: AlignedF64s,
    thresh: AlignedF64s,
    /// Entries in abandoned blocks, reclaimed by compaction.
    slack: usize,
}

impl XTableArena {
    /// Empty arena over `n` variables.
    pub fn new(n: usize) -> Self {
        Self {
            off: vec![u32::MAX; n],
            len: vec![0; n],
            cap: vec![0; n],
            mult: AlignedF64s::new(),
            thresh: AlignedF64s::new(),
            slack: 0,
        }
    }

    /// Register one more variable (no table until the first `set`).
    pub fn add_var(&mut self) {
        self.off.push(u32::MAX);
        self.len.push(0);
        self.cap.push(0);
    }

    /// Entries in abandoned blocks awaiting compaction.
    pub fn slack(&self) -> usize {
        self.slack
    }

    /// `v`'s cached table as parallel `(mult, thresh)` slices, or `None`
    /// when the variable has no cached table.
    #[inline]
    pub fn get(&self, v: usize) -> Option<(&[f64], &[f64])> {
        let len = self.len[v] as usize;
        if len == 0 {
            return None;
        }
        let off = self.off[v] as usize;
        Some((
            &self.mult.as_slice()[off..off + len],
            &self.thresh.as_slice()[off..off + len],
        ))
    }

    /// Install `v`'s table (parallel `mult`/`thresh` values, non-empty).
    /// Rewrites in place when the current block is large enough, else
    /// relocates to the arena end; may trigger a compaction.
    pub fn set(&mut self, v: usize, mult: &[f64], thresh: &[f64]) {
        assert_eq!(mult.len(), thresh.len());
        assert!(!mult.is_empty(), "use clear() to drop a table");
        let n = mult.len();
        if n <= self.cap[v] as usize {
            let off = self.off[v] as usize;
            self.mult.as_mut_slice()[off..off + n].copy_from_slice(mult);
            self.thresh.as_mut_slice()[off..off + n].copy_from_slice(thresh);
            self.len[v] = n as u32;
            return;
        }
        // grow: abandon the old block (if any) and append a padded one
        self.slack += self.cap[v] as usize;
        let off = self.mult.len();
        debug_assert_eq!(off % F64S_PER_CACHE_LINE, 0, "arena lost tile alignment");
        let cap = n.div_ceil(F64S_PER_CACHE_LINE) * F64S_PER_CACHE_LINE;
        self.mult.extend_from_slice(mult);
        self.thresh.extend_from_slice(thresh);
        for _ in n..cap {
            self.mult.push(0.0);
            self.thresh.push(0.0);
        }
        self.off[v] = off as u32;
        self.len[v] = n as u32;
        self.cap[v] = cap as u32;
        self.maybe_compact();
    }

    /// Drop `v`'s table (degree rose above the cache cap).
    pub fn clear(&mut self, v: usize) {
        self.slack += self.cap[v] as usize;
        self.off[v] = u32::MAX;
        self.len[v] = 0;
        self.cap[v] = 0;
        self.maybe_compact();
    }

    /// Compact once abandoned slack outgrows a quarter of the arena
    /// (floor 16 — mirrors [`CsrIncidence::needs_compaction`]).
    fn maybe_compact(&mut self) {
        if self.slack > 16 && self.slack * 4 > self.mult.len() {
            self.compact();
        }
    }

    /// Repack every live block contiguously (shrinking caps to the padded
    /// table size) and reset slack to zero.
    fn compact(&mut self) {
        let mut mult = AlignedF64s::new();
        let mut thresh = AlignedF64s::new();
        for v in 0..self.off.len() {
            let n = self.len[v] as usize;
            if n == 0 {
                self.off[v] = u32::MAX;
                self.cap[v] = 0;
                continue;
            }
            let old = self.off[v] as usize;
            let off = mult.len();
            let cap = n.div_ceil(F64S_PER_CACHE_LINE) * F64S_PER_CACHE_LINE;
            mult.extend_from_slice(&self.mult.as_slice()[old..old + n]);
            thresh.extend_from_slice(&self.thresh.as_slice()[old..old + n]);
            for _ in n..cap {
                mult.push(0.0);
                thresh.push(0.0);
            }
            self.off[v] = off as u32;
            self.cap[v] = cap as u32;
        }
        self.mult = mult;
        self.thresh = thresh;
        self.slack = 0;
    }
}

/// Flat CSR incidence with a delta overlay (see module docs).
#[derive(Clone, Debug, Default)]
pub struct CsrIncidence {
    /// `off[v]` is the start of base variable `v`'s segment; the segment's
    /// *capacity* runs to `off[v + 1]`, its live prefix to
    /// `off[v] + base_live[v]`. Variables added after the last rebuild
    /// have no base segment.
    off: Vec<u32>,
    /// Live prefix length of each base segment (shrinks on remove).
    base_live: Vec<u32>,
    slot: Vec<u32>,
    beta: Vec<f64>,
    /// Per-variable entries inserted since the last rebuild.
    overlay: Vec<Vec<(u32, f64)>>,
    /// Per-variable churn flag since the last rebuild (dedups
    /// `dirty_vars`).
    dirty: Vec<bool>,
    /// Variables touched by insert/remove since the last rebuild, each at
    /// most once — compaction reorders exactly these views, so owners
    /// only need to refresh derived caches for them.
    dirty_vars: Vec<u32>,
    /// Dead base cells (swap-compacted out of every view) awaiting
    /// compaction.
    slack: usize,
    overlay_len: usize,
    epoch: u64,
}

impl CsrIncidence {
    /// Empty arena over `n` variables.
    pub fn new(n: usize) -> Self {
        Self {
            off: vec![0; n + 1],
            base_live: vec![0; n],
            overlay: vec![Vec::new(); n],
            dirty: vec![false; n],
            ..Self::default()
        }
    }

    /// Number of variables the arena covers.
    pub fn num_vars(&self) -> usize {
        self.overlay.len()
    }

    /// Rebuild generation — bumped by every [`CsrIncidence::rebuild`].
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Dead base cells awaiting compaction.
    pub fn slack(&self) -> usize {
        self.slack
    }

    /// Entries living in the overlay (inserted since the last rebuild).
    pub fn overlay_len(&self) -> usize {
        self.overlay_len
    }

    /// Register a variable appended after the last rebuild (no base
    /// segment until then — reads come from its overlay only).
    pub fn add_var(&mut self) {
        self.overlay.push(Vec::new());
        self.dirty.push(false);
    }

    #[inline]
    fn base_range(&self, v: usize) -> (usize, usize) {
        if v < self.base_live.len() {
            let s = self.off[v] as usize;
            (s, s + self.base_live[v] as usize)
        } else {
            (0, 0)
        }
    }

    /// Hot-path view of variable `v`: `(base slots, base βs, overlay)`.
    ///
    /// The two base slices are parallel, contiguous, and contain only
    /// *live* entries (removal swap-compacts within the segment). The
    /// overlay holds entries inserted since the last compaction, iterated
    /// after the base entries.
    #[inline]
    pub fn view(&self, v: usize) -> (&[u32], &[f64], &[(u32, f64)]) {
        let (s, e) = self.base_range(v);
        (&self.slot[s..e], &self.beta[s..e], &self.overlay[v])
    }

    /// Total live entry count of the view — the width of the per-lane
    /// gather for variable `v`, always equal to its live degree.
    #[inline]
    pub fn view_len(&self, v: usize) -> usize {
        let (s, e) = self.base_range(v);
        (e - s) + self.overlay[v].len()
    }

    /// The live incidence of `v` as one list (base then overlay) — the
    /// logical content the nested reference incidence must equal, up to
    /// order.
    pub fn logical(&self, v: usize) -> Vec<(u32, f64)> {
        let (s, e) = self.base_range(v);
        let mut out: Vec<(u32, f64)> = (s..e).map(|i| (self.slot[i], self.beta[i])).collect();
        out.extend_from_slice(&self.overlay[v]);
        out
    }

    /// Variables whose view changed since the last rebuild, each listed
    /// once: the set compaction will reorder.
    pub fn dirty_vars(&self) -> &[u32] {
        &self.dirty_vars
    }

    fn mark_dirty(&mut self, v: usize) {
        if !self.dirty[v] {
            self.dirty[v] = true;
            self.dirty_vars.push(v as u32);
        }
    }

    /// O(1): append `(slot, β)` to `v`'s overlay.
    pub fn insert(&mut self, v: usize, slot: u32, beta: f64) {
        self.overlay[v].push((slot, beta));
        self.overlay_len += 1;
        self.mark_dirty(v);
    }

    /// O(degree): drop `slot` from `v` — from the overlay if it was
    /// inserted since the last rebuild, else by swap-compacting it out of
    /// the base segment's live prefix. Returns whether the entry was
    /// found.
    pub fn remove(&mut self, v: usize, slot: u32) -> bool {
        if let Some(pos) = self.overlay[v].iter().position(|&(s, _)| s == slot) {
            self.overlay[v].swap_remove(pos);
            self.overlay_len -= 1;
            self.mark_dirty(v);
            return true;
        }
        let (s, e) = self.base_range(v);
        for i in s..e {
            if self.slot[i] == slot {
                self.slot.swap(i, e - 1);
                self.beta.swap(i, e - 1);
                self.base_live[v] -= 1;
                self.slack += 1;
                self.mark_dirty(v);
                return true;
            }
        }
        false
    }

    /// Whether enough churn has accumulated that the owner should rebuild:
    /// slack wastes arena memory, overlays cost a second (non-contiguous)
    /// loop per site. The threshold (a quarter of arena + variable count,
    /// floor 16) keeps rebuild cost amortized O(1) per mutation and avoids
    /// rebuild storms during bulk construction.
    pub fn needs_compaction(&self) -> bool {
        let dirty = self.slack + self.overlay_len;
        dirty > 16 && dirty * 4 > self.slot.len() + self.num_vars()
    }

    /// O(E) rebuild from the nested reference incidence; bumps the epoch,
    /// clears slack, overlays, and dirty flags.
    pub fn rebuild(&mut self, incidence: &[Vec<(u32, f64)>]) {
        let n = incidence.len();
        let total: usize = incidence.iter().map(Vec::len).sum();
        assert!(total < u32::MAX as usize, "incidence arena overflows u32");
        self.off.clear();
        self.off.reserve(n + 1);
        self.base_live.clear();
        self.base_live.reserve(n);
        self.slot.clear();
        self.slot.reserve(total);
        self.beta.clear();
        self.beta.reserve(total);
        self.off.push(0);
        for list in incidence {
            for &(slot, beta) in list {
                self.slot.push(slot);
                self.beta.push(beta);
            }
            self.off.push(self.slot.len() as u32);
            self.base_live.push(list.len() as u32);
        }
        self.overlay.clear();
        self.overlay.resize(n, Vec::new());
        self.dirty.clear();
        self.dirty.resize(n, false);
        self.dirty_vars.clear();
        self.slack = 0;
        self.overlay_len = 0;
        self.epoch += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sorted(mut xs: Vec<(u32, f64)>) -> Vec<(u32, f64)> {
        xs.sort_by_key(|e| e.0);
        xs
    }

    #[test]
    fn rebuild_mirrors_nested_lists() {
        let nested = vec![vec![(0u32, 0.5), (2, -0.25)], vec![], vec![(1u32, 1.5)]];
        let mut csr = CsrIncidence::new(3);
        csr.rebuild(&nested);
        assert_eq!(csr.epoch(), 1);
        for v in 0..3 {
            assert_eq!(csr.logical(v), nested[v]);
            let (slots, betas, overlay) = csr.view(v);
            assert_eq!(slots.len(), nested[v].len());
            assert_eq!(betas.len(), nested[v].len());
            assert!(overlay.is_empty());
        }
    }

    #[test]
    fn overlay_and_slack_track_churn() {
        let nested = vec![vec![(0u32, 0.5), (1, 0.75), (2, -1.0)], vec![(1u32, -0.5)]];
        let mut csr = CsrIncidence::new(2);
        csr.rebuild(&nested);
        // removing a base entry swap-compacts it out of the live view
        assert!(csr.remove(0, 1));
        assert_eq!(csr.slack(), 1);
        let (slots, betas, _) = csr.view(0);
        assert_eq!(slots, &[0, 2], "last live entry swapped into the hole");
        assert_eq!(betas, &[0.5, -1.0]);
        assert_eq!(sorted(csr.logical(0)), vec![(0, 0.5), (2, -1.0)]);
        // overlay insert, then overlay remove round-trips without touching
        // the base arena
        csr.insert(0, 7, 2.0);
        assert_eq!(csr.overlay_len(), 1);
        assert_eq!(sorted(csr.logical(0)), vec![(0, 0.5), (2, -1.0), (7, 2.0)]);
        assert!(csr.remove(0, 7));
        assert_eq!(csr.overlay_len(), 0);
        // removing something absent reports false
        assert!(!csr.remove(0, 9));
        assert!(!csr.remove(0, 1)); // already removed
        assert_eq!(csr.view_len(0), 2);
    }

    #[test]
    fn dirty_vars_stay_deduped_under_steady_churn() {
        // regression: a long remove→insert cycle through one variable must
        // not grow the dirty bookkeeping beyond one entry per variable
        let mut csr = CsrIncidence::new(2);
        csr.rebuild(&[vec![(0u32, 1.0)], vec![]]);
        for round in 0..200u32 {
            assert!(csr.remove(0, round));
            csr.insert(0, round + 1, 1.0);
        }
        assert_eq!(csr.dirty_vars(), &[0], "dirty list must stay deduped");
        assert_eq!(csr.view_len(0), 1);
    }

    #[test]
    fn vars_added_after_rebuild_live_in_overlay() {
        let mut csr = CsrIncidence::new(1);
        csr.rebuild(&[vec![(0u32, 1.0)]]);
        csr.add_var();
        assert_eq!(csr.num_vars(), 2);
        assert_eq!(csr.view_len(1), 0);
        csr.insert(1, 3, -1.0);
        assert_eq!(csr.logical(1), vec![(3, -1.0)]);
        let (slots, _, overlay) = csr.view(1);
        assert!(slots.is_empty());
        assert_eq!(overlay, &[(3, -1.0)]);
    }

    #[test]
    fn xtable_arena_roundtrips_and_stays_tile_aligned() {
        let mut xt = XTableArena::new(3);
        assert!(xt.get(0).is_none());
        xt.set(0, &[1.0, 2.0], &[3.0, 4.0]);
        xt.set(2, &[5.0; 16], &[6.0; 16]);
        let (m, t) = xt.get(0).unwrap();
        assert_eq!((m, t), (&[1.0, 2.0][..], &[3.0, 4.0][..]));
        let (m, t) = xt.get(2).unwrap();
        assert_eq!(m, &[5.0; 16][..]);
        assert_eq!(t, &[6.0; 16][..]);
        // every block starts on a 64-byte boundary
        for v in [0usize, 2] {
            let (m, t) = xt.get(v).unwrap();
            assert_eq!(m.as_ptr() as usize % 64, 0, "mult block of {v}");
            assert_eq!(t.as_ptr() as usize % 64, 0, "thresh block of {v}");
        }
    }

    #[test]
    fn xtable_arena_shrink_in_place_grow_relocates() {
        let mut xt = XTableArena::new(2);
        xt.set(0, &[1.0; 8], &[1.0; 8]);
        assert_eq!(xt.slack(), 0);
        // shrink: same block, no slack
        xt.set(0, &[2.0; 4], &[2.5; 4]);
        assert_eq!(xt.slack(), 0);
        assert_eq!(xt.get(0).unwrap().0, &[2.0; 4][..]);
        // regrow within capacity: still in place
        xt.set(0, &[3.0; 8], &[3.5; 8]);
        assert_eq!(xt.slack(), 0);
        // grow past capacity: relocate, old block becomes slack
        xt.set(0, &[4.0; 16], &[4.5; 16]);
        assert_eq!(xt.slack(), 8);
        assert_eq!(xt.get(0).unwrap().0, &[4.0; 16][..]);
        // clear frees the block
        xt.clear(0);
        assert!(xt.get(0).is_none());
    }

    #[test]
    fn xtable_arena_compacts_under_churn() {
        let mut xt = XTableArena::new(4);
        // keep growing var 0's table so it abandons blocks repeatedly,
        // while var 1 holds a stable table that must survive compaction
        xt.set(1, &[9.0, 8.0, 7.0], &[0.9, 0.8, 0.7]);
        for round in 0..50usize {
            let n = 8 << (round % 3); // 8, 16, 32, 8, ... grow + shrink
            xt.set(0, &vec![round as f64; n], &vec![0.5; n]);
            // maybe_compact's post-condition must hold after EVERY
            // mutation: slack small in absolute terms OR at most a
            // quarter of the arena — this fails if compaction is broken
            assert!(
                xt.slack() <= 16 || xt.slack() * 4 <= xt.mult.len(),
                "round {round}: slack {} vs arena {}",
                xt.slack(),
                xt.mult.len()
            );
        }
        let (m, t) = xt.get(1).unwrap();
        assert_eq!(m, &[9.0, 8.0, 7.0][..]);
        assert_eq!(t, &[0.9, 0.8, 0.7][..]);
        let (m, _) = xt.get(0).unwrap();
        assert_eq!(m[0], 49.0);
        // compaction keeps tile alignment
        assert_eq!(m.as_ptr() as usize % 64, 0);
    }

    #[test]
    fn prop_xtable_arena_preserves_contents_and_alignment() {
        // PR-4 code with no property coverage until now: random
        // grow / shrink-in-place / relocate-on-grow / clear sequences
        // (with the slack-threshold compactions they trigger) must
        // preserve every live table's (mult, thresh) contents bit-for-bit
        // and keep every table start 64-byte aligned.
        use crate::util::proptest::{check, Gen};
        check("xtable arena churn", 60, |g: &mut Gen| {
            let nvars = g.usize_in(1..=8);
            let mut xt = XTableArena::new(nvars);
            let mut reference: Vec<Option<(Vec<f64>, Vec<f64>)>> = vec![None; nvars];
            let steps = g.usize_in(20..=120);
            for step in 0..steps {
                let v = g.usize_in(0..=nvars - 1);
                if reference[v].is_none() || g.bool() {
                    // table sizes are the real 2^deg shapes, deg 0..=6
                    let len = 1usize << g.usize_in(0..=6);
                    let mult: Vec<f64> = (0..len).map(|_| g.f64_in(-8.0, 8.0)).collect();
                    let thresh: Vec<f64> = (0..len).map(|_| g.f64_in(0.0, 1.0)).collect();
                    xt.set(v, &mult, &thresh);
                    reference[v] = Some((mult, thresh));
                } else {
                    xt.clear(v);
                    reference[v] = None;
                }
                // the compaction invariant must hold after EVERY mutation
                if !(xt.slack() <= 16 || xt.slack() * 4 <= xt.mult.len()) {
                    return Err(format!(
                        "step {step}: slack {} vs arena {}",
                        xt.slack(),
                        xt.mult.len()
                    ));
                }
                // every live table: exact contents + 64B-aligned start
                for (u, want) in reference.iter().enumerate() {
                    match (want, xt.get(u)) {
                        (None, None) => {}
                        (Some((m, t)), Some((am, at))) => {
                            if am != &m[..] || at != &t[..] {
                                return Err(format!(
                                    "step {step}: var {u} contents corrupted"
                                ));
                            }
                            if am.as_ptr() as usize % 64 != 0
                                || at.as_ptr() as usize % 64 != 0
                            {
                                return Err(format!(
                                    "step {step}: var {u} table start misaligned"
                                ));
                            }
                        }
                        (want, got) => {
                            return Err(format!(
                                "step {step}: var {u} presence mismatch \
                                 (want {:?}, got {:?})",
                                want.is_some(),
                                got.is_some()
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn compaction_threshold_scales_with_arena() {
        let mut csr = CsrIncidence::new(2);
        csr.rebuild(&[vec![(0u32, 1.0)], vec![(0u32, 1.0)]]);
        for i in 0..16 {
            csr.insert(0, 10 + i, 0.1);
        }
        assert!(!csr.needs_compaction(), "16 dirty entries: below threshold");
        csr.insert(0, 99, 0.1);
        assert!(csr.needs_compaction(), "17 dirty on a 2-entry base");
    }
}
