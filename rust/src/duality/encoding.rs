//! §4.2: general discrete MRFs via 0–1 encoding and categorical duals.
//!
//! A `K`-state variable becomes `K` binary indicator variables constrained
//! to one-hot. Rather than encode the constraint as (non-strictly-positive)
//! hard factors — which would break Theorem 2 — we sample each one-hot
//! block *jointly*: the primal conditional for a categorical variable given
//! θ is a softmax over its states, which still factorizes across variables
//! and therefore keeps the parallel structure.
//!
//! For a pairwise table `P ∈ R^{K×L}` the dual is a categorical `θ` over
//! the components of a positive decomposition `P = Σ_t g_t · u_t v_tᵀ`:
//!
//! * [`CategoricalDual::outer_product`] — the always-available rank-`K·L`
//!   decomposition (one component per table cell); degenerate mixing, used
//!   as the correctness baseline (the paper's "nm auxiliary variables").
//! * [`CategoricalDual::potts`] — the Potts short-cut: `P = e^{-w}·𝟙 +
//!   (1 − e^{-w})·diag` needs only `K+1` components ("only n auxiliary
//!   binary random variables per factor").

/// A positive mixture decomposition of a K×L pairwise table:
/// `P[a][b] = Σ_t g[t] · u[t][a] · v[t][b]`, all strictly positive except
/// that `u`/`v` may contain zeros for degenerate (indicator) components.
#[derive(Clone, Debug)]
pub struct CategoricalDual {
    /// Mixture weights, one per component.
    pub g: Vec<f64>,
    /// `u[t][a]`: component `t` factor over the first variable.
    pub u: Vec<Vec<f64>>,
    /// `v[t][b]`: component `t` factor over the second variable.
    pub v: Vec<Vec<f64>>,
    /// States of the first variable.
    pub k: usize,
    /// States of the second variable.
    pub l: usize,
}

impl CategoricalDual {
    /// Trivial decomposition: one component per cell, `u_t, v_t` indicator
    /// vectors. Exact for any positive table; θ has `K·L` states.
    pub fn outer_product(p: &[Vec<f64>]) -> Self {
        let k = p.len();
        let l = p[0].len();
        assert!(p.iter().all(|r| r.len() == l));
        assert!(
            p.iter().flatten().all(|&x| x > 0.0),
            "table must be strictly positive"
        );
        let mut g = Vec::with_capacity(k * l);
        let mut u = Vec::with_capacity(k * l);
        let mut v = Vec::with_capacity(k * l);
        for a in 0..k {
            for b in 0..l {
                g.push(p[a][b]);
                let mut ua = vec![0.0; k];
                ua[a] = 1.0;
                let mut vb = vec![0.0; l];
                vb[b] = 1.0;
                u.push(ua);
                v.push(vb);
            }
        }
        Self { g, u, v, k, l }
    }

    /// Potts factor `P[a][b] = e^{-w·𝟙[a≠b]}` (w ≥ 0): `K+1` components —
    /// one flat "off" component plus one diagonal component per state.
    pub fn potts(kstates: usize, w: f64) -> Self {
        assert!(w >= 0.0, "potts requires w >= 0");
        let off = (-w).exp();
        let mut g = vec![off];
        let mut u = vec![vec![1.0; kstates]];
        let mut v = vec![vec![1.0; kstates]];
        for s in 0..kstates {
            g.push(1.0 - off);
            let mut e = vec![0.0; kstates];
            e[s] = 1.0;
            u.push(e.clone());
            v.push(e);
        }
        Self {
            g,
            u,
            v,
            k: kstates,
            l: kstates,
        }
    }

    /// Number of dual states.
    pub fn components(&self) -> usize {
        self.g.len()
    }

    /// Reconstruct the table (tests; Theorem-1 analogue).
    pub fn table(&self) -> Vec<Vec<f64>> {
        let mut p = vec![vec![0.0; self.l]; self.k];
        for t in 0..self.components() {
            for a in 0..self.k {
                for b in 0..self.l {
                    p[a][b] += self.g[t] * self.u[t][a] * self.v[t][b];
                }
            }
        }
        p
    }

    /// Unnormalized `P(θ = t | x₁ = a, x₂ = b)` weights.
    pub fn theta_weights(&self, a: usize, b: usize) -> Vec<f64> {
        (0..self.components())
            .map(|t| self.g[t] * self.u[t][a] * self.v[t][b])
            .collect()
    }

    /// Per-state multiplicative message this factor sends to endpoint 1
    /// when its dual is in state `t` (the `u_t` column). The primal
    /// conditional of a categorical variable multiplies these across its
    /// incident factors and normalizes — a softmax, parallel across
    /// variables.
    pub fn message_to_v1(&self, t: usize) -> &[f64] {
        &self.u[t]
    }

    /// Component `t`'s factor over the second variable (see [`CategoricalDual::message_to_v1`]).
    pub fn message_to_v2(&self, t: usize) -> &[f64] {
        &self.v[t]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, RngCore};
    use crate::util::proptest::{check, Gen};

    #[test]
    fn outer_product_reconstructs() {
        let p = vec![vec![1.0, 2.0, 0.5], vec![0.3, 4.0, 1.5]];
        let d = CategoricalDual::outer_product(&p);
        assert_eq!(d.components(), 6);
        let t = d.table();
        for a in 0..2 {
            for b in 0..3 {
                assert!((t[a][b] - p[a][b]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn potts_reconstructs_and_is_compact() {
        let k = 5;
        let w = 0.8;
        let d = CategoricalDual::potts(k, w);
        assert_eq!(d.components(), k + 1); // the paper's "only n auxiliaries"
        let t = d.table();
        for a in 0..k {
            for b in 0..k {
                let want = if a == b { 1.0 } else { (-w_val(w)).exp() };
                assert!((t[a][b] - want).abs() < 1e-12, "{a},{b}");
            }
        }
        fn w_val(w: f64) -> f64 {
            w
        }
    }

    #[test]
    fn theta_weights_sum_to_cell() {
        let p = vec![vec![1.2, 0.4], vec![0.9, 2.2]];
        let d = CategoricalDual::outer_product(&p);
        for a in 0..2 {
            for b in 0..2 {
                let s: f64 = d.theta_weights(a, b).iter().sum();
                assert!((s - p[a][b]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn prop_potts_weights_nonnegative_and_valid() {
        check("potts dual conditional valid", 100, |g: &mut Gen| {
            let k = g.usize_in(2..=6);
            let w = g.f64_in(0.0, 3.0);
            let d = CategoricalDual::potts(k, w);
            for a in 0..k {
                for b in 0..k {
                    let wts = d.theta_weights(a, b);
                    if wts.iter().any(|&x| x < 0.0) {
                        return Err(format!("negative weight k={k} w={w}"));
                    }
                    if wts.iter().sum::<f64>() <= 0.0 {
                        return Err(format!("zero mass at ({a},{b})"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gibbs_on_potts_pair_matches_enumeration() {
        // a single Potts factor + unary softmax fields over two 3-state
        // variables: run categorical PD Gibbs by hand, compare marginals.
        let k = 3;
        let d = CategoricalDual::potts(k, 1.0);
        let unary1 = [0.2f64, -0.1, 0.4];
        let unary2 = [-0.3f64, 0.0, 0.25];
        // exact marginal of x1
        let mut exact = [0.0f64; 3];
        let mut z = 0.0;
        let table = d.table();
        for a in 0..k {
            for b in 0..k {
                let w = (unary1[a] + unary2[b]).exp() * table[a][b];
                exact[a] += w;
                z += w;
            }
        }
        for e in &mut exact {
            *e /= z;
        }
        // PD Gibbs
        let mut rng = Pcg64::seed(11);
        let mut a = 0usize;
        let mut b = 0usize;
        let mut counts = [0u64; 3];
        let sweeps = 400_000;
        for it in 0..sweeps {
            // θ | x
            let wts = d.theta_weights(a, b);
            let t = rng.categorical(&wts);
            // x | θ: independent softmaxes
            let wa: Vec<f64> = (0..k)
                .map(|s| (unary1[s]).exp() * d.message_to_v1(t)[s])
                .collect();
            a = rng.categorical(&wa);
            let wb: Vec<f64> = (0..k)
                .map(|s| (unary2[s]).exp() * d.message_to_v2(t)[s])
                .collect();
            b = rng.categorical(&wb);
            if it >= sweeps / 10 {
                counts[a] += 1;
            }
        }
        let total: u64 = counts.iter().sum();
        for s in 0..k {
            let freq = counts[s] as f64 / total as f64;
            assert!(
                (freq - exact[s]).abs() < 0.01,
                "state {s}: {freq} vs {}",
                exact[s]
            );
        }
    }
}
