//! Probabilistic duality (§3–§4): the paper's mathematical core.
//!
//! A pair `(x, θ)` is *dual* via link functions `(s, r)` when
//! `p(x, θ) = h(x) g(θ) exp⟨s(x), r(θ)⟩`. For a binary pairwise MRF the
//! standard choice `s(x) = x` makes both conditionals factorize
//! (Corollary 1), so one binary auxiliary per factor suffices — provided
//! every 2×2 factor table admits a strictly positive factorization
//! `P = B Cᵀ`, which §4.1 constructs for *any* strictly positive table.
//!
//! * [`factorization`] — Lemmas 2–4 + Theorem 2 (`P → (α, q, β)`).
//! * [`model`] — [`DualModel`]: the dualized MRF with O(degree)
//!   incremental add/remove, shared by every sampler and the XLA runtime;
//!   [`MinibatchPolicy`]/[`MbPlan`]: per-site factor-subsampling plans
//!   (alias tables + Poisson/MIN-Gibbs correction constants) for
//!   degree-sublinear hub updates, maintained under the same churn hooks.
//! * [`csr`] — [`CsrIncidence`]: the flat incidence arena (CSR base +
//!   delta overlay + epoch compaction) mirroring the model's nested
//!   reference incidence for the sweep hot path; [`XTableArena`]: the
//!   tile-aligned structure-of-arrays arena of cached x-conditional
//!   tables the SIMD-tiled lane kernels gather from.
//! * [`blocking`] — adaptive tree-blocking (§5.4 automated):
//!   [`BlockPlanner`] grows capped spanning-tree blocks around
//!   strongly-coupled slots from the engine's agreement EWMAs, re-planned
//!   lazily on churn epochs; tree duals are marginalized into softplus
//!   edge potentials for the engine's joint block draws.
//! * [`encoding`] — §4.2 multi-state variables via 0–1 encoding, Potts
//!   short-cut (order-n factor → n+1 dual states).
//! * [`sw`] — §4.3: Swendsen–Wang / Higdon partial-SW as degenerate
//!   decompositions of the Ising factor.

pub mod blocking;
pub mod csr;
pub mod encoding;
pub mod factorization;
pub mod model;
pub mod sw;

pub use blocking::{Block, BlockPlan, BlockPlanner, BlockPolicy, SweepUnit};
pub use csr::{CsrIncidence, XTableArena};
pub use factorization::{dualize_table, factorize_positive, DualFactor};
pub use model::{DualEntry, DualModel, MbPlan, MinibatchPolicy};
