//! §4.1: strictly positive factorization of 2×2 tables + Theorem 2.
//!
//! Pipeline (mirrored by `python/compile/dualize.py`, cross-checked in
//! `rust/tests/parity.rs`):
//!
//! 1. Lemma 4 — if `det P < 0`, pre-multiply by the swap matrix `S`.
//! 2. Lemma 3 — `D = diag(1/p₁₂, 1/p₂₁)` makes `D·P` symmetric.
//! 3. Lemma 2 — a symmetric positive table `M` with `det M ≥ 0` factors as
//!    `M = B Bᵀ` via the trigonometric square root, evaluated in the
//!    cancellation-free form of Remark 1.
//! 4. Undo `D` (and `S`) to obtain `P = B Cᵀ` with `B, C > 0`.
//! 5. Theorem 2 — read off the dual parameters `(α₁, α₂, q, β₁, β₂)` so
//!    `P(x₁,x₂) ∝ Σ_{θ∈{0,1}} exp(α₁x₁ + α₂x₂ + qθ + θ(β₁x₁ + β₂x₂))`.

/// Theorem-2 dual parameters of one pairwise factor.
///
/// Semantics: introduce a binary `θ` with
/// `p(x₁, x₂, θ) ∝ exp(α₁x₁) · exp(α₂x₂) · exp(qθ) · exp(θ(β₁x₁ + β₂x₂))`;
/// marginalizing `θ` recovers the factor's table up to a global constant.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DualFactor {
    /// Base-field contribution to the first endpoint.
    pub alpha1: f64,
    /// Base-field contribution to the second endpoint.
    pub alpha2: f64,
    /// The dual's prior log-odds.
    pub q: f64,
    /// Coupling of the dual to the first endpoint.
    pub beta1: f64,
    /// Coupling of the dual to the second endpoint.
    pub beta2: f64,
}

impl DualFactor {
    /// Reconstruct the (unnormalized) 2×2 table by summing out θ.
    pub fn table(&self) -> [[f64; 2]; 2] {
        let mut t = [[0.0; 2]; 2];
        for (x1, row) in t.iter_mut().enumerate() {
            for (x2, cell) in row.iter_mut().enumerate() {
                for th in 0..2 {
                    let e = self.alpha1 * x1 as f64
                        + self.alpha2 * x2 as f64
                        + self.q * th as f64
                        + th as f64 * (self.beta1 * x1 as f64 + self.beta2 * x2 as f64);
                    *cell += e.exp();
                }
            }
        }
        t
    }

    /// `P(θ=1 | x₁, x₂)` — the factor's dual conditional.
    #[inline]
    pub fn theta_logodds(&self, x1: bool, x2: bool) -> f64 {
        self.q + self.beta1 * (x1 as u8 as f64) + self.beta2 * (x2 as u8 as f64)
    }
}

/// 2×2 matrix helpers on `[[f64; 2]; 2]`.
#[inline]
fn det(m: &[[f64; 2]; 2]) -> f64 {
    m[0][0] * m[1][1] - m[0][1] * m[1][0]
}

#[cfg(test)]
fn matmul(a: &[[f64; 2]; 2], b: &[[f64; 2]; 2]) -> [[f64; 2]; 2] {
    let mut out = [[0.0; 2]; 2];
    for i in 0..2 {
        for j in 0..2 {
            out[i][j] = a[i][0] * b[0][j] + a[i][1] * b[1][j];
        }
    }
    out
}

#[cfg(test)]
fn transpose(m: &[[f64; 2]; 2]) -> [[f64; 2]; 2] {
    [[m[0][0], m[1][0]], [m[0][1], m[1][1]]]
}

/// Lemma 2 (+ Remark 1): `B` with `B Bᵀ = M` for symmetric positive `M`,
/// `det M ≥ 0`. All entries of `B` are strictly positive.
fn symmetric_sqrt_factor(m: &[[f64; 2]; 2]) -> [[f64; 2]; 2] {
    let (m11, m22, m12) = (m[0][0], m[1][1], m[0][1]);
    let ratio = (m12 / (m11 * m22).sqrt()).clamp(-1.0, 1.0);
    // Remark 1: cos/sin of φ = π/4 − arccos(ratio)/2 without trig calls.
    let cos_phi = 0.5 * ((1.0 + ratio).sqrt() + (1.0 - ratio).sqrt());
    let sin_phi = 0.5 * ((1.0 + ratio).sqrt() - (1.0 - ratio).sqrt());
    [
        [m11.sqrt() * cos_phi, m11.sqrt() * sin_phi],
        [m22.sqrt() * sin_phi, m22.sqrt() * cos_phi],
    ]
}

/// Factor a strictly positive table as `P = B Cᵀ`, both strictly positive.
///
/// Panics if any entry of `P` is non-positive or non-finite (the paper's
/// method requires strictly positive factors).
pub fn factorize_positive(p: &[[f64; 2]; 2]) -> ([[f64; 2]; 2], [[f64; 2]; 2]) {
    assert!(
        p.iter().flatten().all(|&v| v > 0.0 && v.is_finite()),
        "factorize_positive requires a strictly positive finite table: {p:?}"
    );

    // Lemma 4: swap rows if the determinant is negative.
    let swapped = det(p) < 0.0;
    let ps = if swapped {
        [[p[1][0], p[1][1]], [p[0][0], p[0][1]]]
    } else {
        *p
    };

    // Lemma 3: D = diag(1/ps12, 1/ps21) symmetrizes.
    let d = [1.0 / ps[0][1], 1.0 / ps[1][0]];
    let mut m = [
        [ps[0][0] * d[0], ps[0][1] * d[0]],
        [ps[1][0] * d[1], ps[1][1] * d[1]],
    ];
    // both off-diagonals equal 1 analytically; enforce bitwise
    m[1][0] = m[0][1];
    if det(&m) < 0.0 {
        // Can only be float roundoff: det(D·ps) = det(ps)/(ps12·ps21) ≥ 0.
        let safe = (m[0][0] * m[1][1]).sqrt() * (1.0 - 1e-12);
        m[0][1] = safe;
        m[1][0] = safe;
    }

    let bsym = symmetric_sqrt_factor(&m); // m = bsym bsymᵀ
    // ps = D⁻¹ m = (D⁻¹ bsym) bsymᵀ
    let mut b = [
        [bsym[0][0] / d[0], bsym[0][1] / d[0]],
        [bsym[1][0] / d[1], bsym[1][1] / d[1]],
    ];
    if swapped {
        b = [[b[1][0], b[1][1]], [b[0][0], b[0][1]]];
    }
    (b, bsym)
}

/// Theorem 2: dual parameters of a strictly positive 2×2 table.
pub fn dualize_table(p: &[[f64; 2]; 2]) -> DualFactor {
    let (b, c) = factorize_positive(p);
    DualFactor {
        alpha1: (b[1][0] / b[0][0]).ln(),
        alpha2: (c[1][0] / c[0][0]).ln(),
        q: (b[0][1] * c[0][1] / (b[0][0] * c[0][0])).ln(),
        beta1: (b[1][1] * b[0][0] / (b[0][1] * b[1][0])).ln(),
        beta2: (c[1][1] * c[0][0] / (c[0][1] * c[1][0])).ln(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};

    fn assert_reconstructs(p: &[[f64; 2]; 2], tol: f64) {
        let d = dualize_table(p);
        let t = d.table();
        let scale = t[0][0] / p[0][0];
        for i in 0..2 {
            for j in 0..2 {
                let rel = (t[i][j] / p[i][j] - scale).abs() / scale;
                assert!(rel < tol, "p={p:?} t={t:?} rel={rel}");
            }
        }
    }

    #[test]
    fn factorization_exact_on_examples() {
        let cases: [[[f64; 2]; 2]; 5] = [
            [[2.0, 1.0], [1.0, 2.0]],        // symmetric PSD (ferromagnetic)
            [[0.5, 2.0], [2.0, 0.5]],        // det < 0 (anti-ferromagnetic)
            [[1.0, 1.0], [1.0, 1.0]],        // rank one
            [[3.0, 0.1], [0.2, 5.0]],        // asymmetric
            [[1e-3, 1e3], [1e3, 1e-3]],      // extreme dynamic range
        ];
        for p in &cases {
            let (b, c) = factorize_positive(p);
            assert!(b.iter().flatten().all(|&v| v > 0.0), "{b:?}");
            assert!(c.iter().flatten().all(|&v| v > 0.0), "{c:?}");
            let r = matmul(&b, &transpose(&c));
            for i in 0..2 {
                for j in 0..2 {
                    assert!(
                        (r[i][j] - p[i][j]).abs() / p[i][j] < 1e-9,
                        "p={p:?} r={r:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn theorem2_reconstructs_examples() {
        assert_reconstructs(&[[2.0, 1.0], [1.0, 2.0]], 1e-9);
        assert_reconstructs(&[[0.5, 2.0], [2.0, 0.5]], 1e-9);
        assert_reconstructs(&[[3.0, 0.1], [0.2, 5.0]], 1e-9);
    }

    #[test]
    fn prop_factorization_positive_and_exact() {
        check("P = B C^T strictly positive", 500, |g: &mut Gen| {
            let p = g.positive_table(6.0);
            let (b, c) = factorize_positive(&p);
            if !b.iter().flatten().chain(c.iter().flatten()).all(|&v| v > 0.0) {
                return Err(format!("non-positive factor for {p:?}"));
            }
            let r = matmul(&b, &transpose(&c));
            for i in 0..2 {
                for j in 0..2 {
                    if (r[i][j] - p[i][j]).abs() / p[i][j] > 1e-8 {
                        return Err(format!("p={p:?} r={r:?}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_theorem2_reconstructs() {
        check("sum_theta dual == table", 500, |g: &mut Gen| {
            let p = g.positive_table(5.0);
            let d = dualize_table(&p);
            let t = d.table();
            let scale = t[0][0] / p[0][0];
            for i in 0..2 {
                for j in 0..2 {
                    if (t[i][j] / p[i][j] - scale).abs() / scale > 1e-7 {
                        return Err(format!("p={p:?} dual={d:?} t={t:?}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_dual_params_finite() {
        check("dual params finite", 300, |g: &mut Gen| {
            let p = g.positive_table(8.0);
            let d = dualize_table(&p);
            for v in [d.alpha1, d.alpha2, d.q, d.beta1, d.beta2] {
                if !v.is_finite() {
                    return Err(format!("p={p:?} d={d:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ising_duality_symmetric_params() {
        // symmetric table → B == C → α₁ == α₂ and β₁ == β₂
        let d = dualize_table(&[[1.5, 0.5], [0.5, 1.5]]);
        assert!((d.alpha1 - d.alpha2).abs() < 1e-12);
        assert!((d.beta1 - d.beta2).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "strictly positive")]
    fn rejects_nonpositive_tables() {
        factorize_positive(&[[1.0, -0.5], [1.0, 1.0]]);
    }

    #[test]
    fn theta_logodds_consistent_with_joint() {
        // P(θ=1|x) from the joint enumeration must equal sigmoid(theta_logodds)
        let p = [[2.0, 0.7], [0.6, 3.0]];
        let d = dualize_table(&p);
        for (x1, x2) in [(false, false), (false, true), (true, false), (true, true)] {
            let e = |th: f64| {
                (d.alpha1 * x1 as u8 as f64
                    + d.alpha2 * x2 as u8 as f64
                    + d.q * th
                    + th * (d.beta1 * x1 as u8 as f64 + d.beta2 * x2 as u8 as f64))
                    .exp()
            };
            let want = e(1.0) / (e(0.0) + e(1.0));
            let got = crate::rng::sigmoid(d.theta_logodds(x1, x2));
            assert!((want - got).abs() < 1e-12);
        }
    }
}
