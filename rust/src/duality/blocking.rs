//! Adaptive tree-blocking over the dual model (ROADMAP: "adaptive
//! dynamic blocking under churn").
//!
//! The paper's §5.4 shows blocking recovers most of the mixing the
//! parallel dual construction gives up, but leaves block choice to the
//! modeler. This module automates it in the spirit of *Dynamic Blocking
//! and Collapsing for Gibbs Sampling* (Venugopal & Gogate, `PAPERS.md`):
//! the engine keeps a cheap per-slot EWMA of endpoint agreement (how
//! often `x_{v1} == x_{v2}` across lanes — a direct proxy for the edge's
//! realized coupling strength, sign-free via `|2m − 1|`), and
//! [`BlockPlanner::plan`] greedily grows capped spanning-tree blocks
//! around the strongest edges using [`crate::util::UnionFind`].
//!
//! A planned block is resampled by one exact joint draw per sweep
//! (forward-filter/backward-sample over the tree in the engine), with
//! the block's tree duals marginalized out: summing a tree slot's
//! `θ ∈ {0, 1}` leaves the pairwise log-potential
//! `E(x₁, x₂) = softplus(q + β₁x₁ + β₂x₂)` ([`edge_table`]). Every
//! cross-block (and in-block non-tree) factor still routes through the
//! PD dual unchanged, so blocks never need to agree with each other
//! within a half-step — the paper's no-coordination selling point
//! survives blocking.
//!
//! Planning is deterministic and *canonical under slot renaming*:
//! candidate edges order by `(strength desc, min endpoint, max
//! endpoint, slot)`, so two engines whose churn histories net to the
//! same graph (with different slot assignments) produce the same blocks
//! over variables. Re-planning is lazy, on churn (`plan_stale`) or
//! every `epoch` sweeps — the same epoch idiom as `CsrIncidence`
//! compaction.

use crate::duality::DualModel;
use crate::util::UnionFind;

/// Agreement-strength floor for a slot to be considered as a tree edge:
/// `|2·ewma − 1|` must reach this. Freshly added slots start at EWMA
/// 0.5 (strength 0), so blocks only ever grow around *observed*
/// coupling, never around topology alone.
pub const BLOCK_SCORE_MIN: f64 = 0.05;

/// Knobs of the adaptive blocking policy (wire form
/// `blocked[:cap[:epoch]]`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BlockPolicy {
    /// Maximum variables per block (≥ 2; FFBS cost is linear in it).
    pub cap: usize,
    /// Re-plan period in sweeps (≥ 1); churn re-plans eagerly anyway.
    pub epoch: usize,
}

impl Default for BlockPolicy {
    fn default() -> Self {
        Self { cap: 8, epoch: 16 }
    }
}

/// One node of a block's spanning tree, in BFS order (`nodes[0]` is the
/// root; every parent index precedes its children).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BlockNode {
    /// The primal variable at this node.
    pub v: u32,
    /// Index of the parent node in `Block::nodes` (`u32::MAX` at root).
    pub parent: u32,
    /// The tree slot connecting this node to its parent (root: unused).
    pub slot: u32,
}

/// A capped tree-block: a connected set of variables whose spanning
/// tree is drawn jointly, tree duals marginalized.
#[derive(Clone, Debug, PartialEq)]
pub struct Block {
    /// BFS-ordered tree nodes; `nodes[0]` is the root (the block's
    /// minimum variable id, which also keys the block's RNG stream).
    pub nodes: Vec<BlockNode>,
    /// The block's tree slots, sorted — excluded from the per-node dual
    /// field during the joint draw (they are marginalized instead).
    pub tree_slots: Vec<u32>,
}

impl Block {
    /// The root variable (minimum var id in the block).
    #[inline]
    pub fn root(&self) -> u32 {
        self.nodes[0].v
    }

    /// Whether `slot` is one of this block's marginalized tree slots.
    #[inline]
    pub fn is_tree_slot(&self, slot: u32) -> bool {
        self.tree_slots.binary_search(&slot).is_ok()
    }
}

/// One unit of the blocked x half-step. Units partition the variables,
/// so pooled chunks over units own disjoint state rows — the same
/// disjointness the per-variable chunks rely on.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SweepUnit {
    /// A multi-variable tree block (index into [`BlockPlan::blocks`]).
    Block(u32),
    /// A singleton variable swept by the ordinary per-site path.
    Var(u32),
}

/// The planner's output: blocks plus the unit sequence that partitions
/// all variables (emitted in ascending order of each unit's first
/// variable, so the sequence is canonical).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BlockPlan {
    /// The tree blocks, in ascending root-variable order.
    pub blocks: Vec<Block>,
    /// All sweep units: every variable appears in exactly one.
    pub units: Vec<SweepUnit>,
    /// Total tree slots across blocks (the FFBS surcharge driver).
    pub tree_slots: usize,
}

impl BlockPlan {
    /// Number of multi-variable blocks.
    #[inline]
    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Number of variables covered by blocks (vs singletons).
    pub fn blocked_vars(&self) -> usize {
        self.blocks.iter().map(|b| b.nodes.len()).sum()
    }

    /// Slot-renaming-invariant view for determinism tests: per block
    /// (sorted variable ids, sorted `(min, max)` tree-edge endpoint
    /// pairs), blocks sorted by root. Two plans over the same logical
    /// graph compare equal here even when churn-order differences gave
    /// the underlying slots different ids.
    pub fn canonical(&self) -> Vec<(Vec<u32>, Vec<(u32, u32)>)> {
        self.blocks
            .iter()
            .map(|b| {
                let mut vars: Vec<u32> = b.nodes.iter().map(|n| n.v).collect();
                vars.sort_unstable();
                let mut edges: Vec<(u32, u32)> = b
                    .nodes
                    .iter()
                    .skip(1)
                    .map(|n| {
                        let p = b.nodes[n.parent as usize].v;
                        (n.v.min(p), n.v.max(p))
                    })
                    .collect();
                edges.sort_unstable();
                (vars, edges)
            })
            .collect()
    }
}

/// Grows capped tree-blocks around strongly-coupled clusters. Stateless
/// — the engine owns the agreement EWMAs and calls [`plan`](Self::plan)
/// on its churn/epoch schedule.
pub struct BlockPlanner;

impl BlockPlanner {
    /// Build a block plan from per-slot agreement statistics
    /// (`stats[slot]` = EWMA of the endpoint-agreement fraction; dead
    /// slots are skipped via the model's endpoint table).
    ///
    /// Deterministic: a pure function of `(model topology, stats,
    /// policy, clamped)`, with candidate ordering canonical under slot
    /// renaming (see module docs). Kruskal-style greedy with a
    /// component-size cap: an edge joins two components only when both
    /// are distinct and the merged block stays within `policy.cap`
    /// variables. Clamped sites never enter a block — evidence is a
    /// fixed boundary condition, so a joint tree draw over it would
    /// waste FFBS work (and its agreement EWMAs are neutral-reset by
    /// the engine anyway); an empty `clamped` slice means no evidence.
    pub fn plan(
        model: &DualModel,
        stats: &[f64],
        policy: BlockPolicy,
        clamped: &[bool],
    ) -> BlockPlan {
        let n = model.num_vars();
        let cap = policy.cap.max(2);
        let is_clamped = |v: usize| clamped.get(v).copied().unwrap_or(false);
        // (strength, min endpoint, max endpoint, slot) — strength is
        // finite by construction, so the f64 comparison is total here
        let mut cand: Vec<(f64, u32, u32, u32)> = Vec::new();
        for slot in 0..model.factor_slots() {
            let Some((v1, v2)) = model.slot_endpoints(slot) else {
                continue;
            };
            if v1 == v2 || is_clamped(v1 as usize) || is_clamped(v2 as usize) {
                continue;
            }
            let m = stats.get(slot).copied().unwrap_or(0.5);
            let strength = (2.0 * m - 1.0).abs();
            if strength >= BLOCK_SCORE_MIN {
                cand.push((strength, v1.min(v2), v1.max(v2), slot as u32));
            }
        }
        cand.sort_unstable_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap()
                .then(a.1.cmp(&b.1))
                .then(a.2.cmp(&b.2))
                .then(a.3.cmp(&b.3))
        });

        let mut uf = UnionFind::new(n);
        // accepted tree edges per variable: (neighbor, slot)
        let mut adj: Vec<Vec<(u32, u32)>> = vec![Vec::new(); n];
        let mut in_tree = vec![false; n];
        for &(_, a, b, slot) in &cand {
            let (a, b) = (a as usize, b as usize);
            if uf.find(a) == uf.find(b) {
                continue; // would close a cycle (or duplicate edge)
            }
            if uf.component_size(a) + uf.component_size(b) > cap {
                continue;
            }
            uf.union(a, b);
            adj[a].push((b as u32, slot));
            adj[b].push((a as u32, slot));
            in_tree[a] = true;
            in_tree[b] = true;
        }

        // materialize blocks by BFS from each component's minimum var
        let mut plan = BlockPlan::default();
        let mut block_of = vec![u32::MAX; n];
        for root in 0..n {
            if !in_tree[root] || block_of[root] != u32::MAX {
                continue;
            }
            let id = plan.blocks.len() as u32;
            let mut nodes = vec![BlockNode { v: root as u32, parent: u32::MAX, slot: 0 }];
            let mut tree_slots = Vec::new();
            block_of[root] = id;
            let mut head = 0;
            while head < nodes.len() {
                let (pv, pi) = (nodes[head].v as usize, head as u32);
                // children in ascending var order for a canonical BFS
                let mut kids: Vec<(u32, u32)> = adj[pv]
                    .iter()
                    .copied()
                    .filter(|&(c, _)| block_of[c as usize] == u32::MAX)
                    .collect();
                kids.sort_unstable();
                for (c, slot) in kids {
                    block_of[c as usize] = id;
                    nodes.push(BlockNode { v: c, parent: pi, slot });
                    tree_slots.push(slot);
                }
                head += 1;
            }
            tree_slots.sort_unstable();
            plan.tree_slots += tree_slots.len();
            plan.blocks.push(Block { nodes, tree_slots });
        }

        // unit sequence: ascending first-var order partitions [0, n)
        for v in 0..n {
            match block_of[v] {
                u32::MAX => plan.units.push(SweepUnit::Var(v as u32)),
                b if plan.blocks[b as usize].root() as usize == v => {
                    plan.units.push(SweepUnit::Block(b))
                }
                _ => {} // non-root member: covered by its block's unit
            }
        }
        plan
    }
}

/// Overflow-safe `softplus(z) = ln(1 + e^z)`.
#[inline]
pub(crate) fn softplus(z: f64) -> f64 {
    z.max(0.0) + (-z.abs()).exp().ln_1p()
}

/// The marginalized tree-edge log-potential table for `slot`:
/// `t[xc * 2 + xp] = softplus(q + β_child·xc + β_parent·xp)`, oriented
/// so the child endpoint indexes the high bit regardless of which of
/// `(v1, v2)` the child is. Lane-independent — computed once per block
/// draw and shared by every lane's FFBS pass.
pub(crate) fn edge_table(model: &DualModel, slot: u32, child: u32) -> [f64; 4] {
    let e = model.entry(slot as usize).expect("tree slot must be live");
    let (bc, bp) = if e.v1 == child as usize {
        (e.beta1, e.beta2)
    } else {
        debug_assert_eq!(e.v2, child as usize, "child must be an endpoint");
        (e.beta2, e.beta1)
    };
    [
        softplus(e.q),
        softplus(e.q + bp),
        softplus(e.q + bc),
        softplus(e.q + bc + bp),
    ]
}

/// The marginalized K-state tree-edge log-potential for `slot`. Under
/// the Potts convention the slot carries one indicator dual per state
/// (`θ_s` fires on `x_c = s ∧ x_p = s`), so summing all k of them out
/// leaves `E(x_c, x_p) = Σ_s softplus(q + β₁·1[x_c = s] + β₂·1[x_p = s])`
/// — which collapses to two values: `E_eq` when the endpoints agree
/// (one state sees both betas, the other `k − 1` see neither) and
/// `E_ne` when they differ (each endpoint's state sees its own beta).
/// Both are symmetric in `(β₁, β₂)`, so unlike the binary
/// [`edge_table`] no child orientation is needed. Returned as
/// `(E_eq, E_ne)`; lane-independent, computed once per block draw.
pub(crate) fn edge_table_k(model: &DualModel, slot: u32, k: usize) -> (f64, f64) {
    let e = model.entry(slot as usize).expect("tree slot must be live");
    let eq = softplus(e.q + e.beta1 + e.beta2) + (k - 1) as f64 * softplus(e.q);
    let ne =
        softplus(e.q + e.beta1) + softplus(e.q + e.beta2) + (k - 2) as f64 * softplus(e.q);
    (eq, ne)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{FactorGraph, PairFactor};
    use crate::workloads;

    fn model(g: &FactorGraph) -> DualModel {
        DualModel::from_graph(g)
    }

    /// Stats vector with every live slot at agreement `a`.
    fn flat_stats(m: &DualModel, a: f64) -> Vec<f64> {
        vec![a; m.factor_slots()]
    }

    #[test]
    fn neutral_stats_produce_no_blocks() {
        let g = workloads::ising_grid(3, 3, 0.5, 0.0);
        let m = model(&g);
        let plan = BlockPlanner::plan(&m, &flat_stats(&m, 0.5), BlockPolicy::default(), &[]);
        assert_eq!(plan.num_blocks(), 0);
        assert_eq!(plan.tree_slots, 0);
        assert_eq!(plan.units.len(), m.num_vars());
        for (v, u) in plan.units.iter().enumerate() {
            assert_eq!(*u, SweepUnit::Var(v as u32));
        }
    }

    #[test]
    fn strong_stats_grow_capped_trees_partitioning_the_vars() {
        let g = workloads::ising_grid(3, 3, 0.5, 0.0);
        let m = model(&g);
        for cap in [2usize, 4, 9] {
            let policy = BlockPolicy { cap, epoch: 16 };
            let plan = BlockPlanner::plan(&m, &flat_stats(&m, 0.95), policy, &[]);
            assert!(plan.num_blocks() >= 1, "cap {cap}: no blocks grown");
            let mut seen = vec![false; m.num_vars()];
            for u in &plan.units {
                match *u {
                    SweepUnit::Var(v) => {
                        assert!(!seen[v as usize]);
                        seen[v as usize] = true;
                    }
                    SweepUnit::Block(b) => {
                        let blk = &plan.blocks[b as usize];
                        assert!(blk.nodes.len() <= cap, "cap {cap} violated");
                        assert_eq!(blk.tree_slots.len(), blk.nodes.len() - 1, "tree edge count");
                        for n in &blk.nodes {
                            assert!(!seen[n.v as usize]);
                            seen[n.v as usize] = true;
                        }
                    }
                }
            }
            assert!(seen.iter().all(|&s| s), "cap {cap}: units must partition");
        }
        // uncapped-by-size (cap = n): strong stats on a connected grid
        // grow one spanning block
        let plan =
            BlockPlanner::plan(&m, &flat_stats(&m, 0.95), BlockPolicy { cap: 9, epoch: 16 }, &[]);
        assert_eq!(plan.blocked_vars(), 9);
        assert_eq!(plan.tree_slots, 8);
    }

    #[test]
    fn bfs_order_keeps_parents_before_children() {
        let g = workloads::ising_grid(3, 3, 0.5, 0.0);
        let m = model(&g);
        let plan =
            BlockPlanner::plan(&m, &flat_stats(&m, 0.05), BlockPolicy { cap: 9, epoch: 1 }, &[]);
        for blk in &plan.blocks {
            assert_eq!(blk.nodes[0].parent, u32::MAX);
            assert_eq!(blk.root(), blk.nodes.iter().map(|n| n.v).min().unwrap());
            for (i, n) in blk.nodes.iter().enumerate().skip(1) {
                assert!((n.parent as usize) < i, "parent must precede child");
                assert!(blk.is_tree_slot(n.slot));
            }
        }
    }

    #[test]
    fn plans_are_canonical_under_slot_renaming() {
        // same logical graph, two slot assignments: build by adding
        // factors in different orders
        let mk = |order: &[(usize, usize, f64)]| {
            let mut g = FactorGraph::new(4);
            for &(a, b, beta) in order {
                g.add_factor(PairFactor::ising(a, b, beta));
            }
            model(&g)
        };
        let edges = [(0usize, 1usize, 0.8), (1, 2, 0.8), (2, 3, 0.8)];
        let mut rev = edges;
        rev.reverse();
        let m1 = mk(&edges);
        let m2 = mk(&rev);
        // per-slot stats keyed by ENDPOINTS, not slot id, to model two
        // engines that observed the same physical graph
        let by_endpoints = |m: &DualModel| -> Vec<f64> {
            (0..m.factor_slots())
                .map(|s| {
                    let (v1, v2) = m.slot_endpoints(s).unwrap();
                    0.80 + 0.03 * v1.min(v2) as f64 // distinct per edge
                })
                .collect()
        };
        let policy = BlockPolicy { cap: 3, epoch: 16 };
        let p1 = BlockPlanner::plan(&m1, &by_endpoints(&m1), policy, &[]);
        let p2 = BlockPlanner::plan(&m2, &by_endpoints(&m2), policy, &[]);
        assert_eq!(p1.canonical(), p2.canonical());
        assert!(p1.num_blocks() >= 1);
    }

    #[test]
    fn anti_correlated_edges_score_by_strength_not_agreement() {
        // agreement near 0 (anti-ferromagnetic lock-step) is as strong a
        // coupling signal as agreement near 1
        let mut g = FactorGraph::new(2);
        g.add_factor(PairFactor::ising(0, 1, -1.0));
        let m = model(&g);
        let plan = BlockPlanner::plan(&m, &[0.03], BlockPolicy::default(), &[]);
        assert_eq!(plan.num_blocks(), 1);
        assert_eq!(plan.blocked_vars(), 2);
    }

    #[test]
    fn dead_slots_and_weak_edges_are_skipped() {
        let mut g = workloads::ising_grid(2, 2, 0.6, 0.0);
        let victim = g.factors().next().unwrap().0;
        let mut m = model(&g);
        m.remove(victim).unwrap();
        let mut stats = flat_stats(&m, 0.9);
        stats[victim] = 0.9; // stale stat on a dead slot must be ignored
        let plan = BlockPlanner::plan(&m, &stats, BlockPolicy::default(), &[]);
        for blk in &plan.blocks {
            assert!(!blk.is_tree_slot(victim as u32));
        }
        // weak: strength below the floor
        let weak = BlockPlanner::plan(&m, &flat_stats(&m, 0.51), BlockPolicy::default(), &[]);
        assert_eq!(weak.num_blocks(), 0);
    }

    #[test]
    fn edge_table_orients_child_and_parent_consistently() {
        let mut g = FactorGraph::new(2);
        g.add_factor(PairFactor::ising(0, 1, 0.7));
        let m = model(&g);
        let e = m.entry(0).unwrap();
        let t01 = edge_table(&m, 0, e.v1 as u32); // child = v1
        let t10 = edge_table(&m, 0, e.v2 as u32); // child = v2
        // swapping child/parent transposes the 2×2 table
        assert_eq!(t01[0], t10[0]);
        assert_eq!(t01[3], t10[3]);
        assert!((t01[1] - t10[2]).abs() < 1e-15);
        assert!((t01[2] - t10[1]).abs() < 1e-15);
        // and softplus is the exact θ marginalization
        for (idx, &t) in t01.iter().enumerate() {
            let (xc, xp) = ((idx >> 1) as f64, (idx & 1) as f64);
            let z = e.q + e.beta1 * xc + e.beta2 * xp;
            assert!((t - (1.0 + z.exp()).ln()).abs() < 1e-12);
        }
    }

    #[test]
    fn clamped_sites_are_excluded_from_blocks() {
        let g = workloads::ising_grid(3, 3, 0.5, 0.0);
        let m = model(&g);
        // center clamped: no block may contain var 4, but the border
        // ring can still form blocks
        let mut clamped = vec![false; 9];
        clamped[4] = true;
        let plan = BlockPlanner::plan(&m, &flat_stats(&m, 0.95), BlockPolicy::default(), &clamped);
        assert!(plan.num_blocks() >= 1, "border must still block");
        for blk in &plan.blocks {
            assert!(blk.nodes.iter().all(|n| n.v != 4), "clamped site entered a block");
        }
        // every site clamped: no blocks at all
        let none = BlockPlanner::plan(&m, &flat_stats(&m, 0.95), BlockPolicy::default(), &[true; 9]);
        assert_eq!(none.num_blocks(), 0);
        assert_eq!(none.units.len(), 9);
    }

    #[test]
    fn edge_table_k_matches_the_explicit_marginalization() {
        let mut g = FactorGraph::new_k(2, 3);
        g.add_factor(PairFactor::potts(0, 1, 0.7));
        let m = model(&g);
        let e = m.entry(0).unwrap();
        for k in [3usize, 5, 8] {
            let (eq, ne) = edge_table_k(&m, 0, k);
            // explicit Σ_s softplus(q + β₁·1[xc=s] + β₂·1[xp=s])
            let explicit = |xc: usize, xp: usize| -> f64 {
                (0..k)
                    .map(|s| {
                        let z = e.q
                            + if xc == s { e.beta1 } else { 0.0 }
                            + if xp == s { e.beta2 } else { 0.0 };
                        softplus(z)
                    })
                    .sum()
            };
            assert!((eq - explicit(0, 0)).abs() < 1e-12, "k={k} E_eq");
            assert!((ne - explicit(0, 1)).abs() < 1e-12, "k={k} E_ne");
            // symmetry: any agreeing pair gives E_eq, any differing E_ne
            assert!((explicit(2, 2) - eq).abs() < 1e-12);
            assert!((explicit(2, 1) - ne).abs() < 1e-12);
            assert!((explicit(1, 2) - ne).abs() < 1e-12);
        }
    }

    #[test]
    fn softplus_is_overflow_safe() {
        assert_eq!(softplus(-800.0), 0.0);
        assert!((softplus(800.0) - 800.0).abs() < 1e-12);
        assert!((softplus(0.0) - 2f64.ln()).abs() < 1e-15);
    }
}
