//! §4.3: Swendsen–Wang and Higdon partial-SW as degenerate dualizations.
//!
//! For the Ising factor `P ∝ [[1, e^{-w}], [e^{-w}, 1]]` (w ≥ 0) the paper
//! exhibits the additive decomposition
//!
//! `P = e^{-w}·𝟙 + (1 − e^{-w})·I`,
//!
//! i.e. a dual `θ_e ∈ {0, 1}` with `g(0) = e^{-w}` (free component) and
//! `g(1) = 1 − e^{-w}` (bond: hard agreement constraint). Conditionals:
//!
//! * `p(θ_e = 1 | x) = (1 − e^{-w}) / 1 = 1 − e^{-w}` when `x_{e₁} = x_{e₂}`,
//!   and 0 otherwise — the classic bond-percolation step;
//! * `p(x | θ)` is uniform over cluster-consistent configurations — sampled
//!   by flipping each connected component fairly.
//!
//! Higdon's partial SW keeps `α` of the diagonal mass unconstrained:
//! `P = [[1−α, e^{-w}], [e^{-w}, 1−α]] + α·I`, giving a 3-state dual once
//! the first (still positive, provided `α < 1 − e^{-w}`… see
//! [`HigdonDual::new`]) term is itself dualized by Theorem 2 — this is how
//! the paper circumvents Higdon's coarse-model sampling step.

use super::factorization::{dualize_table, DualFactor};

/// SW bond activation probability for an Ising factor of coupling `w ≥ 0`
/// (table `[[1, e^{-w}], [e^{-w}, 1]]`, equivalently `β = w/2` in the
/// symmetric parametrization used by [`crate::graph::PairFactor::ising`]).
#[inline]
pub fn bond_probability(w: f64) -> f64 {
    assert!(w >= 0.0, "SW requires ferromagnetic couplings");
    1.0 - (-w).exp()
}

/// Convert a symmetric Ising table `[[e^β, e^{-β}], [e^{-β}, e^β]]` to the
/// SW normal form weight `w = 2β` (table scaled by `e^{-β}`).
pub fn ising_w_from_table(table: &[[f64; 2]; 2]) -> Option<f64> {
    let sym = (table[0][0] - table[1][1]).abs() < 1e-12 * table[0][0].abs()
        && (table[0][1] - table[1][0]).abs() < 1e-12 * table[0][1].abs().max(1e-300);
    if !sym {
        return None;
    }
    let w = (table[0][0] / table[0][1]).ln();
    if w >= 0.0 {
        Some(w)
    } else {
        None // anti-ferromagnetic: SW does not apply
    }
}

/// Higdon partial-SW dual of an Ising factor: a 3-state θ.
///
/// State 0/1 come from the Theorem-2 dualization of the *soft* part
/// `[[1−α, e^{-w}], [e^{-w}, 1−α]]`; state 2 is the hard bond with mass α.
#[derive(Clone, Debug)]
pub struct HigdonDual {
    /// Theorem-2 dual of the soft residual table.
    pub soft: DualFactor,
    /// Soft residual table (strictly positive by construction).
    pub soft_table: [[f64; 2]; 2],
    /// Mass of the hard-agreement component.
    pub alpha: f64,
    /// Total mass `w` of the decomposed Ising factor.
    pub w: f64,
}

impl HigdonDual {
    /// `alpha` must leave the residual strictly positive *and* PSD-able:
    /// `0 ≤ α < 1 − e^{-w}`. `alpha = 0` degenerates to pure Theorem-2;
    /// `alpha → 1 − e^{-w}` approaches classic SW.
    pub fn new(w: f64, alpha: f64) -> Self {
        assert!(w > 0.0);
        let max_alpha = 1.0 - (-w).exp();
        assert!(
            (0.0..max_alpha).contains(&alpha),
            "need 0 <= alpha < 1 - e^-w = {max_alpha}, got {alpha}"
        );
        let diag = 1.0 - alpha;
        let off = (-w).exp();
        let soft_table = [[diag, off], [off, diag]];
        Self {
            soft: dualize_table(&soft_table),
            soft_table,
            alpha,
            w,
        }
    }

    /// Unnormalized weights of the 3 dual states given endpoint values.
    /// Order: [soft θ=0, soft θ=1, hard bond].
    pub fn theta_weights(&self, x1: bool, x2: bool) -> [f64; 3] {
        // soft part: recompute the two mixture components from Theorem 2
        let e = |th: f64| {
            (self.soft.alpha1 * x1 as u8 as f64
                + self.soft.alpha2 * x2 as u8 as f64
                + self.soft.q * th
                + th * (self.soft.beta1 * x1 as u8 as f64
                    + self.soft.beta2 * x2 as u8 as f64))
                .exp()
        };
        // normalize the soft dual so its θ-sum equals the soft table entry
        let soft_cell = self.soft_table[x1 as usize][x2 as usize];
        let raw = [e(0.0), e(1.0)];
        let scale = soft_cell / (raw[0] + raw[1]);
        let hard = if x1 == x2 { self.alpha } else { 0.0 };
        [raw[0] * scale, raw[1] * scale, hard]
    }

    /// Total mixture mass at `(x1, x2)` — must reproduce the Ising table.
    pub fn cell(&self, x1: bool, x2: bool) -> f64 {
        self.theta_weights(x1, x2).iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};

    #[test]
    fn bond_probability_limits() {
        assert!(bond_probability(0.0).abs() < 1e-15);
        assert!((bond_probability(1e9) - 1.0).abs() < 1e-12);
        let w = 0.8f64;
        assert!((bond_probability(w) - (1.0 - (-w).exp())).abs() < 1e-15);
    }

    #[test]
    fn w_from_table_roundtrip() {
        let beta = 0.35;
        let t = crate::graph::PairFactor::ising(0, 1, beta).table;
        let w = ising_w_from_table(&t).unwrap();
        assert!((w - 2.0 * beta).abs() < 1e-12);
        // anti-ferromagnetic rejected
        let t = crate::graph::PairFactor::ising(0, 1, -0.2).table;
        assert!(ising_w_from_table(&t).is_none());
        // asymmetric rejected
        assert!(ising_w_from_table(&[[1.0, 0.5], [0.4, 1.0]]).is_none());
    }

    #[test]
    fn higdon_reproduces_ising_table() {
        let w = 1.2;
        let h = HigdonDual::new(w, 0.3);
        assert!((h.cell(false, false) - 1.0).abs() < 1e-9);
        assert!((h.cell(true, true) - 1.0).abs() < 1e-9);
        assert!((h.cell(false, true) - (-w).exp()).abs() < 1e-9);
        assert!((h.cell(true, false) - (-w).exp()).abs() < 1e-9);
    }

    #[test]
    fn prop_higdon_valid_across_alpha() {
        check("higdon mixture valid", 100, |g: &mut Gen| {
            let w = g.f64_in(0.05, 3.0);
            let alpha = g.f64_in(0.0, (1.0 - (-w).exp()) * 0.999);
            let h = HigdonDual::new(w, alpha);
            for (x1, x2) in [(false, false), (false, true), (true, false), (true, true)] {
                let wts = h.theta_weights(x1, x2);
                if wts.iter().any(|&x| x < -1e-15) {
                    return Err(format!("negative weight w={w} a={alpha}"));
                }
                let want = if x1 == x2 { 1.0 } else { (-w).exp() };
                let got: f64 = wts.iter().sum();
                if (got - want).abs() > 1e-8 {
                    return Err(format!("cell mismatch {got} vs {want}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "alpha")]
    fn higdon_rejects_oversized_alpha() {
        HigdonDual::new(0.5, 0.9);
    }
}
