//! [`DualModel`]: the dualized MRF every sampler and the XLA runtime share.
//!
//! Maintains, incrementally under factor insertion/removal (Theorem 1):
//!
//! * `base_field[v]` — `unary_v + Σ_{i ∋ v} α_{i,slot(v)}`: the primal
//!   conditional is `P(x_v=1 | θ) = σ(base_field[v] + Σ_{i ∋ v} θ_i β_{i,v})`.
//! * per-factor dual parameters `(q_i, β_{i,1}, β_{i,2})`: the dual
//!   conditional is `P(θ_i=1 | x) = σ(q_i + β_{i,1} x_{v₁} + β_{i,2} x_{v₂})`.
//! * nested incidence (`var → [(factor, β)]`) — the *reference*
//!   implementation used by the scalar samplers and tests — mirrored by a
//!   flat [`CsrIncidence`] arena (+ delta overlay, epoch compaction) that
//!   the lane engine's hot kernels read instead, and a dense export
//!   (`J`, `a`, `q`, `β`, endpoints) for the AOT artifacts.
//! * derived per-site conditional caches, invalidated only on churn: the
//!   four-sigmoid θ table per live factor slot ([`DualModel::theta_table`])
//!   and, for low-degree variables, the full `2^deg` table of Bernoulli
//!   acceptance parts over θ-bit patterns ([`DualModel::x_table`]).
//!
//! The *entire* preprocessing for a new factor is one 2×2 factorization,
//! two adjacency pushes, and an O(degree)-bounded cache refresh — this is
//! the "almost no preprocessing" claim that the dynamic benchmark
//! quantifies against graph-coloring repair.

use super::csr::{CsrIncidence, XTableArena};
use super::factorization::{dualize_table, DualFactor};
use crate::graph::{FactorGraph, FactorId, PairFactor, VarId};
use crate::rng::{bernoulli_sigmoid_parts, sigmoid_fast, RngCore};

/// Largest view length for which [`DualModel::x_table`] is materialized:
/// `2^6 = 64` cached entries at most, indexable by a `u8` gather.
const X_TABLE_MAX_DEG: usize = 6;

/// Knobs for minibatched x-site updates (De Sa, Chen & Wong 2018: factor
/// subsampling with a Poisson/MIN-Gibbs auxiliary correction that keeps
/// the chain exact). Defined here rather than in `engine` because the
/// model owns the per-site [`MbPlan`] caches rebuilt under churn; the
/// engine wraps this in its `SweepPolicy`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MinibatchPolicy {
    /// Sites at or below this live degree keep the exact full-incidence
    /// update; only higher-degree sites get a subsampling plan.
    pub degree_threshold: usize,
    /// λ = max(`lambda_min`, `lambda_scale · L²`) where `L` is the site's
    /// maintained total-coupling bound ([`DualModel::coupling_l1`]);
    /// λ = Θ(L²) matches the minibatch-Gibbs guidance for mixing
    /// comparable to the full chain. Any λ > 0 is exact.
    pub lambda_scale: f64,
    /// Floor for λ — keeps κ = λ/(λ+L) away from 0 on weakly-coupled hubs.
    pub lambda_min: f64,
    /// θ half-step refresh stride: slot `s` is refreshed on sweeps where
    /// `s % stride == sweep % stride` (a deterministic cyclic schedule of
    /// valid Gibbs kernels, so exactness is preserved; untouched slots
    /// keep their state and consume no randomness, making trajectories
    /// pool-invariant). `1` = every slot every sweep.
    pub theta_stride: usize,
}

impl Default for MinibatchPolicy {
    fn default() -> Self {
        Self {
            degree_threshold: 64,
            lambda_scale: 1.0,
            lambda_min: 4.0,
            theta_stride: 8,
        }
    }
}

/// Subsampling plan for one high-degree site: a Vose alias table over the
/// site's couplings `|β_j|` plus the constants of the Poisson auxiliary
/// correction. Built (and rebuilt on churn) by [`DualModel`]; consumed by
/// the lane engine's minibatch site update:
///
/// draw `N ~ Poisson(rate)` per lane, alias-pick `N` entries `∝ |β_j|`,
/// thin each with probability `κ + (1-κ)·t_j` where `t_j ∈ {0,1}` is the
/// deterministic bit test `θ_j ∧ x_v` (complemented for `β_j < 0`), and
/// add `sign(β_j)·c` to the site log-odds for every kept event with
/// `θ_j = 1`. The marginal of the resulting draw over the auxiliary
/// counts is exactly the site conditional — validated end-to-end by the
/// statistical harness.
#[derive(Clone, Debug)]
pub struct MbPlan {
    /// Alias-method acceptance probability per entry.
    prob: Vec<f64>,
    /// Alias-method redirect target per entry.
    alias: Vec<u32>,
    /// Factor slot of each entry (plan-local index → slot id).
    slot: Vec<u32>,
    /// Whether the entry's β at this endpoint is negative.
    neg: Vec<bool>,
    /// Exact `L = Σ |β_j|` over the entries this plan was built from
    /// (recomputed at build time, immune to incremental-counter drift —
    /// `rate`/`kappa`/`c` below must be mutually consistent with it).
    l1: f64,
    /// Poisson mean per lane: `λ + L`.
    rate: f64,
    /// Thinning keep-probability for failed bit tests: `λ / (λ + L)`.
    kappa: f64,
    /// Per-kept-event log-odds magnitude: `ln(1 + L/λ)`.
    c: f64,
    /// Expected events per lane, rounded up — the unit the repriced
    /// sweep cost charges instead of the full degree.
    batch: u64,
    /// `degree - min(degree, batch)`: this plan's contribution to
    /// [`DualModel::mb_saved`], remembered so removal stays O(1).
    saved: u64,
}

impl MbPlan {
    /// Poisson mean per lane (`λ + L`).
    #[inline]
    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Thinning keep-probability `λ / (λ + L)` for events whose
    /// deterministic bit test fails.
    #[inline]
    pub fn kappa(&self) -> f64 {
        self.kappa
    }

    /// Per-kept-event log-odds magnitude `ln(1 + L/λ)`.
    #[inline]
    pub fn c(&self) -> f64 {
        self.c
    }

    /// Exact total coupling `L = Σ |β_j|` the plan was built from.
    #[inline]
    pub fn l1(&self) -> f64 {
        self.l1
    }

    /// Expected events per lane, rounded up.
    #[inline]
    pub fn batch(&self) -> u64 {
        self.batch
    }

    /// Number of subsampled entries (the site's nonzero-β degree).
    #[inline]
    pub fn len(&self) -> usize {
        self.slot.len()
    }

    /// True when the plan has no entries (never stored by the model).
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.slot.is_empty()
    }

    /// Alias-pick one entry with probability `|β_j| / L`; returns its
    /// `(factor slot, β < 0)`. Consumes exactly one uniform.
    #[inline]
    pub fn pick<R: RngCore>(&self, rng: &mut R) -> (u32, bool) {
        let u = rng.next_f64() * self.prob.len() as f64;
        let i = (u as usize).min(self.prob.len() - 1);
        let j = if u - i as f64 < self.prob[i] {
            i
        } else {
            self.alias[i] as usize
        };
        (self.slot[j], self.neg[j])
    }
}

/// Dual parameters + endpoints of one live factor.
#[derive(Clone, Copy, Debug)]
pub struct DualEntry {
    /// First endpoint variable.
    pub v1: VarId,
    /// Second endpoint variable.
    pub v2: VarId,
    /// The dual's prior log-odds (Theorem 2).
    pub q: f64,
    /// Coupling of θ to `x_{v1}`.
    pub beta1: f64,
    /// Coupling of θ to `x_{v2}`.
    pub beta2: f64,
    /// Base-field contribution absorbed into `v1`'s field.
    pub alpha1: f64,
    /// Base-field contribution absorbed into `v2`'s field.
    pub alpha2: f64,
}

/// The dualized model (see module docs).
///
/// ## K-state (Potts) duals — §4.2 indicator encoding
///
/// For a `k > 2` graph every factor is a Potts coupling
/// `exp(β·1[x₁ = x₂])` (the graph enforces the convention). Writing the
/// agreement over the 0–1 indicator encoding `z_{v,s} = 1[x_v = s]`,
///
/// `exp(β·1[x₁ = x₂]) = Π_{s<k} exp(β · z_{1,s} z_{2,s})`,
///
/// each of the `k` sub-factors is the binary-product table
/// `[[1, 1], [1, e^β]]` over `(z_{1,s}, z_{2,s})` — strictly positive for
/// *any* sign of β — and dualizes with its own binary auxiliary
/// `θ_{i,s}` through the ordinary Theorem 2 factorization. All `k`
/// sub-factors share one table, hence ONE `(q, β₁, β₂)` triple per
/// factor (one [`DualEntry`], one cached four-sigmoid θ table), but `k`
/// θ bit-planes per slot in the engine. The payoff is that the paper's
/// conditional-independence structure survives: given θ, the site
/// conditional is the softmax of `score(s) = Σ_{i ∋ v} θ_{i,s} β_{i,v}`
/// — no x–x dependence — and `P(θ_{i,s} = 1 | x) =
/// σ(q + β₁·1[x₁ = s] + β₂·1[x₂ = s])` reuses the binary θ draw with
/// indicator words in place of state bits. The α base-field parts of the
/// factorization shift every state's score equally (`Σ_s z_{v,s} = 1`),
/// so K-state entries zero them and leave the base field untouched.
/// Binary graphs keep the general 2×2 factorization path byte-for-byte.
#[derive(Clone, Debug)]
pub struct DualModel {
    base_field: Vec<f64>,
    entries: Vec<Option<DualEntry>>,
    free: Vec<usize>,
    /// `incidence[v]` = (factor slot, β contribution of that factor to v).
    /// Reference structure; `csr` is its flat hot-path mirror.
    incidence: Vec<Vec<(u32, f64)>>,
    /// Flat CSR arena + delta overlay mirroring `incidence`.
    csr: CsrIncidence,
    /// `σ_fast(q + β·pattern)` per factor slot, indexed by the two
    /// endpoint bits; recomputed only on insert (dead slots stay inert).
    theta_tables: Vec<[f64; 4]>,
    /// Flat factor endpoints (`u32::MAX` = dead slot) so the θ half-step
    /// reads 8 contiguous bytes instead of an 80-byte `Option<DualEntry>`.
    slot_v1: Vec<u32>,
    slot_v2: Vec<u32>,
    /// Per-variable Bernoulli acceptance parts over θ-bit patterns, in the
    /// exact iteration order of `csr.view(v)`, stored as a tile-aligned
    /// structure-of-arrays arena ([`XTableArena`]: flat `mult`/`thresh`
    /// streams, every table on a cache-line boundary) so the lane
    /// kernels' gather reads two homogeneous arrays. No table when the
    /// view is longer than [`X_TABLE_MAX_DEG`]. Rebuilt on churn at the
    /// endpoints.
    x_tables: XTableArena,
    active: usize,
    /// Minibatch policy; `None` = every site updates over its full
    /// incidence (the default).
    mb: Option<MinibatchPolicy>,
    /// Per-variable subsampling plans (empty unless `mb` is set; `None`
    /// entries are sites below the degree threshold). Rebuilt at the same
    /// churn points as the x-tables.
    mb_plans: Vec<Option<Box<MbPlan>>>,
    /// Per-variable `Σ |β|` over live incidence, maintained incrementally
    /// under churn (O(1) per insert/remove) and re-anchored to the exact
    /// sum whenever a plan is rebuilt. Only sizes λ and gates policy
    /// decisions — plan exactness never depends on it.
    coupling_l1: Vec<f64>,
    /// `Σ_v (degree(v) - min(degree(v), batch(v)))` over planned sites:
    /// the incidence visits the minibatch path skips per sweep, kept as a
    /// counter so repriced sweep cost stays O(1).
    mb_saved: u64,
    /// States per primal variable (2 = binary, the general-table dual;
    /// > 2 = Potts indicator dual with `k` θ-planes per slot, see the
    /// struct docs).
    k: usize,
}

impl Default for DualModel {
    fn default() -> Self {
        Self::new(Vec::new())
    }
}

impl DualModel {
    /// Dualize every factor of a graph (one factorization per factor),
    /// inheriting its variable cardinality.
    pub fn from_graph(g: &FactorGraph) -> Self {
        let n = g.num_vars();
        let mut m = Self::new_k((0..n).map(|v| g.unary(v)).collect(), g.k());
        for (id, f) in g.factors() {
            // bulk build: defer x-table refreshes and compaction — the
            // single compaction below builds each churned table once
            m.insert_at_inner(id, f, false);
        }
        // leave a clean arena: every incidence read is one contiguous
        // slice, no overlay, until the first post-build mutation
        m.compact_incidence();
        m
    }

    /// Empty binary model over `n` variables with the given unary log-odds.
    pub fn new(unary: Vec<f64>) -> Self {
        Self::new_k(unary, 2)
    }

    /// Empty `k`-state model. For `k > 2` the unary log-odds must all be
    /// zero (the graph layer enforces the same invariant).
    pub fn new_k(unary: Vec<f64>, k: usize) -> Self {
        assert!(
            (2..=crate::graph::MAX_STATES).contains(&k),
            "variable cardinality must be 2..={}, got {k}",
            crate::graph::MAX_STATES
        );
        assert!(
            k == 2 || unary.iter().all(|&u| u == 0.0),
            "unary fields are not defined for k={k} models"
        );
        let n = unary.len();
        let mut m = Self {
            base_field: unary,
            entries: Vec::new(),
            free: Vec::new(),
            incidence: vec![Vec::new(); n],
            csr: CsrIncidence::new(n),
            theta_tables: Vec::new(),
            slot_v1: Vec::new(),
            slot_v2: Vec::new(),
            x_tables: XTableArena::new(n),
            active: 0,
            mb: None,
            mb_plans: Vec::new(),
            coupling_l1: vec![0.0; n],
            mb_saved: 0,
            k,
        };
        for v in 0..n {
            m.rebuild_x_table(v);
        }
        m
    }

    /// States per primal variable (2 = binary).
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// Number of primal variables.
    pub fn num_vars(&self) -> usize {
        self.base_field.len()
    }

    /// Number of live factors.
    pub fn num_factors(&self) -> usize {
        self.active
    }

    /// Capacity of the factor slot space (dense export width).
    pub fn factor_slots(&self) -> usize {
        self.entries.len()
    }

    /// Per-sweep work of this model in site-visits, the unit the
    /// coordinator's fair-share scheduler charges tenants in: the x
    /// half-step walks every variable plus its live incidence (the CSR
    /// arena's live prefix-sum total, `2 · num_factors`), and the θ
    /// half-step visits every slot. O(1): all three totals are maintained
    /// counters.
    #[inline]
    pub fn sweep_cost(&self) -> u64 {
        (self.num_vars() + 2 * self.num_factors() + self.factor_slots()) as u64
    }

    /// [`DualModel::sweep_cost`] repriced for minibatched sweeps: planned
    /// sites are charged their expected batch instead of their degree
    /// (`mb_saved` visits dropped from the x half-step) and the θ
    /// half-step only visits `1/stride` of the slot space per sweep.
    /// O(1), like `sweep_cost` — the DRR scheduler calls this per grant.
    #[inline]
    pub fn minibatch_sweep_cost(&self, theta_stride: usize) -> u64 {
        let x = (2 * self.num_factors() as u64).saturating_sub(self.mb_saved);
        let theta = (self.factor_slots() as u64).div_ceil(theta_stride.max(1) as u64);
        self.num_vars() as u64 + x + theta
    }

    /// Install (or clear, with `None`) the minibatch policy and rebuild
    /// every site's subsampling plan against it. O(vars + incidence).
    /// Cardinality-agnostic: the plan geometry (alias tables, rates,
    /// acceptance constants) depends only on the incidence `|β|` mass,
    /// so one plan serves the binary and the per-state K > 2 thinning
    /// paths alike.
    pub fn set_minibatch(&mut self, policy: Option<MinibatchPolicy>) {
        self.mb = policy;
        self.mb_plans.clear();
        self.mb_saved = 0;
        if self.mb.is_some() {
            self.mb_plans.resize_with(self.num_vars(), || None);
            for v in 0..self.num_vars() {
                self.rebuild_mb_plan(v);
            }
        }
    }

    /// The installed minibatch policy, if any.
    #[inline]
    pub fn minibatch_policy(&self) -> Option<MinibatchPolicy> {
        self.mb
    }

    /// `v`'s subsampling plan — `Some` only when a policy is installed
    /// and `v`'s live degree exceeds its threshold (with nonzero total
    /// coupling). The engine's x half-step takes this path before the
    /// cached-table / accumulate dispatch.
    #[inline]
    pub fn mb_plan(&self, v: VarId) -> Option<&MbPlan> {
        self.mb_plans.get(v).and_then(|p| p.as_deref())
    }

    /// `v`'s maintained total-coupling bound `Σ |β|` (see the field docs:
    /// incrementally updated, re-anchored exactly on plan rebuilds).
    #[inline]
    pub fn coupling_l1(&self, v: VarId) -> f64 {
        self.coupling_l1[v]
    }

    /// Per-site x half-step weight for sweep chunk balancing: `1 + deg`
    /// for exact sites, `1 + min(deg, batch)` for planned sites (the
    /// minibatch path's cost no longer scales with degree).
    #[inline]
    pub fn x_visit_weight(&self, v: VarId) -> u64 {
        let deg = self.degree(v) as u64;
        match self.mb_plan(v) {
            Some(p) => 1 + deg.min(p.batch()),
            None => 1 + deg,
        }
    }

    /// Rebuild `v`'s subsampling plan from the live CSR view (no-op
    /// without a policy). Called wherever `rebuild_x_table` is: the two
    /// caches have identical invalidation points.
    fn rebuild_mb_plan(&mut self, v: VarId) {
        let Some(policy) = self.mb else { return };
        if let Some(old) = self.mb_plans[v].take() {
            self.mb_saved -= old.saved;
        }
        let deg = self.degree(v);
        if deg <= policy.degree_threshold {
            return;
        }
        // exact entries from the live view (base then overlay), skipping
        // zero couplings — they can never change the conditional
        let (slots, betas, overlay) = self.csr.view(v);
        let mut entry_slot = Vec::with_capacity(deg);
        let mut entry_beta = Vec::with_capacity(deg);
        for (&s, &b) in slots.iter().zip(betas).chain(
            overlay.iter().map(|(s, b)| (s, b)),
        ) {
            if b != 0.0 {
                entry_slot.push(s);
                entry_beta.push(b);
            }
        }
        let l1: f64 = entry_beta.iter().map(|b| b.abs()).sum();
        if l1 <= 0.0 {
            return; // all-zero couplings: the exact path is free anyway
        }
        // re-anchor the incremental counter, then size λ from it
        self.coupling_l1[v] = l1;
        let lambda = (policy.lambda_scale * l1 * l1).max(policy.lambda_min);
        debug_assert!(lambda > 0.0, "lambda_min must keep λ positive");
        let rate = lambda + l1;
        let kappa = lambda / rate;
        let c = (l1 / lambda).ln_1p();
        // Vose alias table over |β|
        let ne = entry_beta.len();
        let mut prob = vec![0.0f64; ne];
        let mut alias = vec![0u32; ne];
        let scale = ne as f64 / l1;
        let mut scaled: Vec<f64> = entry_beta.iter().map(|b| b.abs() * scale).collect();
        let mut small: Vec<usize> = Vec::new();
        let mut large: Vec<usize> = Vec::new();
        for (i, &s) in scaled.iter().enumerate() {
            if s < 1.0 {
                small.push(i);
            } else {
                large.push(i);
            }
        }
        while !small.is_empty() && !large.is_empty() {
            let s = small.pop().unwrap();
            let l = *large.last().unwrap();
            prob[s] = scaled[s];
            alias[s] = l as u32;
            scaled[l] = (scaled[l] + scaled[s]) - 1.0;
            if scaled[l] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        for &i in small.iter().chain(large.iter()) {
            prob[i] = 1.0; // roundoff leftovers: certain acceptance
            alias[i] = i as u32;
        }
        let batch = rate.ceil() as u64;
        let saved = (deg as u64).saturating_sub(batch);
        self.mb_saved += saved;
        self.mb_plans[v] = Some(Box::new(MbPlan {
            prob,
            alias,
            slot: entry_slot,
            neg: entry_beta.iter().map(|&b| b < 0.0).collect(),
            l1,
            rate,
            kappa,
            c,
            batch,
            saved,
        }));
    }

    /// The live dual entry in `slot`, or `None` for dead/unknown slots.
    pub fn entry(&self, slot: usize) -> Option<&DualEntry> {
        self.entries.get(slot).and_then(Option::as_ref)
    }

    /// Live `(slot, entry)` pairs in slot order.
    pub fn entries(&self) -> impl Iterator<Item = (usize, &DualEntry)> {
        self.entries
            .iter()
            .enumerate()
            .filter_map(|(i, e)| e.as_ref().map(|e| (i, e)))
    }

    /// `v`'s effective unary log-odds (unary + absorbed α's).
    pub fn base_field(&self, v: VarId) -> f64 {
        self.base_field[v]
    }

    /// Reference (nested) incidence list of `v`.
    pub fn incidence(&self, v: VarId) -> &[(u32, f64)] {
        &self.incidence[v]
    }

    /// Live degree of `v` (length of its reference incidence list) — the
    /// weight degree-aware sweep chunking balances on.
    #[inline]
    pub fn degree(&self, v: VarId) -> usize {
        self.incidence[v].len()
    }

    /// Hot-path incidence view of `v` from the flat arena:
    /// `(base slots, base βs, overlay)`. Both base slices contain only
    /// live entries (removal swap-compacts within the segment), so the
    /// view width always equals the live degree; see
    /// [`CsrIncidence::view`].
    #[inline]
    pub fn incidence_csr(&self, v: VarId) -> (&[u32], &[f64], &[(u32, f64)]) {
        self.csr.view(v)
    }

    /// Live CSR-overlay incidence of `v` as one list — must equal
    /// [`DualModel::incidence`] as a multiset; tested under churn.
    pub fn incidence_csr_logical(&self, v: VarId) -> Vec<(u32, f64)> {
        self.csr.logical(v)
    }

    /// Compaction generation of the incidence arena.
    pub fn csr_epoch(&self) -> u64 {
        self.csr.epoch()
    }

    /// Cached `σ_fast(q + β·bits)` table of a factor slot, indexed by the
    /// two endpoint bits (`x_{v1} | x_{v2} << 1`). Valid only while the
    /// slot is live; dead slots hold an inert all-zeros table.
    #[inline]
    pub fn theta_table(&self, slot: usize) -> &[f64; 4] {
        &self.theta_tables[slot]
    }

    /// Endpoints of a live factor slot, or `None` for a dead slot — the
    /// flat-array fast path the θ half-step uses instead of
    /// [`DualModel::entry`].
    #[inline]
    pub fn slot_endpoints(&self, slot: usize) -> Option<(u32, u32)> {
        let v1 = self.slot_v1[slot];
        if v1 == u32::MAX {
            None
        } else {
            Some((v1, self.slot_v2[slot]))
        }
    }

    /// Cached Bernoulli acceptance parts for `x_v`'s conditional, as
    /// parallel `(mult, thresh)` slices with one entry per θ-bit pattern
    /// of the CSR view (pattern bit `i` = entry `i` in `incidence_csr(v)`
    /// order, base then overlay; the view width is always the live
    /// degree). The slices come from the tile-aligned [`XTableArena`],
    /// so both start on a cache-line boundary. `None` when the degree
    /// exceeds [`X_TABLE_MAX_DEG`] and the caller must accumulate per
    /// lane instead.
    #[inline]
    pub fn x_table(&self, v: VarId) -> Option<(&[f64], &[f64])> {
        self.x_tables.get(v)
    }

    /// Rebuild `v`'s cached x-conditional table from the current CSR view.
    ///
    /// Pattern `m`'s log-odds is `base_field[v]` plus the view's βs folded
    /// in order over the set bits of `m` — the same fold order (and hence
    /// bit-identical draws) as the per-lane accumulate fallback.
    fn rebuild_x_table(&mut self, v: VarId) {
        if self.k > 2 {
            // K-state sites always take the categorical accumulate path;
            // the binary pattern tables would be meaningless.
            self.x_tables.clear(v);
            return;
        }
        let z = {
            let (_, betas, overlay) = self.csr.view(v);
            let d = betas.len() + overlay.len();
            if d > X_TABLE_MAX_DEG {
                None
            } else {
                let mut z = vec![0.0f64; 1usize << d];
                z[0] = self.base_field[v];
                for (i, b) in betas
                    .iter()
                    .copied()
                    .chain(overlay.iter().map(|&(_, b)| b))
                    .enumerate()
                {
                    for m in 0..(1usize << i) {
                        z[m | (1usize << i)] = z[m] + b;
                    }
                }
                Some(z)
            }
        };
        match z {
            None => self.x_tables.clear(v),
            Some(z) => {
                let mut mult = Vec::with_capacity(z.len());
                let mut thresh = Vec::with_capacity(z.len());
                for zi in z {
                    let (m, t) = bernoulli_sigmoid_parts(zi);
                    mult.push(m);
                    thresh.push(t);
                }
                self.x_tables.set(v, &mult, &thresh);
            }
        }
    }

    /// Force a compaction of the incidence arena (normally triggered
    /// automatically once churn outgrows [`CsrIncidence::needs_compaction`])
    /// and refresh the x-tables whose view it changed
    /// (`dirty_vars` is already deduplicated by the arena).
    pub fn compact_incidence(&mut self) {
        let dirty: Vec<u32> = self.csr.dirty_vars().to_vec();
        self.csr.rebuild(&self.incidence);
        for v in dirty {
            self.rebuild_x_table(v as usize);
            self.rebuild_mb_plan(v as usize);
        }
    }

    /// Dualize + insert one factor at a caller-chosen slot id.
    ///
    /// Used with the graph's own [`FactorId`] so graph and dual model share
    /// the slot space — the coordinator relies on this 1:1 mapping.
    pub fn insert_at(&mut self, slot: FactorId, f: &PairFactor) {
        self.insert_at_inner(slot, f, true);
    }

    /// Shared insert body; `maintain_caches: false` is the bulk-build path
    /// ([`DualModel::from_graph`]) where the final compaction refreshes
    /// every churned x-table once instead of twice per insert.
    fn insert_at_inner(&mut self, slot: FactorId, f: &PairFactor, maintain_caches: bool) {
        let DualFactor {
            alpha1,
            alpha2,
            q,
            beta1,
            beta2,
        } = if self.k > 2 {
            // §4.2 indicator dual (struct docs): dualize the per-state
            // sub-factor table [[1,1],[1,e^β]] shared by all k θ-planes.
            // The α parts shift every state's score equally (Σ_s z_{v,s}
            // = 1 collapses them to a per-factor constant), so they are
            // dropped and the base field stays zero.
            let d = dualize_table(&[[1.0, 1.0], [1.0, f.potts_beta().exp()]]);
            DualFactor {
                alpha1: 0.0,
                alpha2: 0.0,
                ..d
            }
        } else {
            dualize_table(&f.table)
        };
        if slot >= self.entries.len() {
            self.entries.resize(slot + 1, None);
        } else if let Some(pos) = self.free.iter().position(|&s| s == slot) {
            // slot reuse (the graph pops its own free list and hands the id
            // back to us): keep our free list consistent under churn
            self.free.swap_remove(pos);
        }
        assert!(self.entries[slot].is_none(), "slot {slot} already live");
        self.entries[slot] = Some(DualEntry {
            v1: f.v1,
            v2: f.v2,
            q,
            beta1,
            beta2,
            alpha1,
            alpha2,
        });
        self.base_field[f.v1] += alpha1;
        self.base_field[f.v2] += alpha2;
        self.coupling_l1[f.v1] += beta1.abs();
        self.coupling_l1[f.v2] += beta2.abs();
        self.incidence[f.v1].push((slot as u32, beta1));
        self.incidence[f.v2].push((slot as u32, beta2));
        self.csr.insert(f.v1, slot as u32, beta1);
        self.csr.insert(f.v2, slot as u32, beta2);
        if self.theta_tables.len() < self.entries.len() {
            self.theta_tables.resize(self.entries.len(), [0.0; 4]);
            self.slot_v1.resize(self.entries.len(), u32::MAX);
            self.slot_v2.resize(self.entries.len(), u32::MAX);
        }
        self.theta_tables[slot] = [
            sigmoid_fast(q),
            sigmoid_fast(q + beta1),
            sigmoid_fast(q + beta2),
            sigmoid_fast(q + beta1 + beta2),
        ];
        self.slot_v1[slot] = f.v1 as u32;
        self.slot_v2[slot] = f.v2 as u32;
        self.active += 1;
        if maintain_caches {
            // base_field / incidence changed at both endpoints; when a
            // compaction is due it refreshes them itself (they are in the
            // arena's dirty set), so rebuild directly only otherwise
            if self.csr.needs_compaction() {
                self.compact_incidence();
            } else {
                self.rebuild_x_table(f.v1);
                self.rebuild_x_table(f.v2);
                self.rebuild_mb_plan(f.v1);
                self.rebuild_mb_plan(f.v2);
            }
        }
    }

    /// Remove the factor in `slot`, undoing its field contribution.
    pub fn remove(&mut self, slot: FactorId) -> Option<DualEntry> {
        let e = self.entries.get_mut(slot)?.take()?;
        self.base_field[e.v1] -= e.alpha1;
        self.base_field[e.v2] -= e.alpha2;
        self.coupling_l1[e.v1] = (self.coupling_l1[e.v1] - e.beta1.abs()).max(0.0);
        self.coupling_l1[e.v2] = (self.coupling_l1[e.v2] - e.beta2.abs()).max(0.0);
        for v in [e.v1, e.v2] {
            let list = &mut self.incidence[v];
            let pos = list
                .iter()
                .position(|&(s, _)| s as usize == slot)
                .expect("incidence desync");
            list.swap_remove(pos);
            assert!(
                self.csr.remove(v, slot as u32),
                "csr/incidence desync at var {v} slot {slot}"
            );
        }
        self.theta_tables[slot] = [0.0; 4];
        self.slot_v1[slot] = u32::MAX;
        self.slot_v2[slot] = u32::MAX;
        self.free.push(slot);
        self.active -= 1;
        // as in insert: a due compaction refreshes the endpoint tables
        // itself via the dirty set
        if self.csr.needs_compaction() {
            self.compact_incidence();
        } else {
            self.rebuild_x_table(e.v1);
            self.rebuild_x_table(e.v2);
            self.rebuild_mb_plan(e.v1);
            self.rebuild_mb_plan(e.v2);
        }
        Some(e)
    }

    /// Currently-free (removed, reusable) factor slots, in removal order.
    /// Emptied again as the slots are reused via [`DualModel::insert_at`].
    pub fn free_slots(&self) -> &[usize] {
        &self.free
    }

    /// Add a variable (dynamic growth).
    pub fn add_var(&mut self, unary: f64) -> VarId {
        self.base_field.push(unary);
        self.incidence.push(Vec::new());
        self.csr.add_var();
        self.x_tables.add_var();
        self.coupling_l1.push(0.0);
        if self.mb.is_some() {
            self.mb_plans.push(None); // degree 0: below any threshold
        }
        let v = self.base_field.len() - 1;
        self.rebuild_x_table(v);
        v
    }

    // -- conditionals (the Markov kernel) ---------------------------------

    /// Log-odds of `x_v = 1` given the dual state θ (Corollary 1).
    #[inline]
    pub fn x_logodds(&self, v: VarId, theta: &[u8]) -> f64 {
        let mut z = self.base_field[v];
        for &(slot, beta) in &self.incidence[v] {
            // branch-free: θ ∈ {0,1}
            z += theta[slot as usize] as f64 * beta;
        }
        z
    }

    /// Log-odds of `θ_i = 1` given the primal state x (Corollary 1).
    /// Binary models only — K > 2 slots carry `k` auxiliaries, see
    /// [`DualModel::theta_logodds_k`].
    #[inline]
    pub fn theta_logodds(&self, e: &DualEntry, x: &[u8]) -> f64 {
        debug_assert_eq!(self.k, 2, "use theta_logodds_k on K-state models");
        e.q + e.beta1 * x[e.v1] as f64 + e.beta2 * x[e.v2] as f64
    }

    /// Log-odds of `θ_{i,s} = 1` given the primal state x on a K > 2
    /// model (struct docs): the binary formula over the state-`s`
    /// indicator bits of the two endpoints.
    #[inline]
    pub fn theta_logodds_k(&self, e: &DualEntry, x: &[u8], s: u8) -> f64 {
        e.q + e.beta1 * f64::from(x[e.v1] == s) + e.beta2 * f64::from(x[e.v2] == s)
    }

    /// Categorical log-scores of `x_v = s` for `s ∈ 0..k` given the dual
    /// state, written into `scores` — the K > 2 analogue of
    /// [`DualModel::x_logodds`] (reference implementation for the lane
    /// kernels' bit-plane path). `theta` holds `k` auxiliaries per slot,
    /// laid out `slot·k + s`; given them the site conditional is the
    /// softmax of `score(s) = Σ_{i ∋ v} θ_{i,s} β_{i,v}` — independent of
    /// every other site.
    pub fn x_scores_k(&self, v: VarId, theta: &[u8], scores: &mut [f64]) {
        assert!(self.k > 2, "x_scores_k is the K-state path; use x_logodds");
        assert_eq!(scores.len(), self.k);
        scores.fill(0.0);
        for &(slot, b) in &self.incidence[v] {
            for (s, score) in scores.iter_mut().enumerate() {
                *score += theta[slot as usize * self.k + s] as f64 * b;
            }
        }
    }

    /// Unnormalized log p(x, θ) — for exactness tests and the §5.2
    /// log-partition estimator. On K > 2 models `theta` holds `k`
    /// auxiliaries per slot (`slot·k + s`) and each scores
    /// `θ_{i,s} (q + β₁·1[x₁ = s] + β₂·1[x₂ = s])`.
    pub fn log_joint_unnorm(&self, x: &[u8], theta: &[u8]) -> f64 {
        if self.k > 2 {
            let mut lp = 0.0;
            for (slot, e) in self.entries() {
                for s in 0..self.k as u8 {
                    let th = theta[slot * self.k + s as usize] as f64;
                    lp += th * self.theta_logodds_k(e, x, s);
                }
            }
            return lp;
        }
        let mut lp = 0.0;
        for (v, &b) in self.base_field.iter().enumerate() {
            lp += b * x[v] as f64;
        }
        for (slot, e) in self.entries() {
            let th = theta[slot] as f64;
            lp += e.q * th + th * (e.beta1 * x[e.v1] as f64 + e.beta2 * x[e.v2] as f64);
        }
        lp
    }

    // -- dense export for the XLA runtime ---------------------------------

    /// Pack the model into the dense operands of an AOT artifact.
    ///
    /// Layout must match `python/compile/dualize.py::dense_operands`:
    /// padded variables get `a = -40` (inert), padded factors `q = -40`,
    /// zero β, endpoints 0. Live factors are packed densely in slot order
    /// (slot gaps from removals are skipped), so `f_pad` only needs to
    /// cover `num_factors()`.
    pub fn dense_operands(&self, n_pad: usize, f_pad: usize) -> DenseOperands {
        let n = self.num_vars();
        assert!(n_pad >= n, "n_pad {n_pad} < n {n}");
        assert!(
            f_pad >= self.active,
            "f_pad {f_pad} < live factors {}",
            self.active
        );
        let mut ops = DenseOperands {
            j: vec![0.0; f_pad * n_pad],
            a: vec![-40.0; n_pad],
            q: vec![-40.0; f_pad],
            b1: vec![0.0; f_pad],
            b2: vec![0.0; f_pad],
            v1: vec![0; f_pad],
            v2: vec![0; f_pad],
            n_pad,
            f_pad,
        };
        ops.a[..n].copy_from_slice(
            &self.base_field.iter().map(|&x| x as f32).collect::<Vec<_>>(),
        );
        for (dense, (_, e)) in self.entries().enumerate() {
            ops.q[dense] = e.q as f32;
            ops.b1[dense] = e.beta1 as f32;
            ops.b2[dense] = e.beta2 as f32;
            ops.v1[dense] = e.v1 as i32;
            ops.v2[dense] = e.v2 as i32;
            ops.j[dense * n_pad + e.v1] += e.beta1 as f32;
            ops.j[dense * n_pad + e.v2] += e.beta2 as f32;
        }
        ops
    }
}

/// Dense row-major operands for the `pd_chain_*` artifacts.
#[derive(Clone, Debug)]
pub struct DenseOperands {
    /// `(f_pad, n_pad)` row-major.
    pub j: Vec<f32>,
    /// `(n_pad,)` — reshaped to `(1, n_pad)` at the runtime boundary.
    pub a: Vec<f32>,
    /// Per-factor dual prior log-odds.
    pub q: Vec<f32>,
    /// Per-factor first-endpoint coupling β₁.
    pub b1: Vec<f32>,
    /// Per-factor second-endpoint coupling β₂.
    pub b2: Vec<f32>,
    /// Per-factor first endpoint index.
    pub v1: Vec<i32>,
    /// Per-factor second endpoint index.
    pub v2: Vec<i32>,
    /// Padded variable count.
    pub n_pad: usize,
    /// Padded factor count.
    pub f_pad: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};
    use crate::workloads;

    /// Enumerate the dual joint and compare its x-marginal to the graph.
    fn assert_marginal_matches(g: &FactorGraph) {
        let m = DualModel::from_graph(g);
        let n = g.num_vars();
        let slots: Vec<usize> = m.entries().map(|(s, _)| s).collect();
        let f = slots.len();
        assert!(n <= 10 && f <= 10, "enumeration blow-up");
        let mut table = vec![0.0f64; 1 << n];
        for xm in 0..1usize << n {
            let x: Vec<u8> = (0..n).map(|v| ((xm >> v) & 1) as u8).collect();
            let mut theta = vec![0u8; m.factor_slots()];
            for tm in 0..1usize << f {
                for (bit, &slot) in slots.iter().enumerate() {
                    theta[slot] = ((tm >> bit) & 1) as u8;
                }
                table[xm] += m.log_joint_unnorm(&x, &theta).exp();
            }
        }
        // compare to graph's unnormalized p(x), up to one global scale
        let mut scale = None;
        for xm in 0..1usize << n {
            let x: Vec<u8> = (0..n).map(|v| ((xm >> v) & 1) as u8).collect();
            let want = g.log_prob_unnorm(&x).exp();
            let r = table[xm] / want;
            match scale {
                None => scale = Some(r),
                Some(s) => assert!(
                    (r / s - 1.0).abs() < 1e-9,
                    "marginal mismatch at {xm}: ratio {r} vs {s}"
                ),
            }
        }
    }

    #[test]
    fn theorem1_marginal_small_chain() {
        let mut g = FactorGraph::new(3);
        g.set_unary(0, 0.3);
        g.set_unary(2, -0.2);
        g.add_factor(PairFactor::ising(0, 1, 0.6));
        g.add_factor(PairFactor::new(1, 2, [[2.0, 0.5], [0.7, 1.5]]));
        assert_marginal_matches(&g);
    }

    #[test]
    fn theorem1_marginal_with_cycle() {
        let mut g = FactorGraph::new(4);
        g.add_factor(PairFactor::ising(0, 1, 0.4));
        g.add_factor(PairFactor::ising(1, 2, -0.3)); // negative β: det < 0 path
        g.add_factor(PairFactor::ising(2, 3, 0.2));
        g.add_factor(PairFactor::ising(3, 0, 0.5));
        assert_marginal_matches(&g);
    }

    #[test]
    fn prop_theorem1_random_graphs() {
        check("dual joint marginalizes to p(x)", 25, |gn: &mut Gen| {
            let n = gn.usize_in(2..=5);
            let mut g = FactorGraph::new(n);
            for v in 0..n {
                g.set_unary(v, gn.f64_in(-1.0, 1.0));
            }
            for _ in 0..gn.usize_in(1..=6) {
                let v1 = gn.usize_in(0..=n - 1);
                let mut v2 = gn.usize_in(0..=n - 1);
                if v1 == v2 {
                    v2 = (v2 + 1) % n;
                }
                g.add_factor(PairFactor::new(v1, v2, gn.positive_table(2.0)));
            }
            assert_marginal_matches(&g);
            Ok(())
        });
    }

    /// Enumerate the K-state dual joint (k auxiliaries per live slot) and
    /// compare its x-marginal to the graph's Potts distribution (the
    /// K > 2 analogue of `assert_marginal_matches`).
    fn assert_potts_marginal_matches(g: &FactorGraph) {
        let m = DualModel::from_graph(g);
        let (n, k) = (g.num_vars(), g.k());
        let slots: Vec<usize> = m.entries().map(|(s, _)| s).collect();
        let f_bits = slots.len() * k;
        assert!(
            k.pow(n as u32) <= 1 << 12 && f_bits <= 16,
            "enumeration blow-up"
        );
        let mut scale = None;
        for code in 0..k.pow(n as u32) {
            let x: Vec<u8> = (0..n)
                .map(|v| ((code / k.pow(v as u32)) % k) as u8)
                .collect();
            let mut theta = vec![0u8; m.factor_slots() * k];
            let mut total = 0.0;
            for tm in 0..1usize << f_bits {
                for (bit, (&slot, s)) in slots
                    .iter()
                    .flat_map(|slot| (0..k).map(move |s| (slot, s)))
                    .enumerate()
                {
                    theta[slot * k + s] = ((tm >> bit) & 1) as u8;
                }
                total += m.log_joint_unnorm(&x, &theta).exp();
            }
            let want = g.log_prob_unnorm(&x).exp();
            let r = total / want;
            match scale {
                None => scale = Some(r),
                Some(s) => assert!(
                    (r / s - 1.0).abs() < 1e-9,
                    "Potts marginal mismatch at {code}: ratio {r} vs {s}"
                ),
            }
        }
    }

    #[test]
    fn potts_dual_marginalizes_on_small_graphs() {
        // triangle with mixed-sign couplings, k = 3
        let mut g = FactorGraph::new_k(3, 3);
        g.add_factor(PairFactor::potts(0, 1, 0.6));
        g.add_factor(PairFactor::potts(1, 2, -0.4));
        g.add_factor(PairFactor::potts(0, 2, 0.3));
        assert_potts_marginal_matches(&g);
        // chain, k = 4 (2 bit-planes)
        let mut g = FactorGraph::new_k(3, 4);
        g.add_factor(PairFactor::potts(0, 1, 0.8));
        g.add_factor(PairFactor::potts(1, 2, 0.5));
        assert_potts_marginal_matches(&g);
        // k = 5 pair (non-power-of-two cardinality)
        let mut g = FactorGraph::new_k(2, 5);
        g.add_factor(PairFactor::potts(0, 1, 1.1));
        assert_potts_marginal_matches(&g);
    }

    #[test]
    fn potts_entry_shape_and_theta_table() {
        use crate::rng::sigmoid_fast;
        let beta = 0.7f64;
        let mut g = FactorGraph::new_k(2, 3);
        let id = g.add_factor(PairFactor::potts(0, 1, beta));
        let m = DualModel::from_graph(&g);
        let e = m.entry(id).unwrap();
        // α dropped: nothing absorbed into the (zero) base field
        assert_eq!((e.alpha1, e.alpha2), (0.0, 0.0));
        assert_eq!(m.base_field(0), 0.0);
        assert_eq!(m.base_field(1), 0.0);
        // the marginalized sub-factor Σ_θ e^{θ(q+β₁z₁+β₂z₂)} = 1+e^{...}
        // must reproduce [[1,1],[1,e^β]] up to the dropped α's, i.e. its
        // cross-ratio (where the α's cancel) must be exactly e^β
        let p = |z1: f64, z2: f64| (e.q + e.beta1 * z1 + e.beta2 * z2).exp().ln_1p();
        let cross = p(0.0, 0.0) + p(1.0, 1.0) - p(1.0, 0.0) - p(0.0, 1.0);
        assert!((cross - beta).abs() < 1e-9, "cross-ratio {cross} vs β {beta}");
        // all four θ-table entries are live (indexed by the two
        // state-indicator bits, one draw per θ-plane)
        let t = m.theta_table(id);
        assert_eq!(t[0], sigmoid_fast(e.q));
        assert_eq!(t[1], sigmoid_fast(e.q + e.beta1));
        assert_eq!(t[2], sigmoid_fast(e.q + e.beta2));
        assert_eq!(t[3], sigmoid_fast(e.q + e.beta1 + e.beta2));
        // K-state sites never use the binary pattern tables
        assert!(m.x_table(0).is_none());
        assert!(m.x_table(1).is_none());
    }

    #[test]
    fn potts_conditionals_match_joint_differences() {
        let mut g = FactorGraph::new_k(3, 3);
        g.add_factor(PairFactor::potts(0, 1, 0.6));
        g.add_factor(PairFactor::potts(1, 2, -0.4));
        g.add_factor(PairFactor::potts(0, 2, 0.3));
        let m = DualModel::from_graph(&g);
        let k = m.k();
        let x = [2u8, 0, 1];
        // θ conditional: one auxiliary per (slot, state)
        let theta0 = vec![0u8; m.factor_slots() * k];
        for (slot, e) in m.entries() {
            for s in 0..k as u8 {
                let mut theta1 = theta0.clone();
                theta1[slot * k + s as usize] = 1;
                let want =
                    m.log_joint_unnorm(&x, &theta1) - m.log_joint_unnorm(&x, &theta0);
                assert!(
                    (m.theta_logodds_k(e, &x, s) - want).abs() < 1e-12,
                    "slot {slot} s {s}"
                );
            }
        }
        // x conditional scores against joint differences under a mixed θ
        let mut theta = vec![0u8; m.factor_slots() * k];
        for (i, t) in theta.iter_mut().enumerate() {
            *t = ((i * 7 + 3) % 3 == 0) as u8;
        }
        let mut scores = vec![0.0; k];
        for v in 0..3 {
            m.x_scores_k(v, &theta, &mut scores);
            for s in 0..k as u8 {
                let mut xs = x;
                xs[v] = s;
                let mut x0 = x;
                x0[v] = 0;
                let want = m.log_joint_unnorm(&xs, &theta) - m.log_joint_unnorm(&x0, &theta);
                let got = scores[s as usize] - scores[0];
                assert!((want - got).abs() < 1e-12, "v={v} s={s}");
            }
        }
    }

    #[test]
    fn potts_model_accepts_minibatch_plans() {
        // K > 2 models build the same alias plans as binary ones: the
        // plan geometry is a function of |β| mass only
        let mut g = FactorGraph::new_k(6, 3);
        for v in 1..6 {
            g.add_factor(PairFactor::potts(0, v, if v % 2 == 0 { 0.4 } else { -0.3 }));
        }
        let mut m = DualModel::from_graph(&g);
        m.set_minibatch(Some(MinibatchPolicy {
            degree_threshold: 3,
            lambda_scale: 0.5,
            lambda_min: 1.0,
            theta_stride: 2,
        }));
        let plan = m.mb_plan(0).expect("hub exceeds the degree threshold");
        assert_eq!(plan.len(), 5);
        assert!((plan.l1() - (0.4 * 2.0 + 0.3 * 3.0)).abs() < 1e-12);
        assert!(m.mb_plan(1).is_none(), "leaves stay exact");
        assert!(m.minibatch_sweep_cost(2) < m.sweep_cost());
    }

    #[test]
    fn incremental_equals_batch() {
        // build incrementally with removals, compare against from_graph
        let mut g = FactorGraph::new(6);
        let mut ids = Vec::new();
        for k in 0..8 {
            ids.push(g.add_factor(PairFactor::ising(k % 6, (k + 1) % 6, 0.1 * (k + 1) as f64)));
        }
        let mut m = DualModel::from_graph(&g);
        // remove 3 factors from both
        for &id in &ids[2..5] {
            g.remove_factor(id);
            m.remove(id);
        }
        let fresh = DualModel::from_graph(&g);
        for v in 0..6 {
            assert!(
                (m.base_field(v) - fresh.base_field(v)).abs() < 1e-12,
                "field desync at {v}"
            );
            let mut a: Vec<_> = m.incidence(v).to_vec();
            let mut b: Vec<_> = fresh.incidence(v).to_vec();
            a.sort_by_key(|x| x.0);
            b.sort_by_key(|x| x.0);
            assert_eq!(a, b);
        }
        assert_eq!(m.num_factors(), fresh.num_factors());
    }

    #[test]
    fn free_list_tracks_slot_reuse() {
        let mut g = FactorGraph::new(4);
        let a = g.add_factor(PairFactor::ising(0, 1, 0.3));
        let b = g.add_factor(PairFactor::ising(1, 2, 0.4));
        let mut m = DualModel::from_graph(&g);
        assert!(m.free_slots().is_empty());
        m.remove(a);
        m.remove(b);
        assert_eq!(m.free_slots(), &[a, b]);
        // re-inserting into a freed slot must drop it from the free list
        m.insert_at(b, &PairFactor::ising(2, 3, 0.5));
        assert_eq!(m.free_slots(), &[a]);
        m.insert_at(a, &PairFactor::ising(0, 1, 0.3));
        assert!(m.free_slots().is_empty());
        assert_eq!(m.num_factors(), 2);
    }

    #[test]
    fn x_logodds_matches_joint_difference() {
        let g = workloads::random_graph(6, 2, 0.8, 3);
        let m = DualModel::from_graph(&g);
        let mut theta = vec![0u8; m.factor_slots()];
        for (i, t) in theta.iter_mut().enumerate() {
            *t = (i % 2) as u8;
        }
        let x0 = vec![0u8; 6];
        for v in 0..6 {
            let mut x1 = x0.clone();
            x1[v] = 1;
            let want = m.log_joint_unnorm(&x1, &theta) - m.log_joint_unnorm(&x0, &theta);
            let got = m.x_logodds(v, &theta);
            assert!((want - got).abs() < 1e-9, "v={v}");
        }
    }

    #[test]
    fn theta_logodds_matches_joint_difference() {
        let g = workloads::random_graph(5, 2, 0.8, 4);
        let m = DualModel::from_graph(&g);
        let x: Vec<u8> = (0..5).map(|v| (v % 2) as u8).collect();
        let theta0 = vec![0u8; m.factor_slots()];
        for (slot, e) in m.entries() {
            let mut theta1 = theta0.clone();
            theta1[slot] = 1;
            let want = m.log_joint_unnorm(&x, &theta1) - m.log_joint_unnorm(&x, &theta0);
            let got = m.theta_logodds(e, &x);
            assert!((want - got).abs() < 1e-9, "slot={slot}");
        }
    }

    #[test]
    fn dense_operands_layout() {
        let g = workloads::ising_grid(2, 2, 0.5, 0.1);
        let m = DualModel::from_graph(&g);
        let ops = m.dense_operands(8, 8);
        assert_eq!(ops.j.len(), 64);
        // 4 live factors → rows 0..4 populated, rest zero
        assert!(ops.q[..4].iter().all(|&q| q != -40.0));
        assert!(ops.q[4..].iter().all(|&q| q == -40.0));
        assert!(ops.a[..4].iter().all(|&a| a != -40.0));
        assert!(ops.a[4..].iter().all(|&a| a == -40.0));
        // each row has exactly two non-zeros (β₁, β₂)
        for row in 0..4 {
            let nz = ops.j[row * 8..(row + 1) * 8]
                .iter()
                .filter(|&&x| x != 0.0)
                .count();
            assert_eq!(nz, 2, "row {row}");
        }
    }

    #[test]
    fn csr_view_matches_nested_incidence_after_build() {
        let g = workloads::ising_grid(3, 3, 0.4, 0.1);
        let m = DualModel::from_graph(&g);
        for v in 0..9 {
            assert_eq!(
                m.incidence_csr_logical(v),
                m.incidence(v).to_vec(),
                "CSR/nested mismatch at {v}"
            );
            // freshly built: pure arena, no overlay
            let (slots, betas, overlay) = m.incidence_csr(v);
            assert!(overlay.is_empty());
            assert_eq!(slots.len(), m.degree(v));
            assert_eq!(betas.len(), m.degree(v));
        }
    }

    #[test]
    fn csr_tracks_churn_and_compaction() {
        let mut g = workloads::ising_grid(3, 3, 0.3, 0.0);
        let mut m = DualModel::from_graph(&g);
        let epoch0 = m.csr_epoch();
        let victim = g.factors().next().unwrap().0;
        g.remove_factor(victim).unwrap();
        m.remove(victim);
        let sorted_eq = |m: &DualModel| {
            for v in 0..m.num_vars() {
                let mut a = m.incidence_csr_logical(v);
                let mut b = m.incidence(v).to_vec();
                a.sort_by_key(|e| e.0);
                b.sort_by_key(|e| e.0);
                assert_eq!(a, b, "CSR drift at var {v}");
            }
        };
        sorted_eq(&m);
        m.insert_at(victim, &PairFactor::ising(0, 8, 0.7));
        sorted_eq(&m);
        // forced compaction keeps the logical view and bumps the epoch
        m.compact_incidence();
        assert!(m.csr_epoch() > epoch0);
        sorted_eq(&m);
    }

    #[test]
    fn theta_table_caches_the_four_sigmoids() {
        use crate::rng::sigmoid_fast;
        let g = workloads::ising_grid(2, 2, 0.5, 0.1);
        let mut m = DualModel::from_graph(&g);
        for (slot, e) in m.entries().map(|(s, e)| (s, *e)).collect::<Vec<_>>() {
            let t = *m.theta_table(slot);
            assert_eq!(t[0], sigmoid_fast(e.q));
            assert_eq!(t[1], sigmoid_fast(e.q + e.beta1));
            assert_eq!(t[2], sigmoid_fast(e.q + e.beta2));
            assert_eq!(t[3], sigmoid_fast(e.q + e.beta1 + e.beta2));
            assert_eq!(m.slot_endpoints(slot), Some((e.v1 as u32, e.v2 as u32)));
        }
        // removal leaves the slot inert; reinsert refreshes the cache
        let (slot, e) = {
            let (s, e) = m.entries().next().unwrap();
            (s, *e)
        };
        m.remove(slot);
        assert_eq!(m.slot_endpoints(slot), None);
        assert_eq!(*m.theta_table(slot), [0.0; 4]);
        m.insert_at(slot, &PairFactor::ising(e.v1, e.v2, 0.9));
        assert!(m.slot_endpoints(slot).is_some());
        assert_ne!(*m.theta_table(slot), [0.0; 4]);
    }

    #[test]
    fn x_table_matches_fold_over_patterns() {
        use crate::rng::bernoulli_sigmoid_parts;
        let g = workloads::ising_grid(2, 2, 0.4, 0.2);
        let m = DualModel::from_graph(&g);
        for v in 0..4 {
            let (_, betas, overlay) = m.incidence_csr(v);
            assert!(overlay.is_empty());
            let d = betas.len();
            let (mult, thresh) = m.x_table(v).expect("grid degree ≤ 2 must be cached");
            assert_eq!(mult.len(), 1 << d);
            assert_eq!(thresh.len(), 1 << d);
            // tile-aligned arena: both streams start on a cache line
            assert_eq!(mult.as_ptr() as usize % 64, 0);
            assert_eq!(thresh.as_ptr() as usize % 64, 0);
            for mask in 0..(1usize << d) {
                let mut z = m.base_field(v);
                for (i, &b) in betas.iter().enumerate() {
                    z += ((mask >> i) & 1) as f64 * b;
                }
                let want = bernoulli_sigmoid_parts(z);
                let got = (mult[mask], thresh[mask]);
                assert!(
                    (got.0 - want.0).abs() < 1e-15 && (got.1 - want.1).abs() < 1e-15,
                    "v={v} mask={mask}: {got:?} vs {want:?}"
                );
            }
        }
    }

    #[test]
    fn x_table_disabled_beyond_degree_cap() {
        // a 7-star hub exceeds X_TABLE_MAX_DEG = 6
        let mut g = FactorGraph::new(8);
        for leaf in 1..8 {
            g.add_factor(PairFactor::ising(0, leaf, 0.1));
        }
        let mut m = DualModel::from_graph(&g);
        assert!(m.x_table(0).is_none());
        assert!(m.x_table(1).is_some());
        // dropping one edge brings the hub under the cap — immediately,
        // with no compaction required (the view tracks live degree)
        let id = g.factors().next().unwrap().0;
        m.remove(id);
        assert!(m.x_table(0).is_some());
        assert_eq!(m.x_table(0).unwrap().0.len(), 1 << 6);
        // and compaction keeps it intact
        m.compact_incidence();
        assert!(m.x_table(0).is_some());
        assert_eq!(m.x_table(0).unwrap().0.len(), 1 << 6);
    }

    /// 9-spoke hub (var 0) with mixed-sign couplings for the plan tests.
    fn hub_graph() -> FactorGraph {
        let mut g = FactorGraph::new(10);
        for leaf in 1..10 {
            let beta = if leaf % 2 == 0 { 0.3 } else { -0.4 } * (1.0 + leaf as f64 / 10.0);
            g.add_factor(PairFactor::ising(0, leaf, beta));
        }
        g
    }

    fn test_policy() -> MinibatchPolicy {
        MinibatchPolicy {
            degree_threshold: 4,
            lambda_scale: 0.25,
            lambda_min: 1.0,
            theta_stride: 2,
        }
    }

    #[test]
    fn mb_plan_built_only_above_threshold() {
        let mut m = DualModel::from_graph(&hub_graph());
        assert!(m.mb_plan(0).is_none(), "no plan before a policy is set");
        m.set_minibatch(Some(test_policy()));
        let plan = m.mb_plan(0).expect("hub degree 9 > threshold 4");
        assert_eq!(plan.len(), 9);
        assert!(!plan.is_empty());
        for leaf in 1..10 {
            assert!(m.mb_plan(leaf).is_none(), "leaf degree 1 stays exact");
        }
        m.set_minibatch(None);
        assert!(m.mb_plan(0).is_none(), "clearing the policy drops plans");
        assert_eq!(m.minibatch_policy(), None);
    }

    #[test]
    fn mb_plan_constants_are_mutually_consistent() {
        let mut m = DualModel::from_graph(&hub_graph());
        let want_l1: f64 = m.incidence(0).iter().map(|&(_, b)| b.abs()).sum();
        m.set_minibatch(Some(test_policy()));
        let p = m.mb_plan(0).unwrap();
        assert!((p.l1() - want_l1).abs() < 1e-12);
        let lambda = (0.25 * want_l1 * want_l1).max(1.0);
        assert!((p.rate() - (lambda + want_l1)).abs() < 1e-12);
        assert!((p.kappa() - lambda / (lambda + want_l1)).abs() < 1e-12);
        assert!((p.c() - (want_l1 / lambda).ln_1p()).abs() < 1e-12);
        assert_eq!(p.batch(), p.rate().ceil() as u64);
        // maintained bound was re-anchored to the exact sum
        assert!((m.coupling_l1(0) - want_l1).abs() < 1e-12);
    }

    #[test]
    fn mb_alias_table_tracks_coupling_weights() {
        use crate::rng::Pcg64;
        let mut m = DualModel::from_graph(&hub_graph());
        m.set_minibatch(Some(test_policy()));
        let p = m.mb_plan(0).unwrap();
        let view: Vec<(u32, f64)> = m.incidence_csr_logical(0);
        let mut want: std::collections::HashMap<u32, f64> = std::collections::HashMap::new();
        for &(s, b) in &view {
            *want.entry(s).or_insert(0.0) += b.abs() / p.l1();
        }
        let mut rng = Pcg64::seed(77);
        let n = 200_000;
        let mut got: std::collections::HashMap<u32, f64> = std::collections::HashMap::new();
        for _ in 0..n {
            let (slot, neg) = p.pick(&mut rng);
            *got.entry(slot).or_insert(0.0) += 1.0 / n as f64;
            // sign metadata matches the model's coupling
            let beta = view.iter().find(|&&(s, _)| s == slot).unwrap().1;
            assert_eq!(neg, beta < 0.0, "slot {slot}");
        }
        for (slot, w) in want {
            let f = got.get(&slot).copied().unwrap_or(0.0);
            assert!((f - w).abs() < 0.01, "slot {slot}: freq {f} vs weight {w}");
        }
    }

    #[test]
    fn mb_plan_follows_churn() {
        let mut g = hub_graph();
        let mut m = DualModel::from_graph(&g);
        m.set_minibatch(Some(test_policy()));
        assert!(m.mb_plan(0).is_some());
        assert_eq!(
            m.mb_saved,
            m.mb_plan(0).unwrap().saved,
            "one planned site: the counter is exactly its contribution"
        );
        // remove spokes until the hub falls to the threshold
        let ids: Vec<_> = g.factors().map(|(id, _)| id).collect();
        for &id in &ids[..5] {
            g.remove_factor(id);
            m.remove(id);
        }
        assert_eq!(m.degree(0), 4);
        assert!(m.mb_plan(0).is_none(), "at the threshold the site is exact");
        assert_eq!(m.mb_saved, 0);
        // and re-adding pushes it back over
        m.insert_at(ids[0], &PairFactor::ising(0, 1, 0.5));
        assert!(m.mb_plan(0).is_some());
        // maintained bound stayed in sync with the live incidence
        let want: f64 = m.incidence(0).iter().map(|&(_, b)| b.abs()).sum();
        assert!((m.coupling_l1(0) - want).abs() < 1e-9);
        // compaction preserves the plan
        m.compact_incidence();
        assert!(m.mb_plan(0).is_some());
    }

    #[test]
    fn minibatch_sweep_cost_discounts_hubs_and_stride() {
        // a wide, weakly-coupled hub: L stays small, so λ bottoms out at
        // lambda_min and the expected batch is far below the degree
        let mut g = FactorGraph::new(41);
        for leaf in 1..41 {
            g.add_factor(PairFactor::ising(0, leaf, 0.05));
        }
        let mut m = DualModel::from_graph(&g);
        let full = m.sweep_cost();
        m.set_minibatch(Some(MinibatchPolicy {
            degree_threshold: 8,
            lambda_scale: 0.01,
            lambda_min: 0.5,
            theta_stride: 2,
        }));
        let p = m.mb_plan(0).expect("degree 40 hub is planned");
        assert!(
            p.batch() < m.degree(0) as u64,
            "batch {} must undercut degree {}",
            p.batch(),
            m.degree(0)
        );
        assert!(m.mb_saved > 0);
        // x weight for the hub is capped at its batch, leaves unchanged
        assert_eq!(m.x_visit_weight(0), 1 + p.batch());
        assert_eq!(m.x_visit_weight(1), 1 + m.degree(1) as u64);
        // repriced cost undercuts the full cost even with stride 1
        // (hub discount alone), and more with the θ stride on top
        assert!(m.minibatch_sweep_cost(1) < full);
        assert!(m.minibatch_sweep_cost(2) < m.minibatch_sweep_cost(1));
    }

    #[test]
    fn dense_operands_skip_removed_slots() {
        let mut g = workloads::ising_grid(2, 2, 0.5, 0.0);
        let first = g.factors().next().unwrap().0;
        let mut m = DualModel::from_graph(&g);
        g.remove_factor(first);
        m.remove(first);
        let ops = m.dense_operands(4, 4);
        // 3 live factors packed densely at rows 0..3
        assert!(ops.q[..3].iter().all(|&q| q != -40.0));
        assert_eq!(ops.q[3], -40.0);
    }
}
