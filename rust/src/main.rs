//! pdgibbs CLI — leader entrypoint for the coordinator and the samplers.
//!
//! Subcommands:
//!   sample     run a sampler on a synthetic workload, print marginals/throughput
//!   mixing     PSRF mixing-time comparison on one workload (one Fig-2 point)
//!   serve      run the dynamic coordinator on a churn trace, print stats
//!   denoise    end-to-end image denoising through the XLA runtime
//!   artifacts  list + compile-check + smoke-run the AOT artifacts
//!
//! Examples:
//!   pdgibbs sample --workload grid --size 16 --beta 0.3 --sweeps 2000
//!   pdgibbs mixing --workload grid --size 50 --beta 0.2
//!   pdgibbs serve --vars 200 --target-factors 400 --steps 500
//!   pdgibbs serve --listen 127.0.0.1:7700 --shards 4
//!   pdgibbs serve --listen 127.0.0.1:0 --soak-steps 80
//!   pdgibbs denoise --artifacts artifacts
//!   pdgibbs artifacts --artifacts artifacts

use std::sync::Arc;

use pdgibbs::bench_support;
use pdgibbs::coordinator::{
    Coordinator, CoordinatorConfig, NetConfig, NetServer, Server, ServerConfig,
};
use pdgibbs::duality::DualModel;
use pdgibbs::rng::Pcg64;
use pdgibbs::runtime::Runtime;
use pdgibbs::util::cli::Cli;
use pdgibbs::util::stats::mean_or_zero;
use pdgibbs::util::ThreadPool;
use pdgibbs::workloads;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        eprintln!("usage: pdgibbs <sample|mixing|serve|denoise|artifacts> [options]");
        std::process::exit(2);
    };
    let rest = rest.to_vec();
    match cmd.as_str() {
        "sample" => cmd_sample(&rest),
        "mixing" => cmd_mixing(&rest),
        "serve" => cmd_serve(&rest),
        "denoise" => cmd_denoise(&rest),
        "artifacts" => cmd_artifacts(&rest),
        other => {
            eprintln!("unknown subcommand '{other}'");
            std::process::exit(2);
        }
    }
}

fn build_workload(cli: &Cli) -> pdgibbs::FactorGraph {
    let size = cli.get_usize("size");
    let beta = cli.get_f64("beta");
    match cli.get("workload").unwrap_or("grid") {
        "grid" => workloads::ising_grid(size, size, beta, cli.get_f64("field")),
        "random" => workloads::random_graph(size, cli.get_usize("k"), 1.0, cli.get_u64("seed")),
        "complete" => workloads::fully_connected_ising(size, |_, _| beta),
        other => {
            eprintln!("unknown workload '{other}' (grid|random|complete)");
            std::process::exit(2);
        }
    }
}

fn common_opts(name: &'static str, about: &'static str) -> Cli {
    Cli::new(name, about)
        .opt("workload", Some("grid"), "grid | random | complete")
        .opt("size", Some("16"), "grid side / variable count")
        .opt("beta", Some("0.3"), "coupling strength")
        .opt("field", Some("0.0"), "uniform unary log-odds")
        .opt("k", Some("2"), "factors-per-variable (random workload)")
        .opt("seed", Some("0"), "experiment seed")
        .opt("threads", Some("0"), "worker threads (0 = sequential)")
}

fn parse_or_exit(cli: Cli, args: &[String]) -> Cli {
    cli.parse(&args.to_vec()).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    })
}

fn cmd_sample(args: &[String]) {
    let cli = parse_or_exit(
        common_opts("pdgibbs sample", "run one sampler, report marginal summary")
            .opt("sampler", Some("pd"), "pd | sequential | chromatic | sw | blocked")
            .opt("sweeps", Some("2000"), "post-burn-in sweeps")
            .opt("burn-in", Some("500"), "burn-in sweeps"),
        args,
    );
    let g = build_workload(&cli);
    let pool = match cli.get_usize("threads") {
        0 => None,
        t => Some(Arc::new(ThreadPool::new(t))),
    };
    let mut rng = Pcg64::seed(cli.get_u64("seed"));
    let mut sampler = bench_support::make_sampler(&g, cli.get("sampler").unwrap(), pool);
    println!(
        "workload: {} vars, {} factors; sampler: {}",
        g.num_vars(),
        g.num_factors(),
        sampler.name()
    );
    let t0 = std::time::Instant::now();
    let marg = pdgibbs::samplers::empirical_marginals(
        sampler.as_mut(),
        &mut rng,
        cli.get_usize("burn-in"),
        cli.get_usize("sweeps"),
    );
    let dt = t0.elapsed().as_secs_f64();
    let mean = mean_or_zero(&marg);
    let sweeps = cli.get_usize("burn-in") + cli.get_usize("sweeps");
    println!("mean marginal: {mean:.4}");
    println!(
        "throughput: {:.1} sweeps/s ({:.2} Msite-updates/s)",
        sweeps as f64 / dt,
        sweeps as f64 * g.num_vars() as f64 / dt / 1e6
    );
}

fn cmd_mixing(args: &[String]) {
    let cli = parse_or_exit(
        common_opts("pdgibbs mixing", "PSRF mixing-time, PD vs sequential")
            .opt("chains", Some("10"), "parallel chains")
            .opt("max-sweeps", Some("4000"), "sweep budget per sampler")
            .opt("threshold", Some("1.01"), "PSRF threshold")
            .opt("monitors", Some("16"), "number of monitored variables"),
        args,
    );
    let g = build_workload(&cli);
    let chains = cli.get_usize("chains");
    let max_sweeps = cli.get_usize("max-sweeps");
    let threshold = cli.get_f64("threshold");
    let monitors = bench_support::pick_monitors(g.num_vars(), cli.get_usize("monitors"));
    println!(
        "workload: {} vars, {} factors; {chains} chains, threshold {threshold}",
        g.num_vars(),
        g.num_factors(),
    );
    for kind in ["pd", "sequential"] {
        let r = bench_support::mixing_run(
            &g,
            kind,
            chains,
            max_sweeps,
            threshold,
            &monitors,
            cli.get_u64("seed"),
        );
        match r.mixing_time {
            Some(t) => println!(
                "{kind:>12}: mixed at sweep {t} (final PSRF {:.4})",
                r.final_psrf
            ),
            None => println!(
                "{kind:>12}: NOT mixed in {max_sweeps} (final PSRF {:.4})",
                r.final_psrf
            ),
        }
    }
}

fn cmd_serve(args: &[String]) {
    let cli = parse_or_exit(
        Cli::new("pdgibbs serve", "dynamic coordinator on a churn trace")
            .opt("vars", Some("100"), "variable count")
            .opt("target-factors", Some("200"), "steady-state live factors")
            .opt("steps", Some("200"), "churn operations")
            .opt("beta-max", Some("0.4"), "max coupling of churned factors")
            .opt("sweeps-per-op", Some("8"), "foreground sweeps between ops")
            .opt("chains", Some("10"), "parallel chains")
            .opt("seed", Some("0"), "trace seed")
            .opt("listen", None, "serve the wire protocol on this TCP address")
            .opt("shards", Some("2"), "shard threads (listen mode)")
            .opt("quantum", Some("4096"), "DRR quantum (listen mode; 0 = off)")
            .opt(
                "soak-steps",
                Some("0"),
                "listen mode: replay this many trace steps through a real socket, then exit",
            ),
        args,
    );
    if cli.get("listen").is_some() {
        serve_net(&cli);
        return;
    }
    let vars = cli.get_usize("vars");
    let trace = workloads::ChurnTrace::generate(
        vars,
        cli.get_usize("target-factors"),
        cli.get_usize("steps"),
        cli.get_f64("beta-max"),
        cli.get_u64("seed"),
    );
    let g = pdgibbs::FactorGraph::new(vars);
    let mut server = Server::spawn(
        g,
        ServerConfig {
            chains: cli.get_usize("chains"),
            ..Default::default()
        },
    );
    let h = server.handle();
    let t0 = std::time::Instant::now();
    let marginals =
        pdgibbs::coordinator::server::replay_trace(&h, &trace, cli.get_usize("sweeps-per-op"));
    let stats = h.stats().expect("server alive after replay");
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "applied {} ops in {dt:.2}s ({:.0} ops/s) — {} live factors, {} sweeps done",
        stats.ops_applied,
        stats.ops_applied as f64 / dt,
        stats.num_factors,
        stats.sweeps_done
    );
    let mean_marginal = mean_or_zero(&marginals);
    println!(
        "final marginals: {} vars, mean {mean_marginal:.4}",
        marginals.len()
    );
    println!("metrics: {}", server.metrics.snapshot().dump());
    server.shutdown();
}

/// `serve --listen`: expose the sharded coordinator over the wire
/// protocol on a real TCP socket. With `--soak-steps N` the process
/// replays a generated multi-tenant trace through a client socket,
/// verifies zero failed replies and zero scheduler desyncs, then exits
/// (the CI soak gate). Without it, the server runs until killed.
fn serve_net(cli: &Cli) {
    let bind = cli.get("listen").unwrap();
    let mut coord = Coordinator::spawn(CoordinatorConfig {
        shards: cli.get_usize("shards").max(1),
        quantum: cli.get_u64("quantum"),
        ..Default::default()
    });
    let mut server = match NetServer::spawn(
        coord.client(),
        coord.metrics().clone(),
        NetConfig::default(),
        bind,
    ) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("serve --listen failed: {e:#}");
            std::process::exit(1);
        }
    };
    println!("serving the wire protocol on {}", server.addr());
    let soak = cli.get_usize("soak-steps");
    if soak == 0 {
        loop {
            std::thread::park();
        }
    }
    let trace = workloads::TenantTrace::generate(
        workloads::TenantTraceConfig {
            max_tenants: 8,
            steps: soak,
            vars: (4, 9),
            target_factors: 8,
            ops_per_apply: 3,
            sweeps_per_step: 4,
            beta_max: cli.get_f64("beta-max"),
        },
        cli.get_u64("seed"),
    );
    let addr = server.addr().to_string();
    let failures = match workloads::replay_trace_over_socket(&addr, &trace) {
        Ok(n) => n,
        Err(e) => {
            eprintln!("soak replay failed: {e:#}");
            std::process::exit(1);
        }
    };
    server.shutdown();
    let desyncs: u64 = (0..coord.num_shards())
        .map(|s| coord.metrics().counter(&format!("shard{s}.sched_desync")))
        .sum();
    println!(
        "soak: {} wire events, {failures} failed replies, {desyncs} scheduler desyncs",
        trace.events.len()
    );
    coord.shutdown();
    if failures > 0 || desyncs > 0 {
        std::process::exit(1);
    }
}

fn cmd_denoise(args: &[String]) {
    let cli = parse_or_exit(
        Cli::new("pdgibbs denoise", "E2E denoising via the XLA runtime")
            .opt("artifacts", Some("artifacts"), "artifact directory")
            .opt("flip-prob", Some("0.12"), "observation noise")
            .opt("coupling", Some("0.35"), "smoothness coupling")
            .opt("chunks", Some("40"), "artifact chunks to run")
            .opt("seed", Some("0"), "noise seed")
            .flag("native", "use the native sampler instead of XLA")
            .flag("quiet", "suppress image rendering"),
        args,
    );
    match bench_support::denoise_e2e(
        cli.get("artifacts").unwrap(),
        cli.get_f64("flip-prob"),
        cli.get_f64("coupling"),
        cli.get_usize("chunks"),
        cli.get_u64("seed"),
        cli.get_flag("native"),
        !cli.get_flag("quiet"),
    ) {
        Ok(result) => {
            println!(
                "accuracy: noisy {:.4} -> denoised {:.4} ({} sweeps in {:.2}s, {:.1} sweeps/s)",
                result.noisy_accuracy,
                result.denoised_accuracy,
                result.sweeps,
                result.seconds,
                result.sweeps as f64 / result.seconds
            );
        }
        Err(e) => {
            eprintln!("denoise failed: {e:#}");
            std::process::exit(1);
        }
    }
}

fn cmd_artifacts(args: &[String]) {
    let cli = parse_or_exit(
        Cli::new("pdgibbs artifacts", "list and compile-check artifacts")
            .opt("artifacts", Some("artifacts"), "artifact directory"),
        args,
    );
    let rt = match Runtime::load(cli.get("artifacts").unwrap()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("failed to load artifacts (run `make artifacts`): {e:#}");
            std::process::exit(1);
        }
    };
    println!("platform: {}", rt.platform());
    for meta in &rt.manifest().artifacts {
        let t0 = std::time::Instant::now();
        match rt.executable(&meta.name) {
            Ok(_) => println!(
                "  {:<16} n={:<6} f={:<6} chains={:<3} sweeps/call={:<3} compiled in {:.2}s",
                meta.name,
                meta.n,
                meta.f,
                meta.chains,
                meta.sweeps,
                t0.elapsed().as_secs_f64()
            ),
            Err(e) => println!("  {:<16} FAILED: {e:#}", meta.name),
        }
    }
    // smoke-run grid16 end to end
    if let Some(meta) = rt.manifest().get("grid16").cloned() {
        let g = workloads::ising_grid(16, 16, 0.25, 0.0);
        let m = DualModel::from_graph(&g);
        let ops = m.dense_operands(meta.n_pad, meta.f_pad);
        match rt.chain_exec(&meta.name, &ops) {
            Ok(exec) => match exec.run(&exec.zero_state(), [1, 2]) {
                Ok(out) => println!(
                    "smoke run ok: mag[last sweep] = {:?}",
                    &out.mag[out.mag.len() - meta.chains..]
                ),
                Err(e) => println!("smoke run failed: {e:#}"),
            },
            Err(e) => println!("smoke bind failed: {e:#}"),
        }
    }
}
