//! Self-contained benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated timed runs with summary statistics, and a
//! fixed-width table printer whose rows mirror the paper's figures. Every
//! binary in `benches/` is a `harness = false` cargo bench target built on
//! this module, and writes a machine-readable JSON report next to its
//! stdout table so EXPERIMENTS.md can be regenerated.

use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::Summary;

/// Time `f()` `reps` times after `warmup` unmeasured calls; returns
/// per-call seconds.
pub fn time_fn<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

/// One benchmark measurement with its label and metadata.
#[derive(Clone, Debug)]
pub struct Record {
    /// Row label (sampler/backend name).
    pub label: String,
    /// Ordered `(key, value)` parameters of the measurement.
    pub params: Vec<(String, String)>,
    /// Ordered `(key, value)` measured metrics.
    pub metrics: Vec<(String, f64)>,
}

impl Record {
    /// Empty record with a label.
    pub fn new(label: impl Into<String>) -> Self {
        Self {
            label: label.into(),
            params: Vec::new(),
            metrics: Vec::new(),
        }
    }

    /// Append one parameter (builder style).
    pub fn param(mut self, key: &str, value: impl ToString) -> Self {
        self.params.push((key.to_string(), value.to_string()));
        self
    }

    /// Append one metric (builder style).
    pub fn metric(mut self, key: &str, value: f64) -> Self {
        self.metrics.push((key.to_string(), value));
        self
    }

    fn to_json(&self) -> Json {
        let mut obj = vec![("label", Json::from(self.label.as_str()))];
        for (k, v) in &self.params {
            obj.push((k.as_str(), Json::from(v.as_str())));
        }
        for (k, v) in &self.metrics {
            obj.push((k.as_str(), Json::from(*v)));
        }
        Json::obj(obj)
    }
}

/// Collects records, prints the table, writes the JSON report.
pub struct Report {
    /// Report (and JSON file) name.
    pub name: String,
    /// Collected rows in push order.
    pub records: Vec<Record>,
    started: Instant,
}

impl Report {
    /// Start a report (prints the bench banner).
    pub fn new(name: &str) -> Self {
        println!("== bench: {name} ==");
        Self {
            name: name.to_string(),
            records: Vec::new(),
            started: Instant::now(),
        }
    }

    /// Add one record, streaming it to stdout.
    pub fn push(&mut self, r: Record) {
        // stream rows as they complete (benches can run minutes)
        let params = r
            .params
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(" ");
        let metrics = r
            .metrics
            .iter()
            .map(|(k, v)| format!("{k}={v:.6}"))
            .collect::<Vec<_>>()
            .join(" ");
        println!("  {:<24} {params:<40} {metrics}", r.label);
        self.records.push(r);
    }

    /// Render the collected records as an aligned table grouped by label.
    pub fn table(&self) -> String {
        let mut out = String::new();
        // header: union of param and metric keys in first-seen order
        let mut pkeys: Vec<String> = Vec::new();
        let mut mkeys: Vec<String> = Vec::new();
        for r in &self.records {
            for (k, _) in &r.params {
                if !pkeys.contains(k) {
                    pkeys.push(k.clone());
                }
            }
            for (k, _) in &r.metrics {
                if !mkeys.contains(k) {
                    mkeys.push(k.clone());
                }
            }
        }
        out.push_str(&format!("{:<24}", "label"));
        for k in pkeys.iter().chain(mkeys.iter()) {
            out.push_str(&format!("{k:>16}"));
        }
        out.push('\n');
        for r in &self.records {
            out.push_str(&format!("{:<24}", r.label));
            for k in &pkeys {
                let v = r
                    .params
                    .iter()
                    .find(|(pk, _)| pk == k)
                    .map(|(_, v)| v.clone())
                    .unwrap_or_default();
                out.push_str(&format!("{v:>16}"));
            }
            for k in &mkeys {
                let v = r
                    .metrics
                    .iter()
                    .find(|(mk, _)| mk == k)
                    .map(|(_, v)| format!("{v:.4}"))
                    .unwrap_or_default();
                out.push_str(&format!("{v:>16}"));
            }
            out.push('\n');
        }
        out
    }

    fn doc(&self, mode: Option<&str>) -> Json {
        let elapsed = self.started.elapsed().as_secs_f64();
        let mut obj = vec![("bench", Json::from(self.name.as_str()))];
        if let Some(m) = mode {
            obj.push(("mode", Json::from(m)));
        }
        obj.push(("elapsed_s", Json::from(elapsed)));
        let rows = Json::Arr(self.records.iter().map(Record::to_json).collect());
        obj.push(("records", rows));
        Json::obj(obj)
    }

    /// Write `target/bench-reports/<name>.json` and print the table.
    pub fn finish(self) {
        self.finish_inner(None);
    }

    /// Like [`Report::finish`], but additionally write the report as
    /// `BENCH_<tracked>.json` at the repository root (tagged with `mode`)
    /// — the machine-readable perf-trajectory file CI and later PRs diff
    /// against, which must not be buried in `target/`.
    pub fn finish_tracked(self, tracked: &str, mode: &str) {
        self.finish_inner(Some((tracked.to_string(), mode.to_string())));
    }

    fn finish_inner(self, tracked: Option<(String, String)>) {
        let table = self.table();
        println!("\n{table}");
        let elapsed = self.started.elapsed().as_secs_f64();
        let mode = tracked.as_ref().map(|(_, m)| m.as_str());
        let doc = self.doc(mode);
        let dir = std::path::Path::new("target/bench-reports");
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(format!("{}.json", self.name));
        if let Err(e) = std::fs::write(&path, doc.dump()) {
            eprintln!("warning: could not write {}: {e}", path.display());
        } else {
            println!("report: {}", path.display());
        }
        if let Some((name, _)) = tracked {
            let path = repo_root().join(format!("BENCH_{name}.json"));
            if let Err(e) = std::fs::write(&path, doc.dump()) {
                eprintln!("warning: could not write {}: {e}", path.display());
            } else {
                println!("tracked report: {}", path.display());
            }
        }
        println!("total {elapsed:.1}s");
    }
}

/// Repository root (parent of the cargo package directory): benches run
/// with varying working directories depending on how they are invoked, so
/// tracked `BENCH_*.json` files anchor on the compile-time manifest path.
pub fn repo_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(std::path::Path::to_path_buf)
        .unwrap_or_else(|| std::path::PathBuf::from("."))
}

/// Format a latency sample as a compact human string.
pub fn fmt_summary(xs: &[f64]) -> String {
    let s = Summary::of(xs);
    format!(
        "mean {:.3}ms p50 {:.3}ms p95 {:.3}ms (n={})",
        s.mean * 1e3,
        s.p50 * 1e3,
        s.p95 * 1e3,
        s.n
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_counts_reps() {
        let mut calls = 0;
        let times = time_fn(2, 5, || calls += 1);
        assert_eq!(times.len(), 5);
        assert_eq!(calls, 7);
        assert!(times.iter().all(|&t| t >= 0.0));
    }

    #[test]
    fn record_builder() {
        let r = Record::new("row")
            .param("beta", 0.3)
            .metric("sweeps", 120.0);
        assert_eq!(r.params[0].1, "0.3");
        assert_eq!(r.metrics[0].1, 120.0);
        let j = r.to_json();
        assert_eq!(j.get("label").and_then(Json::as_str), Some("row"));
    }

    #[test]
    fn report_table_alignment() {
        let mut rep = Report::new("test-table");
        rep.push(Record::new("a").param("k", 1).metric("v", 0.5));
        rep.push(Record::new("b").param("k", 2).metric("v", 1.5));
        let t = rep.table();
        assert!(t.contains("label"));
        assert!(t.lines().count() >= 3);
    }

    #[test]
    fn fmt_summary_contains_fields() {
        let s = fmt_summary(&[0.001, 0.002, 0.003]);
        assert!(s.contains("mean"));
        assert!(s.contains("p95"));
    }

    #[test]
    fn repo_root_is_the_workspace_root() {
        // the tracked BENCH_*.json files land next to the top-level
        // Cargo.toml, not inside rust/ or target/
        assert!(repo_root().join("Cargo.toml").exists());
        assert!(repo_root().join("rust").is_dir());
    }

    #[test]
    fn doc_carries_the_mode_tag() {
        let mut rep = Report::new("tagged");
        rep.push(Record::new("a").metric("v", 1.0));
        let d = rep.doc(Some("lanes"));
        assert_eq!(d.get("bench").and_then(Json::as_str), Some("tagged"));
        assert_eq!(d.get("mode").and_then(Json::as_str), Some("lanes"));
        assert!(rep.doc(None).get("mode").is_none());
    }
}
