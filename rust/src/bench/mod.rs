//! Self-contained benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated timed runs with summary statistics, and a
//! fixed-width table printer whose rows mirror the paper's figures. Every
//! binary in `benches/` is a `harness = false` cargo bench target built on
//! this module, and writes a machine-readable JSON report next to its
//! stdout table so EXPERIMENTS.md can be regenerated.

use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::Summary;

/// Time `f()` `reps` times after `warmup` unmeasured calls; returns
/// per-call seconds.
pub fn time_fn<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

/// One benchmark measurement with its label and metadata.
#[derive(Clone, Debug)]
pub struct Record {
    pub label: String,
    pub params: Vec<(String, String)>,
    pub metrics: Vec<(String, f64)>,
}

impl Record {
    pub fn new(label: impl Into<String>) -> Self {
        Self {
            label: label.into(),
            params: Vec::new(),
            metrics: Vec::new(),
        }
    }

    pub fn param(mut self, key: &str, value: impl ToString) -> Self {
        self.params.push((key.to_string(), value.to_string()));
        self
    }

    pub fn metric(mut self, key: &str, value: f64) -> Self {
        self.metrics.push((key.to_string(), value));
        self
    }

    fn to_json(&self) -> Json {
        let mut obj = vec![("label", Json::from(self.label.as_str()))];
        for (k, v) in &self.params {
            obj.push((k.as_str(), Json::from(v.as_str())));
        }
        for (k, v) in &self.metrics {
            obj.push((k.as_str(), Json::from(*v)));
        }
        Json::obj(obj)
    }
}

/// Collects records, prints the table, writes the JSON report.
pub struct Report {
    pub name: String,
    pub records: Vec<Record>,
    started: Instant,
}

impl Report {
    pub fn new(name: &str) -> Self {
        println!("== bench: {name} ==");
        Self {
            name: name.to_string(),
            records: Vec::new(),
            started: Instant::now(),
        }
    }

    pub fn push(&mut self, r: Record) {
        // stream rows as they complete (benches can run minutes)
        let params = r
            .params
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(" ");
        let metrics = r
            .metrics
            .iter()
            .map(|(k, v)| format!("{k}={v:.6}"))
            .collect::<Vec<_>>()
            .join(" ");
        println!("  {:<24} {params:<40} {metrics}", r.label);
        self.records.push(r);
    }

    /// Render the collected records as an aligned table grouped by label.
    pub fn table(&self) -> String {
        let mut out = String::new();
        // header: union of param and metric keys in first-seen order
        let mut pkeys: Vec<String> = Vec::new();
        let mut mkeys: Vec<String> = Vec::new();
        for r in &self.records {
            for (k, _) in &r.params {
                if !pkeys.contains(k) {
                    pkeys.push(k.clone());
                }
            }
            for (k, _) in &r.metrics {
                if !mkeys.contains(k) {
                    mkeys.push(k.clone());
                }
            }
        }
        out.push_str(&format!("{:<24}", "label"));
        for k in pkeys.iter().chain(mkeys.iter()) {
            out.push_str(&format!("{k:>16}"));
        }
        out.push('\n');
        for r in &self.records {
            out.push_str(&format!("{:<24}", r.label));
            for k in &pkeys {
                let v = r
                    .params
                    .iter()
                    .find(|(pk, _)| pk == k)
                    .map(|(_, v)| v.clone())
                    .unwrap_or_default();
                out.push_str(&format!("{v:>16}"));
            }
            for k in &mkeys {
                let v = r
                    .metrics
                    .iter()
                    .find(|(mk, _)| mk == k)
                    .map(|(_, v)| format!("{v:.4}"))
                    .unwrap_or_default();
                out.push_str(&format!("{v:>16}"));
            }
            out.push('\n');
        }
        out
    }

    /// Write `target/bench-reports/<name>.json` and print the table.
    pub fn finish(self) {
        let table = self.table();
        println!("\n{table}");
        let elapsed = self.started.elapsed().as_secs_f64();
        let doc = Json::obj(vec![
            ("bench", Json::from(self.name.as_str())),
            ("elapsed_s", Json::from(elapsed)),
            (
                "records",
                Json::Arr(self.records.iter().map(Record::to_json).collect()),
            ),
        ]);
        let dir = std::path::Path::new("target/bench-reports");
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(format!("{}.json", self.name));
        if let Err(e) = std::fs::write(&path, doc.dump()) {
            eprintln!("warning: could not write {}: {e}", path.display());
        } else {
            println!("report: {}", path.display());
        }
        println!("total {elapsed:.1}s");
    }
}

/// Format a latency sample as a compact human string.
pub fn fmt_summary(xs: &[f64]) -> String {
    let s = Summary::of(xs);
    format!(
        "mean {:.3}ms p50 {:.3}ms p95 {:.3}ms (n={})",
        s.mean * 1e3,
        s.p50 * 1e3,
        s.p95 * 1e3,
        s.n
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_counts_reps() {
        let mut calls = 0;
        let times = time_fn(2, 5, || calls += 1);
        assert_eq!(times.len(), 5);
        assert_eq!(calls, 7);
        assert!(times.iter().all(|&t| t >= 0.0));
    }

    #[test]
    fn record_builder() {
        let r = Record::new("row")
            .param("beta", 0.3)
            .metric("sweeps", 120.0);
        assert_eq!(r.params[0].1, "0.3");
        assert_eq!(r.metrics[0].1, 120.0);
        let j = r.to_json();
        assert_eq!(j.get("label").and_then(Json::as_str), Some("row"));
    }

    #[test]
    fn report_table_alignment() {
        let mut rep = Report::new("test-table");
        rep.push(Record::new("a").param("k", 1).metric("v", 0.5));
        rep.push(Record::new("b").param("k", 2).metric("v", 1.5));
        let t = rep.table();
        assert!(t.contains("label"));
        assert!(t.lines().count() >= 3);
    }

    #[test]
    fn fmt_summary_contains_fields() {
        let s = fmt_summary(&[0.001, 0.002, 0.003]);
        assert!(s.contains("mean"));
        assert!(s.contains("p95"));
    }
}
