//! # pdgibbs — parallel Gibbs sampling via probabilistic duality
//!
//! Production reproduction of *"Probabilistic Duality for Parallel Gibbs
//! Sampling without Graph Coloring"* (Mescheder, Nowozin, Geiger, 2016).
//!
//! The paper augments a discrete pairwise MRF `p(x)` with one auxiliary
//! ("dual") variable per factor so that the joint becomes an exponential
//! family harmonium `p(x, θ) ∝ h(x) g(θ) exp⟨s(x), r(θ)⟩`. Blockwise Gibbs
//! on `(x, θ)` then resamples *every* primal variable in parallel, and
//! *every* dual variable in parallel — no graph coloring, no preprocessing,
//! and factors can be added/removed at any time.
//!
//! A guided tour lives in the repository: `README.md` for the quickstart,
//! `docs/ARCHITECTURE.md` for the layer diagram and the paper→code map,
//! `docs/BENCHMARKS.md` for every bench mode/flag and the tracked
//! `BENCH_*.json` trajectory files.
//!
//! ## Architecture at a glance (PRs 1–6)
//!
//! The crate grew bottom-up, one serving layer per PR:
//!
//! 1. **Lane engine** (PR 1) — [`engine::LanePdSampler`] packs 64 chains
//!    per `u64` word, variable-major, one incidence traversal per
//!    variable per sweep; RNG streams keyed `(sweep, site)` via
//!    [`rng::Pcg64::split2`] make trajectories invariant to pooling and
//!    chunking.
//! 2. **Flat sweep kernels** (PR 2) — [`duality::DualModel`] mirrors its
//!    nested incidence as a CSR arena ([`duality::CsrIncidence`]) and
//!    caches churn-invalidated conditionals (per-slot four-sigmoid θ
//!    tables; per-variable Bernoulli acceptance tables), so steady-state
//!    sweeps draw without evaluating any exponential.
//! 3. **Multi-tenant coordinator** (PR 3) — [`coordinator::Coordinator`]
//!    routes tenants to shard workers that interleave foreground queries
//!    with deficit-round-robin background sweeping, all sharing one
//!    [`util::ThreadPool`].
//! 4. **SIMD-tiled kernels** (PR 4) — the innermost sweep bodies are
//!    runtime-selectable [`engine::kernels::LaneKernel`]
//!    implementations ([`engine::KernelKind`]): per-lane `scalar`
//!    reference loops, stable-Rust `tiled` 8-lane bodies over 64-byte
//!    aligned buffers with jump-ahead RNG refill
//!    ([`rng::Pcg64::fill_f64`]), or `core::simd` under the
//!    `nightly-simd` feature — all bit-identical in trajectory.
//! 5. **Statistical validation** (PR 5) — bit-identity only proves
//!    every path samples the *same* trajectory; [`validation`] proves
//!    that trajectory targets the *right* distribution: one
//!    [`validation::SamplingPath`] trait over every sampler, kernel,
//!    pool, and the live coordinator, gated against exact enumeration
//!    with deterministic z/TV/chi-square thresholds over the scenario
//!    zoo ([`workloads::scenarios`]).
//! 6. **Network serving edge** (PR 6, this one) — the coordinator gets
//!    a TCP front-end: a line-oriented wire language
//!    ([`coordinator::protocol`]) whose every malformed frame is
//!    answered with a spanned, labeled diagnostic
//!    ([`util::Diagnostic`]), connection multiplexing onto the shard
//!    queues ([`coordinator::NetServer`]), per-tenant/per-shard
//!    admission control against the outstanding-request ledger
//!    ([`coordinator::Depth`] — explicit `overloaded` rejections, never
//!    unbounded queues), edge batching, latency histograms
//!    ([`coordinator::Metrics::observe_hist`]), and a closed-loop
//!    socket load generator ([`workloads::run_net_load`]). See
//!    `docs/PROTOCOL.md`.
//!
//! ## Crate layout
//!
//! * [`graph`] — dynamic pairwise factor graph + builders + coloring baseline.
//! * [`duality`] — §4.1 positive 2×2 factorization, Theorem-2 dual
//!   parameters, multi-state 0–1 encoding, Swendsen–Wang decompositions;
//!   [`duality::DualModel`] keeps a nested reference incidence mirrored by
//!   a flat CSR arena ([`duality::CsrIncidence`]) and churn-invalidated
//!   conditional caches (per-slot four-sigmoid θ tables, per-variable
//!   Bernoulli acceptance tables in the tile-aligned
//!   [`duality::XTableArena`]).
//! * [`samplers`] — sequential Gibbs, chromatic Gibbs, the primal–dual
//!   sampler (native parallel, the readable nested-incidence reference),
//!   Swendsen–Wang, and tree-blocked PD (§5.4).
//! * [`engine`] — lane-batched multi-chain execution: 64 chains per `u64`
//!   word, variable-major state, one *flat-arena* incidence traversal per
//!   variable per sweep, cached-table draws, SIMD-tiled runtime-selected
//!   kernels ([`engine::kernels`]), degree-aware cache-line-aligned
//!   pooled chunking ([`engine::LanePdSampler`]); the substrate under the
//!   ensemble.
//! * [`inference`] — exact enumeration/transfer-matrix oracles, tree BP,
//!   mean-field & EM-MAP (§5.3), log-partition estimators (§5.2).
//! * [`diagnostics`] — PSRF (Gelman–Rubin), ESS, mixing-time extraction.
//! * [`runtime`] — PJRT executor for the AOT-lowered JAX/Pallas artifacts
//!   (Layer 1+2); Python never runs on the request path.
//! * [`coordinator`] — Layer 3: the **multi-tenant sharded coordinator**:
//!   a hash router over `S` shard workers, each owning a registry of
//!   tenants (graph + lane-batched ensemble) and interleaving foreground
//!   requests with deficit-round-robin background sweeping weighted by
//!   per-tenant sweep cost; label-scoped metrics, dispatch policy, a
//!   single-tenant compat façade ([`coordinator::Server`]), and the TCP
//!   serving edge ([`coordinator::protocol`], [`coordinator::net`]) with
//!   spanned wire diagnostics and admission-control backpressure.
//! * [`validation`] — the statistical correctness subsystem: one
//!   [`validation::SamplingPath`] trait over every sampler/engine/serving
//!   path, an exact forward sampler, and deterministic exactness gates
//!   (marginal z-tests, joint TV + chi-square against enumeration) run by
//!   `tests/statistical_validation.rs` over the scenario zoo
//!   ([`workloads::scenarios`]); see `docs/TESTING.md`.
//! * [`workloads`] — the paper's three synthetic model families + churn
//!   traces + multi-tenant arrival/departure traffic traces + the
//!   statistical-validation scenario zoo + the image-denoising demo MRF.
//! * [`bench`] — self-contained bench harness (criterion is unavailable
//!   offline) used by every `benches/` binary.
//! * [`util`] — substrates built from scratch for the offline environment:
//!   JSON, CLI parsing, thread pool (uniform, weighted, and
//!   alignment-aware scoped parallel-for, [`util::balanced_ranges`]),
//!   cache-line-aligned storage ([`util::AlignedF64s`]), property
//!   testing, union-find, error context ([`util::error`], replacing
//!   `anyhow`).

#![warn(missing_docs)]
#![cfg_attr(feature = "nightly-simd", feature(portable_simd))]

pub mod bench;
pub mod bench_support;
pub mod coordinator;
pub mod diagnostics;
pub mod duality;
pub mod engine;
pub mod graph;
pub mod inference;
pub mod rng;
pub mod runtime;
pub mod samplers;
pub mod util;
pub mod validation;
pub mod workloads;

pub use duality::{DualFactor, DualModel};
pub use engine::LanePdSampler;
pub use graph::{FactorGraph, FactorId, VarId};
pub use samplers::Sampler;
