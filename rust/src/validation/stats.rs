//! Statistical machinery behind the exactness gates: distribution
//! distances, test statistics, and the quantile functions their
//! thresholds come from.
//!
//! Everything here is classical frequentist testing, specialized to the
//! deterministic-CI setting of [`super::harness`]: all runs are
//! seed-fixed, so a gate either always passes or always fails for a given
//! build — the `alpha` levels below size the thresholds so that a
//! *correct* sampler passes with overwhelming margin at the committed
//! seeds while real distributional bugs (a wrong conditional table, a
//! missed cache invalidation, a biased draw) still land far outside them.
//!
//! * [`inv_norm_cdf`] — Acklam's rational approximation of the standard
//!   normal quantile (|relative error| < 1.2e-9), the source of every
//!   z-threshold.
//! * [`chi2_quantile`] — Wilson–Hilferty cube approximation of the
//!   chi-square quantile (within ~2% over the df range the harness uses).
//! * [`total_variation`] — ½·L1 between two distributions on the same
//!   support.
//! * [`pooled_chi2`] — Pearson's X² with small-expected-count buckets
//!   pooled into a tail bucket, the standard validity fix.

/// Standard normal quantile `Φ⁻¹(p)` (Acklam's algorithm, |rel err| ≤
/// 1.2e-9 on (0, 1)). Panics outside the open unit interval.
pub fn inv_norm_cdf(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "quantile of p={p} outside (0, 1)");
    // rational approximations per region; coefficients from Acklam (2003)
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    let tail = |p_tail: f64| -> f64 {
        let q = (-2.0 * p_tail.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };
    if p < P_LOW {
        tail(p)
    } else if p > 1.0 - P_LOW {
        -tail(1.0 - p)
    } else {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    }
}

/// Two-sided z critical value at level `alpha`: `Φ⁻¹(1 − alpha/2)`.
pub fn z_critical(alpha: f64) -> f64 {
    inv_norm_cdf(1.0 - alpha / 2.0)
}

/// Chi-square quantile at probability `p` with `df` degrees of freedom
/// (Wilson–Hilferty: `df·(1 − 2/(9df) + z_p·√(2/(9df)))³`, accurate to a
/// few percent for df ≥ 2 — the harness multiplies a safety factor on
/// top, so the approximation error is immaterial).
pub fn chi2_quantile(df: usize, p: f64) -> f64 {
    assert!(df >= 1, "chi-square needs at least 1 degree of freedom");
    let k = df as f64;
    let z = inv_norm_cdf(p);
    let h = 2.0 / (9.0 * k);
    k * (1.0 - h + z * h.sqrt()).powi(3)
}

/// Total-variation distance `½ Σ_s |p(s) − q(s)|` between two
/// distributions on the same support.
pub fn total_variation(p: &[f64], q: &[f64]) -> f64 {
    assert_eq!(p.len(), q.len());
    0.5 * p.iter().zip(q).map(|(a, b)| (a - b).abs()).sum::<f64>()
}

/// Pearson's X² of observed counts against expected probabilities, with
/// every bucket whose expected count falls below `min_expected` pooled
/// into one tail bucket (the classical validity condition). Returns
/// `(statistic, degrees of freedom)`; df is `buckets − 1`, and `None`
/// when fewer than 2 buckets survive pooling (no testable shape).
pub fn pooled_chi2(
    observed: &[u64],
    expected_probs: &[f64],
    total: f64,
    min_expected: f64,
) -> Option<(f64, usize)> {
    assert_eq!(observed.len(), expected_probs.len());
    let mut stat = 0.0;
    let mut buckets = 0usize;
    let mut tail_obs = 0.0;
    let mut tail_exp = 0.0;
    for (&o, &p) in observed.iter().zip(expected_probs) {
        let e = p * total;
        if e >= min_expected {
            let d = o as f64 - e;
            stat += d * d / e;
            buckets += 1;
        } else {
            tail_obs += o as f64;
            tail_exp += e;
        }
    }
    if tail_exp >= min_expected {
        let d = tail_obs - tail_exp;
        stat += d * d / tail_exp;
        buckets += 1;
    } else if tail_exp > 0.0 && buckets > 0 && tail_obs > 0.0 {
        // tail too thin for its own bucket but observations landed
        // there: fold the residual in conservatively (denominator
        // floored at min_expected so a near-impossible state cannot
        // dominate by itself)
        let d = tail_obs - tail_exp;
        stat += d * d / tail_exp.max(min_expected);
    }
    if buckets >= 2 {
        Some((stat, buckets - 1))
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normal_quantile_known_values() {
        assert!(inv_norm_cdf(0.5).abs() < 1e-9);
        assert!((inv_norm_cdf(0.975) - 1.959964).abs() < 1e-5);
        assert!((inv_norm_cdf(0.9986501019683699) - 3.0).abs() < 1e-6);
        assert!((inv_norm_cdf(0.0013498980316301) + 3.0).abs() < 1e-6);
        // deep tail (the Bonferroni-corrected gate regime)
        assert!((inv_norm_cdf(1.0 - 1e-9) - 5.9978).abs() < 1e-3);
        // antisymmetry
        for p in [0.001, 0.01, 0.2, 0.4] {
            assert!((inv_norm_cdf(p) + inv_norm_cdf(1.0 - p)).abs() < 1e-9);
        }
    }

    #[test]
    #[should_panic(expected = "outside")]
    fn normal_quantile_rejects_boundary() {
        inv_norm_cdf(1.0);
    }

    #[test]
    fn chi2_quantile_matches_tables() {
        // (df, p, table value)
        for &(df, p, want) in &[
            (2usize, 0.95, 5.991),
            (10, 0.95, 18.307),
            (10, 0.999, 29.588),
            (100, 0.95, 124.342),
            (255, 0.999, 330.9),
        ] {
            let got = chi2_quantile(df, p);
            assert!(
                (got / want - 1.0).abs() < 0.02,
                "df={df} p={p}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn tv_basics() {
        assert_eq!(total_variation(&[0.5, 0.5], &[0.5, 0.5]), 0.0);
        assert!((total_variation(&[1.0, 0.0], &[0.0, 1.0]) - 1.0).abs() < 1e-12);
        assert!((total_variation(&[0.6, 0.4], &[0.4, 0.6]) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn chi2_pooling_respects_min_expected() {
        // uniform on 4 states, N=40: all expected = 10 ≥ 8 → 4 buckets
        let probs = [0.25; 4];
        let obs = [10u64, 10, 10, 10];
        let (stat, df) = pooled_chi2(&obs, &probs, 40.0, 8.0).unwrap();
        assert_eq!(df, 3);
        assert!(stat.abs() < 1e-12);
        // skewed: two tiny states pool into one tail bucket
        let probs = [0.90, 0.08, 0.01, 0.01];
        let obs = [90u64, 8, 1, 1];
        let (stat, df) = pooled_chi2(&obs, &probs, 100.0, 8.0).unwrap();
        assert_eq!(df, 1, "tail expected 2 < 8 folds away, 90/8 survive");
        assert!(stat < 0.5, "near-perfect agreement: {stat}");
    }

    #[test]
    fn chi2_detects_wrong_distribution() {
        let probs = [0.25; 4];
        let obs = [70u64, 10, 10, 10];
        let (stat, df) = pooled_chi2(&obs, &probs, 100.0, 8.0).unwrap();
        assert_eq!(df, 3);
        assert!(stat > chi2_quantile(df, 0.999), "stat={stat}");
    }

    #[test]
    fn chi2_degenerate_support_is_untestable() {
        assert!(pooled_chi2(&[100], &[1.0], 100.0, 8.0).is_none());
        // everything pools into one tail bucket → still untestable
        assert!(pooled_chi2(&[1, 1], &[0.5, 0.5], 2.0, 8.0).is_none());
    }
}
