//! Statistical correctness subsystem: exactness gates for every sampling
//! path the crate ships.
//!
//! PRs 2–4 guaranteed *bit-identity* — every kernel, pool size, shard
//! count, and chunking samples the same trajectory. Bit-identity says
//! nothing about whether that shared trajectory targets the right
//! distribution: a wrong cached conditional in `DualModel` would pass
//! every equivalence test while biasing every path identically. This
//! module is the correctness floor under all of it (the paper's central
//! claim is exactness — the PD chain targets the true stationary
//! distribution even on densely coupled graphs with no small coloring,
//! unlike Hogwild-style approximate samplers):
//!
//! * [`path`] — [`SamplingPath`], one dyn-safe trait unifying the five
//!   classical `samplers::` baselines, the lane engine (every kernel ×
//!   pool), [`crate::coordinator::PdEnsemble`], and the live coordinator
//!   tenant path, so one harness drives them all.
//! * [`forward`] — [`ExactForward`], iid ground-truth draws by joint-CDF
//!   inversion (≤ 14 variables, ≤ 2¹⁵ base-`k` state codes) plus
//!   deliberately biased variants that calibrate the gates' power and a
//!   [`ExactForward::conditioned`] variant for evidence scenarios.
//! * [`stats`] — quantile functions, total variation, pooled chi-square.
//! * [`harness`] — [`validate`]: burn in, thin by the scenario's
//!   autocorrelation bound, and gate empirical marginals (z-tests,
//!   Bonferroni-corrected, one per `(site, state)` entry on K-state
//!   models) and the empirical joint (TV + chi-square) against exact
//!   enumeration; [`validate_conditioned`] gates against the clamped
//!   conditional law instead. Deterministic: fixed seeds, precomputed
//!   thresholds, no flakes.
//!
//! The scenario zoo the suite runs over lives in
//! [`crate::workloads::scenarios`]; the suite itself is
//! `rust/tests/statistical_validation.rs`, and `docs/TESTING.md`
//! describes the test tiers and how to extend them.

pub mod forward;
pub mod harness;
pub mod path;
pub mod stats;

pub use forward::{
    joint_probs, marginals_from_joint, marginals_from_joint_k, ExactForward, MAX_JOINT_STATES,
    MAX_JOINT_VARS,
};
pub use harness::{validate, validate_conditioned, Gate, GateConfig, ValidationReport};
pub use path::{ClassicalPath, CoordinatorPath, EnsemblePath, LanePath, SamplingPath};
pub use stats::{chi2_quantile, inv_norm_cdf, pooled_chi2, total_variation, z_critical};
