//! Exact forward sampling by joint-CDF inversion — the harness's ground
//! truth *sampler* (as opposed to its ground truth *distribution*, which
//! is exact enumeration).
//!
//! For models of ≤ [`MAX_JOINT_VARS`] variables the full joint is small
//! enough to tabulate: [`joint_probs`] enumerates the normalized
//! probability of every state code, and [`ExactForward`] draws iid states
//! by inverting the cumulative distribution. Two jobs:
//!
//! 1. **Calibration** — the gates of [`super::harness`] must *pass* on
//!    iid draws from the true joint. If they don't, the thresholds are
//!    mis-derived, independent of any sampler bug.
//! 2. **Power** — a deliberately perturbed joint
//!    ([`ExactForward::tilted`] shifts every marginal,
//!    [`ExactForward::parity_tilted`] reshapes the joint while barely
//!    moving marginals) must *fail* the gates. If it doesn't, the gates
//!    are too loose to certify anything.

use crate::graph::FactorGraph;
use crate::inference::exact::log_sum_exp;
use crate::rng::{Pcg64, RngCore};
use crate::workloads::ChurnOp;

use super::path::SamplingPath;

/// Joint tabulation cap: `2^14` states keeps enumeration, histogramming,
/// and chi-square pooling comfortably in cache for every zoo scenario.
pub const MAX_JOINT_VARS: usize = 14;

/// Normalized probability of every state code (bit `v` of the code is
/// `x_v`). Panics above [`MAX_JOINT_VARS`] variables.
pub fn joint_probs(g: &FactorGraph) -> Vec<f64> {
    let n = g.num_vars();
    assert!(
        n <= MAX_JOINT_VARS,
        "joint tabulation limited to {MAX_JOINT_VARS} variables, got {n}"
    );
    let mut x = vec![0u8; n];
    let mut lps = Vec::with_capacity(1 << n);
    for code in 0..1usize << n {
        for (v, xv) in x.iter_mut().enumerate() {
            *xv = ((code >> v) & 1) as u8;
        }
        lps.push(g.log_prob_unnorm(&x));
    }
    let lz = log_sum_exp(&lps);
    lps.iter().map(|lp| (lp - lz).exp()).collect()
}

/// Per-variable marginals `P(x_v = 1)` of a tabulated joint.
pub fn marginals_from_joint(probs: &[f64]) -> Vec<f64> {
    assert!(probs.len().is_power_of_two());
    let n = probs.len().trailing_zeros() as usize;
    let mut out = vec![0.0; n];
    for (code, &p) in probs.iter().enumerate() {
        for (v, m) in out.iter_mut().enumerate() {
            if (code >> v) & 1 == 1 {
                *m += p;
            }
        }
    }
    out
}

/// Iid sampler of a tabulated joint via CDF inversion; implements
/// [`SamplingPath`] (one chain, one fresh state per "sweep", τ = 1).
pub struct ExactForward {
    label: String,
    n: usize,
    cdf: Vec<f64>,
    rng: Pcg64,
    state: Vec<u8>,
}

impl ExactForward {
    /// Forward sampler of the model's true joint.
    pub fn new(g: &FactorGraph, seed: u64) -> Self {
        Self::perturbed(g, seed, "exact-forward", |_| 0.0)
    }

    /// Forward sampler of the *biased* joint `p'(x) ∝ p(x)·e^{eps·Σ_v x_v}`
    /// — every marginal's log-odds shifts by `eps`, so the marginal
    /// z-gates must reject it (power check).
    pub fn tilted(g: &FactorGraph, seed: u64, eps: f64) -> Self {
        Self::perturbed(g, seed, "exact-forward-tilted", move |code| {
            eps * (code.count_ones() as f64)
        })
    }

    /// Forward sampler of `p'(x) ∝ p(x)·e^{±eps}` (sign = parity of
    /// `Σ x_v`) — a joint reshaping that leaves marginals almost exactly
    /// in place, so only the joint TV/chi-square gates can catch it
    /// (power check for the state-distribution gates).
    pub fn parity_tilted(g: &FactorGraph, seed: u64, eps: f64) -> Self {
        Self::perturbed(g, seed, "exact-forward-parity", move |code| {
            if code.count_ones() % 2 == 0 {
                eps
            } else {
                -eps
            }
        })
    }

    /// Forward sampler of `p'(x) ∝ p(x)·e^{logw(code)}` for an arbitrary
    /// log-weight over state codes.
    pub fn perturbed(
        g: &FactorGraph,
        seed: u64,
        label: &str,
        logw: impl Fn(usize) -> f64,
    ) -> Self {
        let probs = joint_probs(g);
        let weighted: Vec<f64> = probs
            .iter()
            .enumerate()
            .map(|(code, &p)| p.ln() + logw(code))
            .collect();
        let lz = log_sum_exp(&weighted);
        let mut acc = 0.0;
        let cdf: Vec<f64> = weighted
            .iter()
            .map(|&lp| {
                acc += (lp - lz).exp();
                acc
            })
            .collect();
        let n = g.num_vars();
        Self {
            label: label.to_string(),
            n,
            cdf,
            rng: Pcg64::seed(seed),
            state: vec![0; n],
        }
    }

    fn draw_code(&mut self) -> usize {
        let u = self.rng.next_f64();
        self.cdf
            .partition_point(|&c| c <= u)
            .min(self.cdf.len() - 1)
    }
}

impl SamplingPath for ExactForward {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn num_vars(&self) -> usize {
        self.n
    }

    fn sweep(&mut self) {
        let code = self.draw_code();
        for (v, xv) in self.state.iter_mut().enumerate() {
            *xv = ((code >> v) & 1) as u8;
        }
    }

    fn visit_states(&self, f: &mut dyn FnMut(&[u8])) -> bool {
        f(&self.state);
        true
    }

    fn apply_churn(&mut self, _ops: &[ChurnOp]) -> bool {
        false // the tabulated joint is frozen at construction
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::PairFactor;
    use crate::inference::exact;
    use crate::workloads;

    #[test]
    fn joint_probs_match_enumeration() {
        let g = workloads::ising_grid(2, 3, 0.3, 0.1);
        let probs = joint_probs(&g);
        assert_eq!(probs.len(), 64);
        assert!((probs.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        let want = exact::enumerate(&g).marginals;
        let got = marginals_from_joint(&probs);
        for v in 0..6 {
            assert!((got[v] - want[v]).abs() < 1e-12, "v={v}");
        }
    }

    #[test]
    fn forward_sampler_frequencies_match_joint() {
        let mut g = FactorGraph::new(3);
        g.set_unary(0, 0.8);
        g.add_factor(PairFactor::ising(0, 1, 0.5));
        g.add_factor(PairFactor::ising(1, 2, -0.4));
        let probs = joint_probs(&g);
        let mut fwd = ExactForward::new(&g, 9);
        let n = 200_000usize;
        let mut hist = vec![0u64; 8];
        for _ in 0..n {
            fwd.sweep();
            fwd.visit_states(&mut |x| {
                let code = x.iter().enumerate().fold(0usize, |c, (v, &b)| {
                    c | ((b as usize) << v)
                });
                hist[code] += 1;
            });
        }
        for (code, &p) in probs.iter().enumerate() {
            let emp = hist[code] as f64 / n as f64;
            // iid binomial: 5σ band
            let se = (p * (1.0 - p) / n as f64).sqrt();
            assert!(
                (emp - p).abs() < 5.0 * se + 1e-9,
                "code {code}: {emp} vs {p} (se {se})"
            );
        }
    }

    #[test]
    fn tilt_shifts_marginals_parity_tilt_does_not() {
        let g = workloads::ising_grid(2, 2, 0.2, 0.0);
        let base = marginals_from_joint(&joint_probs(&g));
        // reconstruct each perturbed joint through the sampler's own CDF
        let tilt = ExactForward::tilted(&g, 1, 0.4);
        let parity = ExactForward::parity_tilted(&g, 1, 0.6);
        let probs_of = |fwd: &ExactForward| -> Vec<f64> {
            let mut prev = 0.0;
            fwd.cdf
                .iter()
                .map(|&c| {
                    let p = c - prev;
                    prev = c;
                    p
                })
                .collect()
        };
        let tilted_m = marginals_from_joint(&probs_of(&tilt));
        let parity_m = marginals_from_joint(&probs_of(&parity));
        for v in 0..4 {
            assert!(
                (tilted_m[v] - base[v]).abs() > 0.05,
                "tilt must move marginal {v}: {} vs {}",
                tilted_m[v],
                base[v]
            );
            assert!(
                (parity_m[v] - base[v]).abs() < 0.02,
                "parity tilt must keep marginal {v}: {} vs {}",
                parity_m[v],
                base[v]
            );
        }
    }

    #[test]
    #[should_panic(expected = "limited to 14")]
    fn joint_tabulation_caps_at_14_vars() {
        joint_probs(&FactorGraph::new(15));
    }
}
