//! Exact forward sampling by joint-CDF inversion — the harness's ground
//! truth *sampler* (as opposed to its ground truth *distribution*, which
//! is exact enumeration).
//!
//! For models of ≤ [`MAX_JOINT_VARS`] variables the full joint is small
//! enough to tabulate: [`joint_probs`] enumerates the normalized
//! probability of every state code, and [`ExactForward`] draws iid states
//! by inverting the cumulative distribution. Two jobs:
//!
//! 1. **Calibration** — the gates of [`super::harness`] must *pass* on
//!    iid draws from the true joint. If they don't, the thresholds are
//!    mis-derived, independent of any sampler bug.
//! 2. **Power** — a deliberately perturbed joint
//!    ([`ExactForward::tilted`] shifts every marginal,
//!    [`ExactForward::parity_tilted`] reshapes the joint while barely
//!    moving marginals) must *fail* the gates. If it doesn't, the gates
//!    are too loose to certify anything.

use crate::graph::FactorGraph;
use crate::inference::exact::log_sum_exp;
use crate::rng::{Pcg64, RngCore};
use crate::workloads::ChurnOp;

use super::path::SamplingPath;

/// Joint tabulation cap: `2^14` binary states keeps enumeration,
/// histogramming, and chi-square pooling comfortably in cache for every
/// zoo scenario.
pub const MAX_JOINT_VARS: usize = 14;

/// K-state joint tabulation cap: `k^n` may not exceed `2^15` state
/// codes (e.g. a 3×3 Potts grid at k = 3 is 3⁹ ≈ 20k codes).
pub const MAX_JOINT_STATES: usize = 1 << 15;

/// Number of joint state codes of `g` — `k^n`, gated by both tabulation
/// caps. Codes are base-`k` and variable-minor: digit `v` of a code is
/// `x_v`, which coincides with the historical bit codes at `k = 2`.
pub fn num_joint_states(g: &FactorGraph) -> usize {
    let (n, k) = (g.num_vars(), g.k());
    assert!(
        n <= MAX_JOINT_VARS,
        "joint tabulation limited to {MAX_JOINT_VARS} variables, got {n}"
    );
    k.checked_pow(n as u32)
        .filter(|&s| s <= MAX_JOINT_STATES)
        .unwrap_or_else(|| {
            panic!("joint tabulation limited to {MAX_JOINT_STATES} states, got {k}^{n}")
        })
}

/// Write the base-`k` digits of `code` into `x` (digit `v` = `x_v`).
#[inline]
pub(crate) fn decode_state(mut code: usize, k: usize, x: &mut [u8]) {
    for xv in x.iter_mut() {
        *xv = (code % k) as u8;
        code /= k;
    }
}

/// Normalized probability of every base-`k` state code of `g` (digit `v`
/// of the code is `x_v`; plain bit codes when `k = 2`). Panics above the
/// tabulation caps.
pub fn joint_probs(g: &FactorGraph) -> Vec<f64> {
    let states = num_joint_states(g);
    let k = g.k();
    let mut x = vec![0u8; g.num_vars()];
    let mut lps = Vec::with_capacity(states);
    for code in 0..states {
        decode_state(code, k, &mut x);
        lps.push(g.log_prob_unnorm(&x));
    }
    let lz = log_sum_exp(&lps);
    lps.iter().map(|lp| (lp - lz).exp()).collect()
}

/// Per-variable marginals `P(x_v = 1)` of a tabulated *binary* joint
/// (bit codes); see [`marginals_from_joint_k`] for the K-state form.
pub fn marginals_from_joint(probs: &[f64]) -> Vec<f64> {
    assert!(probs.len().is_power_of_two());
    let n = probs.len().trailing_zeros() as usize;
    let mut out = vec![0.0; n];
    for (code, &p) in probs.iter().enumerate() {
        for (v, m) in out.iter_mut().enumerate() {
            if (code >> v) & 1 == 1 {
                *m += p;
            }
        }
    }
    out
}

/// Flattened non-zero-state marginals of a tabulated base-`k` joint
/// over `n` variables: `out[v·(k−1) + (s−1)] = P(x_v = s)` for
/// `s ∈ 1..k` — the crate-wide K-state marginal convention, which
/// degenerates to the historical length-`n` `P(x_v = 1)` vector at
/// `k = 2`.
pub fn marginals_from_joint_k(probs: &[f64], n: usize, k: usize) -> Vec<f64> {
    assert_eq!(probs.len(), k.pow(n as u32), "joint size must be k^n");
    let mut out = vec![0.0; n * (k - 1)];
    for (code, &p) in probs.iter().enumerate() {
        let mut c = code;
        for v in 0..n {
            let s = c % k;
            c /= k;
            if s > 0 {
                out[v * (k - 1) + (s - 1)] += p;
            }
        }
    }
    out
}

/// Iid sampler of a tabulated joint via CDF inversion; implements
/// [`SamplingPath`] (one chain, one fresh state per "sweep", τ = 1).
pub struct ExactForward {
    label: String,
    n: usize,
    k: usize,
    cdf: Vec<f64>,
    rng: Pcg64,
    state: Vec<u8>,
}

impl ExactForward {
    /// Forward sampler of the model's true joint.
    pub fn new(g: &FactorGraph, seed: u64) -> Self {
        Self::perturbed(g, seed, "exact-forward", |_| 0.0)
    }

    /// Forward sampler of the joint *conditioned on evidence*: codes
    /// violating any `(site, state)` pair get zero mass, the rest
    /// renormalize. This is the ground truth of
    /// [`super::validate_conditioned`] — clamped-site calibration.
    pub fn conditioned(g: &FactorGraph, evidence: &[(usize, u8)], seed: u64) -> Self {
        let (n, k) = (g.num_vars(), g.k());
        for &(v, s) in evidence {
            assert!(v < n && (s as usize) < k, "evidence ({v}, {s}) out of range");
        }
        let mut probs = joint_probs(g);
        let mut x = vec![0u8; n];
        for (code, p) in probs.iter_mut().enumerate() {
            decode_state(code, k, &mut x);
            if evidence.iter().any(|&(v, s)| x[v] != s) {
                *p = 0.0;
            }
        }
        let z: f64 = probs.iter().sum();
        assert!(z > 0.0, "evidence has zero probability");
        let mut acc = 0.0;
        let cdf = probs
            .iter()
            .map(|&p| {
                acc += p / z;
                acc
            })
            .collect();
        Self {
            label: "exact-forward-cond".to_string(),
            n,
            k,
            cdf,
            rng: Pcg64::seed(seed),
            state: vec![0; n],
        }
    }

    /// Forward sampler of the *biased* joint `p'(x) ∝ p(x)·e^{eps·Σ_v x_v}`
    /// — every marginal's log-odds shifts by `eps`, so the marginal
    /// z-gates must reject it (power check).
    pub fn tilted(g: &FactorGraph, seed: u64, eps: f64) -> Self {
        Self::perturbed(g, seed, "exact-forward-tilted", move |code| {
            eps * (code.count_ones() as f64)
        })
    }

    /// Forward sampler of `p'(x) ∝ p(x)·e^{±eps}` (sign = parity of
    /// `Σ x_v`) — a joint reshaping that leaves marginals almost exactly
    /// in place, so only the joint TV/chi-square gates can catch it
    /// (power check for the state-distribution gates).
    pub fn parity_tilted(g: &FactorGraph, seed: u64, eps: f64) -> Self {
        Self::perturbed(g, seed, "exact-forward-parity", move |code| {
            if code.count_ones() % 2 == 0 {
                eps
            } else {
                -eps
            }
        })
    }

    /// Forward sampler of `p'(x) ∝ p(x)·e^{logw(code)}` for an arbitrary
    /// log-weight over state codes.
    pub fn perturbed(
        g: &FactorGraph,
        seed: u64,
        label: &str,
        logw: impl Fn(usize) -> f64,
    ) -> Self {
        let probs = joint_probs(g);
        let weighted: Vec<f64> = probs
            .iter()
            .enumerate()
            .map(|(code, &p)| p.ln() + logw(code))
            .collect();
        let lz = log_sum_exp(&weighted);
        let mut acc = 0.0;
        let cdf: Vec<f64> = weighted
            .iter()
            .map(|&lp| {
                acc += (lp - lz).exp();
                acc
            })
            .collect();
        let n = g.num_vars();
        Self {
            label: label.to_string(),
            n,
            k: g.k(),
            cdf,
            rng: Pcg64::seed(seed),
            state: vec![0; n],
        }
    }

    fn draw_code(&mut self) -> usize {
        let u = self.rng.next_f64();
        self.cdf
            .partition_point(|&c| c <= u)
            .min(self.cdf.len() - 1)
    }
}

impl SamplingPath for ExactForward {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn num_vars(&self) -> usize {
        self.n
    }

    fn k(&self) -> usize {
        self.k
    }

    fn sweep(&mut self) {
        let code = self.draw_code();
        decode_state(code, self.k, &mut self.state);
    }

    fn visit_states(&self, f: &mut dyn FnMut(&[u8])) -> bool {
        f(&self.state);
        true
    }

    fn apply_churn(&mut self, _ops: &[ChurnOp]) -> bool {
        false // the tabulated joint is frozen at construction
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::PairFactor;
    use crate::inference::exact;
    use crate::workloads;

    #[test]
    fn joint_probs_match_enumeration() {
        let g = workloads::ising_grid(2, 3, 0.3, 0.1);
        let probs = joint_probs(&g);
        assert_eq!(probs.len(), 64);
        assert!((probs.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        let want = exact::enumerate(&g).marginals;
        let got = marginals_from_joint(&probs);
        for v in 0..6 {
            assert!((got[v] - want[v]).abs() < 1e-12, "v={v}");
        }
    }

    #[test]
    fn forward_sampler_frequencies_match_joint() {
        let mut g = FactorGraph::new(3);
        g.set_unary(0, 0.8);
        g.add_factor(PairFactor::ising(0, 1, 0.5));
        g.add_factor(PairFactor::ising(1, 2, -0.4));
        let probs = joint_probs(&g);
        let mut fwd = ExactForward::new(&g, 9);
        let n = 200_000usize;
        let mut hist = vec![0u64; 8];
        for _ in 0..n {
            fwd.sweep();
            fwd.visit_states(&mut |x| {
                let code = x.iter().enumerate().fold(0usize, |c, (v, &b)| {
                    c | ((b as usize) << v)
                });
                hist[code] += 1;
            });
        }
        for (code, &p) in probs.iter().enumerate() {
            let emp = hist[code] as f64 / n as f64;
            // iid binomial: 5σ band
            let se = (p * (1.0 - p) / n as f64).sqrt();
            assert!(
                (emp - p).abs() < 5.0 * se + 1e-9,
                "code {code}: {emp} vs {p} (se {se})"
            );
        }
    }

    #[test]
    fn tilt_shifts_marginals_parity_tilt_does_not() {
        let g = workloads::ising_grid(2, 2, 0.2, 0.0);
        let base = marginals_from_joint(&joint_probs(&g));
        // reconstruct each perturbed joint through the sampler's own CDF
        let tilt = ExactForward::tilted(&g, 1, 0.4);
        let parity = ExactForward::parity_tilted(&g, 1, 0.6);
        let probs_of = |fwd: &ExactForward| -> Vec<f64> {
            let mut prev = 0.0;
            fwd.cdf
                .iter()
                .map(|&c| {
                    let p = c - prev;
                    prev = c;
                    p
                })
                .collect()
        };
        let tilted_m = marginals_from_joint(&probs_of(&tilt));
        let parity_m = marginals_from_joint(&probs_of(&parity));
        for v in 0..4 {
            assert!(
                (tilted_m[v] - base[v]).abs() > 0.05,
                "tilt must move marginal {v}: {} vs {}",
                tilted_m[v],
                base[v]
            );
            assert!(
                (parity_m[v] - base[v]).abs() < 0.02,
                "parity tilt must keep marginal {v}: {} vs {}",
                parity_m[v],
                base[v]
            );
        }
    }

    #[test]
    #[should_panic(expected = "limited to 14")]
    fn joint_tabulation_caps_at_14_vars() {
        joint_probs(&FactorGraph::new(15));
    }

    #[test]
    #[should_panic(expected = "32768 states")]
    fn joint_tabulation_caps_at_kstate_codes() {
        // 11 vars clears the variable cap but 3^11 > 2^15 codes
        joint_probs(&FactorGraph::new_k(11, 3));
    }

    fn potts_chain(k: usize, n: usize) -> FactorGraph {
        let mut g = FactorGraph::new_k(n, k);
        for v in 0..n - 1 {
            let beta = if v % 2 == 0 { 0.6 } else { -0.4 };
            g.add_factor(PairFactor::potts(v, v + 1, beta));
        }
        g
    }

    #[test]
    fn kstate_joint_and_marginals_match_direct_enumeration() {
        let g = potts_chain(3, 4);
        let probs = joint_probs(&g);
        assert_eq!(probs.len(), 81);
        assert!((probs.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        // spot-check one code against the unnormalized ratio to code 0
        let x0 = [0u8; 4];
        let x = [2u8, 1, 0, 2]; // code 2 + 1·3 + 0·9 + 2·27 = 59
        let want_ratio = (g.log_prob_unnorm(&x) - g.log_prob_unnorm(&x0)).exp();
        assert!((probs[59] / probs[0] - want_ratio).abs() < 1e-9);
        // flattened marginals agree with a direct sum over codes
        let m = marginals_from_joint_k(&probs, 4, 3);
        assert_eq!(m.len(), 8);
        let mut want = 0.0;
        let mut xs = [0u8; 4];
        for (code, &p) in probs.iter().enumerate() {
            decode_state(code, 3, &mut xs);
            if xs[1] == 2 {
                want += p;
            }
        }
        assert!((m[3] - want).abs() < 1e-12); // entry v=1, s=2
        // binary degeneration: marginals_from_joint_k == marginals_from_joint
        let g2 = workloads::ising_grid(2, 3, 0.3, 0.1);
        let p2 = joint_probs(&g2);
        assert_eq!(marginals_from_joint_k(&p2, 6, 2), marginals_from_joint(&p2));
    }

    #[test]
    fn conditioned_forward_matches_conditional_law() {
        let g = potts_chain(3, 4);
        let evidence = [(0usize, 2u8), (2usize, 1u8)];
        let mut fwd = ExactForward::conditioned(&g, &evidence, 11);
        assert_eq!(fwd.k(), 3);
        // exact conditional of x_1 by direct enumeration
        let probs = joint_probs(&g);
        let mut cond = [0.0f64; 3];
        let mut z = 0.0;
        let mut xs = [0u8; 4];
        for (code, &p) in probs.iter().enumerate() {
            decode_state(code, 3, &mut xs);
            if xs[0] == 2 && xs[2] == 1 {
                z += p;
                cond[xs[1] as usize] += p;
            }
        }
        for c in &mut cond {
            *c /= z;
        }
        let n = 60_000usize;
        let mut hist = [0u64; 3];
        for _ in 0..n {
            fwd.sweep();
            fwd.visit_states(&mut |x| {
                assert_eq!(x[0], 2, "evidence site 0 moved");
                assert_eq!(x[2], 1, "evidence site 2 moved");
                hist[x[1] as usize] += 1;
            });
        }
        for s in 0..3 {
            let emp = hist[s] as f64 / n as f64;
            let se = (cond[s] * (1.0 - cond[s]) / n as f64).sqrt();
            assert!(
                (emp - cond[s]).abs() < 5.0 * se + 1e-9,
                "s={s}: {emp} vs {}",
                cond[s]
            );
        }
    }

    #[test]
    #[should_panic(expected = "zero probability")]
    fn impossible_evidence_is_rejected() {
        // a conflicting double-clamp of the same site has zero mass
        let g = potts_chain(3, 3);
        ExactForward::conditioned(&g, &[(0, 1), (0, 2)], 1);
    }
}
