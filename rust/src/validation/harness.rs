//! The exactness gates: drive any [`SamplingPath`] long enough, compare
//! its empirical distribution against exact inference, and pass or fail
//! deterministic thresholds.
//!
//! ## Gate design (how the thresholds were precomputed)
//!
//! Every run is seed-fixed, so a gate is a *deterministic* property of
//! the build — there are no CI flakes, only passes and regressions. The
//! thresholds come from iid large-sample theory made applicable by
//! **thinning**: the harness observes states only every `tau` sweeps,
//! where `tau` is the scenario's documented autocorrelation-time bound,
//! so consecutive observations are approximately independent and the
//! classical test distributions hold. On top of that every threshold is
//! multiplied by a `safety` factor (default 1.5) absorbing residual
//! autocorrelation and approximation error in the quantile functions.
//! Three gates run per path × scenario:
//!
//! 1. **Marginal z-gate** — per variable,
//!    `z_v = |p̂_v − p_v| / √(p_v(1−p_v)/N)` must stay below the
//!    two-sided normal critical value at level `alpha/(n+2)` (Bonferroni
//!    across the n marginal tests plus the two joint tests) times
//!    `safety`. This is the only gate serving paths support (the
//!    coordinator exposes pooled marginals, not states); there the
//!    effective sample count divides by `tau` instead of thinning.
//! 2. **Total-variation gate** — `TV(p̂, p)` over the full 2ⁿ-state joint
//!    must stay below `E[TV] + dev`, where `E[TV] ≤ ½Σ_s √(p_s(1−p_s)/N)`
//!    (Jensen, conservative by the missing √(2/π) ≈ 0.8 factor) and
//!    `dev = √(ln(1/α)/2N)` is the McDiarmid bounded-difference tail
//!    (each observation moves TV by at most 1/N).
//! 3. **Chi-square gate** — Pearson's X² on the joint histogram with
//!    small-expected buckets pooled ([`crate::validation::pooled_chi2`],
//!    floor 8), against the Wilson–Hilferty quantile at `1 − alpha/(n+2)`.
//!
//! A correct sampler sits ~10+ standard errors inside these thresholds at
//! the committed seeds; the classic bug classes (wrong cached
//! conditional, stale table after churn, biased tail-lane draw, swapped
//! endpoint) land far outside them — see the power tests in
//! `tests/statistical_validation.rs`, which verify that deliberately
//! perturbed distributions *fail*.

use crate::graph::FactorGraph;

use super::forward::{joint_probs, marginals_from_joint, MAX_JOINT_VARS};
use super::path::SamplingPath;
use super::stats::{chi2_quantile, pooled_chi2, total_variation, z_critical};

/// Budget and threshold parameters of one validation run.
#[derive(Clone, Copy, Debug)]
pub struct GateConfig {
    /// Sweeps discarded before any observation (all chains).
    pub burn_in: usize,
    /// Target observation count pooled over chains (the harness rounds
    /// sweeps up so every chain is observed equally often).
    pub samples: usize,
    /// Thinning stride in sweeps — the scenario's documented integrated
    /// autocorrelation-time bound. States are observed every `tau`-th
    /// sweep; marginal-only paths observe every sweep and divide the
    /// sample count by `tau` instead.
    pub tau: usize,
    /// Overall test level, Bonferroni-split across the `n + 2` tests.
    pub alpha: f64,
    /// Multiplier on every threshold (residual-autocorrelation slack).
    pub safety: f64,
}

impl Default for GateConfig {
    fn default() -> Self {
        Self {
            burn_in: 1500,
            samples: 8192,
            tau: 6,
            alpha: 1e-9,
            safety: 1.5,
        }
    }
}

impl GateConfig {
    /// Default gates with an explicit sample budget and thinning stride.
    pub fn with_budget(samples: usize, tau: usize) -> Self {
        Self {
            samples,
            tau: tau.max(1),
            ..Self::default()
        }
    }
}

/// One gate's observed statistic against its precomputed threshold.
#[derive(Clone, Copy, Debug)]
pub struct Gate {
    /// Observed test statistic.
    pub stat: f64,
    /// Deterministic pass/fail threshold.
    pub threshold: f64,
}

impl Gate {
    /// Whether the statistic clears the threshold.
    pub fn passed(&self) -> bool {
        self.stat <= self.threshold
    }
}

/// Outcome of one path × scenario validation run.
#[derive(Clone, Debug)]
pub struct ValidationReport {
    /// Path label ([`SamplingPath::name`]).
    pub path: String,
    /// Scenario (or ad-hoc context) label supplied by the caller.
    pub scenario: String,
    /// Observations actually pooled (chains × observed sweeps); for
    /// marginal-only paths, the tau-discounted effective count.
    pub samples: u64,
    /// Worst marginal z-statistic vs its critical value.
    pub max_z: Gate,
    /// Variable attaining `max_z`.
    pub worst_var: usize,
    /// Joint total-variation gate (`None` for marginal-only paths).
    pub tv: Option<Gate>,
    /// Joint chi-square gate and its degrees of freedom (`None` for
    /// marginal-only paths or untestably concentrated joints).
    pub chi2: Option<(Gate, usize)>,
    /// Human-readable description of every failed gate (empty = pass).
    pub failures: Vec<String>,
}

impl ValidationReport {
    /// Whether every applicable gate passed.
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }

    /// Panic with full context if any gate failed (test-suite hook).
    pub fn assert_passed(&self) {
        assert!(
            self.passed(),
            "{} on {} failed {} gate(s) at {} samples:\n  {}",
            self.path,
            self.scenario,
            self.failures.len(),
            self.samples,
            self.failures.join("\n  ")
        );
    }

    /// One summary line for bench output.
    pub fn summary(&self) -> String {
        format!(
            "{} on {}: max_z {:.2}/{:.2}{}{} [{}]",
            self.path,
            self.scenario,
            self.max_z.stat,
            self.max_z.threshold,
            self.tv
                .as_ref()
                .map(|g| format!(" tv {:.4}/{:.4}", g.stat, g.threshold))
                .unwrap_or_default(),
            self.chi2
                .as_ref()
                .map(|(g, df)| format!(" chi2 {:.1}/{:.1} (df {df})", g.stat, g.threshold))
                .unwrap_or_default(),
            if self.passed() { "PASS" } else { "FAIL" }
        )
    }
}

/// Drive `path` against the exact joint of `target` and gate the result
/// (see module docs for the statistics). `target` must be the graph the
/// path is *currently* sampling — for churn scenarios, the materialized
/// final graph.
pub fn validate(
    path: &mut dyn SamplingPath,
    target: &FactorGraph,
    scenario: &str,
    cfg: &GateConfig,
) -> ValidationReport {
    let n = target.num_vars();
    assert!(n >= 1 && n <= MAX_JOINT_VARS, "validate needs 1..={MAX_JOINT_VARS} vars");
    assert_eq!(path.num_vars(), n, "path and target graph disagree on size");
    let probs = joint_probs(target);
    let exact_marg = marginals_from_joint(&probs);
    let tau = cfg.tau.max(1);

    path.advance(cfg.burn_in);

    let chains = path.chains().max(1);
    let obs_sweeps = cfg.samples.div_ceil(chains);
    let observable = path.visit_states(&mut |_| {});

    let tests = (n + 2) as f64;
    let a = cfg.alpha / tests;
    let z_crit = z_critical(a) * cfg.safety;
    let mut failures = Vec::new();

    let (emp_marg, total, hist) = if observable {
        // state mode: thin by tau, histogram the joint
        let mut hist = vec![0u64; 1 << n];
        let mut total = 0u64;
        for _ in 0..obs_sweeps {
            path.advance(tau);
            path.visit_states(&mut |x| {
                let mut code = 0usize;
                for (v, &b) in x.iter().enumerate() {
                    code |= ((b & 1) as usize) << v;
                }
                hist[code] += 1;
                total += 1;
            });
        }
        let emp = marginals_from_joint(
            &hist
                .iter()
                .map(|&c| c as f64 / total as f64)
                .collect::<Vec<_>>(),
        );
        (emp, total, Some(hist))
    } else {
        // marginal mode: observe every sweep, discount the count by tau
        let emp = path.estimate_marginals(obs_sweeps * tau);
        (emp, (obs_sweeps * chains) as u64, None)
    };

    // 1. marginal z-gate
    let nf = total as f64;
    let mut max_z = 0.0f64;
    let mut worst_var = 0usize;
    for (v, (&p_hat, &p)) in emp_marg.iter().zip(&exact_marg).enumerate() {
        let se = (p * (1.0 - p) / nf).sqrt();
        let z = if se > 0.0 { (p_hat - p).abs() / se } else { 0.0 };
        if z > max_z {
            max_z = z;
            worst_var = v;
        }
    }
    let z_gate = Gate {
        stat: max_z,
        threshold: z_crit,
    };
    if !z_gate.passed() {
        failures.push(format!(
            "marginal z-gate: var {worst_var} z={max_z:.2} > {z_crit:.2} \
             (empirical {:.4} vs exact {:.4}, N={total})",
            emp_marg[worst_var], exact_marg[worst_var]
        ));
    }

    // 2 + 3. joint gates (state mode only)
    let (tv_gate, chi2_gate) = match &hist {
        Some(hist) => {
            let emp_joint: Vec<f64> = hist.iter().map(|&c| c as f64 / nf).collect();
            let tv = total_variation(&emp_joint, &probs);
            let mean_bound: f64 = 0.5
                * probs
                    .iter()
                    .map(|&p| (p * (1.0 - p) / nf).sqrt())
                    .sum::<f64>();
            let dev = ((1.0 / a).ln() / (2.0 * nf)).sqrt();
            let tv_gate = Gate {
                stat: tv,
                threshold: cfg.safety * (mean_bound + dev),
            };
            if !tv_gate.passed() {
                failures.push(format!(
                    "joint TV gate: {tv:.4} > {:.4} (N={total})",
                    tv_gate.threshold
                ));
            }
            let chi2_gate = pooled_chi2(hist, &probs, nf, 8.0).map(|(stat, df)| {
                let gate = Gate {
                    stat,
                    threshold: chi2_quantile(df, 1.0 - a) * cfg.safety,
                };
                (gate, df)
            });
            if let Some((g, df)) = &chi2_gate {
                if !g.passed() {
                    failures.push(format!(
                        "joint chi-square gate: X²={:.1} > {:.1} (df {df}, N={total})",
                        g.stat, g.threshold
                    ));
                }
            }
            (Some(tv_gate), chi2_gate)
        }
        None => (None, None),
    };

    ValidationReport {
        path: path.name(),
        scenario: scenario.to_string(),
        samples: total,
        max_z: z_gate,
        worst_var,
        tv: tv_gate,
        chi2: chi2_gate,
        failures,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::validation::ExactForward;
    use crate::workloads;

    #[test]
    fn iid_forward_draws_pass_every_gate() {
        // the calibration property: gates must pass on ground-truth draws
        let g = workloads::ising_grid(2, 2, 0.3, 0.1);
        let mut fwd = ExactForward::new(&g, 42);
        let cfg = GateConfig { burn_in: 0, samples: 20_000, tau: 1, ..GateConfig::default() };
        let r = validate(&mut fwd, &g, "grid2x2", &cfg);
        r.assert_passed();
        assert!(r.tv.is_some() && r.chi2.is_some(), "joint gates must run");
        assert_eq!(r.samples, 20_000);
    }

    #[test]
    fn tilted_forward_draws_fail_the_marginal_gate() {
        // the power property: a marginal-shifting bias must be caught
        let g = workloads::ising_grid(2, 2, 0.3, 0.1);
        let mut fwd = ExactForward::tilted(&g, 42, 0.5);
        let cfg = GateConfig { burn_in: 0, samples: 20_000, tau: 1, ..GateConfig::default() };
        let r = validate(&mut fwd, &g, "grid2x2-tilted", &cfg);
        assert!(!r.passed(), "biased sampler slipped through: {}", r.summary());
        assert!(!r.max_z.passed(), "the z-gate specifically must fire");
    }

    #[test]
    fn report_summary_formats() {
        let g = workloads::ising_grid(2, 2, 0.2, 0.0);
        let mut fwd = ExactForward::new(&g, 7);
        let cfg = GateConfig::with_budget(4096, 1);
        let r = validate(&mut fwd, &g, "fmt", &cfg);
        let s = r.summary();
        assert!(s.contains("exact-forward"));
        assert!(s.contains("PASS") || s.contains("FAIL"));
    }

    #[test]
    #[should_panic(expected = "disagree on size")]
    fn mismatched_target_is_rejected() {
        let g = workloads::ising_grid(2, 2, 0.2, 0.0);
        let other = workloads::ising_grid(2, 3, 0.2, 0.0);
        let mut fwd = ExactForward::new(&g, 7);
        validate(&mut fwd, &other, "mismatch", &GateConfig::default());
    }
}
