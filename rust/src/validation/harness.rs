//! The exactness gates: drive any [`SamplingPath`] long enough, compare
//! its empirical distribution against exact inference, and pass or fail
//! deterministic thresholds.
//!
//! ## Gate design (how the thresholds were precomputed)
//!
//! Every run is seed-fixed, so a gate is a *deterministic* property of
//! the build — there are no CI flakes, only passes and regressions. The
//! thresholds come from iid large-sample theory made applicable by
//! **thinning**: the harness observes states only every `tau` sweeps,
//! where `tau` is the scenario's documented autocorrelation-time bound,
//! so consecutive observations are approximately independent and the
//! classical test distributions hold. On top of that every threshold is
//! multiplied by a `safety` factor (default 1.5) absorbing residual
//! autocorrelation and approximation error in the quantile functions.
//! Three gates run per path × scenario:
//!
//! 1. **Marginal z-gate** — per variable,
//!    `z_v = |p̂_v − p_v| / √(p_v(1−p_v)/N)` must stay below the
//!    two-sided normal critical value at level `alpha/(n+2)` (Bonferroni
//!    across the n marginal tests plus the two joint tests) times
//!    `safety`. This is the only gate serving paths support (the
//!    coordinator exposes pooled marginals, not states); there the
//!    effective sample count divides by `tau` instead of thinning.
//! 2. **Total-variation gate** — `TV(p̂, p)` over the full 2ⁿ-state joint
//!    must stay below `E[TV] + dev`, where `E[TV] ≤ ½Σ_s √(p_s(1−p_s)/N)`
//!    (Jensen, conservative by the missing √(2/π) ≈ 0.8 factor) and
//!    `dev = √(ln(1/α)/2N)` is the McDiarmid bounded-difference tail
//!    (each observation moves TV by at most 1/N).
//! 3. **Chi-square gate** — Pearson's X² on the joint histogram with
//!    small-expected buckets pooled ([`crate::validation::pooled_chi2`],
//!    floor 8), against the Wilson–Hilferty quantile at `1 − alpha/(n+2)`.
//!
//! A correct sampler sits ~10+ standard errors inside these thresholds at
//! the committed seeds; the classic bug classes (wrong cached
//! conditional, stale table after churn, biased tail-lane draw, swapped
//! endpoint) land far outside them — see the power tests in
//! `tests/statistical_validation.rs`, which verify that deliberately
//! perturbed distributions *fail*.
//!
//! ## K-state joints and evidence
//!
//! Every gate generalizes to K-state models: joints tabulate over
//! base-`k` codes (capped at [`MAX_JOINT_STATES`]), and the z-gate runs
//! per flattened `(site, state)` marginal entry, `n·(k−1)` tests
//! Bonferroni-split alongside the two joint tests. Clamped-evidence runs
//! go through [`validate_conditioned`]: the reference joint is the
//! conditional law over the *free* sites, observed states must hold the
//! evidence exactly (a dedicated gate counts violations), and in
//! marginal mode the deterministic evidence entries must match to within
//! rounding.

use crate::graph::FactorGraph;
use crate::inference::exact::log_sum_exp;

use super::forward::{marginals_from_joint_k, MAX_JOINT_STATES, MAX_JOINT_VARS};
use super::path::SamplingPath;
use super::stats::{chi2_quantile, pooled_chi2, total_variation, z_critical};

/// Budget and threshold parameters of one validation run.
#[derive(Clone, Copy, Debug)]
pub struct GateConfig {
    /// Sweeps discarded before any observation (all chains).
    pub burn_in: usize,
    /// Target observation count pooled over chains (the harness rounds
    /// sweeps up so every chain is observed equally often).
    pub samples: usize,
    /// Thinning stride in sweeps — the scenario's documented integrated
    /// autocorrelation-time bound. States are observed every `tau`-th
    /// sweep; marginal-only paths observe every sweep and divide the
    /// sample count by `tau` instead.
    pub tau: usize,
    /// Overall test level, Bonferroni-split across the `n + 2` tests.
    pub alpha: f64,
    /// Multiplier on every threshold (residual-autocorrelation slack).
    pub safety: f64,
}

impl Default for GateConfig {
    fn default() -> Self {
        Self {
            burn_in: 1500,
            samples: 8192,
            tau: 6,
            alpha: 1e-9,
            safety: 1.5,
        }
    }
}

impl GateConfig {
    /// Default gates with an explicit sample budget and thinning stride.
    pub fn with_budget(samples: usize, tau: usize) -> Self {
        Self {
            samples,
            tau: tau.max(1),
            ..Self::default()
        }
    }
}

/// One gate's observed statistic against its precomputed threshold.
#[derive(Clone, Copy, Debug)]
pub struct Gate {
    /// Observed test statistic.
    pub stat: f64,
    /// Deterministic pass/fail threshold.
    pub threshold: f64,
}

impl Gate {
    /// Whether the statistic clears the threshold.
    pub fn passed(&self) -> bool {
        self.stat <= self.threshold
    }
}

/// Outcome of one path × scenario validation run.
#[derive(Clone, Debug)]
pub struct ValidationReport {
    /// Path label ([`SamplingPath::name`]).
    pub path: String,
    /// Scenario (or ad-hoc context) label supplied by the caller.
    pub scenario: String,
    /// Observations actually pooled (chains × observed sweeps); for
    /// marginal-only paths, the tau-discounted effective count.
    pub samples: u64,
    /// Worst marginal z-statistic vs its critical value.
    pub max_z: Gate,
    /// Variable attaining `max_z`.
    pub worst_var: usize,
    /// Joint total-variation gate (`None` for marginal-only paths).
    pub tv: Option<Gate>,
    /// Joint chi-square gate and its degrees of freedom (`None` for
    /// marginal-only paths or untestably concentrated joints).
    pub chi2: Option<(Gate, usize)>,
    /// Human-readable description of every failed gate (empty = pass).
    pub failures: Vec<String>,
}

impl ValidationReport {
    /// Whether every applicable gate passed.
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }

    /// Panic with full context if any gate failed (test-suite hook).
    pub fn assert_passed(&self) {
        assert!(
            self.passed(),
            "{} on {} failed {} gate(s) at {} samples:\n  {}",
            self.path,
            self.scenario,
            self.failures.len(),
            self.samples,
            self.failures.join("\n  ")
        );
    }

    /// One summary line for bench output.
    pub fn summary(&self) -> String {
        format!(
            "{} on {}: max_z {:.2}/{:.2}{}{} [{}]",
            self.path,
            self.scenario,
            self.max_z.stat,
            self.max_z.threshold,
            self.tv
                .as_ref()
                .map(|g| format!(" tv {:.4}/{:.4}", g.stat, g.threshold))
                .unwrap_or_default(),
            self.chi2
                .as_ref()
                .map(|(g, df)| format!(" chi2 {:.1}/{:.1} (df {df})", g.stat, g.threshold))
                .unwrap_or_default(),
            if self.passed() { "PASS" } else { "FAIL" }
        )
    }
}

/// Drive `path` against the exact joint of `target` and gate the result
/// (see module docs for the statistics). `target` must be the graph the
/// path is *currently* sampling — for churn scenarios, the materialized
/// final graph.
pub fn validate(
    path: &mut dyn SamplingPath,
    target: &FactorGraph,
    scenario: &str,
    cfg: &GateConfig,
) -> ValidationReport {
    validate_conditioned(path, target, &[], scenario, cfg)
}

/// Conditioned joint over the free variables' base-`k` codes (digit `i`
/// of a code is `x_{free[i]}`), with the evidence sites held fixed.
fn conditioned_joint(g: &FactorGraph, evidence: &[(usize, u8)], free: &[usize]) -> Vec<f64> {
    let k = g.k();
    let states = k
        .checked_pow(free.len() as u32)
        .filter(|&s| s <= MAX_JOINT_STATES)
        .unwrap_or_else(|| {
            panic!(
                "conditioned joint limited to {MAX_JOINT_STATES} states, got {k}^{}",
                free.len()
            )
        });
    let mut x = vec![0u8; g.num_vars()];
    for &(v, s) in evidence {
        x[v] = s;
    }
    let mut lps = Vec::with_capacity(states);
    for code in 0..states {
        let mut c = code;
        for &v in free {
            x[v] = (c % k) as u8;
            c /= k;
        }
        lps.push(g.log_prob_unnorm(&x));
    }
    let lz = log_sum_exp(&lps);
    lps.iter().map(|lp| (lp - lz).exp()).collect()
}

/// [`validate`] against the *conditional* joint given `evidence`
/// `(site, state)` pairs — the ground truth check for clamped tenants.
/// The path must already hold the same evidence (e.g. via
/// [`SamplingPath::clamp`]): any observed state off the evidence fails a
/// dedicated gate. The joint gates run over the free variables' base-`k`
/// codes; in marginal mode the comparison spans the full flattened
/// marginal vector, with the deterministic evidence entries required to
/// match exactly.
pub fn validate_conditioned(
    path: &mut dyn SamplingPath,
    target: &FactorGraph,
    evidence: &[(usize, u8)],
    scenario: &str,
    cfg: &GateConfig,
) -> ValidationReport {
    let (n, k) = (target.num_vars(), target.k());
    assert!(n >= 1 && n <= MAX_JOINT_VARS, "validate needs 1..={MAX_JOINT_VARS} vars");
    assert_eq!(path.num_vars(), n, "path and target graph disagree on size");
    assert_eq!(path.k(), k, "path and target graph disagree on cardinality");
    let mut clamp_state: Vec<Option<u8>> = vec![None; n];
    for &(v, s) in evidence {
        assert!(v < n && (s as usize) < k, "evidence ({v}, {s}) out of range");
        assert!(
            clamp_state[v].replace(s).is_none(),
            "duplicate evidence for site {v}"
        );
    }
    let free: Vec<usize> = (0..n).filter(|&v| clamp_state[v].is_none()).collect();
    assert!(!free.is_empty(), "evidence must leave at least one free variable");
    let probs = conditioned_joint(target, evidence, &free);
    let exact_free = marginals_from_joint_k(&probs, free.len(), k);
    let tau = cfg.tau.max(1);

    path.advance(cfg.burn_in);

    let chains = path.chains().max(1);
    let obs_sweeps = cfg.samples.div_ceil(chains);
    let observable = path.visit_states(&mut |_| {});

    let m_tests = if observable { free.len() } else { n } * (k - 1);
    let tests = (m_tests + 2) as f64;
    let a = cfg.alpha / tests;
    let z_crit = z_critical(a) * cfg.safety;
    let mut failures = Vec::new();

    let (emp_marg, exact_marg, total, hist, violations) = if observable {
        // state mode: thin by tau, histogram the free variables' joint
        let mut hist = vec![0u64; probs.len()];
        let mut total = 0u64;
        let mut violations = 0u64;
        for _ in 0..obs_sweeps {
            path.advance(tau);
            path.visit_states(&mut |x| {
                let mut code = 0usize;
                let mut mul = 1usize;
                for &v in &free {
                    code += (x[v] as usize).min(k - 1) * mul;
                    mul *= k;
                }
                hist[code] += 1;
                total += 1;
                for (v, cs) in clamp_state.iter().enumerate() {
                    if cs.is_some_and(|s| x[v] != s) {
                        violations += 1;
                    }
                }
            });
        }
        let emp = marginals_from_joint_k(
            &hist
                .iter()
                .map(|&c| c as f64 / total as f64)
                .collect::<Vec<_>>(),
            free.len(),
            k,
        );
        (emp, exact_free, total, Some(hist), violations)
    } else {
        // marginal mode: observe every sweep, discount the count by tau;
        // the serving vector spans every site, evidence entries included
        let mut exact_full = vec![0.0; n * (k - 1)];
        for (fi, &v) in free.iter().enumerate() {
            exact_full[v * (k - 1)..(v + 1) * (k - 1)]
                .copy_from_slice(&exact_free[fi * (k - 1)..(fi + 1) * (k - 1)]);
        }
        for (v, cs) in clamp_state.iter().enumerate() {
            if let Some(s) = cs {
                if *s > 0 {
                    exact_full[v * (k - 1) + (*s as usize - 1)] = 1.0;
                }
            }
        }
        let emp = path.estimate_marginals(obs_sweeps * tau);
        (emp, exact_full, (obs_sweeps * chains) as u64, None, 0)
    };
    if violations > 0 {
        failures.push(format!(
            "evidence gate: {violations} observed states moved a clamped site"
        ));
    }

    // 1. marginal z-gate (free entries in state mode, every site's
    //    entries in marginal mode; deterministic evidence entries must
    //    match exactly — their binomial se is 0)
    assert_eq!(
        emp_marg.len(),
        exact_marg.len(),
        "path marginal vector has the wrong arity for k={k}"
    );
    let nf = total as f64;
    let mut max_z = 0.0f64;
    let mut worst_entry = 0usize;
    for (e, (&p_hat, &p)) in emp_marg.iter().zip(&exact_marg).enumerate() {
        let se = (p * (1.0 - p) / nf).sqrt();
        let z = if se > 0.0 {
            (p_hat - p).abs() / se
        } else if (p_hat - p).abs() > 1e-9 {
            f64::INFINITY // a deterministic (evidence) entry drifted
        } else {
            0.0
        };
        if z > max_z {
            max_z = z;
            worst_entry = e;
        }
    }
    // map the worst flattened entry back to its variable for the report
    let worst_var = if observable {
        free[worst_entry / (k - 1)]
    } else {
        worst_entry / (k - 1)
    };
    let z_gate = Gate {
        stat: max_z,
        threshold: z_crit,
    };
    if !z_gate.passed() {
        failures.push(format!(
            "marginal z-gate: var {worst_var} z={max_z:.2} > {z_crit:.2} \
             (empirical {:.4} vs exact {:.4}, N={total})",
            emp_marg[worst_entry], exact_marg[worst_entry]
        ));
    }

    // 2 + 3. joint gates (state mode only)
    let (tv_gate, chi2_gate) = match &hist {
        Some(hist) => {
            let emp_joint: Vec<f64> = hist.iter().map(|&c| c as f64 / nf).collect();
            let tv = total_variation(&emp_joint, &probs);
            let mean_bound: f64 = 0.5
                * probs
                    .iter()
                    .map(|&p| (p * (1.0 - p) / nf).sqrt())
                    .sum::<f64>();
            let dev = ((1.0 / a).ln() / (2.0 * nf)).sqrt();
            let tv_gate = Gate {
                stat: tv,
                threshold: cfg.safety * (mean_bound + dev),
            };
            if !tv_gate.passed() {
                failures.push(format!(
                    "joint TV gate: {tv:.4} > {:.4} (N={total})",
                    tv_gate.threshold
                ));
            }
            let chi2_gate = pooled_chi2(hist, &probs, nf, 8.0).map(|(stat, df)| {
                let gate = Gate {
                    stat,
                    threshold: chi2_quantile(df, 1.0 - a) * cfg.safety,
                };
                (gate, df)
            });
            if let Some((g, df)) = &chi2_gate {
                if !g.passed() {
                    failures.push(format!(
                        "joint chi-square gate: X²={:.1} > {:.1} (df {df}, N={total})",
                        g.stat, g.threshold
                    ));
                }
            }
            (Some(tv_gate), chi2_gate)
        }
        None => (None, None),
    };

    ValidationReport {
        path: path.name(),
        scenario: scenario.to_string(),
        samples: total,
        max_z: z_gate,
        worst_var,
        tv: tv_gate,
        chi2: chi2_gate,
        failures,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::validation::ExactForward;
    use crate::workloads;

    #[test]
    fn iid_forward_draws_pass_every_gate() {
        // the calibration property: gates must pass on ground-truth draws
        let g = workloads::ising_grid(2, 2, 0.3, 0.1);
        let mut fwd = ExactForward::new(&g, 42);
        let cfg = GateConfig { burn_in: 0, samples: 20_000, tau: 1, ..GateConfig::default() };
        let r = validate(&mut fwd, &g, "grid2x2", &cfg);
        r.assert_passed();
        assert!(r.tv.is_some() && r.chi2.is_some(), "joint gates must run");
        assert_eq!(r.samples, 20_000);
    }

    #[test]
    fn tilted_forward_draws_fail_the_marginal_gate() {
        // the power property: a marginal-shifting bias must be caught
        let g = workloads::ising_grid(2, 2, 0.3, 0.1);
        let mut fwd = ExactForward::tilted(&g, 42, 0.5);
        let cfg = GateConfig { burn_in: 0, samples: 20_000, tau: 1, ..GateConfig::default() };
        let r = validate(&mut fwd, &g, "grid2x2-tilted", &cfg);
        assert!(!r.passed(), "biased sampler slipped through: {}", r.summary());
        assert!(!r.max_z.passed(), "the z-gate specifically must fire");
    }

    #[test]
    fn report_summary_formats() {
        let g = workloads::ising_grid(2, 2, 0.2, 0.0);
        let mut fwd = ExactForward::new(&g, 7);
        let cfg = GateConfig::with_budget(4096, 1);
        let r = validate(&mut fwd, &g, "fmt", &cfg);
        let s = r.summary();
        assert!(s.contains("exact-forward"));
        assert!(s.contains("PASS") || s.contains("FAIL"));
    }

    #[test]
    fn kstate_and_conditioned_calibration_and_power() {
        use crate::graph::{FactorGraph, PairFactor};
        let mut g = FactorGraph::new_k(5, 3);
        for v in 0..4 {
            let beta = if v % 2 == 0 { 0.5 } else { -0.4 };
            g.add_factor(PairFactor::potts(v, v + 1, beta));
        }
        let cfg = GateConfig { burn_in: 0, samples: 20_000, tau: 1, ..GateConfig::default() };
        // calibration: iid K-state ground-truth draws pass every gate
        let mut fwd = ExactForward::new(&g, 42);
        let r = validate(&mut fwd, &g, "potts-chain5", &cfg);
        r.assert_passed();
        assert!(r.tv.is_some() && r.chi2.is_some(), "joint gates must run");
        // calibration under evidence: the conditional forward sampler
        // passes the conditioned gates
        let evidence = [(0usize, 2u8), (3usize, 0u8)];
        let mut cond = ExactForward::conditioned(&g, &evidence, 43);
        let r = validate_conditioned(&mut cond, &g, &evidence, "chain5-evidence", &cfg);
        r.assert_passed();
        // power: the unconditioned sampler must fail the conditioned
        // gates — and specifically trip the evidence gate
        let mut un = ExactForward::new(&g, 44);
        let r = validate_conditioned(&mut un, &g, &evidence, "chain5-evidence", &cfg);
        assert!(!r.passed(), "unconditioned draws slipped through");
        assert!(
            r.failures.iter().any(|f| f.contains("evidence gate")),
            "expected the evidence gate to fire: {:?}",
            r.failures
        );
    }

    #[test]
    #[should_panic(expected = "disagree on size")]
    fn mismatched_target_is_rejected() {
        let g = workloads::ising_grid(2, 2, 0.2, 0.0);
        let other = workloads::ising_grid(2, 3, 0.2, 0.0);
        let mut fwd = ExactForward::new(&g, 7);
        validate(&mut fwd, &other, "mismatch", &GateConfig::default());
    }
}
