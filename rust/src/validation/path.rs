//! [`SamplingPath`]: one uniform handle over every way this crate can
//! sample — the trait-unification half of the validation subsystem.
//!
//! The crate grew four execution layers with four shapes: the classical
//! [`Sampler`] baselines (single chain, caller-supplied RNG, borrowed
//! graph), the bit-packed [`LanePdSampler`] engine (64 chains per word,
//! internal `(sweep, site)` streams), the [`PdEnsemble`] monitor wrapper,
//! and the multi-tenant coordinator (chains behind a request queue that
//! only surfaces pooled marginals). A correctness harness that drove each
//! shape with bespoke code would itself be four times as likely to be
//! wrong, so every shape is adapted onto this one trait:
//!
//! * [`ClassicalPath`] — any `samplers::` baseline (sequential,
//!   chromatic, blocked, Swendsen–Wang, scalar primal–dual).
//! * [`LanePath`] — the lane engine under any [`KernelKind`] and any
//!   pool size, with churn support.
//! * [`EnsemblePath`] — [`PdEnsemble`] (what the coordinator hosts per
//!   tenant), with churn support.
//! * [`CoordinatorPath`] — a real sharded coordinator serving one
//!   tenant; states are unobservable through the serving API, so it
//!   reports `visit_states → false` and the harness falls back to the
//!   marginal gate via [`SamplingPath::estimate_marginals`].
//! * [`super::ExactForward`] — iid ground-truth draws (calibration).
//!
//! Churn semantics are shared with [`crate::coordinator::Tenant`]: the
//! live list indexed by [`ChurnOp::RemoveLive`] starts as the base
//! graph's factors in iteration order, and every `Add` appends. Scenario
//! materialization ([`crate::workloads::Scenario::final_graph`]) uses the
//! same convention, so a path and its reference graph never drift.

use std::sync::Arc;

use crate::coordinator::{Client, Coordinator, CoordinatorConfig, PdEnsemble, TenantConfig};
use crate::engine::{EngineConfig, KernelKind, LanePdSampler};
use crate::graph::{FactorGraph, FactorId};
use crate::rng::Pcg64;
use crate::samplers::Sampler;
use crate::util::ThreadPool;
use crate::workloads::{ChurnOp, ChurnTrace};

/// A uniform, dyn-safe handle over one sampling execution path: some
/// number of chains advanced in lockstep, with (where the path permits)
/// per-chain state observation and dynamic churn.
pub trait SamplingPath {
    /// Path label for reports (`"sequential-gibbs"`, `"lane-tiled-pool4"`…).
    fn name(&self) -> String;

    /// Number of primal variables of the current model.
    fn num_vars(&self) -> usize;

    /// States per variable of the current model (2 = binary). Marginal
    /// vectors are flattened v-major over the non-zero states,
    /// `num_vars · (k − 1)` entries.
    fn k(&self) -> usize {
        2
    }

    /// Clamp site `v` to evidence `state`: the site stops being
    /// resampled but keeps conditioning its neighbors. Returns `false`
    /// when the path cannot clamp (immutable baselines, frozen joints).
    fn clamp(&mut self, v: usize, state: u8) -> bool {
        let _ = (v, state);
        false
    }

    /// Independent chains advanced per [`SamplingPath::sweep`] call.
    fn chains(&self) -> usize {
        1
    }

    /// Advance every chain by one full sweep.
    fn sweep(&mut self);

    /// Advance every chain by `sweeps` sweeps (burn-in / thinning bulk
    /// hook; the coordinator adapter batches this into one request).
    fn advance(&mut self, sweeps: usize) {
        for _ in 0..sweeps {
            self.sweep();
        }
    }

    /// Visit every chain's current primal state. Returns `false` when the
    /// path cannot observe raw states (serving paths expose only pooled
    /// marginals) — callers must then fall back to
    /// [`SamplingPath::estimate_marginals`].
    fn visit_states(&self, f: &mut dyn FnMut(&[u8])) -> bool;

    /// Pooled flattened marginal estimate over `sweeps` further sweeps
    /// of all chains (every sweep observed, no thinning):
    /// `out[v·(k−1) + (s−1)] = P̂(x_v = s)` for `s ∈ 1..k`, the plain
    /// `P̂(x_v = 1)` vector on binary models. Default accumulates through
    /// [`SamplingPath::visit_states`]; marginal-only paths override it
    /// with their serving query.
    fn estimate_marginals(&mut self, sweeps: usize) -> Vec<f64> {
        let (n, k) = (self.num_vars(), self.k());
        let mut acc = vec![0.0f64; n * (k - 1)];
        let mut count = 0u64;
        for _ in 0..sweeps {
            self.sweep();
            self.visit_states(&mut |x| {
                count += 1;
                for (v, &s) in x.iter().enumerate() {
                    if s > 0 {
                        acc[v * (k - 1) + (s as usize - 1)] += 1.0;
                    }
                }
            });
        }
        let denom = count.max(1) as f64;
        for a in &mut acc {
            *a /= denom;
        }
        acc
    }

    /// Apply topology churn to the live model. Returns `false` when the
    /// path cannot mutate its model (baselines borrowing an immutable
    /// graph); the ops use the shared live-list convention (module docs).
    fn apply_churn(&mut self, ops: &[ChurnOp]) -> bool {
        let _ = ops;
        false
    }
}

/// What one churn op did to `(graph, live)` — callers mirror it into
/// their sampler state.
enum Applied {
    Added(FactorId),
    Removed(FactorId),
}

/// Apply one op via the one canonical live-list implementation
/// ([`ChurnTrace::apply`]), tagging which kind of mutation happened.
fn apply_op(graph: &mut FactorGraph, live: &mut Vec<FactorId>, op: &ChurnOp) -> Applied {
    let id = ChurnTrace::apply(graph, live, op);
    match op {
        ChurnOp::Add { .. } => Applied::Added(id),
        ChurnOp::RemoveLive { .. } => Applied::Removed(id),
    }
}

// -- classical baselines ----------------------------------------------------

/// One chain of any classical [`Sampler`] baseline plus its RNG stream.
pub struct ClassicalPath<'g> {
    sampler: Box<dyn Sampler + 'g>,
    rng: Pcg64,
}

impl<'g> ClassicalPath<'g> {
    /// Wrap a boxed baseline sampler with a seeded sweep stream.
    pub fn new(sampler: Box<dyn Sampler + 'g>, seed: u64) -> Self {
        Self {
            sampler,
            rng: Pcg64::seed(seed),
        }
    }
}

impl SamplingPath for ClassicalPath<'_> {
    fn name(&self) -> String {
        self.sampler.name().to_string()
    }

    fn num_vars(&self) -> usize {
        self.sampler.state().len()
    }

    fn k(&self) -> usize {
        self.sampler.k()
    }

    fn clamp(&mut self, v: usize, state: u8) -> bool {
        self.sampler.clamp(v, state)
    }

    fn sweep(&mut self) {
        self.sampler.sweep(&mut self.rng);
    }

    fn visit_states(&self, f: &mut dyn FnMut(&[u8])) -> bool {
        f(self.sampler.state());
        true
    }
}

// -- lane engine ------------------------------------------------------------

/// The lane-batched engine as a sampling path: any lane count, kernel,
/// and pool size; owns its graph so churn scenarios can mutate it.
pub struct LanePath {
    graph: FactorGraph,
    engine: LanePdSampler,
    live: Vec<FactorId>,
    label: String,
}

impl LanePath {
    /// Build over an owned copy of `graph` with explicit engine knobs.
    pub fn new(
        graph: FactorGraph,
        cfg: EngineConfig,
        pool: Option<Arc<ThreadPool>>,
    ) -> Self {
        let pool_size = pool.as_ref().map_or(0, |p| p.size());
        let mut engine = LanePdSampler::with_config(&graph, cfg);
        if let Some(pool) = pool {
            engine = engine.with_pool(pool);
        }
        let live = graph.factors().map(|(id, _)| id).collect();
        Self {
            label: format!("lane-{}-pool{pool_size}", cfg.kernel.name()),
            graph,
            engine,
            live,
        }
    }

    /// Convenience constructor with the default (tiled) kernel, no pool.
    pub fn with_lanes(graph: FactorGraph, lanes: usize, seed: u64) -> Self {
        Self::new(
            graph,
            EngineConfig {
                lanes,
                seed,
                kernel: KernelKind::default(),
                ..EngineConfig::default()
            },
            None,
        )
    }

    /// The engine under validation (e.g. to inspect its model's caches).
    pub fn engine(&self) -> &LanePdSampler {
        &self.engine
    }
}

impl SamplingPath for LanePath {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn num_vars(&self) -> usize {
        self.engine.num_vars()
    }

    fn k(&self) -> usize {
        self.engine.k()
    }

    fn clamp(&mut self, v: usize, state: u8) -> bool {
        self.engine.clamp(v, state).is_ok()
    }

    fn chains(&self) -> usize {
        self.engine.lanes()
    }

    fn sweep(&mut self) {
        self.engine.sweep();
    }

    fn visit_states(&self, f: &mut dyn FnMut(&[u8])) -> bool {
        for lane in 0..self.engine.lanes() {
            f(&self.engine.lane_state(lane));
        }
        true
    }

    fn apply_churn(&mut self, ops: &[ChurnOp]) -> bool {
        for op in ops {
            match apply_op(&mut self.graph, &mut self.live, op) {
                Applied::Added(id) => {
                    let f = self.graph.factor(id).expect("just added");
                    self.engine.add_factor(id, f);
                }
                Applied::Removed(id) => {
                    assert!(self.engine.remove_factor(id), "engine/live desync");
                }
            }
        }
        true
    }
}

// -- ensemble ---------------------------------------------------------------

/// [`PdEnsemble`] (the per-tenant execution object) as a sampling path.
pub struct EnsemblePath {
    graph: FactorGraph,
    ensemble: PdEnsemble,
    live: Vec<FactorId>,
}

impl EnsemblePath {
    /// Build over an owned copy of `graph` with overdispersed chain
    /// initialization (exactly what a coordinator tenant does).
    pub fn new(
        graph: FactorGraph,
        chains: usize,
        seed: u64,
        pool: Option<Arc<ThreadPool>>,
    ) -> Self {
        let mut ensemble = PdEnsemble::new(&graph, chains, seed);
        if let Some(pool) = pool {
            ensemble = ensemble.with_pool(pool);
        }
        ensemble.init_overdispersed();
        let live = graph.factors().map(|(id, _)| id).collect();
        Self {
            graph,
            ensemble,
            live,
        }
    }
}

impl SamplingPath for EnsemblePath {
    fn name(&self) -> String {
        "pd-ensemble".to_string()
    }

    fn num_vars(&self) -> usize {
        self.graph.num_vars()
    }

    fn k(&self) -> usize {
        self.graph.k()
    }

    fn clamp(&mut self, v: usize, state: u8) -> bool {
        self.ensemble.clamp(v, state).is_ok()
    }

    fn chains(&self) -> usize {
        self.ensemble.num_chains()
    }

    fn sweep(&mut self) {
        self.ensemble.run(1);
    }

    fn visit_states(&self, f: &mut dyn FnMut(&[u8])) -> bool {
        for c in 0..self.ensemble.num_chains() {
            f(&self.ensemble.chain_state(c));
        }
        true
    }

    fn apply_churn(&mut self, ops: &[ChurnOp]) -> bool {
        for op in ops {
            match apply_op(&mut self.graph, &mut self.live, op) {
                Applied::Added(id) => {
                    let f = self.graph.factor(id).expect("just added");
                    self.ensemble.add_factor(id, f);
                }
                Applied::Removed(id) => {
                    assert!(self.ensemble.remove_factor(id), "ensemble/live desync");
                }
            }
        }
        true
    }
}

// -- coordinator ------------------------------------------------------------

/// A real sharded coordinator serving one tenant, driven through the
/// public client API. Background sweeping is disabled (`quantum: 0`) so
/// the trajectory is a pure function of the request stream — the
/// deterministic-CI requirement. Raw states are not observable through
/// the serving API, so the harness uses the marginal gate.
pub struct CoordinatorPath {
    _coord: Coordinator,
    client: Client,
    tenant: u64,
    chains: usize,
    vars: usize,
    k: usize,
    label: String,
}

impl CoordinatorPath {
    /// Spawn a coordinator of `shards` shards (sharing one pool of
    /// `pool_threads` workers if nonzero) hosting `graph` as one tenant.
    pub fn new(
        graph: FactorGraph,
        shards: usize,
        pool_threads: usize,
        chains: usize,
        seed: u64,
    ) -> Self {
        let coord = Coordinator::spawn(CoordinatorConfig {
            shards,
            pool_threads,
            quantum: 0,
            ..Default::default()
        });
        let client = coord.client();
        let tenant = 1u64;
        let vars = graph.num_vars();
        let k = graph.k();
        client
            .create_tenant(
                tenant,
                graph,
                TenantConfig {
                    chains,
                    seed,
                    ..TenantConfig::default()
                },
            )
            .expect("create validation tenant");
        Self {
            label: format!("coordinator-s{shards}-pool{pool_threads}"),
            _coord: coord,
            client,
            tenant,
            chains,
            vars,
            k,
        }
    }
}

impl SamplingPath for CoordinatorPath {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn num_vars(&self) -> usize {
        self.vars
    }

    fn k(&self) -> usize {
        self.k
    }

    fn clamp(&mut self, v: usize, state: u8) -> bool {
        self.client.clamp(self.tenant, v, state).is_ok()
    }

    fn chains(&self) -> usize {
        self.chains
    }

    fn sweep(&mut self) {
        self.client.sweep(self.tenant, 1).expect("shard alive");
    }

    fn advance(&mut self, sweeps: usize) {
        if sweeps > 0 {
            self.client.sweep(self.tenant, sweeps).expect("shard alive");
        }
    }

    fn visit_states(&self, _f: &mut dyn FnMut(&[u8])) -> bool {
        false // the serving API pools over chains and sweeps
    }

    fn estimate_marginals(&mut self, sweeps: usize) -> Vec<f64> {
        self.client.reset_stats(self.tenant).expect("shard alive");
        self.advance(sweeps);
        self.client.marginals(self.tenant).expect("shard alive")
    }

    fn apply_churn(&mut self, ops: &[ChurnOp]) -> bool {
        self.client
            .apply(self.tenant, ops.to_vec())
            .expect("shard alive");
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::samplers::SequentialGibbs;
    use crate::workloads;

    #[test]
    fn classical_path_observes_its_single_chain() {
        let g = workloads::ising_grid(2, 2, 0.2, 0.0);
        let mut p = ClassicalPath::new(Box::new(SequentialGibbs::new(&g)), 3);
        assert_eq!(p.chains(), 1);
        assert_eq!(p.num_vars(), 4);
        p.advance(5);
        let mut visits = 0;
        assert!(p.visit_states(&mut |x| {
            visits += 1;
            assert_eq!(x.len(), 4);
        }));
        assert_eq!(visits, 1);
        assert!(!p.apply_churn(&[]), "borrowed graph cannot churn");
    }

    #[test]
    fn lane_path_visits_every_lane_and_churns() {
        let g = workloads::ising_grid(2, 2, 0.2, 0.1);
        let mut p = LanePath::with_lanes(g, 7, 5);
        p.advance(3);
        let mut visits = 0;
        assert!(p.visit_states(&mut |x| {
            visits += 1;
            assert_eq!(x.len(), 4);
        }));
        assert_eq!(visits, 7);
        // add a diagonal factor, then remove a base factor (live index 0)
        assert!(p.apply_churn(&[
            ChurnOp::Add { v1: 0, v2: 3, beta: 0.3 },
            ChurnOp::RemoveLive { index: 0 },
        ]));
        assert_eq!(p.engine().model().num_factors(), 4);
        p.advance(3);
    }

    #[test]
    fn ensemble_and_lane_agree_on_churned_topology() {
        // same ops through both adapters must leave the same live factors
        let g = workloads::ising_grid(2, 3, 0.25, 0.0);
        let ops = vec![
            ChurnOp::Add { v1: 0, v2: 4, beta: 0.2 },
            ChurnOp::RemoveLive { index: 2 },
            ChurnOp::Add { v1: 1, v2: 5, beta: -0.1 },
        ];
        let mut lane = LanePath::with_lanes(g.clone(), 4, 1);
        let mut ens = EnsemblePath::new(g, 4, 1, None);
        assert!(lane.apply_churn(&ops));
        assert!(ens.apply_churn(&ops));
        assert_eq!(
            lane.graph.factors().map(|(id, _)| id).collect::<Vec<_>>(),
            ens.graph.factors().map(|(id, _)| id).collect::<Vec<_>>()
        );
    }

    #[test]
    fn every_adapter_reports_cardinality_and_clamps_uniformly() {
        use crate::graph::PairFactor;
        let mut g = FactorGraph::new_k(4, 3);
        for v in 0..3 {
            g.add_factor(PairFactor::potts(v, v + 1, 0.4));
        }
        let mut lane = LanePath::with_lanes(g.clone(), 5, 3);
        let mut ens = EnsemblePath::new(g.clone(), 4, 3, None);
        let mut coord = CoordinatorPath::new(g.clone(), 1, 0, 4, 3);
        let mut classical =
            ClassicalPath::new(Box::new(crate::samplers::KStateGibbs::new(&g)), 3);
        let paths: [&mut dyn SamplingPath; 4] =
            [&mut lane, &mut ens, &mut coord, &mut classical];
        for p in paths {
            assert_eq!(p.k(), 3, "{}", p.name());
            assert!(p.clamp(1, 2), "{}", p.name());
            assert!(!p.clamp(1, 3), "{}: state ≥ k must be refused", p.name());
            p.advance(5);
            let m = p.estimate_marginals(30);
            assert_eq!(m.len(), 4 * 2, "{}", p.name());
            // entry v=1, s=2 of the flattened n·(k−1) layout
            assert_eq!(m[3], 1.0, "{}: clamped entry pins", p.name());
        }
    }

    #[test]
    fn coordinator_path_serves_marginals_only() {
        let g = workloads::ising_grid(2, 2, 0.3, 0.2);
        let mut p = CoordinatorPath::new(g, 2, 0, 4, 11);
        assert_eq!(p.num_vars(), 4);
        assert_eq!(p.chains(), 4);
        assert!(!p.visit_states(&mut |_| {}), "states must be unobservable");
        p.advance(50);
        let m = p.estimate_marginals(200);
        assert_eq!(m.len(), 4);
        assert!(m.iter().all(|x| (0.0..=1.0).contains(x)));
        assert!(p.apply_churn(&[ChurnOp::Add { v1: 0, v2: 3, beta: 0.2 }]));
    }
}
