//! SIMD-tiled lane kernels: the innermost bodies of the lane sweep.
//!
//! The lane engine ([`super::LanePdSampler`]) processes chains in packed
//! 64-lane words; everything it does per `(site, word)` decomposes into
//! five primitive operations, collected here behind the [`LaneKernel`]
//! trait:
//!
//! * [`LaneKernel::accumulate`] — fold one packed θ word into 64 per-lane
//!   log-odds accumulators (`acc[l] += bit·β`),
//! * [`LaneKernel::gather`] — scatter one packed θ word into 64 per-lane
//!   conditional-table pattern indices,
//! * [`LaneKernel::draw_table_word`] — assemble an x draw word from the
//!   model's cached Bernoulli acceptance parts,
//! * [`LaneKernel::draw_logodds_word`] — assemble an x draw word from
//!   accumulated per-lane log-odds (the high-degree fallback),
//! * [`LaneKernel::draw_theta_word`] — assemble a θ draw word from a
//!   slot's four-sigmoid table broadcast over the endpoint bits.
//!
//! Three interchangeable implementations exist, selected at runtime via
//! [`KernelKind`] (surfaced through [`super::EngineConfig`] and the bench
//! CLI's `--kernel` flag):
//!
//! * [`ScalarKernel`] — straight per-lane loops; the readable reference,
//!   byte-for-byte the pre-tiling hot path.
//! * [`TiledKernel`] — explicit [`TILE`]-wide (8-lane) tiles over
//!   64-byte-aligned buffers ([`F64Lanes`] / [`U8Lanes`]), with the
//!   uniform draws refilled through [`Pcg64::fill_f64`]'s jump-ahead
//!   chains so the LCG's serial dependency no longer gates the draw
//!   loop. Stable Rust; the tile bodies are fixed-size loops the
//!   backend lowers to vector instructions.
//! * `SimdKernel` (feature `nightly-simd`) — the same tile schedule
//!   written against `core::simd` (`f64x8` + mask selects) for toolchains
//!   that have `portable_simd`.
//!
//! **Determinism contract:** all kernels produce bit-identical draw words
//! from identical inputs and RNG state. Per lane, the accumulate order
//! over incidence entries, the acceptance-part arithmetic, and the
//! uniform consumed are exactly those of [`ScalarKernel`]; tiles only
//! change *which lanes compute concurrently*, never what any lane
//! computes. `tests/kernel_equivalence.rs` asserts this for whole
//! trajectories across lane counts, pool sizes, and churn.

use crate::rng::{bernoulli_from_parts, bernoulli_sigmoid, bernoulli_sigmoid_parts};
use crate::rng::{Pcg64, RngCore};

/// Lane-tile width: 8 × f64 = one 64-byte cache line
/// ([`crate::util::aligned::F64S_PER_CACHE_LINE`], the single source of
/// this constant), one AVX-512 vector, two NEON/SSE pairs — and the
/// number of jump-ahead RNG chains behind [`Pcg64::fill_f64`] (equality
/// asserted at compile time below). A packed lane word is
/// [`LANES_PER_WORD`] / [`TILE`] = 8 tiles.
pub const TILE: usize = crate::util::aligned::F64S_PER_CACHE_LINE;

// Retuning any one of the three tile-shaped constants silently breaks
// the others' layout/ILP assumptions — fail the build instead.
const _: () = assert!(
    TILE == crate::rng::FILL_CHAINS,
    "tile width must match fill_f64's jump-ahead chain count"
);
const _: () = assert!(
    LANES_PER_WORD % TILE == 0,
    "a packed lane word must hold a whole number of tiles"
);

/// Lanes per packed state word (`u64` bits).
pub const LANES_PER_WORD: usize = 64;

/// All-ones mask over the low `k` lanes of a packed word (`k ∈ 0..=64`).
#[inline]
pub fn lane_mask(k: usize) -> u64 {
    debug_assert!(k <= LANES_PER_WORD);
    if k >= 64 {
        u64::MAX
    } else {
        (1u64 << k) - 1
    }
}

/// 64-byte-aligned buffer of one `f64` per lane of a packed word
/// (8 [`TILE`]s); alignment makes every tile a single aligned vector
/// load/store.
#[repr(C, align(64))]
#[derive(Clone, Debug)]
pub struct F64Lanes(pub [f64; LANES_PER_WORD]);

impl Default for F64Lanes {
    fn default() -> Self {
        Self([0.0; LANES_PER_WORD])
    }
}

/// 64-byte-aligned buffer of one pattern index per lane of a packed word.
#[repr(C, align(64))]
#[derive(Clone, Debug)]
pub struct U8Lanes(pub [u8; LANES_PER_WORD]);

impl Default for U8Lanes {
    fn default() -> Self {
        Self([0; LANES_PER_WORD])
    }
}

/// Reusable per-draw scratch: the uniform buffer and two gathered operand
/// buffers (mult/thresh, or the broadcast θ probabilities). Owned by
/// [`SweepBuf`]; filled fresh for the live lanes of every word, so stale
/// ghost-lane contents are never observable (draw words are masked to the
/// live lane count).
#[derive(Clone, Debug, Default)]
pub struct DrawScratch {
    /// Per-lane uniforms, consumed in lane order (the determinism key).
    pub u: F64Lanes,
    /// First gathered operand (acceptance `mult`, or θ probability).
    pub a: F64Lanes,
    /// Second gathered operand (acceptance `thresh`).
    pub b: F64Lanes,
}

/// All per-worker sweep state: tile-major, 64-byte-aligned, allocated
/// once per sweep chunk and reused across every site in it — the sweep
/// hot path performs no per-site allocation.
#[derive(Clone, Debug, Default)]
pub struct SweepBuf {
    /// Per-lane log-odds accumulators (high-degree x fallback).
    pub acc: F64Lanes,
    /// Per-lane conditional-table pattern indices (cached-table x path).
    pub idx: U8Lanes,
    /// Draw-word assembly scratch.
    pub draw: DrawScratch,
    /// Per-state per-lane score accumulators for K > 2 sites (one
    /// [`F64Lanes`] per state, grown lazily to the engine's `k` on first
    /// use and reused across sites — still no per-site allocation).
    pub cat: Vec<F64Lanes>,
}

impl SweepBuf {
    /// Fresh zeroed buffers.
    pub fn new() -> Self {
        Self::default()
    }

    /// Zeroed per-state score accumulators for a `k`-state site (grows
    /// the buffer on first use, then only clears).
    pub fn cat_scores(&mut self, k: usize) -> &mut [F64Lanes] {
        if self.cat.len() < k {
            self.cat.resize_with(k, F64Lanes::default);
        }
        for s in self.cat[..k].iter_mut() {
            s.0.fill(0.0);
        }
        &mut self.cat[..k]
    }
}

/// Draw one K-state categorical site update for a packed lane word and
/// scatter the winning states into `planes_out` bit-planes.
///
/// Per live lane `l` the winner is drawn from the max-subtracted softmax
/// of `scores[s].0[l]` (`s ∈ 0..k_states`) by inverse-CDF over one
/// uniform: `s* = min { s : u · Σ_t e^{z_t − z_max} < Σ_{t ≤ s} … }`.
/// Exactly `lanes` uniforms are consumed via [`Pcg64::fill_f64`] (lane
/// order, the determinism key); ghost bits `lanes..` of every output
/// plane are zero.
///
/// This helper is deliberately kernel-independent: every [`LaneKernel`]'s
/// K-state site body accumulates into the same [`SweepBuf::cat_scores`]
/// buffers with its own `accumulate`, then calls this one draw routine —
/// so cross-kernel bit-identity of K-state trajectories holds by
/// construction (the accumulate arithmetic is already pinned by the
/// binary contract above).
pub fn draw_categorical_planes(
    rng: &mut Pcg64,
    scores: &[F64Lanes],
    lanes: usize,
    scratch: &mut DrawScratch,
    planes_out: &mut [u64],
) {
    let k_states = scores.len();
    debug_assert!(k_states >= 2 && lanes <= LANES_PER_WORD);
    debug_assert!(k_states <= 1 << planes_out.len());
    planes_out.fill(0);
    rng.fill_f64(&mut scratch.u.0, lanes);
    for l in 0..lanes {
        let mut zmax = scores[0].0[l];
        for sc in &scores[1..] {
            zmax = zmax.max(sc.0[l]);
        }
        let mut total = 0.0;
        for (w, sc) in scratch.a.0[..k_states].iter_mut().zip(scores) {
            *w = (sc.0[l] - zmax).exp();
            total += *w;
        }
        let target = scratch.u.0[l] * total;
        let mut cum = 0.0;
        let mut win = k_states - 1;
        for (s, &w) in scratch.a.0[..k_states].iter().enumerate() {
            cum += w;
            if target < cum {
                win = s;
                break;
            }
        }
        for (p, word) in planes_out.iter_mut().enumerate() {
            *word |= (((win >> p) & 1) as u64) << l;
        }
    }
}

/// Runtime-selectable lane-kernel implementation (see module docs).
///
/// Every variant samples the *same trajectory*; the choice is purely a
/// performance knob, so it can be flipped per engine without touching
/// reproducibility. Parsed from the bench CLI via [`KernelKind::parse`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum KernelKind {
    /// Per-lane reference loops ([`ScalarKernel`]).
    Scalar,
    /// Explicitly 8-lane-tiled stable-Rust kernels ([`TiledKernel`]).
    #[default]
    Tiled,
    /// `core::simd` kernels; only with the `nightly-simd` feature.
    #[cfg(feature = "nightly-simd")]
    Simd,
}

impl KernelKind {
    /// Parse a CLI name (`scalar` / `tiled` / `nightly-simd`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "scalar" => Some(Self::Scalar),
            "tiled" => Some(Self::Tiled),
            #[cfg(feature = "nightly-simd")]
            "nightly-simd" | "simd" => Some(Self::Simd),
            _ => None,
        }
    }

    /// The CLI/report name of this kernel.
    pub fn name(self) -> &'static str {
        match self {
            Self::Scalar => "scalar",
            Self::Tiled => "tiled",
            #[cfg(feature = "nightly-simd")]
            Self::Simd => "nightly-simd",
        }
    }

    /// Every kernel compiled into this build.
    pub fn all() -> &'static [KernelKind] {
        const ALL: &[KernelKind] = &[
            KernelKind::Scalar,
            KernelKind::Tiled,
            #[cfg(feature = "nightly-simd")]
            KernelKind::Simd,
        ];
        ALL
    }
}

/// The five primitive lane operations a sweep is built from (see module
/// docs). Implementations are zero-sized types; the engine monomorphizes
/// one sweep body per kernel and dispatches once per sweep.
pub trait LaneKernel {
    /// Report/bench label of this implementation.
    const NAME: &'static str;

    /// `acc[l] += θ_l · β` for all 64 lanes of packed θ word `tw`.
    ///
    /// Ghost lanes accumulate garbage the caller never draws from. The
    /// arithmetic per lane must be exactly `((tw >> l) & 1) as f64 * β`
    /// added in incidence order — the fold order the cached x-tables
    /// replicate.
    fn accumulate(acc: &mut F64Lanes, tw: u64, beta: f64);

    /// Set bit `bit` of each lane's pattern index to that lane's θ bit.
    fn gather(idx: &mut U8Lanes, tw: u64, bit: u32);

    /// Assemble the x draw word for one packed word from the model's
    /// cached acceptance parts (`mult`/`thresh`, indexed by each lane's
    /// gathered pattern): lane `l` of the result is
    /// `u_l · mult[idx_l] < thresh[idx_l]`, with `u_l` the `l`-th next
    /// uniform of `rng`. Exactly `k` uniforms are consumed; bits `k..`
    /// are zero.
    fn draw_table_word(
        rng: &mut Pcg64,
        mult: &[f64],
        thresh: &[f64],
        idx: &U8Lanes,
        k: usize,
        scratch: &mut DrawScratch,
    ) -> u64;

    /// Assemble the x draw word from accumulated per-lane log-odds: lane
    /// `l` draws `Bernoulli(σ(acc_l))` via the same acceptance-part
    /// comparison as the cached path. Exactly `k` uniforms are consumed;
    /// bits `k..` are zero.
    fn draw_logodds_word(
        rng: &mut Pcg64,
        acc: &F64Lanes,
        k: usize,
        scratch: &mut DrawScratch,
    ) -> u64;

    /// Assemble the θ draw word for one factor slot: lane `l` draws
    /// `Bernoulli(p[x1_l | x2_l·2])` from the slot's cached four-sigmoid
    /// table. Exactly `k` uniforms are consumed; bits `k..` are zero.
    fn draw_theta_word(
        rng: &mut Pcg64,
        p: &[f64; 4],
        x1: u64,
        x2: u64,
        k: usize,
        scratch: &mut DrawScratch,
    ) -> u64;
}

// -- scalar reference -------------------------------------------------------

/// Per-lane reference kernels — the pre-tiling hot path, kept verbatim as
/// the readable baseline every other kernel must match bit-for-bit.
pub struct ScalarKernel;

impl LaneKernel for ScalarKernel {
    const NAME: &'static str = "scalar";

    #[inline(always)]
    fn accumulate(acc: &mut F64Lanes, tw: u64, beta: f64) {
        if tw == 0 {
            return;
        }
        if tw == u64::MAX {
            // word-level shortcut: adds β to every lane, exactly what the
            // general body computes for all-ones
            for a in acc.0.iter_mut() {
                *a += beta;
            }
        } else {
            for (l, a) in acc.0.iter_mut().enumerate() {
                *a += ((tw >> l) & 1) as f64 * beta;
            }
        }
    }

    #[inline(always)]
    fn gather(idx: &mut U8Lanes, tw: u64, bit: u32) {
        if tw == 0 {
            return;
        }
        let b = 1u8 << bit;
        if tw == u64::MAX {
            for i in idx.0.iter_mut() {
                *i |= b;
            }
        } else {
            for (l, i) in idx.0.iter_mut().enumerate() {
                *i |= (((tw >> l) & 1) as u8) << bit;
            }
        }
    }

    #[inline(always)]
    fn draw_table_word(
        rng: &mut Pcg64,
        mult: &[f64],
        thresh: &[f64],
        idx: &U8Lanes,
        k: usize,
        _scratch: &mut DrawScratch,
    ) -> u64 {
        let mut word = 0u64;
        for (l, &i) in idx.0[..k].iter().enumerate() {
            let hit = bernoulli_from_parts(rng, mult[i as usize], thresh[i as usize]);
            word |= (hit as u64) << l;
        }
        word
    }

    #[inline(always)]
    fn draw_logodds_word(
        rng: &mut Pcg64,
        acc: &F64Lanes,
        k: usize,
        _scratch: &mut DrawScratch,
    ) -> u64 {
        let mut word = 0u64;
        for (l, &z) in acc.0[..k].iter().enumerate() {
            word |= (bernoulli_sigmoid(rng, z) as u64) << l;
        }
        word
    }

    #[inline(always)]
    fn draw_theta_word(
        rng: &mut Pcg64,
        p: &[f64; 4],
        x1: u64,
        x2: u64,
        k: usize,
        _scratch: &mut DrawScratch,
    ) -> u64 {
        let mut word = 0u64;
        for l in 0..k {
            let idx = (((x1 >> l) & 1) | (((x2 >> l) & 1) << 1)) as usize;
            word |= (rng.bernoulli(p[idx]) as u64) << l;
        }
        word
    }
}

// -- stable tiled -----------------------------------------------------------

/// Explicitly 8-lane-tiled kernels on stable Rust (see module docs):
/// fixed-width tile bodies over the 64-byte-aligned [`SweepBuf`] buffers,
/// uniforms refilled through [`Pcg64::fill_f64`]'s eight jump-ahead
/// chains, and the per-lane shift-or draw assembly replaced by per-tile
/// bitmask reduction.
pub struct TiledKernel;

/// Compare `u·a < b` across all live tiles and pack the results into a
/// lane word, masked to the low `k` lanes. Tail-tile lanes ≥ `k` compare
/// stale scratch — finite garbage whose bits the mask then discards.
#[inline(always)]
fn compare_tiles_mul(u: &F64Lanes, a: &F64Lanes, b: &F64Lanes, k: usize) -> u64 {
    let mut word = 0u64;
    for (t, ((ut, at), bt)) in u
        .0
        .chunks_exact(TILE)
        .zip(a.0.chunks_exact(TILE))
        .zip(b.0.chunks_exact(TILE))
        .enumerate()
    {
        if t * TILE >= k {
            break;
        }
        let mut bits = 0u64;
        for (j, ((&uj, &aj), &bj)) in ut.iter().zip(at.iter()).zip(bt.iter()).enumerate() {
            bits |= ((uj * aj < bj) as u64) << j;
        }
        word |= bits << (t * TILE);
    }
    word & lane_mask(k)
}

/// Compare `u < a` across all live tiles, packed and masked as in
/// [`compare_tiles_mul`].
#[inline(always)]
fn compare_tiles_lt(u: &F64Lanes, a: &F64Lanes, k: usize) -> u64 {
    let mut word = 0u64;
    for (t, (ut, at)) in u
        .0
        .chunks_exact(TILE)
        .zip(a.0.chunks_exact(TILE))
        .enumerate()
    {
        if t * TILE >= k {
            break;
        }
        let mut bits = 0u64;
        for (j, (&uj, &aj)) in ut.iter().zip(at.iter()).enumerate() {
            bits |= ((uj < aj) as u64) << j;
        }
        word |= bits << (t * TILE);
    }
    word & lane_mask(k)
}

impl LaneKernel for TiledKernel {
    const NAME: &'static str = "tiled";

    #[inline(always)]
    fn accumulate(acc: &mut F64Lanes, tw: u64, beta: f64) {
        if tw == 0 {
            return;
        }
        if tw == u64::MAX {
            for tile in acc.0.chunks_exact_mut(TILE) {
                for a in tile.iter_mut() {
                    *a += beta;
                }
            }
            return;
        }
        for (t, tile) in acc.0.chunks_exact_mut(TILE).enumerate() {
            let bits = tw >> (t * TILE);
            // same per-lane arithmetic as ScalarKernel (±0.0 included),
            // in a fixed 8-wide select+add the backend vectorizes
            let mut add = [0.0f64; TILE];
            for (j, v) in add.iter_mut().enumerate() {
                *v = ((bits >> j) & 1) as f64 * beta;
            }
            for (a, &v) in tile.iter_mut().zip(add.iter()) {
                *a += v;
            }
        }
    }

    #[inline(always)]
    fn gather(idx: &mut U8Lanes, tw: u64, bit: u32) {
        if tw == 0 {
            return;
        }
        let b = 1u8 << bit;
        if tw == u64::MAX {
            for tile in idx.0.chunks_exact_mut(TILE) {
                for i in tile.iter_mut() {
                    *i |= b;
                }
            }
            return;
        }
        for (t, tile) in idx.0.chunks_exact_mut(TILE).enumerate() {
            let bits = tw >> (t * TILE);
            for (j, i) in tile.iter_mut().enumerate() {
                *i |= (((bits >> j) & 1) as u8) << bit;
            }
        }
    }

    #[inline(always)]
    fn draw_table_word(
        rng: &mut Pcg64,
        mult: &[f64],
        thresh: &[f64],
        idx: &U8Lanes,
        k: usize,
        scratch: &mut DrawScratch,
    ) -> u64 {
        rng.fill_f64(&mut scratch.u.0, k);
        for ((a, b), &i) in scratch
            .a
            .0
            .iter_mut()
            .zip(scratch.b.0.iter_mut())
            .zip(idx.0[..k].iter())
        {
            *a = mult[i as usize];
            *b = thresh[i as usize];
        }
        compare_tiles_mul(&scratch.u, &scratch.a, &scratch.b, k)
    }

    #[inline(always)]
    fn draw_logodds_word(
        rng: &mut Pcg64,
        acc: &F64Lanes,
        k: usize,
        scratch: &mut DrawScratch,
    ) -> u64 {
        rng.fill_f64(&mut scratch.u.0, k);
        for ((a, b), &z) in scratch
            .a
            .0
            .iter_mut()
            .zip(scratch.b.0.iter_mut())
            .zip(acc.0[..k].iter())
        {
            let (m, t) = bernoulli_sigmoid_parts(z);
            *a = m;
            *b = t;
        }
        compare_tiles_mul(&scratch.u, &scratch.a, &scratch.b, k)
    }

    #[inline(always)]
    fn draw_theta_word(
        rng: &mut Pcg64,
        p: &[f64; 4],
        x1: u64,
        x2: u64,
        k: usize,
        scratch: &mut DrawScratch,
    ) -> u64 {
        rng.fill_f64(&mut scratch.u.0, k);
        for (l, a) in scratch.a.0[..k].iter_mut().enumerate() {
            let idx = (((x1 >> l) & 1) | (((x2 >> l) & 1) << 1)) as usize;
            *a = p[idx];
        }
        compare_tiles_lt(&scratch.u, &scratch.a, k)
    }
}

// -- nightly core::simd -----------------------------------------------------

#[cfg(feature = "nightly-simd")]
pub use nightly::SimdKernel;

#[cfg(feature = "nightly-simd")]
mod nightly {
    //! `core::simd` kernels (`portable_simd`, nightly only). Same tile
    //! schedule and per-lane arithmetic as [`TiledKernel`], written as
    //! explicit `f64x8` vectors + mask bit-ops instead of relying on the
    //! autovectorizer.

    use core::simd::prelude::*;

    use super::*;

    type F8 = Simd<f64, TILE>;
    type M8 = Mask<i64, TILE>;

    /// `core::simd` implementation of [`LaneKernel`] (see module docs).
    pub struct SimdKernel;

    #[inline(always)]
    fn compare_mul(u: &F64Lanes, a: &F64Lanes, b: &F64Lanes, k: usize) -> u64 {
        let mut word = 0u64;
        for (t, ((ut, at), bt)) in u
            .0
            .chunks_exact(TILE)
            .zip(a.0.chunks_exact(TILE))
            .zip(b.0.chunks_exact(TILE))
            .enumerate()
        {
            if t * TILE >= k {
                break;
            }
            let prod = F8::from_slice(ut) * F8::from_slice(at);
            let bits = prod.simd_lt(F8::from_slice(bt)).to_bitmask();
            word |= bits << (t * TILE);
        }
        word & lane_mask(k)
    }

    impl LaneKernel for SimdKernel {
        const NAME: &'static str = "nightly-simd";

        #[inline(always)]
        fn accumulate(acc: &mut F64Lanes, tw: u64, beta: f64) {
            if tw == 0 {
                return;
            }
            let beta_v = F8::splat(beta);
            if tw == u64::MAX {
                for tile in acc.0.chunks_exact_mut(TILE) {
                    (F8::from_slice(tile) + beta_v).copy_to_slice(tile);
                }
                return;
            }
            let (one, zero) = (F8::splat(1.0), F8::splat(0.0));
            for (t, tile) in acc.0.chunks_exact_mut(TILE).enumerate() {
                let mask = M8::from_bitmask(tw >> (t * TILE));
                // select 1.0/0.0 then multiply: keeps the exact scalar
                // arithmetic `bit as f64 * β` (±0.0 sign included)
                let add = mask.select(one, zero) * beta_v;
                (F8::from_slice(tile) + add).copy_to_slice(tile);
            }
        }

        #[inline(always)]
        fn gather(idx: &mut U8Lanes, tw: u64, bit: u32) {
            // byte scatter: same body as TiledKernel (no f64 lanes here)
            TiledKernel::gather(idx, tw, bit);
        }

        #[inline(always)]
        fn draw_table_word(
            rng: &mut Pcg64,
            mult: &[f64],
            thresh: &[f64],
            idx: &U8Lanes,
            k: usize,
            scratch: &mut DrawScratch,
        ) -> u64 {
            rng.fill_f64(&mut scratch.u.0, k);
            for ((a, b), &i) in scratch
                .a
                .0
                .iter_mut()
                .zip(scratch.b.0.iter_mut())
                .zip(idx.0[..k].iter())
            {
                *a = mult[i as usize];
                *b = thresh[i as usize];
            }
            compare_mul(&scratch.u, &scratch.a, &scratch.b, k)
        }

        #[inline(always)]
        fn draw_logodds_word(
            rng: &mut Pcg64,
            acc: &F64Lanes,
            k: usize,
            scratch: &mut DrawScratch,
        ) -> u64 {
            rng.fill_f64(&mut scratch.u.0, k);
            for ((a, b), &z) in scratch
                .a
                .0
                .iter_mut()
                .zip(scratch.b.0.iter_mut())
                .zip(acc.0[..k].iter())
            {
                let (m, t) = bernoulli_sigmoid_parts(z);
                *a = m;
                *b = t;
            }
            compare_mul(&scratch.u, &scratch.a, &scratch.b, k)
        }

        #[inline(always)]
        fn draw_theta_word(
            rng: &mut Pcg64,
            p: &[f64; 4],
            x1: u64,
            x2: u64,
            k: usize,
            scratch: &mut DrawScratch,
        ) -> u64 {
            rng.fill_f64(&mut scratch.u.0, k);
            for (l, a) in scratch.a.0[..k].iter_mut().enumerate() {
                let idx = (((x1 >> l) & 1) | (((x2 >> l) & 1) << 1)) as usize;
                *a = p[idx];
            }
            let mut word = 0u64;
            for (t, (ut, at)) in scratch
                .u
                .0
                .chunks_exact(TILE)
                .zip(scratch.a.0.chunks_exact(TILE))
                .enumerate()
            {
                if t * TILE >= k {
                    break;
                }
                let bits = F8::from_slice(ut).simd_lt(F8::from_slice(at)).to_bitmask();
                word |= bits << (t * TILE);
            }
            word & lane_mask(k)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_word(rng: &mut Pcg64) -> u64 {
        rng.next_u64()
    }

    #[test]
    fn lane_mask_boundaries() {
        assert_eq!(lane_mask(0), 0);
        assert_eq!(lane_mask(1), 1);
        assert_eq!(lane_mask(63), u64::MAX >> 1);
        assert_eq!(lane_mask(64), u64::MAX);
    }

    #[test]
    fn kernel_kind_parse_roundtrip() {
        for &k in KernelKind::all() {
            assert_eq!(KernelKind::parse(k.name()), Some(k));
        }
        assert_eq!(KernelKind::parse("warp"), None);
        assert_eq!(KernelKind::default(), KernelKind::Tiled);
    }

    #[test]
    fn tiled_accumulate_matches_scalar_bitwise() {
        let mut rng = Pcg64::seed(21);
        for case in 0..200 {
            let tw = match case % 4 {
                0 => 0,
                1 => u64::MAX,
                _ => rand_word(&mut rng),
            };
            let beta = (rng.next_f64() - 0.5) * 4.0;
            let field = (rng.next_f64() - 0.5) * 2.0;
            let mut a = F64Lanes([field; LANES_PER_WORD]);
            let mut b = a.clone();
            ScalarKernel::accumulate(&mut a, tw, beta);
            TiledKernel::accumulate(&mut b, tw, beta);
            for l in 0..LANES_PER_WORD {
                assert_eq!(a.0[l].to_bits(), b.0[l].to_bits(), "case {case} lane {l}");
            }
        }
    }

    #[test]
    fn tiled_gather_matches_scalar() {
        let mut rng = Pcg64::seed(22);
        for case in 0..100 {
            let mut a = U8Lanes::default();
            let mut b = U8Lanes::default();
            for bit in 0..6 {
                let tw = rand_word(&mut rng);
                ScalarKernel::gather(&mut a, tw, bit);
                TiledKernel::gather(&mut b, tw, bit);
            }
            assert_eq!(a.0, b.0, "case {case}");
        }
    }

    #[test]
    fn tiled_draw_words_match_scalar_and_consume_equal_rng() {
        let base = Pcg64::seed(23);
        let mut gen = Pcg64::seed(24);
        for case in 0..120u64 {
            let k = 1 + (gen.next_u64() % 64) as usize;
            // cached-table path operands
            let table_bits = 3usize;
            let (mut mult, mut thresh) = (Vec::new(), Vec::new());
            for m in 0..(1 << table_bits) {
                let (a, b) = bernoulli_sigmoid_parts((m as f64 - 4.0) * 0.37);
                mult.push(a);
                thresh.push(b);
            }
            let mut idx = U8Lanes::default();
            for i in idx.0.iter_mut() {
                *i = (gen.next_u64() % (1 << table_bits)) as u8;
            }
            let mut scratch = DrawScratch::default();
            let mut r1 = base.split2(case, 0);
            let mut r2 = r1.clone();
            let w1 = ScalarKernel::draw_table_word(&mut r1, &mult, &thresh, &idx, k, &mut scratch);
            let w2 = TiledKernel::draw_table_word(&mut r2, &mult, &thresh, &idx, k, &mut scratch);
            assert_eq!(w1, w2, "table word diverged, case {case} k {k}");
            assert_eq!(r1.next_u64(), r2.next_u64(), "rng desync (table), case {case}");

            // log-odds fallback path
            let mut acc = F64Lanes::default();
            for a in acc.0.iter_mut() {
                *a = (gen.next_f64() - 0.5) * 6.0;
            }
            let mut r1 = base.split2(case, 1);
            let mut r2 = r1.clone();
            let w1 = ScalarKernel::draw_logodds_word(&mut r1, &acc, k, &mut scratch);
            let w2 = TiledKernel::draw_logodds_word(&mut r2, &acc, k, &mut scratch);
            assert_eq!(w1, w2, "logodds word diverged, case {case} k {k}");
            assert_eq!(r1.next_u64(), r2.next_u64(), "rng desync (logodds), case {case}");

            // θ four-sigmoid broadcast path
            let p = [0.12, 0.48, 0.73, 0.97];
            let (x1, x2) = (gen.next_u64(), gen.next_u64());
            let mut r1 = base.split2(case, 2);
            let mut r2 = r1.clone();
            let w1 = ScalarKernel::draw_theta_word(&mut r1, &p, x1, x2, k, &mut scratch);
            let w2 = TiledKernel::draw_theta_word(&mut r2, &p, x1, x2, k, &mut scratch);
            assert_eq!(w1, w2, "theta word diverged, case {case} k {k}");
            assert_eq!(r1.next_u64(), r2.next_u64(), "rng desync (theta), case {case}");
        }
    }

    #[test]
    fn categorical_draw_matches_sequential_reference_and_masks_ghosts() {
        let base = Pcg64::seed(91);
        let mut gen = Pcg64::seed(92);
        for case in 0..60u64 {
            let k_states = 3 + (gen.next_u64() % 6) as usize; // 3..=8
            let planes = usize::BITS as usize - (k_states - 1).leading_zeros() as usize;
            let lanes = 1 + (gen.next_u64() % 64) as usize;
            let mut scores: Vec<F64Lanes> = (0..k_states).map(|_| F64Lanes::default()).collect();
            for sc in scores.iter_mut() {
                for z in sc.0.iter_mut() {
                    *z = (gen.next_f64() - 0.5) * 8.0;
                }
            }
            let mut scratch = DrawScratch::default();
            let mut out = vec![u64::MAX; planes]; // stale garbage must be cleared
            let mut rng = base.split2(case, 0);
            draw_categorical_planes(&mut rng, &scores, lanes, &mut scratch, &mut out);

            // reference: one sequential uniform per live lane, plain softmax CDF
            let mut rref = base.split2(case, 0);
            for l in 0..lanes {
                let u = rref.next_f64();
                let zmax = scores.iter().map(|s| s.0[l]).fold(f64::NEG_INFINITY, f64::max);
                let w: Vec<f64> = scores.iter().map(|s| (s.0[l] - zmax).exp()).collect();
                let total: f64 = w.iter().sum();
                let target = u * total;
                let mut cum = 0.0;
                let mut win = k_states - 1;
                for (s, &ws) in w.iter().enumerate() {
                    cum += ws;
                    if target < cum {
                        win = s;
                        break;
                    }
                }
                let got: usize = (0..planes).map(|p| (((out[p] >> l) & 1) as usize) << p).sum();
                assert_eq!(got, win, "case {case} lane {l}");
            }
            // rng advanced identically (exactly `lanes` uniforms)
            assert_eq!(rng.next_u64(), rref.next_u64(), "rng desync, case {case}");
            // ghost bits cleared on every plane
            for (p, &word) in out.iter().enumerate() {
                assert_eq!(word & !lane_mask(lanes), 0, "ghost bits, case {case} plane {p}");
            }
        }
    }

    #[test]
    fn draw_words_mask_ghost_lanes() {
        // stale scratch from a previous full word must never leak into
        // the bits above k
        let mut scratch = DrawScratch::default();
        let mut acc = F64Lanes([40.0; LANES_PER_WORD]); // σ ≈ 1: draws all-ones
        let mut rng = Pcg64::seed(31);
        let full = TiledKernel::draw_logodds_word(&mut rng, &acc, 64, &mut scratch);
        assert_eq!(full, u64::MAX);
        acc = F64Lanes([40.0; LANES_PER_WORD]);
        let mut rng = Pcg64::seed(31);
        let tail = TiledKernel::draw_logodds_word(&mut rng, &acc, 5, &mut scratch);
        assert_eq!(tail & !lane_mask(5), 0, "ghost lanes set: {tail:#x}");
        assert_eq!(tail, lane_mask(5));
    }
}
