//! Lane-batched multi-chain execution engine.
//!
//! Serving many independent chains at once is the batching axis the
//! paper's coloring-free parallelism makes cheap: every chain targets the
//! same dualized model, so a sweep can traverse each variable's incidence
//! list *once* and resample that variable in all chains simultaneously.
//! [`LanePdSampler`] stores chain state variable-major and bit-packed —
//! lane `c` of the word at variable `v` is chain `c`'s value of `x_v`,
//! 64 chains per `u64` — which turns the per-chain inner loop into
//! straight-line word arithmetic and divides the model traffic (incidence
//! lists, dual parameters) by the lane count. The θ half-step collapses
//! further: a factor's conditional depends only on its two endpoint bits,
//! so four sigmoids cover all 64 lanes.
//!
//! Contrast with running N scalar [`crate::samplers::PdSampler`]s: those
//! re-read the incidence lists N times per sweep and keep N separate
//! `Vec<u8>` states. `benches/throughput.rs --mode lanes` measures the
//! gap (acceptance: ≥ 3× for 64 chains on a 64×64 grid).
//!
//! The sweep hot path runs on flat arenas, not the model's nested
//! reference structures: the CSR incidence view
//! ([`crate::duality::DualModel::incidence_csr`]), the per-slot cached
//! four-sigmoid θ tables, and — for low-degree variables — cached
//! per-pattern Bernoulli acceptance parts that remove the exponential
//! from the per-lane draw entirely. All three caches are invalidated by
//! churn only, never by sweeping.
//!
//! Thread parallelism splits over *variables* (then factor slots), not
//! chains, so it scales with model size rather than chain count; chunk
//! boundaries are degree-aware ([`crate::util::balanced_ranges`] over an
//! incidence-length prefix sum) so hubs in skewed graphs don't pile into
//! one worker. RNG streams are keyed per `(sweep, site)` via
//! [`crate::rng::Pcg64::split2`], which makes a lane sweep bit-identical
//! for every pool size and chunking, including none — see
//! `tests/lane_engine.rs`.
//!
//! Churn keeps working mid-run: [`LanePdSampler::add_factor`] /
//! [`LanePdSampler::remove_factor`] apply one O(degree) update to the
//! shared [`crate::duality::DualModel`] for all lanes at once.

mod sampler;

pub use sampler::LanePdSampler;
