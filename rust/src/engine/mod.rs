//! Lane-batched multi-chain execution engine.
//!
//! Serving many independent chains at once is the batching axis the
//! paper's coloring-free parallelism makes cheap: every chain targets the
//! same dualized model, so a sweep can traverse each variable's incidence
//! list *once* and resample that variable in all chains simultaneously.
//! [`LanePdSampler`] stores chain state variable-major and bit-packed —
//! lane `c` of the word at variable `v` is chain `c`'s value of `x_v`,
//! 64 chains per `u64` — which turns the per-chain inner loop into
//! straight-line word arithmetic and divides the model traffic (incidence
//! lists, dual parameters) by the lane count. The θ half-step collapses
//! further: a factor's conditional depends only on its two endpoint bits,
//! so four sigmoids cover all 64 lanes.
//!
//! Contrast with running N scalar [`crate::samplers::PdSampler`]s: those
//! re-read the incidence lists N times per sweep and keep N separate
//! `Vec<u8>` states. `benches/throughput.rs --mode lanes` measures the
//! gap (acceptance: ≥ 3× for 64 chains on a 64×64 grid).
//!
//! The sweep hot path runs on flat arenas, not the model's nested
//! reference structures: the CSR incidence view
//! ([`crate::duality::DualModel::incidence_csr`]), the per-slot cached
//! four-sigmoid θ tables, and — for low-degree variables — a tile-aligned
//! arena of cached per-pattern Bernoulli acceptance parts
//! ([`crate::duality::DualModel::x_table`]) that removes the exponential
//! from the per-lane draw entirely. All three caches are invalidated by
//! churn only, never by sweeping.
//!
//! The innermost `(site, word)` bodies live in [`kernels`] behind the
//! [`kernels::LaneKernel`] trait and are selected at runtime via
//! [`EngineConfig`] / [`KernelKind`]: `scalar` per-lane reference loops,
//! explicitly `tiled` 8-lane bodies over 64-byte-aligned per-worker
//! buffers with jump-ahead RNG refill ([`crate::rng::Pcg64::fill_f64`]),
//! or `core::simd` kernels under the `nightly-simd` feature. All kernels
//! sample bit-identical trajectories — the choice is purely a throughput
//! knob (`benches/throughput.rs --mode lanes --kernel <name>`).
//!
//! Thread parallelism splits over *variables* (then factor slots), not
//! chains, so it scales with model size rather than chain count; chunk
//! boundaries are degree-aware
//! ([`crate::util::threadpool::balanced_ranges_aligned`] over an
//! incidence-length prefix sum, rounded so seams fall on cache-line
//! multiples of the state rows) so hubs in skewed graphs don't pile into
//! one worker and false sharing at chunk seams is minimized (exact
//! guarantee documented at the sampler's `row_align`). RNG streams are
//! keyed per
//! `(sweep, site)` via [`crate::rng::Pcg64::split2`], which makes a lane
//! sweep bit-identical for every pool size and chunking, including none —
//! see `tests/lane_engine.rs` and `tests/kernel_equivalence.rs`.
//!
//! Churn keeps working mid-run: [`LanePdSampler::add_factor`] /
//! [`LanePdSampler::remove_factor`] apply one O(degree) update to the
//! shared [`crate::duality::DualModel`] for all lanes at once.
//!
//! Heavy-tailed graphs get a second axis: [`SweepPolicy::Minibatch`]
//! switches sites above a degree threshold to Poisson-subsampled
//! MIN-Gibbs-corrected updates over per-site alias plans
//! ([`crate::duality::MbPlan`]), so hubs pay O(batch) instead of
//! O(degree) per sweep, and refreshes only `1/stride` of the θ slots per
//! sweep. The corrected chain is a different trajectory than the exact
//! path (same stationary law — gated by `tests/statistical_validation.rs`)
//! but remains kernel- and pool-invariant for a fixed policy.
//!
//! Strongly-coupled graphs get the opposite lever: [`SweepPolicy::Blocked`]
//! tracks a per-slot endpoint-agreement EWMA during normal sweeps, lets
//! [`crate::duality::BlockPlanner`] grow capped spanning-tree blocks
//! around the strongly-coupled clusters (re-planned lazily on churn
//! epochs), and draws each block's tree jointly by per-lane
//! forward-filter/backward-sample with the tree slots' duals marginalized
//! into softplus edge potentials — cross-block factors still route
//! through the PD dual, so the paper's coloring-free θ half-step is
//! untouched. Joint draws cost more per sweep (DRR `cost()` carries a
//! per-tree-slot surcharge) but buy mixing where flat PD stalls; the
//! tracked win is ESS/s (`benches/throughput.rs --mode blocked`).
//! Blocked trajectories are bit-identical across kernels, pool sizes,
//! and shard counts for a fixed policy.
//!
//! K-state (Potts) models generalize the packed state to `⌈log₂ k⌉`
//! bit-planes per site and `k` θ-planes per slot (the indicator dual of
//! [`crate::duality::DualModel`]); the site draw becomes one shared
//! categorical CDF inversion ([`kernels::draw_categorical_planes`]) so
//! cross-kernel bit-identity holds by construction, and `k = 2`
//! collapses to the historical binary layout byte-for-byte. Evidence
//! clamping ([`LanePdSampler::clamp`]) pins observed sites while their
//! neighbors keep reading them — conditional-marginal queries on any
//! tenant. Both are exact-policy-only; unsupported combinations are
//! typed [`EngineError`] rejections.

pub mod kernels;
mod sampler;

pub use kernels::KernelKind;
pub use sampler::{EngineConfig, EngineError, LanePdSampler, SweepPolicy};
