//! [`LanePdSampler`]: the bit-packed multi-chain primal–dual sampler.
//!
//! State layout (variable-major, `words = lanes.div_ceil(64)`). A site's
//! state is `x_planes` bit-planes (`⌈log₂ k⌉`; 1 for binary) and a slot's
//! dual state is `t_planes` bit-planes (1 for binary, `k` for K-state —
//! one auxiliary per state, see the indicator dual in
//! [`DualModel`](crate::duality::DualModel)):
//!
//! ```text
//! x[(v·x_planes + p) · words + w]      bit l  =  bit p of x_v, chain (w·64 + l)
//! theta[(i·t_planes + s) · words + w]  bit l  =  θ_{i,s} of chain (w·64 + l)
//! ```
//!
//! For `k = 2` both plane counts are 1 and this is exactly the historical
//! binary layout — every binary trajectory is preserved bit-for-bit by
//! construction, which `tests/kernel_equivalence.rs` pins.
//!
//! ## Evidence clamping
//!
//! [`LanePdSampler::clamp`] pins a site to an observed state in every
//! lane: the x half-step skips the site's draw entirely (its
//! per-`(sweep, site)` RNG stream is simply never consumed, so no other
//! site's draws shift), while the θ half-step keeps reading the clamped
//! bits — so neighbors' conditionals see the evidence and the chain
//! samples the conditional joint. Clamping composes with every sweep
//! policy: minibatched sites skip their thinning pass entirely when
//! clamped (the dispatch skip precedes the plan lookup), and under a
//! blocked policy a clamp/unclamp is a semantic mutation — incident
//! agreement EWMAs neutral-reset and the block plan rebuilds eagerly on
//! the next sweep, with clamped sites excluded from planner candidates
//! so evidence never sits inside a joint tree draw.
//!
//! One sweep is the usual two half-steps, but vectorized over lanes:
//!
//! * x: per variable, ONE pass over the flat CSR incidence view
//!   ([`DualModel::incidence_csr`]: contiguous slot/β arrays + delta
//!   overlay — no nested-`Vec` pointer chasing) resamples the variable in
//!   all lanes. Low-degree variables skip the per-lane log-odds
//!   accumulation entirely: the model caches the Bernoulli acceptance
//!   parts for every θ-bit pattern ([`DualModel::x_table`], a tile-aligned
//!   mult/thresh arena invalidated only on churn), so each lane gathers
//!   its pattern index and draws — no exponential on the sweep path.
//!   High-degree variables fall back to the per-lane `f64` accumulate.
//! * θ: per live factor, the conditional depends only on the two endpoint
//!   bits, so the four sigmoids cached per slot in the model
//!   ([`DualModel::theta_table`], recomputed only on insert/remove — not
//!   4× per slot per sweep) serve every lane; endpoints come from flat
//!   arrays ([`DualModel::slot_endpoints`]), not `Option<DualEntry>`.
//!
//! The innermost `(site, word)` bodies — accumulate, table gather, draw
//! word assembly, four-sigmoid broadcast — are the [`LaneKernel`]
//! primitives of [`super::kernels`], selected at runtime through
//! [`EngineConfig::kernel`] / [`LanePdSampler::with_kernel`]: `scalar`
//! per-lane loops, explicitly `tiled` 8-lane bodies over 64-byte-aligned
//! reused buffers ([`SweepBuf`], one per worker — no per-site
//! allocation), or `core::simd` under the `nightly-simd` feature. Every
//! kernel samples the same trajectory bit-for-bit; see the determinism
//! contract in [`super::kernels`].
//!
//! Pooled sweeps split sites into *degree-aware* chunks: chunk boundaries
//! come from [`balanced_ranges_aligned`] over a prefix sum of incidence
//! lengths (recomputed lazily after churn), rounded so each chunk's first
//! state row starts a fresh cache line relative to the state base —
//! minimizing false sharing at chunk seams (see `row_align` for the
//! exact guarantee). Chunking never affects the trajectory: RNG streams
//! are keyed per `(sweep, site)`.
//!
//! Unused high lanes of the last word are kept zero (`lanes % 64` tail).

use std::fmt;
use std::sync::Arc;

use super::kernels::{
    draw_categorical_planes, lane_mask, F64Lanes, KernelKind, LaneKernel, ScalarKernel, SweepBuf,
    TiledKernel,
};
use crate::duality::blocking::{self, Block, BlockPlan, BlockPlanner, BlockPolicy, SweepUnit};
use crate::duality::{DualModel, MbPlan, MinibatchPolicy};
use crate::graph::{FactorGraph, FactorId, PairFactor};
use crate::rng::{bernoulli_sigmoid, Pcg64, RngCore};
use crate::util::threadpool::balanced_ranges_aligned;
use crate::util::ThreadPool;

#[cfg(feature = "nightly-simd")]
use super::kernels::SimdKernel;

/// How the engine visits sites per sweep.
///
/// Unlike the kernel choice, this is *not* trajectory-preserving — the
/// minibatch chain is a different (still exact-stationary) Markov chain.
/// It IS invariant across kernels and pool sizes for a fixed policy: the
/// subsampling draws come from the same per-`(sweep, site)` streams as
/// the exact path, and the θ stride is a pure function of
/// `(sweep, slot)`.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum SweepPolicy {
    /// Every site update folds its full live incidence (the default).
    #[default]
    Exact,
    /// Sites above the policy's degree threshold subsample factors with
    /// the Poisson/MIN-Gibbs correction ([`MinibatchPolicy`]); the θ
    /// half-step refreshes `1/stride` of the slots per sweep.
    Minibatch(MinibatchPolicy),
    /// Adaptive tree-blocking ([`BlockPolicy`]): the engine tracks
    /// per-slot endpoint-agreement EWMAs, plans capped tree-blocks
    /// around strongly-coupled clusters ([`BlockPlanner`]), and draws
    /// each block's spanning tree jointly per sweep (tree duals
    /// marginalized; everything else through the PD dual). Re-plans on
    /// churn and every `epoch` sweeps.
    Blocked(BlockPolicy),
}

impl SweepPolicy {
    /// The minibatch knobs, if this policy subsamples.
    #[inline]
    pub fn minibatch(self) -> Option<MinibatchPolicy> {
        match self {
            Self::Minibatch(p) => Some(p),
            Self::Exact | Self::Blocked(_) => None,
        }
    }

    /// The blocking knobs, if this policy plans tree-blocks.
    #[inline]
    pub fn blocked(self) -> Option<BlockPolicy> {
        match self {
            Self::Blocked(p) => Some(p),
            Self::Exact | Self::Minibatch(_) => None,
        }
    }

    /// Parse the wire form: `exact`, `minibatch`,
    /// `minibatch:<degree_threshold>`,
    /// `minibatch:<degree_threshold>:<theta_stride>` (λ knobs stay at
    /// their defaults on the wire), `blocked`, `blocked:<cap>` or
    /// `blocked:<cap>:<epoch>` (cap ≥ 2, epoch ≥ 1). Inverse of
    /// [`SweepPolicy`]'s `Display` for those forms.
    pub fn parse(tok: &str) -> Option<Self> {
        if tok == "exact" {
            return Some(Self::Exact);
        }
        let mut parts = tok.split(':');
        match parts.next()? {
            "minibatch" => {
                let mut p = MinibatchPolicy::default();
                if let Some(deg) = parts.next() {
                    p.degree_threshold = deg.parse().ok()?;
                    if let Some(stride) = parts.next() {
                        p.theta_stride = stride.parse::<usize>().ok().filter(|&s| s >= 1)?;
                        if parts.next().is_some() {
                            return None;
                        }
                    }
                }
                Some(Self::Minibatch(p))
            }
            "blocked" => {
                let mut p = BlockPolicy::default();
                if let Some(cap) = parts.next() {
                    p.cap = cap.parse::<usize>().ok().filter(|&c| c >= 2)?;
                    if let Some(epoch) = parts.next() {
                        p.epoch = epoch.parse::<usize>().ok().filter(|&e| e >= 1)?;
                        if parts.next().is_some() {
                            return None;
                        }
                    }
                }
                Some(Self::Blocked(p))
            }
            _ => None,
        }
    }
}

impl fmt::Display for SweepPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Exact => write!(f, "exact"),
            Self::Minibatch(p) => {
                write!(f, "minibatch:{}:{}", p.degree_threshold, p.theta_stride)
            }
            Self::Blocked(p) => write!(f, "blocked:{}:{}", p.cap, p.epoch),
        }
    }
}

/// Knob validation shared by the fallible constructors: `Some(reason)`
/// when the policy's knobs define a degenerate chain. The wire parser
/// already blocks these forms, so this guards the programmatic API —
/// [`MinibatchPolicy`]'s λ knobs in particular never cross the wire.
fn validate_policy(policy: SweepPolicy) -> Option<&'static str> {
    match policy {
        SweepPolicy::Exact => None,
        SweepPolicy::Minibatch(p) => {
            if p.theta_stride == 0 {
                Some("theta_stride must be >= 1 (0 would never refresh any slot)")
            } else if !(p.lambda_min > 0.0) || !p.lambda_min.is_finite() {
                Some("lambda_min must be a positive finite float (the λ floor keeps κ > 0)")
            } else if !(p.lambda_scale >= 0.0) || !p.lambda_scale.is_finite() {
                Some("lambda_scale must be a non-negative finite float")
            } else {
                None
            }
        }
        SweepPolicy::Blocked(p) => {
            if p.cap < 2 {
                Some("cap must be >= 2 (a 1-variable block cannot block anything)")
            } else if p.epoch == 0 {
                Some("epoch must be >= 1 (0 would never re-plan)")
            } else {
                None
            }
        }
    }
}

/// Engine construction / clamping errors — every invalid request is an
/// explicit, typed rejection instead of a silently wrong chain. Every
/// sweep policy now supports every cardinality `2 ≤ k ≤ 8` and clamping
/// (the former policy × K and policy × clamp rejections are gone), so
/// what remains fallible is degenerate policy knobs and out-of-range
/// targets.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EngineError {
    /// A sweep policy with degenerate knobs (zero/non-finite λ floor,
    /// negative λ scale, zero θ stride, blocking cap below 2, zero
    /// epoch): the chain such knobs define is not a valid Gibbs kernel,
    /// rejected at construction so serving paths return error replies
    /// instead of hosting a silently wrong tenant.
    InvalidPolicy {
        /// The rejected policy.
        policy: SweepPolicy,
        /// Which knob is degenerate and why.
        reason: &'static str,
    },
    /// Clamp/unclamp site index out of range (unknown site).
    SiteOutOfRange {
        /// Requested site.
        v: usize,
        /// Number of variables.
        n: usize,
    },
    /// Clamp evidence state out of range (`state ≥ k`).
    ClampOutOfRange {
        /// Requested site.
        v: usize,
        /// Number of variables.
        n: usize,
        /// Requested state.
        state: u8,
        /// States per variable.
        k: usize,
    },
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            Self::InvalidPolicy { policy, reason } => {
                write!(f, "invalid sweep policy `{policy}`: {reason}")
            }
            Self::SiteOutOfRange { v, n } => {
                write!(f, "site {v} out of range (model has {n} variables)")
            }
            Self::ClampOutOfRange { v, n, state, k } => write!(
                f,
                "clamp target out of range: site {v} (of {n}) state {state} (of {k})"
            ),
        }
    }
}

impl std::error::Error for EngineError {}

/// Construction-time knobs of a [`LanePdSampler`] (lane count, stream
/// seed, which [`LaneKernel`] implementation runs the sweep bodies, and
/// the sweep policy).
///
/// The kernel choice is a pure performance knob — every kernel samples
/// the same trajectory bit-for-bit — so configs differing only in
/// `kernel` are interchangeable mid-experiment. The sweep policy is not:
/// see [`SweepPolicy`].
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Number of chains (any positive count; 64 are packed per word).
    pub lanes: usize,
    /// Root seed of the `(sweep, site)`-keyed RNG streams.
    pub seed: u64,
    /// Sweep-kernel implementation (default: [`KernelKind::Tiled`]).
    pub kernel: KernelKind,
    /// Site-visit policy (default: [`SweepPolicy::Exact`]).
    pub sweep: SweepPolicy,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            lanes: 64,
            seed: 0,
            kernel: KernelKind::default(),
            sweep: SweepPolicy::default(),
        }
    }
}

/// Lane-batched primal–dual Gibbs sampler (up to any number of chains;
/// 64 per machine word).
pub struct LanePdSampler {
    model: DualModel,
    lanes: usize,
    words: usize,
    /// Bit-planes per x site: `⌈log₂ k⌉` (1 for binary).
    x_planes: usize,
    /// θ bit-planes per factor slot: 1 for binary, `k` for K-state (one
    /// indicator auxiliary per state — see the module docs).
    t_planes: usize,
    kernel: KernelKind,
    x: Vec<u64>,
    theta: Vec<u64>,
    /// Evidence mask: clamped sites skip their draw (module docs) under
    /// every sweep policy — minibatch plans are bypassed by the dispatch
    /// skip and the block planner excludes clamped sites.
    clamped: Vec<bool>,
    /// Number of `true` entries in `clamped` (serving stats).
    clamp_count: usize,
    pool: Option<Arc<ThreadPool>>,
    /// Stream root: every site's draws are keyed `split2(sweep, site)`.
    base: Pcg64,
    sweep_count: u64,
    /// Degree-aware chunk bounds for pooled sweeps (x over variables,
    /// θ over factor slots), valid for a pool of `chunk_plan_for` workers;
    /// 0 = stale (rebuilt lazily on the next pooled sweep).
    x_bounds: Vec<usize>,
    theta_bounds: Vec<usize>,
    chunk_plan_for: usize,
    /// The configured sweep policy (the model additionally owns the
    /// minibatch plans when it is [`SweepPolicy::Minibatch`]).
    policy: SweepPolicy,
    /// Per-slot EWMA of endpoint agreement across lanes, maintained
    /// after every sweep under a blocked policy (empty otherwise). New
    /// and recycled slots reset to the neutral 0.5.
    edge_stats: Vec<f64>,
    /// The current block plan (blocked policy only; built lazily on the
    /// first sweep and re-planned on churn/epoch — see
    /// `ensure_block_plan`).
    block_plan: Option<BlockPlan>,
    /// Set by churn: the next blocked sweep re-plans eagerly instead of
    /// waiting for the epoch boundary.
    plan_stale: bool,
    /// Pooled chunk bounds over the plan's sweep units (blocked policy
    /// only) — units partition variables, so unit chunks own disjoint
    /// x rows exactly like the per-variable chunks in `x_bounds`.
    unit_bounds: Vec<usize>,
}

/// DRR surcharge per marginalized tree slot: a joint block draw does
/// log-domain FFBS work (exp/ln per edge per lane) instead of a cached
/// table gather, so blocked tenants bill more per sweep. Repriced
/// automatically whenever the plan changes — `cost()` reads the live
/// plan.
const BLOCK_COST_SURCHARGE: u64 = 8;

/// Number of live lanes in word `w` of a site's lane row.
#[inline]
fn lanes_in_word(lanes: usize, w: usize) -> usize {
    (lanes - w * 64).min(64)
}

impl LanePdSampler {
    /// Dualize `graph` and start all lanes from the all-zeros state
    /// (default kernel; see [`LanePdSampler::with_config`] to choose).
    pub fn new(graph: &FactorGraph, lanes: usize, seed: u64) -> Self {
        Self::with_config(
            graph,
            EngineConfig {
                lanes,
                seed,
                ..EngineConfig::default()
            },
        )
    }

    /// Dualize `graph` with explicit [`EngineConfig`] knobs.
    pub fn with_config(graph: &FactorGraph, cfg: EngineConfig) -> Self {
        Self::from_model_config(DualModel::from_graph(graph), cfg)
    }

    /// Fallible [`LanePdSampler::with_config`]: rejects unsupported
    /// policy × cardinality combinations instead of panicking.
    pub fn try_with_config(graph: &FactorGraph, cfg: EngineConfig) -> Result<Self, EngineError> {
        Self::try_from_model_config(DualModel::from_graph(graph), cfg)
    }

    /// Wrap an existing dual model (shared slot space with the graph).
    pub fn from_model(model: DualModel, lanes: usize, seed: u64) -> Self {
        Self::from_model_config(
            model,
            EngineConfig {
                lanes,
                seed,
                ..EngineConfig::default()
            },
        )
    }

    /// Wrap an existing dual model with explicit [`EngineConfig`] knobs.
    /// Panics on degenerate policy knobs — use
    /// [`LanePdSampler::try_from_model_config`] to get a typed error.
    pub fn from_model_config(model: DualModel, cfg: EngineConfig) -> Self {
        Self::try_from_model_config(model, cfg).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible [`LanePdSampler::from_model_config`]: every sweep policy
    /// hosts every cardinality `2 ≤ k ≤ 8`, but degenerate policy knobs
    /// (which would define a chain that is not a valid Gibbs kernel) are
    /// rejected here with [`EngineError::InvalidPolicy`] so serving
    /// paths can turn them into error replies instead of dead shards.
    pub fn try_from_model_config(
        mut model: DualModel,
        cfg: EngineConfig,
    ) -> Result<Self, EngineError> {
        assert!(cfg.lanes >= 1, "at least one lane");
        let k = model.k();
        if let Some(reason) = validate_policy(cfg.sweep) {
            return Err(EngineError::InvalidPolicy {
                policy: cfg.sweep,
                reason,
            });
        }
        model.set_minibatch(cfg.sweep.minibatch());
        // ⌈log₂ k⌉ x planes; one θ auxiliary per state for K > 2
        let x_planes = (usize::BITS - (k - 1).leading_zeros()) as usize;
        let t_planes = if k == 2 { 1 } else { k };
        let words = cfg.lanes.div_ceil(64);
        let x = vec![0u64; model.num_vars() * x_planes * words];
        let theta = vec![0u64; model.factor_slots() * t_planes * words];
        let clamped = vec![false; model.num_vars()];
        // agreement EWMAs start neutral; only blocked engines pay for them
        let edge_stats = if cfg.sweep.blocked().is_some() {
            vec![0.5; model.factor_slots()]
        } else {
            Vec::new()
        };
        Ok(Self {
            model,
            lanes: cfg.lanes,
            words,
            x_planes,
            t_planes,
            kernel: cfg.kernel,
            x,
            theta,
            clamped,
            clamp_count: 0,
            pool: None,
            base: Pcg64::seed(cfg.seed),
            sweep_count: 0,
            x_bounds: Vec::new(),
            theta_bounds: Vec::new(),
            chunk_plan_for: 0,
            policy: cfg.sweep,
            edge_stats,
            block_plan: None,
            plan_stale: false,
            unit_bounds: Vec::new(),
        })
    }

    /// Enable variable-parallel sweeps on the given pool. Does not change
    /// the sampled trajectory: streams are keyed per `(sweep, site)`.
    pub fn with_pool(mut self, pool: Arc<ThreadPool>) -> Self {
        self.pool = Some(pool);
        self.chunk_plan_for = 0;
        self
    }

    /// Switch the sweep-kernel implementation. Pure performance knob:
    /// the trajectory is unchanged (see [`super::kernels`]).
    pub fn with_kernel(mut self, kernel: KernelKind) -> Self {
        self.kernel = kernel;
        self
    }

    /// The kernel currently running the sweep bodies.
    pub fn kernel(&self) -> KernelKind {
        self.kernel
    }

    /// The sweep policy the engine was configured with.
    pub fn sweep_policy(&self) -> SweepPolicy {
        self.policy
    }

    /// The current block plan, if a blocked policy has built one (plans
    /// are built lazily on the first blocked sweep).
    pub fn block_plan(&self) -> Option<&BlockPlan> {
        self.block_plan.as_ref()
    }

    /// Block-plan summary for serving stats: `(blocks, blocked_vars,
    /// tree_slots)` of the current plan — all zeros before the first
    /// blocked sweep or under a non-blocked policy.
    pub fn block_summary(&self) -> (usize, usize, usize) {
        match &self.block_plan {
            Some(p) => (p.num_blocks(), p.blocked_vars(), p.tree_slots),
            None => (0, 0, 0),
        }
    }

    /// θ-slot refresh stride of the current policy (1 = every sweep).
    #[inline]
    fn theta_stride(&self) -> u64 {
        self.model
            .minibatch_policy()
            .map_or(1, |p| p.theta_stride.max(1) as u64)
    }

    /// The dualized model all lanes share.
    pub fn model(&self) -> &DualModel {
        &self.model
    }

    /// Number of chains.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Words of packed state per site (`lanes.div_ceil(64)`).
    pub fn words_per_site(&self) -> usize {
        self.words
    }

    /// Number of primal variables.
    pub fn num_vars(&self) -> usize {
        self.model.num_vars()
    }

    /// Total sweeps performed since construction.
    pub fn sweeps_done(&self) -> u64 {
        self.sweep_count
    }

    /// Accounting hook for the multi-tenant scheduler: the cost of one
    /// sweep of this engine in site-visits ([`DualModel::sweep_cost`],
    /// [`DualModel::minibatch_sweep_cost`] under a minibatch policy, or
    /// the base cost plus [`BLOCK_COST_SURCHARGE`] per marginalized tree
    /// slot under a blocked policy — DRR fairness then reflects both the
    /// cheaper hub visits and the pricier joint block draws). Tracks
    /// churn *and* re-planning: inserting/removing factors or a fresh
    /// block plan changes the next sweep's charge.
    #[inline]
    pub fn cost(&self) -> u64 {
        match self.policy {
            SweepPolicy::Minibatch(p) => {
                self.model.minibatch_sweep_cost(p.theta_stride.max(1))
            }
            SweepPolicy::Blocked(_) => {
                let tree = self.block_plan.as_ref().map_or(0, |p| p.tree_slots) as u64;
                self.model.sweep_cost() + BLOCK_COST_SURCHARGE * tree
            }
            SweepPolicy::Exact => self.model.sweep_cost(),
        }
    }

    /// States per variable (2 = binary).
    #[inline]
    pub fn k(&self) -> usize {
        self.model.k()
    }

    /// Bit-planes per x site (`⌈log₂ k⌉`; 1 for binary).
    #[inline]
    pub fn bit_planes(&self) -> usize {
        self.x_planes
    }

    /// θ bit-planes per factor slot (1 for binary, `k` for K-state).
    #[inline]
    pub fn theta_planes(&self) -> usize {
        self.t_planes
    }

    /// Words of one x site row (`bit_planes() · words_per_site()`).
    #[inline]
    fn row_words(&self) -> usize {
        self.x_planes * self.words
    }

    /// Words of one θ slot row (`theta_planes() · words_per_site()`).
    #[inline]
    fn t_row(&self) -> usize {
        self.t_planes * self.words
    }

    /// Packed primal state, `x[(v·bit_planes() + p) · words_per_site() + w]`.
    pub fn state_words(&self) -> &[u64] {
        &self.x
    }

    /// Packed dual state,
    /// `theta[(slot·theta_planes() + s) · words_per_site() + w]`.
    pub fn theta_words(&self) -> &[u64] {
        &self.theta
    }

    /// Chain `lane`'s plane-0 bit of variable `v` — the full value on
    /// binary models; see [`LanePdSampler::lane_value`] for K > 2.
    #[inline]
    pub fn lane_bit(&self, v: usize, lane: usize) -> u8 {
        ((self.x[v * self.row_words() + lane / 64] >> (lane % 64)) & 1) as u8
    }

    /// Chain `lane`'s value of variable `v`, folded over all bit-planes.
    #[inline]
    pub fn lane_value(&self, v: usize, lane: usize) -> u8 {
        let (w, bit) = (lane / 64, lane % 64);
        let mut s = 0u8;
        for p in 0..self.x_planes {
            let word = self.x[(v * self.x_planes + p) * self.words + w];
            s |= (((word >> bit) & 1) as u8) << p;
        }
        s
    }

    /// Number of lanes with `x_v = 1` (binary marginal accumulation —
    /// plane 0 popcount; on K > 2 models use
    /// [`LanePdSampler::popcount_state`]).
    #[inline]
    pub fn popcount_var(&self, v: usize) -> u32 {
        let row = v * self.row_words();
        self.x[row..row + self.words]
            .iter()
            .map(|w| w.count_ones())
            .sum()
    }

    /// Number of live lanes with `x_v = state` (K-state marginal
    /// accumulation): one AND-of-XNORs per word over the bit-planes.
    pub fn popcount_state(&self, v: usize, state: u8) -> u32 {
        debug_assert!((state as usize) < self.k());
        let mut total = 0u32;
        for w in 0..self.words {
            let kl = lanes_in_word(self.lanes, w);
            let mut eq = lane_mask(kl);
            for p in 0..self.x_planes {
                let xp = self.x[(v * self.x_planes + p) * self.words + w];
                eq &= if (state >> p) & 1 == 1 { xp } else { !xp };
            }
            total += eq.count_ones();
        }
        total
    }

    /// One chain's primal state, unpacked to bytes (state values, not
    /// bits, on K > 2 models).
    pub fn lane_state(&self, lane: usize) -> Vec<u8> {
        assert!(lane < self.lanes);
        (0..self.num_vars())
            .map(|v| self.lane_value(v, lane))
            .collect()
    }

    /// Overwrite one chain's primal state (chain initialization) with
    /// state values `< k`. Clamped sites keep their evidence value —
    /// [`LanePdSampler::clamp`] is the only way to move them.
    pub fn set_lane_state(&mut self, lane: usize, xs: &[u8]) {
        assert!(lane < self.lanes);
        assert_eq!(xs.len(), self.num_vars());
        let k = self.k();
        let (w, mask) = (lane / 64, 1u64 << (lane % 64));
        for (v, &s) in xs.iter().enumerate() {
            assert!((s as usize) < k, "state {s} out of range for k={k}");
            if self.clamped[v] {
                continue;
            }
            for p in 0..self.x_planes {
                let word = &mut self.x[(v * self.x_planes + p) * self.words + w];
                if (s >> p) & 1 == 1 {
                    *word |= mask;
                } else {
                    *word &= !mask;
                }
            }
        }
    }

    /// Set one chain's primal state to a constant (all-0 / all-1 start).
    pub fn fill_lane(&mut self, lane: usize, value: bool) {
        self.fill_lane_state(lane, value as u8);
    }

    /// Set one chain's primal state to a constant state value `< k`
    /// (overdispersed K-state starts). Clamped sites keep their evidence.
    pub fn fill_lane_state(&mut self, lane: usize, state: u8) {
        assert!(lane < self.lanes);
        assert!((state as usize) < self.k(), "state out of range");
        let (w, mask) = (lane / 64, 1u64 << (lane % 64));
        for v in 0..self.num_vars() {
            if self.clamped[v] {
                continue;
            }
            for p in 0..self.x_planes {
                let word = &mut self.x[(v * self.x_planes + p) * self.words + w];
                if (state >> p) & 1 == 1 {
                    *word |= mask;
                } else {
                    *word &= !mask;
                }
            }
        }
    }

    /// Randomize one chain's primal state from the lane-indexed init
    /// stream (`split2(0, lane)`; sweeps use sweep indices ≥ 1). Binary
    /// models keep the historical one-bit-per-site draw stream
    /// bit-for-bit; K > 2 models draw a state per site from the same
    /// stream. Clamped sites consume their draw but keep their evidence
    /// value, so clamping never shifts other sites' init draws.
    pub fn randomize_lane(&mut self, lane: usize) {
        assert!(lane < self.lanes);
        let k = self.k() as u64;
        let mut rng = self.base.split2(0, lane as u64);
        let (w, mask) = (lane / 64, 1u64 << (lane % 64));
        for v in 0..self.num_vars() {
            let s = if k == 2 {
                (rng.next_u64() & 1) as u8
            } else {
                (rng.next_u64() % k) as u8
            };
            if self.clamped[v] {
                continue;
            }
            for p in 0..self.x_planes {
                let word = &mut self.x[(v * self.x_planes + p) * self.words + w];
                if (s >> p) & 1 == 1 {
                    *word |= mask;
                } else {
                    *word &= !mask;
                }
            }
        }
    }

    /// Zero one chain's dual state (pairs with the init helpers above).
    pub fn clear_theta_lane(&mut self, lane: usize) {
        assert!(lane < self.lanes);
        let (w, mask) = (lane / 64, 1u64 << (lane % 64));
        for row in self.theta.chunks_exact_mut(self.words) {
            row[w] &= !mask;
        }
    }

    // -- evidence clamping -------------------------------------------------

    /// Clamp site `v` to `state` in every lane: the site's value is set
    /// now and its draw is skipped on every subsequent sweep, while the
    /// θ half-step keeps reading it — neighbors' conditionals see the
    /// evidence (module docs). Idempotent; re-clamping to a different
    /// state just moves the evidence. Composes with every sweep policy:
    /// minibatched plans are simply never consumed for a clamped site,
    /// and under a blocked policy the clamp is a semantic mutation —
    /// incident agreement EWMAs neutral-reset and the plan rebuilds
    /// eagerly on the next sweep (clamped sites leave the candidate set).
    pub fn clamp(&mut self, v: usize, state: u8) -> Result<(), EngineError> {
        let (n, k) = (self.num_vars(), self.k());
        if v >= n {
            return Err(EngineError::SiteOutOfRange { v, n });
        }
        if state as usize >= k {
            return Err(EngineError::ClampOutOfRange { v, n, state, k });
        }
        let moved = !self.clamped[v] || self.lane_value(v, 0) != state;
        // write the evidence into the live lanes of every plane (ghost
        // bits of the tail word stay zero)
        for p in 0..self.x_planes {
            for w in 0..self.words {
                let kl = lanes_in_word(self.lanes, w);
                self.x[(v * self.x_planes + p) * self.words + w] =
                    if (state >> p) & 1 == 1 { lane_mask(kl) } else { 0 };
            }
        }
        if !self.clamped[v] {
            self.clamped[v] = true;
            self.clamp_count += 1;
        }
        if moved {
            self.note_evidence_mutation(v);
        }
        Ok(())
    }

    /// Release a clamp; the site resumes sampling from its current
    /// (evidence) value on the next sweep. No-op if not clamped. Like
    /// [`LanePdSampler::clamp`], a release is a semantic mutation under
    /// a blocked policy: EWMAs reset and the plan rebuilds eagerly.
    pub fn unclamp(&mut self, v: usize) -> Result<(), EngineError> {
        let n = self.num_vars();
        if v >= n {
            return Err(EngineError::SiteOutOfRange { v, n });
        }
        if self.clamped[v] {
            self.clamped[v] = false;
            self.clamp_count -= 1;
            self.note_evidence_mutation(v);
        }
        Ok(())
    }

    /// Clamp/unclamp changed the conditional law around `v`. Under a
    /// blocked policy that invalidates everything the planner learned
    /// near the evidence: the incident slots' agreement EWMAs reflect
    /// the *old* law (a clamped endpoint drags agreement toward a
    /// constant), so they neutral-reset to 0.5, and the plan is marked
    /// stale so [`LanePdSampler::sweep`] rebuilds it eagerly *before*
    /// the next x half-step — a stale plan could otherwise joint-draw a
    /// freshly clamped site.
    fn note_evidence_mutation(&mut self, v: usize) {
        if self.policy.blocked().is_none() {
            return;
        }
        let (slots, _, overlay) = self.model.incidence_csr(v);
        let incident: Vec<u32> = slots
            .iter()
            .copied()
            .chain(overlay.iter().map(|&(s, _)| s))
            .collect();
        for slot in incident {
            if let Some(m) = self.edge_stats.get_mut(slot as usize) {
                *m = 0.5;
            }
        }
        self.plan_stale = true;
    }

    /// Whether site `v` is currently clamped.
    #[inline]
    pub fn is_clamped(&self, v: usize) -> bool {
        self.clamped.get(v).copied().unwrap_or(false)
    }

    /// Number of currently clamped sites.
    #[inline]
    pub fn clamped_count(&self) -> usize {
        self.clamp_count
    }

    // -- dynamic topology --------------------------------------------------

    /// Dynamic update for ALL lanes at once: one O(degree) model mutation,
    /// no recoloring, no per-chain work beyond zeroing the new θ word.
    pub fn add_factor(&mut self, id: FactorId, f: &PairFactor) {
        self.model.insert_at(id, f);
        let need = self.model.factor_slots() * self.t_row();
        if self.theta.len() < need {
            self.theta.resize(need, 0);
        }
        self.theta[id * self.t_row()..(id + 1) * self.t_row()].fill(0);
        self.chunk_plan_for = 0; // degrees changed: re-plan chunks lazily
        if self.policy.blocked().is_some() {
            // a new (or recycled) slot starts with no observed coupling
            self.edge_stats.resize(self.model.factor_slots(), 0.5);
            self.edge_stats[id] = 0.5;
            self.plan_stale = true; // churn: re-plan on the next sweep
        }
    }

    /// Dynamic update: unwire a factor for all lanes. O(degree).
    ///
    /// Returns whether the slot was live — a dead/unknown `id` is a no-op
    /// reporting `false`, exactly mirroring [`DualModel::remove`]; for a
    /// live slot the θ words are always zeroed (the θ state can never be
    /// shorter than the model's slot space, asserted here rather than
    /// silently skipped).
    pub fn remove_factor(&mut self, id: FactorId) -> bool {
        if self.model.remove(id).is_none() {
            return false;
        }
        assert!(
            (id + 1) * self.t_row() <= self.theta.len(),
            "theta state shorter than the model's slot space (slot {id})"
        );
        self.theta[id * self.t_row()..(id + 1) * self.t_row()].fill(0);
        self.chunk_plan_for = 0; // degrees changed: re-plan chunks lazily
        if self.policy.blocked().is_some() {
            if let Some(m) = self.edge_stats.get_mut(id) {
                *m = 0.5; // a recycled slot must not inherit the stat
            }
            self.plan_stale = true; // churn: re-plan on the next sweep
        }
        true
    }

    // -- sampling ----------------------------------------------------------

    /// One full sweep of every lane: x half-step, then θ half-step. The
    /// trajectory depends only on the seed and the sweep index — not on
    /// whether/how a pool is attached, nor on the selected kernel.
    /// Under a blocked policy the sweep additionally (re)builds the
    /// block plan when due and folds the post-sweep state into the
    /// agreement EWMAs — both deterministic functions of the trajectory,
    /// so the kernel/pool invariance extends to the plan itself.
    pub fn sweep(&mut self) {
        self.sweep_count += 1;
        if let SweepPolicy::Blocked(p) = self.policy {
            self.ensure_block_plan(p);
        }
        match self.kernel {
            KernelKind::Scalar => self.sweep_kernel::<ScalarKernel>(),
            KernelKind::Tiled => self.sweep_kernel::<TiledKernel>(),
            #[cfg(feature = "nightly-simd")]
            KernelKind::Simd => self.sweep_kernel::<SimdKernel>(),
        }
        if self.policy.blocked().is_some() {
            self.update_edge_stats();
        }
    }

    /// Lazy re-planning, the `CsrIncidence` epoch idiom: rebuild when
    /// there is no plan yet, when churn marked the plan stale, or on the
    /// fixed epoch phase (`(sweep − 1) % epoch == 0` — a pure function
    /// of the sweep index, so every kernel/pool/shard replica re-plans
    /// on the same sweep from the same EWMAs and stays bit-identical).
    fn ensure_block_plan(&mut self, p: BlockPolicy) {
        let epoch = p.epoch.max(1) as u64;
        let due = (self.sweep_count - 1) % epoch == 0;
        if self.block_plan.is_some() && !self.plan_stale && !due {
            return;
        }
        self.edge_stats.resize(self.model.factor_slots(), 0.5);
        let plan = BlockPlanner::plan(&self.model, &self.edge_stats, p, &self.clamped);
        if self.block_plan.as_ref() != Some(&plan) {
            self.chunk_plan_for = 0; // unit weights changed: re-chunk
        }
        self.block_plan = Some(plan);
        self.plan_stale = false;
    }

    /// Fold the post-sweep state into the per-slot agreement EWMAs:
    /// `m += γ(a − m)` with `a` = fraction of live lanes where the
    /// slot's endpoints agree *in state* — an AND over the `⌈log₂k⌉`
    /// bit-planes of per-plane XNOR words, popcounted. At `k = 2` the
    /// single plane makes this arithmetic-identical to the historical
    /// binary XNOR, so binary blocked trajectories are unchanged.
    /// O(live slots × words × planes) — far below the sweep's own
    /// incidence traversal.
    fn update_edge_stats(&mut self) {
        /// EWMA gain: ~16-sweep memory, matching the default re-plan
        /// epoch so one epoch of observations dominates the stat.
        const GAMMA: f64 = 0.0625;
        let lanes = self.lanes as f64;
        self.edge_stats.resize(self.model.factor_slots(), 0.5);
        for slot in 0..self.model.factor_slots() {
            let Some((v1, v2)) = self.model.slot_endpoints(slot) else {
                continue; // dead slot: stat stays at its reset value
            };
            let (v1, v2) = (v1 as usize, v2 as usize);
            let mut agree = 0u32;
            for w in 0..self.words {
                let k = lanes_in_word(self.lanes, w);
                let mut eq = lane_mask(k);
                for p in 0..self.x_planes {
                    let x1 = self.x[(v1 * self.x_planes + p) * self.words + w];
                    let x2 = self.x[(v2 * self.x_planes + p) * self.words + w];
                    eq &= !(x1 ^ x2);
                }
                agree += eq.count_ones();
            }
            let m = &mut self.edge_stats[slot];
            *m += GAMMA * (agree as f64 / lanes - *m);
        }
    }

    fn sweep_kernel<K: LaneKernel>(&mut self) {
        match self.pool.clone() {
            Some(pool) => self.sweep_pooled::<K>(&pool),
            None => self.sweep_serial::<K>(),
        }
    }

    fn sweep_serial<K: LaneKernel>(&mut self) {
        let words = self.words;
        let (rw, tr) = (self.row_words(), self.t_row());
        let n = self.model.num_vars();
        // one set of tile-major buffers reused across every site
        let mut buf = SweepBuf::new();
        {
            let ctx = XCtx {
                model: &self.model,
                theta: &self.theta,
                words,
                lanes: self.lanes,
                base: &self.base,
                sweep: self.sweep_count,
                x_planes: self.x_planes,
                t_planes: self.t_planes,
                clamped: &self.clamped,
            };
            match &self.block_plan {
                Some(plan) if self.policy.blocked().is_some() => {
                    let mut scratch = BlockScratch::default();
                    for unit in &plan.units {
                        match *unit {
                            SweepUnit::Var(v) => {
                                let v = v as usize;
                                ctx.dispatch::<K>(v, &mut self.x[v * rw..(v + 1) * rw], &mut buf);
                            }
                            // SAFETY: serial sweep — exclusive access to
                            // the whole x array.
                            SweepUnit::Block(b) => unsafe {
                                ctx.block_site(
                                    &plan.blocks[b as usize],
                                    self.x.as_mut_ptr(),
                                    &mut scratch,
                                );
                            },
                        }
                    }
                }
                _ => {
                    for v in 0..n {
                        ctx.dispatch::<K>(v, &mut self.x[v * rw..(v + 1) * rw], &mut buf);
                    }
                }
            }
        }
        let slots = self.model.factor_slots();
        let (stride, phase) = self.theta_window();
        {
            let ctx = ThetaCtx {
                model: &self.model,
                x: &self.x,
                words,
                lanes: self.lanes,
                base: &self.base,
                sweep: self.sweep_count,
                x_planes: self.x_planes,
                t_planes: self.t_planes,
            };
            for slot in 0..slots {
                if slot % stride != phase {
                    continue; // out-of-window slot: θ keeps its state
                }
                ctx.dispatch::<K>(slot, &mut self.theta[slot * tr..(slot + 1) * tr], &mut buf);
            }
        }
    }

    /// This sweep's θ refresh window: slot `s` is resampled iff
    /// `s % stride == phase`. A pure function of `(sweep, slot)`, so the
    /// trajectory stays pool- and kernel-invariant; skipped live slots
    /// keep their state and consume no RNG (their streams are keyed per
    /// sweep, not consumed incrementally), and skipped dead slots are
    /// already zero because `remove_factor` zeroes the row eagerly.
    #[inline]
    fn theta_window(&self) -> (usize, usize) {
        let stride = self.theta_stride();
        (stride as usize, (self.sweep_count % stride) as usize)
    }

    /// Alignment unit of pooled chunk bounds, in sites: the smallest
    /// site count whose packed rows span a whole number of 64-byte cache
    /// lines (`8 / gcd(words, 8)` — e.g. 8 sites at 1 word/site, 8 sites
    /// at 3 words/site, 2 at 4, 1 at 8). Seams on this grid start a new
    /// line *relative to the state base*, so adjacent workers only ever
    /// false-share when the allocation itself straddles line boundaries
    /// (a `Vec<u64>` base is 8/16-byte aligned, so at most one straddled
    /// line per seam remains — versus every seam row without alignment).
    #[inline]
    fn row_align(&self, row_words: usize) -> usize {
        fn gcd(a: usize, b: usize) -> usize {
            if b == 0 {
                a
            } else {
                gcd(b, a % b)
            }
        }
        // u64 state words and f64 lanes are both 8 bytes, so "u64s per
        // cache line" is the same shared constant as the tile width
        const WORDS_PER_LINE: usize = crate::util::aligned::F64S_PER_CACHE_LINE;
        WORDS_PER_LINE / gcd(row_words, WORDS_PER_LINE)
    }

    /// Rebuild the degree-aware chunk plan for a pool of `chunks` workers:
    /// x chunks balance [`DualModel::x_visit_weight`] (`1 + degree(v)`,
    /// with minibatched hubs discounted to their expected batch size),
    /// θ chunks weight live slots over dead ones (a dead slot is a plain
    /// memset of its lane row; out-of-window slots under a θ stride are
    /// skipped uniformly, so relative balance is unchanged). Bounds are
    /// rounded to cache-line-aligned state rows
    /// ([`LanePdSampler::row_align`]).
    fn rebuild_chunk_plan(&mut self, chunks: usize) {
        let n = self.model.num_vars();
        let mut prefix = Vec::with_capacity(n + 1);
        prefix.push(0u64);
        let mut acc = 0u64;
        for v in 0..n {
            acc += self.model.x_visit_weight(v);
            prefix.push(acc);
        }
        self.x_bounds = balanced_ranges_aligned(&prefix, chunks, self.row_align(self.row_words()));

        let slots = self.model.factor_slots();
        let mut tprefix = Vec::with_capacity(slots + 1);
        tprefix.push(0u64);
        let mut tacc = 0u64;
        for slot in 0..slots {
            tacc += if self.model.slot_endpoints(slot).is_some() {
                8
            } else {
                1
            };
            tprefix.push(tacc);
        }
        self.theta_bounds = balanced_ranges_aligned(&tprefix, chunks, self.row_align(self.t_row()));

        // blocked policy: chunk the x half-step over the plan's sweep
        // units instead (units partition the variables, so unit chunks
        // own disjoint x rows); a block unit weighs its members plus the
        // FFBS surcharge per tree slot. Unit rows are scattered, so
        // cache-line alignment buys nothing — align 1.
        if let Some(plan) = &self.block_plan {
            if self.policy.blocked().is_some() {
                let mut uprefix = Vec::with_capacity(plan.units.len() + 1);
                uprefix.push(0u64);
                let mut uacc = 0u64;
                for unit in &plan.units {
                    uacc += match *unit {
                        SweepUnit::Var(v) => self.model.x_visit_weight(v as usize),
                        SweepUnit::Block(b) => {
                            let blk = &plan.blocks[b as usize];
                            blk.nodes
                                .iter()
                                .map(|nd| self.model.x_visit_weight(nd.v as usize))
                                .sum::<u64>()
                                + BLOCK_COST_SURCHARGE * blk.tree_slots.len() as u64
                        }
                    };
                    uprefix.push(uacc);
                }
                self.unit_bounds = balanced_ranges_aligned(&uprefix, chunks, 1);
            }
        }
        self.chunk_plan_for = chunks;
    }

    fn sweep_pooled<K: LaneKernel>(&mut self, pool: &ThreadPool) {
        if self.chunk_plan_for != pool.size() {
            self.rebuild_chunk_plan(pool.size());
        }
        let words = self.words;
        let (rw, tr) = (self.row_words(), self.t_row());
        // x | θ : chunks over variables write x, read frozen θ
        {
            let ctx = XCtx {
                model: &self.model,
                theta: &self.theta,
                words,
                lanes: self.lanes,
                base: &self.base,
                sweep: self.sweep_count,
                x_planes: self.x_planes,
                t_planes: self.t_planes,
                clamped: &self.clamped,
            };
            let x_ptr = SendPtr(self.x.as_mut_ptr());
            match &self.block_plan {
                Some(plan) if self.policy.blocked().is_some() => {
                    pool.scope_ranges(&self.unit_bounds, |_, start, end| {
                        let x_ptr = &x_ptr;
                        let mut buf = SweepBuf::new();
                        let mut scratch = BlockScratch::default();
                        for unit in &plan.units[start..end] {
                            match *unit {
                                SweepUnit::Var(v) => {
                                    let v = v as usize;
                                    // SAFETY: units partition the
                                    // variables and chunks own disjoint
                                    // unit ranges, hence disjoint x rows.
                                    let out = unsafe {
                                        std::slice::from_raw_parts_mut(x_ptr.0.add(v * rw), rw)
                                    };
                                    ctx.dispatch::<K>(v, out, &mut buf);
                                }
                                // SAFETY: as above — every variable of
                                // this block belongs to this unit alone.
                                SweepUnit::Block(b) => unsafe {
                                    ctx.block_site(
                                        &plan.blocks[b as usize],
                                        x_ptr.0,
                                        &mut scratch,
                                    );
                                },
                            }
                        }
                    });
                }
                _ => {
                    pool.scope_ranges(&self.x_bounds, |_, start, end| {
                        let x_ptr = &x_ptr;
                        // per-worker tile-major buffers, reused across
                        // the chunk
                        let mut buf = SweepBuf::new();
                        for v in start..end {
                            // SAFETY: chunks own disjoint variable
                            // ranges, hence disjoint row-sized word
                            // rows of x.
                            let out = unsafe {
                                std::slice::from_raw_parts_mut(x_ptr.0.add(v * rw), rw)
                            };
                            ctx.dispatch::<K>(v, out, &mut buf);
                        }
                    });
                }
            }
        }
        // θ | x : chunks over factor slots write θ, read the fresh x
        {
            let ctx = ThetaCtx {
                model: &self.model,
                x: &self.x,
                words,
                lanes: self.lanes,
                base: &self.base,
                sweep: self.sweep_count,
                x_planes: self.x_planes,
                t_planes: self.t_planes,
            };
            let (stride, phase) = self.theta_window();
            let t_ptr = SendPtr(self.theta.as_mut_ptr());
            pool.scope_ranges(&self.theta_bounds, |_, start, end| {
                let t_ptr = &t_ptr;
                let mut buf = SweepBuf::new();
                for slot in start..end {
                    if slot % stride != phase {
                        continue; // out-of-window slot: θ keeps its state
                    }
                    // SAFETY: chunks own disjoint slot ranges.
                    let out = unsafe {
                        std::slice::from_raw_parts_mut(t_ptr.0.add(slot * tr), tr)
                    };
                    ctx.dispatch::<K>(slot, out, &mut buf);
                }
            });
        }
    }
}

/// Shared read-only context of the x half-step.
struct XCtx<'a> {
    model: &'a DualModel,
    theta: &'a [u64],
    words: usize,
    lanes: usize,
    base: &'a Pcg64,
    sweep: u64,
    /// Bit-planes per x site row (`out` spans `x_planes · words`).
    x_planes: usize,
    /// θ bit-planes per slot row.
    t_planes: usize,
    /// Evidence mask: clamped sites skip their draw entirely.
    clamped: &'a [bool],
}

impl XCtx<'_> {
    /// Route one site: clamped sites skip their draw (their keyed RNG
    /// stream is never consumed, so every other site's draws are
    /// untouched — clamp invariance across kernels/pools/shards is
    /// structural), binary sites take the historical paths, K > 2 sites
    /// the categorical bit-plane body.
    fn dispatch<K: LaneKernel>(&self, v: usize, out: &mut [u64], buf: &mut SweepBuf) {
        if self.clamped[v] {
            return;
        }
        if self.x_planes == 1 {
            self.site::<K>(v, out, buf);
        } else {
            self.site_k::<K>(v, out, buf);
        }
    }

    /// Resample the K-state `x_v` in every lane. Per word: accumulate
    /// `score(s) += β · θ_{i,s}`-words over the flat incidence view with
    /// the same kernel primitive as the binary accumulate path, then one
    /// shared categorical draw
    /// ([`super::kernels::draw_categorical_planes`]) writes the winner's
    /// bit-planes. RNG: the site's `split2(sweep, v·2)` stream consumes
    /// exactly `lanes_in_word` uniforms per word, in word order — the
    /// same stream discipline as the binary paths, so trajectories stay
    /// kernel-, pool-, and shard-invariant.
    fn site_k<K: LaneKernel>(&self, v: usize, out: &mut [u64], buf: &mut SweepBuf) {
        let k_states = self.model.k();
        let mut rng = self.base.split2(self.sweep, (v as u64) << 1);
        if let Some(plan) = self.model.mb_plan(v) {
            return self.site_minibatch_k(plan, v, out, buf, &mut rng);
        }
        let (slots, betas, overlay) = self.model.incidence_csr(v);
        if buf.cat.len() < k_states {
            buf.cat.resize_with(k_states, F64Lanes::default);
        }
        let SweepBuf { cat, draw, .. } = buf;
        let cat = &mut cat[..k_states];
        let mut planes_out = [0u64; crate::graph::MAX_STATES];
        for w in 0..self.words {
            let kl = lanes_in_word(self.lanes, w);
            for sc in cat.iter_mut() {
                sc.0.fill(0.0);
            }
            for (&slot, &beta) in slots
                .iter()
                .zip(betas.iter())
                .chain(overlay.iter().map(|(s, b)| (s, b)))
            {
                let row = slot as usize * self.t_planes * self.words;
                for (s, sc) in cat.iter_mut().enumerate() {
                    let tw = self.theta[row + s * self.words + w];
                    K::accumulate(sc, tw, beta);
                }
            }
            draw_categorical_planes(&mut rng, cat, kl, draw, &mut planes_out[..self.x_planes]);
            for (p, &word) in planes_out[..self.x_planes].iter().enumerate() {
                out[p * self.words + w] = word;
            }
        }
    }

    /// Resample `x_v` in every lane: one flat incidence traversal total,
    /// kernel bodies from `K`.
    fn site<K: LaneKernel>(&self, v: usize, out: &mut [u64], buf: &mut SweepBuf) {
        // even site codes are x-variables, odd are θ-slots
        let mut rng = self.base.split2(self.sweep, (v as u64) << 1);
        if let Some(plan) = self.model.mb_plan(v) {
            return self.site_minibatch::<K>(plan, v, out, buf, &mut rng);
        }
        let (slots, betas, overlay) = self.model.incidence_csr(v);
        match self.model.x_table(v) {
            Some((mult, thresh)) => {
                // cached-table path: gather each lane's θ-bit pattern and
                // draw from the precomputed acceptance parts — the draws
                // are bit-identical to the accumulate path below
                for (w, out_word) in out.iter_mut().enumerate() {
                    let k = lanes_in_word(self.lanes, w);
                    buf.idx.0.fill(0);
                    let mut bit = 0u32;
                    for &slot in slots {
                        let tw = self.theta[slot as usize * self.words + w];
                        K::gather(&mut buf.idx, tw, bit);
                        bit += 1;
                    }
                    for &(slot, _) in overlay {
                        let tw = self.theta[slot as usize * self.words + w];
                        K::gather(&mut buf.idx, tw, bit);
                        bit += 1;
                    }
                    *out_word =
                        K::draw_table_word(&mut rng, mult, thresh, &buf.idx, k, &mut buf.draw);
                }
            }
            None => {
                // high-degree fallback: per-lane log-odds accumulate over
                // the same flat view (tail lanes masked only at the draw)
                let field = self.model.base_field(v);
                for (w, out_word) in out.iter_mut().enumerate() {
                    let k = lanes_in_word(self.lanes, w);
                    buf.acc.0.fill(field);
                    for (&slot, &beta) in slots.iter().zip(betas.iter()) {
                        let tw = self.theta[slot as usize * self.words + w];
                        K::accumulate(&mut buf.acc, tw, beta);
                    }
                    for &(slot, beta) in overlay {
                        let tw = self.theta[slot as usize * self.words + w];
                        K::accumulate(&mut buf.acc, tw, beta);
                    }
                    *out_word = K::draw_logodds_word(&mut rng, &buf.acc, k, &mut buf.draw);
                }
            }
        }
    }

    /// Minibatched resample of `x_v`: the MIN-Gibbs correction over a
    /// Poisson number of alias-sampled factor events instead of a full
    /// incidence fold. Exact stationarity comes from the Poisson
    /// auxiliary augmentation: per lane, `N ~ Poisson(λ + L)` events each
    /// pick entry `j ∝ |β_j|` and are thinned with acceptance
    /// `κ + (1 − κ)·t_j`, where `t_j ∈ {0, 1}` is the entry's energy bit
    /// under the *pre-update* value of `x_v` (`t_j = θ_j ∧ x_v` for
    /// `β_j > 0`, complemented for `β_j < 0`). Each kept event with
    /// `θ_j = 1` shifts the log-odds by `sign(β_j)·c`,
    /// `c = ln(1 + L/λ)`, and the final draw reuses the kernel's
    /// log-odds word draw — so the correction composes with every
    /// kernel unchanged.
    ///
    /// The RNG consumption (events, thinning uniforms, word draw) is
    /// kernel-independent, preserving cross-kernel bit-identity, and the
    /// per-`(sweep, site)` stream keying preserves pool-invariance.
    fn site_minibatch<K: LaneKernel>(
        &self,
        plan: &MbPlan,
        v: usize,
        out: &mut [u64],
        buf: &mut SweepBuf,
        rng: &mut Pcg64,
    ) {
        let field = self.model.base_field(v);
        let (rate, kappa, c) = (plan.rate(), plan.kappa(), plan.c());
        for (w, out_word) in out.iter_mut().enumerate() {
            let k = lanes_in_word(self.lanes, w);
            let old = *out_word; // pre-update x_v bits of this word
            buf.acc.0.fill(field);
            for l in 0..k {
                let b_old = (old >> l) & 1;
                let events = rng.poisson(rate);
                let mut net = 0i64;
                for _ in 0..events {
                    let (slot, neg) = plan.pick(rng);
                    let tb = (self.theta[slot as usize * self.words + w] >> l) & 1;
                    let t = if neg { 1 - (tb & b_old) } else { tb & b_old };
                    // the uniform is consumed only when the deterministic
                    // bit test fails — t = 1 always keeps the event
                    if (t == 1 || rng.next_f64() < kappa) && tb == 1 {
                        net += if neg { -1 } else { 1 };
                    }
                }
                buf.acc.0[l] += c * net as f64;
            }
            *out_word = K::draw_logodds_word(rng, &buf.acc, k, &mut buf.draw);
        }
    }

    /// Minibatched resample of a K-state `x_v`: the binary Poisson /
    /// MIN-Gibbs correction run once per state plane. Per lane, each
    /// state `s` runs its own thinning pass against the pre-update
    /// indicator `1[x_v = s]` (the per-`(factor, state)` auxiliary
    /// counts factorize across states, so the passes are independent),
    /// with entry `j`'s energy bit read from θ's state-`s` plane:
    /// `t_{j,s} = θ_{j,s} ∧ 1[x_v = s]`, complemented for `β_j < 0`.
    /// Each kept event with the θ-bit set shifts `score(s)` by
    /// `sign(β_j)·c`, and the corrected scores finish through the same
    /// categorical bit-plane draw as the exact K-state path. At `k = 2`
    /// the engine stays on [`Self::site_minibatch`] (one plane, base
    /// field folded in), so binary trajectories are untouched.
    ///
    /// RNG order: per word, per lane, state planes in ascending order —
    /// events, picks, and thinning uniforms for plane `s` before plane
    /// `s + 1` — then the word's categorical draw consumes exactly
    /// `lanes_in_word` uniforms. All of it is kernel-independent scalar
    /// code, preserving cross-kernel bit-identity.
    fn site_minibatch_k(
        &self,
        plan: &MbPlan,
        v: usize,
        out: &mut [u64],
        buf: &mut SweepBuf,
        rng: &mut Pcg64,
    ) {
        let _ = v; // K > 2 sites have no base field to look up
        let k_states = self.model.k();
        let (rate, kappa, c) = (plan.rate(), plan.kappa(), plan.c());
        if buf.cat.len() < k_states {
            buf.cat.resize_with(k_states, F64Lanes::default);
        }
        let SweepBuf { cat, draw, .. } = buf;
        let cat = &mut cat[..k_states];
        let mut planes_out = [0u64; crate::graph::MAX_STATES];
        for w in 0..self.words {
            let kl = lanes_in_word(self.lanes, w);
            for sc in cat.iter_mut() {
                sc.0.fill(0.0);
            }
            for l in 0..kl {
                // pre-update state of this lane from the packed planes
                let mut s_old = 0usize;
                for p in 0..self.x_planes {
                    s_old |= (((out[p * self.words + w] >> l) & 1) as usize) << p;
                }
                for (s, sc) in cat.iter_mut().enumerate() {
                    let z_old = (s_old == s) as u64;
                    let events = rng.poisson(rate);
                    let mut net = 0i64;
                    for _ in 0..events {
                        let (slot, neg) = plan.pick(rng);
                        let row = (slot as usize * self.t_planes + s) * self.words;
                        let tb = (self.theta[row + w] >> l) & 1;
                        let t = if neg { 1 - (tb & z_old) } else { tb & z_old };
                        // uniform consumed only when the bit test fails
                        if (t == 1 || rng.next_f64() < kappa) && tb == 1 {
                            net += if neg { -1 } else { 1 };
                        }
                    }
                    sc.0[l] += c * net as f64;
                }
            }
            draw_categorical_planes(rng, cat, kl, draw, &mut planes_out[..self.x_planes]);
            for (p, &word) in planes_out[..self.x_planes].iter().enumerate() {
                out[p * self.words + w] = word;
            }
        }
    }

    /// Joint draw of one tree block: per lane, forward-filter /
    /// backward-sample over the block's spanning tree with the tree
    /// duals marginalized out (softplus edge potentials — see
    /// [`crate::duality::blocking`]). Cross-block and non-tree factors
    /// enter through each node's dual field exactly as in the flat
    /// x half-step, so blocks never coordinate within the half-step.
    ///
    /// Kernel-independence for free: the pass is plain per-lane scalar
    /// code using no kernel primitive (the `site_minibatch` precedent),
    /// and its RNG is one stream keyed by the block's ROOT variable
    /// (`split2(sweep, root << 1)`) consumed in a fixed order — root
    /// draw then BFS-order conditionals, lanes consecutively. Block
    /// members are exactly the variables the singleton path skips, so
    /// no stream is ever consumed twice in a sweep.
    ///
    /// # Safety
    ///
    /// `x` must point at the full packed x array, and the caller must
    /// have exclusive access to every block member's `words`-sized row
    /// (units partition the variables; see the sweep paths).
    unsafe fn block_site(&self, block: &Block, x: *mut u64, scratch: &mut BlockScratch) {
        if self.x_planes == 1 {
            self.block_site_bin(block, x, scratch);
        } else {
            self.block_site_k(block, x, scratch);
        }
    }

    /// Binary body of [`Self::block_site`]: two-state FFBS over the
    /// orientation-sensitive four-entry edge tables.
    ///
    /// # Safety
    ///
    /// Same contract as [`Self::block_site`].
    unsafe fn block_site_bin(&self, block: &Block, x: *mut u64, scratch: &mut BlockScratch) {
        let nn = block.nodes.len();
        let mut rng = self.base.split2(self.sweep, (block.root() as u64) << 1);
        // lane-independent per-edge tables, once per block per sweep
        scratch.etab.clear();
        for node in &block.nodes[1..] {
            scratch.etab.push(blocking::edge_table(self.model, node.slot, node.v));
        }
        scratch.local.resize(nn, [0.0; 2]);
        scratch.bits.resize(nn, 0);
        for lane in 0..self.lanes {
            let (w, bit) = (lane / 64, lane % 64);
            // leaves→root: local[i][b] = b_i·b + Σ_children msg, where
            // msg[pb] = logaddexp over the child's two states through
            // the marginalized edge table t[xc·2 + xp]
            for i in 0..nn {
                scratch.local[i] = [0.0, self.dual_field(block, block.nodes[i].v, w, bit)];
            }
            for i in (1..nn).rev() {
                let t = &scratch.etab[i - 1];
                let li = scratch.local[i];
                let msg0 = logaddexp(li[0] + t[0], li[1] + t[2]);
                let msg1 = logaddexp(li[0] + t[1], li[1] + t[3]);
                let p = block.nodes[i].parent as usize;
                scratch.local[p][0] += msg0;
                scratch.local[p][1] += msg1;
            }
            // root→leaves: exact conditional draws down the tree
            scratch.bits[0] =
                bernoulli_sigmoid(&mut rng, scratch.local[0][1] - scratch.local[0][0]) as u8;
            for i in 1..nn {
                let pb = scratch.bits[block.nodes[i].parent as usize] as usize;
                let t = &scratch.etab[i - 1];
                let z = (scratch.local[i][1] - scratch.local[i][0]) + (t[2 + pb] - t[pb]);
                scratch.bits[i] = bernoulli_sigmoid(&mut rng, z) as u8;
            }
            let mask = 1u64 << bit;
            for (i, node) in block.nodes.iter().enumerate() {
                // caller guarantees exclusive access to this row;
                // `lane < lanes` keeps ghost bits of the tail word zero
                let word = &mut *x.add(node.v as usize * self.words + w);
                if scratch.bits[i] == 1 {
                    *word |= mask;
                } else {
                    *word &= !mask;
                }
            }
        }
    }

    /// One lane's dual field at `v` with the block's tree slots skipped:
    /// `base_field(v) + Σ_{incident live slots ∉ tree} θ_bit·β` — the
    /// same fold as the flat accumulate path, restricted to one lane.
    fn dual_field(&self, block: &Block, v: u32, w: usize, bit: usize) -> f64 {
        let mut b = self.model.base_field(v as usize);
        let (slots, betas, overlay) = self.model.incidence_csr(v as usize);
        for (&slot, &beta) in slots.iter().zip(betas.iter()) {
            if !block.is_tree_slot(slot)
                && (self.theta[slot as usize * self.words + w] >> bit) & 1 == 1
            {
                b += beta;
            }
        }
        for &(slot, beta) in overlay {
            if !block.is_tree_slot(slot)
                && (self.theta[slot as usize * self.words + w] >> bit) & 1 == 1
            {
                b += beta;
            }
        }
        b
    }

    /// K-state body of [`Self::block_site`]: FFBS with k-vector upward
    /// messages. The marginalized K-state tree-edge potential is Potts
    /// by symmetry — it takes one value when child and parent states
    /// agree and one when they differ (see
    /// [`crate::duality::blocking::edge_table_k`]) — so upward messages
    /// fold each child's k local scores through a two-value table:
    /// `msg[ps] = logsumexp_cs(local[cs] + E(cs, ps))`. Root and
    /// downward draws use the scalar categorical draw
    /// ([`draw_cat_scalar`], the per-lane mirror of the exact path's
    /// plane draw), consuming exactly one uniform per node per lane —
    /// the same stream count as the binary body, keyed by the block's
    /// root. Non-tree factors enter through the per-state dual field
    /// ([`Self::dual_field_k`]).
    ///
    /// # Safety
    ///
    /// Same contract as [`Self::block_site`]: exclusive access to every
    /// block member's `x_planes · words` row.
    unsafe fn block_site_k(&self, block: &Block, x: *mut u64, scratch: &mut BlockScratch) {
        let nn = block.nodes.len();
        let k = self.model.k();
        let mut rng = self.base.split2(self.sweep, (block.root() as u64) << 1);
        // lane-independent per-edge (E_eq, E_ne) tables, once per sweep
        scratch.etab_k.clear();
        for node in &block.nodes[1..] {
            scratch
                .etab_k
                .push(blocking::edge_table_k(self.model, node.slot, k));
        }
        scratch.local_k.resize(nn * k, 0.0);
        scratch.states.resize(nn, 0);
        let mut scores = [0.0f64; crate::graph::MAX_STATES];
        for lane in 0..self.lanes {
            let (w, bit) = (lane / 64, lane % 64);
            for (i, node) in block.nodes.iter().enumerate() {
                self.dual_field_k(block, node.v, w, bit, &mut scratch.local_k[i * k..(i + 1) * k]);
            }
            // leaves→root: msg[ps] = logsumexp_cs(local[cs] + E(cs, ps))
            for i in (1..nn).rev() {
                let (eq, ne) = scratch.etab_k[i - 1];
                for ps in 0..k {
                    let mut m = f64::NEG_INFINITY;
                    for cs in 0..k {
                        let e = if cs == ps { eq } else { ne };
                        m = logaddexp(m, scratch.local_k[i * k + cs] + e);
                    }
                    scores[ps] = m;
                }
                let p = block.nodes[i].parent as usize;
                for (ps, &m) in scores[..k].iter().enumerate() {
                    scratch.local_k[p * k + ps] += m;
                }
            }
            // root→leaves: exact conditional categorical draws
            scores[..k].copy_from_slice(&scratch.local_k[..k]);
            scratch.states[0] = draw_cat_scalar(&mut rng, &scores[..k]);
            for i in 1..nn {
                let ps = scratch.states[block.nodes[i].parent as usize] as usize;
                let (eq, ne) = scratch.etab_k[i - 1];
                for (cs, sc) in scores[..k].iter_mut().enumerate() {
                    *sc = scratch.local_k[i * k + cs] + if cs == ps { eq } else { ne };
                }
                scratch.states[i] = draw_cat_scalar(&mut rng, &scores[..k]);
            }
            let mask = 1u64 << bit;
            for (i, node) in block.nodes.iter().enumerate() {
                let s = scratch.states[i] as usize;
                for p in 0..self.x_planes {
                    // caller guarantees exclusive access to this row
                    let word =
                        &mut *x.add((node.v as usize * self.x_planes + p) * self.words + w);
                    if (s >> p) & 1 == 1 {
                        *word |= mask;
                    } else {
                        *word &= !mask;
                    }
                }
            }
        }
    }

    /// One lane's per-state dual scores at a K-state `v` with the
    /// block's tree slots skipped: `score[s] = Σ_{incident ∉ tree}
    /// θ_{slot,s}·β`, the [`Self::site_k`] fold restricted to one lane
    /// (K > 2 sites have no base field).
    fn dual_field_k(&self, block: &Block, v: u32, w: usize, bit: usize, scores: &mut [f64]) {
        scores.fill(0.0);
        let (slots, betas, overlay) = self.model.incidence_csr(v as usize);
        for (&slot, &beta) in slots
            .iter()
            .zip(betas.iter())
            .chain(overlay.iter().map(|(s, b)| (s, b)))
        {
            if block.is_tree_slot(slot) {
                continue;
            }
            let row = slot as usize * self.t_planes * self.words;
            for (s, sc) in scores.iter_mut().enumerate() {
                if (self.theta[row + s * self.words + w] >> bit) & 1 == 1 {
                    *sc += beta;
                }
            }
        }
    }
}

/// One categorical draw from unnormalized log-scores, consuming exactly
/// one uniform — the scalar mirror of
/// [`super::kernels::draw_categorical_planes`]'s per-lane body
/// (max-subtract, exp, inverse-CDF scan, last state on fp underflow),
/// used by the blocked K-state tree draws.
fn draw_cat_scalar(rng: &mut Pcg64, scores: &[f64]) -> u8 {
    let mut zmax = scores[0];
    for &z in &scores[1..] {
        zmax = zmax.max(z);
    }
    let mut total = 0.0;
    let mut weights = [0.0f64; crate::graph::MAX_STATES];
    for (wt, &z) in weights.iter_mut().zip(scores) {
        *wt = (z - zmax).exp();
        total += *wt;
    }
    let target = rng.next_f64() * total;
    let mut cum = 0.0;
    for (s, &wt) in weights[..scores.len()].iter().enumerate() {
        cum += wt;
        if target < cum {
            return s as u8;
        }
    }
    (scores.len() - 1) as u8
}

/// Reused scratch of the blocked joint draw: per-edge softplus tables
/// (lane-independent), the per-node upward messages, and the current
/// lane's drawn bits.
#[derive(Default)]
struct BlockScratch {
    etab: Vec<[f64; 4]>,
    local: Vec<[f64; 2]>,
    bits: Vec<u8>,
    /// K-state per-edge `(E_eq, E_ne)` Potts tables.
    etab_k: Vec<(f64, f64)>,
    /// K-state upward messages, flat `nodes × k`.
    local_k: Vec<f64>,
    /// K-state drawn states of the current lane.
    states: Vec<u8>,
}

/// Overflow-safe `ln(e^a + e^b)`.
#[inline]
fn logaddexp(a: f64, b: f64) -> f64 {
    let (hi, lo) = if a >= b { (a, b) } else { (b, a) };
    hi + (lo - hi).exp().ln_1p()
}

/// Shared read-only context of the θ half-step.
struct ThetaCtx<'a> {
    model: &'a DualModel,
    x: &'a [u64],
    words: usize,
    lanes: usize,
    base: &'a Pcg64,
    sweep: u64,
    /// Bit-planes per x site row.
    x_planes: usize,
    /// θ bit-planes per slot row (`out` spans `t_planes · words`).
    t_planes: usize,
}

impl ThetaCtx<'_> {
    /// Route one slot: binary slots take the historical single-plane
    /// draw, K > 2 slots draw one auxiliary per state.
    fn dispatch<K: LaneKernel>(&self, slot: usize, out: &mut [u64], buf: &mut SweepBuf) {
        if self.t_planes == 1 {
            self.site::<K>(slot, out, buf);
        } else {
            self.site_k::<K>(slot, out, buf);
        }
    }

    /// Resample `θ_slot` in every lane: the conditional takes one of four
    /// values per factor, so the model's cached four-sigmoid table serves
    /// all lanes (recomputed on churn, not per sweep).
    fn site<K: LaneKernel>(&self, slot: usize, out: &mut [u64], buf: &mut SweepBuf) {
        let Some((v1, v2)) = self.model.slot_endpoints(slot) else {
            out.fill(0); // dead slot: keep θ = 0 in every lane
            return;
        };
        let p = self.model.theta_table(slot);
        let (v1, v2) = (v1 as usize, v2 as usize);
        let mut rng = self.base.split2(self.sweep, ((slot as u64) << 1) | 1);
        for (w, out_word) in out.iter_mut().enumerate() {
            let k = lanes_in_word(self.lanes, w);
            let x1 = self.x[v1 * self.words + w];
            let x2 = self.x[v2 * self.words + w];
            *out_word = K::draw_theta_word(&mut rng, p, x1, x2, k, &mut buf.draw);
        }
    }

    /// Resample the `k` indicator auxiliaries of one K-state slot: for
    /// each state `s`, the conditional of `θ_{slot,s}` is the binary
    /// four-sigmoid formula over the endpoints' state-`s` indicator
    /// words, so the cached table and kernel θ draw are reused verbatim
    /// — one draw per `(word, state)` in that fixed order, all from the
    /// slot's single `split2(sweep, slot·2 + 1)` stream.
    fn site_k<K: LaneKernel>(&self, slot: usize, out: &mut [u64], buf: &mut SweepBuf) {
        let Some((v1, v2)) = self.model.slot_endpoints(slot) else {
            out.fill(0); // dead slot: keep θ = 0 in every lane
            return;
        };
        let p = self.model.theta_table(slot);
        let (v1, v2) = (v1 as usize, v2 as usize);
        let mut rng = self.base.split2(self.sweep, ((slot as u64) << 1) | 1);
        for w in 0..self.words {
            let k = lanes_in_word(self.lanes, w);
            for s in 0..self.t_planes {
                let z1 = self.eq_word(v1, s as u8, w);
                let z2 = self.eq_word(v2, s as u8, w);
                out[s * self.words + w] = K::draw_theta_word(&mut rng, p, z1, z2, k, &mut buf.draw);
            }
        }
    }

    /// Word of state-`s` indicator bits of `v` (`bit l = 1[x_v = s]` in
    /// lane `l`): AND of per-plane XNORs against `s`'s bits. Ghost lanes
    /// may read 1 here; every consumer masks its draw to the live lanes.
    #[inline]
    fn eq_word(&self, v: usize, s: u8, w: usize) -> u64 {
        let mut eq = u64::MAX;
        for p in 0..self.x_planes {
            let xp = self.x[(v * self.x_planes + p) * self.words + w];
            eq &= if (s >> p) & 1 == 1 { xp } else { !xp };
        }
        eq
    }
}

struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::kernels::lane_mask;
    use crate::inference::exact;
    use crate::workloads;

    fn lane_marginals(eng: &mut LanePdSampler, burn: usize, sweeps: usize) -> Vec<f64> {
        for _ in 0..burn {
            eng.sweep();
        }
        let n = eng.num_vars();
        let mut acc = vec![0.0f64; n];
        for _ in 0..sweeps {
            eng.sweep();
            for (v, a) in acc.iter_mut().enumerate() {
                *a += eng.popcount_var(v) as f64;
            }
        }
        let denom = (sweeps * eng.lanes()) as f64;
        acc.into_iter().map(|a| a / denom).collect()
    }

    #[test]
    fn pack_unpack_roundtrip_with_tail_lanes() {
        let g = workloads::ising_grid(3, 3, 0.2, 0.0);
        // 70 lanes: two words per site, 6-bit tail
        let mut eng = LanePdSampler::new(&g, 70, 1);
        let pattern: Vec<u8> = (0..9).map(|v| (v % 2) as u8).collect();
        eng.set_lane_state(3, &pattern);
        eng.set_lane_state(69, &pattern);
        assert_eq!(eng.lane_state(3), pattern);
        assert_eq!(eng.lane_state(69), pattern);
        assert_eq!(eng.lane_state(4), vec![0u8; 9]);
        assert_eq!(eng.popcount_var(1), 2); // lanes 3 and 69 set
    }

    #[test]
    fn fill_and_randomize_lane() {
        let g = workloads::ising_grid(2, 2, 0.1, 0.0);
        let mut eng = LanePdSampler::new(&g, 5, 2);
        eng.fill_lane(1, true);
        assert_eq!(eng.lane_state(1), vec![1, 1, 1, 1]);
        assert_eq!(eng.lane_state(0), vec![0, 0, 0, 0]);
        eng.fill_lane(1, false);
        assert_eq!(eng.lane_state(1), vec![0, 0, 0, 0]);
        // deterministic randomization
        let mut eng2 = LanePdSampler::new(&g, 5, 2);
        eng.randomize_lane(2);
        eng2.randomize_lane(2);
        assert_eq!(eng.lane_state(2), eng2.lane_state(2));
    }

    #[test]
    fn tail_lanes_stay_zero_under_sweeps() {
        let g = workloads::ising_grid(3, 3, 0.4, 0.2);
        for &kernel in KernelKind::all() {
            let mut eng = LanePdSampler::new(&g, 5, 3).with_kernel(kernel);
            for _ in 0..50 {
                eng.sweep();
            }
            for &w in eng.state_words().iter().chain(eng.theta_words()) {
                assert_eq!(
                    w & !lane_mask(5),
                    0,
                    "ghost lanes written by {}",
                    kernel.name()
                );
            }
        }
    }

    #[test]
    fn exact_on_small_grid() {
        let g = workloads::ising_grid(3, 3, 0.3, 0.1);
        let mut eng = LanePdSampler::new(&g, 64, 4);
        let got = lane_marginals(&mut eng, 500, 2500);
        let want = exact::enumerate(&g).marginals;
        for v in 0..9 {
            assert!(
                (got[v] - want[v]).abs() < 0.012,
                "v={v}: {} vs exact {}",
                got[v],
                want[v]
            );
        }
    }

    #[test]
    fn exact_with_negative_couplings() {
        // anti-ferromagnetic couplings exercise the Lemma-4 swap path
        let mut g = FactorGraph::new(5);
        g.set_unary(0, 0.4);
        g.add_factor(PairFactor::ising(0, 1, -0.5));
        g.add_factor(PairFactor::ising(1, 2, 0.6));
        g.add_factor(PairFactor::ising(2, 3, -0.4));
        g.add_factor(PairFactor::ising(3, 4, 0.3));
        g.add_factor(PairFactor::ising(4, 0, -0.2));
        let mut eng = LanePdSampler::new(&g, 64, 5);
        let got = lane_marginals(&mut eng, 500, 2500);
        let want = exact::enumerate(&g).marginals;
        for v in 0..5 {
            assert!(
                (got[v] - want[v]).abs() < 0.012,
                "v={v}: {} vs exact {}",
                got[v],
                want[v]
            );
        }
    }

    #[test]
    fn table_and_accumulate_paths_mix_correctly() {
        // a star graph: the hub's degree 7 exceeds X_TABLE_MAX_DEG so it
        // takes the per-lane accumulate fallback, while every leaf (degree
        // 1) draws from its cached x-table — the mixed-path chain must
        // still match the exact oracle
        let mut g = FactorGraph::new(8);
        g.set_unary(0, 0.2);
        for leaf in 1..8 {
            let sign = if leaf % 2 == 0 { -0.3 } else { 0.4 };
            g.add_factor(PairFactor::ising(0, leaf, sign));
        }
        let mut eng = LanePdSampler::new(&g, 64, 11);
        assert!(eng.model().x_table(0).is_none(), "hub must fall back");
        assert!(eng.model().x_table(1).is_some(), "leaf must use the table");
        let got = lane_marginals(&mut eng, 600, 3000);
        let want = exact::enumerate(&g).marginals;
        for v in 0..8 {
            assert!(
                (got[v] - want[v]).abs() < 0.015,
                "v={v}: {} vs exact {}",
                got[v],
                want[v]
            );
        }
    }

    #[test]
    fn dynamic_add_remove_keeps_correctness() {
        // mutate the shared model mid-run, applied once for all lanes
        let mut g = workloads::ising_grid(2, 3, 0.3, 0.1);
        let mut eng = LanePdSampler::new(&g, 64, 6);
        for _ in 0..100 {
            eng.sweep();
        }
        let added = g.add_factor(PairFactor::ising(0, 4, 0.5));
        eng.add_factor(added, g.factor(added).unwrap());
        let victim = g.factors().next().unwrap().0;
        g.remove_factor(victim).unwrap();
        assert!(eng.remove_factor(victim));
        let got = lane_marginals(&mut eng, 300, 2000);
        let want = exact::enumerate(&g).marginals;
        for v in 0..6 {
            assert!(
                (got[v] - want[v]).abs() < 0.012,
                "v={v}: {} vs exact {}",
                got[v],
                want[v]
            );
        }
    }

    #[test]
    fn remove_factor_of_dead_slot_is_a_reported_noop() {
        // regression: removing an unknown/already-removed slot must not
        // touch any θ state and must say so, consistently with
        // DualModel::remove returning None
        let mut g = workloads::ising_grid(2, 2, 0.3, 0.0);
        let victim = g.factors().next().unwrap().0;
        let mut eng = LanePdSampler::new(&g, 70, 7);
        for _ in 0..20 {
            eng.sweep();
        }
        let live = eng.model().num_factors();
        assert!(eng.remove_factor(victim), "first removal hits a live slot");
        assert_eq!(eng.model().num_factors(), live - 1);
        let theta_before = eng.theta_words().to_vec();
        let x_before = eng.state_words().to_vec();
        assert!(!eng.remove_factor(victim), "double remove must report false");
        assert!(!eng.remove_factor(victim + 1000), "unknown slot must report false");
        assert_eq!(eng.theta_words(), &theta_before[..], "θ state touched");
        assert_eq!(eng.state_words(), &x_before[..], "x state touched");
        assert_eq!(eng.model().num_factors(), live - 1);
    }

    #[test]
    fn config_constructor_carries_the_kernel() {
        let g = workloads::ising_grid(2, 2, 0.2, 0.0);
        let eng = LanePdSampler::with_config(
            &g,
            EngineConfig {
                lanes: 3,
                seed: 9,
                kernel: KernelKind::Scalar,
                ..EngineConfig::default()
            },
        );
        assert_eq!(eng.kernel(), KernelKind::Scalar);
        assert_eq!(eng.lanes(), 3);
        assert_eq!(eng.sweep_policy(), SweepPolicy::Exact);
        let eng = eng.with_kernel(KernelKind::Tiled);
        assert_eq!(eng.kernel(), KernelKind::Tiled);
        // default config: tiled
        assert_eq!(LanePdSampler::new(&g, 2, 0).kernel(), KernelKind::Tiled);
    }

    /// Hub-heavy star used by the minibatch tests: degree 9 exceeds both
    /// `X_TABLE_MAX_DEG` and the test policy's threshold.
    fn mb_star() -> FactorGraph {
        let mut g = FactorGraph::new(10);
        g.set_unary(0, 0.3);
        for leaf in 1..10 {
            let beta = if leaf % 2 == 0 { -0.35 } else { 0.3 };
            g.add_factor(PairFactor::ising(0, leaf, beta));
        }
        g
    }

    /// Aggressive subsampling so the correction (not the λ floor) does
    /// the work: small λ makes κ small, maximizing thinning pressure.
    fn mb_cfg(seed: u64, theta_stride: usize) -> EngineConfig {
        EngineConfig {
            lanes: 64,
            seed,
            kernel: KernelKind::default(),
            sweep: SweepPolicy::Minibatch(MinibatchPolicy {
                degree_threshold: 4,
                lambda_scale: 0.25,
                lambda_min: 1.0,
                theta_stride,
            }),
        }
    }

    #[test]
    fn minibatch_policy_builds_plans_and_reprices_cost() {
        let g = mb_star();
        let eng = LanePdSampler::with_config(&g, mb_cfg(13, 2));
        assert_eq!(eng.sweep_policy(), SweepPolicy::Minibatch(MinibatchPolicy {
            degree_threshold: 4,
            lambda_scale: 0.25,
            lambda_min: 1.0,
            theta_stride: 2,
        }));
        assert!(eng.model().mb_plan(0).is_some(), "hub must be planned");
        assert!(eng.model().mb_plan(1).is_none(), "leaves stay exact");
        let exact = LanePdSampler::new(&g, 64, 13);
        assert_eq!(exact.sweep_policy(), SweepPolicy::Exact);
        assert!(
            eng.cost() < exact.cost(),
            "minibatch cost {} must undercut exact cost {}",
            eng.cost(),
            exact.cost()
        );
    }

    #[test]
    fn minibatch_matches_exact_enumeration() {
        // the corrected chain is a *different* trajectory but the same
        // stationary law — compare long-run marginals to the oracle
        let g = mb_star();
        let want = exact::enumerate(&g).marginals;
        for stride in [1usize, 2] {
            let mut eng = LanePdSampler::with_config(&g, mb_cfg(17, stride));
            // stride-s θ refreshes need ~s× the sweeps to mix
            let (burn, sweeps) = (800 * stride, 4000 * stride);
            let got = lane_marginals(&mut eng, burn, sweeps);
            for v in 0..10 {
                assert!(
                    (got[v] - want[v]).abs() < 0.02,
                    "stride={stride} v={v}: {} vs exact {}",
                    got[v],
                    want[v]
                );
            }
        }
    }

    #[test]
    fn minibatch_trajectory_is_kernel_and_pool_invariant() {
        let g = mb_star();
        let mut reference: Option<(Vec<u64>, Vec<u64>)> = None;
        for &kernel in KernelKind::all() {
            for pool_size in [0usize, 3] {
                let cfg = EngineConfig {
                    kernel,
                    ..mb_cfg(23, 2)
                };
                let mut eng = LanePdSampler::with_config(&g, cfg);
                if pool_size > 0 {
                    eng = eng.with_pool(Arc::new(ThreadPool::new(pool_size)));
                }
                for _ in 0..40 {
                    eng.sweep();
                }
                let state = (eng.state_words().to_vec(), eng.theta_words().to_vec());
                match &reference {
                    None => reference = Some(state),
                    Some(want) => assert_eq!(
                        &state,
                        want,
                        "kernel {} pool {pool_size} diverged",
                        kernel.name()
                    ),
                }
            }
        }
    }

    #[test]
    fn theta_stride_skips_out_of_window_slots() {
        // stride 3: a slot's θ word may only change on sweeps where
        // sweep % 3 == slot % 3
        let g = mb_star();
        let mut eng = LanePdSampler::with_config(&g, mb_cfg(29, 3));
        for _ in 0..5 {
            eng.sweep(); // move off the all-zeros state
        }
        let slots = eng.model().factor_slots();
        for _ in 0..12 {
            let before = eng.theta_words().to_vec();
            eng.sweep();
            let phase = (eng.sweeps_done() % 3) as usize;
            let words = eng.words_per_site();
            for slot in 0..slots {
                if slot % 3 != phase {
                    assert_eq!(
                        &eng.theta_words()[slot * words..(slot + 1) * words],
                        &before[slot * words..(slot + 1) * words],
                        "out-of-window slot {slot} changed on sweep {}",
                        eng.sweeps_done()
                    );
                }
            }
        }
    }

    #[test]
    fn minibatch_tail_lanes_stay_zero() {
        let g = mb_star();
        let cfg = EngineConfig {
            lanes: 5,
            ..mb_cfg(31, 2)
        };
        for &kernel in KernelKind::all() {
            let mut eng =
                LanePdSampler::with_config(&g, EngineConfig { kernel, ..cfg });
            for _ in 0..50 {
                eng.sweep();
            }
            for &w in eng.state_words().iter().chain(eng.theta_words()) {
                assert_eq!(w & !lane_mask(5), 0, "ghost lanes by {}", kernel.name());
            }
        }
    }

    #[test]
    fn sweep_policy_wire_forms_round_trip() {
        let cases = [
            ("exact", SweepPolicy::Exact),
            (
                "minibatch",
                SweepPolicy::Minibatch(MinibatchPolicy::default()),
            ),
            (
                "minibatch:128",
                SweepPolicy::Minibatch(MinibatchPolicy {
                    degree_threshold: 128,
                    ..MinibatchPolicy::default()
                }),
            ),
            (
                "minibatch:32:4",
                SweepPolicy::Minibatch(MinibatchPolicy {
                    degree_threshold: 32,
                    theta_stride: 4,
                    ..MinibatchPolicy::default()
                }),
            ),
            ("blocked", SweepPolicy::Blocked(BlockPolicy::default())),
            (
                "blocked:12",
                SweepPolicy::Blocked(BlockPolicy {
                    cap: 12,
                    ..BlockPolicy::default()
                }),
            ),
            (
                "blocked:6:4",
                SweepPolicy::Blocked(BlockPolicy { cap: 6, epoch: 4 }),
            ),
        ];
        for (tok, want) in cases {
            assert_eq!(SweepPolicy::parse(tok), Some(want), "parse {tok:?}");
        }
        // display round-trips through parse for every policy form
        for (_, p) in cases {
            assert_eq!(SweepPolicy::parse(&p.to_string()), Some(p));
        }
        for bad in ["", "mini", "minibatch:", "minibatch:x", "minibatch:8:0",
                    "minibatch:8:2:9", "exact:1", "blocked:", "blocked:1",
                    "blocked:x", "blocked:8:0", "blocked:8:2:1"] {
            assert_eq!(SweepPolicy::parse(bad), None, "must reject {bad:?}");
        }
    }

    /// Blocked config on a strongly-coupled grid: blocks must actually
    /// form once the agreement EWMAs see the correlated lanes.
    fn blk_cfg(seed: u64, cap: usize, epoch: usize) -> EngineConfig {
        EngineConfig {
            lanes: 64,
            seed,
            kernel: KernelKind::default(),
            sweep: SweepPolicy::Blocked(BlockPolicy { cap, epoch }),
        }
    }

    #[test]
    fn blocked_policy_grows_blocks_and_reprices_cost() {
        let g = workloads::ising_grid(3, 3, 0.9, 0.05);
        let mut eng = LanePdSampler::with_config(&g, blk_cfg(41, 4, 8));
        assert_eq!(
            eng.sweep_policy(),
            SweepPolicy::Blocked(BlockPolicy { cap: 4, epoch: 8 })
        );
        assert_eq!(eng.block_summary(), (0, 0, 0), "no plan before sweeping");
        let flat_cost = eng.cost();
        for _ in 0..64 {
            eng.sweep();
        }
        let (blocks, vars, tree) = eng.block_summary();
        assert!(blocks >= 1, "β=0.9 lanes must lock step into blocks");
        assert!(vars >= 2 && tree >= 1);
        assert!(
            eng.cost() > flat_cost,
            "joint draws must bill a surcharge: {} vs flat {flat_cost}",
            eng.cost()
        );
        // every block respects the cap and units partition the vars
        let plan = eng.block_plan().unwrap();
        assert!(plan.blocks.iter().all(|b| b.nodes.len() <= 4));
        let covered: usize = plan
            .units
            .iter()
            .map(|u| match *u {
                crate::duality::SweepUnit::Var(_) => 1,
                crate::duality::SweepUnit::Block(b) => plan.blocks[b as usize].nodes.len(),
            })
            .sum();
        assert_eq!(covered, g.num_vars());
    }

    #[test]
    fn blocked_matches_exact_enumeration() {
        // the blocked chain is a different (better-mixing) trajectory
        // but the same stationary law — above-critical coupling where
        // flat PD struggles most
        let g = workloads::ising_grid(3, 3, 0.6, 0.1);
        let want = exact::enumerate(&g).marginals;
        let mut eng = LanePdSampler::with_config(&g, blk_cfg(43, 4, 8));
        let got = lane_marginals(&mut eng, 600, 3000);
        for v in 0..9 {
            assert!(
                (got[v] - want[v]).abs() < 0.015,
                "v={v}: {} vs exact {}",
                got[v],
                want[v]
            );
        }
    }

    #[test]
    fn blocked_trajectory_is_kernel_and_pool_invariant() {
        let g = workloads::ising_grid(3, 3, 0.8, 0.05);
        let mut reference: Option<(Vec<u64>, Vec<u64>)> = None;
        for &kernel in KernelKind::all() {
            for pool_size in [0usize, 3] {
                let cfg = EngineConfig { kernel, ..blk_cfg(47, 4, 4) };
                let mut eng = LanePdSampler::with_config(&g, cfg);
                if pool_size > 0 {
                    eng = eng.with_pool(Arc::new(ThreadPool::new(pool_size)));
                }
                for _ in 0..40 {
                    eng.sweep();
                }
                assert!(eng.block_summary().0 >= 1, "plan must engage mid-run");
                let state = (eng.state_words().to_vec(), eng.theta_words().to_vec());
                match &reference {
                    None => reference = Some(state),
                    Some(want) => assert_eq!(
                        &state,
                        want,
                        "kernel {} pool {pool_size} diverged",
                        kernel.name()
                    ),
                }
            }
        }
    }

    #[test]
    fn blocked_tail_lanes_stay_zero() {
        let g = workloads::ising_grid(3, 3, 0.8, 0.0);
        for &kernel in KernelKind::all() {
            let cfg = EngineConfig { lanes: 5, kernel, ..blk_cfg(53, 4, 4) };
            let mut eng = LanePdSampler::with_config(&g, cfg);
            for _ in 0..50 {
                eng.sweep();
            }
            for &w in eng.state_words().iter().chain(eng.theta_words()) {
                assert_eq!(w & !lane_mask(5), 0, "ghost lanes by {}", kernel.name());
            }
        }
    }

    #[test]
    fn churn_invalidates_the_block_plan_eagerly() {
        // a removed tree edge must leave the plan on the NEXT sweep even
        // mid-epoch, and its recycled slot must not inherit the stat;
        // 62 warmup sweeps put the next two sweeps strictly inside an
        // epoch, so only churn staleness can explain a re-plan
        let mut g = workloads::ising_grid(3, 3, 0.9, 0.0);
        let mut eng = LanePdSampler::with_config(&g, blk_cfg(59, 9, 8));
        for _ in 0..62 {
            eng.sweep();
        }
        let plan = eng.block_plan().unwrap().clone();
        assert!(plan.tree_slots >= 1, "need a tree edge to remove");
        let victim = plan.blocks[0].tree_slots[0] as usize;
        g.remove_factor(victim).unwrap();
        assert!(eng.remove_factor(victim));
        eng.sweep();
        let replanned = eng.block_plan().unwrap();
        assert!(
            replanned.blocks.iter().all(|b| !b.is_tree_slot(victim as u32)),
            "dead slot survived re-planning as a tree edge"
        );
        // re-adding reuses the slot with a neutral stat: still no tree
        // edge through it on the immediate next plan
        let id = g.add_factor(PairFactor::ising(0, 1, 0.9));
        eng.add_factor(id, g.factor(id).unwrap());
        eng.sweep();
        assert!(
            eng.block_plan().unwrap().blocks.iter().all(|b| !b.is_tree_slot(id as u32)),
            "fresh slot must re-earn its block membership"
        );
    }

    // -- K-state (Potts) + clamping ---------------------------------------

    /// Exact per-(site, state) marginals by enumeration of the K-state
    /// joint, optionally conditioned on evidence: `out[v][s] = P(x_v=s)`.
    fn enumerate_k(g: &FactorGraph, evidence: &[(usize, u8)]) -> Vec<Vec<f64>> {
        let (n, k) = (g.num_vars(), g.k());
        let mut x = vec![0u8; n];
        let mut acc = vec![vec![0.0f64; k]; n];
        let mut z = 0.0f64;
        'joint: for code in 0..k.pow(n as u32) {
            let mut c = code;
            for xv in x.iter_mut() {
                *xv = (c % k) as u8;
                c /= k;
            }
            for &(v, s) in evidence {
                if x[v] != s {
                    continue 'joint;
                }
            }
            let w = g.log_prob_unnorm(&x).exp();
            z += w;
            for (v, &xv) in x.iter().enumerate() {
                acc[v][xv as usize] += w;
            }
        }
        for row in &mut acc {
            for p in row.iter_mut() {
                *p /= z;
            }
        }
        acc
    }

    fn lane_marginals_k(
        eng: &mut LanePdSampler,
        burn: usize,
        sweeps: usize,
    ) -> Vec<Vec<f64>> {
        for _ in 0..burn {
            eng.sweep();
        }
        let (n, k) = (eng.num_vars(), eng.k());
        let mut acc = vec![vec![0.0f64; k]; n];
        for _ in 0..sweeps {
            eng.sweep();
            for (v, row) in acc.iter_mut().enumerate() {
                for (s, a) in row.iter_mut().enumerate() {
                    *a += eng.popcount_state(v, s as u8) as f64;
                }
            }
        }
        let denom = (sweeps * eng.lanes()) as f64;
        for row in &mut acc {
            for p in row.iter_mut() {
                *p /= denom;
            }
        }
        acc
    }

    /// Mixed-sign Potts ring: even edges attract, odd edges repel, so
    /// both signs of β exercise the indicator dual.
    fn potts_ring(k: usize, n: usize) -> FactorGraph {
        let mut g = FactorGraph::new_k(n, k);
        for v in 0..n {
            let beta = if v % 2 == 0 { 0.6 } else { -0.4 };
            g.add_factor(PairFactor::potts(v, (v + 1) % n, beta));
        }
        g
    }

    #[test]
    fn potts_lane_marginals_match_enumeration() {
        // k=3 ring (2 bit-planes) and k=4 chain: every (site, state)
        // marginal must match brute-force enumeration of the Potts joint
        let g3 = potts_ring(3, 5);
        let mut g4 = FactorGraph::new_k(4, 4);
        g4.add_factor(PairFactor::potts(0, 1, 0.7));
        g4.add_factor(PairFactor::potts(1, 2, -0.5));
        g4.add_factor(PairFactor::potts(2, 3, 0.4));
        for g in [&g3, &g4] {
            let want = enumerate_k(g, &[]);
            let mut eng = LanePdSampler::new(g, 64, 19);
            assert_eq!(eng.k(), g.k());
            let got = lane_marginals_k(&mut eng, 600, 3000);
            for v in 0..g.num_vars() {
                for s in 0..g.k() {
                    assert!(
                        (got[v][s] - want[v][s]).abs() < 0.015,
                        "k={} v={v} s={s}: {} vs exact {}",
                        g.k(),
                        got[v][s],
                        want[v][s]
                    );
                }
            }
        }
    }

    #[test]
    fn kstate_trajectory_is_kernel_and_pool_invariant() {
        // 70 lanes forces a 6-bit tail word; a clamped site rides along
        // to pin clamp invariance across kernels and pool sizes too
        let g = potts_ring(3, 6);
        let mut reference: Option<(Vec<u64>, Vec<u64>)> = None;
        for &kernel in KernelKind::all() {
            for pool_size in [0usize, 3] {
                let cfg = EngineConfig {
                    lanes: 70,
                    seed: 67,
                    kernel,
                    ..EngineConfig::default()
                };
                let mut eng = LanePdSampler::with_config(&g, cfg);
                eng.clamp(2, 1).unwrap();
                if pool_size > 0 {
                    eng = eng.with_pool(Arc::new(ThreadPool::new(pool_size)));
                }
                for _ in 0..40 {
                    eng.sweep();
                }
                let state = (eng.state_words().to_vec(), eng.theta_words().to_vec());
                match &reference {
                    None => reference = Some(state),
                    Some(want) => assert_eq!(
                        &state,
                        want,
                        "kernel {} pool {pool_size} diverged",
                        kernel.name()
                    ),
                }
            }
        }
    }

    #[test]
    fn kstate_tail_lanes_stay_zero() {
        // 2 bit-planes (k=3) and 3 bit-planes (k=5): ghost bits of every
        // x plane and θ plane must stay zero under sweeps and clamping
        for k in [3usize, 5] {
            let g = potts_ring(k, 5);
            for &kernel in KernelKind::all() {
                let cfg = EngineConfig {
                    lanes: 5,
                    seed: 71,
                    kernel,
                    ..EngineConfig::default()
                };
                let mut eng = LanePdSampler::with_config(&g, cfg);
                eng.clamp(0, (k - 1) as u8).unwrap();
                for _ in 0..50 {
                    eng.sweep();
                }
                for &w in eng.state_words().iter().chain(eng.theta_words()) {
                    assert_eq!(
                        w & !lane_mask(5),
                        0,
                        "k={k} ghost lanes written by {}",
                        kernel.name()
                    );
                }
            }
        }
    }

    #[test]
    fn clamped_sites_pin_and_condition_neighbors() {
        // clamping must freeze the site in every lane AND steer the
        // neighbors' stationary law to the exact conditional — on a
        // binary grid and on a k=3 ring
        let cases: Vec<(FactorGraph, Vec<(usize, u8)>)> = vec![
            (workloads::ising_grid(3, 3, 0.3, 0.1), vec![(4, 1)]),
            (potts_ring(3, 5), vec![(0, 2), (2, 1)]),
        ];
        for (g, evidence) in cases {
            let want = enumerate_k(&g, &evidence);
            let mut eng = LanePdSampler::new(&g, 64, 23);
            for &(v, s) in &evidence {
                eng.clamp(v, s).unwrap();
            }
            assert_eq!(eng.clamped_count(), evidence.len());
            let got = lane_marginals_k(&mut eng, 600, 3000);
            for &(v, s) in &evidence {
                // the clamp held: all mass on the evidence state
                assert_eq!(eng.popcount_state(v, s) as usize, eng.lanes());
                assert_eq!(got[v][s as usize], 1.0, "evidence site {v} drifted");
            }
            for v in 0..g.num_vars() {
                for s in 0..g.k() {
                    assert!(
                        (got[v][s] - want[v][s]).abs() < 0.015,
                        "k={} v={v} s={s}: {} vs conditional exact {}",
                        g.k(),
                        got[v][s],
                        want[v][s]
                    );
                }
            }
        }
    }

    #[test]
    fn clamp_survives_init_helpers_and_unclamp_resumes() {
        let g = potts_ring(3, 5);
        let mut eng = LanePdSampler::new(&g, 7, 29);
        eng.clamp(1, 2).unwrap();
        eng.clamp(1, 2).unwrap(); // idempotent
        assert_eq!(eng.clamped_count(), 1);
        assert!(eng.is_clamped(1) && !eng.is_clamped(0));
        // init helpers must not move the evidence
        eng.set_lane_state(3, &[0, 0, 0, 0, 0]);
        eng.fill_lane_state(4, 1);
        eng.randomize_lane(5);
        for lane in 0..7 {
            assert_eq!(eng.lane_value(1, lane), 2, "lane {lane} moved evidence");
        }
        // randomize_lane consumes the clamped site's draw, so free sites
        // land identically with and without the clamp
        let mut free = LanePdSampler::new(&g, 7, 29);
        free.randomize_lane(5);
        for v in [0usize, 2, 3, 4] {
            assert_eq!(eng.lane_value(v, 5), free.lane_value(v, 5));
        }
        // re-clamping to a different state moves the evidence
        eng.clamp(1, 0).unwrap();
        assert_eq!(eng.clamped_count(), 1);
        assert_eq!(eng.popcount_state(1, 0) as usize, eng.lanes());
        // unclamp keeps the value until the next sweep resamples it
        eng.unclamp(1).unwrap();
        eng.unclamp(1).unwrap(); // no-op
        assert_eq!(eng.clamped_count(), 0);
        assert!(!eng.is_clamped(1));
        assert_eq!(eng.popcount_state(1, 0) as usize, eng.lanes());
        let mut moved = false;
        for _ in 0..20 {
            eng.sweep();
            moved |= (eng.popcount_state(1, 0) as usize) != eng.lanes();
        }
        assert!(moved, "released site never resampled");
    }

    /// Hub-heavy K-state star: the hub's degree exceeds the minibatch
    /// test policies' thresholds, both β signs exercised (no unary —
    /// K > 2 forbids it).
    fn potts_star(k: usize, n: usize) -> FactorGraph {
        let mut g = FactorGraph::new_k(n, k);
        for leaf in 1..n {
            let beta = if leaf % 2 == 0 { -0.35 } else { 0.3 };
            g.add_factor(PairFactor::potts(0, leaf, beta));
        }
        g
    }

    #[test]
    fn minibatch_kstate_matches_exact_enumeration() {
        // the per-state Poisson/MIN-Gibbs correction is a different
        // trajectory but the same stationary K-state law
        let g = potts_star(3, 8);
        let want = enumerate_k(&g, &[]);
        for stride in [1usize, 2] {
            let mut eng = LanePdSampler::with_config(&g, mb_cfg(17, stride));
            assert!(eng.model().mb_plan(0).is_some(), "hub must be planned");
            assert!(eng.model().mb_plan(1).is_none(), "leaves stay exact");
            let got = lane_marginals_k(&mut eng, 800 * stride, 4000 * stride);
            for v in 0..g.num_vars() {
                for s in 0..3 {
                    assert!(
                        (got[v][s] - want[v][s]).abs() < 0.02,
                        "stride={stride} v={v} s={s}: {} vs exact {}",
                        got[v][s],
                        want[v][s]
                    );
                }
            }
        }
    }

    #[test]
    fn blocked_kstate_matches_exact_enumeration() {
        // strongly-coupled k=3 grid: blocks must engage and the joint
        // FFBS draws must leave the Potts law invariant
        let g = workloads::potts_grid(2, 3, 3, 0.8);
        let want = enumerate_k(&g, &[]);
        let mut eng = LanePdSampler::with_config(&g, blk_cfg(43, 4, 8));
        let got = lane_marginals_k(&mut eng, 600, 3000);
        assert!(eng.block_summary().0 >= 1, "plan must engage on β=0.8");
        for v in 0..g.num_vars() {
            for s in 0..3 {
                assert!(
                    (got[v][s] - want[v][s]).abs() < 0.015,
                    "v={v} s={s}: {} vs exact {}",
                    got[v][s],
                    want[v][s]
                );
            }
        }
    }

    #[test]
    fn clamped_sites_condition_neighbors_under_minibatch_and_blocked() {
        // clamping composes with both lifted policies, binary and k=3:
        // the free sites' stationary law must match the exact
        // conditional and the evidence must never drift
        let cases: Vec<(FactorGraph, EngineConfig, Vec<(usize, u8)>, usize, usize)> = vec![
            (mb_star(), mb_cfg(37, 1), vec![(4, 1)], 800, 4000),
            (potts_star(3, 8), mb_cfg(39, 1), vec![(1, 2)], 800, 4000),
            (
                workloads::ising_grid(3, 3, 0.6, 0.1),
                blk_cfg(41, 4, 8),
                vec![(4, 1)],
                600,
                3000,
            ),
            (workloads::potts_grid(2, 3, 3, 0.8), blk_cfg(43, 4, 8), vec![(0, 1)], 600, 3000),
        ];
        for (g, cfg, evidence, burn, sweeps) in cases {
            let want = enumerate_k(&g, &evidence);
            let mut eng = LanePdSampler::with_config(&g, cfg);
            for &(v, s) in &evidence {
                eng.clamp(v, s).unwrap();
            }
            let got = lane_marginals_k(&mut eng, burn, sweeps);
            for &(v, s) in &evidence {
                assert_eq!(eng.popcount_state(v, s) as usize, eng.lanes());
                assert_eq!(got[v][s as usize], 1.0, "evidence site {v} drifted");
            }
            if let Some(plan) = eng.block_plan() {
                for blk in &plan.blocks {
                    for node in &blk.nodes {
                        assert!(
                            !eng.is_clamped(node.v as usize),
                            "clamped site {} entered a block",
                            node.v
                        );
                    }
                }
            }
            for v in 0..g.num_vars() {
                for s in 0..g.k() {
                    assert!(
                        (got[v][s] - want[v][s]).abs() < 0.02,
                        "k={} {:?} v={v} s={s}: {} vs conditional exact {}",
                        g.k(),
                        cfg_policy_name(&eng),
                        got[v][s],
                        want[v][s]
                    );
                }
            }
        }
    }

    /// Short policy tag for assertion messages.
    fn cfg_policy_name(eng: &LanePdSampler) -> &'static str {
        match eng.sweep_policy() {
            SweepPolicy::Exact => "exact",
            SweepPolicy::Minibatch(_) => "minibatch",
            SweepPolicy::Blocked(_) => "blocked",
        }
    }

    #[test]
    fn kstate_policy_trajectories_are_kernel_and_pool_invariant() {
        // the new K-state minibatch / blocked draw paths are scalar code
        // with kernel-independent RNG order — pin it, tail word included
        let star = potts_star(3, 8);
        let grid = workloads::potts_grid(2, 3, 3, 0.8);
        let cases: Vec<(&FactorGraph, EngineConfig)> = vec![
            (&star, EngineConfig { lanes: 70, ..mb_cfg(61, 2) }),
            (&grid, EngineConfig { lanes: 70, ..blk_cfg(67, 4, 4) }),
        ];
        for (g, cfg) in cases {
            let mut reference: Option<(Vec<u64>, Vec<u64>)> = None;
            for &kernel in KernelKind::all() {
                for pool_size in [0usize, 3] {
                    let mut eng =
                        LanePdSampler::with_config(g, EngineConfig { kernel, ..cfg });
                    eng.clamp(2, 1).unwrap();
                    if pool_size > 0 {
                        eng = eng.with_pool(Arc::new(ThreadPool::new(pool_size)));
                    }
                    for _ in 0..40 {
                        eng.sweep();
                    }
                    let state = (eng.state_words().to_vec(), eng.theta_words().to_vec());
                    match &reference {
                        None => reference = Some(state),
                        Some(want) => assert_eq!(
                            &state,
                            want,
                            "kernel {} pool {pool_size} diverged",
                            kernel.name()
                        ),
                    }
                }
            }
        }
    }

    #[test]
    fn kstate_policy_tail_lanes_stay_zero() {
        let star = potts_star(5, 6);
        let grid = workloads::potts_grid(2, 3, 5, 0.8);
        let cases: Vec<(&FactorGraph, EngineConfig)> = vec![
            (&star, EngineConfig { lanes: 5, ..mb_cfg(71, 2) }),
            (&grid, EngineConfig { lanes: 5, ..blk_cfg(73, 4, 4) }),
        ];
        for (g, cfg) in cases {
            for &kernel in KernelKind::all() {
                let mut eng = LanePdSampler::with_config(g, EngineConfig { kernel, ..cfg });
                for _ in 0..50 {
                    eng.sweep();
                }
                for &w in eng.state_words().iter().chain(eng.theta_words()) {
                    assert_eq!(w & !lane_mask(5), 0, "ghost lanes by {}", kernel.name());
                }
            }
        }
    }

    #[test]
    fn clamp_mid_epoch_rebuilds_the_block_plan() {
        // a clamp is a semantic mutation: the plan must shed the clamped
        // site on the NEXT sweep even strictly mid-epoch, and an unclamp
        // must make the site re-earn membership from neutral EWMAs
        let g = workloads::ising_grid(3, 3, 0.9, 0.0);
        let mut eng = LanePdSampler::with_config(&g, blk_cfg(59, 9, 8));
        for _ in 0..62 {
            eng.sweep();
        }
        let plan = eng.block_plan().unwrap().clone();
        let victim = plan.blocks[0].nodes[0].v as usize;
        eng.clamp(victim, 1).unwrap();
        eng.sweep(); // sweeps 63, 64: strictly inside the epoch window
        let replanned = eng.block_plan().unwrap();
        assert!(
            replanned.blocks.iter().all(|b| b.nodes.iter().all(|n| n.v as usize != victim)),
            "clamped site survived re-planning inside a block"
        );
        eng.unclamp(victim).unwrap();
        eng.sweep();
        assert!(
            eng.block_plan()
                .unwrap()
                .blocks
                .iter()
                .all(|b| b.nodes.iter().all(|n| n.v as usize != victim)),
            "released site must re-earn membership from neutral EWMAs"
        );
    }

    #[test]
    fn range_and_policy_errors_carry_context() {
        let g3 = potts_ring(3, 5);
        let mut eng = LanePdSampler::new(&g3, 4, 5);
        // out-of-range SITE is its own variant for clamp AND unclamp —
        // no phantom `state: 0` in the unclamp diagnostic
        assert_eq!(eng.clamp(9, 0), Err(EngineError::SiteOutOfRange { v: 9, n: 5 }));
        assert_eq!(eng.unclamp(9), Err(EngineError::SiteOutOfRange { v: 9, n: 5 }));
        assert_eq!(
            eng.clamp(1, 3),
            Err(EngineError::ClampOutOfRange { v: 1, n: 5, state: 3, k: 3 })
        );
        assert_eq!(eng.clamped_count(), 0, "failed clamps must not count");
        // degenerate policy knobs are typed errors at construction
        let bad = [
            SweepPolicy::Minibatch(MinibatchPolicy {
                theta_stride: 0,
                ..MinibatchPolicy::default()
            }),
            SweepPolicy::Minibatch(MinibatchPolicy {
                lambda_min: 0.0,
                ..MinibatchPolicy::default()
            }),
            SweepPolicy::Minibatch(MinibatchPolicy {
                lambda_scale: -1.0,
                ..MinibatchPolicy::default()
            }),
            SweepPolicy::Blocked(BlockPolicy { cap: 1, epoch: 16 }),
            SweepPolicy::Blocked(BlockPolicy { cap: 8, epoch: 0 }),
        ];
        for sweep in bad {
            let cfg = EngineConfig { lanes: 4, seed: 3, kernel: KernelKind::default(), sweep };
            match LanePdSampler::try_with_config(&g3, cfg).err() {
                Some(EngineError::InvalidPolicy { policy, reason }) => {
                    assert_eq!(policy, sweep);
                    assert!(!reason.is_empty());
                }
                other => panic!("{sweep} must be an InvalidPolicy error, got {other:?}"),
            }
        }
        // non-finite knobs reject too (not comparable by eq above)
        let nan = SweepPolicy::Minibatch(MinibatchPolicy {
            lambda_min: f64::NAN,
            ..MinibatchPolicy::default()
        });
        assert!(matches!(
            LanePdSampler::try_with_config(
                &g3,
                EngineConfig { lanes: 4, seed: 3, kernel: KernelKind::default(), sweep: nan }
            )
            .err(),
            Some(EngineError::InvalidPolicy { .. })
        ));
        // error strings render the offending context
        let msg = EngineError::SiteOutOfRange { v: 9, n: 5 }.to_string();
        assert!(msg.contains("site 9") && msg.contains('5'), "{msg}");
        let msg =
            EngineError::ClampOutOfRange { v: 1, n: 5, state: 3, k: 3 }.to_string();
        assert!(msg.contains("state 3"), "{msg}");
    }

    #[test]
    fn kstate_churn_keeps_correctness() {
        // add + remove Potts factors mid-run: θ rows must resize per
        // slot × k planes and the stationary law must track the new graph
        let mut g = potts_ring(3, 5);
        let mut eng = LanePdSampler::new(&g, 64, 31);
        for _ in 0..100 {
            eng.sweep();
        }
        let added = g.add_factor(PairFactor::potts(0, 2, 0.5));
        eng.add_factor(added, g.factor(added).unwrap());
        let victim = g.factors().next().unwrap().0;
        g.remove_factor(victim).unwrap();
        assert!(eng.remove_factor(victim));
        let want = enumerate_k(&g, &[]);
        let got = lane_marginals_k(&mut eng, 400, 2500);
        for v in 0..5 {
            for s in 0..3 {
                assert!(
                    (got[v][s] - want[v][s]).abs() < 0.015,
                    "v={v} s={s}: {} vs exact {}",
                    got[v][s],
                    want[v][s]
                );
            }
        }
    }

    use crate::graph::FactorGraph;
}
