//! Mean-field inference: naive coordinate ascent and the paper's parallel
//! primal–dual variant (§5.3).
//!
//! Naive mean-field iterates `μ_v ← σ(conditional field under μ)` one
//! variable at a time (sequential, always convergent to a local optimum).
//! The primal–dual variant alternates
//!
//!   `η ← E[s(x) | ξ]`   (all variables in parallel)
//!   `ξ ← E[r(θ) | η]`   (all factors in parallel)
//!
//! and, per Lemma 6, minimizes an *upper bound* on the true mean-field
//! objective `KL(p(x|ξ) ‖ p(x))` — so it can be worse at the optimum (the
//! paper recommends fine-tuning with naive updates afterwards; the
//! [`pd_then_naive`] helper implements exactly that pipeline).

use crate::duality::DualModel;
use crate::graph::FactorGraph;
use crate::rng::sigmoid;

/// Result of a mean-field run.
#[derive(Clone, Debug)]
pub struct MeanFieldResult {
    /// Final `μ_v = q(x_v = 1)`.
    pub mu: Vec<f64>,
    /// Iterations executed.
    pub iters: usize,
    /// Mean-field free energy `E_q[log q − log p̃]` (lower is better; equals
    /// `−log Z + KL(q ‖ p)`).
    pub free_energy: f64,
}

fn entropy_term(mu: f64) -> f64 {
    let h = |p: f64| if p > 0.0 { p * p.ln() } else { 0.0 };
    h(mu) + h(1.0 - mu)
}

/// Free energy of a fully factorized `q` on the graph.
pub fn free_energy(g: &FactorGraph, mu: &[f64]) -> f64 {
    let mut e = 0.0;
    // E_q[-log p̃] = -Σ unary_v μ_v − Σ_f Σ_{a,b} q(a)q(b) log ψ_f(a,b)
    for v in 0..g.num_vars() {
        e -= g.unary(v) * mu[v];
    }
    for (_, f) in g.factors() {
        for a in 0..2 {
            for b in 0..2 {
                let qa = if a == 1 { mu[f.v1] } else { 1.0 - mu[f.v1] };
                let qb = if b == 1 { mu[f.v2] } else { 1.0 - mu[f.v2] };
                e -= qa * qb * f.table[a][b].ln();
            }
        }
    }
    // + E_q[log q]
    for &m in mu {
        e += entropy_term(m);
    }
    e
}

/// Naive sequential mean-field until `max_dx < tol` or `max_iters`.
pub fn naive(g: &FactorGraph, max_iters: usize, tol: f64) -> MeanFieldResult {
    let n = g.num_vars();
    let mut mu = vec![0.5f64; n];
    let mut iters = 0;
    for it in 0..max_iters {
        iters = it + 1;
        let mut max_dx: f64 = 0.0;
        for v in 0..n {
            // E[conditional log-odds] under q: replace neighbors by their means
            let mut z = g.unary(v);
            for &id in g.incident(v) {
                let f = g.factor(id).unwrap();
                let (mu_o, orient_first) = if f.v1 == v {
                    (mu[f.v2], true)
                } else {
                    (mu[f.v1], false)
                };
                for (o, w) in [(0usize, 1.0 - mu_o), (1usize, mu_o)] {
                    let ratio = if orient_first {
                        (f.table[1][o] / f.table[0][o]).ln()
                    } else {
                        (f.table[o][1] / f.table[o][0]).ln()
                    };
                    z += w * ratio;
                }
            }
            let new = sigmoid(z);
            max_dx = max_dx.max((new - mu[v]).abs());
            mu[v] = new;
        }
        if max_dx < tol {
            break;
        }
    }
    let fe = free_energy(g, &mu);
    MeanFieldResult {
        mu,
        iters,
        free_energy: fe,
    }
}

/// Primal–dual parallel mean-field (§5.3) on a dualized model.
///
/// State: `eta[v] = E[x_v]`, `xi_th[i] = E[θ_i]`. Both updates touch every
/// coordinate simultaneously — embarrassingly parallel, matching the
/// paper's GPU story (the XLA artifact reuses the same dense form).
pub fn primal_dual(m: &DualModel, max_iters: usize, tol: f64) -> (Vec<f64>, Vec<f64>, usize) {
    let n = m.num_vars();
    let mut eta = vec![0.5f64; n];
    let mut xi = vec![0.5f64; m.factor_slots()];
    let mut iters = 0;
    for it in 0..max_iters {
        iters = it + 1;
        let mut max_dx: f64 = 0.0;
        // η ← E[s(x) | ξ]: field uses E[θ] in place of θ
        for v in 0..n {
            let mut z = m.base_field(v);
            for &(slot, beta) in m.incidence(v) {
                z += xi[slot as usize] * beta;
            }
            let new = sigmoid(z);
            max_dx = max_dx.max((new - eta[v]).abs());
            eta[v] = new;
        }
        // ξ ← E[r(θ) | η]
        for (slot, e) in m.entries() {
            let z = e.q + e.beta1 * eta[e.v1] + e.beta2 * eta[e.v2];
            let new = sigmoid(z);
            max_dx = max_dx.max((new - xi[slot]).abs());
            xi[slot] = new;
        }
        if max_dx < tol {
            break;
        }
    }
    (eta, xi, iters)
}

/// The paper's recommended pipeline: fast parallel PD mean-field to get a
/// good initialization, then naive mean-field fine-tuning.
pub fn pd_then_naive(
    g: &FactorGraph,
    m: &DualModel,
    pd_iters: usize,
    naive_iters: usize,
    tol: f64,
) -> MeanFieldResult {
    let (eta, _, pd_done) = primal_dual(m, pd_iters, tol);
    // seed naive MF with the PD solution
    let n = g.num_vars();
    let mut mu = eta;
    let mut iters = pd_done;
    for it in 0..naive_iters {
        iters += 1;
        let mut max_dx: f64 = 0.0;
        for v in 0..n {
            let mut z = g.unary(v);
            for &id in g.incident(v) {
                let f = g.factor(id).unwrap();
                let (mu_o, first) = if f.v1 == v { (mu[f.v2], true) } else { (mu[f.v1], false) };
                for (o, w) in [(0usize, 1.0 - mu_o), (1usize, mu_o)] {
                    let ratio = if first {
                        (f.table[1][o] / f.table[0][o]).ln()
                    } else {
                        (f.table[o][1] / f.table[o][0]).ln()
                    };
                    z += w * ratio;
                }
            }
            let new = sigmoid(z);
            max_dx = max_dx.max((new - mu[v]).abs());
            mu[v] = new;
        }
        if max_dx < tol {
            let _ = it;
            break;
        }
    }
    let fe = free_energy(g, &mu);
    MeanFieldResult {
        mu,
        iters,
        free_energy: fe,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::exact;
    use crate::workloads;

    #[test]
    fn naive_exact_on_independent_variables() {
        let mut g = FactorGraph::new(4);
        for v in 0..4 {
            g.set_unary(v, 0.3 * (v as f64 + 1.0));
        }
        let r = naive(&g, 100, 1e-12);
        let want = exact::enumerate(&g);
        for v in 0..4 {
            assert!((r.mu[v] - want.marginals[v]).abs() < 1e-10);
        }
        // free energy equals −log Z exactly when q == p
        assert!((r.free_energy + want.log_z).abs() < 1e-9);
    }

    #[test]
    fn naive_free_energy_upper_bounds_neg_logz() {
        let g = workloads::random_graph(8, 2, 0.7, 3);
        let r = naive(&g, 200, 1e-10);
        let want = exact::enumerate(&g);
        assert!(
            r.free_energy >= -want.log_z - 1e-9,
            "F={} < -logZ={}",
            r.free_energy,
            -want.log_z
        );
    }

    #[test]
    fn pd_mean_field_agrees_on_weak_coupling() {
        // weakly coupled: PD-MF and exact marginals should be close
        let g = workloads::ising_grid(4, 4, 0.05, 0.2);
        let m = crate::duality::DualModel::from_graph(&g);
        // Lemma 6: PD-MF optimizes an upper bound on the true MF
        // objective, so allow a visible-but-small bias (the logz bench
        // quantifies it precisely).
        let (eta, _, _) = primal_dual(&m, 500, 1e-12);
        let want = exact::enumerate(&g);
        for v in 0..16 {
            assert!(
                (eta[v] - want.marginals[v]).abs() < 0.05,
                "v={v}: {} vs {}",
                eta[v],
                want.marginals[v]
            );
        }
    }

    #[test]
    fn pd_then_naive_no_worse_than_pd_alone() {
        let g = workloads::random_graph(10, 3, 0.8, 9);
        let m = crate::duality::DualModel::from_graph(&g);
        let (eta, _, _) = primal_dual(&m, 300, 1e-10);
        let fe_pd = free_energy(&g, &eta);
        let r = pd_then_naive(&g, &m, 300, 300, 1e-10);
        assert!(
            r.free_energy <= fe_pd + 1e-6,
            "fine-tune worsened: {} vs {}",
            r.free_energy,
            fe_pd
        );
    }

    #[test]
    fn pd_mean_field_converges() {
        let g = workloads::ising_grid(6, 6, 0.3, 0.1);
        let m = crate::duality::DualModel::from_graph(&g);
        let (eta, xi, iters) = primal_dual(&m, 2000, 1e-10);
        assert!(iters < 2000, "did not converge");
        assert!(eta.iter().all(|&e| (0.0..=1.0).contains(&e)));
        assert!(xi.iter().all(|&x| (0.0..=1.0).contains(&x)));
    }
}
