//! MAP inference: ICM baseline and the paper's parallel EM (§5.3).
//!
//! The EM updates on the dualized model are
//!
//!   `x ← argmax_x h(x) e^{⟨s(x), ξ⟩}`   — per-variable threshold, parallel
//!   `ξ ← E[r(θ) | x]`                   — per-factor expectation, parallel
//!
//! Both steps are coordinate-free (every variable / factor at once) and
//! the objective `log p(x)` is non-decreasing (standard EM argument on the
//! mixture representation `p(x) = h(x) Σ_θ g(θ) e^{⟨s(x),r(θ)⟩}`), unlike
//! naive "flip everything in parallel" ICM which can oscillate.

use crate::duality::DualModel;
use crate::graph::FactorGraph;
use crate::rng::sigmoid;

/// Iterated conditional modes (sequential coordinate ascent) — baseline.
pub fn icm(g: &FactorGraph, init: &[u8], max_iters: usize) -> (Vec<u8>, usize) {
    let mut x = init.to_vec();
    for it in 0..max_iters {
        let mut changed = false;
        for v in 0..g.num_vars() {
            let want = (g.conditional_logodds(v, &x) > 0.0) as u8;
            if want != x[v] {
                x[v] = want;
                changed = true;
            }
        }
        if !changed {
            return (x, it + 1);
        }
    }
    (x, max_iters)
}

/// Parallel primal–dual EM for MAP (§5.3). Returns the assignment and the
/// number of iterations until the fixed point.
pub fn pd_em(m: &DualModel, init: &[u8], max_iters: usize) -> (Vec<u8>, usize) {
    let n = m.num_vars();
    let mut x = init.to_vec();
    // ξ_i = E[θ_i | x] — maintained per factor slot
    let mut xi = vec![0.0f64; m.factor_slots()];
    for it in 0..max_iters {
        // E-step over θ: ξ ← E[θ | x]  (parallel over factors)
        for (slot, e) in m.entries() {
            xi[slot] = sigmoid(m.theta_logodds(e, &x));
        }
        // M-step over x: x_v ← 1{ base_field + Σ ξ_i β_{i,v} > 0 }  (parallel)
        let mut changed = false;
        for v in 0..n {
            let mut z = m.base_field(v);
            for &(slot, beta) in m.incidence(v) {
                z += xi[slot as usize] * beta;
            }
            let want = (z > 0.0) as u8;
            if want != x[v] {
                x[v] = want;
                changed = true;
            }
        }
        if !changed {
            return (x, it + 1);
        }
    }
    (x, max_iters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::duality::DualModel;
    use crate::inference::exact;
    use crate::workloads;

    #[test]
    fn icm_fixed_point_is_local_optimum() {
        let g = workloads::random_graph(10, 2, 1.0, 5);
        let (x, _) = icm(&g, &vec![0u8; 10], 200);
        // no single flip improves
        let lp = g.log_prob_unnorm(&x);
        for v in 0..10 {
            let mut y = x.clone();
            y[v] ^= 1;
            assert!(g.log_prob_unnorm(&y) <= lp + 1e-12, "flip {v} improves");
        }
    }

    #[test]
    fn pd_em_monotone_objective() {
        let g = workloads::random_graph(12, 3, 1.0, 8);
        let m = DualModel::from_graph(&g);
        let mut x = vec![0u8; 12];
        let mut prev = g.log_prob_unnorm(&x);
        // run EM one iteration at a time and check log p(x) never decreases
        for _ in 0..50 {
            let (nx, iters) = pd_em(&m, &x, 1);
            let cur = g.log_prob_unnorm(&nx);
            assert!(
                cur >= prev - 1e-9,
                "EM decreased objective: {prev} -> {cur}"
            );
            if nx == x && iters == 1 {
                break;
            }
            x = nx;
            prev = cur;
        }
    }

    #[test]
    fn pd_em_finds_exact_map_on_strong_unaries() {
        // strong unary fields dominate: MAP is the unary sign pattern
        let mut g = workloads::ising_grid(4, 4, 0.1, 0.0);
        for v in 0..16 {
            g.set_unary(v, if v % 3 == 0 { 4.0 } else { -4.0 });
        }
        let m = DualModel::from_graph(&g);
        let (x, _) = pd_em(&m, &vec![0u8; 16], 100);
        let want = exact::enumerate(&g).map;
        assert_eq!(x, want);
    }

    #[test]
    fn pd_em_matches_icm_quality_with_restarts() {
        // ferromagnetic + positive field ⇒ all-ones is the MAP. ICM finds
        // it from zeros; PD-EM — like any EM — is a local method whose
        // basin depends on the init, so give it the standard overdispersed
        // restarts and take the best.
        let g = workloads::ising_grid(5, 5, 0.4, 0.5);
        let m = DualModel::from_graph(&g);
        // both all-zeros and all-ones are single-flip-stable; the MAP is
        // all-ones (positive field): restarts must find it for both methods
        assert!(g.log_prob_unnorm(&vec![1u8; 25]) > g.log_prob_unnorm(&vec![0u8; 25]));
        let best_of = |f: &dyn Fn(&[u8]) -> Vec<u8>| -> f64 {
            [vec![0u8; 25], vec![1u8; 25]]
                .iter()
                .map(|init| g.log_prob_unnorm(&f(init)))
                .fold(f64::NEG_INFINITY, f64::max)
        };
        let lp_icm = best_of(&|init| icm(&g, init, 300).0);
        let lp_em = best_of(&|init| pd_em(&m, init, 300).0);
        let lp_ones = g.log_prob_unnorm(&vec![1u8; 25]);
        assert!((lp_icm - lp_ones).abs() < 1e-9, "{lp_icm} vs {lp_ones}");
        assert!((lp_em - lp_ones).abs() < 1e-9, "{lp_em} vs {lp_ones}");
    }

    #[test]
    fn pd_em_terminates_quickly_on_tree() {
        let g = workloads::random_tree(30, 1.0, 2);
        let m = DualModel::from_graph(&g);
        let (_, iters) = pd_em(&m, &vec![0u8; 30], 500);
        assert!(iters < 100, "iters={iters}");
    }
}
