//! Inference algorithms (§5) and exact oracles used to validate them.
//!
//! * [`exact`] — brute-force enumeration (≤ ~20 vars) and a transfer-matrix
//!   solver for grids: ground truth for every sampler/estimator test.
//! * [`bp`] — belief propagation on forests: sum-product marginals + log Z,
//!   max-product MAP, and forward-filter/backward-sample exact tree
//!   sampling (the §5.4 blocking primitive).
//! * [`mean_field`] — naive coordinate-ascent mean-field and the paper's
//!   parallel primal–dual mean-field (§5.3, Lemma 6 upper bound).
//! * [`em_map`] — ICM baseline and the paper's parallel EM MAP (§5.3).
//! * [`partition`] — §5.2 log-partition estimators: unbiased `V(x, θ)` and
//!   the `E[log V]` lower bound.

pub mod bp;
pub mod em_map;
pub mod exact;
pub mod mean_field;
pub mod partition;
