//! §5.2: log-partition-function estimation from the primal–dual chain.
//!
//! With `p̃(x, θ) = h(x) g(θ) e^{⟨s(x), r(θ)⟩}` the statistic
//!
//!   `V(x, θ) = G(x) H(θ) e^{−⟨s(x), r(θ)⟩}`
//!
//! is an unbiased estimator of `Z` under the joint; `E[log V] ≤ log Z` with
//! gap exactly the mutual information `𝕀(x, θ)` (the paper's uncertainty
//! measure). On our dualized binary MRF all three pieces factorize:
//!
//!   `log G(x) = Σ_i log(1 + e^{q_i + β_{i,1} x_{v₁} + β_{i,2} x_{v₂}})`
//!   `log H(θ) = Σ_v log(1 + e^{a_v + Σ_{i∋v} θ_i β_{i,v}})`
//!   `⟨s(x), r(θ)⟩ = Σ_v x_v · Σ_{i∋v} θ_i β_{i,v}`
//!
//! Note `Z` here normalizes the *dualized* joint, which differs from the
//! original graph's `Z` by the per-factor dualization scale constants;
//! [`dualization_log_scale`] computes the offset so estimates are
//! comparable to [`crate::inference::exact::enumerate`] on the graph.

use crate::duality::DualModel;
use crate::graph::FactorGraph;
use crate::samplers::{PdSampler, Sampler};
use crate::rng::Pcg64;

/// `log1p(exp(z))` without overflow.
#[inline]
fn log1p_exp(z: f64) -> f64 {
    if z > 35.0 {
        z
    } else if z < -35.0 {
        z.exp()
    } else {
        z.exp().ln_1p()
    }
}

/// `log V(x, θ)` for one joint state (see module docs).
pub fn log_v(m: &DualModel, x: &[u8], theta: &[u8]) -> f64 {
    let mut log_g = 0.0;
    for (_, e) in m.entries() {
        log_g += log1p_exp(m.theta_logodds(e, x));
    }
    let mut log_h = 0.0;
    let mut inner = 0.0;
    for v in 0..m.num_vars() {
        let z = m.x_logodds(v, theta); // a_v + Σ θ β
        log_h += log1p_exp(z);
        inner += x[v] as f64 * (z - m.base_field(v)); // x_v · Σ θ β
    }
    log_g + log_h - inner
}

/// Per-factor log scale between graph tables and their dual reconstruction:
/// `Σ_i log( table_i(0,0) / Σ_θ dual_i(0,0,θ) )`-style offset so that
/// `log Z_graph = log Z_dual + dualization_log_scale`.
pub fn dualization_log_scale(g: &FactorGraph, m: &DualModel) -> f64 {
    let mut offset = 0.0;
    for (slot, e) in m.entries() {
        let f = g.factor(slot).expect("graph/model slot mismatch");
        // dual mass at (x1, x2) = (0, 0): θ=0 contributes 1, θ=1 contributes e^q
        let dual00 = 1.0 + e.q.exp();
        offset += (f.table[0][0] / dual00).ln();
    }
    offset
}

/// Estimate of `E[log V]` (a lower bound on `log Z_dual`) from `samples`
/// sweeps of a PD chain after `burn_in`, together with the sample std-err.
pub struct LogZEstimate {
    /// Mean of `log V` (lower bound on the dual log Z).
    pub lower_bound: f64,
    /// Standard error of the `lower_bound` mean.
    pub std_err: f64,
    /// Unbiased (but high-variance) estimate `log mean(V)`, computed
    /// stably in the log domain.
    pub log_mean_v: f64,
    /// Number of post-burn-in sweeps averaged.
    pub samples: usize,
}

/// Run a PD chain and estimate the §5.2 quantities *for the dual model*.
/// Add [`dualization_log_scale`] to compare against the graph's log Z.
pub fn estimate_log_z(
    m: &DualModel,
    burn_in: usize,
    samples: usize,
    seed: u64,
) -> LogZEstimate {
    let mut sampler = PdSampler::from_model(m.clone());
    let mut rng = Pcg64::seed(seed);
    for _ in 0..burn_in {
        sampler.sweep(&mut rng);
    }
    let mut vals = Vec::with_capacity(samples);
    for _ in 0..samples {
        sampler.sweep(&mut rng);
        vals.push(log_v(m, sampler.state(), sampler.theta()));
    }
    let mut w = crate::util::stats::Welford::new();
    for &v in &vals {
        w.push(v);
    }
    let log_mean_v = crate::inference::exact::log_sum_exp(&vals) - (samples as f64).ln();
    LogZEstimate {
        lower_bound: w.mean(),
        std_err: w.std_dev() / (samples as f64).sqrt(),
        log_mean_v,
        samples,
    }
}

/// Exact `log Z` of the dual joint by enumeration (tests only; ≤ ~12+12).
pub fn exact_dual_log_z(m: &DualModel) -> f64 {
    let n = m.num_vars();
    let slots: Vec<usize> = m.entries().map(|(s, _)| s).collect();
    let f = slots.len();
    assert!(n + f <= 24, "enumeration blow-up");
    let mut terms = Vec::with_capacity(1 << (n + f));
    let mut x = vec![0u8; n];
    let mut theta = vec![0u8; m.factor_slots()];
    for xm in 0..1usize << n {
        for (v, xv) in x.iter_mut().enumerate() {
            *xv = ((xm >> v) & 1) as u8;
        }
        for tm in 0..1usize << f {
            for (bit, &slot) in slots.iter().enumerate() {
                theta[slot] = ((tm >> bit) & 1) as u8;
            }
            terms.push(m.log_joint_unnorm(&x, &theta));
        }
    }
    crate::inference::exact::log_sum_exp(&terms)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::exact;
    use crate::workloads;

    #[test]
    fn dual_log_z_matches_graph_up_to_scale() {
        let g = workloads::random_graph(5, 1, 0.8, 17);
        let m = DualModel::from_graph(&g);
        let lz_dual = exact_dual_log_z(&m);
        let lz_graph = exact::enumerate(&g).log_z;
        let offset = dualization_log_scale(&g, &m);
        assert!(
            (lz_graph - (lz_dual + offset)).abs() < 1e-9,
            "graph {lz_graph} dual {lz_dual} offset {offset}"
        );
    }

    #[test]
    fn log_v_expectation_bounds_log_z() {
        let g = workloads::ising_grid(3, 3, 0.3, 0.1);
        let m = DualModel::from_graph(&g);
        let est = estimate_log_z(&m, 500, 4000, 3);
        let lz = exact_dual_log_z(&m);
        // lower bound property (allow 4 std errs of slack)
        assert!(
            est.lower_bound <= lz + 4.0 * est.std_err,
            "E[logV]={} > logZ={}",
            est.lower_bound,
            lz
        );
        // and it should not be absurdly loose on a small weak model
        assert!(
            est.lower_bound > lz - 4.0,
            "bound too loose: {} vs {}",
            est.lower_bound,
            lz
        );
    }

    #[test]
    fn log_mean_v_near_log_z() {
        // unbiased estimator: on a tiny weakly coupled model the log-mean
        // should land close to the exact value with many samples
        let g = workloads::ising_grid(2, 2, 0.2, 0.0);
        let m = DualModel::from_graph(&g);
        let est = estimate_log_z(&m, 500, 20_000, 11);
        let lz = exact_dual_log_z(&m);
        assert!(
            (est.log_mean_v - lz).abs() < 0.1,
            "logmeanV {} vs logZ {}",
            est.log_mean_v,
            lz
        );
    }

    #[test]
    fn log1p_exp_stable() {
        assert!((log1p_exp(0.0) - 2f64.ln().abs()).abs() < 1e-12 + 2f64.ln());
        assert_eq!(log1p_exp(800.0), 800.0);
        assert!(log1p_exp(-800.0) >= 0.0);
        assert!((log1p_exp(1.0) - (1.0 + 1f64.exp()).ln()).abs() < 1e-12);
    }
}
