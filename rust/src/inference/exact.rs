//! Exact inference oracles.
//!
//! [`enumerate`] brute-forces the full joint (fine to ~20 variables) and is
//! the ground truth every sampler and estimator is validated against.
//! [`grid_transfer_matrix`] computes log Z exactly for `rows × cols` Ising
//! grids by sweeping a column transfer operator — exponential only in the
//! number of rows, so 16×N grids are exact in milliseconds. It exists so
//! mixing-time experiments on non-toy grids can still report calibrated
//! marginals/log Z.

use crate::graph::FactorGraph;

/// Result of brute-force enumeration.
#[derive(Clone, Debug)]
pub struct ExactResult {
    /// `P(x_v = 1)` for every variable.
    pub marginals: Vec<f64>,
    /// Log partition function of the *unnormalized* model.
    pub log_z: f64,
    /// MAP assignment (ties broken toward lower binary code).
    pub map: Vec<u8>,
    /// Unnormalized log-probability of the MAP assignment.
    pub map_log_prob: f64,
}

/// Enumerate all `2^n` assignments. Panics above 24 variables.
pub fn enumerate(g: &FactorGraph) -> ExactResult {
    let n = g.num_vars();
    assert!(n <= 24, "enumeration limited to 24 variables, got {n}");
    let mut x = vec![0u8; n];
    let mut log_probs = Vec::with_capacity(1 << n);
    let mut best = f64::NEG_INFINITY;
    let mut best_idx = 0usize;
    for code in 0..1usize << n {
        for (v, xv) in x.iter_mut().enumerate() {
            *xv = ((code >> v) & 1) as u8;
        }
        let lp = g.log_prob_unnorm(&x);
        if lp > best {
            best = lp;
            best_idx = code;
        }
        log_probs.push(lp);
    }
    let log_z = log_sum_exp(&log_probs);
    let mut marginals = vec![0.0; n];
    for (code, &lp) in log_probs.iter().enumerate() {
        let p = (lp - log_z).exp();
        for (v, m) in marginals.iter_mut().enumerate() {
            if (code >> v) & 1 == 1 {
                *m += p;
            }
        }
    }
    let map: Vec<u8> = (0..n).map(|v| ((best_idx >> v) & 1) as u8).collect();
    ExactResult {
        marginals,
        log_z,
        map,
        map_log_prob: best,
    }
}

/// Numerically stable `log Σ exp`.
pub fn log_sum_exp(xs: &[f64]) -> f64 {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if m == f64::NEG_INFINITY {
        return m;
    }
    m + xs.iter().map(|x| (x - m).exp()).sum::<f64>().ln()
}

/// Exact log Z of a uniform-coupling Ising grid with uniform field, by
/// column-to-column transfer: state = one column (2^rows configurations).
///
/// The graph must be exactly `workloads::ising_grid(rows, cols, beta, h)`;
/// this recomputes from the parameters rather than walking the graph.
pub fn grid_transfer_matrix(rows: usize, cols: usize, beta: f64, h: f64) -> f64 {
    assert!(rows <= 16, "transfer matrix limited to 16 rows");
    let states = 1usize << rows;
    let bit = |s: usize, r: usize| ((s >> r) & 1) as f64;

    // within-column energy: vertical couplings + fields
    let col_weight = |s: usize| -> f64 {
        let mut e = 0.0;
        for r in 0..rows {
            e += h * bit(s, r);
            if r + 1 < rows {
                // ising: +β agree, −β disagree ⇒ β(2·agree−1)
                let agree = if ((s >> r) ^ (s >> (r + 1))) & 1 == 0 { 1.0 } else { -1.0 };
                e += beta * agree;
            }
        }
        e
    };
    // between-column energy: horizontal couplings
    let pair_weight = |s: usize, t: usize| -> f64 {
        let mut e = 0.0;
        for r in 0..rows {
            let agree = if ((s >> r) ^ (t >> r)) & 1 == 0 { 1.0 } else { -1.0 };
            e += beta * agree;
        }
        e
    };

    // log-domain vector iteration
    let mut logv: Vec<f64> = (0..states).map(col_weight).collect();
    let mut scratch = vec![0.0f64; states];
    for _ in 1..cols {
        for (t, out) in scratch.iter_mut().enumerate() {
            let terms: Vec<f64> = (0..states)
                .map(|s| logv[s] + pair_weight(s, t))
                .collect();
            *out = log_sum_exp(&terms) + col_weight(t);
        }
        std::mem::swap(&mut logv, &mut scratch);
    }
    log_sum_exp(&logv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::PairFactor;
    use crate::workloads;

    #[test]
    fn single_variable() {
        let mut g = FactorGraph::new(1);
        g.set_unary(0, 0.7f64.ln()); // odds 0.7 ⇒ P(1) = 0.7/1.7
        let r = enumerate(&g);
        assert!((r.marginals[0] - 0.7 / 1.7).abs() < 1e-12);
        assert!((r.log_z - 1.7f64.ln()).abs() < 1e-12);
        assert_eq!(r.map, vec![0]); // 1.0 > 0.7
    }

    #[test]
    fn two_variable_table() {
        let mut g = FactorGraph::new(2);
        g.add_factor(PairFactor::new(0, 1, [[1.0, 2.0], [3.0, 4.0]]));
        let r = enumerate(&g);
        let z = 10.0f64;
        assert!((r.log_z - z.ln()).abs() < 1e-12);
        assert!((r.marginals[0] - (3.0 + 4.0) / z).abs() < 1e-12);
        assert!((r.marginals[1] - (2.0 + 4.0) / z).abs() < 1e-12);
        assert_eq!(r.map, vec![1, 1]);
    }

    #[test]
    fn ising_pair_symmetry() {
        let mut g = FactorGraph::new(2);
        g.add_factor(PairFactor::ising(0, 1, 0.8));
        let r = enumerate(&g);
        assert!((r.marginals[0] - 0.5).abs() < 1e-12);
        assert!((r.marginals[1] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn transfer_matrix_matches_enumeration() {
        for (rows, cols, beta, h) in [(2, 3, 0.4, 0.1), (3, 3, 0.25, -0.2), (4, 2, 0.5, 0.0)] {
            let g = workloads::ising_grid(rows, cols, beta, h);
            let want = enumerate(&g).log_z;
            let got = grid_transfer_matrix(rows, cols, beta, h);
            assert!(
                (want - got).abs() < 1e-9,
                "{rows}x{cols} β={beta} h={h}: {want} vs {got}"
            );
        }
    }

    #[test]
    fn log_sum_exp_stability() {
        assert!((log_sum_exp(&[1000.0, 1000.0]) - (1000.0 + 2f64.ln())).abs() < 1e-9);
        assert_eq!(log_sum_exp(&[]), f64::NEG_INFINITY);
        assert!((log_sum_exp(&[-1e308, 0.0]) - 0.0).abs() < 1e-12);
    }
}
