//! Belief propagation on forests (acyclic factor subsets).
//!
//! The §5.4 blocking machinery needs three exact tree primitives, all
//! provided here over the *same* upward message pass:
//!
//! * [`Forest::sum_product`] — marginals + log Z (tree mean-field / logZ),
//! * [`Forest::max_product`] — MAP with backtracking (blocked EM),
//! * [`Forest::sample`] — forward-filter backward-sample: one exact joint
//!   draw of all tree variables (blocked PD Gibbs).
//!
//! A [`Forest`] is built from a [`FactorGraph`] plus a subset of factor
//! ids; construction fails if the subset contains a cycle. Unary fields
//! are *inputs* to each call (not baked in) because the blocked sampler
//! re-derives them each sweep from the off-tree dual state.

use crate::graph::{FactorGraph, FactorId, VarId};
use crate::rng::{Pcg64, RngCore};

use super::exact::log_sum_exp;

#[derive(Clone, Debug)]
struct TreeEdge {
    v1: VarId,
    v2: VarId,
    /// `log_table[x1][x2]`.
    log_table: [[f64; 2]; 2],
}

/// An acyclic collection of pairwise factors over `n` variables.
#[derive(Clone, Debug)]
pub struct Forest {
    n: usize,
    edges: Vec<TreeEdge>,
    /// BFS order: `(node, Some(edge index to parent))`, roots first with None.
    order: Vec<(VarId, Option<usize>)>,
    /// `parent[v]` = (parent var, edge index) for non-roots.
    parent: Vec<Option<(VarId, usize)>>,
}

impl Forest {
    /// Build from a subset of the graph's factors. Returns `Err` with the
    /// offending factor if the subset is cyclic (a factor joins two
    /// already-connected variables).
    pub fn from_factors(g: &FactorGraph, ids: &[FactorId]) -> Result<Forest, FactorId> {
        let n = g.num_vars();
        let mut uf = crate::util::UnionFind::new(n);
        let mut edges = Vec::with_capacity(ids.len());
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
        for &id in ids {
            let f = g.factor(id).expect("dead factor id in forest");
            if !uf.union(f.v1, f.v2) {
                return Err(id);
            }
            let mut log_table = [[0.0; 2]; 2];
            for (a, row) in log_table.iter_mut().enumerate() {
                for (b, cell) in row.iter_mut().enumerate() {
                    *cell = f.table[a][b].ln();
                }
            }
            let e = edges.len();
            edges.push(TreeEdge {
                v1: f.v1,
                v2: f.v2,
                log_table,
            });
            adj[f.v1].push(e);
            adj[f.v2].push(e);
        }
        // BFS forest
        let mut order = Vec::with_capacity(n);
        let mut parent = vec![None; n];
        let mut seen = vec![false; n];
        let mut queue = std::collections::VecDeque::new();
        for root in 0..n {
            if seen[root] {
                continue;
            }
            seen[root] = true;
            order.push((root, None));
            queue.push_back(root);
            while let Some(v) = queue.pop_front() {
                for &e in &adj[v] {
                    let other = if edges[e].v1 == v { edges[e].v2 } else { edges[e].v1 };
                    if !seen[other] {
                        seen[other] = true;
                        parent[other] = Some((v, e));
                        order.push((other, Some(e)));
                        queue.push_back(other);
                    }
                }
            }
        }
        Ok(Forest {
            n,
            edges,
            order,
            parent,
        })
    }

    /// Spanning forest of the whole graph (greedy first-come edges);
    /// returns the chosen factor ids — the default §5.4 blocking choice.
    pub fn spanning_ids(g: &FactorGraph) -> Vec<FactorId> {
        let mut uf = crate::util::UnionFind::new(g.num_vars());
        g.factors()
            .filter(|(_, f)| uf.union(f.v1, f.v2))
            .map(|(id, _)| id)
            .collect()
    }

    /// Number of tree edges in the forest.
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    #[inline]
    fn edge_log(&self, e: usize, xv: usize, v: VarId, xo: usize) -> f64 {
        let ed = &self.edges[e];
        if ed.v1 == v {
            ed.log_table[xv][xo]
        } else {
            ed.log_table[xo][xv]
        }
    }

    /// Upward pass. `up[v][s]` = log message from `v`'s subtree to its
    /// parent, as a function of the *parent's* state `s`; `local[v][s]` =
    /// field(v, s) + Σ child messages (a function of `v`'s own state).
    fn upward<const MAX: bool>(&self, fields: &[f64]) -> (Vec<[f64; 2]>, Vec<[f64; 2]>) {
        assert_eq!(fields.len(), self.n);
        let mut up = vec![[0.0f64; 2]; self.n];
        let mut local = vec![[0.0f64; 2]; self.n];
        for v in 0..self.n {
            local[v] = [0.0, fields[v]];
        }
        // children precede parents in reverse BFS order
        for &(v, pe) in self.order.iter().rev() {
            if let Some(e) = pe {
                let (p, _) = self.parent[v].unwrap();
                debug_assert_eq!(self.parent[v].unwrap().1, e);
                for s in 0..2 {
                    let t0 = local[v][0] + self.edge_log(e, 0, v, s);
                    let t1 = local[v][1] + self.edge_log(e, 1, v, s);
                    up[v][s] = if MAX {
                        t0.max(t1)
                    } else {
                        log_sum_exp(&[t0, t1])
                    };
                }
                local[p][0] += up[v][0];
                local[p][1] += up[v][1];
            }
        }
        (up, local)
    }

    /// Exact marginals `P(x_v = 1)` and log Z, given per-variable fields
    /// (log-odds: state 1 contributes `fields[v]`, state 0 contributes 0).
    pub fn sum_product(&self, fields: &[f64]) -> (Vec<f64>, f64) {
        let (up, local) = self.upward::<false>(fields);
        // downward pass: dn[v][s] = log message arriving at v from above
        let mut dn = vec![[0.0f64; 2]; self.n];
        let mut log_z = 0.0;
        for &(v, pe) in &self.order {
            match pe {
                None => {
                    log_z += log_sum_exp(&[local[v][0], local[v][1]]);
                }
                Some(e) => {
                    let (p, _) = self.parent[v].unwrap();
                    // parent belief minus v's own upward contribution
                    for s in 0..2 {
                        let without = [
                            local[p][0] - up[v][0] + dn[p][0] + self.edge_log(e, s, v, 0),
                            local[p][1] - up[v][1] + dn[p][1] + self.edge_log(e, s, v, 1),
                        ];
                        dn[v][s] = log_sum_exp(&without);
                    }
                }
            }
        }
        let marginals = (0..self.n)
            .map(|v| {
                let b = [local[v][0] + dn[v][0], local[v][1] + dn[v][1]];
                let z = log_sum_exp(&b);
                (b[1] - z).exp()
            })
            .collect();
        (marginals, log_z)
    }

    /// Exact MAP assignment (max-product + backtracking).
    pub fn max_product(&self, fields: &[f64]) -> Vec<u8> {
        let (_, local) = self.upward::<true>(fields);
        let mut x = vec![0u8; self.n];
        for &(v, pe) in &self.order {
            match pe {
                None => {
                    x[v] = (local[v][1] > local[v][0]) as u8;
                }
                Some(e) => {
                    let (p, _) = self.parent[v].unwrap();
                    let s = x[p] as usize;
                    let t0 = local[v][0] + self.edge_log(e, 0, v, s);
                    let t1 = local[v][1] + self.edge_log(e, 1, v, s);
                    x[v] = (t1 > t0) as u8;
                }
            }
        }
        x
    }

    /// One exact joint sample (forward-filter backward-sample).
    pub fn sample(&self, fields: &[f64], rng: &mut Pcg64) -> Vec<u8> {
        let (_, local) = self.upward::<false>(fields);
        let mut x = vec![0u8; self.n];
        for &(v, pe) in &self.order {
            let (b0, b1) = match pe {
                None => (local[v][0], local[v][1]),
                Some(e) => {
                    let (p, _) = self.parent[v].unwrap();
                    let s = x[p] as usize;
                    (
                        local[v][0] + self.edge_log(e, 0, v, s),
                        local[v][1] + self.edge_log(e, 1, v, s),
                    )
                }
            };
            let p1 = crate::rng::sigmoid(b1 - b0);
            x[v] = rng.bernoulli(p1) as u8;
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::exact;
    use crate::util::proptest::{check, Gen};
    use crate::workloads;

    fn tree_fields(g: &FactorGraph) -> Vec<f64> {
        (0..g.num_vars()).map(|v| g.unary(v)).collect()
    }

    #[test]
    fn sum_product_matches_enumeration_on_random_trees() {
        for seed in 0..5 {
            let g = workloads::random_tree(8, 0.9, seed);
            let ids: Vec<_> = g.factors().map(|(id, _)| id).collect();
            let forest = Forest::from_factors(&g, &ids).unwrap();
            let (marg, log_z) = forest.sum_product(&tree_fields(&g));
            let want = exact::enumerate(&g);
            assert!((log_z - want.log_z).abs() < 1e-9, "seed {seed}");
            for v in 0..8 {
                assert!(
                    (marg[v] - want.marginals[v]).abs() < 1e-9,
                    "seed {seed} v {v}"
                );
            }
        }
    }

    #[test]
    fn max_product_matches_enumeration() {
        for seed in 5..10 {
            let g = workloads::random_tree(7, 1.2, seed);
            let ids: Vec<_> = g.factors().map(|(id, _)| id).collect();
            let forest = Forest::from_factors(&g, &ids).unwrap();
            let map = forest.max_product(&tree_fields(&g));
            let want = exact::enumerate(&g);
            let got_lp = g.log_prob_unnorm(&map);
            assert!(
                (got_lp - want.map_log_prob).abs() < 1e-9,
                "seed {seed}: {got_lp} vs {}",
                want.map_log_prob
            );
        }
    }

    #[test]
    fn sampling_matches_marginals() {
        let g = workloads::random_tree(6, 0.8, 21);
        let ids: Vec<_> = g.factors().map(|(id, _)| id).collect();
        let forest = Forest::from_factors(&g, &ids).unwrap();
        let fields = tree_fields(&g);
        let (marg, _) = forest.sum_product(&fields);
        let mut rng = Pcg64::seed(3);
        let mut counts = vec![0u64; 6];
        let reps = 200_000;
        for _ in 0..reps {
            let x = forest.sample(&fields, &mut rng);
            for (v, &xv) in x.iter().enumerate() {
                counts[v] += xv as u64;
            }
        }
        for v in 0..6 {
            let freq = counts[v] as f64 / reps as f64;
            assert!((freq - marg[v]).abs() < 0.005, "v={v}: {freq} vs {}", marg[v]);
        }
    }

    #[test]
    fn cycle_detected() {
        let g = workloads::ising_grid(2, 2, 0.3, 0.0);
        let ids: Vec<_> = g.factors().map(|(id, _)| id).collect();
        assert_eq!(ids.len(), 4); // the 4-cycle
        assert!(Forest::from_factors(&g, &ids).is_err());
        assert!(Forest::from_factors(&g, &ids[..3]).is_ok());
    }

    #[test]
    fn spanning_ids_are_acyclic_and_maximal() {
        let g = workloads::ising_grid(4, 5, 0.3, 0.0);
        let ids = Forest::spanning_ids(&g);
        assert_eq!(ids.len(), g.num_vars() - 1); // connected grid
        assert!(Forest::from_factors(&g, &ids).is_ok());
    }

    #[test]
    fn disconnected_forest_logz() {
        // two disjoint edges + one isolated variable
        let mut g = FactorGraph::new(5);
        g.set_unary(4, 0.5);
        g.add_factor(crate::graph::PairFactor::ising(0, 1, 0.4));
        g.add_factor(crate::graph::PairFactor::ising(2, 3, -0.3));
        let ids: Vec<_> = g.factors().map(|(id, _)| id).collect();
        let forest = Forest::from_factors(&g, &ids).unwrap();
        let (marg, log_z) = forest.sum_product(&tree_fields(&g));
        let want = exact::enumerate(&g);
        assert!((log_z - want.log_z).abs() < 1e-9);
        for v in 0..5 {
            assert!((marg[v] - want.marginals[v]).abs() < 1e-9);
        }
    }

    #[test]
    fn prop_bp_exact_on_random_forests() {
        check("bp == enumeration on forests", 30, |gn: &mut Gen| {
            let n = gn.usize_in(2..=9);
            let g = workloads::random_tree(n, 1.0, gn.u64());
            // drop a random subset of edges to get a strict forest
            let ids: Vec<_> = g
                .factors()
                .map(|(id, _)| id)
                .filter(|_| gn.f64_in(0.0, 1.0) < 0.8)
                .collect();
            let forest = Forest::from_factors(&g, &ids).map_err(|e| format!("cycle {e}"))?;
            // build the comparison graph containing only kept factors
            let mut sub = FactorGraph::new(n);
            for v in 0..n {
                sub.set_unary(v, g.unary(v));
            }
            for &id in &ids {
                sub.add_factor(g.factor(id).unwrap().clone());
            }
            let want = exact::enumerate(&sub);
            let (marg, log_z) = forest.sum_product(&tree_fields(&g));
            if (log_z - want.log_z).abs() > 1e-8 {
                return Err(format!("logZ {log_z} vs {}", want.log_z));
            }
            for v in 0..n {
                if (marg[v] - want.marginals[v]).abs() > 1e-8 {
                    return Err(format!("marginal v={v}"));
                }
            }
            Ok(())
        });
    }
}
