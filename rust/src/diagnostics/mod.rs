//! Convergence diagnostics: PSRF (Gelman–Rubin), ESS, mixing times.
//!
//! The paper's §6 metric is the *potential scale reduction factor* over 10
//! parallel chains, and the mixing time is "the first index so that the
//! PSRF remains below some specified threshold afterwards" (1.01 in
//! Fig 2). [`mixing_time`] implements exactly that extraction; [`psrf`] /
//! [`psrf_at`] the split-free multi-chain PSRF with the standard
//! second-half-window convention.

mod ess;
mod psrf;

pub use ess::{autocorrelation, effective_sample_size};
pub use psrf::{mixing_time, mixing_time_multi, psrf, psrf_at, psrf_series, psrf_window, MixingResult};
