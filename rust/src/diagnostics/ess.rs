//! Autocorrelation and effective sample size.
//!
//! ESS complements PSRF: PSRF certifies *between-chain* agreement, ESS
//! quantifies *within-chain* information content. The benches report both
//! (`sweeps-to-PSRF<1.01` for the paper's headline plot, ESS/sweep for the
//! throughput-normalized comparison).

/// Lag-`k` autocorrelations of one trace, up to `max_lag` (biased, FFT-free
/// — traces in the benches are short enough for the O(n·k) loop).
///
/// Degenerate traces (length < 2 — e.g. a freshly-created tenant whose
/// PSRF monitors have recorded at most one sweep) return `vec![1.0]`:
/// ρ₀ = 1 by convention and no lag carries information, instead of
/// panicking the caller (which on the coordinator would be a shared
/// shard thread).
pub fn autocorrelation(trace: &[f64], max_lag: usize) -> Vec<f64> {
    let n = trace.len();
    if n < 2 {
        return vec![1.0];
    }
    let mean = trace.iter().sum::<f64>() / n as f64;
    let var: f64 = trace.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
    if var == 0.0 {
        return vec![0.0; max_lag.min(n - 1) + 1];
    }
    (0..=max_lag.min(n - 1))
        .map(|k| {
            let mut acc = 0.0;
            for t in 0..n - k {
                acc += (trace[t] - mean) * (trace[t + k] - mean);
            }
            acc / (n as f64 * var)
        })
        .collect()
}

/// ESS via Geyer's initial positive sequence: sum consecutive-pair
/// autocorrelations while the pair sums stay positive.
pub fn effective_sample_size(trace: &[f64]) -> f64 {
    let n = trace.len();
    if n < 4 {
        return n as f64;
    }
    let rho = autocorrelation(trace, n / 2);
    let mut tau = 1.0; // integrated autocorrelation time ×1 (ρ₀ = 1)
    let mut k = 1;
    while k + 1 < rho.len() {
        let pair = rho[k] + rho[k + 1];
        if pair <= 0.0 {
            break;
        }
        tau += 2.0 * pair;
        k += 2;
    }
    (n as f64 / tau).min(n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, RngCore};

    #[test]
    fn iid_ess_near_n() {
        let mut rng = Pcg64::seed(1);
        let trace: Vec<f64> = (0..4000).map(|_| rng.normal()).collect();
        let ess = effective_sample_size(&trace);
        assert!(ess > 2500.0, "ess={ess}");
    }

    #[test]
    fn ar1_ess_matches_theory() {
        // AR(1) with coefficient φ: ESS/n ≈ (1−φ)/(1+φ)
        let phi = 0.9;
        let mut rng = Pcg64::seed(2);
        let n = 60_000;
        let mut x = 0.0;
        let trace: Vec<f64> = (0..n)
            .map(|_| {
                x = phi * x + rng.normal();
                x
            })
            .collect();
        let ess = effective_sample_size(&trace);
        let expect = n as f64 * (1.0 - phi) / (1.0 + phi);
        assert!(
            (ess / expect - 1.0).abs() < 0.25,
            "ess={ess} expect≈{expect}"
        );
    }

    #[test]
    fn autocorrelation_lag0_is_one() {
        let mut rng = Pcg64::seed(3);
        let trace: Vec<f64> = (0..500).map(|_| rng.normal()).collect();
        let rho = autocorrelation(&trace, 10);
        assert!((rho[0] - 1.0).abs() < 1e-12);
        assert!(rho[5].abs() < 0.15);
    }

    #[test]
    fn constant_trace_degenerates_gracefully() {
        let trace = vec![2.0; 100];
        let rho = autocorrelation(&trace, 5);
        assert!(rho.iter().all(|&r| r == 0.0));
        let ess = effective_sample_size(&trace);
        assert!(ess <= 100.0);
    }

    #[test]
    fn tiny_traces_do_not_panic() {
        // regression: traces of length < 2 (fresh tenants) used to hit
        // `assert!(n >= 2)`; they now return the degenerate [1.0]
        assert_eq!(autocorrelation(&[], 8), vec![1.0]);
        assert_eq!(autocorrelation(&[3.5], 8), vec![1.0]);
        assert_eq!(autocorrelation(&[3.5], 0), vec![1.0]);
        // and the ESS guards keep composing with it
        assert_eq!(effective_sample_size(&[]), 0.0);
        assert_eq!(effective_sample_size(&[1.0]), 1.0);
        assert_eq!(effective_sample_size(&[1.0, 0.0, 1.0]), 3.0);
    }
}
