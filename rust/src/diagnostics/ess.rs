//! Autocorrelation and effective sample size.
//!
//! ESS complements PSRF: PSRF certifies *between-chain* agreement, ESS
//! quantifies *within-chain* information content. The benches report both
//! (`sweeps-to-PSRF<1.01` for the paper's headline plot, ESS/sweep for the
//! throughput-normalized comparison — and ESS/s for `--mode blocked`,
//! where it is the tracked metric and traces get long enough that the
//! lag-capped path below matters).

/// Hard ceiling on the lags [`effective_sample_size`] examines. Geyer's
/// initial-positive-sequence estimator terminates at the first
/// non-positive pair anyway; lags past the cutoff contribute nothing but
/// O(n) work each, which made the old `autocorrelation(trace, n/2)` call
/// O(n²) on long bench traces. 1024 lags bounds the integrated
/// autocorrelation time at 2049 — far beyond any trace this crate
/// diagnoses (an AR(1) would need φ > 0.999).
pub const ESS_MAX_LAG: usize = 1024;

/// Lag-`k` autocorrelations of one trace, up to `max_lag` (biased, FFT-free
/// — traces in the benches are short enough for the O(n·k) loop).
///
/// Degenerate traces (length < 2 — e.g. a freshly-created tenant whose
/// PSRF monitors have recorded at most one sweep) return `vec![1.0]`:
/// ρ₀ = 1 by convention and no lag carries information, instead of
/// panicking the caller (which on the coordinator would be a shared
/// shard thread). A constant trace (zero variance) follows the same
/// convention — ρ₀ = 1, every positive lag 0 — rather than the
/// self-contradictory all-zero vector it used to return.
pub fn autocorrelation(trace: &[f64], max_lag: usize) -> Vec<f64> {
    let n = trace.len();
    if n < 2 {
        return vec![1.0];
    }
    let mean = trace.iter().sum::<f64>() / n as f64;
    let var: f64 = trace.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
    if var == 0.0 {
        let mut rho = vec![0.0; max_lag.min(n - 1) + 1];
        rho[0] = 1.0;
        return rho;
    }
    (0..=max_lag.min(n - 1))
        .map(|k| {
            let mut acc = 0.0;
            for t in 0..n - k {
                acc += (trace[t] - mean) * (trace[t + k] - mean);
            }
            acc / (n as f64 * var)
        })
        .collect()
}

/// ESS via Geyer's initial positive sequence: sum consecutive-pair
/// autocorrelations while the pair sums stay positive.
///
/// Lags are computed incrementally and on demand — capped at
/// `min(n/2, `[`ESS_MAX_LAG`]`)` and abandoned at the first non-positive
/// Geyer pair — so the cost is O(n · τ) for integrated autocorrelation
/// time τ, not the O(n²) of materializing `autocorrelation(trace, n/2)`
/// first. Equivalence with the materialized estimator is pinned by
/// `long_trace_ess_is_lag_capped_and_matches_uncapped`.
pub fn effective_sample_size(trace: &[f64]) -> f64 {
    let n = trace.len();
    if n < 4 {
        return n as f64;
    }
    let mean = trace.iter().sum::<f64>() / n as f64;
    let var: f64 = trace.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
    if var == 0.0 {
        // constant trace: ρ₀ = 1, no informative lags → τ = 1
        return n as f64;
    }
    let rho = |k: usize| -> f64 {
        let mut acc = 0.0;
        for t in 0..n - k {
            acc += (trace[t] - mean) * (trace[t + k] - mean);
        }
        acc / (n as f64 * var)
    };
    let max_lag = (n / 2).min(ESS_MAX_LAG);
    let mut tau = 1.0; // integrated autocorrelation time ×1 (ρ₀ = 1)
    let mut k = 1;
    while k + 1 <= max_lag {
        let pair = rho(k) + rho(k + 1);
        if pair <= 0.0 {
            break;
        }
        tau += 2.0 * pair;
        k += 2;
    }
    (n as f64 / tau).min(n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, RngCore};

    #[test]
    fn iid_ess_near_n() {
        let mut rng = Pcg64::seed(1);
        let trace: Vec<f64> = (0..4000).map(|_| rng.normal()).collect();
        let ess = effective_sample_size(&trace);
        assert!(ess > 2500.0, "ess={ess}");
    }

    #[test]
    fn ar1_ess_matches_theory() {
        // AR(1) with coefficient φ: ESS/n ≈ (1−φ)/(1+φ)
        let phi = 0.9;
        let mut rng = Pcg64::seed(2);
        let n = 60_000;
        let mut x = 0.0;
        let trace: Vec<f64> = (0..n)
            .map(|_| {
                x = phi * x + rng.normal();
                x
            })
            .collect();
        let ess = effective_sample_size(&trace);
        let expect = n as f64 * (1.0 - phi) / (1.0 + phi);
        assert!(
            (ess / expect - 1.0).abs() < 0.25,
            "ess={ess} expect≈{expect}"
        );
    }

    #[test]
    fn autocorrelation_lag0_is_one() {
        let mut rng = Pcg64::seed(3);
        let trace: Vec<f64> = (0..500).map(|_| rng.normal()).collect();
        let rho = autocorrelation(&trace, 10);
        assert!((rho[0] - 1.0).abs() < 1e-12);
        assert!(rho[5].abs() < 0.15);
    }

    #[test]
    fn constant_trace_degenerates_gracefully() {
        // regression: the var == 0 branch used to return all-zero ρ,
        // contradicting both the ρ₀ = 1 convention and the n < 2 branch
        let trace = vec![2.0; 100];
        let rho = autocorrelation(&trace, 5);
        assert_eq!(rho.len(), 6);
        assert_eq!(rho[0], 1.0, "ρ₀ = 1 even for constant traces");
        assert!(rho[1..].iter().all(|&r| r == 0.0));
        // a constant trace carries no dependence information: τ = 1
        let ess = effective_sample_size(&trace);
        assert_eq!(ess, 100.0);
    }

    #[test]
    fn tiny_traces_do_not_panic() {
        // regression: traces of length < 2 (fresh tenants) used to hit
        // `assert!(n >= 2)`; they now return the degenerate [1.0]
        assert_eq!(autocorrelation(&[], 8), vec![1.0]);
        assert_eq!(autocorrelation(&[3.5], 8), vec![1.0]);
        assert_eq!(autocorrelation(&[3.5], 0), vec![1.0]);
        // and the ESS guards keep composing with it
        assert_eq!(effective_sample_size(&[]), 0.0);
        assert_eq!(effective_sample_size(&[1.0]), 1.0);
        assert_eq!(effective_sample_size(&[1.0, 0.0, 1.0]), 3.0);
    }

    /// The materialized O(n²) estimator this module used to run: compute
    /// every lag up to n/2 first, then apply Geyer's cutoff.
    fn ess_materialized(trace: &[f64], max_lag: usize) -> f64 {
        let n = trace.len();
        if n < 4 {
            return n as f64;
        }
        let rho = autocorrelation(trace, max_lag);
        let mut tau = 1.0;
        let mut k = 1;
        while k + 1 < rho.len() {
            let pair = rho[k] + rho[k + 1];
            if pair <= 0.0 {
                break;
            }
            tau += 2.0 * pair;
            k += 2;
        }
        (n as f64 / tau).min(n as f64)
    }

    #[test]
    fn long_trace_ess_is_lag_capped_and_matches_uncapped() {
        // the incremental early-terminating path must agree with the old
        // materialize-all-lags estimator wherever the Geyer cutoff falls
        // below the cap — i.e. on every realistic MCMC trace — while
        // doing O(n·τ) work instead of O(n²)
        let phi = 0.95;
        let mut rng = Pcg64::seed(9);
        let n = 200_000; // n/2 lags would be 10^10 mul-adds — the old cost
        let mut x = 0.0;
        let trace: Vec<f64> = (0..n)
            .map(|_| {
                x = phi * x + rng.normal();
                x
            })
            .collect();
        let fast = effective_sample_size(&trace);
        let slow = ess_materialized(&trace, ESS_MAX_LAG);
        assert!(
            (fast - slow).abs() < 1e-9 * slow.max(1.0),
            "fast={fast} slow={slow}"
        );
        let expect = n as f64 * (1.0 - phi) / (1.0 + phi);
        assert!((fast / expect - 1.0).abs() < 0.3, "ess={fast} expect≈{expect}");
    }

    #[test]
    fn pathological_trace_stops_at_the_lag_cap() {
        // a period-2 trace with a tiny positive drift keeps every Geyer
        // pair positive forever; the cap must bound the work and τ
        let n = 40_000;
        let trace: Vec<f64> = (0..n).map(|t| (t % 2) as f64 * 1e-12 + t as f64).collect();
        let ess = effective_sample_size(&trace);
        // a near-linear trace is maximally autocorrelated: ESS collapses
        // toward n/(2·max_lag+1) but the call returns (quickly) instead
        // of scanning all n/2 lags
        assert!(ess >= n as f64 / (2.0 * ESS_MAX_LAG as f64 + 1.0) - 1.0);
        assert!(ess < 100.0, "ess={ess}");
    }
}
