//! Gelman–Rubin potential scale reduction factor and mixing times.
//!
//! Conventions (matching the paper's setup): `m` chains, each a scalar
//! trace; at checkpoint `t` the statistic uses the *second half* of each
//! chain's prefix `[t/2, t)` (discarding the first half as burn-in):
//!
//!   `W` = mean within-chain variance, `B/n` = variance of chain means,
//!   `V̂ = (n−1)/n · W + B/n`,  `PSRF = sqrt(V̂ / W)`.
//!
//! For binary traces (single Ising sites) `W` can be 0 when every chain is
//! frozen; we return `INFINITY` when chains disagree with zero within-
//! variance and `1.0` when they agree exactly — both are what the mixing-
//! time extraction expects.

/// PSRF of `chains` scalar traces using samples `[lo, hi)`.
pub fn psrf_window(chains: &[Vec<f64>], lo: usize, hi: usize) -> f64 {
    let m = chains.len();
    assert!(m >= 2, "PSRF needs at least 2 chains");
    let n = hi - lo;
    if n < 2 {
        return f64::INFINITY;
    }
    let mut means = Vec::with_capacity(m);
    let mut vars = Vec::with_capacity(m);
    for c in chains {
        assert!(c.len() >= hi, "trace shorter than window");
        let s = &c[lo..hi];
        let mean = s.iter().sum::<f64>() / n as f64;
        let var = s.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64;
        means.push(mean);
        vars.push(var);
    }
    let w: f64 = vars.iter().sum::<f64>() / m as f64;
    let grand = means.iter().sum::<f64>() / m as f64;
    let b_over_n: f64 =
        means.iter().map(|mu| (mu - grand).powi(2)).sum::<f64>() / (m - 1) as f64;
    if w <= 0.0 {
        return if b_over_n <= 0.0 { 1.0 } else { f64::INFINITY };
    }
    let v_hat = (n - 1) as f64 / n as f64 * w + b_over_n;
    (v_hat / w).sqrt()
}

/// PSRF at prefix length `t` (second-half window `[t/2, t)`).
pub fn psrf_at(chains: &[Vec<f64>], t: usize) -> f64 {
    psrf_window(chains, t / 2, t)
}

/// PSRF of the full traces (second-half convention).
pub fn psrf(chains: &[Vec<f64>]) -> f64 {
    let t = chains.iter().map(Vec::len).min().unwrap_or(0);
    psrf_at(chains, t)
}

/// PSRF evaluated at every multiple of `stride` (for plots).
pub fn psrf_series(chains: &[Vec<f64>], stride: usize) -> Vec<(usize, f64)> {
    let t_max = chains.iter().map(Vec::len).min().unwrap_or(0);
    (1..=t_max / stride)
        .map(|k| (k * stride, psrf_at(chains, k * stride)))
        .collect()
}

/// Result of a mixing-time extraction.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MixingResult {
    /// First checkpoint index (in sweeps) after which PSRF stays < threshold,
    /// or `None` if never within the trace.
    pub mixing_time: Option<usize>,
    /// PSRF at the final checkpoint.
    pub final_psrf: f64,
}

/// The paper's §6 extraction: the first `t` (on a `stride` grid) such that
/// `PSRF(t') < threshold` for every later checkpoint `t' ≥ t`.
///
/// When several scalar traces are monitored (e.g. many variables), take
/// the max PSRF across them first — see [`mixing_time_multi`].
pub fn mixing_time(chains: &[Vec<f64>], threshold: f64, stride: usize) -> MixingResult {
    let series = psrf_series(chains, stride);
    from_series(&series, threshold)
}

fn from_series(series: &[(usize, f64)], threshold: f64) -> MixingResult {
    let final_psrf = series.last().map(|&(_, r)| r).unwrap_or(f64::INFINITY);
    let mut mix = None;
    for &(t, r) in series.iter().rev() {
        if r < threshold {
            mix = Some(t);
        } else {
            break;
        }
    }
    MixingResult {
        mixing_time: mix,
        final_psrf,
    }
}

/// Multi-statistic mixing time: PSRF at each checkpoint is the max over
/// all monitored scalar traces (`traces[stat][chain][t]`).
pub fn mixing_time_multi(
    traces: &[Vec<Vec<f64>>],
    threshold: f64,
    stride: usize,
) -> MixingResult {
    assert!(!traces.is_empty());
    let t_max = traces
        .iter()
        .flat_map(|chains| chains.iter().map(Vec::len))
        .min()
        .unwrap();
    let series: Vec<(usize, f64)> = (1..=t_max / stride)
        .map(|k| {
            let t = k * stride;
            let worst = traces
                .iter()
                .map(|chains| psrf_at(chains, t))
                .fold(f64::NEG_INFINITY, f64::max);
            (t, worst)
        })
        .collect();
    from_series(&series, threshold)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, RngCore};

    fn iid_chains(m: usize, n: usize, mean: f64, seed: u64) -> Vec<Vec<f64>> {
        (0..m)
            .map(|c| {
                let mut rng = Pcg64::seed(seed + c as u64);
                (0..n).map(|_| mean + rng.normal()).collect()
            })
            .collect()
    }

    #[test]
    fn iid_chains_have_psrf_near_one() {
        let chains = iid_chains(10, 4000, 0.0, 1);
        let r = psrf(&chains);
        assert!(r < 1.01, "psrf={r}");
        assert!(r >= 1.0 - 1e-6);
    }

    #[test]
    fn shifted_chains_have_large_psrf() {
        let mut chains = iid_chains(4, 2000, 0.0, 2);
        for x in &mut chains[0] {
            *x += 5.0; // one chain stuck in a different mode
        }
        assert!(psrf(&chains) > 2.0);
    }

    #[test]
    fn decaying_transient_mixing_time() {
        // chains start far apart and converge: PSRF should cross 1.01 and stay
        let m = 8;
        let n = 6000;
        let chains: Vec<Vec<f64>> = (0..m)
            .map(|c| {
                let mut rng = Pcg64::seed(100 + c as u64);
                let offset = (c as f64 - 3.5) * 4.0;
                (0..n)
                    .map(|t| offset * (-(t as f64) / 150.0).exp() + rng.normal())
                    .collect()
            })
            .collect();
        let r = mixing_time(&chains, 1.01, 50);
        let mt = r.mixing_time.expect("should mix");
        assert!(mt > 100, "mixed suspiciously fast: {mt}");
        assert!(mt < 5000, "mixed too slowly: {mt}");
        assert!(r.final_psrf < 1.01);
    }

    #[test]
    fn never_mixing_returns_none() {
        let mut chains = iid_chains(4, 1000, 0.0, 3);
        for x in &mut chains[1] {
            *x += 10.0;
        }
        let r = mixing_time(&chains, 1.01, 100);
        assert_eq!(r.mixing_time, None);
        assert!(r.final_psrf > 1.01);
    }

    #[test]
    fn frozen_identical_chains_psrf_one() {
        let chains = vec![vec![1.0; 100], vec![1.0; 100]];
        assert_eq!(psrf(&chains), 1.0);
    }

    #[test]
    fn frozen_disagreeing_chains_psrf_inf() {
        let chains = vec![vec![1.0; 100], vec![0.0; 100]];
        assert_eq!(psrf(&chains), f64::INFINITY);
    }

    #[test]
    fn multi_takes_worst_statistic() {
        let good = iid_chains(4, 2000, 0.0, 5);
        let mut bad = iid_chains(4, 2000, 0.0, 6);
        for x in &mut bad[0] {
            *x += 8.0;
        }
        let r = mixing_time_multi(&[good, bad], 1.01, 100);
        assert_eq!(r.mixing_time, None);
    }

    #[test]
    fn series_is_monotone_in_index() {
        let chains = iid_chains(4, 1000, 0.0, 7);
        let s = psrf_series(&chains, 100);
        assert_eq!(s.len(), 10);
        assert_eq!(s[0].0, 100);
        assert_eq!(s[9].0, 1000);
    }
}
