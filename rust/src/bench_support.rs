//! Shared experiment drivers used by the CLI, the examples and every bench
//! binary: multi-chain mixing runs (the paper's §6 protocol) and the
//! end-to-end denoising pipeline over the XLA runtime.

use std::sync::Arc;

use crate::util::error::{Context, Result};

use crate::coordinator::PdEnsemble;
use crate::diagnostics::{mixing_time_multi, MixingResult};
use crate::duality::DualModel;
use crate::graph::FactorGraph;
use crate::rng::{Pcg64, RngCore};
use crate::runtime::Runtime;
use crate::samplers::{
    BlockedPd, ChromaticGibbs, PdSampler, Sampler, SequentialGibbs, SwendsenWang,
};
use crate::util::ThreadPool;
use crate::workloads::{self, DenoiseConfig};

/// Deterministic spread of `k` monitored variables over `0..n`.
pub fn pick_monitors(n: usize, k: usize) -> Vec<usize> {
    let k = k.min(n).max(1);
    (0..k).map(|i| i * n / k).collect()
}

/// Build a sampler by CLI name. `'static` workloads only (borrows `g`).
pub fn make_sampler<'g>(
    g: &'g FactorGraph,
    kind: &str,
    pool: Option<Arc<ThreadPool>>,
) -> Box<dyn Sampler + 'g> {
    match kind {
        "pd" => {
            let s = PdSampler::new(g);
            match pool {
                Some(p) => Box::new(s.with_pool(p)),
                None => Box::new(s),
            }
        }
        "sequential" => Box::new(SequentialGibbs::new(g)),
        "chromatic" => {
            let s = ChromaticGibbs::new(g);
            match pool {
                Some(p) => Box::new(s.with_pool(p)),
                None => Box::new(s),
            }
        }
        "sw" => Box::new(SwendsenWang::new(g)),
        "blocked" => Box::new(BlockedPd::new(g)),
        other => panic!("unknown sampler kind '{other}'"),
    }
}

/// The paper's §6 protocol: `chains` overdispersed chains of `kind`,
/// `max_sweeps` sweeps each, PSRF over magnetization + `monitors`,
/// mixing time at `threshold` (checkpoint stride = max_sweeps/100, min 10).
pub fn mixing_run(
    g: &FactorGraph,
    kind: &str,
    chains: usize,
    max_sweeps: usize,
    threshold: f64,
    monitors: &[usize],
    seed: u64,
) -> MixingResult {
    let base = Pcg64::seed(seed);
    let n = g.num_vars();
    // chains are independent — run them on their own OS threads
    let chain_traces: Vec<Vec<Vec<f64>>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..chains)
            .map(|c| {
                let base = base.clone();
                scope.spawn(move || {
                    let mut sampler = make_sampler(g, kind, None);
                    // overdispersed start (same schedule as PdEnsemble)
                    let mut rng = base.split(c as u64 + 1);
                    let init: Vec<u8> = match c % 3 {
                        0 => vec![0; n],
                        1 => vec![1; n],
                        _ => (0..n).map(|_| (rng.next_u64() & 1) as u8).collect(),
                    };
                    sampler.set_state(&init);
                    // local[stat][sweep]; stat 0 = magnetization, then monitors
                    let mut local = vec![Vec::with_capacity(max_sweeps); 1 + monitors.len()];
                    for _ in 0..max_sweeps {
                        sampler.sweep(&mut rng);
                        let x = sampler.state();
                        let mag = x.iter().map(|&b| b as f64).sum::<f64>() / n as f64;
                        local[0].push(mag);
                        for (k, &v) in monitors.iter().enumerate() {
                            local[1 + k].push(x[v] as f64);
                        }
                    }
                    local
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    // transpose to traces[stat][chain][sweep]
    let mut traces = vec![vec![Vec::new(); chains]; 1 + monitors.len()];
    for (c, per_chain) in chain_traces.into_iter().enumerate() {
        for (stat, t) in per_chain.into_iter().enumerate() {
            traces[stat][c] = t;
        }
    }
    let stride = (max_sweeps / 100).max(10);
    mixing_time_multi(&traces, threshold, stride)
}

/// Result of the end-to-end denoising run.
#[derive(Clone, Copy, Debug)]
pub struct DenoiseResult {
    /// Pixel accuracy of the noisy observation vs ground truth.
    pub noisy_accuracy: f64,
    /// Pixel accuracy after thresholding the sampled marginals.
    pub denoised_accuracy: f64,
    /// Sweeps executed by the sampler.
    pub sweeps: usize,
    /// Wall-clock sampling time in seconds.
    pub seconds: f64,
}

/// End-to-end §E2E driver: 50×50 binary image → noise → posterior Ising
/// MRF → dualize → sample (XLA `grid50` artifact or native) → threshold
/// pooled marginals → accuracy. Exercises all three layers when
/// `native == false`.
pub fn denoise_e2e(
    artifacts_dir: &str,
    flip_prob: f64,
    coupling: f64,
    chunks: usize,
    seed: u64,
    native: bool,
    verbose: bool,
) -> Result<DenoiseResult> {
    let cfg = DenoiseConfig {
        rows: 50,
        cols: 50,
        coupling,
        flip_prob,
    };
    let clean = workloads::synthetic_image(cfg.rows, cfg.cols);
    let noisy = workloads::noisy_image(&clean, cfg.flip_prob, seed);
    let g = workloads::denoise_mrf(&cfg, &noisy);
    let model = DualModel::from_graph(&g);
    let n = g.num_vars();
    let t0 = std::time::Instant::now();
    let (marginals, sweeps) = if native {
        let mut ens = PdEnsemble::from_model(model, 10, seed ^ 0xD1CE);
        ens.run(64); // burn-in
        ens.reset_stats();
        ens.run(chunks * 16);
        (ens.marginals(), (chunks + 4) * 16)
    } else {
        let rt = Runtime::load(artifacts_dir).context("loading artifacts")?;
        let meta = rt
            .manifest()
            .get("grid50")
            .context("grid50 artifact missing")?
            .clone();
        let ops = model.dense_operands(meta.n_pad, meta.f_pad);
        let exec = rt.chain_exec("grid50", &ops)?;
        let mut state = exec.zero_state();
        let mut rng = Pcg64::seed(seed ^ 0xA07);
        let mut sum = vec![0.0f64; n];
        let burn_chunks = 4usize;
        for chunk in 0..burn_chunks + chunks {
            let key = [rng.next_u64() as u32, rng.next_u64() as u32];
            let out = exec.run(&state, key)?;
            state = out.state;
            if chunk >= burn_chunks {
                for c in 0..meta.chains {
                    for v in 0..n {
                        sum[v] += out.sum_x[c * meta.n_pad + v] as f64;
                    }
                }
            }
        }
        let total = (chunks * meta.sweeps * meta.chains) as f64;
        let marginals: Vec<f64> = sum.into_iter().map(|s| s / total).collect();
        (marginals, (burn_chunks + chunks) * meta.sweeps)
    };
    let seconds = t0.elapsed().as_secs_f64();
    let denoised: Vec<bool> = marginals.iter().map(|&p| p > 0.5).collect();
    let result = DenoiseResult {
        noisy_accuracy: workloads::accuracy(&clean, &noisy),
        denoised_accuracy: workloads::accuracy(&clean, &denoised),
        sweeps,
        seconds,
    };
    if verbose {
        println!("clean:\n{}", workloads::render(&clean, cfg.rows, cfg.cols));
        println!("noisy:\n{}", workloads::render(&noisy, cfg.rows, cfg.cols));
        println!(
            "denoised ({}):\n{}",
            if native { "native" } else { "xla/grid50" },
            workloads::render(&denoised, cfg.rows, cfg.cols)
        );
    }
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    #[test]
    fn monitors_spread() {
        assert_eq!(pick_monitors(100, 4), vec![0, 25, 50, 75]);
        assert_eq!(pick_monitors(3, 10), vec![0, 1, 2]);
        assert_eq!(pick_monitors(5, 1), vec![0]);
    }

    #[test]
    fn mixing_run_weak_coupling_mixes_fast() {
        let g = workloads::ising_grid(6, 6, 0.1, 0.0);
        let r = mixing_run(&g, "pd", 6, 1500, 1.05, &pick_monitors(36, 6), 3);
        assert!(r.mixing_time.is_some(), "final psrf {}", r.final_psrf);
    }

    #[test]
    fn mixing_sequential_not_slower_than_pd_on_grid() {
        // the paper's qualitative claim: sequential mixes faster (in sweeps)
        let g = workloads::ising_grid(8, 8, 0.35, 0.0);
        let mons = pick_monitors(64, 8);
        let seq = mixing_run(&g, "sequential", 8, 3000, 1.02, &mons, 5);
        let pd = mixing_run(&g, "pd", 8, 3000, 1.02, &mons, 5);
        if let (Some(ts), Some(tp)) = (seq.mixing_time, pd.mixing_time) {
            assert!(
                tp as f64 >= ts as f64 * 0.5,
                "PD mixed implausibly faster: {tp} vs {ts}"
            );
        }
    }

    #[test]
    fn denoise_native_improves_accuracy() {
        let r = denoise_e2e("artifacts", 0.12, 0.35, 10, 1, true, false).unwrap();
        assert!(r.denoised_accuracy > r.noisy_accuracy + 0.03);
        assert!(r.denoised_accuracy > 0.95);
    }
}
