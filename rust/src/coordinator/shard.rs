//! A shard worker: one thread owning a registry of tenants.
//!
//! The coordinator front-end routes every tenant id to exactly one shard
//! ([`super::route`]); the shard thread owns its tenants outright — no
//! locks on the request path — and drains one request queue. When the
//! queue is empty it asks its deficit-round-robin scheduler
//! ([`super::schedule::DrrScheduler`]) for the next background grant, so
//! foreground requests interleave with fair-share background sweeping at
//! slice granularity (bounded by the DRR quantum, the latency/throughput
//! knob).
//!
//! Heavy sweeps do not get a private thread pool per shard: all shards
//! *lend* one shared [`ThreadPool`] (passed in at spawn), so the machine
//! runs `shards` request loops plus one fixed set of workers instead of
//! `shards × pool` threads fighting each other.

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::Arc;

use crate::diagnostics::MixingResult;
use crate::graph::FactorGraph;
use crate::runtime::Manifest;
use crate::util::error::Result;
use crate::util::ThreadPool;
use crate::workloads::ChurnOp;

use super::dispatch::DispatchPolicy;
use super::metrics::Metrics;
use super::schedule::DrrScheduler;
use super::tenant::{Tenant, TenantConfig, TenantId, TenantStats};

/// Requests a shard worker accepts. `Apply`/`Sweep`/`ResetStats` are
/// fire-and-forget (ordering per tenant is still FIFO — one queue, one
/// consumer); queries carry a typed reply channel whose payload is a
/// [`Result`] so an unknown tenant degrades into an error the caller can
/// route around instead of a panic.
pub(super) enum ShardRequest {
    Create {
        tenant: TenantId,
        graph: FactorGraph,
        config: TenantConfig,
        reply: Sender<Result<()>>,
    },
    Drop {
        tenant: TenantId,
        reply: Sender<Result<bool>>,
    },
    Apply {
        tenant: TenantId,
        ops: Vec<ChurnOp>,
    },
    Sweep {
        tenant: TenantId,
        n: usize,
    },
    ResetStats {
        tenant: TenantId,
    },
    Suspend {
        tenant: TenantId,
    },
    Resume {
        tenant: TenantId,
    },
    Clamp {
        tenant: TenantId,
        v: usize,
        state: u8,
        reply: Sender<Result<()>>,
    },
    Unclamp {
        tenant: TenantId,
        v: usize,
        reply: Sender<Result<()>>,
    },
    Marginals {
        tenant: TenantId,
        reply: Sender<Result<Vec<f64>>>,
    },
    Mixing {
        tenant: TenantId,
        threshold: f64,
        stride: usize,
        reply: Sender<Result<MixingResult>>,
    },
    Stats {
        tenant: TenantId,
        reply: Sender<Result<TenantStats>>,
    },
    ShardStats {
        reply: Sender<ShardStats>,
    },
    Shutdown,
    /// Test-only fault injector: enroll a tenant id in the DRR ring
    /// without creating it, reproducing a scheduler/registry desync (the
    /// ghost-grant panic this module regression-tests against).
    #[cfg(test)]
    DebugEnroll { tenant: TenantId },
}

impl ShardRequest {
    /// Tenant the request addresses (None for shard-wide requests) —
    /// drives per-tenant queue-depth accounting in [`super::Depth`].
    pub(super) fn tenant(&self) -> Option<TenantId> {
        match self {
            ShardRequest::Create { tenant, .. }
            | ShardRequest::Drop { tenant, .. }
            | ShardRequest::Apply { tenant, .. }
            | ShardRequest::Sweep { tenant, .. }
            | ShardRequest::ResetStats { tenant }
            | ShardRequest::Suspend { tenant }
            | ShardRequest::Resume { tenant }
            | ShardRequest::Clamp { tenant, .. }
            | ShardRequest::Unclamp { tenant, .. }
            | ShardRequest::Marginals { tenant, .. }
            | ShardRequest::Mixing { tenant, .. }
            | ShardRequest::Stats { tenant, .. } => Some(*tenant),
            ShardRequest::ShardStats { .. } | ShardRequest::Shutdown => None,
            #[cfg(test)]
            ShardRequest::DebugEnroll { tenant } => Some(*tenant),
        }
    }
}

/// Aggregate snapshot of one shard.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardStats {
    /// Shard index.
    pub shard: usize,
    /// Hosted tenants (including suspended ones).
    pub tenants: usize,
    /// Currently suspended tenants.
    pub suspended: usize,
    /// Requests handled since spawn (all kinds).
    pub requests: u64,
    /// Background sweeps granted by the DRR scheduler, summed over
    /// tenants.
    pub background_sweeps: u64,
}

/// Per-shard fixed parameters.
pub(super) struct ShardConfig {
    /// Index of this shard within the coordinator.
    pub shard_id: usize,
    /// DRR quantum in site-visits; 0 disables background sweeping.
    pub quantum: u64,
    /// Native-vs-XLA dispatch policy evaluated per tenant.
    pub dispatch: DispatchPolicy,
    /// Artifact manifest consulted by the dispatch policy (None: the
    /// offline default — every decision is `Native`, but `stable_for`
    /// hysteresis is still tracked and surfaced).
    pub manifest: Option<Manifest>,
}

pub(super) fn shard_worker(
    config: ShardConfig,
    rx: Receiver<ShardRequest>,
    metrics: Metrics,
    pool: Option<Arc<ThreadPool>>,
    depth: Arc<super::Depth>,
) {
    let shard_metrics = metrics.scoped(format!("shard{}", config.shard_id));
    let mut tenants: HashMap<TenantId, Tenant> = HashMap::new();
    let mut sched = DrrScheduler::new(config.quantum.max(1));
    let background = config.quantum > 0;
    let mut requests = 0u64;
    let mut background_total = 0u64;

    loop {
        // With background work pending, poll; otherwise block — an idle
        // shard must not spin.
        let polled = if background && !sched.is_empty() {
            match rx.try_recv() {
                Ok(r) => Some(r),
                Err(TryRecvError::Empty) => None,
                Err(TryRecvError::Disconnected) => return,
            }
        } else {
            match rx.recv() {
                Ok(r) => Some(r),
                Err(_) => return,
            }
        };

        let req = if let Some(r) = polled {
            r
        } else if let Some(slice) =
            sched.next_slice(|id| tenants.get(&id).map_or(1, Tenant::cost))
        {
            // idle: next fair-share background grant
            match tenants.get_mut(&slice.tenant) {
                Some(t) => {
                    t.background_sweep(slice.sweeps);
                    background_total += slice.sweeps as u64;
                }
                None => {
                    // ghost grant: the ring holds a tenant the registry
                    // does not. Withdraw it and count the desync instead
                    // of indexing the registry (which killed the shard
                    // thread and silenced every later request).
                    sched.withdraw(slice.tenant);
                    shard_metrics.inc("sched_desync");
                }
            }
            continue;
        } else {
            // Enrolled tenants but no grant — only possible if sweep
            // costs shifted between the scheduler's sizing and grant
            // passes. Block for the next request rather than hot-spinning
            // the try_recv/next_slice loop on one core.
            match rx.recv() {
                Ok(r) => r,
                Err(_) => return,
            }
        };

        depth.dequeued(config.shard_id, req.tenant());
        requests += 1;
        shard_metrics.inc("requests");
        match req {
            ShardRequest::Create {
                tenant,
                graph,
                config: tcfg,
                reply,
            } => {
                let out = if tenants.contains_key(&tenant) {
                    Err(crate::err!(
                        "tenant {tenant} already hosted on shard {}",
                        config.shard_id
                    ))
                } else {
                    let view = metrics.scoped(format!("tenant{tenant}"));
                    // fallible: degenerate sweep-policy knobs must come
                    // back as an error reply, not a dead shard thread —
                    // the refused id stays reusable
                    match Tenant::try_new(graph, &tcfg, pool.clone(), view) {
                        Ok(t) => {
                            tenants.insert(tenant, t);
                            if background {
                                sched.enroll(tenant);
                            }
                            shard_metrics.inc("tenants_created");
                            Ok(())
                        }
                        Err(e) => {
                            metrics.remove_scope(&format!("tenant{tenant}"));
                            shard_metrics.inc("tenants_rejected");
                            Err(crate::err!("create rejected: {e}"))
                        }
                    }
                };
                let _ = reply.send(out);
            }
            ShardRequest::Drop { tenant, reply } => {
                let existed = tenants.remove(&tenant).is_some();
                sched.withdraw(tenant);
                if existed {
                    // reclaim the tenant's scoped keys: ids are never
                    // reused, so leaked scopes would grow forever
                    metrics.remove_scope(&format!("tenant{tenant}"));
                    shard_metrics.inc("tenants_dropped");
                }
                let _ = reply.send(Ok(existed));
            }
            ShardRequest::Apply { tenant, ops } => match tenants.get_mut(&tenant) {
                Some(t) => {
                    t.apply(&ops);
                }
                None => shard_metrics.inc("unknown_tenant"),
            },
            ShardRequest::Sweep { tenant, n } => match tenants.get_mut(&tenant) {
                Some(t) => t.sweep(n),
                None => shard_metrics.inc("unknown_tenant"),
            },
            ShardRequest::ResetStats { tenant } => match tenants.get_mut(&tenant) {
                Some(t) => t.reset_stats(),
                None => shard_metrics.inc("unknown_tenant"),
            },
            ShardRequest::Suspend { tenant } => {
                if let Some(t) = tenants.get_mut(&tenant) {
                    t.suspend();
                    sched.withdraw(tenant);
                } else {
                    shard_metrics.inc("unknown_tenant");
                }
            }
            ShardRequest::Resume { tenant } => {
                if let Some(t) = tenants.get_mut(&tenant) {
                    t.resume();
                    if background {
                        sched.enroll(tenant);
                    }
                } else {
                    shard_metrics.inc("unknown_tenant");
                }
            }
            ShardRequest::Clamp {
                tenant,
                v,
                state,
                reply,
            } => {
                let out = match tenants.get_mut(&tenant) {
                    Some(t) => t
                        .clamp(v, state)
                        .map_err(|e| crate::err!("clamp rejected: {e}")),
                    None => Err(crate::err!(
                        "tenant {tenant} not hosted on shard {}",
                        config.shard_id
                    )),
                };
                let _ = reply.send(out);
            }
            ShardRequest::Unclamp { tenant, v, reply } => {
                let out = match tenants.get_mut(&tenant) {
                    Some(t) => t
                        .unclamp(v)
                        .map_err(|e| crate::err!("unclamp rejected: {e}")),
                    None => Err(crate::err!(
                        "tenant {tenant} not hosted on shard {}",
                        config.shard_id
                    )),
                };
                let _ = reply.send(out);
            }
            ShardRequest::Marginals { tenant, reply } => {
                let out = lookup(&tenants, tenant, config.shard_id).map(Tenant::marginals);
                let _ = reply.send(out);
            }
            ShardRequest::Mixing {
                tenant,
                threshold,
                stride,
                reply,
            } => {
                let out = lookup(&tenants, tenant, config.shard_id)
                    .map(|t| t.mixing(threshold, stride));
                let _ = reply.send(out);
            }
            ShardRequest::Stats { tenant, reply } => {
                let out = lookup(&tenants, tenant, config.shard_id)
                    .map(|t| t.stats(&config.dispatch, config.manifest.as_ref()));
                let _ = reply.send(out);
            }
            ShardRequest::ShardStats { reply } => {
                let _ = reply.send(ShardStats {
                    shard: config.shard_id,
                    tenants: tenants.len(),
                    suspended: tenants.values().filter(|t| t.is_suspended()).count(),
                    requests,
                    background_sweeps: background_total,
                });
            }
            ShardRequest::Shutdown => return,
            #[cfg(test)]
            ShardRequest::DebugEnroll { tenant } => sched.enroll(tenant),
        }
    }
}

fn lookup(tenants: &HashMap<TenantId, Tenant>, id: TenantId, shard: usize) -> Result<&Tenant> {
    tenants
        .get(&id)
        .ok_or_else(|| crate::err!("tenant {id} not hosted on shard {shard}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;
    use std::sync::mpsc::channel;
    use std::time::{Duration, Instant};

    fn spawn(quantum: u64) -> (Sender<ShardRequest>, Metrics, std::thread::JoinHandle<()>) {
        let metrics = Metrics::new();
        let depth = Arc::new(super::super::Depth::new(1));
        let (tx, rx) = channel();
        let cfg = ShardConfig {
            shard_id: 0,
            quantum,
            dispatch: DispatchPolicy::default(),
            manifest: None,
        };
        let m = metrics.clone();
        let h = std::thread::spawn(move || shard_worker(cfg, rx, m, None, depth));
        (tx, metrics, h)
    }

    fn shard_stats(tx: &Sender<ShardRequest>) -> ShardStats {
        let (reply, rx) = channel();
        tx.send(ShardRequest::ShardStats { reply }).unwrap();
        rx.recv_timeout(Duration::from_secs(10)).unwrap()
    }

    #[test]
    fn ghost_scheduler_entry_is_withdrawn_not_a_panic() {
        // regression: a DRR ring entry with no registry tenant used to hit
        // `tenants[&id]` / `.expect("scheduled tenant exists")` on the
        // first idle poll, killing the shard thread for good
        let (tx, metrics, h) = spawn(64);
        tx.send(ShardRequest::DebugEnroll { tenant: 42 }).unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        while metrics.counter("shard0.sched_desync") == 0 {
            assert!(Instant::now() < deadline, "desync was never counted");
            std::thread::sleep(Duration::from_millis(5));
        }
        // the worker is still alive and serving, with an empty registry
        let stats = shard_stats(&tx);
        assert_eq!(stats.tenants, 0);
        tx.send(ShardRequest::Shutdown).unwrap();
        h.join().expect("shard thread must not have panicked");
    }

    #[test]
    fn background_sweeping_survives_a_desync() {
        // a ghost ring entry must not stall background service for the
        // real tenants sharing the shard
        let (tx, metrics, h) = spawn(4096);
        let (reply, rrx) = channel();
        tx.send(ShardRequest::Create {
            tenant: 1,
            graph: workloads::ising_grid(2, 2, 0.2, 0.0),
            config: TenantConfig {
                chains: 2,
                seed: 9,
                ..TenantConfig::default()
            },
            reply,
        })
        .unwrap();
        rrx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        tx.send(ShardRequest::DebugEnroll { tenant: 777 }).unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let stats = shard_stats(&tx);
            if stats.background_sweeps > 0 && metrics.counter("shard0.sched_desync") >= 1 {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "real tenant starved after desync: {stats:?}"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        tx.send(ShardRequest::Shutdown).unwrap();
        h.join().expect("shard thread must not have panicked");
    }
}
