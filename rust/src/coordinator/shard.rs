//! A shard worker: one thread owning a registry of tenants.
//!
//! The coordinator front-end routes every tenant id to exactly one shard
//! ([`super::route`]); the shard thread owns its tenants outright — no
//! locks on the request path — and drains one request queue. When the
//! queue is empty it asks its deficit-round-robin scheduler
//! ([`super::schedule::DrrScheduler`]) for the next background grant, so
//! foreground requests interleave with fair-share background sweeping at
//! slice granularity (bounded by the DRR quantum, the latency/throughput
//! knob).
//!
//! Heavy sweeps do not get a private thread pool per shard: all shards
//! *lend* one shared [`ThreadPool`] (passed in at spawn), so the machine
//! runs `shards` request loops plus one fixed set of workers instead of
//! `shards × pool` threads fighting each other.

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::Arc;

use crate::diagnostics::MixingResult;
use crate::graph::FactorGraph;
use crate::runtime::Manifest;
use crate::util::error::Result;
use crate::util::ThreadPool;
use crate::workloads::ChurnOp;

use super::dispatch::DispatchPolicy;
use super::metrics::Metrics;
use super::schedule::DrrScheduler;
use super::tenant::{Tenant, TenantConfig, TenantId, TenantStats};

/// Requests a shard worker accepts. `Apply`/`Sweep`/`ResetStats` are
/// fire-and-forget (ordering per tenant is still FIFO — one queue, one
/// consumer); queries carry a typed reply channel whose payload is a
/// [`Result`] so an unknown tenant degrades into an error the caller can
/// route around instead of a panic.
pub(super) enum ShardRequest {
    Create {
        tenant: TenantId,
        graph: FactorGraph,
        config: TenantConfig,
        reply: Sender<Result<()>>,
    },
    Drop {
        tenant: TenantId,
        reply: Sender<Result<bool>>,
    },
    Apply {
        tenant: TenantId,
        ops: Vec<ChurnOp>,
    },
    Sweep {
        tenant: TenantId,
        n: usize,
    },
    ResetStats {
        tenant: TenantId,
    },
    Suspend {
        tenant: TenantId,
    },
    Resume {
        tenant: TenantId,
    },
    Marginals {
        tenant: TenantId,
        reply: Sender<Result<Vec<f64>>>,
    },
    Mixing {
        tenant: TenantId,
        threshold: f64,
        stride: usize,
        reply: Sender<Result<MixingResult>>,
    },
    Stats {
        tenant: TenantId,
        reply: Sender<Result<TenantStats>>,
    },
    ShardStats {
        reply: Sender<ShardStats>,
    },
    Shutdown,
}

/// Aggregate snapshot of one shard.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardStats {
    /// Shard index.
    pub shard: usize,
    /// Hosted tenants (including suspended ones).
    pub tenants: usize,
    /// Currently suspended tenants.
    pub suspended: usize,
    /// Requests handled since spawn (all kinds).
    pub requests: u64,
    /// Background sweeps granted by the DRR scheduler, summed over
    /// tenants.
    pub background_sweeps: u64,
}

/// Per-shard fixed parameters.
pub(super) struct ShardConfig {
    /// Index of this shard within the coordinator.
    pub shard_id: usize,
    /// DRR quantum in site-visits; 0 disables background sweeping.
    pub quantum: u64,
    /// Native-vs-XLA dispatch policy evaluated per tenant.
    pub dispatch: DispatchPolicy,
    /// Artifact manifest consulted by the dispatch policy (None: the
    /// offline default — every decision is `Native`, but `stable_for`
    /// hysteresis is still tracked and surfaced).
    pub manifest: Option<Manifest>,
}

pub(super) fn shard_worker(
    config: ShardConfig,
    rx: Receiver<ShardRequest>,
    metrics: Metrics,
    pool: Option<Arc<ThreadPool>>,
) {
    let shard_metrics = metrics.scoped(format!("shard{}", config.shard_id));
    let mut tenants: HashMap<TenantId, Tenant> = HashMap::new();
    let mut sched = DrrScheduler::new(config.quantum.max(1));
    let background = config.quantum > 0;
    let mut requests = 0u64;
    let mut background_total = 0u64;

    loop {
        // With background work pending, poll; otherwise block — an idle
        // shard must not spin.
        let req = if background && !sched.is_empty() {
            match rx.try_recv() {
                Ok(r) => Some(r),
                Err(TryRecvError::Empty) => None,
                Err(TryRecvError::Disconnected) => return,
            }
        } else {
            match rx.recv() {
                Ok(r) => Some(r),
                Err(_) => return,
            }
        };

        let Some(req) = req else {
            // idle: next fair-share background grant
            if let Some(slice) = sched.next_slice(|id| tenants[&id].cost()) {
                let t = tenants.get_mut(&slice.tenant).expect("scheduled tenant exists");
                t.background_sweep(slice.sweeps);
                background_total += slice.sweeps as u64;
            }
            continue;
        };

        requests += 1;
        shard_metrics.inc("requests");
        match req {
            ShardRequest::Create {
                tenant,
                graph,
                config: tcfg,
                reply,
            } => {
                let out = if tenants.contains_key(&tenant) {
                    Err(crate::err!(
                        "tenant {tenant} already hosted on shard {}",
                        config.shard_id
                    ))
                } else {
                    let view = metrics.scoped(format!("tenant{tenant}"));
                    tenants.insert(tenant, Tenant::new(graph, &tcfg, pool.clone(), view));
                    if background {
                        sched.enroll(tenant);
                    }
                    shard_metrics.inc("tenants_created");
                    Ok(())
                };
                let _ = reply.send(out);
            }
            ShardRequest::Drop { tenant, reply } => {
                let existed = tenants.remove(&tenant).is_some();
                sched.withdraw(tenant);
                if existed {
                    // reclaim the tenant's scoped keys: ids are never
                    // reused, so leaked scopes would grow forever
                    metrics.remove_scope(&format!("tenant{tenant}"));
                    shard_metrics.inc("tenants_dropped");
                }
                let _ = reply.send(Ok(existed));
            }
            ShardRequest::Apply { tenant, ops } => match tenants.get_mut(&tenant) {
                Some(t) => {
                    t.apply(&ops);
                }
                None => shard_metrics.inc("unknown_tenant"),
            },
            ShardRequest::Sweep { tenant, n } => match tenants.get_mut(&tenant) {
                Some(t) => t.sweep(n),
                None => shard_metrics.inc("unknown_tenant"),
            },
            ShardRequest::ResetStats { tenant } => match tenants.get_mut(&tenant) {
                Some(t) => t.reset_stats(),
                None => shard_metrics.inc("unknown_tenant"),
            },
            ShardRequest::Suspend { tenant } => {
                if let Some(t) = tenants.get_mut(&tenant) {
                    t.suspend();
                    sched.withdraw(tenant);
                } else {
                    shard_metrics.inc("unknown_tenant");
                }
            }
            ShardRequest::Resume { tenant } => {
                if let Some(t) = tenants.get_mut(&tenant) {
                    t.resume();
                    if background {
                        sched.enroll(tenant);
                    }
                } else {
                    shard_metrics.inc("unknown_tenant");
                }
            }
            ShardRequest::Marginals { tenant, reply } => {
                let out = lookup(&tenants, tenant, config.shard_id).map(Tenant::marginals);
                let _ = reply.send(out);
            }
            ShardRequest::Mixing {
                tenant,
                threshold,
                stride,
                reply,
            } => {
                let out = lookup(&tenants, tenant, config.shard_id)
                    .map(|t| t.mixing(threshold, stride));
                let _ = reply.send(out);
            }
            ShardRequest::Stats { tenant, reply } => {
                let out = lookup(&tenants, tenant, config.shard_id)
                    .map(|t| t.stats(&config.dispatch, config.manifest.as_ref()));
                let _ = reply.send(out);
            }
            ShardRequest::ShardStats { reply } => {
                let _ = reply.send(ShardStats {
                    shard: config.shard_id,
                    tenants: tenants.len(),
                    suspended: tenants.values().filter(|t| t.is_suspended()).count(),
                    requests,
                    background_sweeps: background_total,
                });
            }
            ShardRequest::Shutdown => return,
        }
    }
}

fn lookup(tenants: &HashMap<TenantId, Tenant>, id: TenantId, shard: usize) -> Result<&Tenant> {
    tenants
        .get(&id)
        .ok_or_else(|| crate::err!("tenant {id} not hosted on shard {shard}"))
}
