//! Dispatch policy: native sparse sampler vs. AOT/XLA artifact path.
//!
//! The XLA artifacts are shape-specialized (static `n_pad`/`f_pad`/chains)
//! and amortize beautifully on *stable* topologies — the dense x-update is
//! one MXU matmul per sweep. Under churn the native sparse sampler wins:
//! it needs no recompilation and absorbs O(degree) mutations. The policy
//! formalizes the crossover the coordinator uses:
//!
//! * graph fits an artifact (padding-wise), and
//! * the topology has been stable for ≥ `stability_sweeps` sweeps
//!
//! → XLA; otherwise native. Hysteresis (`stability_sweeps`) prevents
//! flapping when mutations arrive in bursts.
//!
//! The policy is wired into the multi-tenant coordinator: every
//! [`super::tenant::Tenant`] tracks `stable_for` (sweeps since its last
//! topology mutation, reset by every `Apply`), each shard holds the
//! policy plus the optional artifact manifest, and the per-tenant
//! decision is surfaced in [`super::TenantStats::dispatch`].

use crate::runtime::Manifest;

/// Which execution backend a sweep batch should use.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DispatchDecision {
    /// Native sparse CPU sampler.
    Native,
    /// AOT artifact (by name).
    Xla(String),
}

/// Tunable dispatch policy.
#[derive(Clone, Debug)]
pub struct DispatchPolicy {
    /// Sweeps of unchanged topology required before switching to XLA.
    pub stability_sweeps: usize,
    /// Hard disable of the XLA path (e.g. artifacts not built).
    pub allow_xla: bool,
}

impl Default for DispatchPolicy {
    fn default() -> Self {
        Self {
            stability_sweeps: 64,
            allow_xla: true,
        }
    }
}

impl DispatchPolicy {
    /// Decide for a model of `n` vars / `f` live factors whose topology has
    /// been unchanged for `stable_for` sweeps.
    pub fn decide(
        &self,
        manifest: Option<&Manifest>,
        n: usize,
        f: usize,
        stable_for: usize,
    ) -> DispatchDecision {
        if !self.allow_xla || stable_for < self.stability_sweeps {
            return DispatchDecision::Native;
        }
        match manifest.and_then(|m| m.best_fit(n, f)) {
            Some(meta) => DispatchDecision::Xla(meta.name.clone()),
            None => DispatchDecision::Native,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Manifest {
        Manifest::parse(
            r#"{"artifacts": [
                {"name": "grid16", "file": "x", "n": 256, "f": 480,
                 "chains": 4, "sweeps": 8, "n_pad": 256, "f_pad": 512}
            ]}"#,
        )
        .unwrap()
    }

    #[test]
    fn unstable_topology_stays_native() {
        let p = DispatchPolicy::default();
        let m = manifest();
        assert_eq!(
            p.decide(Some(&m), 100, 100, 3),
            DispatchDecision::Native
        );
    }

    #[test]
    fn stable_and_fitting_goes_xla() {
        let p = DispatchPolicy::default();
        let m = manifest();
        assert_eq!(
            p.decide(Some(&m), 256, 480, 1000),
            DispatchDecision::Xla("grid16".into())
        );
    }

    #[test]
    fn oversized_model_stays_native() {
        let p = DispatchPolicy::default();
        let m = manifest();
        assert_eq!(
            p.decide(Some(&m), 5000, 100, 1000),
            DispatchDecision::Native
        );
    }

    #[test]
    fn xla_disabled() {
        let p = DispatchPolicy {
            allow_xla: false,
            ..Default::default()
        };
        let m = manifest();
        assert_eq!(
            p.decide(Some(&m), 256, 480, 1000),
            DispatchDecision::Native
        );
    }

    #[test]
    fn no_manifest_stays_native() {
        let p = DispatchPolicy::default();
        assert_eq!(p.decide(None, 10, 10, 1000), DispatchDecision::Native);
    }
}
