//! TCP front-end for the sharded coordinator: the network serving edge.
//!
//! One accept thread plus one thread per connection; each connection
//! thread parses newline-framed requests ([`super::protocol`]) and
//! multiplexes them onto the coordinator's per-shard queues through a
//! routing [`Client`]. The edge is where serving policy lives:
//!
//! * **Diagnostics** — malformed input (bad syntax, oversized frames,
//!   truncated frames) is answered with a spanned, labeled
//!   `err parse …` line and the connection survives; only EOF or an I/O
//!   error closes it. A frame that exceeds [`NetConfig::max_frame`] is
//!   rejected and the reader discards bytes until the next newline, so
//!   one runaway frame cannot wedge the stream.
//! * **Backpressure** — before a request is enqueued, the edge consults
//!   the coordinator's [`super::Depth`] ledger. A tenant (or shard) at
//!   its depth limit gets an explicit `err overloaded …` rejection:
//!   clients see overload instead of unbounded queueing, and foreground
//!   latency stays bounded under abuse.
//! * **Batching** — within one read burst, consecutive `apply` (resp.
//!   `sweep`) requests to the same tenant are coalesced into one shard
//!   message; every constituent still receives its own reply line, in
//!   order. This collapses per-request channel overhead for chatty
//!   clients without changing observable semantics.
//! * **Edge metrics** — the `net.` scope counts connections, requests,
//!   rejections, and coalesced sends, and feeds per-request latency into
//!   the `net.request_seconds` histogram (p50/p99/p999 in snapshots).
//!
//! `apply` and `sweep` are acknowledged at admission (fire-and-forget
//! into the owning shard's FIFO queue), matching the in-process
//! [`Client`] contract; queries (`marginals`, `stats`, `create`, `drop`,
//! `subscribe`) complete before their reply. See `docs/PROTOCOL.md`.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::graph::FactorGraph;
use crate::util::error::{Context, Result};
use crate::util::stats::mean_or_zero;
use crate::workloads::ChurnOp;

use super::protocol::{self, Request, Response, DEFAULT_MAX_FRAME, MAX_OPS, MAX_SWEEPS};
use super::{Client, Metrics, MetricsView, TenantConfig, TenantId};

/// How often a parked connection thread re-checks the shutdown flag.
const READ_TICK: Duration = Duration::from_millis(50);

/// Edge policy knobs for [`NetServer`].
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Per-frame byte budget; longer lines are rejected with a spanned
    /// diagnostic and discarded up to the next newline.
    pub max_frame: usize,
    /// Admission bound on outstanding requests per tenant.
    pub max_tenant_depth: u64,
    /// Admission bound on outstanding requests per shard queue.
    pub max_shard_depth: u64,
    /// Coalesce consecutive same-tenant `apply`/`sweep` requests within
    /// a read burst into one shard message.
    pub batch: bool,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            max_frame: DEFAULT_MAX_FRAME,
            max_tenant_depth: 64,
            max_shard_depth: 4096,
            batch: true,
        }
    }
}

/// A listening network front-end over a coordinator [`Client`].
///
/// Dropping (or [`NetServer::shutdown`]) stops the accept loop, wakes
/// every parked connection thread, and joins them all — no thread
/// outlives the server handle.
pub struct NetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_join: Option<JoinHandle<()>>,
}

impl NetServer {
    /// Bind `bind` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start serving `client` under `config`.
    pub fn spawn(client: Client, metrics: Metrics, config: NetConfig, bind: &str) -> Result<Self> {
        let listener =
            TcpListener::bind(bind).with_context(|| format!("binding serving edge to {bind}"))?;
        let addr = listener.local_addr().context("resolving bound address")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let accept_join = std::thread::spawn(move || {
            accept_loop(listener, client, metrics, config, stop2);
        });
        Ok(Self {
            addr,
            stop,
            accept_join: Some(accept_join),
        })
    }

    /// The bound address (with the real port when bound to port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, drain connection threads, and join (idempotent).
    pub fn shutdown(&mut self) {
        if let Some(join) = self.accept_join.take() {
            self.stop.store(true, Ordering::SeqCst);
            // wake the blocking accept with a throwaway connection
            let _ = TcpStream::connect(self.addr);
            let _ = join.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: TcpListener,
    client: Client,
    metrics: Metrics,
    config: NetConfig,
    stop: Arc<AtomicBool>,
) {
    let edge = metrics.scoped("net");
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        match stream {
            Ok(s) => {
                edge.inc("connections");
                let client = client.clone();
                let edge = metrics.scoped("net");
                let config = config.clone();
                let stop = stop.clone();
                conns.push(std::thread::spawn(move || {
                    let _ = handle_connection(s, &client, &edge, &config, &stop);
                }));
            }
            Err(_) => continue,
        }
        // reap finished connection threads so long-lived servers do not
        // accumulate handles (finished threads need no join to free)
        conns.retain(|h| !h.is_finished());
    }
    for h in conns {
        let _ = h.join();
    }
}

fn write_line(stream: &mut TcpStream, line: &str) -> std::io::Result<()> {
    stream.write_all(line.as_bytes())?;
    stream.write_all(b"\n")
}

fn handle_connection(
    mut stream: TcpStream,
    client: &Client,
    edge: &MetricsView,
    config: &NetConfig,
    stop: &AtomicBool,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(READ_TICK))?;
    let _ = stream.set_nodelay(true);
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    // true while skipping the tail of an already-rejected oversized frame
    let mut discarding = false;
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        match stream.read(&mut chunk) {
            Ok(0) => {
                // EOF with a partial frame buffered: the newline never
                // arrived — report the truncation before closing
                if !buf.is_empty() && !discarding {
                    edge.inc("parse_errors");
                    let reply = Response::ParseError(protocol::truncated(buf.len())).render();
                    let _ = write_line(&mut stream, &reply);
                }
                return Ok(());
            }
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                drain_frames(&mut stream, &mut buf, &mut discarding, client, edge, config)?;
            }
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut
                    || e.kind() == ErrorKind::Interrupted =>
            {
                continue;
            }
            Err(e) => return Err(e),
        }
    }
}

/// Extract every complete line from `buf`, serve them as one batch, and
/// enforce the frame budget on whatever partial frame remains.
fn drain_frames(
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
    discarding: &mut bool,
    client: &Client,
    edge: &MetricsView,
    config: &NetConfig,
) -> std::io::Result<()> {
    let mut lines: Vec<String> = Vec::new();
    let mut oversize = None;
    loop {
        match buf.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                let frame: Vec<u8> = buf.drain(..=pos).collect();
                if *discarding {
                    // tail of a frame already rejected as oversized
                    *discarding = false;
                } else {
                    lines.push(String::from_utf8_lossy(&frame[..pos]).into_owned());
                }
            }
            None => {
                if !*discarding && buf.len() > config.max_frame {
                    oversize = Some(protocol::oversized(buf.len(), config.max_frame));
                    *discarding = true;
                }
                if *discarding {
                    buf.clear();
                }
                break;
            }
        }
    }
    serve_batch(stream, &lines, client, edge, config)?;
    if let Some(d) = oversize {
        edge.inc("parse_errors");
        write_line(stream, &Response::ParseError(d).render())?;
    }
    Ok(())
}

/// A fire-and-forget send being coalesced across consecutive requests;
/// `acks` counts the constituent requests awaiting their reply line.
enum Pending {
    Apply {
        tenant: TenantId,
        ops: Vec<ChurnOp>,
        acks: usize,
    },
    Sweep {
        tenant: TenantId,
        n: usize,
        acks: usize,
    },
}

/// Send a pending coalesced request and emit one reply line per
/// constituent (replies for merged requests are identical by
/// construction, so ordering is preserved).
fn flush(
    stream: &mut TcpStream,
    pending: &mut Option<Pending>,
    client: &Client,
    edge: &MetricsView,
) -> std::io::Result<()> {
    let Some(p) = pending.take() else {
        return Ok(());
    };
    let start = Instant::now();
    let (sent, acks) = match p {
        Pending::Apply { tenant, ops, acks } => (client.apply(tenant, ops), acks),
        Pending::Sweep { tenant, n, acks } => (client.sweep(tenant, n), acks),
    };
    if acks > 1 {
        edge.add("coalesced", (acks - 1) as u64);
    }
    let reply = match sent {
        Ok(()) => Response::Ok,
        Err(e) => {
            edge.inc("exec_errors");
            Response::Exec(e.to_string())
        }
    };
    edge.observe_hist("request_seconds", start.elapsed().as_secs_f64());
    let line = reply.render();
    for _ in 0..acks {
        write_line(stream, &line)?;
    }
    Ok(())
}

/// Admission control: reject (without enqueueing) when the tenant or its
/// shard is at its outstanding-request bound.
fn admit(client: &Client, req: &Request, config: &NetConfig) -> Option<Response> {
    let tenant = req.tenant();
    let depth = client.tenant_depth(tenant);
    if depth >= config.max_tenant_depth {
        return Some(Response::Overloaded {
            scope: format!("tenant {tenant}"),
            depth,
            limit: config.max_tenant_depth,
        });
    }
    let shard = client.shard_for(tenant);
    let depth = client.queue_depth(shard);
    if depth >= config.max_shard_depth {
        return Some(Response::Overloaded {
            scope: format!("shard {shard}"),
            depth,
            limit: config.max_shard_depth,
        });
    }
    None
}

fn serve_batch(
    stream: &mut TcpStream,
    lines: &[String],
    client: &Client,
    edge: &MetricsView,
    config: &NetConfig,
) -> std::io::Result<()> {
    let mut pending: Option<Pending> = None;
    for line in lines {
        if line.trim().is_empty() {
            // blank frame: cheap keepalive, no reply
            continue;
        }
        edge.inc("requests");
        let req = match protocol::parse_request(line) {
            Ok(req) => req,
            Err(d) => {
                flush(stream, &mut pending, client, edge)?;
                edge.inc("parse_errors");
                write_line(stream, &Response::ParseError(d).render())?;
                continue;
            }
        };
        if let Some(reject) = admit(client, &req, config) {
            flush(stream, &mut pending, client, edge)?;
            edge.inc("overloaded");
            write_line(stream, &reject.render())?;
            continue;
        }
        match req {
            Request::Apply { tenant, ops } if config.batch => match &mut pending {
                Some(Pending::Apply {
                    tenant: t,
                    ops: merged,
                    acks,
                }) if *t == tenant && merged.len() + ops.len() <= MAX_OPS => {
                    merged.extend(ops);
                    *acks += 1;
                }
                _ => {
                    flush(stream, &mut pending, client, edge)?;
                    pending = Some(Pending::Apply {
                        tenant,
                        ops,
                        acks: 1,
                    });
                }
            },
            Request::Sweep { tenant, n } if config.batch => match &mut pending {
                Some(Pending::Sweep {
                    tenant: t,
                    n: total,
                    acks,
                }) if *t == tenant && *total + n <= MAX_SWEEPS => {
                    *total += n;
                    *acks += 1;
                }
                _ => {
                    flush(stream, &mut pending, client, edge)?;
                    pending = Some(Pending::Sweep { tenant, n, acks: 1 });
                }
            },
            Request::Subscribe {
                tenant,
                count,
                every,
            } => {
                flush(stream, &mut pending, client, edge)?;
                serve_subscribe(stream, client, edge, tenant, count, every)?;
            }
            other => {
                flush(stream, &mut pending, client, edge)?;
                let start = Instant::now();
                let reply = execute(client, other);
                edge.observe_hist("request_seconds", start.elapsed().as_secs_f64());
                if !reply.is_ok() {
                    edge.inc("exec_errors");
                }
                write_line(stream, &reply.render())?;
            }
        }
    }
    flush(stream, &mut pending, client, edge)
}

/// Execute one non-streaming request against the coordinator. `apply`
/// and `sweep` land here when edge batching is disabled; they are still
/// acknowledged at admission.
pub fn execute(client: &Client, req: Request) -> Response {
    let done = |sent: Result<()>| match sent {
        Ok(()) => Response::Ok,
        Err(e) => Response::Exec(e.to_string()),
    };
    match req {
        Request::Create {
            tenant,
            vars,
            chains,
            seed,
            k,
            sweep,
        } => done(client.create_tenant(
            tenant,
            FactorGraph::new_k(vars, k),
            TenantConfig {
                chains,
                seed,
                monitor_vars: Vec::new(),
                sweep,
            },
        )),
        Request::Apply { tenant, ops } => done(client.apply(tenant, ops)),
        Request::Sweep { tenant, n } => done(client.sweep(tenant, n)),
        Request::Clamp { tenant, v, state } => done(client.clamp(tenant, v, state)),
        Request::Unclamp { tenant, v } => done(client.unclamp(tenant, v)),
        Request::Marginals { tenant } => match client.marginals(tenant) {
            Ok(m) => Response::Marginals(m),
            Err(e) => Response::Exec(e.to_string()),
        },
        Request::Stats { tenant } => match client.stats(tenant) {
            Ok(s) => Response::Stats(Box::new(s)),
            Err(e) => Response::Exec(e.to_string()),
        },
        Request::Drop { tenant } => match client.drop_tenant(tenant) {
            Ok(existed) => Response::Dropped(existed),
            Err(e) => Response::Exec(e.to_string()),
        },
        Request::Subscribe { tenant, .. } => {
            // streaming is a connection-handler concern; a bare execute
            // degrades to a single-event probe of current state
            match client.stats(tenant) {
                Ok(_) => Response::Ok,
                Err(e) => Response::Exec(e.to_string()),
            }
        }
    }
}

/// Stream `count` marginal snapshots `every` sweeps apart, then `ok`.
/// The sweep is issued fire-and-forget and the follow-up marginals query
/// acts as the barrier (FIFO per tenant), so each event reflects at
/// least `every * (index + 1)` additional sweeps.
fn serve_subscribe(
    stream: &mut TcpStream,
    client: &Client,
    edge: &MetricsView,
    tenant: TenantId,
    count: usize,
    every: usize,
) -> std::io::Result<()> {
    for index in 0..count {
        let start = Instant::now();
        if let Err(e) = client.sweep(tenant, every) {
            edge.inc("exec_errors");
            return write_line(stream, &Response::Exec(e.to_string()).render());
        }
        let (marginals, stats) = match client.marginals(tenant).and_then(|m| {
            let s = client.stats(tenant)?;
            Ok((m, s))
        }) {
            Ok(pair) => pair,
            Err(e) => {
                edge.inc("exec_errors");
                return write_line(stream, &Response::Exec(e.to_string()).render());
            }
        };
        edge.observe_hist("request_seconds", start.elapsed().as_secs_f64());
        let event = Response::Event {
            index,
            sweeps_done: stats.sweeps_done,
            mean: mean_or_zero(&marginals),
        };
        write_line(stream, &event.render())?;
    }
    write_line(stream, &Response::Ok.render())
}
