//! Single-tenant compat façade over the sharded coordinator.
//!
//! [`Server`] is the PR-2 API — one dynamic MRF behind a request loop —
//! now implemented as a 1-shard [`Coordinator`] hosting exactly one
//! tenant (id 0). Existing callers keep their `spawn/handle/shutdown`
//! shape; new code should talk to [`Coordinator`]/[`Client`] directly
//! and host many tenants per process.
//!
//! Differences from the pre-refactor server, on purpose:
//!
//! * [`Handle::marginals`]/[`Handle::mixing`]/[`Handle::stats`] return
//!   [`Result`] instead of panicking with `expect("server dropped")` —
//!   a dead shard degrades into an error the caller can route around.
//! * [`replay_trace`] returns the final marginals, as its doc always
//!   claimed.
//! * The `ops` metrics counter increments by the batch size per `Apply`
//!   (it used to re-add the cumulative total every batch, inflating the
//!   counter quadratically).

use crate::diagnostics::MixingResult;
use crate::graph::FactorGraph;
use crate::util::error::Result;
use crate::workloads::{ChurnOp, ChurnTrace};

use super::dispatch::DispatchPolicy;
use super::metrics::Metrics;
use super::tenant::TenantConfig;
use super::{Client, Coordinator, CoordinatorConfig};

/// The façade's single tenant id (scope key `tenant0` in the metrics).
const TENANT: u64 = 0;

/// Server construction parameters (compat shape).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Chains (engine lanes) of the single tenant.
    pub chains: usize,
    /// Root RNG seed of the tenant ensemble.
    pub seed: u64,
    /// Target sweeps per idle background slice (0 disables background
    /// sweeping). Internally mapped to a DRR quantum at the spawn-time
    /// model cost, so a heavily churned tenant's slices shrink in sweep
    /// count but stay constant in work.
    pub background_sweeps: usize,
    /// Worker threads for sweep parallelism (0 = no pool).
    pub pool_threads: usize,
    /// Variables to monitor for PSRF (empty = magnetization only).
    pub monitor_vars: Vec<usize>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            chains: 10,
            seed: 0xC0FFEE,
            background_sweeps: 16,
            pool_threads: 0,
            monitor_vars: Vec::new(),
        }
    }
}

/// Snapshot of server state (compat shape; see
/// [`super::TenantStats`] for the richer multi-tenant form).
#[derive(Clone, Debug, PartialEq)]
pub struct ServerStats {
    /// Variables in the served model.
    pub num_vars: usize,
    /// Live factors in the served model.
    pub num_factors: usize,
    /// Total sweeps (foreground + background).
    pub sweeps_done: usize,
    /// Churn operations applied so far.
    pub ops_applied: u64,
    /// The graph's monotone topology version.
    pub graph_version: u64,
}

/// Client handle to a running server.
#[derive(Clone)]
pub struct Handle {
    client: Client,
}

impl Handle {
    /// Apply topology mutations (fire-and-forget, FIFO with later calls).
    pub fn apply(&self, ops: Vec<ChurnOp>) {
        let _ = self.client.apply(TENANT, ops);
    }

    /// Run exactly `n` foreground sweeps before answering anything else.
    pub fn sweep(&self, n: usize) {
        let _ = self.client.sweep(TENANT, n);
    }

    /// Drop accumulated statistics (e.g. after burn-in).
    pub fn reset_stats(&self) {
        let _ = self.client.reset_stats(TENANT);
    }

    /// Posterior marginal estimates; `Err` if the server is gone.
    pub fn marginals(&self) -> Result<Vec<f64>> {
        self.client.marginals(TENANT)
    }

    /// PSRF mixing diagnosis; `Err` if the server is gone.
    pub fn mixing(&self, threshold: f64, stride: usize) -> Result<MixingResult> {
        self.client.mixing(TENANT, threshold, stride)
    }

    /// Server counters; `Err` if the server is gone.
    pub fn stats(&self) -> Result<ServerStats> {
        let t = self.client.stats(TENANT)?;
        Ok(ServerStats {
            num_vars: t.num_vars,
            num_factors: t.num_factors,
            sweeps_done: t.sweeps_done,
            ops_applied: t.ops_applied,
            graph_version: t.graph_version,
        })
    }
}

/// A running single-model server (compat façade; see module docs).
pub struct Server {
    coord: Coordinator,
    handle: Handle,
    /// Cheap-clone handle onto the coordinator's metrics registry.
    pub metrics: Metrics,
}

impl Server {
    /// Spawn a 1-shard coordinator hosting `graph` as its only tenant.
    pub fn spawn(graph: FactorGraph, config: ServerConfig) -> Server {
        let quantum = if config.background_sweeps == 0 {
            0
        } else {
            // background_sweeps sweeps per slice at the spawn-time cost —
            // priced by the same accounting the scheduler debits, so the
            // mapping cannot drift from DualModel::sweep_cost
            let per_sweep = crate::duality::DualModel::from_graph(&graph).sweep_cost().max(1);
            config.background_sweeps as u64 * per_sweep
        };
        let coord = Coordinator::spawn(CoordinatorConfig {
            shards: 1,
            pool_threads: config.pool_threads,
            quantum,
            dispatch: DispatchPolicy::default(),
            manifest: None,
        });
        let client = coord.client();
        let metrics = coord.metrics().clone();
        client
            .create_tenant(
                TENANT,
                graph,
                TenantConfig {
                    chains: config.chains,
                    seed: config.seed,
                    monitor_vars: config.monitor_vars.clone(),
                    ..TenantConfig::default()
                },
            )
            .expect("freshly spawned shard hosts the façade tenant");
        Server {
            coord,
            handle: Handle { client },
            metrics,
        }
    }

    /// A cloneable client handle to this server.
    pub fn handle(&self) -> Handle {
        self.handle.clone()
    }

    /// Graceful shutdown (idempotent; also runs on drop).
    pub fn shutdown(&mut self) {
        self.coord.shutdown();
    }
}

/// Replay a churn trace against a server, sweeping between ops; returns
/// the final marginals (used by the dynamic example + bench). If the
/// server dies mid-replay the result is empty — query [`Handle::stats`]
/// for the error.
pub fn replay_trace(handle: &Handle, trace: &ChurnTrace, sweeps_per_op: usize) -> Vec<f64> {
    for op in &trace.ops {
        handle.apply(vec![op.clone()]);
        handle.sweep(sweeps_per_op);
    }
    handle.marginals().unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::PairFactor;
    use crate::inference::exact;
    use crate::workloads;

    #[test]
    fn server_answers_marginals() {
        let g = workloads::ising_grid(3, 3, 0.3, 0.1);
        let mut server = Server::spawn(
            g.clone(),
            ServerConfig {
                chains: 8,
                background_sweeps: 64,
                ..Default::default()
            },
        );
        let h = server.handle();
        h.sweep(300);
        h.reset_stats();
        h.sweep(12_000);
        let got = h.marginals().unwrap();
        let want = exact::enumerate(&g).marginals;
        for v in 0..9 {
            assert!(
                (got[v] - want[v]).abs() < 0.015,
                "v={v}: {} vs {}",
                got[v],
                want[v]
            );
        }
        let stats = h.stats().unwrap();
        assert!(stats.sweeps_done >= 12_300);
        assert_eq!(stats.num_vars, 9);
        server.shutdown();
    }

    #[test]
    fn server_applies_churn_and_tracks_target() {
        let mut g = FactorGraph::new(2);
        g.set_unary(0, 1.5);
        let mut server = Server::spawn(g, ServerConfig::default());
        let h = server.handle();
        h.apply(vec![ChurnOp::Add {
            v1: 0,
            v2: 1,
            beta: 1.2,
        }]);
        h.sweep(200);
        h.reset_stats();
        h.sweep(10_000);
        let got = h.marginals().unwrap();
        // compare to exact on the mutated graph
        let mut g2 = FactorGraph::new(2);
        g2.set_unary(0, 1.5);
        g2.add_factor(PairFactor::ising(0, 1, 1.2));
        let want = exact::enumerate(&g2).marginals;
        for v in 0..2 {
            assert!(
                (got[v] - want[v]).abs() < 0.02,
                "v={v}: {} vs {}",
                got[v],
                want[v]
            );
        }
        let stats = h.stats().unwrap();
        assert_eq!(stats.num_factors, 1);
        assert_eq!(stats.ops_applied, 1);
        server.shutdown();
    }

    #[test]
    fn ops_counter_increments_by_batch_size() {
        // regression for the quadratic ops counter: the old worker did
        // `metrics.add("ops", ops_applied)` with the *cumulative* total,
        // so two batches of 3 + 2 ops recorded 3 + 5 = 8. It must be 5.
        let mut server = Server::spawn(FactorGraph::new(4), ServerConfig::default());
        let h = server.handle();
        h.apply(vec![
            ChurnOp::Add { v1: 0, v2: 1, beta: 0.2 },
            ChurnOp::Add { v1: 1, v2: 2, beta: 0.2 },
            ChurnOp::Add { v1: 2, v2: 3, beta: 0.2 },
        ]);
        h.apply(vec![
            ChurnOp::Add { v1: 0, v2: 3, beta: 0.1 },
            ChurnOp::RemoveLive { index: 0 },
        ]);
        let stats = h.stats().unwrap(); // barrier: both batches processed
        assert_eq!(stats.ops_applied, 5);
        assert_eq!(
            server.metrics.counter("tenant0.ops"),
            5,
            "metrics counter must match ops applied, not grow quadratically"
        );
        server.shutdown();
    }

    #[test]
    fn replay_trace_returns_final_marginals() {
        let trace = ChurnTrace::generate(6, 6, 20, 0.4, 9);
        let mut server = Server::spawn(
            FactorGraph::new(6),
            ServerConfig {
                chains: 6,
                background_sweeps: 0,
                ..Default::default()
            },
        );
        let h = server.handle();
        let got = replay_trace(&h, &trace, 4);
        assert_eq!(got.len(), 6, "one marginal per variable");
        assert!(got.iter().all(|p| (0.0..=1.0).contains(p)));
        server.shutdown();
    }

    #[test]
    fn dead_server_yields_errors_not_panics() {
        // regression for the expect("server dropped") panics
        let mut server = Server::spawn(FactorGraph::new(2), ServerConfig::default());
        let h = server.handle();
        server.shutdown();
        assert!(h.marginals().is_err());
        assert!(h.mixing(1.1, 10).is_err());
        assert!(h.stats().is_err());
    }

    #[test]
    fn background_sweeping_progresses() {
        let g = workloads::ising_grid(4, 4, 0.2, 0.0);
        let mut server = Server::spawn(
            g,
            ServerConfig {
                background_sweeps: 32,
                ..Default::default()
            },
        );
        let h = server.handle();
        std::thread::sleep(std::time::Duration::from_millis(100));
        let s1 = h.stats().unwrap();
        std::thread::sleep(std::time::Duration::from_millis(100));
        let s2 = h.stats().unwrap();
        assert!(
            s2.sweeps_done > s1.sweeps_done,
            "background sweeps idle: {} -> {}",
            s1.sweeps_done,
            s2.sweeps_done
        );
        server.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent() {
        let g = workloads::ising_grid(2, 2, 0.1, 0.0);
        let mut server = Server::spawn(g, ServerConfig::default());
        server.shutdown();
        server.shutdown();
    }
}
