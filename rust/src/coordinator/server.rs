//! The request-loop server: dynamic MRF hosting as a service.
//!
//! One worker thread owns the graph + ensemble and drains a request
//! channel; callers hold a cheap [`Handle`] (clonable sender + typed
//! reply channels). Between requests the server keeps sweeping in
//! `background_sweeps`-sized slices so inference continuously refines —
//! the "sampling never stops while the topology churns" deployment the
//! paper argues for. (std::mpsc everywhere: tokio is unavailable offline.)

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::diagnostics::MixingResult;
use crate::graph::{FactorGraph, FactorId, PairFactor};
use crate::util::ThreadPool;
use crate::workloads::{ChurnOp, ChurnTrace};

use super::ensemble::PdEnsemble;
use super::metrics::Metrics;

/// Server construction parameters.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub chains: usize,
    pub seed: u64,
    /// Sweeps executed per idle slice between request polls.
    pub background_sweeps: usize,
    /// Worker threads for chain-parallel sweeps (0 = no pool).
    pub pool_threads: usize,
    /// Variables to monitor for PSRF (empty = magnetization only).
    pub monitor_vars: Vec<usize>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            chains: 10,
            seed: 0xC0FFEE,
            background_sweeps: 16,
            pool_threads: 0,
            monitor_vars: Vec::new(),
        }
    }
}

/// Requests accepted by the server.
pub enum Request {
    /// Apply topology mutations (resets statistics: the target changed).
    Apply(Vec<ChurnOp>),
    /// Run exactly `n` foreground sweeps before answering anything else.
    Sweep(usize),
    /// Drop accumulated statistics (e.g. after burn-in).
    ResetStats,
    /// Posterior marginal estimates.
    Marginals(Sender<Vec<f64>>),
    /// PSRF mixing diagnosis at `threshold` with checkpoint `stride`.
    Mixing {
        threshold: f64,
        stride: usize,
        reply: Sender<MixingResult>,
    },
    /// Server counters.
    Stats(Sender<ServerStats>),
    Shutdown,
}

/// Snapshot of server state.
#[derive(Clone, Debug, PartialEq)]
pub struct ServerStats {
    pub num_vars: usize,
    pub num_factors: usize,
    pub sweeps_done: usize,
    pub ops_applied: u64,
    pub graph_version: u64,
}

/// Client handle to a running server.
#[derive(Clone)]
pub struct Handle {
    tx: Sender<Request>,
}

impl Handle {
    pub fn apply(&self, ops: Vec<ChurnOp>) {
        let _ = self.tx.send(Request::Apply(ops));
    }

    pub fn sweep(&self, n: usize) {
        let _ = self.tx.send(Request::Sweep(n));
    }

    pub fn reset_stats(&self) {
        let _ = self.tx.send(Request::ResetStats);
    }

    pub fn marginals(&self) -> Vec<f64> {
        let (tx, rx) = channel();
        let _ = self.tx.send(Request::Marginals(tx));
        rx.recv().expect("server dropped")
    }

    pub fn mixing(&self, threshold: f64, stride: usize) -> MixingResult {
        let (tx, rx) = channel();
        let _ = self.tx.send(Request::Mixing {
            threshold,
            stride,
            reply: tx,
        });
        rx.recv().expect("server dropped")
    }

    pub fn stats(&self) -> ServerStats {
        let (tx, rx) = channel();
        let _ = self.tx.send(Request::Stats(tx));
        rx.recv().expect("server dropped")
    }
}

/// A running dynamic-MRF server.
pub struct Server {
    handle: Handle,
    join: Option<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
}

impl Server {
    /// Spawn the worker thread owning `graph`.
    pub fn spawn(graph: FactorGraph, config: ServerConfig) -> Server {
        let (tx, rx) = channel();
        let metrics = Arc::new(Metrics::new());
        let m2 = Arc::clone(&metrics);
        let join = std::thread::spawn(move || worker(graph, config, rx, m2));
        Server {
            handle: Handle { tx },
            join: Some(join),
            metrics,
        }
    }

    pub fn handle(&self) -> Handle {
        self.handle.clone()
    }

    /// Graceful shutdown (idempotent).
    pub fn shutdown(&mut self) {
        let _ = self.handle.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker(
    mut graph: FactorGraph,
    config: ServerConfig,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
) {
    let mut ensemble = PdEnsemble::new(&graph, config.chains, config.seed);
    if config.pool_threads > 0 {
        ensemble = ensemble.with_pool(Arc::new(ThreadPool::new(config.pool_threads)));
    }
    if !config.monitor_vars.is_empty() {
        ensemble.monitor_vars(config.monitor_vars.clone());
    }
    ensemble.init_overdispersed();
    let mut live: Vec<FactorId> = graph.factors().map(|(id, _)| id).collect();
    let mut ops_applied = 0u64;

    loop {
        // drain all pending requests, then do a background slice
        let req = match rx.try_recv() {
            Ok(r) => Some(r),
            Err(std::sync::mpsc::TryRecvError::Empty) => None,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => return,
        };
        match req {
            Some(Request::Apply(ops)) => {
                metrics.time("apply", || {
                    for op in &ops {
                        apply_op(&mut graph, &mut ensemble, &mut live, op);
                        ops_applied += 1;
                    }
                });
                metrics.add("ops", ops_applied);
                // the target distribution changed; stale stats are biased
                ensemble.reset_stats();
            }
            Some(Request::Sweep(n)) => {
                metrics.time("sweep", || ensemble.run(n));
            }
            Some(Request::ResetStats) => ensemble.reset_stats(),
            Some(Request::Marginals(reply)) => {
                let _ = reply.send(ensemble.marginals());
            }
            Some(Request::Mixing {
                threshold,
                stride,
                reply,
            }) => {
                let _ = reply.send(ensemble.mixing(threshold, stride));
            }
            Some(Request::Stats(reply)) => {
                let _ = reply.send(ServerStats {
                    num_vars: graph.num_vars(),
                    num_factors: graph.num_factors(),
                    sweeps_done: ensemble.sweeps_done(),
                    ops_applied,
                    graph_version: graph.version(),
                });
            }
            Some(Request::Shutdown) => return,
            None => {
                // idle: keep sampling
                metrics.time("background", || ensemble.run(config.background_sweeps));
                metrics.add("background_sweeps", config.background_sweeps as u64);
            }
        }
    }
}

fn apply_op(
    graph: &mut FactorGraph,
    ensemble: &mut PdEnsemble,
    live: &mut Vec<FactorId>,
    op: &ChurnOp,
) {
    match *op {
        ChurnOp::Add { v1, v2, beta } => {
            let f = PairFactor::ising(v1, v2, beta);
            let id = graph.add_factor(f);
            ensemble.add_factor(id, graph.factor(id).unwrap());
            live.push(id);
        }
        ChurnOp::RemoveLive { index } => {
            let id = live.swap_remove(index);
            graph.remove_factor(id).expect("live desync");
            ensemble.remove_factor(id);
        }
    }
}

/// Replay a churn trace against a server, sweeping between ops; returns
/// final marginals (used by the dynamic example + bench).
pub fn replay_trace(handle: &Handle, trace: &ChurnTrace, sweeps_per_op: usize) {
    for op in &trace.ops {
        handle.apply(vec![op.clone()]);
        handle.sweep(sweeps_per_op);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::exact;
    use crate::workloads;

    #[test]
    fn server_answers_marginals() {
        let g = workloads::ising_grid(3, 3, 0.3, 0.1);
        let mut server = Server::spawn(
            g.clone(),
            ServerConfig {
                chains: 8,
                background_sweeps: 64,
                ..Default::default()
            },
        );
        let h = server.handle();
        h.sweep(300);
        h.reset_stats();
        h.sweep(12_000);
        let got = h.marginals();
        let want = exact::enumerate(&g).marginals;
        for v in 0..9 {
            assert!(
                (got[v] - want[v]).abs() < 0.015,
                "v={v}: {} vs {}",
                got[v],
                want[v]
            );
        }
        let stats = h.stats();
        assert!(stats.sweeps_done >= 12_300);
        assert_eq!(stats.num_vars, 9);
        server.shutdown();
    }

    #[test]
    fn server_applies_churn_and_tracks_target() {
        let mut g = FactorGraph::new(2);
        g.set_unary(0, 1.5);
        let mut server = Server::spawn(g, ServerConfig::default());
        let h = server.handle();
        h.apply(vec![ChurnOp::Add {
            v1: 0,
            v2: 1,
            beta: 1.2,
        }]);
        h.sweep(200);
        h.reset_stats();
        h.sweep(10_000);
        let got = h.marginals();
        // compare to exact on the mutated graph
        let mut g2 = FactorGraph::new(2);
        g2.set_unary(0, 1.5);
        g2.add_factor(PairFactor::ising(0, 1, 1.2));
        let want = exact::enumerate(&g2).marginals;
        for v in 0..2 {
            assert!(
                (got[v] - want[v]).abs() < 0.02,
                "v={v}: {} vs {}",
                got[v],
                want[v]
            );
        }
        let stats = h.stats();
        assert_eq!(stats.num_factors, 1);
        assert_eq!(stats.ops_applied, 1);
        server.shutdown();
    }

    #[test]
    fn background_sweeping_progresses() {
        let g = workloads::ising_grid(4, 4, 0.2, 0.0);
        let mut server = Server::spawn(
            g,
            ServerConfig {
                background_sweeps: 32,
                ..Default::default()
            },
        );
        let h = server.handle();
        std::thread::sleep(std::time::Duration::from_millis(100));
        let s1 = h.stats();
        std::thread::sleep(std::time::Duration::from_millis(100));
        let s2 = h.stats();
        assert!(
            s2.sweeps_done > s1.sweeps_done,
            "background sweeps idle: {} -> {}",
            s1.sweeps_done,
            s2.sweeps_done
        );
        server.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent() {
        let g = workloads::ising_grid(2, 2, 0.1, 0.0);
        let mut server = Server::spawn(g, ServerConfig::default());
        server.shutdown();
        server.shutdown();
    }

    use crate::graph::{FactorGraph, PairFactor};
}
