//! Wire protocol for the network serving edge: a compact line-oriented
//! request language with spanned, labeled diagnostics.
//!
//! ## Framing
//!
//! One request per line, LF-terminated (a trailing CR is tolerated).
//! Replies are single lines too, except `subscribe`, which streams
//! `event` lines before its final `ok`. Blank lines are ignored (cheap
//! keepalive). Tokens are separated by ASCII whitespace.
//!
//! ## Grammar
//!
//! ```text
//! request   := create | apply | sweep | clamp | unclamp
//!            | marginals | stats | drop | subscribe
//! create    := "create" tenant vars [chains] [seed] ["k=" K] [policy]
//! policy    := "exact" | "minibatch" [":" degree [":" stride]]
//!            | "blocked" [":" cap [":" epoch]]
//! apply     := "apply" tenant op+
//! op        := "add" v1 v2 beta | "del" index
//! sweep     := "sweep" tenant n
//! clamp     := "clamp" tenant v state
//! unclamp   := "unclamp" tenant v
//! marginals := "marginals" tenant
//! stats     := "stats" tenant
//! drop      := "drop" tenant
//! subscribe := "subscribe" tenant count every
//! ```
//!
//! `k=K` hosts a K-state Potts tenant (`2 ≤ K ≤ 8`; omitted = binary);
//! `clamp` pins a site to an evidence state so subsequent sweeps target
//! the conditional law, `unclamp` releases it. The parser only range
//! checks against the wire caps — whether the state fits the *tenant's*
//! cardinality is an execution-time check that comes back as `err exec`.
//!
//! ## Diagnostics
//!
//! Malformed input never produces a bare "parse error" and never kills
//! the connection: every failure is a [`Diagnostic`] carrying the byte
//! span of the offending region plus an expected-token label (the
//! rust-sitter error-reporting idiom), rendered on the wire as
//!
//! ```text
//! err parse span=<start>:<end> expected=<label>; found=<found>
//! ```
//!
//! Oversized and truncated frames are reported through the same shape
//! ([`oversized`], [`truncated`]); backpressure rejections use
//! `err overloaded …` and tenant-level failures `err exec …` — see
//! `docs/PROTOCOL.md` for the full reply grammar and semantics.

use crate::engine::SweepPolicy;
use crate::graph::MAX_STATES;
use crate::util::span::{Diagnostic, Span};
use crate::workloads::ChurnOp;

use super::dispatch::DispatchDecision;
use super::tenant::{TenantId, TenantStats};

/// Hard cap on variables accepted by `create` over the wire.
pub const MAX_VARS: usize = 1 << 20;
/// Hard cap on chains accepted by `create` over the wire.
pub const MAX_CHAINS: usize = 1024;
/// Hard cap on `sweep`/`subscribe` sweep counts per request.
pub const MAX_SWEEPS: usize = 1_000_000;
/// Hard cap on churn ops in one `apply` request.
pub const MAX_OPS: usize = 4096;
/// Default per-frame byte budget enforced by the connection handler.
pub const DEFAULT_MAX_FRAME: usize = 16 * 1024;

/// One parsed request of the wire protocol (see module grammar).
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Host a new tenant with an empty `vars`-variable model.
    Create {
        /// Tenant id (routing key).
        tenant: TenantId,
        /// Variable count of the tenant's model.
        vars: usize,
        /// Ensemble chains (lanes).
        chains: usize,
        /// Per-tenant RNG root.
        seed: u64,
        /// States per variable (`k=K` on the wire; 2 = binary Ising,
        /// larger = Potts on the indicator dual).
        k: usize,
        /// Sweep policy (`exact` unless the client opts into minibatched
        /// hub updates or adaptive tree-blocking; λ knobs stay at their
        /// defaults on the wire).
        sweep: SweepPolicy,
    },
    /// Apply churn ops to a tenant (acknowledged at admission).
    Apply {
        /// Target tenant.
        tenant: TenantId,
        /// Parsed topology mutations, in request order.
        ops: Vec<ChurnOp>,
    },
    /// Run foreground sweeps (acknowledged at admission).
    Sweep {
        /// Target tenant.
        tenant: TenantId,
        /// Sweep count.
        n: usize,
    },
    /// Pin a site to an evidence state (synchronous; range/policy
    /// violations come back as `err exec`).
    Clamp {
        /// Target tenant.
        tenant: TenantId,
        /// Site to clamp.
        v: usize,
        /// Evidence state (`< k` of the tenant's model).
        state: u8,
    },
    /// Release a clamped site.
    Unclamp {
        /// Target tenant.
        tenant: TenantId,
        /// Site to release.
        v: usize,
    },
    /// Read posterior marginal estimates.
    Marginals {
        /// Target tenant.
        tenant: TenantId,
    },
    /// Read the tenant serving snapshot.
    Stats {
        /// Target tenant.
        tenant: TenantId,
    },
    /// Drop the tenant.
    Drop {
        /// Target tenant.
        tenant: TenantId,
    },
    /// Stream `count` marginal snapshots, `every` sweeps apart.
    Subscribe {
        /// Target tenant.
        tenant: TenantId,
        /// Number of `event` lines to stream.
        count: usize,
        /// Foreground sweeps between consecutive events.
        every: usize,
    },
}

impl Request {
    /// The tenant a request addresses (every verb has one) — the
    /// admission-control key.
    pub fn tenant(&self) -> TenantId {
        match *self {
            Request::Create { tenant, .. }
            | Request::Apply { tenant, .. }
            | Request::Sweep { tenant, .. }
            | Request::Clamp { tenant, .. }
            | Request::Unclamp { tenant, .. }
            | Request::Marginals { tenant }
            | Request::Stats { tenant }
            | Request::Drop { tenant }
            | Request::Subscribe { tenant, .. } => tenant,
        }
    }
}

/// One reply line of the wire protocol ([`Response::render`] is the exact
/// wire form, without the trailing newline).
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// Request accepted / completed.
    Ok,
    /// Reply to `drop`: whether the tenant existed.
    Dropped(bool),
    /// Reply to `marginals`.
    Marginals(Vec<f64>),
    /// Reply to `stats`.
    Stats(Box<TenantStats>),
    /// One streamed `subscribe` snapshot.
    Event {
        /// Zero-based event index within the subscription.
        index: usize,
        /// Tenant sweeps completed when the snapshot was taken.
        sweeps_done: usize,
        /// Mean marginal of the snapshot (NaN-safe: 0 for empty models).
        mean: f64,
    },
    /// Spanned, labeled parse failure.
    ParseError(Diagnostic),
    /// Admission-control rejection: the named queue is at its limit.
    Overloaded {
        /// Which bound tripped (`"tenant <id>"` or `"shard <i>"`).
        scope: String,
        /// Observed queue depth.
        depth: u64,
        /// Configured limit.
        limit: u64,
    },
    /// Execution failure (unknown tenant, dead shard, …).
    Exec(String),
}

impl Response {
    /// Render the single wire line for this reply (no trailing newline).
    pub fn render(&self) -> String {
        match self {
            Response::Ok => "ok".to_string(),
            Response::Dropped(existed) => format!("ok dropped={existed}"),
            Response::Marginals(m) => {
                let mut s = format!("ok marginals n={}", m.len());
                for p in m {
                    s.push(' ');
                    s.push_str(&format!("{p:.6}"));
                }
                s
            }
            Response::Stats(t) => {
                let dispatch = match &t.dispatch {
                    DispatchDecision::Native => "native".to_string(),
                    DispatchDecision::Xla(name) => format!("xla:{name}"),
                };
                format!(
                    "ok stats vars={} factors={} sweeps={} background={} ops={} \
                     stable_for={} cost={} suspended={} dispatch={dispatch} policy={} \
                     blocks={} blocked_vars={} tree_slots={} clamped={} k={}",
                    t.num_vars,
                    t.num_factors,
                    t.sweeps_done,
                    t.background_sweeps,
                    t.ops_applied,
                    t.stable_for,
                    t.cost,
                    t.suspended,
                    t.policy,
                    t.blocks,
                    t.blocked_vars,
                    t.tree_slots,
                    t.clamped,
                    t.k,
                )
            }
            Response::Event {
                index,
                sweeps_done,
                mean,
            } => format!("event index={index} sweeps={sweeps_done} mean={mean:.6}"),
            Response::ParseError(d) => format!(
                "err parse span={}:{} expected={}; found={}",
                d.span.start, d.span.end, d.expected, d.found
            ),
            Response::Overloaded { scope, depth, limit } => {
                format!("err overloaded {scope} depth={depth} limit={limit}")
            }
            Response::Exec(msg) => format!("err exec {msg}"),
        }
    }

    /// Whether this reply reports success (`ok …` / `event …`).
    pub fn is_ok(&self) -> bool {
        !matches!(
            self,
            Response::ParseError(_) | Response::Overloaded { .. } | Response::Exec(_)
        )
    }
}

/// Coarse classification of a reply line, for load generators and tests
/// that only need the outcome class, not the payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplyKind {
    /// `ok …` — request succeeded.
    Ok,
    /// `event …` — a streamed subscription snapshot.
    Event,
    /// `err parse …` — spanned diagnostic.
    ParseError,
    /// `err overloaded …` — admission rejection.
    Overloaded,
    /// `err exec …` — execution failure.
    ExecError,
    /// Anything else (protocol violation by the server).
    Unknown,
}

/// Classify one reply line (without its newline).
pub fn classify_reply(line: &str) -> ReplyKind {
    if line == "ok" || line.starts_with("ok ") {
        ReplyKind::Ok
    } else if line.starts_with("event ") {
        ReplyKind::Event
    } else if line.starts_with("err parse ") {
        ReplyKind::ParseError
    } else if line.starts_with("err overloaded ") {
        ReplyKind::Overloaded
    } else if line.starts_with("err exec ") {
        ReplyKind::ExecError
    } else {
        ReplyKind::Unknown
    }
}

/// Diagnostic for a frame exceeding the connection's byte budget. The
/// span covers the whole budget-sized prefix; the reader then discards
/// until the next newline so the connection survives.
pub fn oversized(len_so_far: usize, max: usize) -> Diagnostic {
    Diagnostic::new(
        Span::new(0, len_so_far),
        format!("frame of at most {max} bytes"),
        format!("{len_so_far}+ bytes without a newline"),
    )
}

/// Diagnostic for a frame truncated by EOF (bytes arrived, the newline
/// never did).
pub fn truncated(len: usize) -> Diagnostic {
    Diagnostic::new(
        Span::new(0, len),
        "newline-terminated frame",
        format!("end of stream after {len} bytes"),
    )
}

// -- parser -----------------------------------------------------------------

/// Split `src` into whitespace-separated tokens with byte spans. ASCII
/// whitespace bytes are always char boundaries, so the slicing is safe
/// for arbitrary UTF-8 input.
fn tokenize(src: &str) -> Vec<(&str, Span)> {
    let bytes = src.as_bytes();
    let mut toks = Vec::new();
    let mut start: Option<usize> = None;
    for (i, b) in bytes.iter().enumerate() {
        if b.is_ascii_whitespace() {
            if let Some(s) = start.take() {
                toks.push((&src[s..i], Span::new(s, i)));
            }
        } else if start.is_none() {
            start = Some(i);
        }
    }
    if let Some(s) = start {
        toks.push((&src[s..], Span::new(s, src.len())));
    }
    toks
}

/// Token cursor with labeled-expectation error helpers.
struct Cursor<'a> {
    toks: Vec<(&'a str, Span)>,
    next: usize,
    /// Where "end of line" errors point (one past the last byte).
    eol: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, expected: &str) -> Result<(&'a str, Span), Diagnostic> {
        match self.toks.get(self.next) {
            Some(&(tok, span)) => {
                self.next += 1;
                Ok((tok, span))
            }
            None => Err(Diagnostic::new(
                Span::point(self.eol),
                expected,
                "end of line",
            )),
        }
    }

    fn peek(&self) -> Option<(&'a str, Span)> {
        self.toks.get(self.next).copied()
    }

    fn parse_with<T>(
        &mut self,
        expected: &str,
        parse: impl FnOnce(&str) -> Option<T>,
    ) -> Result<(T, Span), Diagnostic> {
        let (tok, span) = self.take(expected)?;
        match parse(tok) {
            Some(v) => Ok((v, span)),
            None => Err(Diagnostic::new(span, expected, format!("\"{tok}\""))),
        }
    }

    fn u64(&mut self, expected: &str) -> Result<(u64, Span), Diagnostic> {
        self.parse_with(expected, |t| t.parse::<u64>().ok())
    }

    fn usize_in(
        &mut self,
        expected: &str,
        lo: usize,
        hi: usize,
    ) -> Result<(usize, Span), Diagnostic> {
        self.parse_with(expected, |t| {
            t.parse::<usize>().ok().filter(|v| (lo..=hi).contains(v))
        })
    }

    fn f64_finite(&mut self, expected: &str) -> Result<(f64, Span), Diagnostic> {
        self.parse_with(expected, |t| t.parse::<f64>().ok().filter(|v| v.is_finite()))
    }

    fn finish(&mut self) -> Result<(), Diagnostic> {
        match self.peek() {
            None => Ok(()),
            Some((tok, span)) => Err(Diagnostic::new(
                span,
                "end of line",
                format!("\"{tok}\""),
            )),
        }
    }
}

/// Label listing the accepted verbs, shared by the unknown-verb and
/// empty-line diagnostics.
const VERBS: &str = "verb create|apply|sweep|clamp|unclamp|marginals|stats|drop|subscribe";

/// Parse one request line (no trailing newline; a trailing CR is
/// stripped). Errors are spanned, labeled [`Diagnostic`]s — see the
/// module docs for the wire rendering.
pub fn parse_request(line: &str) -> Result<Request, Diagnostic> {
    let line = line.strip_suffix('\r').unwrap_or(line);
    let mut c = Cursor {
        toks: tokenize(line),
        next: 0,
        eol: line.len(),
    };
    let (verb, verb_span) = c.take(VERBS)?;
    let req = match verb {
        "create" => {
            let (tenant, _) = c.u64("tenant id (u64)")?;
            let (vars, _) = c.usize_in("variable count 1..=1048576", 1, MAX_VARS)?;
            // the optional numeric knobs are positional; a non-numeric
            // trailing token is the (also optional) sweep policy
            let next_is_numeric =
                |c: &Cursor| c.peek().is_some_and(|(t, _)| t.bytes().all(|b| b.is_ascii_digit()));
            let chains = if next_is_numeric(&c) {
                c.usize_in("chain count 1..=1024", 1, MAX_CHAINS)?.0
            } else {
                8
            };
            let seed = if next_is_numeric(&c) {
                c.u64("seed (u64)")?.0
            } else {
                tenant ^ 0x9E37_79B9_7F4A_7C15
            };
            // `k=K` is non-numeric too, so it sits unambiguously between
            // the numeric knobs and the policy token
            let k = match c.peek() {
                Some((t, _)) if t.starts_with("k=") => {
                    c.parse_with("state count k=2..=8", |t| {
                        t.strip_prefix("k=")
                            .and_then(|v| v.parse::<usize>().ok())
                            .filter(|k| (2..=MAX_STATES).contains(k))
                    })?
                    .0
                }
                _ => 2,
            };
            let sweep = match c.peek() {
                Some(_) => {
                    c.parse_with(
                        "sweep policy exact|minibatch[:degree[:stride]]|blocked[:cap[:epoch]]",
                        SweepPolicy::parse,
                    )?
                    .0
                }
                None => SweepPolicy::default(),
            };
            Request::Create {
                tenant,
                vars,
                chains,
                seed,
                k,
                sweep,
            }
        }
        "apply" => {
            let (tenant, _) = c.u64("tenant id (u64)")?;
            let mut ops = Vec::new();
            loop {
                let (op, op_span) = c.take("churn op add|del")?;
                match op {
                    "add" => {
                        let (v1, _) = c.usize_in("variable index v1", 0, MAX_VARS - 1)?;
                        let (v2, _) = c.usize_in("variable index v2", 0, MAX_VARS - 1)?;
                        let (beta, _) = c.f64_finite("finite coupling beta (f64)")?;
                        ops.push(ChurnOp::Add { v1, v2, beta });
                    }
                    "del" => {
                        let (index, _) = c.usize_in("live-factor index", 0, usize::MAX)?;
                        ops.push(ChurnOp::RemoveLive { index });
                    }
                    other => {
                        return Err(Diagnostic::new(
                            op_span,
                            "churn op add|del",
                            format!("\"{other}\""),
                        ));
                    }
                }
                if ops.len() > MAX_OPS {
                    return Err(Diagnostic::new(
                        Span::new(op_span.start, line.len()),
                        format!("at most {MAX_OPS} ops per apply"),
                        format!("{}+ ops", ops.len()),
                    ));
                }
                if c.peek().is_none() {
                    break;
                }
            }
            Request::Apply { tenant, ops }
        }
        "sweep" => {
            let (tenant, _) = c.u64("tenant id (u64)")?;
            let (n, _) = c.usize_in("sweep count 1..=1000000", 1, MAX_SWEEPS)?;
            Request::Sweep { tenant, n }
        }
        "clamp" => {
            let (tenant, _) = c.u64("tenant id (u64)")?;
            let (v, _) = c.usize_in("variable index", 0, MAX_VARS - 1)?;
            let (state, _) = c.usize_in("evidence state 0..=7", 0, MAX_STATES - 1)?;
            Request::Clamp {
                tenant,
                v,
                state: state as u8,
            }
        }
        "unclamp" => {
            let (tenant, _) = c.u64("tenant id (u64)")?;
            let (v, _) = c.usize_in("variable index", 0, MAX_VARS - 1)?;
            Request::Unclamp { tenant, v }
        }
        "marginals" => Request::Marginals {
            tenant: c.u64("tenant id (u64)")?.0,
        },
        "stats" => Request::Stats {
            tenant: c.u64("tenant id (u64)")?.0,
        },
        "drop" => Request::Drop {
            tenant: c.u64("tenant id (u64)")?.0,
        },
        "subscribe" => {
            let (tenant, _) = c.u64("tenant id (u64)")?;
            let (count, _) = c.usize_in("event count 1..=10000", 1, 10_000)?;
            let (every, _) = c.usize_in("sweeps per event 1..=1000000", 1, MAX_SWEEPS)?;
            Request::Subscribe {
                tenant,
                count,
                every,
            }
        }
        other => {
            return Err(Diagnostic::new(verb_span, VERBS, format!("\"{other}\"")));
        }
    };
    c.finish()?;
    Ok(req)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_err(line: &str) -> Diagnostic {
        parse_request(line).expect_err("must not parse")
    }

    #[test]
    fn round_trip_every_verb() {
        assert_eq!(
            parse_request("create 7 16 4 99").unwrap(),
            Request::Create {
                tenant: 7,
                vars: 16,
                chains: 4,
                seed: 99,
                k: 2,
                sweep: SweepPolicy::Exact,
            }
        );
        assert_eq!(
            parse_request("create 7 16").unwrap(),
            Request::Create {
                tenant: 7,
                vars: 16,
                chains: 8,
                seed: 7 ^ 0x9E37_79B9_7F4A_7C15,
                k: 2,
                sweep: SweepPolicy::Exact,
            }
        );
        assert_eq!(
            parse_request("apply 3 add 0 1 0.25 del 0 add 1 2 -0.5").unwrap(),
            Request::Apply {
                tenant: 3,
                ops: vec![
                    ChurnOp::Add {
                        v1: 0,
                        v2: 1,
                        beta: 0.25
                    },
                    ChurnOp::RemoveLive { index: 0 },
                    ChurnOp::Add {
                        v1: 1,
                        v2: 2,
                        beta: -0.5
                    },
                ]
            }
        );
        assert_eq!(
            parse_request("sweep 3 200").unwrap(),
            Request::Sweep { tenant: 3, n: 200 }
        );
        assert_eq!(
            parse_request("marginals 3").unwrap(),
            Request::Marginals { tenant: 3 }
        );
        assert_eq!(parse_request("stats 3").unwrap(), Request::Stats { tenant: 3 });
        assert_eq!(parse_request("drop 3").unwrap(), Request::Drop { tenant: 3 });
        assert_eq!(
            parse_request("subscribe 3 5 100").unwrap(),
            Request::Subscribe {
                tenant: 3,
                count: 5,
                every: 100
            }
        );
    }

    #[test]
    fn create_accepts_a_policy_after_any_prefix_of_the_numeric_knobs() {
        use crate::duality::MinibatchPolicy;
        let mb = |degree_threshold, theta_stride| {
            SweepPolicy::Minibatch(MinibatchPolicy {
                degree_threshold,
                theta_stride,
                ..MinibatchPolicy::default()
            })
        };
        // full form: tenant vars chains seed policy
        assert_eq!(
            parse_request("create 7 16 4 99 minibatch:128:4").unwrap(),
            Request::Create {
                tenant: 7,
                vars: 16,
                chains: 4,
                seed: 99,
                k: 2,
                sweep: mb(128, 4),
            }
        );
        // the policy token is non-numeric, so it can follow any prefix
        // of the optional numeric knobs without ambiguity
        assert_eq!(
            parse_request("create 7 16 minibatch").unwrap(),
            Request::Create {
                tenant: 7,
                vars: 16,
                chains: 8,
                seed: 7 ^ 0x9E37_79B9_7F4A_7C15,
                k: 2,
                sweep: SweepPolicy::Minibatch(MinibatchPolicy::default()),
            }
        );
        assert_eq!(
            parse_request("create 7 16 4 exact").unwrap(),
            Request::Create {
                tenant: 7,
                vars: 16,
                chains: 4,
                seed: 7 ^ 0x9E37_79B9_7F4A_7C15,
                k: 2,
                sweep: SweepPolicy::Exact,
            }
        );
        let d = parse_err("create 7 16 minibatch:0x8");
        assert!(d.expected.contains("sweep policy"), "{d}");
        assert_eq!(d.found, "\"minibatch:0x8\"");
        // a zero stride is rejected at parse time, not divided by later
        let d = parse_err("create 7 16 minibatch:8:0");
        assert!(d.expected.contains("sweep policy"), "{d}");
        // adaptive tree-blocking, with and without knobs
        use crate::duality::BlockPolicy;
        assert_eq!(
            parse_request("create 7 16 4 99 blocked:6:4").unwrap(),
            Request::Create {
                tenant: 7,
                vars: 16,
                chains: 4,
                seed: 99,
                k: 2,
                sweep: SweepPolicy::Blocked(BlockPolicy { cap: 6, epoch: 4 }),
            }
        );
        assert_eq!(
            parse_request("create 7 16 blocked").unwrap(),
            Request::Create {
                tenant: 7,
                vars: 16,
                chains: 8,
                seed: 7 ^ 0x9E37_79B9_7F4A_7C15,
                k: 2,
                sweep: SweepPolicy::Blocked(BlockPolicy::default()),
            }
        );
        // a cap below 2 cannot block anything — rejected at parse time
        let d = parse_err("create 7 16 blocked:1");
        assert!(d.expected.contains("sweep policy"), "{d}");
        let d = parse_err("create 7 16 blocked:8:0");
        assert!(d.expected.contains("sweep policy"), "{d}");
        // nothing may follow the policy
        let d = parse_err("create 7 16 exact 4");
        assert_eq!(d.expected, "end of line");
    }

    #[test]
    fn kstate_create_and_clamp_round_trip() {
        // k= after any prefix of the numeric knobs, before the policy
        assert_eq!(
            parse_request("create 7 9 4 99 k=3").unwrap(),
            Request::Create {
                tenant: 7,
                vars: 9,
                chains: 4,
                seed: 99,
                k: 3,
                sweep: SweepPolicy::Exact,
            }
        );
        assert_eq!(
            parse_request("create 7 9 k=5 exact").unwrap(),
            Request::Create {
                tenant: 7,
                vars: 9,
                chains: 8,
                seed: 7 ^ 0x9E37_79B9_7F4A_7C15,
                k: 5,
                sweep: SweepPolicy::Exact,
            }
        );
        assert_eq!(
            parse_request("clamp 3 4 2").unwrap(),
            Request::Clamp {
                tenant: 3,
                v: 4,
                state: 2
            }
        );
        assert_eq!(
            parse_request("unclamp 3 4").unwrap(),
            Request::Unclamp { tenant: 3, v: 4 }
        );
    }

    #[test]
    fn malformed_kstate_frames_are_spanned_and_labeled() {
        // out-of-range cardinality points at the k= token
        let d = parse_err("create 1 9 k=9");
        assert_eq!(d.span, Span::new(11, 14));
        assert!(d.expected.contains("k=2..=8"), "{d}");
        assert_eq!(d.found, "\"k=9\"");
        let d = parse_err("create 1 9 k=1");
        assert!(d.expected.contains("k=2..=8"), "{d}");
        let d = parse_err("create 1 9 k=three");
        assert!(d.expected.contains("k=2..=8"), "{d}");
        // k= must precede the policy token
        let d = parse_err("create 1 9 exact k=3");
        assert_eq!(d.expected, "end of line");
        assert_eq!(d.found, "\"k=3\"");
        // clamp arity and range failures
        let d = parse_err("clamp 3 4");
        assert_eq!(d.span, Span::point(9));
        assert!(d.expected.contains("evidence state"), "{d}");
        assert_eq!(d.found, "end of line");
        let d = parse_err("clamp 3 4 8");
        assert_eq!(d.span, Span::new(10, 11));
        assert!(d.expected.contains("0..=7"), "{d}");
        let d = parse_err("unclamp 3");
        assert!(d.expected.contains("variable index"), "{d}");
        let d = parse_err("unclamp 3 4 5");
        assert_eq!(d.expected, "end of line");
    }

    #[test]
    fn crlf_and_extra_whitespace_are_tolerated() {
        assert_eq!(
            parse_request("  sweep \t 3   9\r").unwrap(),
            Request::Sweep { tenant: 3, n: 9 }
        );
    }

    #[test]
    fn unknown_verb_is_spanned_and_labeled() {
        let d = parse_err("zap 1 2");
        assert_eq!(d.span, Span::new(0, 3));
        assert!(d.expected.contains("create|apply|sweep"), "{d}");
        assert_eq!(d.found, "\"zap\"");
    }

    #[test]
    fn bad_tenant_id_points_at_the_token() {
        let d = parse_err("sweep nine 10");
        assert_eq!(d.span, Span::new(6, 10));
        assert!(d.expected.contains("tenant id"), "{d}");
        assert_eq!(d.found, "\"nine\"");
        // negative ids are not u64
        let d = parse_err("marginals -3");
        assert!(d.expected.contains("tenant id"), "{d}");
    }

    #[test]
    fn missing_argument_points_past_the_end() {
        let d = parse_err("sweep 3");
        assert_eq!(d.span, Span::point(7));
        assert!(d.expected.contains("sweep count"), "{d}");
        assert_eq!(d.found, "end of line");
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        let d = parse_err("marginals 3 please");
        assert_eq!(d.expected, "end of line");
        assert_eq!(d.found, "\"please\"");
        assert_eq!(d.span, Span::new(12, 18));
    }

    #[test]
    fn apply_requires_ops_and_validates_them() {
        let d = parse_err("apply 3");
        assert!(d.expected.contains("add|del"), "{d}");
        assert_eq!(d.found, "end of line");
        let d = parse_err("apply 3 mul 0 1 0.5");
        assert!(d.expected.contains("add|del"), "{d}");
        assert_eq!(d.found, "\"mul\"");
        let d = parse_err("apply 3 add 0 1 not-a-float");
        assert!(d.expected.contains("beta"), "{d}");
        // non-finite couplings are rejected at parse time
        let d = parse_err("apply 3 add 0 1 inf");
        assert!(d.expected.contains("finite"), "{d}");
    }

    #[test]
    fn out_of_range_counts_are_parse_errors() {
        let d = parse_err("sweep 3 0");
        assert!(d.expected.contains("1..=1000000"), "{d}");
        let d = parse_err(&format!("create 1 {}", MAX_VARS + 1));
        assert!(d.expected.contains("variable count"), "{d}");
        let d = parse_err("create 1 4 0");
        assert!(d.expected.contains("chain count"), "{d}");
    }

    #[test]
    fn empty_line_is_a_point_diagnostic() {
        let d = parse_err("");
        assert_eq!(d.span, Span::point(0));
        assert_eq!(d.found, "end of line");
    }

    #[test]
    fn renders_are_stable_and_classified() {
        assert_eq!(Response::Ok.render(), "ok");
        assert_eq!(Response::Dropped(true).render(), "ok dropped=true");
        let m = Response::Marginals(vec![0.5, 0.25]).render();
        assert_eq!(m, "ok marginals n=2 0.500000 0.250000");
        assert_eq!(classify_reply(&m), ReplyKind::Ok);
        let e = Response::ParseError(parse_err("zap")).render();
        assert!(e.starts_with("err parse span=0:3 expected="), "{e}");
        assert_eq!(classify_reply(&e), ReplyKind::ParseError);
        let o = Response::Overloaded {
            scope: "tenant 3".into(),
            depth: 9,
            limit: 8,
        }
        .render();
        assert_eq!(o, "err overloaded tenant 3 depth=9 limit=8");
        assert_eq!(classify_reply(&o), ReplyKind::Overloaded);
        assert_eq!(
            classify_reply(&Response::Exec("tenant 9 not hosted".into()).render()),
            ReplyKind::ExecError
        );
        assert_eq!(
            classify_reply(
                &Response::Event {
                    index: 0,
                    sweeps_done: 10,
                    mean: 0.5
                }
                .render()
            ),
            ReplyKind::Event
        );
        assert_eq!(classify_reply("gibberish"), ReplyKind::Unknown);
    }

    #[test]
    fn frame_guards_are_spanned() {
        let d = oversized(20_000, 16_384);
        assert_eq!(d.span, Span::new(0, 20_000));
        assert!(d.expected.contains("16384 bytes"), "{d}");
        let d = truncated(5);
        assert!(d.expected.contains("newline-terminated"), "{d}");
        assert!(d.found.contains("end of stream"), "{d}");
    }
}
