//! Lightweight metrics registry: counters, gauges, latency histograms.
//!
//! The server increments these on every request; `snapshot()` renders the
//! registry as JSON for the CLI's `stats` subcommand and the benches.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::Welford;

/// Thread-safe metrics registry.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    gauges: Mutex<BTreeMap<String, f64>>,
    timers: Mutex<BTreeMap<String, Welford>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, delta: u64) {
        *self
            .counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert(0) += delta;
    }

    pub fn set_gauge(&self, name: &str, value: f64) {
        self.gauges
            .lock()
            .unwrap()
            .insert(name.to_string(), value);
    }

    /// Record a duration (seconds) under `name`.
    pub fn observe(&self, name: &str, seconds: f64) {
        self.timers
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .push(seconds);
    }

    /// Time a closure and record it.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.observe(name, t0.elapsed().as_secs_f64());
        out
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    /// Render all metrics as a JSON object.
    pub fn snapshot(&self) -> Json {
        let counters = self.counters.lock().unwrap();
        let gauges = self.gauges.lock().unwrap();
        let timers = self.timers.lock().unwrap();
        let mut obj: Vec<(String, Json)> = Vec::new();
        for (k, v) in counters.iter() {
            obj.push((format!("counter.{k}"), Json::from(*v as f64)));
        }
        for (k, v) in gauges.iter() {
            obj.push((format!("gauge.{k}"), Json::from(*v)));
        }
        for (k, w) in timers.iter() {
            obj.push((
                format!("timer.{k}"),
                Json::obj(vec![
                    ("count", Json::from(w.count() as f64)),
                    ("mean_s", Json::from(w.mean())),
                    ("std_s", Json::from(w.std_dev())),
                ]),
            ));
        }
        Json::Obj(obj.into_iter().collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.inc("ops");
        m.add("ops", 4);
        assert_eq!(m.counter("ops"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn timers_record() {
        let m = Metrics::new();
        let out = m.time("work", || {
            std::thread::sleep(std::time::Duration::from_millis(2));
            42
        });
        assert_eq!(out, 42);
        let snap = m.snapshot();
        let timer = snap.get("timer.work").unwrap();
        assert_eq!(timer.get("count").and_then(Json::as_usize), Some(1));
        assert!(timer.get("mean_s").and_then(Json::as_f64).unwrap() > 0.001);
    }

    #[test]
    fn snapshot_roundtrips_as_json() {
        let m = Metrics::new();
        m.inc("a");
        m.set_gauge("g", 1.5);
        let text = m.snapshot().dump();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(
            parsed.get("counter.a").and_then(Json::as_usize),
            Some(1)
        );
        assert_eq!(parsed.get("gauge.g").and_then(Json::as_f64), Some(1.5));
    }

    #[test]
    fn concurrent_increments() {
        let m = std::sync::Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let m = std::sync::Arc::clone(&m);
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    m.inc("hits");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("hits"), 8000);
    }
}
