//! Lightweight metrics registry: counters, gauges, latency histograms.
//!
//! The shards increment these on every request; `snapshot()` renders the
//! registry as JSON for the CLI's `stats` subcommand and the benches.
//!
//! The multi-tenant coordinator needs *label-scoped* views: per-shard and
//! per-tenant counters that land in one shared registry (so one
//! `snapshot()` captures the whole server) without every call site
//! formatting key prefixes by hand. [`Metrics::scoped`] returns a cheap
//! clonable [`MetricsView`] that prepends `"<scope>."` to every name it
//! touches; views of distinct scopes never collide, views of the same
//! scope share keys — exactly the Prometheus label semantics, flattened
//! into the dotted key space our JSON snapshot already uses. To make
//! views own their registry handle, [`Metrics`] itself is a cheap clone
//! (an `Arc` around the maps): clones observe the same counters.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::Welford;

/// Smallest histogram bucket lower bound, in seconds (1 µs).
const HIST_BASE: f64 = 1e-6;
/// Geometric growth factor between bucket bounds (~25 % relative error).
const HIST_GROWTH: f64 = 1.25;
/// Bucket count: covers 1 µs … ~4×10⁵ s.
const HIST_BUCKETS: usize = 120;

/// Log-bucketed histogram for latency quantiles (p50/p99/p999).
///
/// The Welford timers give mean/σ but no tails; the serving edge needs
/// tail quantiles under overload. Buckets are geometric
/// ([`HIST_BASE`] · [`HIST_GROWTH`]ⁱ), so any quantile is answered in
/// O(buckets) with a fixed ~25 % relative resolution and O(1) memory —
/// no per-sample storage on the request path.
#[derive(Clone)]
struct Hist {
    counts: Vec<u64>,
    count: u64,
    max: f64,
}

impl Default for Hist {
    fn default() -> Self {
        Self {
            counts: vec![0; HIST_BUCKETS],
            count: 0,
            max: 0.0,
        }
    }
}

impl Hist {
    fn bucket_of(seconds: f64) -> usize {
        if seconds <= HIST_BASE {
            return 0;
        }
        let i = (seconds / HIST_BASE).ln() / HIST_GROWTH.ln();
        (i as usize).min(HIST_BUCKETS - 1)
    }

    fn push(&mut self, seconds: f64) {
        let v = if seconds.is_finite() { seconds.max(0.0) } else { 0.0 };
        self.counts[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.max = self.max.max(v);
    }

    /// Nearest-rank quantile (`q` in `[0, 1]`), reported as the geometric
    /// midpoint of the covering bucket, clamped to the observed max.
    ///
    /// An empty histogram answers `0.0` — never a bucket edge, which would
    /// read as a phantom ~1 µs latency on dashboards before any traffic.
    fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((self.count as f64 * q.clamp(0.0, 1.0)).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let lo = HIST_BASE * HIST_GROWTH.powi(i as i32);
                let mid = lo * HIST_GROWTH.sqrt();
                return mid.min(self.max);
            }
        }
        self.max
    }
}

#[derive(Default)]
struct Registry {
    counters: Mutex<BTreeMap<String, u64>>,
    gauges: Mutex<BTreeMap<String, f64>>,
    timers: Mutex<BTreeMap<String, Welford>>,
    hists: Mutex<BTreeMap<String, Hist>>,
}

/// Thread-safe metrics registry. Cloning is cheap and aliases the same
/// underlying maps (handle semantics).
#[derive(Default, Clone)]
pub struct Metrics {
    inner: Arc<Registry>,
}

impl Metrics {
    /// Fresh empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// A label-scoped view of this registry: every metric name is
    /// prefixed with `"<scope>."`. Views are cheap to clone and hand to
    /// shard/tenant owners; all of them write into `self`, so a single
    /// [`Metrics::snapshot`] covers the whole coordinator.
    pub fn scoped(&self, scope: impl Into<String>) -> MetricsView {
        let mut prefix = scope.into();
        prefix.push('.');
        MetricsView {
            registry: self.clone(),
            prefix,
        }
    }

    /// Increment a counter by 1.
    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    /// Increment a counter by `delta`.
    pub fn add(&self, name: &str, delta: u64) {
        *self
            .inner
            .counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert(0) += delta;
    }

    /// Set a gauge to an absolute value.
    pub fn set_gauge(&self, name: &str, value: f64) {
        self.inner
            .gauges
            .lock()
            .unwrap()
            .insert(name.to_string(), value);
    }

    /// Record a duration (seconds) under `name`.
    pub fn observe(&self, name: &str, seconds: f64) {
        self.inner
            .timers
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .push(seconds);
    }

    /// Time a closure and record it.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.observe(name, t0.elapsed().as_secs_f64());
        out
    }

    /// Record one observation (seconds) in the log-bucketed quantile
    /// histogram under `name` — the serving edge's latency instrument
    /// (tail quantiles, unlike the mean/σ-only [`Metrics::observe`]).
    pub fn observe_hist(&self, name: &str, seconds: f64) {
        self.inner
            .hists
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .push(seconds);
    }

    /// Quantile (`q` in `[0, 1]`) of the histogram under `name`, in
    /// seconds; `0.0` if nothing was observed (empty or missing
    /// histogram — not a bucket edge). Resolution is the bucket's ~25 %
    /// relative width.
    pub fn hist_quantile(&self, name: &str, q: f64) -> f64 {
        self.inner
            .hists
            .lock()
            .unwrap()
            .get(name)
            .map_or(0.0, |h| h.quantile(q))
    }

    /// Observation count of the histogram under `name`.
    pub fn hist_count(&self, name: &str) -> u64 {
        self.inner
            .hists
            .lock()
            .unwrap()
            .get(name)
            .map_or(0, |h| h.count)
    }

    /// A counter's current value (0 if never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.inner
            .counters
            .lock()
            .unwrap()
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    /// Drop every metric belonging to `scope` (all keys prefixed
    /// `"<scope>."`). The multi-tenant coordinator calls this when a
    /// tenant is dropped: under continuous arrival/departure traffic
    /// tenant ids are never reused, so without reclamation the registry
    /// would grow one key set per tenant ever created.
    pub fn remove_scope(&self, scope: &str) {
        let prefix = format!("{scope}.");
        self.inner
            .counters
            .lock()
            .unwrap()
            .retain(|k, _| !k.starts_with(&prefix));
        self.inner
            .gauges
            .lock()
            .unwrap()
            .retain(|k, _| !k.starts_with(&prefix));
        self.inner
            .timers
            .lock()
            .unwrap()
            .retain(|k, _| !k.starts_with(&prefix));
        self.inner
            .hists
            .lock()
            .unwrap()
            .retain(|k, _| !k.starts_with(&prefix));
    }

    /// Render all metrics as a JSON object.
    pub fn snapshot(&self) -> Json {
        let counters = self.inner.counters.lock().unwrap();
        let gauges = self.inner.gauges.lock().unwrap();
        let timers = self.inner.timers.lock().unwrap();
        let hists = self.inner.hists.lock().unwrap();
        let mut obj: Vec<(String, Json)> = Vec::new();
        for (k, v) in counters.iter() {
            obj.push((format!("counter.{k}"), Json::from(*v as f64)));
        }
        for (k, v) in gauges.iter() {
            obj.push((format!("gauge.{k}"), Json::from(*v)));
        }
        for (k, w) in timers.iter() {
            obj.push((
                format!("timer.{k}"),
                Json::obj(vec![
                    ("count", Json::from(w.count() as f64)),
                    ("mean_s", Json::from(w.mean())),
                    ("std_s", Json::from(w.std_dev())),
                ]),
            ));
        }
        for (k, h) in hists.iter() {
            obj.push((
                format!("hist.{k}"),
                Json::obj(vec![
                    ("count", Json::from(h.count as f64)),
                    ("p50_s", Json::from(h.quantile(0.50))),
                    ("p99_s", Json::from(h.quantile(0.99))),
                    ("p999_s", Json::from(h.quantile(0.999))),
                    ("max_s", Json::from(h.max)),
                ]),
            ));
        }
        Json::Obj(obj.into_iter().collect())
    }
}

/// A label-scoped view over a shared [`Metrics`] registry — see
/// [`Metrics::scoped`]. Mirrors the registry's recording API with the
/// scope prefix applied; reads (`counter`) resolve against the shared
/// registry so tests and dashboards can go through either handle.
#[derive(Clone)]
pub struct MetricsView {
    registry: Metrics,
    /// `"<scope>."` — precomputed so the hot path does one concat.
    prefix: String,
}

impl MetricsView {
    /// The scope label (without the trailing dot).
    pub fn scope(&self) -> &str {
        &self.prefix[..self.prefix.len() - 1]
    }

    /// The shared registry this view writes into.
    pub fn registry(&self) -> &Metrics {
        &self.registry
    }

    fn key(&self, name: &str) -> String {
        let mut k = String::with_capacity(self.prefix.len() + name.len());
        k.push_str(&self.prefix);
        k.push_str(name);
        k
    }

    /// Increment a scoped counter by 1.
    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    /// Increment a scoped counter by `delta`.
    pub fn add(&self, name: &str, delta: u64) {
        self.registry.add(&self.key(name), delta);
    }

    /// Set a scoped gauge to an absolute value.
    pub fn set_gauge(&self, name: &str, value: f64) {
        self.registry.set_gauge(&self.key(name), value);
    }

    /// Record one scoped duration observation.
    pub fn observe(&self, name: &str, seconds: f64) {
        self.registry.observe(&self.key(name), seconds);
    }

    /// Time `f` and record it under the scoped name.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        self.registry.time(&self.key(name), f)
    }

    /// Record one scoped histogram observation (seconds).
    pub fn observe_hist(&self, name: &str, seconds: f64) {
        self.registry.observe_hist(&self.key(name), seconds);
    }

    /// Quantile of the scoped histogram — see [`Metrics::hist_quantile`].
    pub fn hist_quantile(&self, name: &str, q: f64) -> f64 {
        self.registry.hist_quantile(&self.key(name), q)
    }

    /// Observation count of the scoped histogram.
    pub fn hist_count(&self, name: &str) -> u64 {
        self.registry.hist_count(&self.key(name))
    }

    /// A scoped counter's current value.
    pub fn counter(&self, name: &str) -> u64 {
        self.registry.counter(&self.key(name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.inc("ops");
        m.add("ops", 4);
        assert_eq!(m.counter("ops"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn timers_record() {
        let m = Metrics::new();
        let out = m.time("work", || {
            std::thread::sleep(std::time::Duration::from_millis(2));
            42
        });
        assert_eq!(out, 42);
        let snap = m.snapshot();
        let timer = snap.get("timer.work").unwrap();
        assert_eq!(timer.get("count").and_then(Json::as_usize), Some(1));
        assert!(timer.get("mean_s").and_then(Json::as_f64).unwrap() > 0.001);
    }

    #[test]
    fn snapshot_roundtrips_as_json() {
        let m = Metrics::new();
        m.inc("a");
        m.set_gauge("g", 1.5);
        let text = m.snapshot().dump();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(
            parsed.get("counter.a").and_then(Json::as_usize),
            Some(1)
        );
        assert_eq!(parsed.get("gauge.g").and_then(Json::as_f64), Some(1.5));
    }

    #[test]
    fn clones_alias_one_registry() {
        let m = Metrics::new();
        let m2 = m.clone();
        m.inc("shared");
        m2.add("shared", 2);
        assert_eq!(m.counter("shared"), 3);
        assert_eq!(m2.counter("shared"), 3);
    }

    #[test]
    fn concurrent_increments() {
        let m = std::sync::Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let m = std::sync::Arc::clone(&m);
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    m.inc("hits");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("hits"), 8000);
    }

    #[test]
    fn scoped_views_prefix_and_share_the_registry() {
        let m = Metrics::new();
        let shard = m.scoped("shard0");
        let tenant = m.scoped("tenant7");
        assert_eq!(shard.scope(), "shard0");
        shard.add("requests", 3);
        tenant.inc("ops");
        tenant.set_gauge("cost", 45.0);
        // both land in the one registry, under disjoint dotted keys
        assert_eq!(m.counter("shard0.requests"), 3);
        assert_eq!(m.counter("tenant7.ops"), 1);
        assert_eq!(tenant.counter("ops"), 1);
        assert_eq!(shard.counter("ops"), 0, "scopes must not alias");
        assert_eq!(tenant.registry().counter("shard0.requests"), 3);
        let snap = m.snapshot();
        assert_eq!(
            snap.get("gauge.tenant7.cost").and_then(Json::as_f64),
            Some(45.0)
        );
    }

    #[test]
    fn hist_quantiles_track_the_tail() {
        let m = Metrics::new();
        // 99 fast requests at ~1 ms, one slow outlier at ~1 s
        for _ in 0..99 {
            m.observe_hist("lat", 1e-3);
        }
        m.observe_hist("lat", 1.0);
        assert_eq!(m.hist_count("lat"), 100);
        let p50 = m.hist_quantile("lat", 0.50);
        let p99 = m.hist_quantile("lat", 0.99);
        let p999 = m.hist_quantile("lat", 0.999);
        // log buckets: ~25 % relative resolution
        assert!((0.5e-3..2e-3).contains(&p50), "p50={p50}");
        assert!(p99 < 0.1, "p99 must still be in the fast mass: {p99}");
        assert!((0.5..=1.0).contains(&p999), "p999 must see the outlier: {p999}");
        assert_eq!(m.hist_quantile("missing", 0.5), 0.0);
        // degenerate inputs must not poison the buckets
        m.observe_hist("weird", f64::NAN);
        m.observe_hist("weird", -1.0);
        m.observe_hist("weird", 0.0);
        assert_eq!(m.hist_count("weird"), 3);
        assert!(m.hist_quantile("weird", 0.5) >= 0.0);
        // snapshot carries the quantiles
        let snap = m.snapshot();
        let lat = snap.get("hist.lat").unwrap();
        assert_eq!(lat.get("count").and_then(Json::as_usize), Some(100));
        assert!(lat.get("p999_s").and_then(Json::as_f64).unwrap() > 0.4);
    }

    #[test]
    fn scoped_hists_share_the_registry() {
        let m = Metrics::new();
        let edge = m.scoped("net");
        edge.observe_hist("request_s", 0.002);
        assert_eq!(edge.hist_count("request_s"), 1);
        assert_eq!(m.hist_count("net.request_s"), 1);
        assert!(edge.hist_quantile("request_s", 0.5) > 0.0);
    }

    #[test]
    fn empty_histogram_quantile_is_exactly_zero() {
        // satellite: before any observation every quantile must be 0.0 —
        // not the first bucket's geometric midpoint (~1.1 µs), which used
        // to leak out as a phantom latency floor
        let h = Hist::default();
        for &q in &[0.0, 0.5, 0.99, 0.999, 1.0] {
            assert_eq!(h.quantile(q), 0.0, "q={q}");
        }
        let first_bucket_mid = HIST_BASE * HIST_GROWTH.sqrt();
        assert_ne!(h.quantile(0.5), first_bucket_mid);
        // registry-level: missing key and scoped view agree
        let m = Metrics::new();
        assert_eq!(m.hist_quantile("never_observed", 0.999), 0.0);
        assert_eq!(m.scoped("t9").hist_quantile("never_observed", 0.5), 0.0);
        // snapshot of a pushed-then-empty registry still renders zeros:
        // an entry exists only after observe_hist, so seed one elsewhere
        m.observe_hist("other", 0.25);
        assert_eq!(m.hist_quantile("never_observed", 0.5), 0.0);
    }

    #[test]
    fn remove_scope_reclaims_only_that_scope() {
        let m = Metrics::new();
        m.scoped("tenant1").inc("ops");
        m.scoped("tenant1").set_gauge("cost", 1.0);
        m.scoped("tenant1").observe("apply", 0.1);
        m.scoped("tenant1").observe_hist("req", 0.1);
        m.scoped("tenant12").inc("ops");
        m.remove_scope("tenant1");
        assert_eq!(m.hist_count("tenant1.req"), 0, "hist scope reclaimed");
        assert_eq!(m.counter("tenant1.ops"), 0, "scope reclaimed");
        assert_eq!(m.counter("tenant12.ops"), 1, "prefix must not over-match");
        let snap = m.snapshot().dump();
        assert!(!snap.contains("tenant1."), "stale keys leaked: {snap}");
        assert!(snap.contains("tenant12."));
    }

    #[test]
    fn concurrent_tenant_scopes_land_in_distinct_keys() {
        // satellite: per-tenant increments from concurrent writers must
        // stay isolated per scope and survive a JSON round-trip at >= 64
        // tenants
        const TENANTS: usize = 64;
        let m = Metrics::new();
        let mut handles = Vec::new();
        for t in 0..TENANTS {
            let view = m.scoped(format!("tenant{t}"));
            handles.push(std::thread::spawn(move || {
                for _ in 0..100 + t {
                    view.inc("ops");
                }
                view.observe("apply", 0.001 * (t + 1) as f64);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        for t in 0..TENANTS {
            assert_eq!(
                m.counter(&format!("tenant{t}.ops")),
                (100 + t) as u64,
                "tenant {t} counter was crossed by another scope"
            );
        }
        // snapshot() must round-trip through util::json with all 64
        // tenants' counters and timers intact
        let text = m.snapshot().dump();
        let parsed = Json::parse(&text).unwrap();
        for t in 0..TENANTS {
            assert_eq!(
                parsed
                    .get(&format!("counter.tenant{t}.ops"))
                    .and_then(Json::as_usize),
                Some(100 + t)
            );
            let timer_key = format!("timer.tenant{t}.apply");
            assert_eq!(
                parsed
                    .at(&[timer_key.as_str(), "count"])
                    .and_then(Json::as_usize),
                Some(1)
            );
        }
    }
}
