//! One tenant of a shard: a dynamic MRF plus its lane-batched ensemble.
//!
//! A tenant is the unit the multi-tenant coordinator hosts many of: its
//! own [`FactorGraph`], its own [`PdEnsemble`] (per-tenant seed, so its
//! trajectory is a pure function of that seed — independent of shard
//! placement, shard count, pool size, and of every other tenant), the
//! live-factor list its churn ops index into, and the serving counters
//! ([`TenantStats`]) the dispatch policy reads. All request handling is
//! synchronous single-owner code; the shard worker thread is the only
//! caller.

use std::sync::Arc;

use crate::diagnostics::MixingResult;
use crate::engine::{EngineError, SweepPolicy};
use crate::graph::{FactorGraph, FactorId, PairFactor};
use crate::runtime::Manifest;
use crate::util::ThreadPool;
use crate::workloads::ChurnOp;

use super::dispatch::{DispatchDecision, DispatchPolicy};
use super::ensemble::PdEnsemble;
use super::metrics::MetricsView;

/// Tenant identifier. Routing to shards is a pure hash of this id
/// ([`super::route`]), so placement is stable across restarts and shard
/// counts.
pub type TenantId = u64;

/// Per-tenant construction parameters.
#[derive(Clone, Debug)]
pub struct TenantConfig {
    /// Parallel chains (lanes) of the tenant's ensemble.
    pub chains: usize,
    /// Per-tenant RNG root; trajectories are `(sweep, site)`-keyed under
    /// it, hence identical for every shard count and pool size.
    pub seed: u64,
    /// Variables monitored for PSRF (empty = magnetization only).
    pub monitor_vars: Vec<usize>,
    /// Site-visit policy of the tenant's engine (exact sweeps, or
    /// minibatched hub updates for heavy-tailed models). Per-tenant: one
    /// tenant's policy never affects a neighbor's trajectory.
    pub sweep: SweepPolicy,
}

impl Default for TenantConfig {
    fn default() -> Self {
        Self {
            chains: 10,
            seed: 0xC0FFEE,
            monitor_vars: Vec::new(),
            sweep: SweepPolicy::default(),
        }
    }
}

/// Snapshot of one tenant's serving state.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantStats {
    /// Variables in the tenant's model.
    pub num_vars: usize,
    /// States per variable (2 = binary Ising, K > 2 = Potts).
    pub k: usize,
    /// Sites currently clamped to evidence.
    pub clamped: usize,
    /// Live factors in the tenant's model.
    pub num_factors: usize,
    /// Total sweeps (foreground + background).
    pub sweeps_done: usize,
    /// Background sweeps granted by the fair-share scheduler.
    pub background_sweeps: u64,
    /// Churn operations applied so far.
    pub ops_applied: u64,
    /// The tenant graph's monotone topology version.
    pub graph_version: u64,
    /// Sweeps since the last topology mutation — the dispatch policy's
    /// stability input.
    pub stable_for: usize,
    /// Current per-sweep cost in site-visits (the scheduler's unit).
    pub cost: u64,
    /// Whether the tenant is excluded from background sweeping.
    pub suspended: bool,
    /// The tenant's sweep policy (how `cost` was priced).
    pub policy: SweepPolicy,
    /// Tree-blocks in the engine's current blocked-sweep plan (0 unless
    /// the policy is `blocked` and a plan has formed).
    pub blocks: usize,
    /// Variables covered by those blocks.
    pub blocked_vars: usize,
    /// Factor slots marginalized into block trees (what the per-sweep
    /// cost surcharge is billed on).
    pub tree_slots: usize,
    /// What the dispatch policy would run the next sweep batch on, given
    /// the shard's artifact manifest and this tenant's stability.
    pub dispatch: DispatchDecision,
}

/// A hosted tenant (see module docs). Owned and driven by one shard.
pub struct Tenant {
    graph: FactorGraph,
    ensemble: PdEnsemble,
    /// Live churned factors, indexed by `ChurnOp::RemoveLive`.
    live: Vec<FactorId>,
    metrics: MetricsView,
    ops_applied: u64,
    background_sweeps: u64,
    /// Sweeps since the last topology mutation.
    stable_for: usize,
    suspended: bool,
}

impl Tenant {
    /// Build a tenant over `graph`; `pool` is the shard's *lent* shared
    /// worker pool (one pool serves every shard — no per-tenant threads).
    pub fn new(
        graph: FactorGraph,
        config: &TenantConfig,
        pool: Option<Arc<ThreadPool>>,
        metrics: MetricsView,
    ) -> Self {
        Self::try_new(graph, config, pool, metrics)
            .expect("degenerate sweep-policy knobs")
    }

    /// Fallible [`Tenant::new`]: every sweep policy hosts every
    /// cardinality `2 ≤ k ≤ 8` and clamping, so what remains fallible
    /// is degenerate policy knobs ([`EngineError::InvalidPolicy`]) — an
    /// error the serving edge reports to the client, never a panic on
    /// the shard thread other tenants share.
    pub fn try_new(
        graph: FactorGraph,
        config: &TenantConfig,
        pool: Option<Arc<ThreadPool>>,
        metrics: MetricsView,
    ) -> Result<Self, EngineError> {
        let mut ensemble =
            PdEnsemble::try_with_policy(&graph, config.chains, config.seed, config.sweep)?;
        if let Some(pool) = pool {
            ensemble = ensemble.with_pool(pool);
        }
        if !config.monitor_vars.is_empty() {
            ensemble.monitor_vars(config.monitor_vars.clone());
        }
        ensemble.init_overdispersed();
        let live = graph.factors().map(|(id, _)| id).collect();
        Ok(Self {
            graph,
            ensemble,
            live,
            metrics,
            ops_applied: 0,
            background_sweeps: 0,
            stable_for: 0,
            suspended: false,
        })
    }

    /// Clamp site `v` to evidence `state` across all chains (see
    /// [`PdEnsemble::clamp`]). The target distribution changed, so
    /// statistics and the dispatch stability clock both reset — evidence
    /// is a semantic mutation, exactly like churn.
    pub fn clamp(&mut self, v: usize, state: u8) -> Result<(), EngineError> {
        self.ensemble.clamp(v, state)?;
        self.stable_for = 0;
        self.metrics.add("clamps", 1);
        Ok(())
    }

    /// Release a clamped site (see [`PdEnsemble::unclamp`]).
    pub fn unclamp(&mut self, v: usize) -> Result<(), EngineError> {
        self.ensemble.unclamp(v)?;
        self.stable_for = 0;
        self.metrics.add("unclamps", 1);
        Ok(())
    }

    /// Apply topology mutations; if anything landed, resets statistics
    /// (the target changed) and the dispatch stability clock. Returns
    /// how many ops were actually applied: malformed ops (an
    /// out-of-range variable or `RemoveLive` index) are *skipped*,
    /// counted under the tenant's `invalid_ops` metric — one tenant's
    /// bad input must degrade that tenant's request, never panic the
    /// shard thread its neighbors share.
    pub fn apply(&mut self, ops: &[ChurnOp]) -> usize {
        let metrics = self.metrics.clone();
        let applied = metrics.time("apply", || {
            ops.iter().filter(|&op| self.apply_op(op)).count()
        });
        self.ops_applied += applied as u64;
        self.metrics.add("ops", applied as u64);
        let invalid = ops.len() - applied;
        if invalid > 0 {
            self.metrics.add("invalid_ops", invalid as u64);
        }
        if applied > 0 {
            self.stable_for = 0;
            // the target distribution changed; stale stats are biased
            self.ensemble.reset_stats();
        }
        applied
    }

    /// Apply one op; returns whether it was valid (see [`Tenant::apply`]).
    fn apply_op(&mut self, op: &ChurnOp) -> bool {
        match *op {
            ChurnOp::Add { v1, v2, beta } => {
                let n = self.graph.num_vars();
                if v1 >= n || v2 >= n || v1 == v2 {
                    return false;
                }
                let f = PairFactor::ising(v1, v2, beta);
                let id = self.graph.add_factor(f);
                self.ensemble
                    .add_factor(id, self.graph.factor(id).expect("just added"));
                self.live.push(id);
                true
            }
            ChurnOp::RemoveLive { index } => {
                if index >= self.live.len() {
                    return false;
                }
                let id = self.live.swap_remove(index);
                self.graph.remove_factor(id).expect("live desync");
                self.ensemble.remove_factor(id);
                true
            }
        }
    }

    /// Foreground sweeps (an explicit `Sweep` request).
    pub fn sweep(&mut self, n: usize) {
        let metrics = self.metrics.clone();
        metrics.time("sweep", || self.ensemble.run(n));
        self.stable_for += n;
    }

    /// Background sweeps granted by the shard's fair-share scheduler.
    pub fn background_sweep(&mut self, n: usize) {
        self.ensemble.run(n);
        self.background_sweeps += n as u64;
        self.metrics.add("background_sweeps", n as u64);
        self.stable_for += n;
    }

    /// Clear the marginal accumulation window.
    pub fn reset_stats(&mut self) {
        self.ensemble.reset_stats();
    }

    /// Exclude from background scheduling and release the PSRF trace
    /// buffers; sampler state and marginal sums are kept, so resuming is
    /// free and marginal queries keep answering the pre-suspension
    /// estimate.
    pub fn suspend(&mut self) {
        self.suspended = true;
        self.ensemble.park();
    }

    /// Re-enroll a suspended tenant for background sweeping.
    pub fn resume(&mut self) {
        self.suspended = false;
    }

    /// Whether the tenant is currently suspended.
    pub fn is_suspended(&self) -> bool {
        self.suspended
    }

    /// Current per-sweep cost in site-visits — what one scheduler grant
    /// debits. Tracks churn.
    pub fn cost(&self) -> u64 {
        self.ensemble.cost()
    }

    /// Current per-variable marginal estimates.
    pub fn marginals(&self) -> Vec<f64> {
        self.ensemble.marginals()
    }

    /// PSRF mixing diagnosis. `stride` is clamped to ≥ 1: a zero stride
    /// is a caller error that must degrade, not divide-by-zero the shard
    /// thread shared with other tenants.
    pub fn mixing(&self, threshold: f64, stride: usize) -> MixingResult {
        self.ensemble.mixing(threshold, stride.max(1))
    }

    /// Serving snapshot, including the dispatch decision the policy makes
    /// for this tenant's current size and stability.
    pub fn stats(&self, policy: &DispatchPolicy, manifest: Option<&Manifest>) -> TenantStats {
        let (blocks, blocked_vars, tree_slots) = self.ensemble.block_summary();
        TenantStats {
            blocks,
            blocked_vars,
            tree_slots,
            num_vars: self.graph.num_vars(),
            k: self.graph.k(),
            clamped: self.ensemble.clamped_count(),
            num_factors: self.graph.num_factors(),
            sweeps_done: self.ensemble.sweeps_done(),
            background_sweeps: self.background_sweeps,
            ops_applied: self.ops_applied,
            graph_version: self.graph.version(),
            stable_for: self.stable_for,
            cost: self.cost(),
            suspended: self.suspended,
            policy: self.ensemble.sweep_policy(),
            dispatch: policy.decide(
                manifest,
                self.graph.num_vars(),
                self.graph.num_factors(),
                self.stable_for,
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Metrics;
    use crate::workloads;

    fn tenant(graph: FactorGraph) -> (Tenant, Metrics) {
        let registry = Metrics::new();
        let view = registry.scoped("tenant0");
        let cfg = TenantConfig {
            chains: 4,
            seed: 7,
            ..TenantConfig::default()
        };
        (Tenant::new(graph, &cfg, None, view), registry)
    }

    #[test]
    fn apply_resets_stability_and_counts_ops_linearly() {
        let (mut t, registry) = tenant(workloads::ising_grid(3, 3, 0.2, 0.0));
        t.sweep(10);
        assert_eq!(t.stats(&DispatchPolicy::default(), None).stable_for, 10);
        t.apply(&[
            ChurnOp::Add { v1: 0, v2: 4, beta: 0.3 },
            ChurnOp::Add { v1: 1, v2: 5, beta: 0.2 },
        ]);
        t.apply(&[ChurnOp::RemoveLive { index: 0 }]);
        let stats = t.stats(&DispatchPolicy::default(), None);
        assert_eq!(stats.stable_for, 0, "churn must reset the stability clock");
        assert_eq!(stats.ops_applied, 3);
        // regression (quadratic ops counter): two batches of 2 + 1 ops
        // must land 3 in the metrics counter, not 2 + (2 + 1) = 5
        assert_eq!(registry.counter("tenant0.ops"), 3);
    }

    #[test]
    fn cost_tracks_churn() {
        let (mut t, _) = tenant(workloads::ising_grid(2, 2, 0.2, 0.0));
        let before = t.cost();
        t.apply(&[ChurnOp::Add { v1: 0, v2: 3, beta: 0.3 }]);
        assert!(t.cost() > before, "{} vs {before}", t.cost());
    }

    #[test]
    fn suspend_keeps_sampler_state_and_marginals() {
        let (mut t, _) = tenant(workloads::ising_grid(2, 2, 0.3, 0.1));
        t.sweep(50);
        let before = t.marginals();
        assert!(before.iter().any(|&p| p > 0.0), "sums accumulated");
        t.suspend();
        assert!(t.is_suspended());
        let stats = t.stats(&DispatchPolicy::default(), None);
        assert!(stats.suspended);
        assert_eq!(stats.sweeps_done, 50, "suspension must not lose sweeps");
        assert_eq!(
            t.marginals(),
            before,
            "suspended tenant must keep answering the last estimate, \
             not degrade to all-zeros"
        );
        t.resume();
        t.sweep(10);
        assert_eq!(t.stats(&DispatchPolicy::default(), None).sweeps_done, 60);
    }

    #[test]
    fn malformed_ops_are_skipped_not_fatal() {
        // one tenant's bad input must not panic the shard thread its
        // neighbors share: invalid ops are skipped and counted
        let (mut t, registry) = tenant(workloads::ising_grid(2, 2, 0.2, 0.0));
        let applied = t.apply(&[
            ChurnOp::Add { v1: 0, v2: 3, beta: 0.2 },
            ChurnOp::RemoveLive { index: 999 },
            ChurnOp::Add { v1: 0, v2: 99, beta: 0.2 },
            ChurnOp::Add { v1: 1, v2: 1, beta: 0.2 },
        ]);
        assert_eq!(applied, 1, "only the well-formed op lands");
        let stats = t.stats(&DispatchPolicy::default(), None);
        assert_eq!(stats.ops_applied, 1);
        assert_eq!(registry.counter("tenant0.ops"), 1);
        assert_eq!(registry.counter("tenant0.invalid_ops"), 3);
    }

    #[test]
    fn minibatch_policy_reaches_stats_and_reprices_cost() {
        use crate::duality::MinibatchPolicy;
        let policy = SweepPolicy::Minibatch(MinibatchPolicy {
            degree_threshold: 4,
            lambda_scale: 0.05,
            lambda_min: 0.5,
            theta_stride: 4,
        });
        let registry = Metrics::new();
        let mk = |sweep: SweepPolicy| {
            let cfg = TenantConfig {
                chains: 4,
                seed: 7,
                sweep,
                ..TenantConfig::default()
            };
            Tenant::new(
                workloads::fully_connected_jittered(12, 0.04, 0.01, 5),
                &cfg,
                None,
                registry.scoped("t"),
            )
        };
        let exact = mk(SweepPolicy::Exact);
        let mb = mk(policy);
        let stats = mb.stats(&DispatchPolicy::default(), None);
        assert_eq!(stats.policy, policy, "policy must surface in stats");
        assert_eq!(
            exact.stats(&DispatchPolicy::default(), None).policy,
            SweepPolicy::Exact
        );
        assert!(
            stats.cost < exact.cost(),
            "DRR must see the cheaper sweeps: {} vs {}",
            stats.cost,
            exact.cost()
        );
    }

    #[test]
    fn blocked_policy_reaches_stats_and_reprices_cost_upward() {
        use crate::duality::BlockPolicy;
        let policy = SweepPolicy::Blocked(BlockPolicy { cap: 4, epoch: 8 });
        let registry = Metrics::new();
        let mk = |sweep: SweepPolicy| {
            let cfg = TenantConfig {
                chains: 64,
                seed: 7,
                sweep,
                ..TenantConfig::default()
            };
            Tenant::new(workloads::ising_grid(3, 3, 0.9, 0.05), &cfg, None, registry.scoped("t"))
        };
        let exact = mk(SweepPolicy::Exact);
        let mut blk = mk(policy);
        let fresh = blk.stats(&DispatchPolicy::default(), None);
        assert_eq!(fresh.policy, policy, "policy must surface in stats");
        assert_eq!(
            (fresh.blocks, fresh.blocked_vars, fresh.tree_slots),
            (0, 0, 0),
            "no plan before any sweeps"
        );
        blk.sweep(64);
        let stats = blk.stats(&DispatchPolicy::default(), None);
        assert!(stats.blocks >= 1, "β=0.9 grid must grow blocks");
        assert!(stats.blocked_vars >= 2 && stats.tree_slots >= 1);
        assert!(
            stats.cost > exact.cost(),
            "DRR must see the joint-draw surcharge: {} vs {}",
            stats.cost,
            exact.cost()
        );
    }

    #[test]
    fn clamping_resets_stability_and_surfaces_in_stats() {
        let (mut t, registry) = tenant(workloads::ising_grid(2, 2, 0.3, 0.0));
        t.sweep(20);
        t.clamp(1, 1).unwrap();
        let stats = t.stats(&DispatchPolicy::default(), None);
        assert_eq!(stats.stable_for, 0, "evidence is a semantic mutation");
        assert_eq!((stats.clamped, stats.k), (1, 2));
        assert!(t.clamp(9, 0).is_err(), "unknown site must be rejected");
        t.sweep(100);
        assert_eq!(t.marginals()[1], 1.0, "clamped site pins its marginal");
        t.unclamp(1).unwrap();
        assert_eq!(t.stats(&DispatchPolicy::default(), None).clamped, 0);
        assert_eq!(registry.counter("tenant0.clamps"), 1);
        assert_eq!(registry.counter("tenant0.unclamps"), 1);
    }

    #[test]
    fn kstate_tenant_builds_under_every_policy_with_clamping() {
        use crate::duality::{BlockPolicy, MinibatchPolicy};
        use crate::graph::PairFactor;
        let mut g = FactorGraph::new_k(4, 3);
        for v in 0..3 {
            g.add_factor(PairFactor::potts(v, v + 1, 0.5));
        }
        let registry = Metrics::new();
        let base = TenantConfig { chains: 4, seed: 7, ..TenantConfig::default() };
        for (i, sweep) in [
            SweepPolicy::Exact,
            SweepPolicy::Minibatch(MinibatchPolicy {
                degree_threshold: 1,
                ..MinibatchPolicy::default()
            }),
            SweepPolicy::Blocked(BlockPolicy { cap: 4, epoch: 8 }),
        ]
        .into_iter()
        .enumerate()
        {
            let cfg = TenantConfig { sweep, ..base.clone() };
            let mut t =
                Tenant::try_new(g.clone(), &cfg, None, registry.scoped(&format!("t{i}")))
                    .unwrap_or_else(|e| panic!("{sweep} × k=3 tenant must build: {e}"));
            let stats = t.stats(&DispatchPolicy::default(), None);
            assert_eq!((stats.k, stats.clamped, stats.policy), (3, 0, sweep));
            t.clamp(0, 2).unwrap();
            t.sweep(50);
            let m = t.marginals();
            assert_eq!(m.len(), 4 * 2, "flattened n·(k−1) marginals");
            assert_eq!(m[1], 1.0, "{sweep}: evidence state 2 at site 0");
            let stats = t.stats(&DispatchPolicy::default(), None);
            assert_eq!(stats.clamped, 1, "{sweep}: clamp must surface in stats");
        }
        // degenerate knobs stay a clean error, never a shard panic
        let cfg = TenantConfig {
            sweep: SweepPolicy::Blocked(BlockPolicy { cap: 1, epoch: 8 }),
            ..base.clone()
        };
        assert!(
            Tenant::try_new(g, &cfg, None, registry.scoped("bad")).is_err(),
            "cap=1 blocking must be a clean error"
        );
    }

    #[test]
    fn background_sweeps_counted_separately() {
        let (mut t, registry) = tenant(workloads::ising_grid(2, 2, 0.2, 0.0));
        t.sweep(5);
        t.background_sweep(12);
        let stats = t.stats(&DispatchPolicy::default(), None);
        assert_eq!(stats.sweeps_done, 17);
        assert_eq!(stats.background_sweeps, 12);
        assert_eq!(registry.counter("tenant0.background_sweeps"), 12);
    }
}
