//! Layer 3: the coordinator — the deployment story the paper motivates.
//!
//! A long-lived service holds a *dynamic* MRF: clients stream factor
//! add/remove operations while simultaneously asking for posterior
//! summaries. Because the primal–dual sampler needs no graph coloring,
//! every mutation is O(degree) ([`crate::duality::DualModel`] update) and
//! sampling never pauses — the contrast measured in `benches/dynamic.rs`
//! against a chromatic baseline that must repair its coloring.
//!
//! * [`ensemble`] — [`PdEnsemble`]: N parallel chains over one shared dual
//!   model, with magnetization + per-variable traces feeding the PSRF
//!   convergence monitor.
//! * [`server`] — [`Server`]: request-loop service (std::mpsc; the offline
//!   environment has no tokio) with a typed client [`Handle`].
//! * [`dispatch`] — policy choosing between the native sparse sampler
//!   (mutating topologies) and the XLA artifact path (stable topologies).
//! * [`metrics`] — counters/timers registry exported as JSON.

pub mod dispatch;
pub mod ensemble;
pub mod metrics;
pub mod server;

pub use dispatch::{DispatchDecision, DispatchPolicy};
pub use ensemble::PdEnsemble;
pub use metrics::Metrics;
pub use server::{Handle, Request, Server, ServerConfig, ServerStats};
