//! Layer 3: the multi-tenant sharded coordinator — the deployment story
//! the paper motivates, at serving scale.
//!
//! The paper's dynamic-network argument ("factors are added and removed
//! on a continuous basis") is strongest in the many-small-models regime:
//! one server hosting thousands of per-user/per-session MRFs, where
//! per-tenant coloring maintenance is unmaintainable and the primal–dual
//! sampler's O(degree) churn shines. This module is that server.
//!
//! ## Architecture
//!
//! ```text
//!                      Coordinator (front-end)
//!                      │  route(tenant) = splitmix64(id) % S   (pure hash)
//!        ┌─────────────┼─────────────────┐
//!        ▼             ▼                 ▼
//!   Shard 0        Shard 1   …      Shard S-1      (one thread each)
//!   ┌─────────┐    ┌─────────┐      ┌─────────┐
//!   │ tenants │    │ tenants │      │ tenants │    registry: TenantId →
//!   │  A C F  │    │  B D    │      │  E G H  │    FactorGraph+PdEnsemble
//!   │ DRR sched│   │ DRR sched│     │ DRR sched│   deficit-round-robin
//!   └────┬────┘    └────┬────┘      └────┬────┘    background sweeping
//!        └──────────────┴───────┬────────┘
//!                               ▼
//!                     shared ThreadPool (lent to whichever
//!                     tenant sweep is running; no per-shard pools)
//! ```
//!
//! * [`tenant`] — [`Tenant`]: one hosted model (graph + lane-batched
//!   [`PdEnsemble`] + live-factor list + serving counters). Per-tenant
//!   RNG streams stay `(sweep, site)`-keyed under the tenant's own seed,
//!   so trajectories are bit-identical at every shard count and pool
//!   size.
//! * [`shard`] — the worker loop: drains one FIFO request queue,
//!   interleaving foreground requests with background grants from…
//! * [`schedule`] — [`DrrScheduler`]: deficit round robin weighted by
//!   per-tenant sweep cost (site-visits, from the CSR incidence totals),
//!   so a 100k-factor tenant cannot starve a 100-factor one: over full
//!   ring passes every tenant receives the same cost budget.
//! * [`dispatch`] — policy choosing native sparse sweeps vs the XLA
//!   artifact path; fed each tenant's `stable_for` counter and surfaced
//!   in [`TenantStats::dispatch`].
//! * [`metrics`] — one shared registry with label-scoped views
//!   ([`Metrics::scoped`]): per-shard and per-tenant counters in one
//!   snapshot.
//! * [`ensemble`] — [`PdEnsemble`]: N chains over one shared dual model
//!   on the lane engine, with PSRF traces, churn hooks, a `cost()`
//!   accounting hook and cheap park/suspend.
//! * [`server`] — the single-tenant compat façade ([`Server`]) over a
//!   1-shard coordinator, preserving the PR-2 API.
//! * [`protocol`] — the line-oriented wire language (`create` / `apply` /
//!   `sweep` / `marginals` / `stats` / `drop` / `subscribe`) with
//!   spanned, labeled parse diagnostics ([`crate::util::Diagnostic`]).
//! * [`net`] — the TCP front-end: connection threads multiplex parsed
//!   requests onto the shard queues, with per-tenant/per-shard admission
//!   control backed by the [`Depth`] ledger (explicit `overloaded`
//!   rejections, never unbounded queues) and edge latency histograms.
//!
//! Tenant lifecycle: `create` (binary or K-state via `k=K`) / `apply` /
//! `sweep` / `clamp` / `unclamp` / `marginals` / `mixing` / `stats` /
//! `suspend` / `resume` / `drop`. Requests to one
//! tenant are FIFO (one queue per shard, one consumer); queries return
//! [`Result`] so a dead shard or unknown tenant degrades into an error
//! the caller can route around.

pub mod dispatch;
pub mod ensemble;
pub mod metrics;
pub mod net;
pub mod protocol;
pub mod schedule;
pub mod server;
pub mod shard;
pub mod tenant;

pub use dispatch::{DispatchDecision, DispatchPolicy};
pub use ensemble::PdEnsemble;
pub use metrics::{Metrics, MetricsView};
pub use net::{NetConfig, NetServer};
pub use protocol::{Request, Response};
pub use schedule::DrrScheduler;
pub use server::{Handle, Server, ServerConfig, ServerStats};
pub use shard::ShardStats;
pub use tenant::{Tenant, TenantConfig, TenantId, TenantStats};

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::diagnostics::MixingResult;
use crate::graph::FactorGraph;
use crate::rng::{RngCore, SplitMix64};
use crate::runtime::Manifest;
use crate::util::error::Result;
use crate::util::ThreadPool;
use crate::workloads::ChurnOp;

use shard::{shard_worker, ShardConfig, ShardRequest};

/// Route a tenant id to its shard: a pure splitmix64 hash of the id.
/// Stable across processes and independent of tenant creation order, so
/// a trace replays onto the same placement every time; changing `shards`
/// changes placement but never per-tenant behavior (each tenant's
/// trajectory depends only on its own seed).
pub fn route(tenant: TenantId, shards: usize) -> usize {
    debug_assert!(shards >= 1);
    (SplitMix64::new(tenant).next_u64() % shards as u64) as usize
}

/// Outstanding-request ledger shared by the routing [`Client`]s and the
/// shard workers: incremented when a request is enqueued, decremented
/// when its shard dequeues it. The network edge ([`net`]) reads it for
/// admission control — a connection whose tenant (or target shard) is
/// over its depth limit gets an explicit `overloaded` rejection instead
/// of growing the queue without bound. In-process [`Client`] calls are
/// *accounted* here but never rejected: backpressure is an edge policy.
pub struct Depth {
    /// Outstanding requests per shard queue.
    shards: Vec<AtomicU64>,
    /// Outstanding requests per tenant (entries are removed at zero so
    /// the map tracks live traffic, not tenant-id history).
    tenants: Mutex<HashMap<TenantId, u64>>,
}

impl Depth {
    fn new(shards: usize) -> Self {
        Self {
            shards: (0..shards).map(|_| AtomicU64::new(0)).collect(),
            tenants: Mutex::new(HashMap::new()),
        }
    }

    fn enqueued(&self, shard: usize, tenant: Option<TenantId>) {
        if let Some(s) = self.shards.get(shard) {
            s.fetch_add(1, Ordering::Relaxed);
        }
        if let Some(t) = tenant {
            *self.tenants.lock().expect("depth lock").entry(t).or_insert(0) += 1;
        }
    }

    /// Saturating decrement: shutdown markers are sent without accounting,
    /// so a dequeue may have no matching enqueue.
    fn dequeued(&self, shard: usize, tenant: Option<TenantId>) {
        if let Some(s) = self.shards.get(shard) {
            let _ = s.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| {
                d.checked_sub(1)
            });
        }
        if let Some(t) = tenant {
            let mut map = self.tenants.lock().expect("depth lock");
            if let Some(d) = map.get_mut(&t) {
                *d -= 1;
                if *d == 0 {
                    map.remove(&t);
                }
            }
        }
    }

    /// Outstanding requests on `shard`'s queue (0 for unknown shards).
    pub fn shard_depth(&self, shard: usize) -> u64 {
        self.shards.get(shard).map_or(0, |s| s.load(Ordering::Relaxed))
    }

    /// Outstanding requests addressed to `tenant`.
    pub fn tenant_depth(&self, tenant: TenantId) -> u64 {
        self.tenants
            .lock()
            .expect("depth lock")
            .get(&tenant)
            .copied()
            .unwrap_or(0)
    }
}

/// Coordinator construction parameters.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Shard worker threads (each owns a disjoint set of tenants).
    pub shards: usize,
    /// Workers of the one shared sweep pool lent across all shards
    /// (0 = sweeps run on the shard threads themselves).
    pub pool_threads: usize,
    /// Deficit-round-robin quantum: site-visits granted to each tenant
    /// per scheduler ring pass. Larger = longer uninterrupted background
    /// slices (throughput) at the price of request latency. 0 disables
    /// background sweeping entirely (deterministic request-driven mode).
    pub quantum: u64,
    /// Native-vs-XLA dispatch policy (surfaced per tenant in
    /// [`TenantStats::dispatch`]).
    pub dispatch: DispatchPolicy,
    /// Artifact manifest for the dispatch policy (None = native only).
    pub manifest: Option<Manifest>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            shards: 4,
            pool_threads: 0,
            quantum: 8192,
            dispatch: DispatchPolicy::default(),
            manifest: None,
        }
    }
}

/// A running multi-tenant coordinator: `S` shard threads behind a pure
/// hash router, one shared sweep pool, one metrics registry.
pub struct Coordinator {
    txs: Vec<Sender<ShardRequest>>,
    joins: Vec<JoinHandle<()>>,
    metrics: Metrics,
    pool: Option<Arc<ThreadPool>>,
    depth: Arc<Depth>,
}

impl Coordinator {
    /// Spawn the shard workers (and the shared pool, if configured).
    pub fn spawn(config: CoordinatorConfig) -> Coordinator {
        assert!(config.shards >= 1, "at least one shard");
        let metrics = Metrics::new();
        let pool = (config.pool_threads > 0).then(|| ThreadPool::shared(config.pool_threads));
        let depth = Arc::new(Depth::new(config.shards));
        let mut txs = Vec::with_capacity(config.shards);
        let mut joins = Vec::with_capacity(config.shards);
        for shard_id in 0..config.shards {
            let (tx, rx) = channel();
            let scfg = ShardConfig {
                shard_id,
                quantum: config.quantum,
                dispatch: config.dispatch.clone(),
                manifest: config.manifest.clone(),
            };
            let m = metrics.clone();
            let p = pool.clone();
            let d = depth.clone();
            joins.push(std::thread::spawn(move || shard_worker(scfg, rx, m, p, d)));
            txs.push(tx);
        }
        Coordinator {
            txs,
            joins,
            metrics,
            pool,
            depth,
        }
    }

    /// Number of shard workers.
    pub fn num_shards(&self) -> usize {
        self.txs.len()
    }

    /// The shared metrics registry (per-shard and per-tenant scoped keys;
    /// [`Metrics`] is a cheap-clone handle onto one registry).
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// The shared sweep pool, if one was configured.
    pub fn pool(&self) -> Option<&Arc<ThreadPool>> {
        self.pool.as_ref()
    }

    /// A cheap clonable client handle.
    pub fn client(&self) -> Client {
        Client {
            txs: self.txs.clone(),
            depth: self.depth.clone(),
        }
    }

    /// Graceful shutdown (idempotent): every shard drains its queue up to
    /// the shutdown marker, then exits.
    pub fn shutdown(&mut self) {
        for tx in &self.txs {
            let _ = tx.send(ShardRequest::Shutdown);
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Client handle to a coordinator: routes every call to the owning shard.
/// Queries return [`Result`]: an unknown tenant or a dead shard is an
/// error, never a panic.
#[derive(Clone)]
pub struct Client {
    txs: Vec<Sender<ShardRequest>>,
    depth: Arc<Depth>,
}

impl Client {
    fn shard_of(&self, tenant: TenantId) -> usize {
        route(tenant, self.txs.len())
    }

    fn send(&self, shard: usize, req: ShardRequest) -> Result<()> {
        let tx = self
            .txs
            .get(shard)
            .ok_or_else(|| crate::err!("no shard {shard} (coordinator has {})", self.txs.len()))?;
        let tenant = req.tenant();
        self.depth.enqueued(shard, tenant);
        tx.send(req).map_err(|_| {
            self.depth.dequeued(shard, tenant);
            crate::err!("shard {shard} is down")
        })
    }

    /// Send a query carrying a `Result` reply channel and await it.
    fn ask<T>(
        &self,
        shard: usize,
        build: impl FnOnce(Sender<Result<T>>) -> ShardRequest,
    ) -> Result<T> {
        let (tx, rx) = channel();
        self.send(shard, build(tx))?;
        rx.recv()
            .map_err(|_| crate::err!("shard {shard} dropped before replying"))?
    }

    /// Host a new tenant; fails if the id is already hosted.
    pub fn create_tenant(
        &self,
        tenant: TenantId,
        graph: FactorGraph,
        config: TenantConfig,
    ) -> Result<()> {
        self.ask(self.shard_of(tenant), |reply| ShardRequest::Create {
            tenant,
            graph,
            config,
            reply,
        })
    }

    /// Drop a tenant; returns whether it was hosted.
    pub fn drop_tenant(&self, tenant: TenantId) -> Result<bool> {
        self.ask(self.shard_of(tenant), |reply| ShardRequest::Drop {
            tenant,
            reply,
        })
    }

    /// Apply topology mutations (fire-and-forget; FIFO per tenant).
    pub fn apply(&self, tenant: TenantId, ops: Vec<ChurnOp>) -> Result<()> {
        self.send(self.shard_of(tenant), ShardRequest::Apply { tenant, ops })
    }

    /// Run `n` foreground sweeps before later requests are answered.
    pub fn sweep(&self, tenant: TenantId, n: usize) -> Result<()> {
        self.send(self.shard_of(tenant), ShardRequest::Sweep { tenant, n })
    }

    /// Drop accumulated statistics (e.g. after burn-in).
    pub fn reset_stats(&self, tenant: TenantId) -> Result<()> {
        self.send(self.shard_of(tenant), ShardRequest::ResetStats { tenant })
    }

    /// Exclude a tenant from background sweeping (state is kept).
    pub fn suspend(&self, tenant: TenantId) -> Result<()> {
        self.send(self.shard_of(tenant), ShardRequest::Suspend { tenant })
    }

    /// Re-enroll a suspended tenant in background sweeping.
    pub fn resume(&self, tenant: TenantId) -> Result<()> {
        self.send(self.shard_of(tenant), ShardRequest::Resume { tenant })
    }

    /// Clamp site `v` of a tenant to evidence `state`: subsequent sweeps
    /// target the conditional law given the evidence. Synchronous — an
    /// out-of-range site/state or a policy that cannot clamp (minibatch,
    /// blocked) is an error reply, not a panic.
    pub fn clamp(&self, tenant: TenantId, v: usize, state: u8) -> Result<()> {
        self.ask(self.shard_of(tenant), |reply| ShardRequest::Clamp {
            tenant,
            v,
            state,
            reply,
        })
    }

    /// Release a clamped site (no-op if it was not clamped).
    pub fn unclamp(&self, tenant: TenantId, v: usize) -> Result<()> {
        self.ask(self.shard_of(tenant), |reply| ShardRequest::Unclamp {
            tenant,
            v,
            reply,
        })
    }

    /// Posterior marginal estimates.
    pub fn marginals(&self, tenant: TenantId) -> Result<Vec<f64>> {
        self.ask(self.shard_of(tenant), |reply| ShardRequest::Marginals {
            tenant,
            reply,
        })
    }

    /// PSRF mixing diagnosis at `threshold` with checkpoint `stride`.
    pub fn mixing(&self, tenant: TenantId, threshold: f64, stride: usize) -> Result<MixingResult> {
        self.ask(self.shard_of(tenant), |reply| ShardRequest::Mixing {
            tenant,
            threshold,
            stride,
            reply,
        })
    }

    /// Tenant serving snapshot (counters + dispatch decision).
    pub fn stats(&self, tenant: TenantId) -> Result<TenantStats> {
        self.ask(self.shard_of(tenant), |reply| ShardRequest::Stats {
            tenant,
            reply,
        })
    }

    /// Aggregate snapshot of one shard (`0..num_shards`).
    pub fn shard_stats(&self, shard: usize) -> Result<ShardStats> {
        let (tx, rx) = channel();
        self.send(shard, ShardRequest::ShardStats { reply: tx })?;
        rx.recv()
            .map_err(|_| crate::err!("shard {shard} dropped before replying"))
    }

    /// Number of shard workers this client can address.
    pub fn num_shards(&self) -> usize {
        self.txs.len()
    }

    /// Which shard would serve `tenant` (the pure [`route`] hash).
    pub fn shard_for(&self, tenant: TenantId) -> usize {
        self.shard_of(tenant)
    }

    /// Outstanding (enqueued, not yet dequeued) requests on `shard`.
    pub fn queue_depth(&self, shard: usize) -> u64 {
        self.depth.shard_depth(shard)
    }

    /// Outstanding requests addressed to `tenant`.
    pub fn tenant_depth(&self, tenant: TenantId) -> u64 {
        self.depth.tenant_depth(tenant)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::exact;
    use crate::workloads::{self, ChurnTrace, TenantTrace, TenantTraceConfig};

    fn tcfg(seed: u64, chains: usize) -> TenantConfig {
        TenantConfig {
            chains,
            seed,
            ..TenantConfig::default()
        }
    }

    /// Drive 16 tenants with interleaved churn on a coordinator of the
    /// given shape; background sweeping is off so the trajectory is a
    /// pure function of the request stream. Returns per-tenant marginals.
    fn run_configuration(shards: usize, pool_threads: usize) -> Vec<Vec<f64>> {
        const TENANTS: u64 = 16;
        let mut coord = Coordinator::spawn(CoordinatorConfig {
            shards,
            pool_threads,
            quantum: 0,
            ..Default::default()
        });
        let client = coord.client();
        let traces: Vec<ChurnTrace> = (0..TENANTS)
            .map(|t| ChurnTrace::generate(6, 7, 24, 0.6, 100 + t))
            .collect();
        for t in 0..TENANTS {
            client
                .create_tenant(t, FactorGraph::new(6), tcfg(1000 + t, 8))
                .unwrap();
        }
        // interleaved churn: every tenant alternates apply/sweep rounds
        for round in 0..3 {
            for t in 0..TENANTS {
                let ops = traces[t as usize].ops[round * 8..(round + 1) * 8].to_vec();
                client.apply(t, ops).unwrap();
                client.sweep(t, 50).unwrap();
            }
        }
        // settle: burn in, reset, accumulate statistics
        for t in 0..TENANTS {
            client.sweep(t, 300).unwrap();
            client.reset_stats(t).unwrap();
            client.sweep(t, 5000).unwrap();
        }
        let out = (0..TENANTS)
            .map(|t| client.marginals(t).unwrap())
            .collect();
        coord.shutdown();
        out
    }

    #[test]
    fn multi_tenant_deterministic_across_shards_and_pools() {
        // acceptance: 16 tenants with interleaved churn produce marginals
        // (a) within 0.02 of exact enumeration per tenant and (b)
        // bit-identical across shard counts {1, 4} and pool sizes {0, 4}
        let reference = run_configuration(1, 0);
        for &(shards, pool) in &[(4usize, 0usize), (1, 4), (4, 4)] {
            let got = run_configuration(shards, pool);
            assert_eq!(
                got, reference,
                "trajectories diverged at shards={shards} pool={pool}"
            );
        }
        for (t, marginals) in reference.iter().enumerate() {
            let trace = ChurnTrace::generate(6, 7, 24, 0.6, 100 + t as u64);
            let (g, _) = trace.materialize();
            let want = exact::enumerate(&g).marginals;
            for v in 0..6 {
                assert!(
                    (marginals[v] - want[v]).abs() < 0.02,
                    "tenant {t} v={v}: {} vs exact {}",
                    marginals[v],
                    want[v]
                );
            }
        }
    }

    #[test]
    fn fair_share_background_sweeping_under_50x_size_skew() {
        // acceptance: with a ~60x-larger neighbor running hot, the small
        // tenant's background sweep count stays within 2x of its fair
        // share (equal cost budget per tenant per DRR ring pass)
        let mut coord = Coordinator::spawn(CoordinatorConfig {
            shards: 1,
            pool_threads: 0,
            quantum: 8192,
            ..Default::default()
        });
        let client = coord.client();
        client
            .create_tenant(1, workloads::ising_grid(3, 3, 0.25, 0.0), tcfg(11, 4))
            .unwrap();
        client
            .create_tenant(2, workloads::ising_grid(20, 20, 0.25, 0.0), tcfg(22, 4))
            .unwrap();
        std::thread::sleep(std::time::Duration::from_millis(250));
        let s1 = client.stats(1).unwrap();
        let s2 = client.stats(2).unwrap();
        coord.shutdown();
        assert!(s2.cost > 50 * s1.cost, "size skew: {} vs {}", s2.cost, s1.cost);
        assert!(s1.background_sweeps > 0, "small tenant starved");
        assert!(s2.background_sweeps > 0, "big tenant starved");
        let work1 = s1.background_sweeps * s1.cost;
        let work2 = s2.background_sweeps * s2.cost;
        let ratio = work1 as f64 / work2 as f64;
        assert!(
            (0.5..=2.0).contains(&ratio),
            "fair share violated: small {} sweeps x {} = {work1}, \
             big {} sweeps x {} = {work2} (ratio {ratio:.2})",
            s1.background_sweeps,
            s1.cost,
            s2.background_sweeps,
            s2.cost
        );
        // in sweep counts, the small tenant must far out-sweep the big one
        assert!(s1.background_sweeps > 10 * s2.background_sweeps);
    }

    #[test]
    fn queue_depth_tracks_outstanding_and_drains_to_zero() {
        let mut coord = Coordinator::spawn(CoordinatorConfig {
            shards: 1,
            quantum: 0,
            ..Default::default()
        });
        let client = coord.client();
        client
            .create_tenant(5, FactorGraph::new(3), tcfg(5, 2))
            .unwrap();
        for _ in 0..8 {
            client.sweep(5, 5).unwrap();
        }
        // a synchronous query is a FIFO barrier: by the time it answers,
        // everything enqueued before it has been dequeued and accounted
        let _ = client.stats(5).unwrap();
        assert_eq!(client.queue_depth(0), 0);
        assert_eq!(client.tenant_depth(5), 0);
        // out-of-range shard reads 0, never panics
        assert_eq!(client.queue_depth(99), 0);
        coord.shutdown();
    }

    #[test]
    fn routing_is_pure_and_covers_all_shards() {
        for id in 0..64u64 {
            assert_eq!(route(id, 4), route(id, 4));
            assert!(route(id, 4) < 4);
        }
        let mut seen = [false; 4];
        for id in 0..64u64 {
            seen[route(id, 4)] = true;
        }
        assert!(seen.iter().all(|&s| s), "64 tenants must hit all 4 shards");
        // and shard registries agree with the router
        let mut coord = Coordinator::spawn(CoordinatorConfig {
            shards: 4,
            quantum: 0,
            ..Default::default()
        });
        let client = coord.client();
        for id in 0..32u64 {
            client
                .create_tenant(id, FactorGraph::new(2), tcfg(id, 2))
                .unwrap();
        }
        let mut per_shard = [0usize; 4];
        for id in 0..32u64 {
            per_shard[route(id, 4)] += 1;
        }
        for shard in 0..4 {
            let stats = client.shard_stats(shard).unwrap();
            assert_eq!(stats.tenants, per_shard[shard], "shard {shard}");
        }
        coord.shutdown();
    }

    #[test]
    fn unknown_tenant_and_dead_shard_degrade_to_errors() {
        let mut coord = Coordinator::spawn(CoordinatorConfig {
            shards: 2,
            quantum: 0,
            ..Default::default()
        });
        let client = coord.client();
        client
            .create_tenant(3, FactorGraph::new(2), tcfg(3, 2))
            .unwrap();
        // duplicate create fails, unknown tenant queries fail
        assert!(client.create_tenant(3, FactorGraph::new(2), tcfg(3, 2)).is_err());
        assert!(client.marginals(999).is_err());
        assert!(client.stats(999).is_err());
        // an out-of-range shard index is an error, not an index panic
        assert!(client.shard_stats(99).is_err());
        // a zero PSRF stride is clamped, not a shard-killing div-by-zero
        client.sweep(3, 10).unwrap();
        let _ = client.mixing(3, 1.05, 0).unwrap();
        assert!(client.stats(3).is_ok(), "shard survived the zero stride");
        assert!(!client.drop_tenant(998).unwrap());
        assert!(client.drop_tenant(3).unwrap());
        // after shutdown every call degrades into an error, never a panic
        coord.shutdown();
        assert!(client.marginals(3).is_err());
        assert!(client.stats(3).is_err());
        assert!(client.apply(3, Vec::new()).is_err());
        assert!(client.sweep(3, 1).is_err());
        assert!(client.shard_stats(0).is_err());
    }

    #[test]
    fn malformed_ops_do_not_kill_the_shard_and_drop_reclaims_metrics() {
        let mut coord = Coordinator::spawn(CoordinatorConfig {
            shards: 1,
            quantum: 0,
            ..Default::default()
        });
        let client = coord.client();
        client
            .create_tenant(1, FactorGraph::new(3), tcfg(1, 2))
            .unwrap();
        // an out-of-bounds RemoveLive must degrade, not panic the shard
        client
            .apply(1, vec![ChurnOp::RemoveLive { index: 42 }])
            .unwrap();
        let s = client.stats(1).unwrap();
        assert_eq!(s.ops_applied, 0, "invalid op must be skipped");
        assert_eq!(coord.metrics().counter("tenant1.invalid_ops"), 1);
        // dropping the tenant reclaims its scoped metrics keys
        assert!(client.drop_tenant(1).unwrap());
        let snap = coord.metrics().snapshot().dump();
        assert!(!snap.contains("tenant1."), "scope leaked: {snap}");
        coord.shutdown();
    }

    #[test]
    fn dispatch_decision_surfaces_in_tenant_stats() {
        // satellite: DispatchPolicy is finally wired in — stable_for is
        // tracked per tenant and the decision is visible in stats
        let manifest = Manifest::parse(
            r#"{"artifacts": [
                {"name": "g9", "file": "x", "n": 9, "f": 12,
                 "chains": 8, "sweeps": 8, "n_pad": 16, "f_pad": 32}
            ]}"#,
        )
        .unwrap();
        let mut coord = Coordinator::spawn(CoordinatorConfig {
            shards: 1,
            quantum: 0,
            manifest: Some(manifest),
            ..Default::default()
        });
        let client = coord.client();
        client
            .create_tenant(0, workloads::ising_grid(3, 3, 0.2, 0.0), tcfg(5, 4))
            .unwrap();
        let s = client.stats(0).unwrap();
        assert_eq!(s.stable_for, 0);
        assert_eq!(s.dispatch, DispatchDecision::Native, "unstable: native");
        client.sweep(0, 100).unwrap();
        let s = client.stats(0).unwrap();
        assert_eq!(s.stable_for, 100);
        assert_eq!(
            s.dispatch,
            DispatchDecision::Xla("g9".into()),
            "stable + fitting: artifact path"
        );
        client
            .apply(0, vec![ChurnOp::Add { v1: 0, v2: 4, beta: 0.2 }])
            .unwrap();
        let s = client.stats(0).unwrap();
        assert_eq!(s.stable_for, 0, "churn resets stability");
        assert_eq!(s.dispatch, DispatchDecision::Native);
        coord.shutdown();
    }

    #[test]
    fn kstate_tenants_and_clamping_over_the_client() {
        use crate::graph::PairFactor;
        let mut coord = Coordinator::spawn(CoordinatorConfig {
            shards: 2,
            quantum: 0,
            ..Default::default()
        });
        let client = coord.client();
        let mut g = FactorGraph::new_k(4, 3);
        for v in 0..3 {
            g.add_factor(PairFactor::potts(v, v + 1, 0.5));
        }
        client.create_tenant(7, g, tcfg(7, 4)).unwrap();
        let s = client.stats(7).unwrap();
        assert_eq!((s.k, s.clamped), (3, 0));
        client.clamp(7, 0, 2).unwrap();
        assert!(client.clamp(7, 0, 3).is_err(), "state ≥ k is an error reply");
        assert!(client.clamp(7, 9, 0).is_err(), "unknown site likewise");
        assert!(client.clamp(999, 0, 0).is_err(), "unknown tenant likewise");
        client.sweep(7, 50).unwrap();
        let s = client.stats(7).unwrap();
        assert_eq!(s.clamped, 1);
        let m = client.marginals(7).unwrap();
        assert_eq!(m.len(), 4 * 2, "flattened n·(k−1) marginals on the wire");
        assert_eq!(m[1], 1.0, "site 0 pinned to state 2");
        client.unclamp(7, 0).unwrap();
        assert_eq!(client.stats(7).unwrap().clamped, 0);
        // degenerate policy knobs: error reply, shard stays alive
        let mut g2 = FactorGraph::new_k(3, 3);
        g2.add_factor(PairFactor::potts(0, 1, 0.3));
        let bad = TenantConfig {
            sweep: crate::engine::SweepPolicy::Blocked(crate::duality::BlockPolicy {
                cap: 1,
                epoch: 16,
            }),
            ..tcfg(8, 4)
        };
        let err = client.create_tenant(8, g2.clone(), bad).unwrap_err();
        assert!(
            err.to_string().contains("create rejected"),
            "clean rejection, got: {err}"
        );
        // the id is reusable after a rejected create — and the formerly
        // rejected minibatch × K-state combination now hosts cleanly
        let mb = TenantConfig {
            sweep: crate::engine::SweepPolicy::Minibatch(
                crate::duality::MinibatchPolicy::default(),
            ),
            ..tcfg(8, 4)
        };
        client.create_tenant(8, g2, mb.clone()).unwrap();
        let s = client.stats(8).unwrap();
        assert_eq!((s.k, s.policy), (3, mb.sweep));
        coord.shutdown();
    }

    #[test]
    fn suspend_resume_and_drop_lifecycle() {
        let mut coord = Coordinator::spawn(CoordinatorConfig {
            shards: 2,
            quantum: 4096,
            ..Default::default()
        });
        let client = coord.client();
        client
            .create_tenant(0, workloads::ising_grid(2, 2, 0.2, 0.0), tcfg(1, 2))
            .unwrap();
        client.suspend(0).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(30));
        let s = client.stats(0).unwrap();
        assert!(s.suspended);
        let frozen = s.background_sweeps;
        std::thread::sleep(std::time::Duration::from_millis(30));
        let s = client.stats(0).unwrap();
        assert_eq!(
            s.background_sweeps, frozen,
            "suspended tenant must not be background-swept"
        );
        client.resume(0).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(60));
        let s = client.stats(0).unwrap();
        assert!(s.background_sweeps > frozen, "resume re-enrolls in DRR");
        assert!(client.drop_tenant(0).unwrap());
        assert!(client.marginals(0).is_err(), "dropped tenant is gone");
        coord.shutdown();
    }

    #[test]
    fn multi_tenant_soak_churn_on_threaded_pool() {
        // soak: replay a seeded arrival/departure trace with per-tenant
        // churn on 4 shards sharing one 4-worker pool, background on.
        // Exercises create/drop/apply/sweep/marginals/stats concurrency;
        // asserts the coordinator stays consistent and every surviving
        // tenant still answers.
        let mut coord = Coordinator::spawn(CoordinatorConfig {
            shards: 4,
            pool_threads: 4,
            quantum: 2048,
            ..Default::default()
        });
        let client = coord.client();
        let trace = TenantTrace::generate(
            TenantTraceConfig {
                max_tenants: 12,
                steps: 160,
                vars: (4, 9),
                target_factors: 8,
                ops_per_apply: 3,
                sweeps_per_step: 4,
                beta_max: 0.5,
            },
            0xD15EA5E,
        );
        let mut live = Vec::new();
        for event in &trace.events {
            use workloads::TenantEvent::*;
            match event {
                Create { tenant, vars, seed } => {
                    client
                        .create_tenant(*tenant, FactorGraph::new(*vars), tcfg(*seed, 4))
                        .unwrap();
                    live.push(*tenant);
                }
                Apply { tenant, ops } => client.apply(*tenant, ops.clone()).unwrap(),
                Sweep { tenant, n } => client.sweep(*tenant, *n).unwrap(),
                Drop { tenant } => {
                    assert!(client.drop_tenant(*tenant).unwrap());
                    live.retain(|t| t != tenant);
                }
            }
        }
        assert!(!live.is_empty(), "trace must leave survivors");
        let mut total_tenants = 0;
        for shard in 0..4 {
            total_tenants += client.shard_stats(shard).unwrap().tenants;
        }
        assert_eq!(total_tenants, live.len());
        for &t in &live {
            let stats = client.stats(t).unwrap();
            let m = client.marginals(t).unwrap();
            assert_eq!(m.len(), stats.num_vars);
            assert!(m.iter().all(|p| (0.0..=1.0).contains(p)), "tenant {t}");
        }
        // metrics landed under scoped keys for shards and tenants
        let snap = coord.metrics().snapshot().dump();
        assert!(snap.contains("shard0."), "per-shard scope missing: {snap}");
        assert!(snap.contains("tenant"), "per-tenant scope missing");
        coord.shutdown();
    }
}
