//! Multi-chain primal–dual ensemble with convergence monitoring.
//!
//! The paper's experiments run 10 chains and diagnose mixing via PSRF;
//! [`PdEnsemble`] is that harness as a first-class runtime object. The
//! chains execute on the lane-batched engine
//! ([`crate::engine::LanePdSampler`]): one shared [`DualModel`] (updated
//! incrementally under churn), bit-packed variable-major state, and one
//! incidence traversal per variable per sweep regardless of the chain
//! count — thread parallelism splits over variables, so it scales with
//! model size rather than chain count. The PSRF/trace API is unchanged:
//! per-sweep traces (magnetization plus a monitored variable subset) feed
//! [`crate::diagnostics`].

use std::sync::Arc;

use crate::diagnostics::{mixing_time_multi, MixingResult};
use crate::duality::DualModel;
use crate::engine::{EngineConfig, EngineError, LanePdSampler, SweepPolicy};
use crate::graph::{FactorGraph, FactorId, PairFactor};
use crate::util::ThreadPool;

/// N primal–dual chains over one shared dual model, one lane per chain.
pub struct PdEnsemble {
    engine: LanePdSampler,
    /// Variables whose per-sweep traces are recorded for PSRF.
    monitor: Vec<usize>,
    /// `traces[0]` = magnetization (fraction of sites off state 0 — the
    /// fraction of ones on binary models); `traces[1 + m]` = monitor
    /// var m. Layout: `traces[stat][chain][sweep]`.
    traces: Vec<Vec<Vec<f64>>>,
    /// Per-chain sample sums since the last `reset_stats`, flattened
    /// `sums[chain][v·(k−1) + (s−1)]` for states `s ∈ 1..k` (length-n
    /// ones counts on binary models).
    sums: Vec<Vec<f64>>,
    sweeps_done: usize,
    stat_sweeps: usize,
}

impl PdEnsemble {
    /// Build from a graph with `chains` chains seeded from `seed`.
    pub fn new(graph: &FactorGraph, chains: usize, seed: u64) -> Self {
        Self::from_model(DualModel::from_graph(graph), chains, seed)
    }

    /// Build with an explicit sweep policy (the ensemble's chains all
    /// share it — it is a property of the engine, not of a chain).
    pub fn with_policy(
        graph: &FactorGraph,
        chains: usize,
        seed: u64,
        sweep: SweepPolicy,
    ) -> Self {
        Self::try_with_policy(graph, chains, seed, sweep)
            .expect("degenerate sweep-policy knobs")
    }

    /// Fallible [`PdEnsemble::with_policy`]: surfaces the engine's
    /// degenerate-knob rejection ([`EngineError::InvalidPolicy`])
    /// instead of panicking.
    pub fn try_with_policy(
        graph: &FactorGraph,
        chains: usize,
        seed: u64,
        sweep: SweepPolicy,
    ) -> Result<Self, EngineError> {
        Self::try_from_model_config(
            DualModel::from_graph(graph),
            EngineConfig {
                lanes: chains,
                seed,
                sweep,
                ..EngineConfig::default()
            },
        )
    }

    /// Wrap an existing dual model (shared slot space with the graph).
    pub fn from_model(model: DualModel, chains: usize, seed: u64) -> Self {
        Self::from_model_config(
            model,
            EngineConfig {
                lanes: chains,
                seed,
                ..EngineConfig::default()
            },
        )
    }

    /// Wrap an existing dual model with full [`EngineConfig`] knobs
    /// (`cfg.lanes` is the chain count).
    pub fn from_model_config(model: DualModel, cfg: EngineConfig) -> Self {
        Self::try_from_model_config(model, cfg).expect("unsupported engine configuration")
    }

    /// Fallible construction: every sweep policy hosts every cardinality
    /// `2 ≤ k ≤ 8` (and clamping), but degenerate policy knobs are
    /// rejected ([`EngineError::InvalidPolicy`]) instead of panicking —
    /// the multi-tenant serving path must turn these into error
    /// replies, not dead shard threads.
    pub fn try_from_model_config(
        model: DualModel,
        cfg: EngineConfig,
    ) -> Result<Self, EngineError> {
        let chains = cfg.lanes;
        assert!(chains >= 1);
        let n = model.num_vars();
        let marg = n * (model.k() - 1);
        let engine = LanePdSampler::try_from_model_config(model, cfg)?;
        Ok(Self {
            engine,
            monitor: Vec::new(),
            traces: vec![vec![Vec::new(); chains]],
            sums: vec![vec![0.0; marg]; chains],
            sweeps_done: 0,
            stat_sweeps: 0,
        })
    }

    /// Enable pooled sweeps (the engine splits work over variables).
    pub fn with_pool(mut self, pool: Arc<ThreadPool>) -> Self {
        self.engine = self.engine.with_pool(pool);
        self
    }

    /// Record per-sweep traces for these variables (PSRF monitors).
    pub fn monitor_vars(&mut self, vars: Vec<usize>) {
        self.monitor = vars;
        let m = self.num_chains();
        self.traces = vec![vec![Vec::new(); m]; 1 + self.monitor.len()];
    }

    /// Overdispersed initialization: chain c starts all-0 / all-top /
    /// random (all-0 / all-1 on binary models). Clamped sites keep
    /// their evidence value throughout.
    pub fn init_overdispersed(&mut self) {
        let top = (self.k() - 1) as u8;
        for c in 0..self.num_chains() {
            match c % 3 {
                0 => self.engine.fill_lane_state(c, 0),
                1 => self.engine.fill_lane_state(c, top),
                _ => self.engine.randomize_lane(c),
            }
            self.engine.clear_theta_lane(c);
        }
    }

    /// Number of chains (engine lanes).
    pub fn num_chains(&self) -> usize {
        self.engine.lanes()
    }

    /// States per variable of the shared model (2 = binary).
    pub fn k(&self) -> usize {
        self.engine.k()
    }

    /// Clamp site `v` to evidence `state` in **every** chain: its draws
    /// are skipped while it keeps conditioning its neighbors, so the
    /// ensemble targets the conditional law given the evidence.
    /// Accumulated statistics are dropped — the target changed, stale
    /// sums are biased toward the unconditioned law.
    pub fn clamp(&mut self, v: usize, state: u8) -> Result<(), EngineError> {
        self.engine.clamp(v, state)?;
        self.reset_stats();
        Ok(())
    }

    /// Release a clamped site (its last evidence value persists until
    /// the next sweep resamples it). Statistics are dropped as for
    /// [`PdEnsemble::clamp`].
    pub fn unclamp(&mut self, v: usize) -> Result<(), EngineError> {
        self.engine.unclamp(v)?;
        self.reset_stats();
        Ok(())
    }

    /// Number of currently clamped sites.
    pub fn clamped_count(&self) -> usize {
        self.engine.clamped_count()
    }

    /// Total sweeps performed since construction.
    pub fn sweeps_done(&self) -> usize {
        self.sweeps_done
    }

    /// The shared dual model.
    pub fn model(&self) -> &DualModel {
        self.engine.model()
    }

    /// Per-sweep cost in site-visits (the scheduler's fair-share unit) —
    /// delegates to the engine's accounting hook, so it tracks churn
    /// *and* the sweep policy (minibatched hubs are charged their batch,
    /// not their degree).
    pub fn cost(&self) -> u64 {
        self.engine.cost()
    }

    /// The sweep policy all chains share.
    pub fn sweep_policy(&self) -> SweepPolicy {
        self.engine.sweep_policy()
    }

    /// Current blocked-sweep plan summary as
    /// `(blocks, blocked_vars, tree_slots)` — all zeros for non-blocked
    /// policies and before the first plan forms. Surfaces in the wire
    /// `stats` reply so operators can see whether adaptive blocking has
    /// actually engaged for a tenant.
    pub fn block_summary(&self) -> (usize, usize, usize) {
        self.engine.block_summary()
    }

    /// Park the ensemble: a suspended tenant keeps its sampler state
    /// (x/θ words — resuming is free) *and* its marginal sums (so
    /// [`PdEnsemble::marginals`] keeps answering with the pre-suspension
    /// estimate instead of silently degrading to all-zeros), but releases
    /// the per-sweep PSRF trace buffers — the O(sweeps·chains) memory
    /// that actually grows while a tenant idles. Traces restart empty on
    /// resume, exactly as after a `reset_stats`.
    pub fn park(&mut self) {
        for stat in &mut self.traces {
            for t in stat.iter_mut() {
                t.clear();
                t.shrink_to_fit();
            }
        }
    }

    /// One chain's primal state, unpacked to bytes.
    pub fn chain_state(&self, c: usize) -> Vec<u8> {
        self.engine.lane_state(c)
    }

    // -- dynamic topology --------------------------------------------------

    /// O(degree) factor insertion shared by all chains (no recoloring).
    pub fn add_factor(&mut self, id: FactorId, f: &PairFactor) {
        self.engine.add_factor(id, f);
    }

    /// O(degree) factor removal shared by all chains. Returns whether the
    /// slot was live (a dead/unknown id is a reported no-op, mirroring
    /// [`crate::engine::LanePdSampler::remove_factor`]).
    pub fn remove_factor(&mut self, id: FactorId) -> bool {
        self.engine.remove_factor(id)
    }

    // -- sampling -----------------------------------------------------------

    /// Advance every chain by `sweeps` sweeps, recording traces.
    pub fn run(&mut self, sweeps: usize) {
        for _ in 0..sweeps {
            self.engine.sweep();
            self.record();
        }
    }

    fn record(&mut self) {
        self.sweeps_done += 1;
        self.stat_sweeps += 1;
        let n = self.engine.num_vars();
        let m = self.num_chains();
        let k = self.engine.k();
        let words = self.engine.words_per_site();
        let mut mag = vec![0u32; m];
        if k == 2 {
            // one pass over the packed state updates both the per-chain
            // sums and the magnetization counts (bit-sparse iteration per
            // word; one plane per site, so rows are `words` apart)
            let state = self.engine.state_words();
            for v in 0..n {
                for w in 0..words {
                    let mut bits = state[v * words + w];
                    while bits != 0 {
                        let c = w * 64 + bits.trailing_zeros() as usize;
                        mag[c] += 1;
                        self.sums[c][v] += 1.0;
                        bits &= bits - 1;
                    }
                }
            }
        } else {
            for (c, mg) in mag.iter_mut().enumerate() {
                for v in 0..n {
                    let s = self.engine.lane_value(v, c) as usize;
                    if s > 0 {
                        *mg += 1;
                        self.sums[c][v * (k - 1) + (s - 1)] += 1.0;
                    }
                }
            }
        }
        let nf = n as f64;
        for (c, &off0) in mag.iter().enumerate() {
            self.traces[0][c].push(off0 as f64 / nf);
        }
        for (mi, &v) in self.monitor.iter().enumerate() {
            for c in 0..m {
                self.traces[1 + mi][c].push(self.engine.lane_value(v, c) as f64);
            }
        }
    }

    /// Drop accumulated statistics and traces (e.g. after burn-in or a
    /// topology change, which shifts the target distribution).
    pub fn reset_stats(&mut self) {
        for stat in &mut self.traces {
            for t in stat.iter_mut() {
                t.clear();
            }
        }
        for s in &mut self.sums {
            s.fill(0.0);
        }
        self.stat_sweeps = 0;
    }

    /// PSRF-based mixing diagnosis over all monitored statistics.
    pub fn mixing(&self, threshold: f64, stride: usize) -> MixingResult {
        mixing_time_multi(&self.traces, threshold, stride)
    }

    /// Posterior marginal estimates pooled across chains since the last
    /// `reset_stats`, flattened `out[v·(k−1) + (s−1)] = P(x_v = s)` for
    /// `s ∈ 1..k` — length-n `P(x_v = 1)` on binary models. Clamped
    /// sites report their evidence state with probability exactly 1.
    pub fn marginals(&self) -> Vec<f64> {
        let n = self.engine.num_vars() * (self.engine.k() - 1);
        let denom = (self.stat_sweeps * self.num_chains()) as f64;
        let mut out = vec![0.0; n];
        if denom == 0.0 {
            return out;
        }
        for chain_sums in &self.sums {
            for (o, &s) in out.iter_mut().zip(chain_sums) {
                *o += s;
            }
        }
        for o in &mut out {
            *o /= denom;
        }
        out
    }

    /// Magnetization traces (`[chain][sweep]`) — feed to diagnostics.
    pub fn magnetization_traces(&self) -> &[Vec<f64>] {
        &self.traces[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::exact;
    use crate::workloads;

    #[test]
    fn ensemble_marginals_match_exact() {
        let g = workloads::ising_grid(3, 3, 0.3, 0.1);
        let mut e = PdEnsemble::new(&g, 8, 42);
        e.run(500); // burn-in
        e.reset_stats();
        e.run(15_000);
        let got = e.marginals();
        let want = exact::enumerate(&g).marginals;
        for v in 0..9 {
            assert!(
                (got[v] - want[v]).abs() < 0.01,
                "v={v}: {} vs {}",
                got[v],
                want[v]
            );
        }
    }

    #[test]
    fn parallel_pool_matches_exact() {
        let g = workloads::ising_grid(3, 3, 0.25, -0.05);
        let pool = Arc::new(ThreadPool::new(2));
        let mut e = PdEnsemble::new(&g, 8, 43).with_pool(pool);
        e.run(300);
        e.reset_stats();
        e.run(5_000);
        let got = e.marginals();
        let want = exact::enumerate(&g).marginals;
        for v in 0..9 {
            assert!((got[v] - want[v]).abs() < 0.02, "v={v}");
        }
    }

    #[test]
    fn pool_does_not_change_the_trajectory() {
        // engine streams are keyed (sweep, site): pooled and serial runs
        // of the same seed are bit-identical, so ensemble statistics are
        // reproducible however the host machine is sized
        let g = workloads::ising_grid(4, 4, 0.3, 0.05);
        let mut a = PdEnsemble::new(&g, 6, 47);
        let mut b = PdEnsemble::new(&g, 6, 47).with_pool(Arc::new(ThreadPool::new(3)));
        a.init_overdispersed();
        b.init_overdispersed();
        a.run(40);
        b.run(40);
        for c in 0..6 {
            assert_eq!(a.chain_state(c), b.chain_state(c), "chain {c}");
        }
        assert_eq!(a.magnetization_traces(), b.magnetization_traces());
    }

    #[test]
    fn mixing_monitor_reports() {
        let g = workloads::ising_grid(4, 4, 0.2, 0.0);
        let mut e = PdEnsemble::new(&g, 6, 44);
        e.monitor_vars(vec![0, 5, 15]);
        e.init_overdispersed();
        e.run(3000);
        let r = e.mixing(1.05, 50);
        assert!(r.mixing_time.is_some(), "weakly coupled grid must mix");
        assert!(r.final_psrf < 1.05);
    }

    #[test]
    fn dynamic_updates_shift_marginals() {
        let mut g = FactorGraph::new(2);
        g.set_unary(0, 2.0);
        let mut e = PdEnsemble::new(&g, 6, 45);
        e.run(200);
        e.reset_stats();
        e.run(8000);
        let before = e.marginals();
        assert!((before[1] - 0.5).abs() < 0.02, "uncoupled var near 1/2");
        // couple strongly to the biased variable
        let id = g.add_factor(PairFactor::ising(0, 1, 1.5));
        e.add_factor(id, g.factor(id).unwrap());
        e.reset_stats();
        e.run(200);
        e.reset_stats();
        e.run(12_000);
        let after = e.marginals();
        let want = exact::enumerate(&g).marginals;
        assert!(
            (after[1] - want[1]).abs() < 0.015,
            "{} vs {}",
            after[1],
            want[1]
        );
        assert!(after[1] > 0.6, "coupling should drag var 1 up");
        // and removal restores independence
        e.remove_factor(id);
        g.remove_factor(id);
        e.reset_stats();
        e.run(200);
        e.reset_stats();
        e.run(8000);
        let restored = e.marginals();
        assert!((restored[1] - 0.5).abs() < 0.02);
    }

    use crate::graph::FactorGraph;

    #[test]
    fn overdispersed_init_patterns() {
        let g = workloads::ising_grid(2, 2, 0.1, 0.0);
        let mut e = PdEnsemble::new(&g, 3, 46);
        e.init_overdispersed();
        assert_eq!(e.chain_state(0), vec![0, 0, 0, 0]);
        assert_eq!(e.chain_state(1), vec![1, 1, 1, 1]);
        // K-state: all-0 / all-top, and clamped sites hold their evidence
        let mut g3 = FactorGraph::new_k(3, 4);
        g3.add_factor(PairFactor::potts(0, 1, 0.4));
        g3.add_factor(PairFactor::potts(1, 2, 0.4));
        let mut e3 = PdEnsemble::new(&g3, 3, 46);
        e3.clamp(1, 2).unwrap();
        e3.init_overdispersed();
        assert_eq!(e3.chain_state(0), vec![0, 2, 0]);
        assert_eq!(e3.chain_state(1), vec![3, 2, 3]);
        assert_eq!(e3.chain_state(2)[1], 2);
    }

    #[test]
    fn kstate_ensemble_marginals_and_clamping() {
        let mut g = FactorGraph::new_k(4, 3);
        for v in 0..4 {
            let beta = if v % 2 == 0 { 0.7 } else { -0.3 };
            g.add_factor(PairFactor::potts(v, (v + 1) % 4, beta));
        }
        let mut e = PdEnsemble::new(&g, 16, 48);
        assert_eq!(e.k(), 3);
        e.run(300);
        e.reset_stats();
        e.run(8_000);
        let got = e.marginals();
        assert_eq!(got.len(), 4 * 2, "flattened n·(k−1) convention");
        let want =
            crate::validation::marginals_from_joint_k(&crate::validation::joint_probs(&g), 4, 3);
        for (i, (&g_, &w)) in got.iter().zip(&want).enumerate() {
            assert!((g_ - w).abs() < 0.015, "entry {i}: {g_} vs exact {w}");
        }
        // clamping retargets the whole ensemble to the conditional law
        e.clamp(2, 1).unwrap();
        assert_eq!(e.clamped_count(), 1);
        assert!(e.clamp(2, 3).is_err(), "state ≥ k must be rejected");
        e.run(300);
        e.reset_stats();
        e.run(8_000);
        let cond = e.marginals();
        assert_eq!(cond[2 * 2], 1.0, "clamped site reports its evidence");
        assert_eq!(cond[2 * 2 + 1], 0.0);
        // exact conditional marginal of the free site 0 given x_2 = 1
        let probs = crate::validation::joint_probs(&g);
        let (mut z, mut m0) = (0.0f64, [0.0f64; 2]);
        for (code, &p) in probs.iter().enumerate() {
            let (s0, s2) = (code % 3, (code / 9) % 3);
            if s2 != 1 {
                continue;
            }
            z += p;
            if s0 > 0 {
                m0[s0 - 1] += p;
            }
        }
        for s in 0..2 {
            let w = m0[s] / z;
            assert!(
                (cond[s] - w).abs() < 0.02,
                "conditional entry {s}: {} vs exact {w}",
                cond[s]
            );
        }
        e.unclamp(2).unwrap();
        assert_eq!(e.clamped_count(), 0);
    }

    #[test]
    fn invalid_policy_is_an_error_not_a_panic() {
        use crate::duality::{BlockPolicy, MinibatchPolicy};
        let mut g = FactorGraph::new_k(3, 3);
        g.add_factor(PairFactor::potts(0, 1, 0.3));
        // degenerate knobs: a typed error, never a panic
        let r = PdEnsemble::try_with_policy(
            &g,
            4,
            7,
            SweepPolicy::Blocked(BlockPolicy { cap: 1, epoch: 16 }),
        );
        assert!(r.is_err(), "cap=1 blocking must be rejected");
        // formerly rejected: every policy now hosts K-state models
        for sweep in [
            SweepPolicy::Minibatch(MinibatchPolicy::default()),
            SweepPolicy::Blocked(BlockPolicy::default()),
        ] {
            let e = PdEnsemble::try_with_policy(&g, 4, 7, sweep)
                .unwrap_or_else(|err| panic!("{sweep} × k=3 must build: {err}"));
            assert_eq!(e.k(), 3);
        }
    }
}
