//! Multi-chain primal–dual ensemble with convergence monitoring.
//!
//! The paper's experiments run 10 chains and diagnose mixing via PSRF;
//! [`PdEnsemble`] is that harness as a first-class runtime object: chains
//! share one [`DualModel`] (updated incrementally under churn), sweeps run
//! chain-parallel on the pool, and per-sweep traces (magnetization plus a
//! monitored variable subset) feed [`crate::diagnostics`].

use std::sync::Arc;

use crate::diagnostics::{mixing_time_multi, MixingResult};
use crate::duality::DualModel;
use crate::graph::{FactorGraph, FactorId, PairFactor};
use crate::rng::{sigmoid, Pcg64, RngCore};
use crate::util::ThreadPool;

/// One chain's state.
#[derive(Clone, Debug)]
struct Chain {
    x: Vec<u8>,
    theta: Vec<u8>,
    rng: Pcg64,
}

/// N primal–dual chains over one shared dual model.
pub struct PdEnsemble {
    model: DualModel,
    chains: Vec<Chain>,
    pool: Option<Arc<ThreadPool>>,
    /// Variables whose per-sweep traces are recorded for PSRF.
    monitor: Vec<usize>,
    /// `traces[0]` = magnetization; `traces[1 + k]` = monitor var k.
    /// Layout: `traces[stat][chain][sweep]`.
    traces: Vec<Vec<Vec<f64>>>,
    /// Per-variable, per-chain sample sums since the last `reset_stats`.
    sums: Vec<Vec<f64>>,
    sweeps_done: usize,
    stat_sweeps: usize,
}

impl PdEnsemble {
    /// Build from a graph with `chains` chains seeded from `seed`.
    pub fn new(graph: &FactorGraph, chains: usize, seed: u64) -> Self {
        Self::from_model(DualModel::from_graph(graph), chains, seed)
    }

    pub fn from_model(model: DualModel, chains: usize, seed: u64) -> Self {
        assert!(chains >= 1);
        let base = Pcg64::seed(seed);
        let n = model.num_vars();
        let chains: Vec<Chain> = (0..chains)
            .map(|c| Chain {
                x: vec![0; n],
                theta: vec![0; model.factor_slots()],
                rng: base.split(c as u64 + 1),
            })
            .collect();
        let m = chains.len();
        Self {
            model,
            chains,
            pool: None,
            monitor: Vec::new(),
            traces: vec![vec![Vec::new(); m]],
            sums: vec![vec![0.0; n]; m],
            sweeps_done: 0,
            stat_sweeps: 0,
        }
    }

    /// Enable chain-parallel sweeps.
    pub fn with_pool(mut self, pool: Arc<ThreadPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Record per-sweep traces for these variables (PSRF monitors).
    pub fn monitor_vars(&mut self, vars: Vec<usize>) {
        self.monitor = vars;
        let m = self.chains.len();
        self.traces = vec![vec![Vec::new(); m]; 1 + self.monitor.len()];
    }

    /// Overdispersed initialization: chain c starts all-0 / all-1 / random.
    pub fn init_overdispersed(&mut self) {
        let n = self.model.num_vars();
        for (c, chain) in self.chains.iter_mut().enumerate() {
            match c % 3 {
                0 => chain.x.fill(0),
                1 => chain.x.fill(1),
                _ => {
                    for v in 0..n {
                        chain.x[v] = (chain.rng.next_u64() & 1) as u8;
                    }
                }
            }
            chain.theta.fill(0);
        }
    }

    pub fn num_chains(&self) -> usize {
        self.chains.len()
    }

    pub fn sweeps_done(&self) -> usize {
        self.sweeps_done
    }

    pub fn model(&self) -> &DualModel {
        &self.model
    }

    pub fn chain_state(&self, c: usize) -> &[u8] {
        &self.chains[c].x
    }

    // -- dynamic topology --------------------------------------------------

    /// O(degree) factor insertion shared by all chains (no recoloring).
    pub fn add_factor(&mut self, id: FactorId, f: &PairFactor) {
        self.model.insert_at(id, f);
        let slots = self.model.factor_slots();
        for chain in &mut self.chains {
            if chain.theta.len() < slots {
                chain.theta.resize(slots, 0);
            }
            chain.theta[id] = 0;
        }
    }

    /// O(degree) factor removal shared by all chains.
    pub fn remove_factor(&mut self, id: FactorId) {
        self.model.remove(id);
        for chain in &mut self.chains {
            if id < chain.theta.len() {
                chain.theta[id] = 0;
            }
        }
    }

    // -- sampling -----------------------------------------------------------

    fn sweep_chain(model: &DualModel, chain: &mut Chain) {
        let n = model.num_vars();
        for v in 0..n {
            let z = model.x_logodds(v, &chain.theta);
            chain.x[v] = chain.rng.bernoulli(sigmoid(z)) as u8;
        }
        for slot in 0..model.factor_slots() {
            if let Some(e) = model.entry(slot) {
                let z = model.theta_logodds(e, &chain.x);
                chain.theta[slot] = chain.rng.bernoulli(sigmoid(z)) as u8;
            }
        }
    }

    /// Advance every chain by `sweeps` sweeps, recording traces.
    pub fn run(&mut self, sweeps: usize) {
        for _ in 0..sweeps {
            match &self.pool {
                Some(pool) => {
                    let pool = Arc::clone(pool);
                    let model = &self.model;
                    let chains_ptr = SendPtr(self.chains.as_mut_ptr());
                    let m = self.chains.len();
                    pool.scope_chunks(m, |_, start, end| {
                        let chains_ptr = &chains_ptr;
                        for c in start..end {
                            // SAFETY: disjoint chain indices per chunk.
                            let chain = unsafe { &mut *chains_ptr.0.add(c) };
                            Self::sweep_chain(model, chain);
                        }
                    });
                }
                None => {
                    for chain in &mut self.chains {
                        Self::sweep_chain(&self.model, chain);
                    }
                }
            }
            self.record();
        }
    }

    fn record(&mut self) {
        self.sweeps_done += 1;
        self.stat_sweeps += 1;
        let n = self.model.num_vars() as f64;
        for (c, chain) in self.chains.iter().enumerate() {
            let mag = chain.x.iter().map(|&b| b as f64).sum::<f64>() / n;
            self.traces[0][c].push(mag);
            for (k, &v) in self.monitor.iter().enumerate() {
                self.traces[1 + k][c].push(chain.x[v] as f64);
            }
            for (s, &x) in self.sums[c].iter_mut().zip(&chain.x) {
                *s += x as f64;
            }
        }
    }

    /// Drop accumulated statistics and traces (e.g. after burn-in or a
    /// topology change, which shifts the target distribution).
    pub fn reset_stats(&mut self) {
        for stat in &mut self.traces {
            for t in stat.iter_mut() {
                t.clear();
            }
        }
        for s in &mut self.sums {
            s.fill(0.0);
        }
        self.stat_sweeps = 0;
    }

    /// PSRF-based mixing diagnosis over all monitored statistics.
    pub fn mixing(&self, threshold: f64, stride: usize) -> MixingResult {
        mixing_time_multi(&self.traces, threshold, stride)
    }

    /// Posterior marginal estimates pooled across chains since the last
    /// `reset_stats`.
    pub fn marginals(&self) -> Vec<f64> {
        let n = self.model.num_vars();
        let denom = (self.stat_sweeps * self.chains.len()) as f64;
        let mut out = vec![0.0; n];
        if denom == 0.0 {
            return out;
        }
        for chain_sums in &self.sums {
            for (o, &s) in out.iter_mut().zip(chain_sums) {
                *o += s;
            }
        }
        for o in &mut out {
            *o /= denom;
        }
        out
    }

    /// Magnetization traces (`[chain][sweep]`) — feed to diagnostics.
    pub fn magnetization_traces(&self) -> &[Vec<f64>] {
        &self.traces[0]
    }
}

struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::exact;
    use crate::workloads;

    #[test]
    fn ensemble_marginals_match_exact() {
        let g = workloads::ising_grid(3, 3, 0.3, 0.1);
        let mut e = PdEnsemble::new(&g, 8, 42);
        e.run(500); // burn-in
        e.reset_stats();
        e.run(15_000);
        let got = e.marginals();
        let want = exact::enumerate(&g).marginals;
        for v in 0..9 {
            assert!(
                (got[v] - want[v]).abs() < 0.01,
                "v={v}: {} vs {}",
                got[v],
                want[v]
            );
        }
    }

    #[test]
    fn parallel_pool_matches_exact() {
        let g = workloads::ising_grid(3, 3, 0.25, -0.05);
        let pool = Arc::new(ThreadPool::new(2));
        let mut e = PdEnsemble::new(&g, 8, 43).with_pool(pool);
        e.run(300);
        e.reset_stats();
        e.run(5_000);
        let got = e.marginals();
        let want = exact::enumerate(&g).marginals;
        for v in 0..9 {
            assert!((got[v] - want[v]).abs() < 0.02, "v={v}");
        }
    }

    #[test]
    fn mixing_monitor_reports() {
        let g = workloads::ising_grid(4, 4, 0.2, 0.0);
        let mut e = PdEnsemble::new(&g, 6, 44);
        e.monitor_vars(vec![0, 5, 15]);
        e.init_overdispersed();
        e.run(3000);
        let r = e.mixing(1.05, 50);
        assert!(r.mixing_time.is_some(), "weakly coupled grid must mix");
        assert!(r.final_psrf < 1.05);
    }

    #[test]
    fn dynamic_updates_shift_marginals() {
        let mut g = FactorGraph::new(2);
        g.set_unary(0, 2.0);
        let mut e = PdEnsemble::new(&g, 6, 45);
        e.run(200);
        e.reset_stats();
        e.run(8000);
        let before = e.marginals();
        assert!((before[1] - 0.5).abs() < 0.02, "uncoupled var near 1/2");
        // couple strongly to the biased variable
        let id = g.add_factor(PairFactor::ising(0, 1, 1.5));
        e.add_factor(id, g.factor(id).unwrap());
        e.reset_stats();
        e.run(200);
        e.reset_stats();
        e.run(12_000);
        let after = e.marginals();
        let want = exact::enumerate(&g).marginals;
        assert!(
            (after[1] - want[1]).abs() < 0.015,
            "{} vs {}",
            after[1],
            want[1]
        );
        assert!(after[1] > 0.6, "coupling should drag var 1 up");
        // and removal restores independence
        e.remove_factor(id);
        g.remove_factor(id);
        e.reset_stats();
        e.run(200);
        e.reset_stats();
        e.run(8000);
        let restored = e.marginals();
        assert!((restored[1] - 0.5).abs() < 0.02);
    }

    use crate::graph::FactorGraph;

    #[test]
    fn overdispersed_init_patterns() {
        let g = workloads::ising_grid(2, 2, 0.1, 0.0);
        let mut e = PdEnsemble::new(&g, 3, 46);
        e.init_overdispersed();
        assert_eq!(e.chain_state(0), &[0, 0, 0, 0]);
        assert_eq!(e.chain_state(1), &[1, 1, 1, 1]);
    }
}
