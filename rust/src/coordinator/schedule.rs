//! Deficit-round-robin background scheduler for multi-tenant shards.
//!
//! Between foreground requests a shard keeps sweeping its tenants — the
//! "sampling never stops" serving story — but tenants differ in size by
//! orders of magnitude, and one sweep of a 100k-factor tenant costs what
//! thousands of sweeps of a 100-factor tenant cost. Round-robin over
//! *sweeps* would hand the big tenant almost all the CPU; round-robin
//! over *tenants* with one sweep each would starve it instead. Classic
//! deficit round robin solves both: each tenant accrues `quantum` cost
//! credit per ring pass, a sweep debits the tenant's current per-sweep
//! cost ([`crate::duality::DualModel::sweep_cost`] site-visits), and
//! unspent credit carries as a *deficit* so even a tenant whose single
//! sweep exceeds the quantum makes progress every few passes.
//!
//! Over any window of full ring passes every enrolled tenant therefore
//! receives the same total cost budget (±1 sweep), which is the
//! fair-share guarantee the acceptance test asserts: a small tenant's
//! background sweep *count* is `cost_big / cost_small` times the big
//! tenant's, never starved below its share because a neighbor is huge.
//!
//! The scheduler is deliberately not wall-clock based: it is driven by
//! the shard loop calling [`DrrScheduler::next_slice`] whenever the
//! request queue is empty, so its decisions are a pure function of the
//! enroll/withdraw/cost history — deterministic and unit-testable.

use std::collections::{HashMap, VecDeque};

use super::tenant::TenantId;

/// One background grant: run `sweeps` sweeps of `tenant`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Slice {
    /// Tenant to sweep.
    pub tenant: TenantId,
    /// Granted sweep count (≥ 1).
    pub sweeps: usize,
}

/// Deficit-round-robin scheduler over enrolled tenants (see module docs).
pub struct DrrScheduler {
    /// Cost credit granted to each tenant per full ring pass.
    quantum: u64,
    /// Ring of enrolled tenants; front = next to serve.
    ring: VecDeque<TenantId>,
    /// Unspent credit per enrolled tenant.
    deficit: HashMap<TenantId, u64>,
}

impl DrrScheduler {
    /// `quantum` is the per-tenant cost budget per ring pass, in the same
    /// site-visit units as the cost callback. Larger quanta mean longer
    /// uninterrupted slices (better throughput, worse request latency).
    pub fn new(quantum: u64) -> Self {
        Self {
            quantum: quantum.max(1),
            ring: VecDeque::new(),
            deficit: HashMap::new(),
        }
    }

    /// Per-tenant cost budget per ring pass.
    pub fn quantum(&self) -> u64 {
        self.quantum
    }

    /// Number of enrolled tenants.
    pub fn len(&self) -> usize {
        self.ring.len()
    }

    /// Whether no tenants are enrolled.
    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Add a tenant to the ring (no-op if already enrolled). New tenants
    /// start with zero deficit: they receive their first credit when the
    /// ring reaches them, so a join/leave cycle cannot farm credit.
    pub fn enroll(&mut self, id: TenantId) {
        if !self.deficit.contains_key(&id) {
            self.deficit.insert(id, 0);
            self.ring.push_back(id);
        }
    }

    /// Remove a tenant (dropped or suspended); its unspent deficit is
    /// forfeited. No-op if not enrolled.
    pub fn withdraw(&mut self, id: TenantId) {
        if self.deficit.remove(&id).is_some() {
            self.ring.retain(|&t| t != id);
        }
    }

    /// Grant the next background slice. `cost` maps a tenant to its
    /// current per-sweep cost (≥ 1 enforced here).
    ///
    /// Visits tenants in ring order, crediting each `quantum` as it comes
    /// to the front; the first tenant whose deficit covers at least one
    /// sweep is granted `deficit / cost` sweeps and debited.
    ///
    /// **Progress guarantee** (the shard loop's no-hot-spin contract): if
    /// a full pass grants nothing — every enrolled tenant's sweep costs
    /// more than its accrued credit — the scheduler bulk-credits the
    /// *same* number of additional whole ring passes to every tenant
    /// (fairness is preserved: equal credit per tenant per pass), sized
    /// so the cheapest shortfall is covered, then grants in ring order.
    /// A call on a non-empty ring therefore returns `Some` in O(2 ring
    /// passes) instead of burning `ceil(cost/quantum)` idle poll
    /// iterations on the shard thread; `None` is only possible when the
    /// ring is empty or the cost callback changed its answer between the
    /// sizing pass and the grant pass (the shard loop falls back to a
    /// blocking `recv` in that case).
    pub fn next_slice(&mut self, mut cost: impl FnMut(TenantId) -> u64) -> Option<Slice> {
        if self.ring.is_empty() {
            return None;
        }
        // classic DRR pass: credit one quantum as each tenant reaches the
        // front, grant the first tenant that can afford a sweep
        let mut min_shortfall = u64::MAX;
        for _ in 0..self.ring.len() {
            let id = self.ring.pop_front().expect("ring non-empty in loop");
            self.ring.push_back(id);
            let d = self.deficit.get_mut(&id).expect("enrolled tenant has deficit");
            *d += self.quantum;
            let c = cost(id).max(1);
            let sweeps = (*d / c) as usize;
            if sweeps > 0 {
                *d -= sweeps as u64 * c;
                return Some(Slice { tenant: id, sweeps });
            }
            min_shortfall = min_shortfall.min(c - *d);
        }
        // nobody could afford one sweep: advance the clock by the number
        // of whole ring passes that covers the cheapest shortfall
        let passes = min_shortfall.div_ceil(self.quantum);
        for d in self.deficit.values_mut() {
            *d += passes * self.quantum;
        }
        for _ in 0..self.ring.len() {
            let id = self.ring.pop_front().expect("ring non-empty in loop");
            self.ring.push_back(id);
            let d = self.deficit.get_mut(&id).expect("enrolled tenant has deficit");
            let c = cost(id).max(1);
            let sweeps = (*d / c) as usize;
            if sweeps > 0 {
                *d -= sweeps as u64 * c;
                return Some(Slice { tenant: id, sweeps });
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Run `calls` grant attempts against fixed per-tenant costs,
    /// returning total sweeps granted per tenant.
    fn run(
        sched: &mut DrrScheduler,
        costs: &HashMap<TenantId, u64>,
        calls: usize,
    ) -> HashMap<TenantId, u64> {
        let mut sweeps: HashMap<TenantId, u64> = HashMap::new();
        for _ in 0..calls {
            if let Some(s) = sched.next_slice(|id| costs[&id]) {
                *sweeps.entry(s.tenant).or_insert(0) += s.sweeps as u64;
            }
        }
        sweeps
    }

    #[test]
    fn equal_costs_get_equal_sweeps() {
        let mut sched = DrrScheduler::new(100);
        let costs: HashMap<TenantId, u64> = (0..4).map(|t| (t, 10)).collect();
        for t in 0..4 {
            sched.enroll(t);
        }
        let sweeps = run(&mut sched, &costs, 40);
        for t in 0..4 {
            assert_eq!(sweeps[&t], 100, "tenant {t}: {sweeps:?}");
        }
    }

    #[test]
    fn fair_share_by_cost_with_50x_size_ratio() {
        // the acceptance scenario in miniature: tenant 0 is tiny (cost 45),
        // tenant 1 is ~50x larger (cost 2250). Over full rounds both must
        // receive the same *cost* budget, so the small tenant's sweep
        // count must sit near (cost_big / cost_small) x the big one's.
        let mut sched = DrrScheduler::new(4500);
        let costs: HashMap<TenantId, u64> = [(0, 45u64), (1, 2250u64)].into();
        sched.enroll(0);
        sched.enroll(1);
        let sweeps = run(&mut sched, &costs, 200);
        let (small, big) = (sweeps[&0], sweeps[&1]);
        let small_work = small * 45;
        let big_work = big * 2250;
        let ratio = small_work as f64 / big_work as f64;
        assert!(
            (0.9..=1.1).contains(&ratio),
            "cost budgets diverged: small {small} sweeps ({small_work}), \
             big {big} sweeps ({big_work})"
        );
        // and in sweep counts the small tenant gets ~50x more
        assert!(small > 40 * big, "small={small} big={big}");
    }

    #[test]
    fn expensive_tenant_never_overdraws() {
        // cost 250 with quantum 100: every call bulk-credits the
        // shortfall and grants exactly one sweep; across the run the
        // tenant spends exactly what it was credited (deficit ends 0,
        // no free sweeps from the progress guarantee)
        let mut sched = DrrScheduler::new(100);
        sched.enroll(7);
        let mut granted = Vec::new();
        for _ in 0..9 {
            let s = sched.next_slice(|_| 250).expect("non-empty ring always grants");
            granted.push(s.sweeps);
        }
        assert_eq!(granted.iter().sum::<usize>(), 9, "granted={granted:?}");
    }

    #[test]
    fn withdraw_forfeits_deficit_and_enroll_is_idempotent() {
        // quantum 10, cost 6: a fresh tenant's first grant is exactly one
        // sweep (10/6), leaving deficit 4; if that leftover survived a
        // withdraw/re-enroll cycle the next grant would be two sweeps
        // (14/6) — forfeiture means it is one again
        let mut sched = DrrScheduler::new(10);
        sched.enroll(1);
        sched.enroll(1);
        assert_eq!(sched.len(), 1);
        let s = sched.next_slice(|_| 6).unwrap();
        assert_eq!(s.sweeps, 1);
        sched.withdraw(1);
        assert!(sched.is_empty());
        sched.withdraw(1); // no-op
        sched.enroll(1);
        let s = sched.next_slice(|_| 6).unwrap();
        assert_eq!(s.sweeps, 1, "deficit must restart from zero after withdraw");
    }

    #[test]
    fn non_empty_ring_always_grants_and_stays_fair() {
        // the no-hot-spin contract: even when every tenant's sweep costs
        // far more than the quantum, each call returns Some — and the
        // bulk-credit path preserves the equal-cost-budget guarantee
        let mut sched = DrrScheduler::new(10);
        let costs: HashMap<TenantId, u64> = [(0, 1000u64), (1, 500u64)].into();
        sched.enroll(0);
        sched.enroll(1);
        let mut sweeps: HashMap<TenantId, u64> = HashMap::new();
        for _ in 0..100 {
            let s = sched.next_slice(|id| costs[&id]).expect("non-empty ring always grants");
            *sweeps.entry(s.tenant).or_insert(0) += s.sweeps as u64;
        }
        let (work0, work1) = (sweeps[&0] * 1000, sweeps[&1] * 500);
        let ratio = work0 as f64 / work1 as f64;
        assert!(
            (0.8..=1.25).contains(&ratio),
            "cost budgets diverged under bulk credit: {sweeps:?}"
        );
    }

    #[test]
    fn churned_cost_is_recharged_at_grant_time() {
        // the cost callback is consulted on every grant, so a tenant that
        // grew mid-run is charged its new price immediately
        let mut sched = DrrScheduler::new(100);
        sched.enroll(0);
        let s = sched.next_slice(|_| 10).unwrap();
        assert_eq!(s.sweeps, 10);
        let s = sched.next_slice(|_| 50).unwrap();
        assert_eq!(s.sweeps, 2);
    }

    #[test]
    fn zero_cost_is_clamped() {
        let mut sched = DrrScheduler::new(5);
        sched.enroll(0);
        let s = sched.next_slice(|_| 0).unwrap();
        assert_eq!(s.sweeps, 5, "cost clamps to 1, not a division by zero");
    }
}
