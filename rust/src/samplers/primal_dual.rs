//! The paper's contribution: the primal–dual Gibbs sampler (§5.1).
//!
//! One sweep is two fully-parallel half-steps on the dualized model:
//!
//!   `x_v  ~ Bernoulli(σ(a_v + Σ_{i∋v} θ_i β_{i,v}))`   for all v at once
//!   `θ_i  ~ Bernoulli(σ(q_i + β_{i,1} x_{v₁} + β_{i,2} x_{v₂}))`  for all i
//!
//! — the model has become a restricted Boltzmann machine. No graph
//! coloring, no preprocessing beyond one 2×2 factorization per factor,
//! and topology mutations are O(degree) ([`PdSampler::add_factor`] /
//! [`PdSampler::remove_factor`]).
//!
//! `sweep` is sequential-in-memory but order-independent; with a
//! [`ThreadPool`] attached ([`PdSampler::with_pool`]) both half-steps run
//! chunk-parallel, which is the CPU stand-in for the paper's GPU claim
//! (the TPU/XLA story lives in [`crate::runtime`]).
//!
//! This sampler deliberately stays on the model's *nested* reference
//! incidence (`DualModel::x_logodds` / `DualModel::entry`) and the exact
//! [`sigmoid`]: it is the readable baseline that the lane engine's flat
//! CSR arena, cached conditional tables, and fast-sigmoid draws
//! ([`crate::engine::LanePdSampler`]) are validated against.

use std::sync::Arc;

use super::Sampler;
use crate::duality::DualModel;
use crate::graph::{FactorGraph, FactorId, PairFactor};
use crate::rng::{sigmoid, Pcg64, RngCore};
use crate::util::ThreadPool;

/// Native (sparse, CPU) primal–dual Gibbs sampler.
pub struct PdSampler {
    model: DualModel,
    x: Vec<u8>,
    theta: Vec<u8>,
    pool: Option<Arc<ThreadPool>>,
    sweep_count: u64,
}

impl PdSampler {
    /// Dualize `graph` and start from the all-zeros state.
    pub fn new(graph: &FactorGraph) -> Self {
        Self::from_model(DualModel::from_graph(graph))
    }

    /// Wrap an existing dual model (shared with a coordinator).
    pub fn from_model(model: DualModel) -> Self {
        let x = vec![0; model.num_vars()];
        let theta = vec![0; model.factor_slots()];
        Self {
            model,
            x,
            theta,
            pool: None,
            sweep_count: 0,
        }
    }

    /// Enable chunk-parallel sweeps on the given pool.
    pub fn with_pool(mut self, pool: Arc<ThreadPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// The dualized model.
    pub fn model(&self) -> &DualModel {
        &self.model
    }

    /// Dual state (per factor slot; dead slots are meaningless but kept 0).
    pub fn theta(&self) -> &[u8] {
        &self.theta
    }

    /// Dynamic update: dualize + wire a new factor under the graph's id.
    /// O(1) amortized — no recoloring, no re-preprocessing.
    pub fn add_factor(&mut self, id: FactorId, f: &PairFactor) {
        self.model.insert_at(id, f);
        if self.theta.len() < self.model.factor_slots() {
            self.theta.resize(self.model.factor_slots(), 0);
        }
        self.theta[id] = 0;
    }

    /// Dynamic update: unwire a factor. O(degree of endpoints). Returns
    /// whether the slot was live (a dead/unknown id is a reported no-op,
    /// consistent with [`DualModel::remove`]); for a live slot the θ
    /// entry is always reset — the θ state can never be shorter than the
    /// model's slot space.
    pub fn remove_factor(&mut self, id: FactorId) -> bool {
        if self.model.remove(id).is_none() {
            return false;
        }
        assert!(
            id < self.theta.len(),
            "theta state shorter than the model's slot space (slot {id})"
        );
        self.theta[id] = 0;
        true
    }

    #[inline]
    fn x_half_step_range(&mut self, start: usize, end: usize, rng: &mut Pcg64) {
        for v in start..end {
            let z = self.model.x_logodds(v, &self.theta);
            self.x[v] = rng.bernoulli(sigmoid(z)) as u8;
        }
    }

    #[inline]
    fn theta_half_step_range(&mut self, start: usize, end: usize, rng: &mut Pcg64) {
        for slot in start..end {
            if let Some(e) = self.model.entry(slot) {
                let z = self.model.theta_logodds(e, &self.x);
                self.theta[slot] = rng.bernoulli(sigmoid(z)) as u8;
            }
        }
    }

    fn sweep_parallel(&mut self, rng: &mut Pcg64, pool: &ThreadPool) {
        // Stream-domain soundness: x-chunks draw from sweep·8192 + chunk
        // and θ-chunks from sweep·8192 + 4096 + chunk, so the two domains
        // stay disjoint iff the chunk count is ≤ 4096. `ThreadPool::new`
        // clamps to MAX_POOL_SIZE (= 4096) and `scope_chunks` never makes
        // more chunks than workers; assert the invariant anyway so any
        // future pool implementation cannot silently alias streams.
        assert!(
            pool.size() <= crate::util::threadpool::MAX_POOL_SIZE,
            "pool size {} exceeds the PD RNG stream domain (max {})",
            pool.size(),
            crate::util::threadpool::MAX_POOL_SIZE
        );
        let sweep = self.sweep_count;
        let n = self.x.len();
        let slots = self.model.factor_slots();
        let model = &self.model;

        // x | θ : disjoint chunks write x, read θ (frozen this half-step)
        {
            let theta = &self.theta;
            let x_ptr = SendPtr(self.x.as_mut_ptr());
            pool.scope_chunks(n, |chunk, start, end| {
                // disjoint stream domains: x-chunks at sweep·8192 + chunk
                let mut r = rng.split(sweep.wrapping_mul(8192) + chunk as u64);
                let x_ptr = &x_ptr;
                for v in start..end {
                    let z = model.x_logodds(v, theta);
                    // SAFETY: chunks own disjoint v ranges.
                    unsafe { *x_ptr.0.add(v) = r.bernoulli(sigmoid(z)) as u8 };
                }
            });
        }
        // θ | x : disjoint chunks write θ, read x
        {
            let x = &self.x;
            let t_ptr = SendPtr(self.theta.as_mut_ptr());
            pool.scope_chunks(slots, |chunk, start, end| {
                // θ-chunks at sweep·8192 + 4096 + chunk (never collides:
                // chunk count ≤ MAX_POOL_SIZE = 4096, asserted above)
                let mut r = rng.split(sweep.wrapping_mul(8192) + 4096 + chunk as u64);
                let t_ptr = &t_ptr;
                for slot in start..end {
                    if let Some(e) = model.entry(slot) {
                        let z = model.theta_logodds(e, x);
                        // SAFETY: chunks own disjoint slot ranges.
                        unsafe { *t_ptr.0.add(slot) = r.bernoulli(sigmoid(z)) as u8 };
                    }
                }
            });
        }
        // keep the caller's stream moving so repeated sweeps differ
        let _ = rng.next_u64();
    }
}

struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

impl Sampler for PdSampler {
    fn name(&self) -> &'static str {
        "primal-dual"
    }

    fn state(&self) -> &[u8] {
        &self.x
    }

    fn set_state(&mut self, x: &[u8]) {
        assert_eq!(x.len(), self.x.len());
        self.x.copy_from_slice(x);
    }

    fn sweep(&mut self, rng: &mut Pcg64) {
        self.sweep_count += 1;
        match self.pool.clone() {
            Some(pool) => self.sweep_parallel(rng, &pool),
            None => {
                self.x_half_step_range(0, self.x.len(), rng);
                self.theta_half_step_range(0, self.model.factor_slots(), rng);
            }
        }
    }

    /// One PD sweep updates every variable once (plus all duals); the
    /// primal update count is what Fig 2a/2b normalize by.
    fn updates_per_sweep(&self) -> usize {
        self.x.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::samplers::test_support::assert_matches_exact;
    use crate::workloads;

    #[test]
    fn exact_on_small_grid() {
        let g = workloads::ising_grid(3, 3, 0.3, 0.1);
        let mut s = PdSampler::new(&g);
        assert_matches_exact(&g, &mut s, 3, 1000, 120_000, 0.012);
    }

    #[test]
    fn exact_on_random_graph_with_negative_dets() {
        // anti-ferromagnetic couplings exercise the Lemma-4 swap path
        let mut g = FactorGraph::new(5);
        g.set_unary(0, 0.4);
        g.add_factor(PairFactor::ising(0, 1, -0.5));
        g.add_factor(PairFactor::ising(1, 2, 0.6));
        g.add_factor(PairFactor::ising(2, 3, -0.4));
        g.add_factor(PairFactor::ising(3, 4, 0.3));
        g.add_factor(PairFactor::ising(4, 0, -0.2));
        let mut s = PdSampler::new(&g);
        assert_matches_exact(&g, &mut s, 4, 1000, 120_000, 0.012);
    }

    use crate::graph::FactorGraph;

    #[test]
    fn parallel_sweeps_match_exact_too() {
        let g = workloads::ising_grid(3, 3, 0.25, 0.05);
        let pool = Arc::new(ThreadPool::new(2));
        let mut s = PdSampler::new(&g).with_pool(pool);
        // small budget: pooled dispatch dominates on single-core CI
        assert_matches_exact(&g, &mut s, 5, 500, 15_000, 0.035);
    }

    #[test]
    fn dynamic_add_remove_keeps_correctness() {
        // mutate the model mid-run, then verify against the mutated graph
        let mut g = workloads::ising_grid(2, 3, 0.3, 0.1);
        let mut s = PdSampler::new(&g);
        let mut rng = Pcg64::seed(6);
        for _ in 0..100 {
            s.sweep(&mut rng);
        }
        // add a diagonal factor and remove an existing one
        let added = g.add_factor(PairFactor::ising(0, 4, 0.5));
        s.add_factor(added, g.factor(added).unwrap());
        let victim = g.factors().next().unwrap().0;
        let removed = g.remove_factor(victim).unwrap();
        let _ = removed;
        s.remove_factor(victim);
        assert_matches_exact(&g, &mut s, 7, 1000, 120_000, 0.012);
    }

    #[test]
    fn updates_per_sweep_counts_primal_sites() {
        let g = workloads::ising_grid(4, 4, 0.2, 0.0);
        let s = PdSampler::new(&g);
        assert_eq!(s.updates_per_sweep(), 16);
    }

    #[test]
    fn theta_state_tracks_couplings() {
        // strong ferromagnetic coupling + aligned x ⇒ θ mostly 1
        let mut g = FactorGraph::new(2);
        g.add_factor(PairFactor::ising(0, 1, 2.0));
        let mut s = PdSampler::new(&g);
        s.set_state(&[1, 1]);
        let mut rng = Pcg64::seed(8);
        let mut ones = 0;
        for _ in 0..2000 {
            s.sweep(&mut rng);
            ones += s.theta()[0] as u64;
        }
        assert!(ones > 1000, "theta rarely active: {ones}");
    }
}
