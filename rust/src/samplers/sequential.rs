//! Sequential single-site Gibbs — the classic Geman–Geman sampler and the
//! paper's main mixing-time baseline (the "between 2 and 7×" of Fig 2a).

use super::Sampler;
use crate::graph::FactorGraph;
use crate::rng::{sigmoid, Pcg64, RngCore};

/// Single-site Gibbs over a borrowed graph (always up to date with
/// topology mutations — but inherently serial).
pub struct SequentialGibbs<'g> {
    graph: &'g FactorGraph,
    x: Vec<u8>,
}

impl<'g> SequentialGibbs<'g> {
    /// Start from the all-zeros state.
    pub fn new(graph: &'g FactorGraph) -> Self {
        Self {
            graph,
            x: vec![0; graph.num_vars()],
        }
    }
}

impl Sampler for SequentialGibbs<'_> {
    fn name(&self) -> &'static str {
        "sequential-gibbs"
    }

    fn state(&self) -> &[u8] {
        &self.x
    }

    fn set_state(&mut self, x: &[u8]) {
        assert_eq!(x.len(), self.x.len());
        self.x.copy_from_slice(x);
    }

    fn sweep(&mut self, rng: &mut Pcg64) {
        for v in 0..self.x.len() {
            let z = self.graph.conditional_logodds(v, &self.x);
            self.x[v] = rng.bernoulli(sigmoid(z)) as u8;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::samplers::test_support::assert_matches_exact;
    use crate::workloads;

    #[test]
    fn exact_on_small_grid() {
        let g = workloads::ising_grid(3, 3, 0.4, 0.15);
        let mut s = SequentialGibbs::new(&g);
        assert_matches_exact(&g, &mut s, 1, 500, 60_000, 0.012);
    }

    #[test]
    fn exact_on_random_graph() {
        let g = workloads::random_graph(7, 2, 0.8, 23);
        let mut s = SequentialGibbs::new(&g);
        assert_matches_exact(&g, &mut s, 2, 500, 80_000, 0.012);
    }

    #[test]
    fn respects_strong_field() {
        let mut g = workloads::ising_grid(2, 2, 0.1, 0.0);
        for v in 0..4 {
            g.set_unary(v, 6.0);
        }
        let mut s = SequentialGibbs::new(&g);
        let mut rng = Pcg64::seed(7);
        for _ in 0..50 {
            s.sweep(&mut rng);
        }
        assert_eq!(s.state(), &[1, 1, 1, 1]);
    }

    #[test]
    fn set_state_roundtrip() {
        let g = workloads::ising_grid(2, 3, 0.2, 0.0);
        let mut s = SequentialGibbs::new(&g);
        s.set_state(&[1, 0, 1, 0, 1, 0]);
        assert_eq!(s.state(), &[1, 0, 1, 0, 1, 0]);
        assert_eq!(s.updates_per_sweep(), 6);
    }
}
