//! Sequential K-state (Potts) Gibbs — the classical baseline for
//! categorical models, with evidence clamping.
//!
//! One site at a time, each resampled from its exact full conditional
//! via [`crate::graph::FactorGraph::conditional_scores_k`] (softmax over
//! the `k` states). Clamped sites are skipped but keep conditioning
//! their neighbors — the chain then targets the conditional law given
//! the evidence, the same contract as
//! [`crate::engine::LanePdSampler::clamp`]. On binary models this
//! reduces to the [`super::SequentialGibbs`] update order and law
//! (different RNG consumption, same kernel).

use crate::graph::FactorGraph;
use crate::rng::Pcg64;

use super::Sampler;

/// Sequential Gibbs over `{0..k}^n` with per-site clamp masks.
pub struct KStateGibbs<'g> {
    graph: &'g FactorGraph,
    x: Vec<u8>,
    clamped: Vec<bool>,
    scores: Vec<f64>,
}

impl<'g> KStateGibbs<'g> {
    /// All-zeros initial state, nothing clamped.
    pub fn new(graph: &'g FactorGraph) -> Self {
        Self {
            x: vec![0; graph.num_vars()],
            clamped: vec![false; graph.num_vars()],
            scores: vec![0.0; graph.k()],
            graph,
        }
    }
}

impl Sampler for KStateGibbs<'_> {
    fn name(&self) -> &'static str {
        "kstate-gibbs"
    }

    fn state(&self) -> &[u8] {
        &self.x
    }

    fn set_state(&mut self, x: &[u8]) {
        assert_eq!(x.len(), self.x.len());
        let k = self.graph.k();
        for (v, (dst, &src)) in self.x.iter_mut().zip(x).enumerate() {
            assert!((src as usize) < k, "state {src} out of range at site {v}");
            if !self.clamped[v] {
                *dst = src;
            }
        }
    }

    fn k(&self) -> usize {
        self.graph.k()
    }

    fn clamp(&mut self, v: usize, state: u8) -> bool {
        if v >= self.x.len() || state as usize >= self.graph.k() {
            return false;
        }
        self.x[v] = state;
        self.clamped[v] = true;
        true
    }

    fn sweep(&mut self, rng: &mut Pcg64) {
        let k = self.graph.k();
        for v in 0..self.x.len() {
            if self.clamped[v] {
                continue;
            }
            self.graph.conditional_scores_k(v, &self.x, &mut self.scores);
            let mx = self.scores.iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b));
            let mut z = 0.0;
            for s in self.scores.iter_mut() {
                *s = (*s - mx).exp();
                z += *s;
            }
            let u = rng.next_f64() * z;
            let mut acc = 0.0;
            let mut choice = k - 1; // top state absorbs rounding
            for (s, &w) in self.scores.iter().enumerate() {
                acc += w;
                if u < acc {
                    choice = s;
                    break;
                }
            }
            self.x[v] = choice as u8;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::PairFactor;
    use crate::validation::{joint_probs, marginals_from_joint_k};

    fn potts_ring(k: usize, n: usize) -> FactorGraph {
        let mut g = FactorGraph::new_k(n, k);
        for v in 0..n {
            let beta = if v % 2 == 0 { 0.6 } else { -0.4 };
            g.add_factor(PairFactor::potts(v, (v + 1) % n, beta));
        }
        g
    }

    fn empirical_k(
        s: &mut KStateGibbs,
        rng: &mut Pcg64,
        burn: usize,
        sweeps: usize,
    ) -> Vec<f64> {
        for _ in 0..burn {
            s.sweep(rng);
        }
        let (n, k) = (s.state().len(), s.k());
        let mut acc = vec![0.0f64; n * (k - 1)];
        for _ in 0..sweeps {
            s.sweep(rng);
            for (v, &xv) in s.state().iter().enumerate() {
                if xv > 0 {
                    acc[v * (k - 1) + (xv as usize - 1)] += 1.0;
                }
            }
        }
        for a in &mut acc {
            *a /= sweeps as f64;
        }
        acc
    }

    #[test]
    fn matches_exact_potts_marginals() {
        let g = potts_ring(3, 5);
        let want = marginals_from_joint_k(&joint_probs(&g), 5, 3);
        let mut s = KStateGibbs::new(&g);
        let mut rng = Pcg64::seed(7);
        let got = empirical_k(&mut s, &mut rng, 500, 60_000);
        for (e, (&g_, &w)) in got.iter().zip(&want).enumerate() {
            assert!((g_ - w).abs() < 0.01, "entry {e}: {g_} vs exact {w}");
        }
    }

    #[test]
    fn clamped_sites_hold_and_condition() {
        let g = potts_ring(3, 5);
        let mut s = KStateGibbs::new(&g);
        assert!(s.clamp(0, 2));
        assert!(!s.clamp(0, 3), "state ≥ k must be rejected");
        assert!(!s.clamp(9, 0), "unknown site must be rejected");
        // set_state must not move the evidence
        s.set_state(&[1, 1, 1, 1, 1]);
        assert_eq!(s.state()[0], 2);
        // exact conditional marginals given x_0 = 2, free sites only
        let probs = joint_probs(&g);
        let mut cond = vec![0.0f64; 5 * 2];
        let mut z = 0.0;
        for (code, &p) in probs.iter().enumerate() {
            let mut c = code;
            let x: Vec<u8> = (0..5)
                .map(|_| {
                    let d = (c % 3) as u8;
                    c /= 3;
                    d
                })
                .collect();
            if x[0] != 2 {
                continue;
            }
            z += p;
            for (v, &xv) in x.iter().enumerate() {
                if xv > 0 {
                    cond[v * 2 + (xv as usize - 1)] += p;
                }
            }
        }
        for c in &mut cond {
            *c /= z;
        }
        let mut rng = Pcg64::seed(11);
        let got = empirical_k(&mut s, &mut rng, 500, 60_000);
        for (e, (&g_, &w)) in got.iter().zip(&cond).enumerate() {
            assert!((g_ - w).abs() < 0.01, "entry {e}: {g_} vs conditional {w}");
        }
    }

    #[test]
    fn binary_sampler_defaults_report_no_clamping() {
        // the trait defaults: binary baselines expose k = 2, clamp = false
        let g = crate::workloads::ising_grid(2, 2, 0.2, 0.0);
        let mut s = super::super::SequentialGibbs::new(&g);
        assert_eq!(Sampler::k(&s), 2);
        assert!(!Sampler::clamp(&mut s, 0, 1));
    }
}
