//! §5.4: blocked primal–dual Gibbs over *arbitrary* subgraphs.
//!
//! Split the duals into `θ₀` (tree factors — dropped from the state and
//! kept primal) and `θ₁` (off-tree factors — kept dual). Because
//!
//!   `p(x, θ₀ | θ₁) = p(θ₀ | x) · p(x | θ₁)`,
//!
//! it suffices to alternate `x ~ p(x | θ₁)` — an *exact joint draw* over
//! all tree variables via forward-filter backward-sample — and
//! `θ₁ ~ p(θ₁ | x)`. Unlike splash sampling [Gonzalez et al. 2011] the
//! conditioning set is *not* restricted to induced subgraphs: any acyclic
//! factor subset works, including spanning trees touching every variable.
//!
//! The tree can be re-drawn between sweeps ([`BlockedPd::refresh_tree`]),
//! the "vary the decomposition in each step" variant from the paper.

use super::Sampler;
use crate::duality::DualModel;
use crate::graph::{FactorGraph, FactorId};
use crate::inference::bp::Forest;
use crate::rng::{sigmoid, Pcg64, RngCore};

/// Tree-blocked primal–dual sampler over a borrowed graph.
pub struct BlockedPd<'g> {
    graph: &'g FactorGraph,
    model: DualModel,
    forest: Forest,
    /// Slots participating in the tree (their duals are marginalized out).
    tree_mask: Vec<bool>,
    /// `Σ_{tree i ∋ v} α_{i,v}` — subtracted from the dual base field when
    /// building tree vertex potentials (tree factors enter as full edge
    /// potentials instead).
    tree_alpha: Vec<f64>,
    x: Vec<u8>,
    theta: Vec<u8>,
}

impl<'g> BlockedPd<'g> {
    /// Block over a greedy spanning forest of the current graph.
    pub fn new(graph: &'g FactorGraph) -> Self {
        let ids = Forest::spanning_ids(graph);
        Self::with_tree(graph, &ids)
    }

    /// Block over an explicit acyclic factor subset.
    pub fn with_tree(graph: &'g FactorGraph, tree_ids: &[FactorId]) -> Self {
        let model = DualModel::from_graph(graph);
        let forest = Forest::from_factors(graph, tree_ids)
            .unwrap_or_else(|id| panic!("tree subset contains a cycle at factor {id}"));
        let mut tree_mask = vec![false; model.factor_slots()];
        let mut tree_alpha = vec![0.0; graph.num_vars()];
        for &id in tree_ids {
            tree_mask[id] = true;
            let e = model.entry(id).expect("tree id not in model");
            tree_alpha[e.v1] += e.alpha1;
            tree_alpha[e.v2] += e.alpha2;
        }
        let x = vec![0; graph.num_vars()];
        let theta = vec![0; model.factor_slots()];
        Self {
            graph,
            model,
            forest,
            tree_mask,
            tree_alpha,
            x,
            theta,
        }
    }

    /// Redraw the blocking tree (randomized spanning forest): shuffles the
    /// live factors and keeps the first acyclic subset.
    pub fn refresh_tree(&mut self, rng: &mut Pcg64) {
        let mut ids: Vec<FactorId> = self.graph.factors().map(|(id, _)| id).collect();
        rng.shuffle(&mut ids);
        let mut uf = crate::util::UnionFind::new(self.graph.num_vars());
        let tree_ids: Vec<FactorId> = ids
            .into_iter()
            .filter(|&id| {
                let f = self.graph.factor(id).unwrap();
                uf.union(f.v1, f.v2)
            })
            .collect();
        self.forest = Forest::from_factors(self.graph, &tree_ids).expect("forest is acyclic");
        self.tree_mask.iter_mut().for_each(|m| *m = false);
        self.tree_alpha.iter_mut().for_each(|a| *a = 0.0);
        for &id in &tree_ids {
            self.tree_mask[id] = true;
            let e = self.model.entry(id).unwrap();
            self.tree_alpha[e.v1] += e.alpha1;
            self.tree_alpha[e.v2] += e.alpha2;
        }
    }

    /// Number of factors currently blocked into the tree.
    pub fn tree_size(&self) -> usize {
        self.tree_mask.iter().filter(|&&m| m).count()
    }

    fn fields(&self) -> Vec<f64> {
        (0..self.x.len())
            .map(|v| {
                let mut z = self.model.base_field(v) - self.tree_alpha[v];
                for &(slot, beta) in self.model.incidence(v) {
                    if !self.tree_mask[slot as usize] {
                        z += self.theta[slot as usize] as f64 * beta;
                    }
                }
                z
            })
            .collect()
    }
}

impl Sampler for BlockedPd<'_> {
    fn name(&self) -> &'static str {
        "blocked-pd"
    }

    fn state(&self) -> &[u8] {
        &self.x
    }

    fn set_state(&mut self, x: &[u8]) {
        assert_eq!(x.len(), self.x.len());
        self.x.copy_from_slice(x);
    }

    fn sweep(&mut self, rng: &mut Pcg64) {
        // θ₁ | x : off-tree duals, all in parallel
        for slot in 0..self.model.factor_slots() {
            if self.tree_mask[slot] {
                continue;
            }
            if let Some(e) = self.model.entry(slot) {
                let z = self.model.theta_logodds(e, &self.x);
                self.theta[slot] = rng.bernoulli(sigmoid(z)) as u8;
            }
        }
        // x | θ₁ : exact joint draw over the tree
        let fields = self.fields();
        self.x = self.forest.sample(&fields, rng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::samplers::test_support::assert_matches_exact;
    use crate::workloads;

    #[test]
    fn exact_on_cyclic_grid() {
        // 3x3 grid has cycles; spanning tree blocks 8 of 12 factors
        let g = workloads::ising_grid(3, 3, 0.4, 0.1);
        let mut s = BlockedPd::new(&g);
        assert_eq!(s.tree_size(), 8);
        assert_matches_exact(&g, &mut s, 31, 300, 60_000, 0.012);
    }

    #[test]
    fn exact_on_tree_degenerates_to_exact_sampling() {
        // every factor blocked ⇒ independent exact draws each sweep
        let g = workloads::random_tree(8, 0.9, 5);
        let mut s = BlockedPd::new(&g);
        assert_eq!(s.tree_size(), 7);
        assert_matches_exact(&g, &mut s, 32, 0, 40_000, 0.012);
    }

    #[test]
    fn exact_with_refreshed_trees() {
        let g = workloads::ising_grid(3, 3, 0.35, -0.1);
        let mut s = BlockedPd::new(&g);
        let mut rng = Pcg64::seed(33);
        // interleave tree refreshes with sampling
        let mut acc = vec![0.0f64; 9];
        let (burn, keep) = (300usize, 60_000usize);
        for _ in 0..burn {
            s.sweep(&mut rng);
        }
        for it in 0..keep {
            if it % 64 == 0 {
                s.refresh_tree(&mut rng);
            }
            s.sweep(&mut rng);
            for (a, &x) in acc.iter_mut().zip(s.state()) {
                *a += x as f64;
            }
        }
        let want = crate::inference::exact::enumerate(&g);
        for v in 0..9 {
            let got = acc[v] / keep as f64;
            assert!(
                (got - want.marginals[v]).abs() < 0.012,
                "v={v}: {got} vs {}",
                want.marginals[v]
            );
        }
    }

    #[test]
    fn exact_on_fully_connected() {
        // dense graph: tree blocks n-1 of n(n-1)/2 factors
        let g = workloads::fully_connected_ising(6, |i, j| 0.05 * ((i + j) % 3 + 1) as f64);
        let mut s = BlockedPd::new(&g);
        assert_eq!(s.tree_size(), 5);
        assert_matches_exact(&g, &mut s, 34, 300, 60_000, 0.012);
    }

    #[test]
    #[should_panic(expected = "cycle")]
    fn rejects_cyclic_tree_subset() {
        let g = workloads::ising_grid(2, 2, 0.3, 0.0);
        let ids: Vec<_> = g.factors().map(|(id, _)| id).collect();
        BlockedPd::with_tree(&g, &ids); // all 4 factors = the 4-cycle
    }
}
