//! Chromatic parallel Gibbs [Gonzalez et al. 2011] — the coloring baseline.
//!
//! Variables of one color class are conditionally independent, so each
//! class is resampled in parallel; sweeping all classes gives a valid
//! Gibbs sweep with the *same* per-sweep Markov kernel quality as the
//! sequential sampler (per class-order). Its weakness — the one the paper
//! attacks — is the coloring itself: NP-hard to minimize, needs repair on
//! every topology change ([`ChromaticGibbs::refresh_coloring`], whose cost
//! the dynamic bench reports), and useless on dense graphs where the
//! chromatic number approaches `n` (Fig 2b's fully connected model).

use std::sync::Arc;

use super::Sampler;
use crate::graph::coloring::{self, Coloring};
use crate::graph::FactorGraph;
use crate::rng::{sigmoid, Pcg64, RngCore};
use crate::util::ThreadPool;

/// Color-blocked parallel Gibbs over a borrowed graph.
pub struct ChromaticGibbs<'g> {
    graph: &'g FactorGraph,
    coloring: Coloring,
    classes: Vec<Vec<usize>>,
    x: Vec<u8>,
    pool: Option<Arc<ThreadPool>>,
    sweep_count: u64,
    /// Cumulative variables recolored by repair (maintenance cost metric).
    pub repair_touched: usize,
}

impl<'g> ChromaticGibbs<'g> {
    /// Greedily color `graph` and start from the all-zeros state.
    pub fn new(graph: &'g FactorGraph) -> Self {
        let coloring = coloring::greedy(graph);
        let classes = coloring.classes();
        Self {
            graph,
            coloring,
            classes,
            x: vec![0; graph.num_vars()],
            pool: None,
            sweep_count: 0,
            repair_touched: 0,
        }
    }

    /// Enable color-class-parallel sweeps on the given pool.
    pub fn with_pool(mut self, pool: Arc<ThreadPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Number of color classes in the current coloring.
    pub fn num_colors(&self) -> u32 {
        self.coloring.num_colors
    }

    /// Repair the coloring after graph mutations; returns variables touched.
    /// Must be called before `sweep` whenever the topology changed — the
    /// dynamic benchmark charges this to the chromatic baseline.
    pub fn refresh_coloring(&mut self) -> usize {
        if self.coloring.version == self.graph.version() {
            return 0;
        }
        let touched = coloring::repair(self.graph, &mut self.coloring);
        self.classes = self.coloring.classes();
        self.repair_touched += touched;
        touched
    }

    fn sweep_class_parallel(&mut self, class_idx: usize, rng: &mut Pcg64, pool: &ThreadPool) {
        let class = &self.classes[class_idx];
        let graph = self.graph;
        let sweep = self.sweep_count;
        let x_ptr = SendPtr(self.x.as_mut_ptr());
        let x_ref = &self.x;
        pool.scope_chunks(class.len(), |chunk, start, end| {
            let mut r = rng.split(
                sweep.wrapping_mul(1 << 20) + (class_idx as u64) * 4096 + chunk as u64,
            );
            let x_ptr = &x_ptr;
            for &v in &class[start..end] {
                // SAFETY: same-color variables are never neighbors, so the
                // cells written here are disjoint from every cell read.
                let z = graph.conditional_logodds(v, x_ref);
                unsafe { *x_ptr.0.add(v) = r.bernoulli(sigmoid(z)) as u8 };
            }
        });
    }
}

struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

impl Sampler for ChromaticGibbs<'_> {
    fn name(&self) -> &'static str {
        "chromatic-gibbs"
    }

    fn state(&self) -> &[u8] {
        &self.x
    }

    fn set_state(&mut self, x: &[u8]) {
        assert_eq!(x.len(), self.x.len());
        self.x.copy_from_slice(x);
    }

    fn sweep(&mut self, rng: &mut Pcg64) {
        debug_assert!(
            self.coloring.version == self.graph.version(),
            "stale coloring: call refresh_coloring() after mutating the graph"
        );
        self.sweep_count += 1;
        match self.pool.clone() {
            Some(pool) => {
                for ci in 0..self.classes.len() {
                    self.sweep_class_parallel(ci, rng, &pool);
                }
            }
            None => {
                for class in &self.classes {
                    for &v in class {
                        let z = self.graph.conditional_logodds(v, &self.x);
                        self.x[v] = rng.bernoulli(sigmoid(z)) as u8;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::PairFactor;
    use crate::samplers::test_support::assert_matches_exact;
    use crate::workloads;

    #[test]
    fn exact_on_small_grid() {
        let g = workloads::ising_grid(3, 3, 0.35, 0.1);
        let mut s = ChromaticGibbs::new(&g);
        assert_eq!(s.num_colors(), 2);
        assert_matches_exact(&g, &mut s, 11, 500, 80_000, 0.012);
    }

    #[test]
    fn exact_with_pool() {
        let g = workloads::ising_grid(3, 3, 0.3, -0.1);
        let pool = Arc::new(ThreadPool::new(2));
        let mut s = ChromaticGibbs::new(&g).with_pool(pool);
        // pooled dispatch is per color class per sweep: keep the budget
        // small (single-core CI) and the tolerance correspondingly wide
        assert_matches_exact(&g, &mut s, 12, 300, 12_000, 0.035);
    }

    #[test]
    fn refresh_after_mutation() {
        let mut g = workloads::ising_grid(3, 3, 0.2, 0.0);
        {
            let mut s = ChromaticGibbs::new(&g);
            assert_eq!(s.refresh_coloring(), 0); // up to date
        }
        // mutate: a diagonal edge breaks the checkerboard 2-coloring
        g.add_factor(PairFactor::ising(0, 4, 0.2));
        let s2 = ChromaticGibbs::new(&g);
        assert!(s2.coloring.is_proper(&g));
        assert!(s2.num_colors() >= 3);
    }

    #[test]
    fn fully_connected_uses_n_colors() {
        let g = workloads::fully_connected_ising(8, |_, _| 0.05);
        let s = ChromaticGibbs::new(&g);
        // n colors ⇒ zero within-sweep parallelism: the Fig-2b pathology
        assert_eq!(s.num_colors(), 8);
    }
}
