//! Swendsen–Wang cluster sampler — the §4.3 degenerate special case.
//!
//! Applies to ferromagnetic Ising factors (`w ≥ 0` in the normal form
//! `[[1, e^{-w}], [e^{-w}, 1]]`). One sweep:
//!
//! 1. bond step: for each factor with agreeing endpoints, activate with
//!    probability `1 − e^{-w}` (the paper's `g(1)`),
//! 2. cluster step: connected components of active bonds flip jointly;
//!    with unary fields `u_v` a cluster `C` is set to 1 with probability
//!    `σ(Σ_{v∈C} u_v)`.
//!
//! Implemented with union-find; serves as the mixing reference on the
//! low-field Ising workloads and validates the §4.3 equivalence claim.

use super::Sampler;
use crate::duality::sw;
use crate::graph::FactorGraph;
use crate::rng::{sigmoid, Pcg64, RngCore};
use crate::util::UnionFind;

/// Cluster sampler over a borrowed ferromagnetic-Ising graph.
pub struct SwendsenWang<'g> {
    graph: &'g FactorGraph,
    /// `(v1, v2, bond probability)` per applicable factor.
    bonds: Vec<(usize, usize, f64)>,
    x: Vec<u8>,
    /// Cluster count of the last sweep (`C(θ)` in Example 1, used by the
    /// §5.2 SW log-partition estimator).
    pub last_cluster_count: usize,
}

impl<'g> SwendsenWang<'g> {
    /// Panics if any factor is not a symmetric ferromagnetic Ising table.
    pub fn new(graph: &'g FactorGraph) -> Self {
        let bonds = graph
            .factors()
            .map(|(_, f)| {
                let w = sw::ising_w_from_table(&f.table).unwrap_or_else(|| {
                    panic!("SW requires ferromagnetic Ising factors, got {:?}", f.table)
                });
                (f.v1, f.v2, sw::bond_probability(w))
            })
            .collect();
        Self {
            graph,
            bonds,
            x: vec![0; graph.num_vars()],
            last_cluster_count: 0,
        }
    }
}

impl Sampler for SwendsenWang<'_> {
    fn name(&self) -> &'static str {
        "swendsen-wang"
    }

    fn state(&self) -> &[u8] {
        &self.x
    }

    fn set_state(&mut self, x: &[u8]) {
        assert_eq!(x.len(), self.x.len());
        self.x.copy_from_slice(x);
    }

    fn sweep(&mut self, rng: &mut Pcg64) {
        let n = self.x.len();
        // bond step (θ | x)
        let mut uf = UnionFind::new(n);
        for &(v1, v2, p) in &self.bonds {
            if self.x[v1] == self.x[v2] && rng.bernoulli(p) {
                uf.union(v1, v2);
            }
        }
        self.last_cluster_count = uf.components();
        // cluster step (x | θ): field-weighted fair flips per component
        let mut cluster_field = std::collections::BTreeMap::new();
        for v in 0..n {
            *cluster_field.entry(uf.find(v)).or_insert(0.0) += self.graph.unary(v);
        }
        let assignment: std::collections::BTreeMap<usize, u8> = cluster_field
            .into_iter()
            .map(|(root, field)| (root, rng.bernoulli(sigmoid(field)) as u8))
            .collect();
        for v in 0..n {
            self.x[v] = assignment[&uf.find(v)];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::samplers::test_support::assert_matches_exact;
    use crate::workloads;

    #[test]
    fn exact_on_small_grid_no_field() {
        let g = workloads::ising_grid(3, 3, 0.4, 0.0);
        let mut s = SwendsenWang::new(&g);
        assert_matches_exact(&g, &mut s, 21, 200, 60_000, 0.012);
    }

    #[test]
    fn exact_with_fields() {
        let g = workloads::ising_grid(3, 3, 0.3, 0.4);
        let mut s = SwendsenWang::new(&g);
        assert_matches_exact(&g, &mut s, 22, 200, 60_000, 0.012);
    }

    #[test]
    fn mixes_at_strong_coupling() {
        // β = 1.0 grid: single-site Gibbs freezes; SW still flips global
        // magnetization. Check both magnetization signs are visited.
        let g = workloads::ising_grid(5, 5, 1.0, 0.0);
        let mut s = SwendsenWang::new(&g);
        let mut rng = Pcg64::seed(23);
        let mut saw_low = false;
        let mut saw_high = false;
        for _ in 0..2000 {
            s.sweep(&mut rng);
            let m: f64 = s.state().iter().map(|&b| b as f64).sum::<f64>() / 25.0;
            saw_low |= m < 0.2;
            saw_high |= m > 0.8;
        }
        assert!(saw_low && saw_high, "SW failed to tunnel between modes");
    }

    #[test]
    fn cluster_count_reasonable() {
        let g = workloads::ising_grid(4, 4, 0.05, 0.0);
        let mut s = SwendsenWang::new(&g);
        let mut rng = Pcg64::seed(24);
        s.sweep(&mut rng);
        // weak coupling ⇒ few bonds ⇒ many clusters
        assert!(s.last_cluster_count > 8, "{}", s.last_cluster_count);
    }

    #[test]
    #[should_panic(expected = "ferromagnetic")]
    fn rejects_antiferromagnetic() {
        let g = workloads::ising_grid(2, 2, -0.3, 0.0);
        SwendsenWang::new(&g);
    }
}
