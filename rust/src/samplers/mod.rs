//! Samplers: the paper's primal–dual method and every baseline it is
//! evaluated against.
//!
//! | sampler | parallel unit | preprocessing | dynamic graphs |
//! |---|---|---|---|
//! | [`SequentialGibbs`] | none (one site at a time) | none | trivial |
//! | [`ChromaticGibbs`]  | color class | graph coloring (NP-hard to minimize, must be *maintained*) | expensive |
//! | [`PdSampler`]       | **all variables / all factors** | one 2×2 factorization per factor | O(1) per mutation |
//! | [`SwendsenWang`]    | clusters | none (ferromagnetic Ising only) | trivial |
//! | [`BlockedPd`]       | tree + off-tree duals | spanning forest | cheap refresh |
//! | [`KStateGibbs`]     | none (K-state sequential baseline) | none | trivial |
//!
//! All samplers implement [`Sampler`]: a state vector in `{0,1}^n`
//! (`{0..k}^n` for K-state samplers) advanced by full sweeps. RNGs are
//! passed per sweep so multi-chain drivers control reproducibility and
//! stream independence.
//!
//! Running *many chains* of the primal–dual sampler is better served by
//! [`crate::engine::LanePdSampler`], which bit-packs 64 chains per word
//! over one shared dual model instead of looping scalar samplers.

mod blocked;
mod chromatic;
mod kstate;
mod primal_dual;
mod sequential;
mod swendsen_wang;

pub use blocked::BlockedPd;
pub use chromatic::ChromaticGibbs;
pub use kstate::KStateGibbs;
pub use primal_dual::PdSampler;
pub use sequential::SequentialGibbs;
pub use swendsen_wang::SwendsenWang;

use crate::rng::Pcg64;

/// A Markov-chain sampler over discrete states (binary unless the
/// sampler overrides [`Sampler::k`]).
pub trait Sampler {
    /// Human-readable name for reports.
    fn name(&self) -> &'static str;

    /// Current primal state (`x[v] ∈ {0, 1}`, or `{0..k}` for K-state
    /// samplers).
    fn state(&self) -> &[u8];

    /// Overwrite the primal state (chain initialization). Clamped sites
    /// keep their evidence value.
    fn set_state(&mut self, x: &[u8]);

    /// States per variable of the sampled model (2 = binary).
    fn k(&self) -> usize {
        2
    }

    /// Clamp site `v` to evidence `state`: skip its draws while it keeps
    /// conditioning its neighbors. Returns `false` when the sampler does
    /// not support clamping (the binary baselines) or the target is out
    /// of range.
    fn clamp(&mut self, v: usize, state: u8) -> bool {
        let _ = (v, state);
        false
    }

    /// Advance one full sweep (every variable updated once, by whatever
    /// schedule the sampler defines).
    fn sweep(&mut self, rng: &mut Pcg64);

    /// Single-site-equivalent updates per sweep (Fig 2b normalizes the
    /// sequential sampler by this).
    fn updates_per_sweep(&self) -> usize {
        self.state().len()
    }
}

/// Run `sweeps` sweeps and collect the per-sweep trace of monitored
/// variables (diagnostics helper shared by benches and tests).
pub fn run_traced(
    sampler: &mut dyn Sampler,
    rng: &mut Pcg64,
    sweeps: usize,
    monitor: &[usize],
) -> Vec<Vec<f64>> {
    let mut traces = vec![Vec::with_capacity(sweeps); monitor.len()];
    for _ in 0..sweeps {
        sampler.sweep(rng);
        let x = sampler.state();
        for (ti, &v) in monitor.iter().enumerate() {
            traces[ti].push(x[v] as f64);
        }
    }
    traces
}

/// Empirical `P(x_v = 1)` from `sweeps` post-burn-in sweeps.
pub fn empirical_marginals(
    sampler: &mut dyn Sampler,
    rng: &mut Pcg64,
    burn_in: usize,
    sweeps: usize,
) -> Vec<f64> {
    for _ in 0..burn_in {
        sampler.sweep(rng);
    }
    let n = sampler.state().len();
    let mut acc = vec![0.0f64; n];
    for _ in 0..sweeps {
        sampler.sweep(rng);
        for (a, &x) in acc.iter_mut().zip(sampler.state()) {
            *a += x as f64;
        }
    }
    for a in &mut acc {
        *a /= sweeps as f64;
    }
    acc
}

#[cfg(test)]
pub(crate) mod test_support {
    //! Shared correctness harness: every sampler must reproduce exact
    //! marginals on small models (the definitive Markov-kernel test).
    use super::*;
    use crate::graph::FactorGraph;
    use crate::inference::exact;

    /// Burn in, sample, and assert empirical marginals match the exact oracle within `tol`.
    pub fn assert_matches_exact(
        g: &FactorGraph,
        sampler: &mut dyn Sampler,
        seed: u64,
        burn_in: usize,
        sweeps: usize,
        tol: f64,
    ) {
        let mut rng = Pcg64::seed(seed);
        let marg = empirical_marginals(sampler, &mut rng, burn_in, sweeps);
        let want = exact::enumerate(g);
        for v in 0..g.num_vars() {
            assert!(
                (marg[v] - want.marginals[v]).abs() < tol,
                "{}: var {v}: {} vs exact {} (tol {tol})",
                sampler.name(),
                marg[v],
                want.marginals[v]
            );
        }
    }
}
