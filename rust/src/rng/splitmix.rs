//! SplitMix64 — Steele et al.'s fixed-increment generator.
//!
//! Used for seed expansion (one u64 → arbitrarily many well-mixed u64s)
//! and anywhere a cheap standalone stream is needed. Period 2^64.

use super::RngCore;

/// SplitMix64 generator.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a raw seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }
}

impl RngCore for SplitMix64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vector() {
        // Reference values for seed 1234567 (from the published algorithm).
        let mut sm = SplitMix64::new(1234567);
        let v1 = sm.next_u64();
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(v1, sm2.next_u64());
        assert_ne!(v1, sm.next_u64());
    }

    #[test]
    fn zero_seed_is_fine() {
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, 0);
        assert_ne!(a, b);
    }
}
